"""Shared 65 nm stand-in model card + circuit constants (single source of truth).

These constants parameterize the square-law + body-effect device model used
by the Pallas kernel (L1), the jnp oracle, and — mirrored via
``artifacts/params.json`` — the Rust native simulator. Calibration targets
(see DESIGN.md §6):

* dVTH(V_bulk = 0.6 V) ~= -125 mV  (paper Fig. 3)
* WL margin [VTH_eff, WL_MAX]: [0.30, 0.70] V baseline -> [0.175, 0.70] V
  with body bias (paper §III); we use VTH0 = 0.425 V with a -0.425..-0.30 V
  *design* margin interpretation: the DAC's usable range starts at the
  effective threshold.
"""

from __future__ import annotations

import json
from dataclasses import asdict, dataclass, field


@dataclass(frozen=True)
class DeviceCard:
    """65 nm NMOS access-transistor card (M2acc in the paper's Fig. 1)."""

    vdd: float = 1.0            # V   — cell supply (paper Table 1: SMART/AID)
    vth0: float = 0.30          # V   — zero-bias threshold (low-VT access
                                #       device; paper §III: WL margin starts
                                #       at 300 mV baseline, 175 mV biased)
    gamma: float = 0.306        # sqrt(V) — body-effect coefficient (Eq. 6);
                                #       gives dVTH(0.6 V) = -125 mV (Fig. 3)
    phi2f: float = 0.88         # V   — 2*phi_F surface potential (Eq. 6)
    mu_cox: float = 180e-6      # A/V^2 — process transconductance mu_n*Cox
    w_over_l: float = 3.0       # —   — W/L = 195 nm / 65 nm
    lam: float = 0.08           # 1/V — channel-length modulation
    n_sub: float = 1.5          # —   — subthreshold slope factor
    vt_thermal: float = 0.026   # V   — kT/q at 300 K
    k_leak: float = 1e-4        # —   — relative off-path (bit = 0) leakage


@dataclass(frozen=True)
class CircuitCard:
    """Bitline / timing / DAC constants for the 4x4-bit MAC column."""

    c_blb: float = 30e-15       # F  — BLB sampling capacitance
    wl_max: float = 0.70        # V  — top of the usable WL range (paper §III)
    t_sample: float = 0.12e-9   # s  — WL pulse width at the sampling instant:
                                #      ~0.6x the SMART max-code WL_PW_MAX of
                                #      Eq. 4, leaving a >3-sigma mismatch guard
                                #      band before triode entry; identical for
                                #      all variants per the paper's "same WL
                                #      timing" setup
    n_steps: int = 256          # —  — transient integration steps
    n_bits: int = 4             # —  — operand bit width (4x4-bit MAC)
    v_bulk_smart: float = 0.6   # V  — SMART forward body bias (dual-VDD rail)
    sigma_vth: float = 8e-3     # V  — Pelgrom sigma(VTH) for the MC stand-in
    sigma_beta: float = 0.02    # —  — relative sigma(beta)


@dataclass(frozen=True)
class Params:
    device: DeviceCard = field(default_factory=DeviceCard)
    circuit: CircuitCard = field(default_factory=CircuitCard)

    def to_json(self) -> str:
        return json.dumps(asdict(self), indent=2, sort_keys=True)


DEFAULT = Params()

# DAC mode selectors (traced scalar in the L2 model; mirrored in rust/src/dac)
DAC_LINEAR = 0.0   # Eq. 7 — IMAC [9]:  V_WL = VTH + code/(2^N-1) * (WL_MAX - VTH)
DAC_SQRT = 1.0     # Eq. 8 — AID  [10]: V_WL = VTH + sqrt(code/(2^N-1)) * (WL_MAX - VTH)


def delta_vth_body(gamma: float, phi2f: float, v_bulk: float) -> float:
    """Eq. 6 threshold shift for a forward body bias of ``v_bulk`` volts.

    V_SB = -v_bulk (source at ~0 V, bulk raised), so
    dVTH = gamma * (sqrt(2phi_F - v_bulk) - sqrt(2phi_F)) < 0.
    """
    inner = max(phi2f - v_bulk, 0.0)
    return gamma * (inner**0.5 - phi2f**0.5)


if __name__ == "__main__":  # quick calibration readout
    d = DEFAULT.device
    for vb in (0.0, 0.2, 0.4, 0.6):
        print(f"v_bulk={vb:.1f}  dVTH={delta_vth_body(d.gamma, d.phi2f, vb)*1e3:+.1f} mV")
