"""L1 — Pallas kernel: batched BLB-discharge transient integrator.

The compute hot-spot of the reproduction: for every (MC sample x bit-cell),
integrate the access-transistor discharge ODE (paper Eq. 1-3) over
``n_steps`` fixed timesteps and emit the sampled V_BLB.

TPU mapping (DESIGN.md §3 — Hardware-Adaptation): the grid tiles the MC
batch axis; each program instance pulls one (TILE, CELLS) parameter block
HBM->VMEM once, runs the whole time loop on-chip (no per-step HBM traffic),
and writes the sampled voltages back once. ``interpret=True`` is mandatory
on this CPU-PJRT image; on a real TPU the same BlockSpec schedule holds.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from ..params import DEFAULT

_D = DEFAULT.device

# Batch tile: small enough that (TILE, CELLS) f32 blocks for 4 operands plus
# the state fit comfortably in VMEM (~16 KiB at TILE=128, CELLS=4 per ref),
# large enough to amortize the grid overhead.
TILE = 128


def _discharge_body(
    vwl_ref, vth_ref, beta_ref, bits_ref, scal_ref, o_ref,
    *, n_steps: int, lam: float, n_sub: float, vt: float, k_leak: float,
):
    """Kernel body. ``scal_ref`` holds [dt/c_blb, vdd] (runtime scalars)."""
    vwl = vwl_ref[...]
    vth = vth_ref[...]
    beta = beta_ref[...]
    bits = bits_ref[...]
    dt_over_c = scal_ref[0]
    vdd = scal_ref[1]

    # Time-invariant quantities hoisted out of the loop.
    vov = vwl - vth
    gate = jnp.where(bits > 0.5, 1.0, k_leak)
    on = vov > 0.0
    half_bv2 = 0.5 * beta * vov * vov          # saturation prefactor
    i_sub0 = beta * vt * vt * jnp.exp(jnp.minimum(vov, 0.0) / (n_sub * vt))

    def step(_, v):
        clm = 1.0 + lam * v
        i_sat = half_bv2 * clm
        i_tri = beta * (vov - 0.5 * v) * v * clm
        i_on = jnp.where(v >= vov, i_sat, i_tri)
        i_off = i_sub0 * (1.0 - jnp.exp(-jnp.maximum(v, 0.0) / vt))
        # above threshold: square-law floored at the subthreshold current
        # (continuous moderate-inversion handoff; matches ref.py and the
        # Rust device model)
        i = jnp.where(on, jnp.maximum(jnp.maximum(i_on, 0.0), i_off), i_off) * gate
        return jnp.maximum(v - i * dt_over_c, 0.0)

    v0 = jnp.full_like(vwl, vdd)
    o_ref[...] = jax.lax.fori_loop(0, n_steps, step, v0)


@functools.partial(jax.jit, static_argnames=("n_steps",))
def discharge(
    vwl: jnp.ndarray,       # (B, CELLS) f32
    vth_eff: jnp.ndarray,   # (B, CELLS) f32
    beta: jnp.ndarray,      # (B, CELLS) f32
    bits: jnp.ndarray,      # (B, CELLS) f32 in {0,1}
    dt_over_c: jnp.ndarray,  # () f32 — dt / C_BLB, traced so t_sample sweeps
    vdd: jnp.ndarray,        # () f32 — precharge voltage
    *,
    n_steps: int = DEFAULT.circuit.n_steps,
) -> jnp.ndarray:
    """Sampled V_BLB, shape (B, CELLS). Pads B up to a TILE multiple."""
    b, cells = vwl.shape
    tile = min(TILE, b) if b % TILE else TILE
    if b % tile:
        pad = tile - b % tile
        padder = lambda a: jnp.pad(a, ((0, pad), (0, 0)))
        vwl, vth_eff, beta, bits = map(padder, (vwl, vth_eff, beta, bits))
    bp = vwl.shape[0]
    scal = jnp.stack([dt_over_c.astype(jnp.float32), vdd.astype(jnp.float32)])

    kernel = functools.partial(
        _discharge_body,
        n_steps=n_steps,
        lam=_D.lam,
        n_sub=_D.n_sub,
        vt=_D.vt_thermal,
        k_leak=_D.k_leak,
    )
    block = pl.BlockSpec((tile, cells), lambda i: (i, 0))
    out = pl.pallas_call(
        kernel,
        grid=(bp // tile,),
        in_specs=[block, block, block, block,
                  pl.BlockSpec((2,), lambda i: (0,))],
        out_specs=block,
        out_shape=jax.ShapeDtypeStruct((bp, cells), jnp.float32),
        interpret=True,  # CPU PJRT cannot run Mosaic custom-calls
    )(vwl, vth_eff, beta, bits, scal)
    return out[:b]
