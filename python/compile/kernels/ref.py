"""Pure-jnp oracle for the discharge kernel and the MAC model.

This module is the CORE correctness signal: the Pallas kernel in
``discharge.py`` and the Rust native simulator must both agree with these
functions (pytest on the Python side, integration tests on the Rust side).

Physics (paper Eq. 1-6, square-law NMOS with body effect):

    I_sat = 1/2 * beta * Vov^2 * (1 + lam*V)          V >= Vov  (saturation)
    I_tri = beta * (Vov - V/2) * V * (1 + lam*V)      V <  Vov  (triode)
    I_sub = beta * Vt^2 * exp(Vov/(n*Vt)) * (1-e^{-V/Vt})   Vov <= 0
    C_blb * dV/dt = -I(V)                              (Eq. 1)
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from ..params import DEFAULT

_D = DEFAULT.device


def device_current(
    v_blb: jnp.ndarray,
    vov: jnp.ndarray,
    beta: jnp.ndarray,
    *,
    lam: float = _D.lam,
    n_sub: float = _D.n_sub,
    vt: float = _D.vt_thermal,
) -> jnp.ndarray:
    """Region-aware drain current of the access transistor (drain = BLB).

    Above threshold the square-law is floored at the Vov = 0 subthreshold
    current so the weak->strong inversion handoff is continuous and
    monotone in V_GS (EKV-style moderate inversion). Mirrored in
    `rust/src/device/model.rs::drain_current_vov`.
    """
    clm = 1.0 + lam * v_blb
    i_sat = 0.5 * beta * vov * vov * clm
    i_tri = beta * (vov - 0.5 * v_blb) * v_blb * clm
    i_on = jnp.where(v_blb >= vov, i_sat, i_tri)
    # subthreshold: exp saturates at Vov = 0 so the on/off branches meet there
    i_sub = (
        beta
        * vt
        * vt
        * jnp.exp(jnp.minimum(vov, 0.0) / (n_sub * vt))
        * (1.0 - jnp.exp(-jnp.maximum(v_blb, 0.0) / vt))
    )
    return jnp.where(vov > 0.0, jnp.maximum(jnp.maximum(i_on, 0.0), i_sub), i_sub)


def discharge_ref(
    vwl: jnp.ndarray,      # (..., cells) word-line voltage per cell
    vth_eff: jnp.ndarray,  # (..., cells) effective threshold (mismatch + body)
    beta: jnp.ndarray,     # (..., cells) transconductance factor
    bits: jnp.ndarray,     # (..., cells) stored bit in {0,1}: gates the path
    *,
    dt: float,
    n_steps: int,
    c_blb: float = DEFAULT.circuit.c_blb,
    vdd: float = _D.vdd,
    k_leak: float = _D.k_leak,
) -> jnp.ndarray:
    """Integrate the BLB discharge for ``n_steps`` of ``dt``; returns V_BLB(t_s).

    A stored 1 (Q=VDD, Qbar=0) opens the M2acc->M3 path; a stored 0 leaves
    only a ``k_leak``-scaled leakage path (VGS - VTH << 0).
    """
    vov = vwl - vth_eff
    gate = jnp.where(bits > 0.5, 1.0, k_leak)

    def body(_, v):
        i = device_current(v, vov, beta) * gate
        return jnp.maximum(v - i * (dt / c_blb), 0.0)

    v0 = jnp.full_like(vwl, vdd)
    return jax.lax.fori_loop(0, n_steps, body, v0)


def discharge_trace_ref(
    vwl, vth_eff, beta, bits, *, dt, n_steps, stride,
    c_blb=DEFAULT.circuit.c_blb, vdd=_D.vdd, k_leak=_D.k_leak,
):
    """Like :func:`discharge_ref` but returns V_BLB at every ``stride`` steps:
    shape (n_steps // stride, ..., cells). Used for the Fig. 5/6 waveforms."""
    vov = vwl - vth_eff
    gate = jnp.where(bits > 0.5, 1.0, k_leak)

    def step(v, _):
        def inner(_, vv):
            i = device_current(vv, vov, beta) * gate
            return jnp.maximum(vv - i * (dt / c_blb), 0.0)

        v = jax.lax.fori_loop(0, stride, inner, v)
        return v, v

    v0 = jnp.full_like(vwl, vdd)
    _, trace = jax.lax.scan(step, v0, None, length=n_steps // stride)
    return trace
