"""L1 — Pallas kernel: multi-row dot-product discharge (the A in MAC).

The paper's Fig. 7 array generalized to its intended workload: R rows are
activated simultaneously, every row storing a 4-bit weight; the rows'
access currents SUM onto the shared bitlines, so the sampled discharge is
the analog dot product sum_r(a_r * f(b_r)) — one vector-matrix-multiply
column per call. This is how IMAC-class accelerators batch NN layers.

ODE per (batch, cell-column):  C_bl * dV/dt = -sum_r I_r(V)

Grid tiles the MC/batch axis; each program instance holds its
(TILE, R, CELLS) parameter block in VMEM and runs the shared-bitline time
loop on-chip. interpret=True (CPU PJRT).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from ..params import DEFAULT

_D = DEFAULT.device

# Smaller batch tile than the single-row kernel: the block is R times
# bigger per batch element ((TILE, R, 4) x 4 operands in VMEM).
TILE = 16


def _dot_body(
    vwl_ref, vth_ref, beta_ref, bits_ref, scal_ref, o_ref,
    *, n_steps: int, lam: float, n_sub: float, vt: float, k_leak: float,
):
    """Kernel body. Refs: (TILE, R, CELLS); scal_ref holds [dt/c_bl, vdd]."""
    vwl = vwl_ref[...]
    vth = vth_ref[...]
    beta = beta_ref[...]
    bits = bits_ref[...]
    dt_over_c = scal_ref[0]
    vdd = scal_ref[1]

    vov = vwl - vth
    gate = jnp.where(bits > 0.5, 1.0, k_leak)
    on = vov > 0.0
    half_bv2 = 0.5 * beta * vov * vov
    i_sub0 = beta * vt * vt * jnp.exp(jnp.minimum(vov, 0.0) / (n_sub * vt))

    def row_current(v):
        # v: (TILE, 1, CELLS) broadcast against per-row params
        clm = 1.0 + lam * v
        i_sat = half_bv2 * clm
        i_tri = beta * (vov - 0.5 * v) * v * clm
        i_on = jnp.where(v >= vov, i_sat, i_tri)
        i_off = i_sub0 * (1.0 - jnp.exp(-jnp.maximum(v, 0.0) / vt))
        return jnp.where(on, jnp.maximum(jnp.maximum(i_on, 0.0), i_off), i_off) * gate

    def step(_, v):
        # shared bitline: sum currents over the row axis
        i_total = jnp.sum(row_current(v[:, None, :]), axis=1)
        return jnp.maximum(v - i_total * dt_over_c, 0.0)

    v0 = jnp.full(vwl.shape[:1] + vwl.shape[2:], vdd, vwl.dtype)  # (TILE, CELLS)
    o_ref[...] = jax.lax.fori_loop(0, n_steps, step, v0)


@functools.partial(jax.jit, static_argnames=("n_steps",))
def dot_discharge(
    vwl: jnp.ndarray,       # (B, R, CELLS) f32 — per-row word-line voltage
    vth_eff: jnp.ndarray,   # (B, R, CELLS) f32
    beta: jnp.ndarray,      # (B, R, CELLS) f32
    bits: jnp.ndarray,      # (B, R, CELLS) f32 in {0,1}
    dt_over_c: jnp.ndarray,  # () f32 — dt / C_BL (traced)
    vdd: jnp.ndarray,        # () f32
    *,
    n_steps: int = DEFAULT.circuit.n_steps,
) -> jnp.ndarray:
    """Shared-bitline V_BL at the sampling instant, shape (B, CELLS)."""
    b, r, cells = vwl.shape
    tile = min(TILE, b) if b % TILE else TILE
    if b % tile:
        pad = tile - b % tile
        padder = lambda a: jnp.pad(a, ((0, pad), (0, 0), (0, 0)))
        vwl, vth_eff, beta, bits = map(padder, (vwl, vth_eff, beta, bits))
    bp = vwl.shape[0]
    scal = jnp.stack([dt_over_c.astype(jnp.float32), vdd.astype(jnp.float32)])

    kernel = functools.partial(
        _dot_body,
        n_steps=n_steps,
        lam=_D.lam,
        n_sub=_D.n_sub,
        vt=_D.vt_thermal,
        k_leak=_D.k_leak,
    )
    block3 = pl.BlockSpec((tile, r, cells), lambda i: (i, 0, 0))
    out = pl.pallas_call(
        kernel,
        grid=(bp // tile,),
        in_specs=[block3, block3, block3, block3,
                  pl.BlockSpec((2,), lambda i: (0,))],
        out_specs=pl.BlockSpec((tile, cells), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((bp, cells), jnp.float32),
        interpret=True,
    )(vwl, vth_eff, beta, bits, scal)
    return out[:b]


def dot_discharge_ref(vwl, vth_eff, beta, bits, *, dt, n_steps,
                      c_bl=DEFAULT.circuit.c_blb, vdd=_D.vdd, k_leak=_D.k_leak):
    """Pure-jnp oracle of the shared-bitline dot-product discharge."""
    from . import ref

    vov = vwl - vth_eff
    gate = jnp.where(bits > 0.5, 1.0, k_leak)

    def body(_, v):
        i_rows = ref.device_current(v[..., None, :], vov, beta) * gate
        i_total = jnp.sum(i_rows, axis=-2)
        return jnp.maximum(v - i_total * (dt / c_bl), 0.0)

    v0 = jnp.full(vwl.shape[:-2] + vwl.shape[-1:], vdd, vwl.dtype)
    return jax.lax.fori_loop(0, n_steps, body, v0)
