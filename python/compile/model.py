"""L2 — JAX model of the 4x4-bit in-SRAM analog MAC column (paper §II-III).

The full compute graph the Rust coordinator executes at campaign time:

    operand A (4 stored bits) , operand B (DAC code)
      -> body-effect VTH shift (Eq. 6) from the V_bulk input
      -> DAC word-line coding (Eq. 7 linear / Eq. 8 sqrt, traced mode flag)
      -> per-cell BLB discharge transient   [L1 Pallas kernel]
      -> binary-weighted charge-share combine -> V_multiplication
      -> dynamic-energy accounting (sum C*VDD*dV)

Everything is a single jitted function, AOT-lowered by ``aot.py`` to HLO
text. Python never runs at campaign time.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .kernels import discharge as dk
from .kernels import dotprod as dot
from .kernels import ref
from .params import DEFAULT

_D = DEFAULT.device
_C = DEFAULT.circuit

# Binary weights for the MSB-first 4-cell word (paper Fig. 7: MSB leftmost).
_WEIGHTS = jnp.array([8.0, 4.0, 2.0, 1.0]) / 15.0


def vth_effective(v_bulk: jnp.ndarray, dvth: jnp.ndarray) -> jnp.ndarray:
    """Eq. 6: VTH0 + gamma*(sqrt(2phiF + V_SB) - sqrt(2phiF)) + mismatch.

    V_SB = -v_bulk (forward body bias via the dual-VDD rail), clamped so the
    sqrt argument stays non-negative (junction would forward-bias earlier).
    """
    inner = jnp.maximum(_D.phi2f - v_bulk, 0.0)
    return _D.vth0 + _D.gamma * (jnp.sqrt(inner) - jnp.sqrt(_D.phi2f)) + dvth


def dac_vwl(b_code: jnp.ndarray, vth_design: jnp.ndarray, dac_mode: jnp.ndarray) -> jnp.ndarray:
    """Word-line voltage for DAC code ``b_code`` in [0, 2^N - 1].

    Eq. 7 (mode 0, IMAC [9]):  VWL = VTH + code/(2^N-1) * (WL_MAX - VTH)
    Eq. 8 (mode 1, AID [10]):  VWL = VTH + sqrt(code/(2^N-1)) * (WL_MAX - VTH)
    The sqrt coding linearizes I ~ (VWL - VTH)^2 in the code.
    A zero code keeps the WL at 0 V (no pulse at all).
    """
    full = 2.0**_C.n_bits - 1.0
    frac = b_code / full
    margin = _C.wl_max - vth_design
    lin = vth_design + frac * margin
    sqr = vth_design + jnp.sqrt(frac) * margin
    vwl = jnp.where(dac_mode > 0.5, sqr, lin)
    return jnp.where(b_code > 0.0, vwl, 0.0)


def mac_forward(
    a_bits: jnp.ndarray,    # (B, 4) f32 in {0,1}, MSB first
    b_code: jnp.ndarray,    # (B,)   f32 in [0, 15]
    v_bulk: jnp.ndarray,    # ()     f32 — 0.0 baseline, 0.6 SMART
    dac_mode: jnp.ndarray,  # ()     f32 — 0 linear [9], 1 sqrt [10]
    t_sample: jnp.ndarray,  # ()     f32 — WL pulse width (s)
    dvth: jnp.ndarray,      # (B, 4) f32 — MC threshold mismatch (V)
    dbeta: jnp.ndarray,     # (B, 4) f32 — MC relative beta mismatch
):
    """Returns (v_mult (B,), v_blb (B,4), energy (B,), fault (B,)).

    ``v_mult`` is the binary-weighted discharge voltage — the paper's
    "V_multiplication" axis in Fig. 8/9. ``energy`` is the raw dynamic
    bitline energy sum(C * VDD * dV); fixed per-op overheads (DAC, WL
    driver, body-bias rail) are added by the Rust energy model. ``fault``
    is 1.0 when any conducting cell left saturation before the sampling
    instant (V_BLB < Vov) — the paper's "systematic fault" / worst-case
    incorrect output condition (§II-A).
    """
    b = a_bits.shape[0]
    vth_eff = vth_effective(v_bulk, dvth)
    # The DAC is calibrated to the *nominal* (mismatch-free) threshold: the
    # designer knows v_bulk but not the per-device mismatch.
    vth_nom = vth_effective(v_bulk, jnp.zeros(()))
    vwl = jnp.broadcast_to(dac_vwl(b_code, vth_nom, dac_mode)[:, None], (b, 4))
    beta = _D.mu_cox * _D.w_over_l * (1.0 + dbeta)
    dt_over_c = t_sample / (_C.n_steps * _C.c_blb)
    v_blb = dk.discharge(
        vwl, vth_eff, beta, a_bits,
        dt_over_c.astype(jnp.float32), jnp.float32(_D.vdd),
        n_steps=_C.n_steps,
    )
    dv = _D.vdd - v_blb
    v_mult = dv @ _WEIGHTS
    energy = _C.c_blb * _D.vdd * jnp.sum(dv, axis=-1)
    # Saturation-exit check (Eq. 4's validity condition): a conducting cell
    # whose V_BLB dropped below its overdrive has entered triode -> invalid.
    vov = vwl - vth_eff
    in_triode = (v_blb < vov) & (a_bits > 0.5) & (vov > 0.0)
    fault = jnp.max(in_triode.astype(jnp.float32), axis=-1)
    return v_mult, v_blb, energy, fault


def mac_trace(
    a_bits, b_code, v_bulk, dac_mode, t_total, dvth, dbeta,
    *, n_points: int = 64,
):
    """Waveform variant for Fig. 5/6: V_BLB(t) at ``n_points`` instants,
    shape (n_points, B, 4). Pure-jnp scan (figure path, not the hot path)."""
    b = a_bits.shape[0]
    vth_eff = vth_effective(v_bulk, dvth)
    vth_nom = vth_effective(v_bulk, jnp.zeros(()))
    vwl = jnp.broadcast_to(dac_vwl(b_code, vth_nom, dac_mode)[:, None], (b, 4))
    beta = _D.mu_cox * _D.w_over_l * (1.0 + dbeta)
    stride = _C.n_steps // n_points
    dt = t_total / _C.n_steps
    trace = ref.discharge_trace_ref(
        vwl, vth_eff, beta, a_bits,
        dt=dt, n_steps=_C.n_steps, stride=stride,
    )
    return (trace,)


def mac_forward_tuple(*args):
    """Tuple-returning wrapper for AOT lowering (return_tuple=True)."""
    return tuple(mac_forward(*args))


def dot_forward(
    a_bits: jnp.ndarray,    # (B, R, 4) f32 — R stored 4-bit weights
    b_code: jnp.ndarray,    # (B, R)    f32 — per-row DAC codes (activations)
    v_bulk: jnp.ndarray,    # ()        f32
    dac_mode: jnp.ndarray,  # ()        f32
    t_sample: jnp.ndarray,  # ()        f32 — WL pulse width (s)
    dvth: jnp.ndarray,      # (B, R, 4) f32
    dbeta: jnp.ndarray,     # (B, R, 4) f32
):
    """Multi-row analog dot product on the shared bitlines (Fig. 7 array
    as a VMM column): returns (v_dot (B,), v_bl (B,4), energy (B,), fault (B,)).

    The bitline capacitance scales with the number of attached rows
    (C_bl = C_BLB * R/4), so per-row discharge rates match the single-row
    column and the linear-summation regime is preserved. ``fault`` flags
    any conducting row whose saturation condition V_BL >= Vov broke before
    sampling.
    """
    b, r, _ = a_bits.shape
    c_bl = _C.c_blb * (r / 4.0)
    vth_eff = vth_effective(v_bulk, dvth)
    vth_nom = vth_effective(v_bulk, jnp.zeros(()))
    vwl = jnp.broadcast_to(dac_vwl(b_code, vth_nom, dac_mode)[..., None], (b, r, 4))
    beta = _D.mu_cox * _D.w_over_l * (1.0 + dbeta)
    dt_over_c = t_sample / (_C.n_steps * c_bl)
    v_bl = dot.dot_discharge(
        vwl, vth_eff, beta, a_bits,
        dt_over_c.astype(jnp.float32), jnp.float32(_D.vdd),
        n_steps=_C.n_steps,
    )
    dv = _D.vdd - v_bl
    v_dot = dv @ _WEIGHTS
    energy = c_bl * _D.vdd * jnp.sum(dv, axis=-1)
    vov = vwl - vth_eff
    conducting = (a_bits > 0.5) & (vov > 0.0)
    in_triode = (v_bl[:, None, :] < vov) & conducting
    fault = jnp.max(in_triode.astype(jnp.float32), axis=(-2, -1))
    return v_dot, v_bl, energy, fault


def dot_forward_tuple(*args):
    return tuple(dot_forward(*args))


def dot_example_args(batch: int, rows: int):
    """ShapeDtypeStructs matching ``dot_forward`` for (batch, rows)."""
    f32 = jnp.float32
    s = jax.ShapeDtypeStruct
    return (
        s((batch, rows, 4), f32),
        s((batch, rows), f32),
        s((), f32),
        s((), f32),
        s((), f32),
        s((batch, rows, 4), f32),
        s((batch, rows, 4), f32),
    )


def example_args(batch: int):
    """ShapeDtypeStructs matching ``mac_forward``'s signature for ``batch``."""
    f32 = jnp.float32
    s = jax.ShapeDtypeStruct
    return (
        s((batch, 4), f32),   # a_bits
        s((batch,), f32),     # b_code
        s((), f32),           # v_bulk
        s((), f32),           # dac_mode
        s((), f32),           # t_sample
        s((batch, 4), f32),   # dvth
        s((batch, 4), f32),   # dbeta
    )
