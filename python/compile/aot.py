"""AOT lowering: jax (L2 + L1) -> HLO *text* artifacts for the Rust runtime.

HLO text (NOT ``lowered.compiler_ir('hlo').as_serialized_hlo_module_proto()``)
is the interchange format: jax >= 0.5 emits protos with 64-bit instruction
ids that the image's xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``);
the text parser reassigns ids and round-trips cleanly. See
/opt/xla-example/gen_hlo.py.

Emits into ``artifacts/``:
    mac_b{B}.hlo.txt     — mac_forward for each batch size B
    trace_b{B}.hlo.txt   — mac_trace waveform variant
    params.json          — the model card mirrored to the Rust side
    manifest.json        — artifact -> (entry, batch, inputs) index
"""

from __future__ import annotations

import argparse
import json
import os

import jax
from jax._src.lib import xla_client as xc

from . import model
from .params import DEFAULT

MAC_BATCHES = (1, 256, 1024)
TRACE_BATCHES = (8,)
TRACE_POINTS = 64
DOT_ROWS = 16
DOT_BATCHES = (16, 64)


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_mac(batch: int) -> str:
    lowered = jax.jit(model.mac_forward_tuple).lower(*model.example_args(batch))
    return to_hlo_text(lowered)


def lower_trace(batch: int) -> str:
    fn = lambda *a: model.mac_trace(*a, n_points=TRACE_POINTS)
    lowered = jax.jit(fn).lower(*model.example_args(batch))
    return to_hlo_text(lowered)


def lower_dot(batch: int, rows: int) -> str:
    lowered = jax.jit(model.dot_forward_tuple).lower(*model.dot_example_args(batch, rows))
    return to_hlo_text(lowered)


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts/model.hlo.txt",
                    help="path of the primary artifact; siblings land next to it")
    args = ap.parse_args()
    outdir = os.path.dirname(os.path.abspath(args.out)) or "."
    os.makedirs(outdir, exist_ok=True)

    manifest = {"artifacts": [], "mac_batches": list(MAC_BATCHES),
                "trace_batches": list(TRACE_BATCHES), "trace_points": TRACE_POINTS,
                "dot_batches": list(DOT_BATCHES), "dot_rows": DOT_ROWS,
                "n_steps": DEFAULT.circuit.n_steps}

    for b in MAC_BATCHES:
        path = os.path.join(outdir, f"mac_b{b}.hlo.txt")
        text = lower_mac(b)
        with open(path, "w") as f:
            f.write(text)
        manifest["artifacts"].append(
            {"name": f"mac_b{b}", "path": os.path.basename(path),
             "kind": "mac", "batch": b})
        print(f"wrote {path} ({len(text)} chars)")

    for b in DOT_BATCHES:
        path = os.path.join(outdir, f"dot_r{DOT_ROWS}_b{b}.hlo.txt")
        text = lower_dot(b, DOT_ROWS)
        with open(path, "w") as f:
            f.write(text)
        manifest["artifacts"].append(
            {"name": f"dot_r{DOT_ROWS}_b{b}", "path": os.path.basename(path),
             "kind": "dot", "batch": b, "rows": DOT_ROWS})
        print(f"wrote {path} ({len(text)} chars)")

    for b in TRACE_BATCHES:
        path = os.path.join(outdir, f"trace_b{b}.hlo.txt")
        text = lower_trace(b)
        with open(path, "w") as f:
            f.write(text)
        manifest["artifacts"].append(
            {"name": f"trace_b{b}", "path": os.path.basename(path),
             "kind": "trace", "batch": b, "n_points": TRACE_POINTS})
        print(f"wrote {path} ({len(text)} chars)")

    with open(os.path.join(outdir, "params.json"), "w") as f:
        f.write(DEFAULT.to_json())
    with open(os.path.join(outdir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)

    # Primary artifact expected by the Makefile stamp rule.
    primary = lower_mac(MAC_BATCHES[1])
    with open(args.out, "w") as f:
        f.write(primary)
    print(f"wrote {args.out} (primary, batch={MAC_BATCHES[1]})")


if __name__ == "__main__":
    main()
