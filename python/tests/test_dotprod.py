"""Dot-product (multi-row, shared-bitline) kernel vs oracle + semantics."""

import hypothesis.strategies as st
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings

from compile import model
from compile.kernels import dotprod as dp
from compile.params import DEFAULT

_C = DEFAULT.circuit
_D = DEFAULT.device
F32 = jnp.float32
R = 4  # small row count keeps hypothesis cases fast; AOT uses 16


def run_pair(vwl, vth, beta, bits, t_s, n_steps, c_bl):
    dt = t_s / n_steps
    out_k = dp.dot_discharge(
        vwl, vth, beta, bits,
        jnp.float32(dt / c_bl), jnp.float32(_D.vdd), n_steps=n_steps,
    )
    out_r = dp.dot_discharge_ref(vwl, vth, beta, bits, dt=dt, n_steps=n_steps, c_bl=c_bl)
    return np.asarray(out_k), np.asarray(out_r)


@given(
    batch=st.sampled_from([1, 3, 16, 20]),
    rows=st.sampled_from([1, 4, 16]),
    seed=st.integers(0, 2**31 - 1),
)
@settings(deadline=None, max_examples=15, derandomize=True)
def test_dot_kernel_matches_ref(batch, rows, seed):
    rng = np.random.default_rng(seed)
    shape = (batch, rows, 4)
    vwl = jnp.asarray(rng.uniform(0.0, 0.7, shape), F32)
    vth = jnp.asarray(rng.uniform(0.15, 0.4, shape), F32)
    beta = jnp.asarray(rng.uniform(2e-4, 8e-4, shape), F32)
    bits = jnp.asarray(rng.integers(0, 2, shape), F32)
    c_bl = _C.c_blb * rows / 4.0
    k, r = run_pair(vwl, vth, beta, bits, 0.05e-9, 64, c_bl)
    np.testing.assert_allclose(k, r, rtol=1e-5, atol=1e-6)


def dot_run(weights, codes, v_bulk=0.0, t_scale=1.0):
    """Helper: run model.dot_forward for one batch element."""
    r = len(weights)
    a = np.zeros((1, r, 4), np.float32)
    for i, w in enumerate(weights):
        a[0, i] = [(w >> 3) & 1, (w >> 2) & 1, (w >> 1) & 1, w & 1]
    b = np.asarray([codes], np.float32)
    z = jnp.zeros((1, r, 4), F32)
    # Fixed full-scale across row counts: C_bl scales with R, so the
    # all-rows-max discharge equals the single-row MAC full-scale when
    # t = t_sample / 4 (independent of R).
    t_s = _C.t_sample / 4.0 * t_scale
    return model.dot_forward(
        jnp.asarray(a), jnp.asarray(b), F32(v_bulk), F32(1.0), F32(t_s), z, z
    )


def test_single_row_dot_equals_mac():
    """R=1 dot product must reduce to the single-row MAC (same C scaling)."""
    vd, _, _, fault = dot_run([15], [15.0])
    bits = jnp.ones((1, 4), F32)
    code = jnp.full((1,), 15.0, F32)
    z = jnp.zeros((1, 4), F32)
    # R=1: C_bl = c_blb/4 and t = t_sample/4 -> identical dt/C to the MAC
    vm, _, _, _ = model.mac_forward(
        bits, code, F32(0.0), F32(1.0), F32(_C.t_sample), z, z
    )
    assert abs(float(vd[0]) - float(vm[0])) < 1e-3
    assert float(fault[0]) == 0.0


def test_dot_is_additive_across_rows():
    """In the linear (saturation) regime the discharge sums over rows."""
    v1, _, _, _ = dot_run([9, 0, 0, 0], [12.0, 0, 0, 0])
    v2, _, _, _ = dot_run([0, 0, 5, 0], [0, 0, 7.0, 0])
    v12, _, _, _ = dot_run([9, 0, 5, 0], [12.0, 0, 7.0, 0])
    assert abs(float(v12[0]) - float(v1[0]) - float(v2[0])) < 3e-3


def test_dot_tracks_integer_dot_product():
    """With sqrt DAC, v_dot is proportional to sum_r(a_r * b_r)."""
    rng = np.random.default_rng(5)
    full, _, _, _ = dot_run([15] * R, [15.0] * R)
    fs = float(full[0]) / (R * 225.0)
    for _ in range(6):
        w = rng.integers(0, 16, R).tolist()
        c = rng.integers(0, 16, R).astype(float).tolist()
        vd, _, _, fault = dot_run(w, c)
        ideal = sum(a * b for a, b in zip(w, c)) * fs
        assert float(fault[0]) == 0.0
        assert abs(float(vd[0]) - ideal) < 0.04 * float(full[0]) + 1e-3, (w, c)


def test_dot_fault_on_deep_discharge():
    _, _, _, fault = dot_run([15] * R, [15.0] * R, t_scale=12.0)
    assert float(fault[0]) == 1.0


def test_dot_body_bias_enlarges_signal():
    base, _, _, _ = dot_run([15] * R, [15.0] * R, v_bulk=0.0)
    smart, _, _, _ = dot_run([15] * R, [15.0] * R, v_bulk=0.6)
    assert float(smart[0]) > float(base[0]) * 1.3
