"""L2 model invariants: DAC coding, body effect, MAC semantics, energy."""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from compile import model
from compile.params import DEFAULT, delta_vth_body

_D = DEFAULT.device
_C = DEFAULT.circuit
F32 = jnp.float32


def run(a: int, b: int, v_bulk=0.0, dac_mode=1.0, batch=1, dvth=None, dbeta=None,
        t_sample=_C.t_sample):
    bits = jnp.asarray(
        np.tile([(a >> 3) & 1, (a >> 2) & 1, (a >> 1) & 1, a & 1], (batch, 1)),
        F32,
    )
    code = jnp.full((batch,), float(b), F32)
    z = jnp.zeros((batch, 4), F32)
    return model.mac_forward(
        bits, code, F32(v_bulk), F32(dac_mode), F32(t_sample),
        z if dvth is None else dvth, z if dbeta is None else dbeta,
    )


# ---------------------------------------------------------------- DAC / Eq. 6-8


def test_vth_effective_matches_eq6():
    for vb in (0.0, 0.2, 0.4, 0.6):
        got = float(model.vth_effective(F32(vb), jnp.zeros(())))
        want = _D.vth0 + delta_vth_body(_D.gamma, _D.phi2f, vb)
        assert abs(got - want) < 1e-6


def test_body_bias_shift_is_125mv():
    """Fig. 3 calibration: dVTH(V_bulk = 0.6 V) ~= -125 mV."""
    shift = float(model.vth_effective(F32(0.6), jnp.zeros(()))) - _D.vth0
    assert -0.130 < shift < -0.120


def test_dac_linear_levels_equispaced():
    vth = jnp.zeros(()) + 0.3
    lv = [float(model.dac_vwl(F32(c), vth, F32(0.0))) for c in range(1, 16)]
    steps = np.diff(lv)
    np.testing.assert_allclose(steps, steps[0], rtol=1e-5)
    assert abs(lv[-1] - _C.wl_max) < 1e-6


def test_dac_sqrt_linearizes_current():
    """Eq. 8: with sqrt coding, (VWL - VTH)^2 is proportional to the code."""
    vth = jnp.zeros(()) + 0.3
    for c in range(1, 16):
        vwl = float(model.dac_vwl(F32(c), vth, F32(1.0)))
        lhs = (vwl - 0.3) ** 2
        rhs = (c / 15.0) * (_C.wl_max - 0.3) ** 2
        assert abs(lhs - rhs) < 1e-6


def test_dac_zero_code_grounds_wl():
    vth = jnp.zeros(()) + 0.3
    for mode in (0.0, 1.0):
        assert float(model.dac_vwl(F32(0.0), vth, F32(mode))) == 0.0


def test_dac_range_widens_with_body_bias():
    """Paper §III: margin [300, 700] mV -> [175, 700] mV under 0.6 V bias."""
    lo_base = float(model.vth_effective(F32(0.0), jnp.zeros(())))
    lo_smart = float(model.vth_effective(F32(0.6), jnp.zeros(())))
    assert abs(lo_base - 0.300) < 1e-3
    assert abs(lo_smart - 0.175) < 2e-3
    assert (_C.wl_max - lo_smart) > (_C.wl_max - lo_base)


# ---------------------------------------------------------------- MAC semantics


def test_zero_operand_zero_output():
    for a, b in [(0, 9), (11, 0), (0, 0)]:
        vm, _, _, fault = run(a, b)
        assert abs(float(vm[0])) < 2e-3
        assert float(fault[0]) == 0.0


def test_output_monotone_in_both_operands():
    vm_grid = np.array(
        [[float(run(a, b)[0][0]) for b in range(16)] for a in range(16)]
    )
    # monotone (non-strict at 0) along both axes
    assert np.all(np.diff(vm_grid, axis=0) >= -1e-6)
    assert np.all(np.diff(vm_grid, axis=1) >= -1e-6)
    # strictly increasing along the max row/col
    assert np.all(np.diff(vm_grid[15, 1:]) > 0)
    assert np.all(np.diff(vm_grid[1:, 15]) > 0)


def test_binary_weighting_of_stored_bits():
    """With sqrt coding (current linear in code), the stored-operand weighting
    is exactly binary: v_mult(A) proportional to A at fixed B."""
    vms = np.array([float(run(a, 15)[0][0]) for a in range(16)])
    ratio = vms[1:] / vms[15]
    np.testing.assert_allclose(ratio, np.arange(1, 16) / 15.0, rtol=5e-3)


def test_sqrt_coding_linear_in_b_code():
    vms = np.array([float(run(15, b, dac_mode=1.0)[0][0]) for b in range(16)])
    ideal = vms[15] * np.arange(16) / 15.0
    np.testing.assert_allclose(vms, ideal, atol=0.015 * vms[15])


def test_linear_coding_quadratic_in_b_code():
    """IMAC's Eq. 7 coding makes the discharge ~quadratic in the code — the
    systematic nonlinearity that dominates its error (Table 1: sigma 0.6)."""
    vms = np.array([float(run(15, b, dac_mode=0.0)[0][0]) for b in range(16)])
    lin_err = np.abs(vms - vms[15] * np.arange(16) / 15.0).max()
    quad = vms[15] * (np.arange(16) / 15.0) ** 2
    quad_err = np.abs(vms - quad).max()
    assert quad_err < lin_err * 0.35


def test_smart_enlarges_signal_at_same_timing():
    """Same WL timing, body bias on -> faster discharge -> larger full-scale."""
    base = float(run(15, 15, v_bulk=0.0)[0][0])
    smart = float(run(15, 15, v_bulk=0.6)[0][0])
    assert smart > base * 1.3


def test_no_fault_at_design_timing():
    """At the calibrated t_sample every nominal code stays in saturation."""
    for vb in (0.0, 0.6):
        for b in range(16):
            _, _, _, fault = run(15, b, v_bulk=vb)
            assert float(fault[0]) == 0.0, (vb, b)


def test_fault_flag_raises_on_overlong_pulse():
    _, _, _, fault = run(15, 15, v_bulk=0.6, t_sample=2e-9)
    assert float(fault[0]) == 1.0


def test_energy_scales_with_discharge():
    _, _, e_small, _ = run(1, 3)
    _, _, e_big, _ = run(15, 15)
    assert float(e_big[0]) > float(e_small[0]) * 5


def test_energy_matches_cv_dv():
    _, vblb, energy, _ = run(15, 15)
    dv = _D.vdd - np.asarray(vblb)
    want = _C.c_blb * _D.vdd * dv.sum()
    assert abs(float(energy[0]) - want) < 1e-18


# ---------------------------------------------------------------- MC behaviour


@given(seed=st.integers(0, 2**31 - 1))
@settings(deadline=None, max_examples=10, derandomize=True)
def test_mismatch_spreads_output(seed):
    rng = np.random.default_rng(seed)
    batch = 64
    dvth = jnp.asarray(rng.normal(0, _C.sigma_vth, (batch, 4)), F32)
    dbeta = jnp.asarray(rng.normal(0, _C.sigma_beta, (batch, 4)), F32)
    vm, _, _, _ = run(15, 15, batch=batch, dvth=dvth, dbeta=dbeta)
    vm = np.asarray(vm)
    assert vm.std() > 1e-4          # mismatch spreads
    assert vm.std() < 0.15 * vm.mean()  # but stays a perturbation


def test_smart_reduces_relative_spread():
    """The headline claim: body bias -> lower normalized MC sigma (Fig. 8)."""
    rng = np.random.default_rng(42)
    batch = 256
    dvth = jnp.asarray(rng.normal(0, _C.sigma_vth, (batch, 4)), F32)
    dbeta = jnp.asarray(rng.normal(0, _C.sigma_beta, (batch, 4)), F32)
    spreads = {}
    for name, vb in [("base", 0.0), ("smart", 0.6)]:
        vm, _, _, _ = run(15, 15, v_bulk=vb, batch=batch, dvth=dvth, dbeta=dbeta)
        vm = np.asarray(vm)
        spreads[name] = vm.std() / vm.mean()
    assert spreads["smart"] < spreads["base"] * 0.85


def test_trace_shape_and_monotonicity():
    bits = jnp.ones((2, 4), F32)
    code = jnp.full((2,), 15.0, F32)
    z = jnp.zeros((2, 4), F32)
    (tr,) = model.mac_trace(
        bits, code, F32(0.0), F32(1.0), F32(1e-9), z, z, n_points=32
    )
    tr = np.asarray(tr)
    assert tr.shape == (32, 2, 4)
    assert np.all(np.diff(tr, axis=0) <= 1e-7)
