"""AOT lowering sanity: HLO text parses structurally, manifest is coherent."""

import json
import os

import pytest

from compile import aot, model
from compile.params import DEFAULT


@pytest.fixture(scope="module")
def hlo_b1():
    return aot.lower_mac(1)


def test_hlo_text_has_entry(hlo_b1):
    assert "ENTRY" in hlo_b1
    assert "HloModule" in hlo_b1


def test_hlo_text_shapes(hlo_b1):
    # 7 ENTRY parameters: a_bits, b_code, v_bulk, dac_mode, t_sample, dvth,
    # dbeta (nested fusion computations have their own parameters, so count
    # only within the ENTRY block — it is the last computation in the text).
    entry = hlo_b1[hlo_b1.rindex("ENTRY") :]
    assert entry.count("parameter(") == 7
    # tuple of 4 results: v_mult, v_blb, energy, fault
    assert "f32[1,4]" in hlo_b1  # a_bits / v_blb shape


def test_hlo_no_custom_calls(hlo_b1):
    """interpret=True must lower the Pallas kernel to plain HLO — a Mosaic
    custom-call would be unloadable by the CPU PJRT client."""
    assert "custom-call" not in hlo_b1.lower() or "mosaic" not in hlo_b1.lower()


def test_trace_lowering():
    text = aot.lower_trace(8)
    assert "ENTRY" in text
    assert f"f32[{aot.TRACE_POINTS},8,4]" in text


def test_example_args_signature():
    args = model.example_args(16)
    assert len(args) == 7
    assert args[0].shape == (16, 4)
    assert args[1].shape == (16,)
    assert args[2].shape == ()


def test_params_json_roundtrip():
    d = json.loads(DEFAULT.to_json())
    assert d["device"]["vth0"] == pytest.approx(0.30)
    assert d["circuit"]["n_bits"] == 4
    assert d["circuit"]["c_blb"] == pytest.approx(30e-15)


def test_artifacts_if_built():
    """When `make artifacts` has run, check the manifest indexes real files."""
    art = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")
    man = os.path.join(art, "manifest.json")
    if not os.path.exists(man):
        pytest.skip("artifacts not built")
    with open(man) as f:
        m = json.load(f)
    assert m["n_steps"] == DEFAULT.circuit.n_steps
    for a in m["artifacts"]:
        p = os.path.join(art, a["path"])
        assert os.path.exists(p), p
        with open(p) as f:
            head = f.read(4096)
        assert "HloModule" in head
