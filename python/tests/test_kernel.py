"""Pallas discharge kernel vs pure-jnp oracle — the CORE correctness signal.

Hypothesis sweeps shapes and physical parameter ranges; every case asserts
allclose between the interpret-mode Pallas kernel and ``kernels.ref``.
"""

import hypothesis
import hypothesis.strategies as st
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings

from compile.kernels import discharge as dk
from compile.kernels import ref
from compile.params import DEFAULT

_C = DEFAULT.circuit
_D = DEFAULT.device

hypothesis.settings.register_profile(
    "kernels", deadline=None, max_examples=25, derandomize=True
)
hypothesis.settings.load_profile("kernels")


def run_pair(vwl, vth, beta, bits, t_s, n_steps, c_blb=_C.c_blb, vdd=_D.vdd):
    dt = t_s / n_steps
    out_k = dk.discharge(
        vwl, vth, beta, bits,
        jnp.float32(dt / c_blb), jnp.float32(vdd), n_steps=n_steps,
    )
    out_r = ref.discharge_ref(
        vwl, vth, beta, bits, dt=dt, n_steps=n_steps, c_blb=c_blb, vdd=vdd,
    )
    return np.asarray(out_k), np.asarray(out_r)


@given(
    batch=st.sampled_from([1, 2, 5, 16, 128, 130, 256]),
    cells=st.sampled_from([1, 4, 8]),
    seed=st.integers(0, 2**31 - 1),
    t_ns=st.floats(0.01, 1.0),
)
def test_kernel_matches_ref_random(batch, cells, seed, t_ns):
    rng = np.random.default_rng(seed)
    vwl = jnp.asarray(rng.uniform(0.0, 0.75, (batch, cells)), jnp.float32)
    vth = jnp.asarray(rng.uniform(0.1, 0.5, (batch, cells)), jnp.float32)
    beta = jnp.asarray(rng.uniform(1e-4, 1e-3, (batch, cells)), jnp.float32)
    bits = jnp.asarray(rng.integers(0, 2, (batch, cells)), jnp.float32)
    k, r = run_pair(vwl, vth, beta, bits, t_ns * 1e-9, 64)
    np.testing.assert_allclose(k, r, rtol=1e-5, atol=1e-6)


@given(
    vwl=st.floats(0.0, 0.75),
    vth=st.floats(0.1, 0.5),
    bit=st.sampled_from([0.0, 1.0]),
)
def test_kernel_matches_ref_scalar_corners(vwl, vth, bit):
    shape = (1, 4)
    k, r = run_pair(
        jnp.full(shape, vwl, jnp.float32),
        jnp.full(shape, vth, jnp.float32),
        jnp.full(shape, _D.mu_cox * _D.w_over_l, jnp.float32),
        jnp.full(shape, bit, jnp.float32),
        _C.t_sample, _C.n_steps,
    )
    np.testing.assert_allclose(k, r, rtol=1e-5, atol=1e-6)


def test_zero_wl_no_discharge():
    """WL at 0 V (code 0): VGS = 0 << VTH -> only femtoscale subthreshold."""
    shape = (4, 4)
    k, _ = run_pair(
        jnp.zeros(shape, jnp.float32),
        jnp.full(shape, 0.3, jnp.float32),
        jnp.full(shape, 540e-6, jnp.float32),
        jnp.ones(shape, jnp.float32),
        _C.t_sample, _C.n_steps,
    )
    assert np.all(k > _D.vdd - 1e-3)


def test_stored_zero_blocks_path():
    """bit = 0 leaves only k_leak-scaled leakage: ~1e4x less discharge."""
    shape = (2, 4)
    args = (
        jnp.full(shape, 0.7, jnp.float32),
        jnp.full(shape, 0.3, jnp.float32),
        jnp.full(shape, 540e-6, jnp.float32),
    )
    on, _ = run_pair(*args, jnp.ones(shape, jnp.float32), _C.t_sample, _C.n_steps)
    off, _ = run_pair(*args, jnp.zeros(shape, jnp.float32), _C.t_sample, _C.n_steps)
    dv_on = _D.vdd - on
    dv_off = _D.vdd - off
    assert np.all(dv_off < dv_on * 1e-2)
    assert np.all(dv_off >= 0.0)


def test_discharge_monotonic_in_vwl():
    """Higher WL voltage -> strictly more discharge (saturation region)."""
    vwls = np.linspace(0.35, 0.7, 12)
    shape = (1, 4)
    dvs = []
    for v in vwls:
        k, _ = run_pair(
            jnp.full(shape, v, jnp.float32),
            jnp.full(shape, 0.3, jnp.float32),
            jnp.full(shape, 540e-6, jnp.float32),
            jnp.ones(shape, jnp.float32),
            _C.t_sample, _C.n_steps,
        )
        dvs.append(_D.vdd - float(k[0, 0]))
    assert np.all(np.diff(dvs) > 0)


def test_body_bias_accelerates_discharge():
    """Fig. 5/6: suppressed VTH (body bias) -> faster BLB discharge."""
    shape = (1, 4)
    common = (
        jnp.full(shape, 0.55, jnp.float32),
        jnp.full(shape, 540e-6, jnp.float32),
        jnp.ones(shape, jnp.float32),
    )
    base, _ = run_pair(common[0] * 0 + 0.55, jnp.full(shape, 0.300, jnp.float32),
                       common[1], common[2], _C.t_sample, _C.n_steps)
    smart, _ = run_pair(common[0] * 0 + 0.55, jnp.full(shape, 0.175, jnp.float32),
                        common[1], common[2], _C.t_sample, _C.n_steps)
    assert np.all(smart < base - 0.02)


def test_voltage_never_negative():
    """Even absurdly long pulses clamp at 0 V, never undershoot."""
    shape = (3, 4)
    k, r = run_pair(
        jnp.full(shape, 0.7, jnp.float32),
        jnp.full(shape, 0.15, jnp.float32),
        jnp.full(shape, 5e-3, jnp.float32),
        jnp.ones(shape, jnp.float32),
        50e-9, 128,
    )
    assert np.all(k >= 0.0) and np.all(r >= 0.0)
    np.testing.assert_allclose(k, r, rtol=1e-5, atol=1e-6)


def test_tile_padding_roundtrip():
    """Batch sizes straddling the TILE boundary agree with an unpadded run."""
    rng = np.random.default_rng(7)
    big = 130  # 128 + 2 -> exercises the pad/unpad path
    vwl = jnp.asarray(rng.uniform(0.3, 0.7, (big, 4)), jnp.float32)
    vth = jnp.asarray(rng.uniform(0.15, 0.35, (big, 4)), jnp.float32)
    beta = jnp.full((big, 4), 540e-6, jnp.float32)
    bits = jnp.ones((big, 4), jnp.float32)
    full, _ = run_pair(vwl, vth, beta, bits, _C.t_sample, 64)
    head, _ = run_pair(vwl[:64], vth[:64], beta[:64], bits[:64], _C.t_sample, 64)
    np.testing.assert_allclose(full[:64], head, rtol=1e-6, atol=1e-7)


def test_dtype_is_f32():
    out = dk.discharge(
        jnp.ones((2, 4)), jnp.full((2, 4), 0.3), jnp.full((2, 4), 5e-4),
        jnp.ones((2, 4)), jnp.float32(1e-12 / 30e-15), jnp.float32(1.0),
        n_steps=8,
    )
    assert out.dtype == jnp.float32


def test_trace_ref_endpoint_matches_discharge_ref():
    """The last trace sample equals the single-shot integration."""
    rng = np.random.default_rng(3)
    shape = (5, 4)
    vwl = jnp.asarray(rng.uniform(0.3, 0.7, shape), jnp.float32)
    vth = jnp.asarray(rng.uniform(0.15, 0.35, shape), jnp.float32)
    beta = jnp.full(shape, 540e-6, jnp.float32)
    bits = jnp.asarray(rng.integers(0, 2, shape), jnp.float32)
    dt = _C.t_sample / 64
    tr = ref.discharge_trace_ref(vwl, vth, beta, bits, dt=dt, n_steps=64, stride=8)
    end = ref.discharge_ref(vwl, vth, beta, bits, dt=dt, n_steps=64)
    np.testing.assert_allclose(np.asarray(tr)[-1], np.asarray(end), rtol=1e-6)
    assert tr.shape == (8, 5, 4)
    # traces are monotonically non-increasing in time
    assert np.all(np.diff(np.asarray(tr), axis=0) <= 1e-7)
