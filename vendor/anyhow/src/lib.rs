//! Offline stand-in for the `anyhow` crate: the subset of its API this
//! workspace uses, implemented over a plain message chain.
//!
//! The build environment resolves crates offline, so instead of the real
//! `anyhow` this path dependency provides compatible `Error`, `Result`,
//! `Context`, and the `anyhow!` / `bail!` / `ensure!` macros. Semantics
//! mirror upstream where it matters to callers:
//!
//! * `{e}` displays the outermost message; `{e:#}` joins the whole
//!   context chain with `": "` (what the CLI prints).
//! * `?` converts any `std::error::Error + Send + Sync + 'static` into
//!   [`Error`], capturing its `source()` chain.
//! * Like upstream, [`Error`] deliberately does NOT implement
//!   `std::error::Error` (that is what makes the blanket `From` legal).

use std::fmt;

/// An error carrying a chain of context messages, outermost first.
pub struct Error {
    chain: Vec<String>,
}

impl Error {
    /// Build an error from a displayable message (what `anyhow!` expands to).
    pub fn msg<M: fmt::Display>(message: M) -> Self {
        Self { chain: vec![message.to_string()] }
    }

    /// Wrap with an outer context message (used by the [`Context`] trait).
    pub fn context<C: fmt::Display>(mut self, context: C) -> Self {
        self.chain.insert(0, context.to_string());
        self
    }

    /// The context chain, outermost first.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.chain.iter().map(String::as_str)
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            f.write_str(&self.chain.join(": "))
        } else {
            f.write_str(&self.chain[0])
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.chain[0])?;
        if self.chain.len() > 1 {
            f.write_str("\n\nCaused by:")?;
            for cause in &self.chain[1..] {
                write!(f, "\n    {cause}")?;
            }
        }
        Ok(())
    }
}

impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Self {
        let mut chain = vec![e.to_string()];
        let mut src = e.source();
        while let Some(s) = src {
            chain.push(s.to_string());
            src = s.source();
        }
        Self { chain }
    }
}

/// `anyhow::Result<T>` — `Result` with [`Error`] as the default error type.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Extension trait adding `.context(..)` / `.with_context(..)`.
pub trait Context<T, E> {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, context: C) -> Result<T>;
    fn with_context<C: fmt::Display + Send + Sync + 'static, F: FnOnce() -> C>(
        self,
        f: F,
    ) -> Result<T>;
}

impl<T, E: Into<Error>> Context<T, E> for std::result::Result<T, E> {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, context: C) -> Result<T> {
        self.map_err(|e| e.into().context(context))
    }

    fn with_context<C: fmt::Display + Send + Sync + 'static, F: FnOnce() -> C>(
        self,
        f: F,
    ) -> Result<T> {
        self.map_err(|e| e.into().context(f()))
    }
}

impl<T> Context<T, std::convert::Infallible> for Option<T> {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, context: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C: fmt::Display + Send + Sync + 'static, F: FnOnce() -> C>(
        self,
        f: F,
    ) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string or any `Display` value.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
}

/// Return early with an error built by [`anyhow!`].
#[macro_export]
macro_rules! bail {
    ($($t:tt)*) => {
        return Err($crate::anyhow!($($t)*))
    };
}

/// Return early with an error unless a condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            return Err($crate::anyhow!("condition failed: {}", stringify!($cond)));
        }
    };
    ($cond:expr, $($t:tt)+) => {
        if !($cond) {
            return Err($crate::anyhow!($($t)+));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "file gone")
    }

    #[test]
    fn display_plain_vs_alternate() {
        let e: Error = Error::from(io_err()).context("loading manifest");
        assert_eq!(format!("{e}"), "loading manifest");
        assert_eq!(format!("{e:#}"), "loading manifest: file gone");
    }

    #[test]
    fn macros_build_messages() {
        let x = 3;
        let e = anyhow!("bad value {x}");
        assert_eq!(format!("{e}"), "bad value 3");
        let e = anyhow!(String::from("owned"));
        assert_eq!(format!("{e}"), "owned");
        let e = anyhow!("{} and {}", 1, 2);
        assert_eq!(format!("{e}"), "1 and 2");
    }

    #[test]
    fn bail_and_ensure() {
        fn f(ok: bool) -> Result<()> {
            ensure!(ok, "not ok");
            bail!("reached the end")
        }
        assert_eq!(format!("{}", f(false).unwrap_err()), "not ok");
        assert_eq!(format!("{}", f(true).unwrap_err()), "reached the end");
        fn g(x: u32) -> Result<u32> {
            ensure!(x > 1);
            Ok(x)
        }
        assert!(format!("{}", g(0).unwrap_err()).contains("x > 1"));
        assert_eq!(g(2).unwrap(), 2);
    }

    #[test]
    fn context_on_result_and_option() {
        let r: std::result::Result<(), std::io::Error> = Err(io_err());
        let e = r.with_context(|| format!("step {}", 7)).unwrap_err();
        assert_eq!(format!("{e:#}"), "step 7: file gone");
        let o: Option<u8> = None;
        let e = Context::context(o, "missing key").unwrap_err();
        assert_eq!(format!("{e}"), "missing key");
    }

    #[test]
    fn question_mark_captures_source_chain() {
        fn inner() -> Result<()> {
            Err(io_err())?;
            Ok(())
        }
        let e = inner().unwrap_err();
        assert_eq!(e.chain().count(), 1);
        let wrapped = Err::<(), _>(e).context("outer").unwrap_err();
        assert_eq!(wrapped.chain().next(), Some("outer"));
    }
}
