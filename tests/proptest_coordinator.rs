//! Property tests over the coordinator invariants (in-tree prop driver —
//! see `rust/src/util/prop.rs`).

use std::collections::HashSet;

use smart_insram::coordinator::{Batcher, RowTag};
use smart_insram::mac::{reconstruct, IdealTransfer, Variant};
use smart_insram::metrics::OnlineStats;
use smart_insram::montecarlo::MismatchSampler;
use smart_insram::params::Params;
use smart_insram::prop_assert;
use smart_insram::util::prop::{check, Gen};

fn mk_batcher(g: &mut Gen) -> (Batcher, usize, u32, usize) {
    let p = Params::default();
    let variant = *g.pick(&Variant::ALL);
    let cfg = variant.config(&p);
    let n_ops = g.usize_in(1, 6);
    let operands: Vec<(u8, u8)> = (0..n_ops)
        .map(|_| (g.u8_in(0, 15), g.u8_in(0, 15)))
        .collect();
    let n_mc = g.usize_in(1, 300) as u32;
    let batch = g.usize_in(1, 64);
    let seed = g.u64(1 << 40);
    let b = Batcher::new(
        operands,
        n_mc,
        batch,
        (&cfg).into(),
        MismatchSampler::new(seed, p.circuit.sigma_vth, p.circuit.sigma_beta),
    );
    (b, n_ops, n_mc, batch)
}

/// Every (operand, mc) item appears exactly once; pads only in the last
/// batch; all batches have exactly `batch` rows.
#[test]
fn batcher_covers_items_exactly_once() {
    check(0xBA7C4, 60, |g| {
        let (batcher, n_ops, n_mc, batch) = mk_batcher(g);
        let expect_batches = batcher.n_batches();
        let mut seen = HashSet::new();
        let mut n_batches = 0u64;
        let mut pads = 0usize;
        for pb in batcher {
            n_batches += 1;
            prop_assert!(pb.tags.len() == batch, "short batch {}", pb.tags.len());
            prop_assert!(pb.inputs.len() == batch, "inputs len mismatch");
            let is_last = n_batches == expect_batches;
            for t in &pb.tags {
                match *t {
                    RowTag::Item { op_idx, mc_idx, a, b } => {
                        prop_assert!(a < 16 && b < 16, "bad operands {a},{b}");
                        prop_assert!(
                            seen.insert((op_idx, mc_idx)),
                            "duplicate item {op_idx}/{mc_idx}"
                        );
                    }
                    RowTag::Pad => {
                        pads += 1;
                        prop_assert!(is_last, "pad before the last batch");
                    }
                }
            }
        }
        let total = n_ops as u64 * u64::from(n_mc);
        prop_assert!(seen.len() as u64 == total, "covered {} of {total}", seen.len());
        prop_assert!(n_batches == expect_batches, "{n_batches} != {expect_batches}");
        prop_assert!(
            n_batches * batch as u64 == total + pads as u64,
            "row accounting broken"
        );
        Ok(())
    });
}

/// The batcher's mismatch stream is identical across re-instantiations
/// (bit-reproducible campaigns).
#[test]
fn batcher_is_deterministic() {
    check(0xDE7E2, 25, |g| {
        let p = Params::default();
        let cfg = Variant::Aid.config(&p);
        let seed = g.u64(1 << 40);
        let n_mc = g.usize_in(1, 100) as u32;
        let batch = g.usize_in(1, 32);
        let mk = || {
            Batcher::new(
                vec![(15, 15)],
                n_mc,
                batch,
                (&cfg).into(),
                MismatchSampler::new(seed, p.circuit.sigma_vth, p.circuit.sigma_beta),
            )
        };
        for (x, y) in mk().zip(mk()) {
            prop_assert!(x.tags == y.tags, "tags diverged");
            prop_assert!(x.inputs.dvth == y.inputs.dvth, "dvth diverged");
            prop_assert!(x.inputs.dbeta == y.inputs.dbeta, "dbeta diverged");
        }
        Ok(())
    });
}

/// Welford merge == sequential accumulation for arbitrary splits.
#[test]
fn welford_merge_associative() {
    check(0x3EF0 , 50, |g| {
        let n = g.usize_in(2, 400);
        let xs: Vec<f64> = (0..n).map(|_| g.normal(1.0) + g.f64_in(-2.0, 2.0)).collect();
        let cut = g.usize_in(1, n - 1);
        let mut whole = OnlineStats::new();
        xs.iter().for_each(|&x| whole.push(x));
        let mut a = OnlineStats::new();
        let mut b = OnlineStats::new();
        xs[..cut].iter().for_each(|&x| a.push(x));
        xs[cut..].iter().for_each(|&x| b.push(x));
        a.merge(&b);
        prop_assert!((a.mean() - whole.mean()).abs() < 1e-10, "mean mismatch");
        prop_assert!(
            (a.variance() - whole.variance()).abs() < 1e-10,
            "variance mismatch"
        );
        prop_assert!(a.count() == whole.count(), "count mismatch");
        Ok(())
    });
}

/// Reconstruction is the left inverse of the ideal transfer on the exact
/// product grid, and clamps to [0, 225] everywhere.
#[test]
fn reconstruct_inverts_ideal_transfer() {
    check(0x1DEA1, 40, |g| {
        let fs = g.f64_in(0.05, 0.8);
        let t = IdealTransfer { full_scale: fs };
        let a = g.u8_in(0, 15);
        let b = g.u8_in(0, 15);
        let v = t.v_ideal(a, b);
        let got = reconstruct(&t, v);
        prop_assert!(
            got == u16::from(a) * u16::from(b),
            "{a}x{b}: reconstructed {got}"
        );
        let noisy = reconstruct(&t, v + g.normal(fs * 10.0));
        prop_assert!(noisy <= 225, "clamp broken: {noisy}");
        Ok(())
    });
}

/// Campaign spec TOML round-trips for arbitrary valid specs.
#[test]
fn spec_toml_roundtrip_random() {
    use smart_insram::coordinator::{CampaignSpec, Workload};
    use smart_insram::montecarlo::Corner;
    check(0x70771, 60, |g| {
        let spec = CampaignSpec {
            variant: *g.pick(&Variant::ALL),
            workload: match g.u64(3) {
                0 => Workload::Fixed { a: g.u8_in(0, 15), b: g.u8_in(0, 15) },
                1 => Workload::FullSweep,
                _ => Workload::Random { n_ops: g.usize_in(1, 5000) as u32 },
            },
            n_mc: g.usize_in(1, 100_000) as u32,
            seed: g.u64(1 << 53),
            corner: *g.pick(&[Corner::Tt, Corner::Ff, Corner::Ss]),
            workers: g.usize_in(0, 16),
            batch: g.usize_in(0, 2048),
            shards: g.usize_in(0, 64),
            block: g.usize_in(0, 512),
            kernel: *g.pick(&smart_insram::mac::KernelKind::ALL),
        };
        let doc = smart_insram::util::toml_lite::parse(&spec.to_toml())
            .map_err(|e| format!("parse: {e}"))?;
        let arr = doc.get("campaigns").unwrap().as_arr().unwrap();
        let back = CampaignSpec::from_value(&arr[0]).map_err(|e| format!("from_value: {e}"))?;
        prop_assert!(back == spec, "roundtrip mismatch: {spec:?} -> {back:?}");
        Ok(())
    });
}

/// JSON parser round-trips arbitrary value trees built from the generator.
#[test]
fn json_roundtrip_random_trees() {
    use smart_insram::util::json::{parse, to_string_pretty, Value};
    fn gen_value(g: &mut Gen, depth: usize) -> Value {
        match if depth == 0 { g.u64(4) } else { g.u64(6) } {
            0 => Value::Null,
            1 => Value::Bool(g.bool()),
            2 => Value::Num((g.f64_in(-1e6, 1e6) * 1e3).round() / 1e3),
            3 => Value::Str(format!("s{}-\"q\"-\n", g.u64(1000))),
            4 => Value::Arr((0..g.usize_in(0, 4)).map(|_| gen_value(g, depth - 1)).collect()),
            _ => Value::Obj(
                (0..g.usize_in(0, 4))
                    .map(|i| (format!("k{i}"), gen_value(g, depth - 1)))
                    .collect(),
            ),
        }
    }
    check(0x150_u64, 80, |g| {
        let v = gen_value(g, 3);
        let text = to_string_pretty(&v);
        let back = parse(&text).map_err(|e| format!("{e}"))?;
        prop_assert!(back == v, "roundtrip mismatch");
        Ok(())
    });
}

/// Dot-product additivity: in the saturation regime the shared-bitline
/// discharge of disjoint row sets sums (linear charge-domain accumulation).
#[test]
fn dot_engine_additive_over_disjoint_rows() {
    use smart_insram::mac::NativeDotEngine;
    use smart_insram::montecarlo::McSample;
    check(0xD07, 30, |g| {
        let p = Params::default();
        let variant = *g.pick(&[Variant::Smart, Variant::Aid]);
        let e = NativeDotEngine::new(p, variant.config(&p), 8);
        let nom = vec![McSample::nominal(); 8];
        let mut w1 = vec![0u8; 8];
        let mut c1 = vec![0u8; 8];
        let mut w2 = vec![0u8; 8];
        let mut c2 = vec![0u8; 8];
        let mut wj = vec![0u8; 8];
        let mut cj = vec![0u8; 8];
        for r in 0..8 {
            let (w, c) = (g.u8_in(0, 15), g.u8_in(0, 15));
            if g.bool() {
                w1[r] = w;
                c1[r] = c;
            } else {
                w2[r] = w;
                c2[r] = c;
            }
            wj[r] = w1[r].max(w2[r]);
            cj[r] = c1[r].max(c2[r]);
        }
        let a = e.dot(&w1, &c1, &nom).v_dot;
        let b = e.dot(&w2, &c2, &nom).v_dot;
        let joint = e.dot(&wj, &cj, &nom);
        prop_assert!(!joint.fault, "design point must stay in saturation");
        prop_assert!(
            (joint.v_dot - a - b).abs() < 8e-3,
            "additivity broke: {} vs {a} + {b}",
            joint.v_dot
        );
        Ok(())
    });
}

/// Histogram conservation: every push lands in exactly one bin.
#[test]
fn histogram_conserves_counts() {
    use smart_insram::metrics::Histogram;
    check(0x415706, 40, |g| {
        let lo = g.f64_in(-2.0, 0.0);
        let hi = lo + g.f64_in(0.1, 3.0);
        let mut h = Histogram::new(lo, hi, g.usize_in(1, 50));
        let n = g.usize_in(1, 500);
        for _ in 0..n {
            h.push(g.f64_in(lo - 1.0, hi + 1.0)); // includes out-of-range
        }
        let total: u64 = h.counts().iter().sum();
        prop_assert!(total == n as u64, "lost samples: {total} != {n}");
        prop_assert!(h.total() == n as u64, "total() disagrees");
        Ok(())
    });
}

/// toml_lite never panics on arbitrary printable input (fuzz-light).
#[test]
fn toml_lite_total_on_garbage() {
    use smart_insram::util::toml_lite::parse;
    check(0x70F2, 200, |g| {
        let len = g.usize_in(0, 120);
        let charset: Vec<char> =
            "abz=[]{}#\".\n\t 0123456789-_,eE+".chars().collect();
        let s: String = (0..len).map(|_| *g.pick(&charset)).collect();
        let _ = parse(&s); // Ok or Err both fine; must not panic
        Ok(())
    });
}
