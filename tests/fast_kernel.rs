//! Fast surrogate kernel tier (DESIGN.md §13): `FastKernel` must track
//! the bit-exact `ScalarKernel` oracle within the committed error-bound
//! contract on every bit-line endpoint, agree with it on every
//! saturation-exit fault flag, and preserve the campaign layer's
//! shard/thread/block byte-identity within the fast tier. The golden
//! bounds live in `configs/fast_tol.toml`; this suite re-measures them
//! and fails on any drift above the committed values, writing the
//! measurements to `target/fast_tol_report.json` for CI.

use std::collections::BTreeMap;
use std::path::Path;
use std::str::FromStr;

use smart_insram::coordinator::{run_campaign, Backend, CampaignReport, CampaignSpec, Workload};
use smart_insram::mac::{
    FastKernel, KernelKind, NativeMacEngine, ScalarKernel, SimKernel, TrialBlock, Variant,
    FAST_TOLERANCE,
};
use smart_insram::montecarlo::{Corner, MismatchSampler};
use smart_insram::params::Params;
use smart_insram::prop_assert;
use smart_insram::util::json::{to_string_pretty, Value};
use smart_insram::util::prop::check;

/// Worst lane error and fault census of one fast-vs-oracle block run.
struct Measured {
    /// max |v_blb(fast) - v_blb(oracle)| over all 4*n_words lanes, on the
    /// f32 endpoints the public API reports.
    max_abs_dv: f64,
    /// oracle fault flags raised (the fast kernel agreed on every one —
    /// asserted before this is returned)
    faults: u32,
}

/// Run the deterministic fixture block (operands a=(i*5+3)%16, b=i%16 so
/// all 16 DAC codes appear) through both kernels and compare endpoints.
fn measure(
    variant: Variant,
    corner: Corner,
    vdd: f64,
    t_sample: Option<f64>,
    n_words: usize,
    seed: u64,
) -> Measured {
    let mut p = Params::default();
    p.device.vdd = vdd;
    let mut cfg = variant.config(&p);
    if let Some(t) = t_sample {
        cfg.t_sample = t;
    }
    let engine = NativeMacEngine::new(p, cfg);

    let mut fast = TrialBlock::with_capacity(n_words);
    fast.reset(n_words);
    let sampler = MismatchSampler::new(seed, p.circuit.sigma_vth, p.circuit.sigma_beta)
        .with_corner(corner);
    {
        let (dvth, dbeta) = fast.deviates_mut();
        sampler.fill_block(0, dvth, dbeta);
    }
    for i in 0..n_words {
        fast.set_operands(i, ((i * 5 + 3) % 16) as u8, (i % 16) as u8);
    }
    let mut oracle = fast.clone();

    FastKernel::shared().simulate(&engine, &mut fast);
    ScalarKernel.simulate(&engine, &mut oracle);

    let mut max_abs_dv = 0.0f64;
    let mut faults = 0u32;
    let tag = format!("{variant:?}/{corner:?} vdd={vdd} t_sample={t_sample:?}");
    for i in 0..n_words {
        assert_eq!(
            fast.out.fault[i].to_bits(),
            oracle.out.fault[i].to_bits(),
            "{tag}: word {i} fault flag diverged"
        );
        if oracle.out.fault[i] > 0.5 {
            faults += 1;
        }
        for k in 0..4 {
            let dv = f64::from((fast.out.v_blb[i * 4 + k] - oracle.out.v_blb[i * 4 + k]).abs());
            assert!(
                dv <= FAST_TOLERANCE,
                "{tag}: word {i} lane {k} error {dv:e} above FAST_TOLERANCE"
            );
            max_abs_dv = max_abs_dv.max(dv);
        }
    }
    Measured { max_abs_dv, faults }
}

fn fixture_path() -> std::path::PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR")).join("configs/fast_tol.toml")
}

/// Golden regression: re-measure every committed `[[config]]` row of
/// `configs/fast_tol.toml` and fail if the surrogate drifted above its
/// committed bound. The measurements land in `target/fast_tol_report.json`
/// so CI can archive the actual error profile next to the pass/fail bit.
#[test]
fn committed_tolerances_hold_on_the_fixture_grid() {
    let text = std::fs::read_to_string(fixture_path()).unwrap();
    let doc = smart_insram::util::toml_lite::parse(&text).unwrap();

    let global = doc.path(&["global", "max_abs_dv"]).unwrap().as_f64().unwrap();
    assert_eq!(
        global.to_bits(),
        FAST_TOLERANCE.to_bits(),
        "global.max_abs_dv must mirror mac::FAST_TOLERANCE"
    );
    let n_words = doc.path(&["global", "n_words"]).unwrap().as_u64().unwrap() as usize;
    let seed = doc.path(&["global", "seed"]).unwrap().as_u64().unwrap();

    let rows = doc.get("config").unwrap().as_arr().unwrap();
    assert!(rows.len() >= 10, "fixture grid shrank to {} rows", rows.len());

    let mut report_rows = Vec::new();
    let mut deep_faults = 0u32;
    for row in rows {
        let variant = Variant::from_str(row.get("variant").unwrap().as_str().unwrap()).unwrap();
        let corner = Corner::from_str(row.get("corner").unwrap().as_str().unwrap()).unwrap();
        let vdd = row.get("vdd").unwrap().as_f64().unwrap();
        let t_sample = row.get("t_sample").and_then(Value::as_f64);
        let bound = row.get("max_abs_dv").unwrap().as_f64().unwrap();
        assert!(
            bound <= global,
            "row bound {bound:e} exceeds the global contract {global:e}"
        );

        let m = measure(variant, corner, vdd, t_sample, n_words, seed);
        assert!(
            m.max_abs_dv <= bound,
            "{}/{} vdd={vdd} t_sample={t_sample:?}: measured {:e} drifted above \
             the committed bound {bound:e}",
            variant.token(),
            corner.name(),
            m.max_abs_dv
        );
        if t_sample.is_some() {
            deep_faults += m.faults;
        }

        let mut r = BTreeMap::new();
        r.insert("variant".to_string(), Value::Str(variant.token().to_string()));
        r.insert("corner".to_string(), Value::Str(corner.name().to_string()));
        r.insert("vdd".to_string(), Value::Num(vdd));
        if let Some(t) = t_sample {
            r.insert("t_sample".to_string(), Value::Num(t));
        }
        r.insert("committed_max_abs_dv".to_string(), Value::Num(bound));
        r.insert("measured_max_abs_dv".to_string(), Value::Num(m.max_abs_dv));
        r.insert("oracle_faults".to_string(), Value::Num(f64::from(m.faults)));
        report_rows.push(Value::Obj(r));
    }
    // The grid must actually exercise the saturation-exit table path:
    // the overlong-pulse rows fault on a large fraction of their lanes.
    assert!(deep_faults >= 64, "deep-discharge rows faulted only {deep_faults} words");

    let mut root = BTreeMap::new();
    root.insert("tolerance".to_string(), Value::Num(FAST_TOLERANCE));
    root.insert("n_words".to_string(), Value::Num(n_words as f64));
    root.insert("configs".to_string(), Value::Arr(report_rows));
    let out = Path::new(env!("CARGO_MANIFEST_DIR")).join("target/fast_tol_report.json");
    std::fs::create_dir_all(out.parent().unwrap()).unwrap();
    std::fs::write(&out, to_string_pretty(&Value::Obj(root))).unwrap();
}

/// Property: on random blocks (variant, corner, supply, pulse length,
/// operands, padding), every live lane endpoint stays within
/// [`FAST_TOLERANCE`] of the oracle, fault flags agree bit for bit, and
/// padding lanes stay zeroed.
#[test]
fn fast_endpoints_track_the_oracle_on_random_blocks() {
    check(0xFA57_0007, 32, |g| {
        let mut p = Params::default();
        p.device.vdd = *g.pick(&[1.0, 0.9, 0.8]);
        let variant = *g.pick(&Variant::ALL);
        let mut cfg = variant.config(&p);
        if g.usize_in(0, 3) == 0 {
            cfg.t_sample = 2e-9; // deep discharge: the table is the hot path
        }
        let engine = NativeMacEngine::new(p, cfg);

        let n = g.usize_in(1, 48);
        let mut fast = TrialBlock::with_capacity(n);
        fast.reset(n);
        let sampler =
            MismatchSampler::new(g.u64(1 << 40), p.circuit.sigma_vth, p.circuit.sigma_beta)
                .with_corner(*g.pick(&[Corner::Tt, Corner::Ff, Corner::Ss]));
        {
            let (dvth, dbeta) = fast.deviates_mut();
            sampler.fill_block(g.u64(1 << 20), dvth, dbeta);
        }
        for i in 0..n {
            if g.usize_in(0, 9) == 0 {
                continue; // ~10% padding lanes, left unset
            }
            fast.set_operands(i, g.u8_in(0, 15), g.u8_in(0, 15));
        }
        let mut oracle = fast.clone();

        FastKernel::shared().simulate(&engine, &mut fast);
        ScalarKernel.simulate(&engine, &mut oracle);

        for i in 0..n {
            prop_assert!(
                fast.out.fault[i].to_bits() == oracle.out.fault[i].to_bits(),
                "word {i}: fault flag diverged"
            );
            for k in 0..4 {
                let dv = f64::from((fast.out.v_blb[i * 4 + k] - oracle.out.v_blb[i * 4 + k]).abs());
                prop_assert!(
                    dv <= FAST_TOLERANCE,
                    "word {i} lane {k}: |dv| = {dv:e} above tolerance"
                );
            }
            if fast.is_pad(i) {
                prop_assert!(
                    fast.out.v_mult[i] == 0.0 && fast.out.fault[i] == 0.0,
                    "pad word {i} simulated"
                );
            }
        }
        Ok(())
    });
}

/// Bitwise comparison of the aggregate statistics two campaign reports
/// expose (the same set `tests/shard_determinism.rs` pins).
fn assert_reports_bit_identical(a: &CampaignReport, b: &CampaignReport, label: &str) {
    assert_eq!(a.rows, b.rows, "{label}: rows");
    assert_eq!(a.raw_vmult.mean().to_bits(), b.raw_vmult.mean().to_bits(), "{label}: mean");
    assert_eq!(
        a.raw_vmult.std_dev().to_bits(),
        b.raw_vmult.std_dev().to_bits(),
        "{label}: sigma"
    );
    assert_eq!(
        a.accuracy.sigma_norm.to_bits(),
        b.accuracy.sigma_norm.to_bits(),
        "{label}: sigma_norm"
    );
    assert_eq!(a.accuracy.ber.to_bits(), b.accuracy.ber.to_bits(), "{label}: ber");
    assert_eq!(
        a.accuracy.fault_rate.to_bits(),
        b.accuracy.fault_rate.to_bits(),
        "{label}: fault_rate"
    );
    assert_eq!(a.hist.counts(), b.hist.counts(), "{label}: histogram");
    assert_eq!(a.energy.mean().to_bits(), b.energy.mean().to_bits(), "{label}: energy");
    assert_eq!(a.per_op.len(), b.per_op.len(), "{label}: per_op");
}

/// Within the fast tier, `--shards`/`--threads`/`--block` stay pure
/// performance knobs: aggregates are bit-identical for every choice (the
/// DESIGN.md §9 contract, carried over to the surrogate kernel).
#[test]
fn fast_tier_aggregates_are_shard_thread_block_invariant() {
    let p = Params::default();
    let spec = |shards: usize, workers: usize, block: usize| CampaignSpec {
        variant: Variant::Smart,
        workload: Workload::FullSweep,
        n_mc: 8,
        seed: 2022,
        corner: Corner::Tt,
        workers,
        batch: 0,
        shards,
        block,
        kernel: KernelKind::Fast,
    };
    let base = run_campaign(&p, &spec(1, 1, 0), Backend::Native, None).unwrap();
    assert_eq!(base.rows, 256 * 8);
    for (shards, workers, block) in [(4, 2, 0), (7, 3, 5), (0, 0, 1), (2, 2, 999)] {
        let r = run_campaign(&p, &spec(shards, workers, block), Backend::Native, None).unwrap();
        assert_reports_bit_identical(
            &base,
            &r,
            &format!("shards={shards} workers={workers} block={block}"),
        );
    }
}

/// The surrogate's aggregates land on top of the oracle's: the paper-level
/// statistics a fast-tier campaign reports differ from the scalar tier by
/// no more than the endpoint tolerance allows.
#[test]
fn fast_tier_campaign_statistics_track_the_oracle() {
    let p = Params::default();
    let spec = |kernel| CampaignSpec {
        variant: Variant::Smart,
        workload: Workload::FullSweep,
        n_mc: 8,
        seed: 7,
        corner: Corner::Tt,
        workers: 1,
        batch: 0,
        shards: 1,
        block: 0,
        kernel,
    };
    let fast = run_campaign(&p, &spec(KernelKind::Fast), Backend::Native, None).unwrap();
    let exact = run_campaign(&p, &spec(KernelKind::Scalar), Backend::Native, None).unwrap();
    assert_eq!(fast.rows, exact.rows);
    // v_mult folds 4 lanes with weights summing to 8.52; a per-lane bound
    // of FAST_TOLERANCE bounds the fold by 8.52x that.
    let bound = 10.0 * FAST_TOLERANCE;
    assert!(
        (fast.raw_vmult.mean() - exact.raw_vmult.mean()).abs() <= bound,
        "fast mean {} vs oracle {}",
        fast.raw_vmult.mean(),
        exact.raw_vmult.mean()
    );
    assert_eq!(
        fast.accuracy.fault_rate.to_bits(),
        exact.accuracy.fault_rate.to_bits(),
        "fault rates must agree exactly (flag-level agreement)"
    );
}
