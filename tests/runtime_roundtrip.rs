//! Integration: the AOT/PJRT path against the native Rust oracle.
//!
//! These tests require `make artifacts` (they skip, loudly, if the
//! artifact directory is absent — the Makefile's `test` target builds it
//! first).

use smart_insram::coordinator::{run_campaign, Backend, CampaignSpec, Workload};
use smart_insram::mac::{NativeMacEngine, Variant};
use smart_insram::montecarlo::{McSample, MismatchSampler};
use smart_insram::params::Params;
use smart_insram::runtime::{default_artifact_dir, MacBatch, XlaRuntime};

fn artifacts() -> Option<std::path::PathBuf> {
    let dir = default_artifact_dir();
    if dir.join("manifest.json").exists() {
        Some(dir)
    } else {
        eprintln!("SKIP: artifacts not built (run `make artifacts`)");
        None
    }
}

/// |native_f64 - hlo_f32| tolerance: f32 rounding through 256 Euler steps.
const TOL: f64 = 5e-4;

#[test]
fn params_json_matches_builtin() {
    let Some(dir) = artifacts() else { return };
    let text = std::fs::read_to_string(dir.join("params.json")).unwrap();
    let from_py = Params::load_artifact_json(&text).unwrap();
    assert_eq!(
        from_py,
        Params::default(),
        "python/compile/params.py drifted from rust/src/params.rs"
    );
}

#[test]
fn nominal_mac_matches_native_all_variants() {
    let Some(dir) = artifacts() else { return };
    let params = Params::default();
    let mut rt = XlaRuntime::open(&dir).unwrap();
    let exe = rt.mac_executable(1).unwrap();

    for variant in Variant::ALL {
        let cfg = variant.config(&params);
        let native = NativeMacEngine::new(params, cfg);
        for (a, b) in [(15u8, 15u8), (15, 1), (1, 15), (9, 6), (0, 15), (15, 0)] {
            let mut batch = MacBatch::nominal(
                1,
                cfg.v_bulk as f32,
                cfg.dac_mode.flag(),
                cfg.t_sample as f32,
            );
            batch.set_row(0, a, b, [0.0; 4], [0.0; 4]);
            let out = exe.run(&batch).unwrap();
            let want = native.mac(a, b, &McSample::nominal());
            assert!(
                (f64::from(out.v_mult[0]) - want.v_mult).abs() < TOL,
                "{variant:?} {a}x{b}: hlo {} vs native {}",
                out.v_mult[0],
                want.v_mult
            );
            for k in 0..4 {
                assert!(
                    (f64::from(out.v_blb[k]) - want.v_blb[k]).abs() < TOL,
                    "{variant:?} {a}x{b} cell {k}"
                );
            }
            assert_eq!(out.fault[0] > 0.5, want.fault, "{variant:?} {a}x{b} fault");
        }
    }
}

#[test]
fn mismatch_batch_matches_native() {
    let Some(dir) = artifacts() else { return };
    let params = Params::default();
    let mut rt = XlaRuntime::open(&dir).unwrap();
    let exe = rt.mac_executable(256).unwrap();
    let cfg = Variant::Smart.config(&params);
    let native = NativeMacEngine::new(params, cfg);

    let mut sampler = MismatchSampler::new(99, params.circuit.sigma_vth, params.circuit.sigma_beta);
    let mut batch = MacBatch::nominal(
        256,
        cfg.v_bulk as f32,
        cfg.dac_mode.flag(),
        cfg.t_sample as f32,
    );
    let mut rows = Vec::new();
    for i in 0..256usize {
        let a = (i % 16) as u8;
        let b = ((i / 16) % 16) as u8;
        let mc = sampler.sample();
        batch.set_row(
            i,
            a,
            b,
            mc.dvth.map(|x| x as f32),
            mc.dbeta.map(|x| x as f32),
        );
        rows.push((a, b, mc));
    }
    let out = exe.run(&batch).unwrap();
    let mut worst: f64 = 0.0;
    for (i, (a, b, mc)) in rows.iter().enumerate() {
        // native engine sees the f32-rounded deviates the artifact saw
        let mc32 = McSample {
            dvth: mc.dvth.map(|x| f64::from(x as f32)),
            dbeta: mc.dbeta.map(|x| f64::from(x as f32)),
        };
        let want = native.mac(*a, *b, &mc32);
        let got = f64::from(out.v_mult[i]);
        worst = worst.max((got - want.v_mult).abs());
        assert!(
            (got - want.v_mult).abs() < TOL,
            "row {i} ({a}x{b}): hlo {got} vs native {}",
            want.v_mult
        );
    }
    eprintln!("mismatch_batch_matches_native: worst |delta| = {worst:.2e} V");
}

#[test]
fn energy_output_matches_native() {
    let Some(dir) = artifacts() else { return };
    let params = Params::default();
    let mut rt = XlaRuntime::open(&dir).unwrap();
    let exe = rt.mac_executable(1).unwrap();
    let cfg = Variant::Aid.config(&params);
    let native = NativeMacEngine::new(params, cfg);
    let mut batch = MacBatch::nominal(1, 0.0, cfg.dac_mode.flag(), cfg.t_sample as f32);
    batch.set_row(0, 15, 15, [0.0; 4], [0.0; 4]);
    let out = exe.run(&batch).unwrap();
    let want = native.mac(15, 15, &McSample::nominal()).energy;
    assert!(
        (f64::from(out.energy[0]) - want).abs() < want * 1e-3,
        "hlo {} vs native {want}",
        out.energy[0]
    );
}

#[test]
fn trace_artifact_is_monotone_and_ends_at_discharge() {
    let Some(dir) = artifacts() else { return };
    let params = Params::default();
    let mut rt = XlaRuntime::open(&dir).unwrap();
    let n_points = rt.manifest().trace_points;
    let cfg = Variant::Smart.config(&params);
    let mut batch = MacBatch::nominal(8, cfg.v_bulk as f32, 1.0, cfg.t_sample as f32);
    for i in 0..8 {
        batch.set_row(i, 15, (i * 2) as u8, [0.0; 4], [0.0; 4]);
    }
    let trace = rt.run_trace(&batch, cfg.t_sample as f32).unwrap();
    assert_eq!(trace.len(), n_points * 8 * 4);
    // monotone non-increasing along time for every (row, cell)
    for row in 0..8 {
        for cell in 0..4 {
            for t in 1..n_points {
                let prev = trace[(t - 1) * 32 + row * 4 + cell];
                let cur = trace[t * 32 + row * 4 + cell];
                assert!(cur <= prev + 1e-6, "row {row} cell {cell} t {t}");
            }
        }
    }
}

#[test]
fn xla_campaign_matches_native_campaign() {
    let Some(dir) = artifacts() else { return };
    let params = Params::default();
    let spec = CampaignSpec {
        variant: Variant::Smart,
        workload: Workload::Fixed { a: 15, b: 15 },
        n_mc: 256,
        seed: 7,
        corner: smart_insram::montecarlo::Corner::Tt,
        workers: 2,
        batch: 256,
        shards: 0,
        block: 0,
        kernel: smart_insram::mac::KernelKind::Block,
    };
    let x = run_campaign(&params, &spec, Backend::Xla, Some(dir)).unwrap();
    let n = run_campaign(&params, &spec, Backend::Native, None).unwrap();
    assert_eq!(x.rows, n.rows);
    // same MC stream, different arithmetic precision: stats agree tightly
    assert!(
        (x.raw_vmult.mean() - n.raw_vmult.mean()).abs() < 1e-4,
        "means: xla {} native {}",
        x.raw_vmult.mean(),
        n.raw_vmult.mean()
    );
    assert!((x.raw_vmult.std_dev() - n.raw_vmult.std_dev()).abs() < 1e-4);
    assert_eq!(x.accuracy.ber, n.accuracy.ber);
}

#[test]
fn worker_pool_scales_and_preserves_results() {
    let Some(dir) = artifacts() else { return };
    let params = Params::default();
    let mk = |workers| CampaignSpec {
        variant: Variant::Aid,
        workload: Workload::Fixed { a: 15, b: 15 },
        n_mc: 512,
        seed: 3,
        corner: smart_insram::montecarlo::Corner::Tt,
        workers,
        batch: 256,
        shards: 0,
        block: 0,
        kernel: smart_insram::mac::KernelKind::Block,
    };
    let one = run_campaign(&params, &mk(1), Backend::Xla, Some(dir.clone())).unwrap();
    let four = run_campaign(&params, &mk(4), Backend::Xla, Some(dir)).unwrap();
    assert_eq!(one.rows, four.rows);
    // identical inputs -> identical aggregate stats regardless of workers
    assert!((one.raw_vmult.mean() - four.raw_vmult.mean()).abs() < 1e-9);
    assert!((one.raw_vmult.std_dev() - four.raw_vmult.std_dev()).abs() < 1e-9);
}

#[test]
fn dot_artifact_matches_native_dot_engine() {
    let Some(dir) = artifacts() else { return };
    let params = Params::default();
    let mut rt = XlaRuntime::open(&dir).unwrap();
    let rows = rt.manifest().dot_rows;
    assert_eq!(rows, 16, "manifest dot_rows");
    let exe = rt.dot_executable(16).unwrap();
    let cfg = Variant::Smart.config(&params);
    let native = smart_insram::mac::NativeDotEngine::new(params, cfg, rows);

    let mut sampler = MismatchSampler::new(41, params.circuit.sigma_vth, params.circuit.sigma_beta);
    let mut batch = smart_insram::runtime::DotBatch::nominal(
        16,
        rows,
        cfg.v_bulk as f32,
        cfg.dac_mode.flag(),
        native.t_sample() as f32,
    );
    let mut rng = smart_insram::montecarlo::SplitMix64::new(5);
    let mut rows_data = Vec::new();
    for i in 0..16usize {
        let mut ws = Vec::new();
        let mut cs = Vec::new();
        let mut mcs = Vec::new();
        for r in 0..rows {
            let w = (rng.next_u64() % 16) as u8;
            let c = (rng.next_u64() % 16) as u8;
            let mc = sampler.sample();
            batch.set_row(i, r, w, c, mc.dvth.map(|x| x as f32), mc.dbeta.map(|x| x as f32));
            // native engine sees the f32-rounded deviates the artifact saw
            mcs.push(McSample {
                dvth: mc.dvth.map(|x| f64::from(x as f32)),
                dbeta: mc.dbeta.map(|x| f64::from(x as f32)),
            });
            ws.push(w);
            cs.push(c);
        }
        rows_data.push((ws, cs, mcs));
    }
    let out = exe.run(&batch).unwrap();
    let mut worst: f64 = 0.0;
    for (i, (ws, cs, mcs)) in rows_data.iter().enumerate() {
        let want = native.dot(ws, cs, mcs);
        let got = f64::from(out.v_dot[i]);
        worst = worst.max((got - want.v_dot).abs());
        assert!(
            (got - want.v_dot).abs() < TOL,
            "dot row {i}: hlo {got} vs native {}",
            want.v_dot
        );
        assert_eq!(out.fault[i] > 0.5, want.fault, "dot row {i} fault");
    }
    eprintln!("dot_artifact_matches_native: worst |delta| = {worst:.2e} V");
}

#[test]
fn dot_full_scale_matches_mac_full_scale() {
    let Some(dir) = artifacts() else { return };
    let params = Params::default();
    let mut rt = XlaRuntime::open(&dir).unwrap();
    let rows = rt.manifest().dot_rows;
    let exe = rt.dot_executable(16).unwrap();
    let cfg = Variant::Aid.config(&params);
    let native_mac = NativeMacEngine::new(params, cfg);
    let mut batch = smart_insram::runtime::DotBatch::nominal(
        16,
        rows,
        cfg.v_bulk as f32,
        cfg.dac_mode.flag(),
        (cfg.t_sample / 4.0) as f32,
    );
    for r in 0..rows {
        batch.set_row(0, r, 15, 15, [0.0; 4], [0.0; 4]);
    }
    let out = exe.run(&batch).unwrap();
    let fs_mac = native_mac.full_scale();
    assert!(
        (f64::from(out.v_dot[0]) - fs_mac).abs() < 3e-3,
        "dot FS {} vs mac FS {fs_mac}",
        out.v_dot[0]
    );
}
