//! Failure injection: the coordinator and runtime must fail fast and
//! loudly on broken inputs — no hangs, no silent zeros.

use smart_insram::coordinator::{run_campaign, Backend, CampaignSpec, WorkerPool, Workload};
use smart_insram::mac::Variant;
use smart_insram::montecarlo::Corner;
use smart_insram::params::Params;
use smart_insram::runtime::{default_artifact_dir, MacBatch, XlaRuntime};

fn tmpdir(name: &str) -> std::path::PathBuf {
    let dir = std::env::temp_dir().join(format!("smart_fail_{name}_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

#[test]
fn runtime_rejects_missing_artifact_dir() {
    let err = match XlaRuntime::open("/nonexistent/artifacts") {
        Err(e) => e,
        Ok(_) => panic!("open must fail"),
    };
    let msg = format!("{err:#}");
    assert!(msg.contains("manifest.json"), "{msg}");
}

#[test]
fn runtime_rejects_corrupt_manifest() {
    let dir = tmpdir("corrupt_manifest");
    std::fs::write(dir.join("manifest.json"), "{ not json").unwrap();
    assert!(XlaRuntime::open(&dir).is_err());
}

#[test]
fn runtime_rejects_corrupt_hlo_text() {
    let dir = tmpdir("corrupt_hlo");
    std::fs::write(
        dir.join("manifest.json"),
        r#"{"artifacts": [{"name": "mac_b1", "path": "mac_b1.hlo.txt", "kind": "mac", "batch": 1}],
            "mac_batches": [1], "trace_batches": [], "trace_points": 0, "n_steps": 256}"#,
    )
    .unwrap();
    std::fs::write(dir.join("mac_b1.hlo.txt"), "HloModule garbage\nnot a module").unwrap();
    let mut rt = XlaRuntime::open(&dir).unwrap();
    assert!(rt.mac_executable(1).is_err());
}

#[test]
fn runtime_rejects_unknown_batch_size() {
    let dir = default_artifact_dir();
    if !dir.join("manifest.json").exists() {
        eprintln!("SKIP: artifacts not built");
        return;
    }
    let mut rt = XlaRuntime::open(&dir).unwrap();
    let err = match rt.mac_executable(333) {
        Err(e) => e,
        Ok(_) => panic!("batch 333 must not exist"),
    };
    assert!(format!("{err:#}").contains("no mac artifact for batch 333"));
}

#[test]
fn executable_rejects_wrong_batch_len() {
    let dir = default_artifact_dir();
    if !dir.join("manifest.json").exists() {
        eprintln!("SKIP: artifacts not built");
        return;
    }
    let mut rt = XlaRuntime::open(&dir).unwrap();
    let exe = rt.mac_executable(1).unwrap();
    let batch = MacBatch::nominal(2, 0.0, 1.0, 1e-10);
    let err = exe.run(&batch).unwrap_err();
    assert!(format!("{err:#}").contains("batch mismatch"));
}

#[test]
fn worker_pool_init_failure_is_reported_not_hung() {
    let dir = tmpdir("pool_bad");
    std::fs::write(
        dir.join("manifest.json"),
        r#"{"artifacts": [], "mac_batches": [], "trace_batches": [], "trace_points": 0, "n_steps": 256}"#,
    )
    .unwrap();
    let t0 = std::time::Instant::now();
    let err = WorkerPool::spawn(dir, 256, 2);
    assert!(err.is_err());
    assert!(t0.elapsed() < std::time::Duration::from_secs(30), "did not fail fast");
}

#[test]
fn campaign_rejects_invalid_spec() {
    let p = Params::default();
    let spec = CampaignSpec {
        variant: Variant::Smart,
        workload: Workload::Fixed { a: 99, b: 0 },
        n_mc: 10,
        seed: 1,
        corner: Corner::Tt,
        workers: 1,
        batch: 1,
        shards: 1,
        block: 0,
        kernel: smart_insram::mac::KernelKind::Block,
    };
    assert!(run_campaign(&p, &spec, Backend::Native, None).is_err());
}

#[test]
fn corner_campaigns_shift_the_output_as_expected() {
    // FF (fast): lower VTH -> more discharge -> larger mean V_mult; SS inverse.
    let p = Params::default();
    let mk = |corner| CampaignSpec {
        variant: Variant::Smart,
        workload: Workload::Fixed { a: 15, b: 15 },
        n_mc: 128,
        seed: 5,
        corner,
        workers: 1,
        batch: 64,
        shards: 1,
        block: 0,
        kernel: smart_insram::mac::KernelKind::Block,
    };
    let tt = run_campaign(&p, &mk(Corner::Tt), Backend::Native, None).unwrap();
    let ff = run_campaign(&p, &mk(Corner::Ff), Backend::Native, None).unwrap();
    let ss = run_campaign(&p, &mk(Corner::Ss), Backend::Native, None).unwrap();
    assert!(
        ff.raw_vmult.mean() > tt.raw_vmult.mean() && tt.raw_vmult.mean() > ss.raw_vmult.mean(),
        "ff {} tt {} ss {}",
        ff.raw_vmult.mean(),
        tt.raw_vmult.mean(),
        ss.raw_vmult.mean()
    );
    // corners shift the mean but the DAC still tracks the nominal design:
    // accuracy degrades relative to TT
    assert!(tt.accuracy.rms_norm < ff.accuracy.rms_norm);
    assert!(tt.accuracy.rms_norm < ss.accuracy.rms_norm);
}

#[test]
fn params_override_cannot_smuggle_bad_types() {
    let mut p = Params::default();
    let v = smart_insram::util::toml_lite::parse("[device]\nvth0 = \"high\"\n").unwrap();
    assert!(p.apply_overrides(&v).is_err());
    // untouched on failure path for the earlier keys
    assert_eq!(p.device.vth0, Params::default().device.vth0);
}
