//! Observability-layer integration tests (DESIGN.md §15). The
//! load-bearing contract: tracing is **provably inert** — every
//! canonical artifact family (mc.json, sweep CSV/JSON, infer.json,
//! served HTTP bodies) is byte-identical with tracing on or off, for
//! any shards/threads/block shape and kernel tier. Plus the JSONL trace
//! schema itself, the log2 histogram boundaries, and the PROFILE.json
//! golden from a committed fixture trace.

use std::path::PathBuf;

use smart_insram::coordinator::{run_campaign_traced, Backend, CampaignSpec};
use smart_insram::mac::{KernelKind, Variant};
use smart_insram::obs::registry::{bucket_bound, bucket_index};
use smart_insram::obs::{profile_trace, Histogram, Tracer};
use smart_insram::params::Params;
use smart_insram::report::mc_json;
use smart_insram::util::json::{parse, to_string_pretty, Value};

/// Self-cleaning temp dir per test.
struct Scratch(PathBuf);

impl Scratch {
    fn new(tag: &str) -> Scratch {
        let dir =
            std::env::temp_dir().join(format!("smart-obs-it-{tag}-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        Scratch(dir)
    }

    fn path(&self, name: &str) -> PathBuf {
        self.0.join(name)
    }
}

impl Drop for Scratch {
    fn drop(&mut self) {
        let _ = std::fs::remove_dir_all(&self.0);
    }
}

fn read(p: &PathBuf) -> String {
    std::fs::read_to_string(p).unwrap_or_else(|e| panic!("{}: {e}", p.display()))
}

/// Validate one trace file against the schema contract: line 1 is the
/// `meta` record (version 1, the given cmd); every `span` has a 16-hex
/// id, a name, integer `start_us`/`dur_us`, and a parent that is null
/// or another span's id; every `counters` record has `at_us` and a
/// `metrics` object. Returns the span records for extra assertions.
fn check_trace_schema(text: &str, cmd: &str) -> Vec<Value> {
    let lines: Vec<&str> = text.lines().filter(|l| !l.trim().is_empty()).collect();
    assert!(!lines.is_empty(), "trace is empty");
    let meta = parse(lines[0]).unwrap();
    assert_eq!(meta.get("type").unwrap().as_str(), Some("meta"), "{}", lines[0]);
    assert_eq!(meta.get("version").unwrap().as_u64(), Some(1));
    assert_eq!(meta.get("cmd").unwrap().as_str(), Some(cmd));

    let is_hex16 =
        |s: &str| s.len() == 16 && s.bytes().all(|b| b.is_ascii_hexdigit());
    let mut ids = std::collections::BTreeSet::new();
    let mut spans = Vec::new();
    for line in &lines[1..] {
        let rec = parse(line).unwrap_or_else(|e| panic!("unparseable trace line: {e}\n{line}"));
        match rec.get("type").and_then(Value::as_str) {
            Some("span") => {
                let id = rec.get("id").unwrap().as_str().unwrap().to_string();
                assert!(is_hex16(&id), "bad span id: {line}");
                assert!(ids.insert(id), "duplicate span id: {line}");
                assert!(rec.get("name").unwrap().as_str().is_some(), "{line}");
                assert!(rec.get("start_us").unwrap().as_u64().is_some(), "{line}");
                assert!(rec.get("dur_us").unwrap().as_u64().is_some(), "{line}");
                match rec.get("parent").unwrap() {
                    Value::Null => {}
                    Value::Str(p) => assert!(is_hex16(p), "bad parent id: {line}"),
                    other => panic!("parent must be null or hex: {other:?}"),
                }
                spans.push(rec);
            }
            Some("counters") => {
                assert!(rec.get("at_us").unwrap().as_u64().is_some(), "{line}");
                assert!(matches!(rec.get("metrics"), Some(Value::Obj(_))), "{line}");
            }
            Some("meta") => panic!("meta must appear exactly once, first: {line}"),
            other => panic!("unknown record type {other:?}: {line}"),
        }
    }
    // every non-null parent refers to a span in the same trace
    for s in &spans {
        if let Some(Value::Str(p)) = s.get("parent") {
            assert!(ids.contains(p.as_str()), "dangling parent {p}");
        }
    }
    spans
}

fn fig8_spec(n_mc: u32, shards: usize, threads: usize, block: usize, k: KernelKind) -> CampaignSpec {
    let mut spec = CampaignSpec::paper_fig8(Variant::Smart);
    spec.n_mc = n_mc;
    spec.shards = shards;
    spec.workers = threads;
    spec.block = block;
    spec.kernel = k;
    spec
}

#[test]
fn tracing_is_inert_for_mc_artifacts_across_shapes_and_kernels() {
    let scratch = Scratch::new("mc");
    let params = Params::default();
    for (i, (shards, threads, block, kernel)) in [
        (1usize, 1usize, 0usize, KernelKind::Block),
        (3, 2, 7, KernelKind::Block),
        (2, 2, 5, KernelKind::Scalar),
        (2, 1, 0, KernelKind::Fast),
    ]
    .into_iter()
    .enumerate()
    {
        let spec = fig8_spec(16, shards, threads, block, kernel);
        let quiet =
            run_campaign_traced(&params, &spec, Backend::Native, None, &Tracer::disabled())
                .unwrap();
        let trace_path = scratch.path(&format!("mc-{i}.jsonl"));
        let tracer = Tracer::to_file(&trace_path, "mc").unwrap();
        let traced = run_campaign_traced(&params, &spec, Backend::Native, None, &tracer).unwrap();
        assert_eq!(
            mc_json(&spec, &quiet),
            mc_json(&spec, &traced),
            "tracing changed mc.json bytes at shape {shards}/{threads}/{block} {kernel:?}"
        );
        // ... and the trace it wrote is schema-valid with campaign + shard spans
        let spans = check_trace_schema(&read(&trace_path), "mc");
        let names: Vec<&str> =
            spans.iter().filter_map(|s| s.get("name").and_then(Value::as_str)).collect();
        assert!(names.contains(&"campaign"), "{names:?}");
        assert!(names.contains(&"shard"), "{names:?}");
        assert!(names.contains(&"worker"), "{names:?}");
    }
}

#[test]
fn tracing_is_inert_for_sweep_artifacts() {
    use smart_insram::dse::{run_sweep, SweepOptions, SweepSpec};
    let spec_toml = r#"
name = "obs-test"
seed = 7
n_mc = 8
[grid]
variant = ["smart", "aid"]
v_bulk = [0.0, 0.6]
bits = [2]
corner = ["tt"]
"#;
    let scratch = Scratch::new("sweep");
    let spec = SweepSpec::parse(spec_toml).unwrap();
    let quiet = run_sweep(
        &spec,
        &SweepOptions { out_dir: scratch.path("quiet"), ..Default::default() },
    )
    .unwrap();
    let tracer = Tracer::to_file(&scratch.path("sweep.jsonl"), "sweep").unwrap();
    let traced = run_sweep(
        &spec,
        &SweepOptions {
            shards: 3,
            threads: 2,
            out_dir: scratch.path("traced"),
            tracer,
            ..Default::default()
        },
    )
    .unwrap();
    assert_eq!(
        read(&quiet.csv_path),
        read(&traced.csv_path),
        "tracing (or its shard shape) changed the sweep CSV bytes"
    );
    assert_eq!(read(&quiet.json_path), read(&traced.json_path));
    let spans = check_trace_schema(&read(&scratch.path("sweep.jsonl")), "sweep");
    let n_points = spans
        .iter()
        .filter(|s| s.get("name").and_then(Value::as_str) == Some("grid_point"))
        .count();
    assert_eq!(n_points, 4, "one grid_point span per grid point");
}

#[test]
fn tracing_is_inert_for_infer_artifacts() {
    use smart_insram::nn::{run_infer, InferOptions, ModelSpec};
    let body = r#"{"name": "obs-it", "seed": 11, "trials": 4, "bits": 4,
                   "dataset": {"classes": 3, "features": 6, "jitter": 0.1},
                   "layers": [{"inputs": 6, "outputs": 4, "relu": true},
                              {"inputs": 4, "outputs": 3}]}"#;
    let spec = ModelSpec::from_value(&parse(body).unwrap()).unwrap();
    let scratch = Scratch::new("infer");
    run_infer(
        &Params::default(),
        &spec,
        &InferOptions {
            write_artifacts: true,
            out_dir: scratch.path("quiet"),
            ..InferOptions::default()
        },
    )
    .unwrap();
    let tracer = Tracer::to_file(&scratch.path("infer.jsonl"), "infer").unwrap();
    run_infer(
        &Params::default(),
        &spec,
        &InferOptions {
            write_artifacts: true,
            out_dir: scratch.path("traced"),
            shards: 3,
            threads: 2,
            tracer,
            ..InferOptions::default()
        },
    )
    .unwrap();
    assert_eq!(
        read(&scratch.path("quiet").join("infer.json")),
        read(&scratch.path("traced").join("infer.json")),
        "tracing (or its shard shape) changed the infer.json bytes"
    );
    let spans = check_trace_schema(&read(&scratch.path("infer.jsonl")), "infer");
    assert!(spans
        .iter()
        .any(|s| s.get("name").and_then(Value::as_str) == Some("infer")));
    assert!(spans
        .iter()
        .any(|s| s.get("name").and_then(Value::as_str) == Some("trial_block")));
}

#[test]
fn tracing_is_inert_for_served_bodies() {
    use smart_insram::serve::{http_request, ServeOptions, Server};
    let scratch = Scratch::new("serve");
    let body = r#"{"variant": "smart", "n_mc": 8,
                   "workload": {"kind": "fixed", "a": 15, "b": 15}}"#;
    let serve_once = |tracer: Tracer| {
        let mut server = Server::start(
            Params::default(),
            &ServeOptions {
                addr: "127.0.0.1:0".to_string(),
                workers: 2,
                tracer,
                ..ServeOptions::default()
            },
        )
        .unwrap();
        let (status, _, got) =
            http_request(&server.addr().to_string(), "POST", "/v1/mc", body).unwrap();
        assert_eq!(status, 200, "{got}");
        server.stop();
        got
    };
    let quiet = serve_once(Tracer::disabled());
    let trace_path = scratch.path("serve.jsonl");
    let traced = serve_once(Tracer::to_file(&trace_path, "serve").unwrap());
    assert_eq!(quiet, traced, "tracing changed a served response body");
    let spans = check_trace_schema(&read(&trace_path), "serve");
    let request = spans
        .iter()
        .find(|s| s.get("name").and_then(Value::as_str) == Some("request"))
        .expect("serve trace has request spans");
    let attrs = request.get("attrs").unwrap();
    assert_eq!(attrs.get("method").unwrap().as_str(), Some("POST"));
    assert_eq!(attrs.get("path").unwrap().as_str(), Some("/v1/mc"));
    assert_eq!(attrs.get("status").unwrap().as_u64(), Some(200));
}

#[test]
fn histogram_buckets_are_log2_with_inclusive_bounds() {
    // bucket i covers [2^i, 2^(i+1) - 1], bucket 0 also takes 0
    assert_eq!(bucket_index(0), 0);
    assert_eq!(bucket_index(1), 0);
    assert_eq!(bucket_index(2), 1);
    assert_eq!(bucket_index(3), 1);
    assert_eq!(bucket_index(4), 2);
    assert_eq!(bucket_bound(0), 1);
    assert_eq!(bucket_bound(1), 3);
    assert_eq!(bucket_bound(2), 7);
    assert_eq!(bucket_bound(63), u64::MAX);
    // boundary values land on their own side of the edge
    for exp in 1..63u32 {
        let edge = 1u64 << exp;
        assert_eq!(bucket_index(edge), exp as usize, "2^{exp} opens its bucket");
        assert_eq!(bucket_index(edge - 1), exp as usize - 1, "2^{exp}-1 closes the previous");
        assert_eq!(bucket_bound(exp as usize - 1), edge - 1);
    }
    let h = Histogram::new();
    for v in [0u64, 1, 2, 3, 4, 255, 256] {
        h.record(v);
    }
    assert_eq!(h.count(), 7);
    assert_eq!(h.sum(), 521);
    assert_eq!(h.bucket(0), 2); // 0, 1
    assert_eq!(h.bucket(1), 2); // 2, 3
    assert_eq!(h.bucket(2), 1); // 4
    assert_eq!(h.bucket(7), 1); // 255
    assert_eq!(h.bucket(8), 1); // 256
    // quantiles report the inclusive upper bound of the landing bucket
    assert_eq!(h.quantile(50.0), 3);
    assert_eq!(h.quantile(100.0), 511);
}

#[test]
fn profile_of_committed_fixture_matches_the_golden() {
    let root = std::path::Path::new(env!("CARGO_MANIFEST_DIR"));
    let trace = std::fs::read_to_string(root.join("tests/fixtures/trace_profile.jsonl"))
        .expect("committed fixture trace");
    let golden = std::fs::read_to_string(root.join("tests/fixtures/PROFILE_golden.json"))
        .expect("committed golden profile");
    let profile = profile_trace(&trace).expect("fixture profiles cleanly");
    let mut text = to_string_pretty(&profile);
    text.push('\n');
    assert_eq!(text, golden, "PROFILE.json drifted from the committed golden");
    // folding is a pure function of the trace text
    let again = profile_trace(&trace).unwrap();
    assert_eq!(to_string_pretty(&again), to_string_pretty(&profile));
}

#[test]
fn profile_cli_writes_profile_json_for_a_traced_mc_run() {
    let scratch = Scratch::new("cli");
    let trace_path = scratch.path("trace.jsonl");
    let out = std::process::Command::new(env!("CARGO_BIN_EXE_smart"))
        .args([
            "mc", "--native", "--n-mc", "8", "--shards", "2",
            "--trace", trace_path.to_str().unwrap(),
        ])
        .output()
        .unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    check_trace_schema(&read(&trace_path), "mc");

    let out = std::process::Command::new(env!("CARGO_BIN_EXE_smart"))
        .args(["profile", trace_path.to_str().unwrap(), "--out", scratch.0.to_str().unwrap()])
        .output()
        .unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let profile = parse(&read(&scratch.path("PROFILE.json"))).unwrap();
    assert!(profile.get("records").unwrap().as_u64().unwrap() > 0);
    assert!(profile.path(&["phases", "campaign", "count"]).is_some());
    assert_eq!(profile.path(&["shards", "n"]).unwrap().as_u64(), Some(2));

    // SMART_TRACE env var names the same sink as --trace
    let env_trace = scratch.path("env.jsonl");
    let out = std::process::Command::new(env!("CARGO_BIN_EXE_smart"))
        .args(["mc", "--native", "--n-mc", "8"])
        .env("SMART_TRACE", env_trace.to_str().unwrap())
        .output()
        .unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    check_trace_schema(&read(&env_trace), "mc");
}
