//! Metrics against closed-form values: Welford stats on samples whose
//! mean/sigma are known exactly, exact quantiles of small fixed vectors,
//! and histogram bin arithmetic.

use smart_insram::metrics::{Histogram, OnlineStats, SampleSet};
use smart_insram::montecarlo::SplitMix64;

#[test]
fn welford_matches_textbook_sample() {
    // Classic example: mean 5, population variance 4, sigma 2 — exactly.
    let xs = [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0];
    let mut s = OnlineStats::new();
    xs.iter().for_each(|&x| s.push(x));
    assert_eq!(s.count(), 8);
    assert!((s.mean() - 5.0).abs() < 1e-12);
    assert!((s.variance() - 4.0).abs() < 1e-12);
    assert!((s.std_dev() - 2.0).abs() < 1e-12);
    assert_eq!(s.min(), 2.0);
    assert_eq!(s.max(), 9.0);
}

#[test]
fn welford_recovers_known_normal_moments() {
    // N(mu = 1, sigma = 2) drawn from the library RNG: the estimates must
    // land within standard-error-scale tolerances of the true moments.
    let mut rng = SplitMix64::new(42);
    let mut s = OnlineStats::new();
    let n = 50_000;
    for _ in 0..n {
        s.push(1.0 + 2.0 * rng.next_normal());
    }
    // se(mean) = sigma/sqrt(n) ~ 0.009; se(sigma) ~ sigma/sqrt(2n) ~ 0.006
    assert!((s.mean() - 1.0).abs() < 0.05, "mean {}", s.mean());
    assert!((s.std_dev() - 2.0).abs() < 0.05, "sigma {}", s.std_dev());
}

#[test]
fn quantiles_of_fixed_vectors_are_exact() {
    let mut odd = SampleSet::new();
    for x in [5.0, 1.0, 3.0, 2.0, 4.0] {
        odd.push(x); // insertion order must not matter
    }
    assert_eq!(odd.quantile(0.0), 1.0);
    assert_eq!(odd.quantile(0.25), 2.0);
    assert_eq!(odd.quantile(0.5), 3.0);
    assert_eq!(odd.quantile(1.0), 5.0);

    let mut even = SampleSet::new();
    for x in [1.0, 2.0, 3.0, 4.0] {
        even.push(x);
    }
    // linear interpolation between the two middle order statistics
    assert!((even.quantile(0.5) - 2.5).abs() < 1e-12);
    assert!((even.quantile(1.0 / 3.0) - 2.0).abs() < 1e-12);
}

#[test]
fn histogram_bins_against_hand_count() {
    let mut h = Histogram::new(0.0, 1.0, 4);
    // bin edges at 0.25/0.5/0.75: hand-placed samples
    for x in [0.1, 0.2, 0.3, 0.6, 0.6, 0.9, -1.0, 2.0] {
        h.push(x);
    }
    assert_eq!(h.counts(), &[3, 1, 2, 2]); // clamped ends included
    assert_eq!(h.total(), 8);
    assert!((h.bin_center(0) - 0.125).abs() < 1e-12);
    assert!((h.bin_center(3) - 0.875).abs() < 1e-12);
    assert!((h.mode() - 0.125).abs() < 1e-12);
}
