//! The noisy-inference workload subsystem (`smart infer`, DESIGN.md
//! §10): quantizer properties, a hand-computed dense-layer golden
//! fixture through the scalar oracle, scalar-vs-block bit-identity on a
//! full inference, and shard/thread/block byte-identity of the CLI's
//! JSON/CSV artifacts.

use std::process::Command;

use smart_insram::mac::{NativeMacEngine, ScalarKernel, Variant};
use smart_insram::montecarlo::MismatchSampler;
use smart_insram::nn::{
    nibble, run_infer, InferOptions, ModelSpec, QParams, QuantMatrix, QuantVec, Tiler,
};
use smart_insram::params::Params;

fn engine(v: Variant) -> NativeMacEngine {
    let p = Params::default();
    NativeMacEngine::new(p, v.config(&p))
}

#[test]
fn quantizer_roundtrip_property() {
    // |dequantize(quantize(x)) - x| <= scale/2 over the calibrated range,
    // for both supported operand widths, and nibbles recombine exactly.
    for bits in [4u32, 8] {
        for max_abs in [0.4f64, 1.0, 37.5] {
            let qp = QParams::symmetric(max_abs, bits);
            for k in -250..=250 {
                let x = max_abs * f64::from(k) / 250.0;
                let q = qp.quantize(x);
                assert!(q.unsigned_abs() <= qp.q_max() as u32);
                let err = (qp.dequantize(q) - x).abs();
                assert!(err <= qp.scale / 2.0 + 1e-12, "bits={bits} x={x}: err {err}");
                let mag = q.unsigned_abs();
                let recombined: u32 = (0..qp.words())
                    .map(|w| u32::from(nibble(mag, w)) << (4 * w))
                    .sum();
                assert_eq!(recombined, mag);
            }
        }
    }
}

#[test]
fn golden_2x2_dense_layer_through_scalar_kernel() {
    // Hand-computed fixture: W = [[3, -5], [2, 7]], x = [4, 9]. With
    // mismatch off, the offset-calibrated reconstruction recovers every
    // product exactly, so the analog accumulators equal the integer
    // matvec: [3*4 - 5*9, 2*4 + 7*9] = [-33, 71].
    let e = engine(Variant::Smart);
    let quiet = MismatchSampler::new(2022, 0.0, 0.0);
    let qp = QParams::symmetric(1.0, 4);
    let w = QuantMatrix { rows: 2, cols: 2, q: vec![3, -5, 2, 7], qp };
    let x = QuantVec { q: vec![4, 9], qp };
    let mut tiler = Tiler::new(&e, &ScalarKernel, &quiet, 3);
    let r = tiler.matvec(&w, &x, 0);
    assert_eq!(r.acc, vec![-33, 71]);
    assert_eq!(r.ops, 4);
    assert_eq!(r.faults, 0);
    assert!(r.energy > 0.0);
}

#[test]
fn noise_off_equals_the_exact_integer_pipeline() {
    // Acceptance: with mismatch off, `smart infer` reports the ideal
    // accuracy exactly — the noisy pass IS the exact pipeline.
    let spec = ModelSpec::fixture();
    let opts = InferOptions { trials: 8, noise_off: true, ..InferOptions::default() };
    let r = run_infer(&Params::default(), &spec, &opts).unwrap();
    assert_eq!(r.noisy_accuracy, r.ideal_accuracy);
    assert_eq!(r.agreement, 1.0);
    assert_eq!(r.accuracy_delta(), 0.0);
    assert_eq!(r.out_err.max(), 0.0);
    for rec in &r.records {
        assert_eq!(rec.noisy_pred, rec.ideal_pred, "trial {}", rec.trial);
        assert_eq!(rec.out_err, 0.0);
    }
}

#[test]
fn scalar_and_block_kernels_are_bit_identical_on_a_full_inference() {
    let spec = ModelSpec::fixture();
    let p = Params::default();
    let base = InferOptions { trials: 6, ..InferOptions::default() };
    let block = run_infer(&p, &spec, &base).unwrap();
    let scalar = run_infer(
        &p,
        &spec,
        &InferOptions {
            kernel: smart_insram::mac::KernelKind::Scalar,
            block: 7,
            shards: 3,
            ..base
        },
    )
    .unwrap();
    assert_eq!(block.kernel, "block");
    assert_eq!(scalar.kernel, "scalar");
    assert_eq!(block.records.len(), scalar.records.len());
    for (a, b) in block.records.iter().zip(&scalar.records) {
        assert_eq!(a.noisy_pred, b.noisy_pred, "trial {}", a.trial);
        assert_eq!(a.out_err.to_bits(), b.out_err.to_bits(), "trial {}", a.trial);
        assert_eq!(a.energy_raw.to_bits(), b.energy_raw.to_bits(), "trial {}", a.trial);
        assert_eq!(a.faults, b.faults, "trial {}", a.trial);
    }
    assert_eq!(block.out_err.mean().to_bits(), scalar.out_err.mean().to_bits());
    assert_eq!(block.noisy_accuracy.to_bits(), scalar.noisy_accuracy.to_bits());
}

#[test]
fn smart_variant_shrinks_the_noise_penalty_vs_baseline() {
    // Acceptance: at the same supply, replacing the AID baseline with
    // SMART (threshold suppression on) must shrink the application-level
    // noise figures.
    let spec = ModelSpec::fixture();
    let p = Params::default();
    let mk = |variant| {
        let opts = InferOptions { trials: 12, variant, ..InferOptions::default() };
        run_infer(&p, &spec, &opts).unwrap()
    };
    let smart = mk(Variant::Smart);
    let aid = mk(Variant::Aid);
    assert!(
        smart.out_err.mean() < aid.out_err.mean(),
        "SMART output error {} !< AID {}",
        smart.out_err.mean(),
        aid.out_err.mean()
    );
    assert!(
        smart.accuracy_delta() <= aid.accuracy_delta(),
        "SMART delta {} !<= AID delta {}",
        smart.accuracy_delta(),
        aid.accuracy_delta()
    );
    // both share the same exact reference
    assert_eq!(smart.ideal_accuracy, aid.ideal_accuracy);
}

fn smart_bin() -> Command {
    Command::new(env!("CARGO_BIN_EXE_smart"))
}

#[test]
fn infer_cli_artifacts_are_shard_thread_block_invariant() {
    // Acceptance: `smart infer --json` artifacts are byte-identical for
    // any --shards/--threads/--block choice and for either kernel.
    let cfg = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("configs/nn.toml");
    let run = |tag: &str, extra: &[&str]| {
        let out_dir =
            std::env::temp_dir().join(format!("smart_nn_infer_{tag}_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&out_dir);
        let mut args = vec![
            "infer".to_string(),
            cfg.to_str().unwrap().to_string(),
            "--trials".to_string(),
            "6".to_string(),
            "--json".to_string(),
            "--out".to_string(),
            out_dir.to_str().unwrap().to_string(),
        ];
        args.extend(extra.iter().map(|s| s.to_string()));
        let out = smart_bin().args(&args).output().unwrap();
        assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
        let text = String::from_utf8_lossy(&out.stdout).to_string();
        assert!(text.contains("top-1"), "{text}");
        let csv = std::fs::read_to_string(out_dir.join("infer.csv")).unwrap();
        let json = std::fs::read_to_string(out_dir.join("infer.json")).unwrap();
        (csv, json)
    };
    let (csv_a, json_a) = run("a", &["--shards", "1", "--threads", "1"]);
    let (csv_b, json_b) = run("b", &["--shards", "4", "--threads", "2", "--block", "9"]);
    let (csv_c, json_c) = run("c", &["--scalar", "--shards", "3", "--threads", "3"]);
    assert_eq!(csv_a, csv_b, "CSV artifacts differ across --shards/--threads/--block");
    assert_eq!(json_a, json_b, "JSON artifacts differ across --shards/--threads/--block");
    // the scalar oracle reproduces every number; only the recorded
    // kernel name may differ between the two JSON artifacts
    assert_eq!(csv_a, csv_c, "CSV artifacts differ between kernels");
    assert!(json_c.contains("\"kernel\": \"scalar\""));
    assert_eq!(
        json_a.replace("\"kernel\": \"block\"", "\"kernel\": \"scalar\""),
        json_c,
        "JSON artifacts differ between kernels beyond the kernel tag"
    );
    assert_eq!(csv_a.lines().count(), 7); // header + 6 trials
    assert!(json_a.contains("\"noisy_accuracy\""));
}

#[test]
fn infer_cli_smoke_caps_trials() {
    let cfg = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("configs/nn.toml");
    let out = smart_bin()
        .args(["infer", cfg.to_str().unwrap(), "--smoke", "--noise-off"])
        .output()
        .unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("8 trials"), "{text}");
    assert!(text.contains("delta +0.0 pp"), "{text}");
}
