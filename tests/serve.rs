//! Integration tests for `smart serve` (DESIGN.md §11/§14): a real
//! server on an ephemeral port, concurrent loopback clients, and
//! byte-identity between HTTP responses and the CLI `--json` artifacts —
//! through the in-memory LRU, the disk tier, the single-flight dedup
//! map, and the cross-request coalescer.

use std::path::PathBuf;
use std::sync::Arc;

use smart_insram::params::Params;
use smart_insram::serve::{http_request, ServeOptions, Server};

fn start_server(workers: usize) -> Server {
    Server::start(
        Params::default(),
        &ServeOptions {
            addr: "127.0.0.1:0".to_string(),
            workers,
            cache_cap: 1 << 20,
            ..ServeOptions::default()
        },
    )
    .expect("server starts on an ephemeral port")
}

fn start_disk_server(workers: usize, dir: &std::path::Path) -> Server {
    Server::start(
        Params::default(),
        &ServeOptions {
            addr: "127.0.0.1:0".to_string(),
            workers,
            cache_cap: 1 << 20,
            cache_dir: Some(dir.to_path_buf()),
            ..ServeOptions::default()
        },
    )
    .expect("server starts with a disk tier")
}

/// Self-cleaning temp dir for disk-tier tests.
struct Scratch(PathBuf);

impl Scratch {
    fn new(tag: &str) -> Scratch {
        let dir = std::env::temp_dir().join(format!("smart-serve-it-{tag}-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        Scratch(dir)
    }
}

impl Drop for Scratch {
    fn drop(&mut self) {
        let _ = std::fs::remove_dir_all(&self.0);
    }
}

#[test]
fn mc_response_byte_matches_the_cli_json_artifact() {
    // the artifact, via the real binary: `smart mc --json`
    let out_dir = std::env::temp_dir().join(format!("smart_serve_mc_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&out_dir);
    let out = std::process::Command::new(env!("CARGO_BIN_EXE_smart"))
        .args([
            "mc",
            "--variant",
            "smart",
            "--n-mc",
            "12",
            "--native",
            "--json",
            "--out",
            out_dir.to_str().unwrap(),
        ])
        .output()
        .unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let artifact = std::fs::read_to_string(out_dir.join("mc.json")).unwrap();

    // the same campaign over HTTP
    let mut server = start_server(2);
    let addr = server.addr().to_string();
    let body = r#"{"variant": "smart", "n_mc": 12,
                   "workload": {"kind": "fixed", "a": 15, "b": 15}}"#;
    let (status, headers, got) = http_request(&addr, "POST", "/v1/mc", body).unwrap();
    assert_eq!(status, 200, "{got}");
    assert_eq!(got, artifact, "HTTP response diverged from the CLI mc.json bytes");
    assert!(
        headers.iter().any(|(k, v)| k == "X-Smart-Cache" && v == "miss"),
        "first request must miss: {headers:?}"
    );
    assert!(
        headers.iter().any(|(k, _)| k == "X-Smart-Time-Us"),
        "missing timing header: {headers:?}"
    );

    // a perf-knobbed request describes the same campaign: cache hit,
    // identical bytes
    let knobbed = r#"{"variant": "smart", "n_mc": 12, "shards": 3, "workers": 2, "block": 7,
                      "workload": {"kind": "fixed", "a": 15, "b": 15}}"#;
    let (status, headers, again) = http_request(&addr, "POST", "/v1/mc", knobbed).unwrap();
    assert_eq!(status, 200);
    assert_eq!(again, artifact);
    assert!(
        headers.iter().any(|(k, v)| k == "X-Smart-Cache" && v == "hit"),
        "perf knobs must not fork the cache key: {headers:?}"
    );
    server.stop();
    let _ = std::fs::remove_dir_all(&out_dir);
}

#[test]
fn infer_response_byte_matches_the_written_artifact() {
    use smart_insram::nn::{run_infer, InferOptions, ModelSpec};
    // write the CLI-style artifact through the library entry point the
    // `smart infer --json` subcommand calls
    let out_dir = std::env::temp_dir().join(format!("smart_serve_infer_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&out_dir);
    let body = r#"{"name": "serve-it", "seed": 11, "trials": 3, "bits": 4,
                   "dataset": {"classes": 3, "features": 6, "jitter": 0.1},
                   "layers": [{"inputs": 6, "outputs": 4, "relu": true},
                              {"inputs": 4, "outputs": 3}]}"#;
    let spec = ModelSpec::from_value(&smart_insram::util::json::parse(body).unwrap()).unwrap();
    let opts = InferOptions {
        write_artifacts: true,
        out_dir: out_dir.clone(),
        ..InferOptions::default()
    };
    run_infer(&Params::default(), &spec, &opts).unwrap();
    let artifact = std::fs::read_to_string(out_dir.join("infer.json")).unwrap();

    let mut server = start_server(2);
    let (status, _, got) =
        http_request(&server.addr().to_string(), "POST", "/v1/infer", body).unwrap();
    assert_eq!(status, 200, "{got}");
    assert_eq!(got, artifact, "HTTP response diverged from the infer.json bytes");
    server.stop();
    let _ = std::fs::remove_dir_all(&out_dir);
}

#[test]
fn concurrent_clients_share_the_cache_and_the_bytes() {
    let mut server = start_server(3);
    let addr = Arc::new(server.addr().to_string());
    let body = r#"{"variant": "aid", "n_mc": 10,
                   "workload": {"kind": "fixed", "a": 3, "b": 9}}"#;
    // prime once so every concurrent request can be a hit
    let (status, _, expect) = http_request(&addr, "POST", "/v1/mc", body).unwrap();
    assert_eq!(status, 200, "{expect}");

    let clients: u64 = 6;
    let repeats: u64 = 4;
    std::thread::scope(|scope| {
        for _ in 0..clients {
            let addr = Arc::clone(&addr);
            let expect = expect.clone();
            scope.spawn(move || {
                for _ in 0..repeats {
                    let (status, headers, got) =
                        http_request(&addr, "POST", "/v1/mc", body).unwrap();
                    assert_eq!(status, 200);
                    assert_eq!(got, expect, "concurrent responses must be byte-identical");
                    assert!(
                        headers.iter().any(|(k, v)| k == "X-Smart-Cache" && v == "hit"),
                        "repeat requests must be served from the cache: {headers:?}"
                    );
                }
            });
        }
    });
    assert_eq!(server.cache_misses(), 1, "only the priming request computes");
    assert_eq!(server.cache_hits(), clients * repeats);

    // stats reflect the run and are valid JSON
    let (status, _, stats) = http_request(&addr, "GET", "/v1/stats", "").unwrap();
    assert_eq!(status, 200);
    let v = smart_insram::util::json::parse(&stats).unwrap();
    assert_eq!(v.get("cache").unwrap().get("hits").unwrap().as_u64().unwrap(), clients * repeats);
    assert!(v.get("requests").unwrap().as_u64().unwrap() >= clients * repeats + 1);
    server.stop();
}

#[test]
fn wire_errors_are_json_with_the_right_status() {
    let mut server = start_server(1);
    let addr = server.addr().to_string();
    for (method, path, body, want) in [
        ("GET", "/nope", "", 404u16),
        ("GET", "/v1/mc", "", 405),
        ("POST", "/v1/health", "", 405),
        ("POST", "/v1/mc", "not json", 400),
        ("POST", "/v1/infer", r#"{"name": "no-layers"}"#, 400),
    ] {
        let (status, _, got) = http_request(&addr, method, path, body).unwrap();
        assert_eq!(status, want, "{method} {path}: {got}");
        let v = smart_insram::util::json::parse(&got).unwrap();
        assert!(v.get("error").is_some(), "{method} {path}: {got}");
    }
    // the work ceiling guards the pool from batch-sized campaigns
    let huge = r#"{"variant": "smart", "n_mc": 1000000, "workload": {"kind": "full_sweep"}}"#;
    let (status, _, got) = http_request(&addr, "POST", "/v1/mc", huge).unwrap();
    assert_eq!(status, 400);
    assert!(got.contains("ceiling"), "{got}");
    server.stop();
}

#[test]
fn concurrent_misses_single_flight_into_one_campaign() {
    let mut server = start_server(4);
    let addr = Arc::new(server.addr().to_string());
    let pipe = server.pipeline();
    let body = r#"{"variant": "smart", "n_mc": 8,
                   "workload": {"kind": "fixed", "a": 6, "b": 10}}"#;
    let clients = 8usize;
    // Hold the flight leader at the compute gate until every follower has
    // joined its slot: the dedup is then provable, not timing-dependent.
    pipe.gate().pause();
    let results: Vec<(u16, Vec<(String, String)>, String)> = std::thread::scope(|scope| {
        let handles: Vec<_> = (0..clients)
            .map(|_| {
                let addr = Arc::clone(&addr);
                scope.spawn(move || http_request(&addr, "POST", "/v1/mc", body).unwrap())
            })
            .collect();
        while pipe.flight().waiting() < clients as u64 - 1 {
            std::thread::sleep(std::time::Duration::from_millis(1));
        }
        pipe.gate().resume();
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    });
    let (mut miss_n, mut dedup_n) = (0, 0);
    let first = &results[0].2;
    for (status, headers, got) in &results {
        assert_eq!(*status, 200, "{got}");
        assert_eq!(got, first, "fanned-out bodies must be byte-identical");
        for (k, v) in headers {
            if k == "X-Smart-Cache" {
                match v.as_str() {
                    "miss" => miss_n += 1,
                    "dedup" => dedup_n += 1,
                    other => panic!("unexpected cache tier {other}"),
                }
            }
        }
    }
    assert_eq!(miss_n, 1, "exactly one client leads the flight");
    assert_eq!(dedup_n, clients - 1, "every other client shares the leader's result");
    assert_eq!(pipe.stats().campaigns.get(), 1, "the herd must cost one campaign");
    assert_eq!(pipe.flight().deduped(), clients as u64 - 1);
    server.stop();
}

#[test]
fn disk_tier_serves_byte_identical_bodies_across_a_restart() {
    let scratch = Scratch::new("restart");
    let body = r#"{"variant": "aid", "n_mc": 8,
                   "workload": {"kind": "fixed", "a": 4, "b": 12}}"#;
    let expect = {
        let mut server = start_disk_server(2, &scratch.0);
        let (status, headers, got) =
            http_request(&server.addr().to_string(), "POST", "/v1/mc", body).unwrap();
        assert_eq!(status, 200, "{got}");
        assert!(headers.iter().any(|(k, v)| k == "X-Smart-Cache" && v == "miss"));
        server.stop();
        got
    };
    // "kill/restart": a fresh process-equivalent over the same directory
    let mut server = start_disk_server(2, &scratch.0);
    let (status, headers, got) =
        http_request(&server.addr().to_string(), "POST", "/v1/mc", body).unwrap();
    assert_eq!(status, 200, "{got}");
    assert_eq!(got, expect, "warm-start bytes must be identical to the pre-restart response");
    assert!(
        headers.iter().any(|(k, v)| k == "X-Smart-Cache" && v == "disk"),
        "restart must serve from the disk tier: {headers:?}"
    );
    assert_eq!(server.pipeline().stats().campaigns.get(), 0, "warm start must not recompute");
    server.stop();
}

#[test]
fn corrupted_cache_files_are_rejected_and_recomputed() {
    let scratch = Scratch::new("corrupt");
    let body = r#"{"variant": "smart", "n_mc": 8,
                   "workload": {"kind": "fixed", "a": 7, "b": 5}}"#;
    let expect = {
        let mut server = start_disk_server(2, &scratch.0);
        let (status, _, got) =
            http_request(&server.addr().to_string(), "POST", "/v1/mc", body).unwrap();
        assert_eq!(status, 200, "{got}");
        server.stop();
        got
    };
    // flip stored bytes in every persisted entry (fingerprint mismatch)
    let mut corrupted = 0;
    for entry in std::fs::read_dir(&scratch.0).unwrap() {
        let path = entry.unwrap().path();
        if path.extension().and_then(|e| e.to_str()) == Some("body") {
            let text = std::fs::read_to_string(&path).unwrap();
            std::fs::write(&path, text.replace(':', ";")).unwrap();
            corrupted += 1;
        }
    }
    assert_eq!(corrupted, 1, "the priming request must have persisted one entry");
    let mut server = start_disk_server(2, &scratch.0);
    let pipe = server.pipeline();
    let (status, headers, got) =
        http_request(&server.addr().to_string(), "POST", "/v1/mc", body).unwrap();
    assert_eq!(status, 200, "{got}");
    assert_eq!(got, expect, "the recomputed body must match the original bytes");
    assert!(
        headers.iter().any(|(k, v)| k == "X-Smart-Cache" && v == "miss"),
        "a corrupted entry must be treated as a miss: {headers:?}"
    );
    assert_eq!(pipe.disk().unwrap().rejects(), 1, "the tampered entry must be rejected");
    assert_eq!(pipe.stats().campaigns.get(), 1, "the rejected entry must be recomputed");
    // the recompute re-persisted a valid entry: one more restart hits disk
    server.stop();
    let mut server = start_disk_server(2, &scratch.0);
    let (_, headers, got) =
        http_request(&server.addr().to_string(), "POST", "/v1/mc", body).unwrap();
    assert_eq!(got, expect);
    assert!(headers.iter().any(|(k, v)| k == "X-Smart-Cache" && v == "disk"), "{headers:?}");
    server.stop();
}

#[test]
fn batched_inferences_are_byte_identical_to_solo_runs() {
    use smart_insram::nn::{infer_json, run_infer, InferOptions, ModelSpec};
    let jobs = 3usize;
    let bodies: Vec<String> = (0..jobs)
        .map(|i| {
            format!(
                "{{\"name\": \"serve-it-batch\", \"seed\": {}, \"trials\": 3, \"bits\": 4, \
                 \"dataset\": {{\"classes\": 3, \"features\": 6, \"jitter\": 0.1}}, \
                 \"layers\": [{{\"inputs\": 6, \"outputs\": 4, \"relu\": true}}, \
                              {{\"inputs\": 4, \"outputs\": 3}}]}}",
                31 + i
            )
        })
        .collect();
    // the unbatched reference: each model solo, through the same encoder
    let expects: Vec<String> = bodies
        .iter()
        .map(|b| {
            let spec =
                ModelSpec::from_value(&smart_insram::util::json::parse(b).unwrap()).unwrap();
            let r = run_infer(&Params::default(), &spec, &InferOptions::default()).unwrap();
            infer_json(&spec, &r)
        })
        .collect();

    let mut server = start_server(jobs.max(2));
    let addr = Arc::new(server.addr().to_string());
    let pipe = server.pipeline();
    // hold the group leader at the gate until every follower is queued,
    // so the requests provably coalesce into one merged execution
    pipe.gate().pause();
    let results: Vec<(usize, u16, String)> = std::thread::scope(|scope| {
        let handles: Vec<_> = bodies
            .iter()
            .enumerate()
            .map(|(i, body)| {
                let addr = Arc::clone(&addr);
                scope.spawn(move || {
                    let (status, _, got) =
                        http_request(&addr, "POST", "/v1/infer", body).unwrap();
                    (i, status, got)
                })
            })
            .collect();
        while pipe.batch().queued() < jobs as u64 - 1 {
            std::thread::sleep(std::time::Duration::from_millis(1));
        }
        pipe.gate().resume();
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    });
    for (i, status, got) in &results {
        assert_eq!(*status, 200, "batched infer {i}: {got}");
        assert_eq!(
            got, &expects[*i],
            "batched inference {i} must be byte-identical to its solo run"
        );
    }
    assert_eq!(pipe.batch().batched(), jobs as u64, "all jobs must ride the merged group");
    assert_eq!(pipe.batch().groups(), 1, "one merged execution covers the whole group");
    assert_eq!(pipe.stats().campaigns.get(), jobs as u64);
    server.stop();
}

#[test]
fn disk_tier_warm_starts_from_a_cli_artifact() {
    use smart_insram::coordinator::{run_campaign, Backend, CampaignSpec};
    use smart_insram::mac::Variant;
    use smart_insram::report::mc_json;
    use smart_insram::serve::{mc_cache_key, DiskTier};
    let scratch = Scratch::new("warmcli");
    // the artifact a prior `smart mc --json` run would have produced
    let mut spec = CampaignSpec::paper_fig8(Variant::Smart);
    spec.n_mc = 8;
    let artifact =
        mc_json(&spec, &run_campaign(&Params::default(), &spec, Backend::Native, None).unwrap());
    // seed the disk tier from it: key = mc_cache_key(spec), body = bytes
    DiskTier::open(&scratch.0).unwrap().put(&mc_cache_key(&spec), &artifact).unwrap();

    let mut server = start_disk_server(2, &scratch.0);
    assert_eq!(server.pipeline().disk().unwrap().warm_entries(), 1);
    let body = r#"{"variant": "smart", "n_mc": 8,
                   "workload": {"kind": "fixed", "a": 15, "b": 15}}"#;
    let (status, headers, got) =
        http_request(&server.addr().to_string(), "POST", "/v1/mc", body).unwrap();
    assert_eq!(status, 200, "{got}");
    assert_eq!(got, artifact, "the seeded artifact bytes must be served verbatim");
    assert!(
        headers.iter().any(|(k, v)| k == "X-Smart-Cache" && v == "disk"),
        "the seeded entry must be served from disk: {headers:?}"
    );
    assert_eq!(server.pipeline().stats().campaigns.get(), 0, "nothing to recompute");
    server.stop();
}

#[test]
fn graceful_shutdown_finishes_in_flight_requests() {
    let mut server = start_server(1);
    let addr = server.addr().to_string();
    // an uncached compute request large enough to still be in flight when
    // stop() is called (~thousands of ODE integrations)
    let body = r#"{"variant": "smart", "n_mc": 4000,
                   "workload": {"kind": "fixed", "a": 9, "b": 9}}"#;
    let client = {
        let addr = addr.clone();
        std::thread::spawn(move || http_request(&addr, "POST", "/v1/mc", body))
    };
    // let the request reach the worker, then shut down underneath it
    std::thread::sleep(std::time::Duration::from_millis(80));
    server.stop();
    let (status, _, got) = client.join().unwrap().expect("in-flight request completed");
    assert_eq!(status, 200, "graceful stop must drain in-flight requests: {got}");
    assert!(got.contains("\"n_mc\": 4000"), "{got}");
    // stop-then-restart liveness: a fresh server binds and serves again
    let mut again = start_server(1);
    let (status, _, _) =
        http_request(&again.addr().to_string(), "GET", "/v1/health", "").unwrap();
    assert_eq!(status, 200);
    again.stop();
}

#[test]
fn metrics_endpoint_serves_prometheus_text_and_tracks_requests() {
    let mut server = start_server(2);
    let addr = server.addr().to_string();
    let body = r#"{"variant": "smart", "n_mc": 8,
                   "workload": {"kind": "fixed", "a": 15, "b": 15}}"#;
    let (status, _, _) = http_request(&addr, "POST", "/v1/mc", body).unwrap();
    assert_eq!(status, 200);
    let (status, headers, text) = http_request(&addr, "GET", "/v1/metrics", "").unwrap();
    assert_eq!(status, 200, "{text}");
    assert!(
        headers
            .iter()
            .any(|(k, v)| k == "Content-Type" && v.starts_with("text/plain")),
        "metrics must be Prometheus text, not JSON: {headers:?}"
    );
    assert!(
        !headers.iter().any(|(k, _)| k == "X-Smart-Cache"),
        "a metrics scrape is not a cacheable campaign: {headers:?}"
    );
    // native metrics: the request histogram saw both requests above
    assert!(text.contains("# TYPE serve_request_us histogram"), "{text}");
    assert!(text.contains("serve_request_us_count"), "{text}");
    assert!(text.contains("serve_responses_total"), "{text}");
    // mirrored pipeline gauges: one campaign ran, one cache miss
    assert!(text.contains("serve_campaigns 1"), "{text}");
    assert!(text.contains("# TYPE serve_cache_misses gauge"), "{text}");
    // the scrape itself is registered by the time a second scrape reads it
    let (_, _, again) = http_request(&addr, "GET", "/v1/metrics", "").unwrap();
    assert!(again.contains("serve_responses_total"), "{again}");
    server.stop();
}
