//! Integration tests for `smart serve` (DESIGN.md §11): a real server on
//! an ephemeral port, concurrent loopback clients, and byte-identity
//! between HTTP responses and the CLI `--json` artifacts.

use std::sync::Arc;

use smart_insram::params::Params;
use smart_insram::serve::{http_request, ServeOptions, Server};

fn start_server(workers: usize) -> Server {
    Server::start(
        Params::default(),
        &ServeOptions { addr: "127.0.0.1:0".to_string(), workers, cache_cap: 16 },
    )
    .expect("server starts on an ephemeral port")
}

#[test]
fn mc_response_byte_matches_the_cli_json_artifact() {
    // the artifact, via the real binary: `smart mc --json`
    let out_dir = std::env::temp_dir().join(format!("smart_serve_mc_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&out_dir);
    let out = std::process::Command::new(env!("CARGO_BIN_EXE_smart"))
        .args([
            "mc",
            "--variant",
            "smart",
            "--n-mc",
            "12",
            "--native",
            "--json",
            "--out",
            out_dir.to_str().unwrap(),
        ])
        .output()
        .unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let artifact = std::fs::read_to_string(out_dir.join("mc.json")).unwrap();

    // the same campaign over HTTP
    let mut server = start_server(2);
    let addr = server.addr().to_string();
    let body = r#"{"variant": "smart", "n_mc": 12,
                   "workload": {"kind": "fixed", "a": 15, "b": 15}}"#;
    let (status, headers, got) = http_request(&addr, "POST", "/v1/mc", body).unwrap();
    assert_eq!(status, 200, "{got}");
    assert_eq!(got, artifact, "HTTP response diverged from the CLI mc.json bytes");
    assert!(
        headers.iter().any(|(k, v)| k == "X-Smart-Cache" && v == "miss"),
        "first request must miss: {headers:?}"
    );
    assert!(
        headers.iter().any(|(k, _)| k == "X-Smart-Time-Us"),
        "missing timing header: {headers:?}"
    );

    // a perf-knobbed request describes the same campaign: cache hit,
    // identical bytes
    let knobbed = r#"{"variant": "smart", "n_mc": 12, "shards": 3, "workers": 2, "block": 7,
                      "workload": {"kind": "fixed", "a": 15, "b": 15}}"#;
    let (status, headers, again) = http_request(&addr, "POST", "/v1/mc", knobbed).unwrap();
    assert_eq!(status, 200);
    assert_eq!(again, artifact);
    assert!(
        headers.iter().any(|(k, v)| k == "X-Smart-Cache" && v == "hit"),
        "perf knobs must not fork the cache key: {headers:?}"
    );
    server.stop();
    let _ = std::fs::remove_dir_all(&out_dir);
}

#[test]
fn infer_response_byte_matches_the_written_artifact() {
    use smart_insram::nn::{run_infer, InferOptions, ModelSpec};
    // write the CLI-style artifact through the library entry point the
    // `smart infer --json` subcommand calls
    let out_dir = std::env::temp_dir().join(format!("smart_serve_infer_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&out_dir);
    let body = r#"{"name": "serve-it", "seed": 11, "trials": 3, "bits": 4,
                   "dataset": {"classes": 3, "features": 6, "jitter": 0.1},
                   "layers": [{"inputs": 6, "outputs": 4, "relu": true},
                              {"inputs": 4, "outputs": 3}]}"#;
    let spec = ModelSpec::from_value(&smart_insram::util::json::parse(body).unwrap()).unwrap();
    let opts = InferOptions {
        write_artifacts: true,
        out_dir: out_dir.clone(),
        ..InferOptions::default()
    };
    run_infer(&Params::default(), &spec, &opts).unwrap();
    let artifact = std::fs::read_to_string(out_dir.join("infer.json")).unwrap();

    let mut server = start_server(2);
    let (status, _, got) =
        http_request(&server.addr().to_string(), "POST", "/v1/infer", body).unwrap();
    assert_eq!(status, 200, "{got}");
    assert_eq!(got, artifact, "HTTP response diverged from the infer.json bytes");
    server.stop();
    let _ = std::fs::remove_dir_all(&out_dir);
}

#[test]
fn concurrent_clients_share_the_cache_and_the_bytes() {
    let mut server = start_server(3);
    let addr = Arc::new(server.addr().to_string());
    let body = r#"{"variant": "aid", "n_mc": 10,
                   "workload": {"kind": "fixed", "a": 3, "b": 9}}"#;
    // prime once so every concurrent request can be a hit
    let (status, _, expect) = http_request(&addr, "POST", "/v1/mc", body).unwrap();
    assert_eq!(status, 200, "{expect}");

    let clients: u64 = 6;
    let repeats: u64 = 4;
    std::thread::scope(|scope| {
        for _ in 0..clients {
            let addr = Arc::clone(&addr);
            let expect = expect.clone();
            scope.spawn(move || {
                for _ in 0..repeats {
                    let (status, headers, got) =
                        http_request(&addr, "POST", "/v1/mc", body).unwrap();
                    assert_eq!(status, 200);
                    assert_eq!(got, expect, "concurrent responses must be byte-identical");
                    assert!(
                        headers.iter().any(|(k, v)| k == "X-Smart-Cache" && v == "hit"),
                        "repeat requests must be served from the cache: {headers:?}"
                    );
                }
            });
        }
    });
    assert_eq!(server.cache_misses(), 1, "only the priming request computes");
    assert_eq!(server.cache_hits(), clients * repeats);

    // stats reflect the run and are valid JSON
    let (status, _, stats) = http_request(&addr, "GET", "/v1/stats", "").unwrap();
    assert_eq!(status, 200);
    let v = smart_insram::util::json::parse(&stats).unwrap();
    assert_eq!(v.get("cache").unwrap().get("hits").unwrap().as_u64().unwrap(), clients * repeats);
    assert!(v.get("requests").unwrap().as_u64().unwrap() >= clients * repeats + 1);
    server.stop();
}

#[test]
fn wire_errors_are_json_with_the_right_status() {
    let mut server = start_server(1);
    let addr = server.addr().to_string();
    for (method, path, body, want) in [
        ("GET", "/nope", "", 404u16),
        ("GET", "/v1/mc", "", 405),
        ("POST", "/v1/health", "", 405),
        ("POST", "/v1/mc", "not json", 400),
        ("POST", "/v1/infer", r#"{"name": "no-layers"}"#, 400),
    ] {
        let (status, _, got) = http_request(&addr, method, path, body).unwrap();
        assert_eq!(status, want, "{method} {path}: {got}");
        let v = smart_insram::util::json::parse(&got).unwrap();
        assert!(v.get("error").is_some(), "{method} {path}: {got}");
    }
    // the work ceiling guards the pool from batch-sized campaigns
    let huge = r#"{"variant": "smart", "n_mc": 1000000, "workload": {"kind": "full_sweep"}}"#;
    let (status, _, got) = http_request(&addr, "POST", "/v1/mc", huge).unwrap();
    assert_eq!(status, 400);
    assert!(got.contains("ceiling"), "{got}");
    server.stop();
}

#[test]
fn graceful_shutdown_finishes_in_flight_requests() {
    let mut server = start_server(1);
    let addr = server.addr().to_string();
    // an uncached compute request large enough to still be in flight when
    // stop() is called (~thousands of ODE integrations)
    let body = r#"{"variant": "smart", "n_mc": 4000,
                   "workload": {"kind": "fixed", "a": 9, "b": 9}}"#;
    let client = {
        let addr = addr.clone();
        std::thread::spawn(move || http_request(&addr, "POST", "/v1/mc", body))
    };
    // let the request reach the worker, then shut down underneath it
    std::thread::sleep(std::time::Duration::from_millis(80));
    server.stop();
    let (status, _, got) = client.join().unwrap().expect("in-flight request completed");
    assert_eq!(status, 200, "graceful stop must drain in-flight requests: {got}");
    assert!(got.contains("\"n_mc\": 4000"), "{got}");
    // stop-then-restart liveness: a fresh server binds and serves again
    let mut again = start_server(1);
    let (status, _, _) =
        http_request(&again.addr().to_string(), "GET", "/v1/health", "").unwrap();
    assert_eq!(status, 200);
    again.stop();
}
