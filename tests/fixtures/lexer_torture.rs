// lexer torture fixture for tests/lint.rs: raw identifiers, nested
// block comments, raw/byte strings, lifetime-vs-char, maximal munch.
/* depth one /* depth two /* depth three */
   back to two */ back to one */
fn r#type(r#fn: u32) -> u32 {
    let raw = r#"raw "quoted" body"#;
    let braw = br#"byte raw "#;
    let ch = 'x';
    let esc = '\n';
    let life: &'static str = "s";
    let f = 1.5e-3;
    let g = 0.5f64;
    let hex = 0xEFu32;
    let r = 0..16;
    r#fn
}
