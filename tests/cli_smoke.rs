//! CLI integration: spawn the `smart` binary end-to-end (native backend so
//! the tests stay fast; the XLA path is covered by runtime_roundtrip).

use std::process::Command;

fn smart() -> Command {
    Command::new(env!("CARGO_BIN_EXE_smart"))
}

fn have_artifacts() -> bool {
    smart_insram::runtime::default_artifact_dir()
        .join("manifest.json")
        .exists()
}

#[test]
fn help_prints_usage() {
    let out = smart().arg("--help").output().unwrap();
    assert!(out.status.success());
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("USAGE"));
    assert!(text.contains("table1"));
}

#[test]
fn no_args_prints_usage_ok() {
    let out = smart().output().unwrap();
    assert!(out.status.success());
}

#[test]
fn unknown_command_fails() {
    let out = smart().arg("frobnicate").output().unwrap();
    assert!(!out.status.success());
    assert!(String::from_utf8_lossy(&out.stderr).contains("unknown command"));
}

#[test]
fn mac_native_runs() {
    let out = smart()
        .args(["mac", "13", "7", "--variant", "smart", "--native"])
        .output()
        .unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("13 x 7 on SMART"), "{text}");
}

#[test]
fn mc_native_reports_sigma() {
    let out = smart()
        .args(["mc", "--variant", "aid", "--n-mc", "64", "--native"])
        .output()
        .unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("sigma/FS"), "{text}");
    assert!(text.contains("throughput"), "{text}");
}

#[test]
fn mc_native_accepts_block_knob() {
    let out = smart()
        .args(["mc", "--variant", "smart", "--n-mc", "32", "--native", "--block", "9"])
        .output()
        .unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    assert!(String::from_utf8_lossy(&out.stdout).contains("sigma/FS"));
}

#[test]
fn bench_json_writes_perf_artifact() {
    let out_dir = std::env::temp_dir().join(format!("smart_bench_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&out_dir);
    let out = smart()
        .args([
            "bench",
            "--json",
            "--smoke",
            "--n-mc",
            "16",
            "--out",
            out_dir.to_str().unwrap(),
        ])
        .output()
        .unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("block kernel"), "{text}");
    assert!(text.contains("fast kernel"), "{text}");
    let json = std::fs::read_to_string(out_dir.join("BENCH_native.json")).unwrap();
    for key in [
        "\"backend\"",
        "\"items_per_sec\"",
        "\"n_items\"",
        "\"variant\"",
        "\"block\"",
        "\"threads\"",
        "\"fast_items_per_sec\"",
        "\"fast_speedup\"",
        "native-block",
    ] {
        assert!(json.contains(key), "missing {key} in {json}");
    }
    // the surrogate replaces 256-step integrations with closed forms and
    // table lookups; even a one-sample smoke must measure a real speedup
    let v = smart_insram::util::json::parse(&json).unwrap();
    let fast_speedup = v.get("fast_speedup").unwrap().as_f64().unwrap();
    assert!(fast_speedup > 1.0, "fast tier must beat the block kernel, got {fast_speedup}");
}

#[test]
fn run_config_native() {
    let cfg = concat!(
        "name = \"smoke\"\n",
        "[[campaigns]]\nvariant = \"smart\"\nn_mc = 16\n",
        "[campaigns.workload]\nkind = \"fixed\"\na = 15\nb = 15\n"
    );
    let path = std::env::temp_dir().join("smart_cli_smoke.toml");
    std::fs::write(&path, cfg).unwrap();
    let out = smart()
        .args(["run", path.to_str().unwrap(), "--native"])
        .output()
        .unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    assert!(String::from_utf8_lossy(&out.stdout).contains("smoke"));
}

#[test]
fn bad_config_fails_with_context() {
    let path = std::env::temp_dir().join("smart_cli_bad.toml");
    let cfg = concat!(
        "name = \"x\"\n[[campaigns]]\nvariant = \"nope\"\n",
        "[campaigns.workload]\nkind = \"full_sweep\"\n"
    );
    std::fs::write(&path, cfg).unwrap();
    let out = smart().args(["run", path.to_str().unwrap(), "--native"]).output().unwrap();
    assert!(!out.status.success());
    assert!(String::from_utf8_lossy(&out.stderr).contains("unknown variant"));
}

#[test]
fn info_smokes_pjrt() {
    if !have_artifacts() {
        eprintln!("SKIP: artifacts not built");
        return;
    }
    let out = smart().arg("info").output().unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("platform: cpu"));
    assert!(text.contains("PJRT smoke 15x15"));
}

#[test]
fn checked_in_configs_parse() {
    // keep the shipped configs/ directory loadable at all times; dse*
    // files are sweep specs, nn* files are inference models, lint* is
    // the analyzer's own config, the rest are experiment files
    let root = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("configs");
    let mut n = 0;
    for entry in std::fs::read_dir(root).unwrap() {
        let path = entry.unwrap().path();
        if path.extension().is_some_and(|e| e == "toml") {
            let stem = path.file_name().and_then(|s| s.to_str()).unwrap_or("");
            if stem.starts_with("dse") {
                smart_insram::dse::SweepSpec::load(&path)
                    .unwrap_or_else(|e| panic!("{}: {e:#}", path.display()));
            } else if stem.starts_with("lint") {
                smart_insram::lint::LintConfig::load(&path)
                    .unwrap_or_else(|e| panic!("{}: {e:#}", path.display()));
            } else if stem.starts_with("fast_tol") {
                // golden tolerance fixture for tests/fast_kernel.rs
                let text = std::fs::read_to_string(&path).unwrap();
                let doc = smart_insram::util::toml_lite::parse(&text)
                    .unwrap_or_else(|e| panic!("{}: {e}", path.display()));
                assert!(doc.path(&["global", "max_abs_dv"]).is_some());
                assert!(!doc.get("config").unwrap().as_arr().unwrap().is_empty());
            } else if stem.starts_with("nn") {
                smart_insram::nn::ModelSpec::load(&path)
                    .unwrap_or_else(|e| panic!("{}: {e:#}", path.display()));
            } else {
                smart_insram::config::ExperimentConfig::load(&path)
                    .unwrap_or_else(|e| panic!("{}: {e:#}", path.display()));
            }
            n += 1;
        }
    }
    assert!(n >= 5, "expected the shipped configs, found {n}");
}

#[test]
fn sweep_cli_is_byte_deterministic() {
    // THE acceptance criterion: `smart sweep configs/dse.toml --shards 4
    // --threads 2` and `--shards 1 --threads 1` produce byte-identical
    // CSV/JSON artifacts.
    let cfg = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("configs/dse.toml");
    let run = |tag: &str, shards: &str, threads: &str, block: &str| {
        let out_dir =
            std::env::temp_dir().join(format!("smart_cli_sweep_{tag}_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&out_dir);
        let out = smart()
            .args([
                "sweep",
                cfg.to_str().unwrap(),
                "--shards",
                shards,
                "--threads",
                threads,
                "--block",
                block,
                "--out",
                out_dir.to_str().unwrap(),
            ])
            .output()
            .unwrap();
        assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
        let text = String::from_utf8_lossy(&out.stdout).to_string();
        assert!(text.contains("pareto front"), "{text}");
        let csv = std::fs::read_to_string(out_dir.join("sweep.csv")).unwrap();
        let json = std::fs::read_to_string(out_dir.join("sweep.json")).unwrap();
        (csv, json)
    };
    let (csv_a, json_a) = run("a", "4", "2", "256");
    let (csv_b, json_b) = run("b", "1", "1", "13");
    assert_eq!(csv_a, csv_b, "CSV artifacts differ across --shards/--threads/--block");
    assert_eq!(json_a, json_b, "JSON artifacts differ across --shards/--threads/--block");
    assert!(csv_a.lines().count() > 1);
}

#[test]
fn zero_knobs_fail_at_the_cli_boundary() {
    // regression (PR 5): an explicit `--shards 0` etc. used to sail into
    // the campaign stack and die on a deep `assert!` in coordinator::pool;
    // now the CLI rejects it with a descriptive error before any work runs
    for knob in ["--shards", "--threads", "--block", "--workers", "--batch"] {
        let out = smart()
            .args(["mc", "--variant", "smart", "--n-mc", "8", "--native", knob, "0"])
            .output()
            .unwrap();
        assert!(!out.status.success(), "mc {knob} 0 should fail");
        let err = String::from_utf8_lossy(&out.stderr);
        assert!(
            err.contains(&format!("{knob} must be >= 1")) && err.contains("auto-select"),
            "mc {knob} 0: {err}"
        );
        assert!(!err.contains("panicked"), "mc {knob} 0 panicked instead of erroring: {err}");
    }
    let out = smart().args(["serve", "--workers", "0"]).output().unwrap();
    assert!(!out.status.success());
    assert!(String::from_utf8_lossy(&out.stderr).contains("--workers must be >= 1"));
    let out = smart().args(["serve", "--cache-cap", "0"]).output().unwrap();
    assert!(!out.status.success());
    assert!(String::from_utf8_lossy(&out.stderr).contains("--cache-cap must be >= 1"));
}

#[test]
fn mc_json_writes_the_canonical_artifact() {
    let out_dir = std::env::temp_dir().join(format!("smart_cli_mcjson_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&out_dir);
    let run = |shards: &str| {
        let out = smart()
            .args([
                "mc", "--variant", "aid", "--n-mc", "16", "--native", "--shards", shards,
                "--json", "--out", out_dir.to_str().unwrap(),
            ])
            .output()
            .unwrap();
        assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
        std::fs::read_to_string(out_dir.join("mc.json")).unwrap()
    };
    let a = run("2");
    let b = run("5");
    assert_eq!(a, b, "mc.json must be byte-identical for any --shards");
    let v = smart_insram::util::json::parse(&a).unwrap();
    assert_eq!(v.get("variant").unwrap().as_str().unwrap(), "aid");
    assert_eq!(v.get("n_mc").unwrap().as_u64().unwrap(), 16);
    assert!(v.get("hist").unwrap().get("non_finite").is_some());
    assert!(v.get("shards").is_none(), "perf knobs must not appear in mc.json");
    let _ = std::fs::remove_dir_all(&out_dir);
}

#[test]
fn kernel_knob_selects_the_tier_on_mc() {
    // `--kernel` is an identity knob: the selected tier lands in mc.json
    let out_dir = std::env::temp_dir().join(format!("smart_cli_kernel_{}", std::process::id()));
    for kernel in ["scalar", "block", "fast"] {
        let _ = std::fs::remove_dir_all(&out_dir);
        let out = smart()
            .args([
                "mc", "--variant", "smart", "--n-mc", "8", "--native", "--kernel", kernel,
                "--json", "--out", out_dir.to_str().unwrap(),
            ])
            .output()
            .unwrap();
        let err = String::from_utf8_lossy(&out.stderr);
        assert!(out.status.success(), "--kernel {kernel}: {err}");
        let json = std::fs::read_to_string(out_dir.join("mc.json")).unwrap();
        assert!(
            json.contains(&format!("\"kernel\": \"{kernel}\"")),
            "--kernel {kernel} missing from mc.json: {json}"
        );
    }
    let _ = std::fs::remove_dir_all(&out_dir);
}

#[test]
fn unknown_kernel_is_rejected_descriptively() {
    let nn = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("configs/nn.toml");
    let nn = nn.to_str().unwrap();
    for cmd in [
        vec!["mc", "--variant", "smart", "--n-mc", "8", "--native", "--kernel", "warp"],
        vec!["infer", nn, "--smoke", "--kernel", "warp"],
        vec!["serve", "--self-test", "--smoke", "--kernel", "warp"],
    ] {
        let out = smart().args(&cmd).output().unwrap();
        assert!(!out.status.success(), "{cmd:?} should fail");
        let err = String::from_utf8_lossy(&out.stderr);
        assert!(
            err.contains("unknown kernel 'warp'") && err.contains("scalar|block|fast"),
            "{cmd:?}: {err}"
        );
        assert!(!err.contains("panicked"), "{cmd:?} panicked: {err}");
    }
}

#[test]
fn sweep_accepts_the_kernel_knob() {
    // a tiny inline grid so the fast tier runs in milliseconds; the CSV
    // carries the kernel token in every row (it is part of the resume key)
    let spec = concat!(
        "name = \"k\"\nseed = 7\nn_mc = 4\n",
        "[grid]\nvariant = [\"smart\"]\nv_bulk = [0.6]\nbits = [2]\ncorner = [\"tt\"]\n"
    );
    let cfg = std::env::temp_dir().join(format!("smart_cli_ksweep_{}.toml", std::process::id()));
    std::fs::write(&cfg, spec).unwrap();
    let out_dir = std::env::temp_dir().join(format!("smart_cli_ksweep_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&out_dir);
    let out = smart()
        .args([
            "sweep",
            cfg.to_str().unwrap(),
            "--kernel",
            "fast",
            "--out",
            out_dir.to_str().unwrap(),
        ])
        .output()
        .unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let csv = std::fs::read_to_string(out_dir.join("sweep.csv")).unwrap();
    assert!(csv.lines().next().unwrap().contains(",kernel,"), "{csv}");
    assert!(csv.lines().nth(1).unwrap().contains(",fast,"), "{csv}");
    let json = std::fs::read_to_string(out_dir.join("sweep.json")).unwrap();
    assert!(json.contains("\"kernel\": \"fast\""), "{json}");
    let _ = std::fs::remove_dir_all(&out_dir);
    let _ = std::fs::remove_file(&cfg);
}

#[test]
fn infer_kernel_knob_and_deprecated_scalar_alias() {
    let cfg = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("configs/nn.toml");
    // explicit --kernel fast
    let out = smart()
        .args(["infer", cfg.to_str().unwrap(), "--smoke", "--kernel", "fast"])
        .output()
        .unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    assert!(String::from_utf8_lossy(&out.stdout).contains("top-1"));
    // the deprecated boolean stays honored, with a warning on stderr
    let out = smart()
        .args(["infer", cfg.to_str().unwrap(), "--smoke", "--scalar"])
        .output()
        .unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let err = String::from_utf8_lossy(&out.stderr);
    assert!(err.contains("--scalar is deprecated"), "{err}");
    // ... and an explicit --kernel wins over the alias, silently for the
    // alias (one warning, the kernel parser's choice takes effect)
    let out = smart()
        .args(["infer", cfg.to_str().unwrap(), "--smoke", "--scalar", "--kernel", "block"])
        .output()
        .unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
}

#[test]
fn serve_self_test_passes_on_the_fast_tier() {
    let out = smart()
        .args(["serve", "--self-test", "--smoke", "--workers", "2", "--kernel", "fast"])
        .output()
        .unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    assert!(String::from_utf8_lossy(&out.stdout).contains("serve self-test OK"));
}

#[test]
fn serve_self_test_smoke_passes_and_writes_stats() {
    let out_dir = std::env::temp_dir().join(format!("smart_cli_serve_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&out_dir);
    let out = smart()
        .args([
            "serve", "--self-test", "--smoke", "--workers", "2", "--json", "--out",
            out_dir.to_str().unwrap(),
        ])
        .output()
        .unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("serve self-test OK"), "{text}");
    let stats = std::fs::read_to_string(out_dir.join("SERVE_stats.json")).unwrap();
    let v = smart_insram::util::json::parse(&stats).unwrap();
    assert_eq!(v.get("service").unwrap().as_str().unwrap(), "smart-serve");
    assert!(v.get("cache").unwrap().get("hits").unwrap().as_u64().unwrap() > 0);
    let _ = std::fs::remove_dir_all(&out_dir);
}
