//! DSE subsystem integration: grid sweeps must be byte-deterministic for
//! any `--shards`/`--threads` choice (the campaign layer's contract
//! carried through to the artifacts — acceptance: `smart sweep
//! configs/dse.toml --shards 4 --threads 2` matches `--shards 1
//! --threads 1` byte for byte), and `--resume` must reuse checkpoint
//! rows without changing a single output byte.

use std::path::PathBuf;

use smart_insram::dse::{pareto_flags, run_sweep, SweepOptions, SweepSpec};

/// A grid small enough for CI but wide enough to cross shard boundaries:
/// 2 variants x 2 v_bulk = 4 points, 16 operands x 8 MC each.
const SPEC: &str = r#"
name = "dse-test"
seed = 7
n_mc = 8
[grid]
variant = ["smart", "aid"]
v_bulk = [0.0, 0.6]
bits = [2]
corner = ["tt"]
"#;

fn tmp_dir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("smart_dse_{tag}_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

fn read(p: &PathBuf) -> String {
    std::fs::read_to_string(p).unwrap_or_else(|e| panic!("{}: {e}", p.display()))
}

#[test]
fn shard_thread_block_choices_never_change_artifacts() {
    let spec = SweepSpec::parse(SPEC).unwrap();
    let base_dir = tmp_dir("base");
    let base = run_sweep(
        &spec,
        &SweepOptions {
            shards: 1,
            threads: 1,
            resume: false,
            out_dir: base_dir,
            block: 0,
            kernel: smart_insram::mac::KernelKind::Block,
            ..Default::default()
        },
    )
    .unwrap();
    assert_eq!(base.points.len(), 4);
    assert_eq!(base.computed, 4);
    assert_eq!(base.resumed, 0);
    let (csv, json) = (read(&base.csv_path), read(&base.json_path));
    for (shards, threads, block) in [(4usize, 2usize, 0usize), (7, 3, 5), (0, 0, 1), (2, 2, 999)] {
        let dir = tmp_dir(&format!("s{shards}t{threads}b{block}"));
        let r = run_sweep(
            &spec,
            &SweepOptions {
                shards,
                threads,
                block,
                resume: false,
                out_dir: dir,
                kernel: smart_insram::mac::KernelKind::Block,
                ..Default::default()
            },
        )
        .unwrap();
        assert_eq!(
            read(&r.csv_path),
            csv,
            "CSV differs at shards={shards} threads={threads} block={block}"
        );
        assert_eq!(
            read(&r.json_path),
            json,
            "JSON differs at shards={shards} threads={threads} block={block}"
        );
    }
}

#[test]
fn sweep_shape_matches_the_paper() {
    // smart (v_bulk 0.6) must beat its own unbiased point (== AID), and
    // the two baseline rows (smart@0, aid@0) must agree exactly.
    let spec = SweepSpec::parse(SPEC).unwrap();
    let r = run_sweep(
        &spec,
        &SweepOptions { out_dir: tmp_dir("shape"), ..Default::default() },
    )
    .unwrap();
    // canonical order: (smart, 0.0), (smart, 0.6), (aid, 0.0), (aid, 0.6)
    let sigma: Vec<f64> = r.points.iter().map(|p| p.sigma_norm).collect();
    assert!(sigma[1] < sigma[0], "body bias must shrink sigma: {sigma:?}");
    assert_eq!(
        sigma[0].to_bits(),
        sigma[2].to_bits(),
        "smart@v_bulk=0 must equal the AID baseline"
    );
    // aid ignores the v_bulk axis entirely
    assert_eq!(sigma[2].to_bits(), sigma[3].to_bits());
    assert_eq!(r.points.iter().map(|p| p.rows).sum::<u64>(), 4 * 16 * 8);
    // the front is recomputed from the artifact objectives
    let objectives: Vec<(f64, f64)> =
        r.points.iter().map(|p| (p.energy_pj, p.sigma_norm)).collect();
    assert_eq!(pareto_flags(&objectives), r.pareto);
    assert!(!r.front().is_empty());
}

#[test]
fn resume_reuses_rows_and_preserves_bytes() {
    let spec = SweepSpec::parse(SPEC).unwrap();
    let scratch = run_sweep(
        &spec,
        &SweepOptions { out_dir: tmp_dir("scratch"), ..Default::default() },
    )
    .unwrap();
    let (csv, json) = (read(&scratch.csv_path), read(&scratch.json_path));

    // simulate an interrupted sweep: keep the header + first two rows
    let resume_dir = tmp_dir("resume");
    std::fs::create_dir_all(&resume_dir).unwrap();
    let partial: String = csv.lines().take(3).map(|l| format!("{l}\n")).collect();
    std::fs::write(resume_dir.join("sweep.csv"), partial).unwrap();

    let resumed = run_sweep(
        &spec,
        &SweepOptions { resume: true, out_dir: resume_dir, ..Default::default() },
    )
    .unwrap();
    assert_eq!(resumed.resumed, 2, "two checkpoint rows must be reused");
    assert_eq!(resumed.computed, 2);
    assert_eq!(read(&resumed.csv_path), csv, "resume changed the CSV bytes");
    assert_eq!(read(&resumed.json_path), json, "resume changed the JSON bytes");

    // resume with no checkpoint at all: a plain scratch run
    let cold = run_sweep(
        &spec,
        &SweepOptions { resume: true, out_dir: tmp_dir("cold"), ..Default::default() },
    )
    .unwrap();
    assert_eq!(cold.computed, 4);
    assert_eq!(read(&cold.csv_path), csv);
}

#[test]
fn checkpoint_from_a_different_spec_is_ignored() {
    // a checkpoint keyed with a different seed must not be reused
    let spec = SweepSpec::parse(SPEC).unwrap();
    let other = SweepSpec::parse(&SPEC.replace("seed = 7", "seed = 8")).unwrap();
    let dir = tmp_dir("cross");
    run_sweep(&other, &SweepOptions { out_dir: dir.clone(), ..Default::default() }).unwrap();
    let r = run_sweep(
        &spec,
        &SweepOptions { resume: true, out_dir: dir, ..Default::default() },
    )
    .unwrap();
    assert_eq!(r.resumed, 0);
    assert_eq!(r.computed, 4);

    // ... and neither must a checkpoint computed under different
    // [params.*] overrides (the card fingerprint differs)
    let edited =
        SweepSpec::parse(&format!("{SPEC}\n[params.circuit]\nsigma_vth = 0.05\n")).unwrap();
    let dir = tmp_dir("cross_params");
    run_sweep(&spec, &SweepOptions { out_dir: dir.clone(), ..Default::default() }).unwrap();
    let r = run_sweep(
        &edited,
        &SweepOptions { resume: true, out_dir: dir, ..Default::default() },
    )
    .unwrap();
    assert_eq!(r.resumed, 0, "edited model card must invalidate the checkpoint");
    assert_eq!(r.computed, 4);
}

#[test]
fn shipped_dse_config_loads() {
    let path = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("configs/dse.toml");
    let spec = SweepSpec::load(&path).unwrap();
    assert_eq!(spec.name, "dse-demo");
    assert!(spec.grid.len() >= 8, "demo grid should cover several points");
    spec.validate().unwrap();
}
