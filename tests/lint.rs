//! Integration tests for `smart lint` (DESIGN.md §12, §16): every rule
//! on an inline fixture (positive hit, pragma suppression,
//! comment/string immunity), the lock-order analysis on seeded deadlock
//! cycles, a pinned lexer-torture census, byte-identical report
//! serialization, the repo's own sources staying lint-clean, and the
//! CLI exit/report contract on a seeded violation.

use std::path::Path;

use smart_insram::lint::lexer::{is_float_literal, lex, Tok};
use smart_insram::lint::{self, lint_source, LintConfig, Rule};

/// One triggering fixture per rule: `(rule, lint path, source, line of
/// the hit)`. Each source produces EXACTLY one finding, on the stated
/// line. The D6 fixture is scanned under an `obs/` path so the D7
/// quarantine (which bans the `Instant` ident everywhere else) does not
/// add a second finding; D7 has its own import-only fixture that D6
/// (which needs a `::now()` / `SystemTime::` *read*) stays silent on.
/// L5 (drift) is absent here: it needs repo context (README text, the
/// configs/ key inventory) and gets its own `lint::analyze` fixture
/// below.
fn fixtures() -> Vec<(Rule, &'static str, &'static str, u32)> {
    vec![
        (
            Rule::MapIteration,
            "fixture.rs",
            "fn f() -> u32 {\n    let m: std::collections::HashMap<u32, u32> = Default::default();\n    let mut total = 0u32;\n    for v in m.values() {\n        total += v;\n    }\n    total\n}\n",
            4,
        ),
        (
            Rule::FloatAccum,
            "fixture.rs",
            "fn f(xs: &[f64]) -> f64 {\n    let mut acc = 0.0;\n    for x in xs {\n        acc += x;\n    }\n    acc\n}\n",
            4,
        ),
        (Rule::NarrowingCast, "fixture.rs", "fn parse_count(n: u64) -> u32 {\n    n as u32\n}\n", 2),
        (Rule::PanicPath, "fixture.rs", "fn f(o: Option<u8>) -> u8 {\n    o.unwrap()\n}\n", 2),
        (
            Rule::FloatFormat,
            "fixture.rs",
            "fn show(x: f64) -> String {\n    format!(\"{x:.3}\")\n}\n",
            2,
        ),
        (
            Rule::WallClock,
            "rust/src/obs/fixture.rs",
            "fn f() -> std::time::Instant {\n    std::time::Instant::now()\n}\n",
            2,
        ),
        (Rule::TimeQuarantine, "fixture.rs", "use std::time::SystemTime;\nfn f() {}\n", 1),
        (
            Rule::LockOrder,
            "fixture.rs",
            "struct S {\n    a: std::sync::Mutex<u32>,\n}\nimpl S {\n    fn f(&self) -> u32 {\n        let g = self.a.lock();\n        let h = self.a.lock();\n        0\n    }\n}\n",
            7,
        ),
        (
            Rule::AtomicHygiene,
            "fixture.rs",
            "fn f(c: &std::sync::atomic::AtomicU64) {\n    c.fetch_add(1, std::sync::atomic::Ordering::Relaxed);\n}\n",
            2,
        ),
        (Rule::TaintedArith, "fixture.rs", "fn parse_total(n: u32) -> u32 {\n    n + 1\n}\n", 2),
        (
            Rule::WildcardArm,
            "fixture.rs",
            "fn f(v: Variant) -> u32 {\n    match v {\n        Variant::Smart => 0,\n        _ => 1,\n    }\n}\n",
            4,
        ),
    ]
}

#[test]
fn every_rule_fires_on_its_fixture() {
    let cfg = LintConfig::default();
    for (rule, path, src, line) in fixtures() {
        let fs = lint_source(path, src, &cfg);
        assert_eq!(fs.len(), 1, "{}: expected one finding, got {fs:?}", rule.id());
        assert_eq!(fs[0].rule, rule, "{}: wrong rule: {fs:?}", rule.id());
        assert_eq!(fs[0].line, line, "{}: wrong line: {fs:?}", rule.id());
        assert!(fs[0].suppressed.is_none(), "{}: should be open", rule.id());
        assert_eq!(fs[0].location(), format!("{path}:{line}"));
    }
}

#[test]
fn a_reasoned_pragma_suppresses_each_rule_without_d0_noise() {
    let cfg = LintConfig::default();
    for (rule, path, src, line) in fixtures() {
        // splice `// lint:allow(Dn): reason` directly above the hit line
        let mut lines: Vec<&str> = src.lines().collect();
        let pragma = format!("// lint:allow({}): fixture justification", rule.id());
        lines.insert(line as usize - 1, &pragma);
        let patched = lines.join("\n");
        let fs = lint_source(path, &patched, &cfg);
        assert_eq!(fs.len(), 1, "{}: {fs:?}", rule.id());
        assert_eq!(
            fs[0].suppressed.as_deref(),
            Some("fixture justification"),
            "{}: pragma did not suppress: {fs:?}",
            rule.id()
        );
    }
}

#[test]
fn rule_tokens_in_comments_and_strings_are_ignored() {
    let cfg = LintConfig::default();
    let src = "// prose: HashMap iteration, .unwrap(), panic!, Instant::now(), {x:.3}\n\
               /* block prose: acc += x; n as u32; m.values() */\n\
               fn f() -> &'static str {\n    \
                   \".unwrap() and {x:.3} and Instant::now() inside a string\"\n\
               }\n";
    let fs = lint_source("fixture.rs", src, &cfg);
    assert!(fs.is_empty(), "prose should never fire rules: {fs:?}");
}

#[test]
fn test_code_is_masked() {
    let cfg = LintConfig::default();
    let src = "#[cfg(test)]\nmod tests {\n    fn helper(o: Option<u8>) -> u8 {\n        o.unwrap()\n    }\n}\n";
    let fs = lint_source("fixture.rs", src, &cfg);
    assert!(fs.is_empty(), "#[cfg(test)] bodies are out of scope: {fs:?}");
    let src = "#[test]\nfn t() {\n    let x: Option<u8> = None;\n    x.unwrap();\n}\n";
    let fs = lint_source("fixture.rs", src, &cfg);
    assert!(fs.is_empty(), "#[test] bodies are out of scope: {fs:?}");
}

#[test]
fn allowlist_suppresses_by_path_suffix_and_carries_its_reason() {
    let cfg = LintConfig {
        roots: vec!["rust/src".to_string()],
        allows: vec![lint::AllowEntry {
            rule: Rule::PanicPath,
            path: "sub/fixture.rs".to_string(),
            reason: "fixture file-level waiver".to_string(),
            line: 0,
        }],
    };
    let src = "fn f(o: Option<u8>) -> u8 {\n    o.unwrap()\n}\n";
    let fs = lint_source("rust/src/sub/fixture.rs", src, &cfg);
    assert_eq!(fs.len(), 1);
    assert_eq!(fs[0].suppressed.as_deref(), Some("fixture file-level waiver"));
    // a different file stays open
    let fs = lint_source("rust/src/other.rs", src, &cfg);
    assert!(fs[0].suppressed.is_none());
}

/// Two functions acquiring the same pair of locks in opposite orders
/// form a cycle in the acquired-while-holding relation; the component is
/// reported once, at its smallest `(file, line)` edge.
#[test]
fn opposite_lock_orders_are_one_cycle_finding() {
    let cfg = LintConfig::default();
    let src = "struct S {\n    a: std::sync::Mutex<u32>,\n    b: std::sync::Mutex<u32>,\n}\n\
               impl S {\n    fn ab(&self) -> u32 {\n        let g = self.a.lock();\n        \
               let h = self.b.lock();\n        0\n    }\n    fn ba(&self) -> u32 {\n        \
               let g = self.b.lock();\n        let h = self.a.lock();\n        0\n    }\n}\n";
    let fs = lint_source("fixture.rs", src, &cfg);
    assert_eq!(fs.len(), 1, "one finding per cycle component: {fs:?}");
    assert_eq!(fs[0].rule, Rule::LockOrder);
    assert_eq!(fs[0].line, 8, "reported at the smallest edge: {fs:?}");
    assert!(fs[0].note.contains("lock-order cycle"), "{}", fs[0].note);
    assert!(fs[0].note.contains("S.a") && fs[0].note.contains("S.b"), "{}", fs[0].note);
}

/// The same two locks taken in the SAME order everywhere is the sanctioned
/// pattern — no cycle, no findings.
#[test]
fn consistent_lock_order_is_clean() {
    let cfg = LintConfig::default();
    let src = "struct S {\n    a: std::sync::Mutex<u32>,\n    b: std::sync::Mutex<u32>,\n}\n\
               impl S {\n    fn ab(&self) -> u32 {\n        let g = self.a.lock();\n        \
               let h = self.b.lock();\n        0\n    }\n    fn ab_again(&self) -> u32 {\n        \
               let g = self.a.lock();\n        let h = self.b.lock();\n        0\n    }\n}\n";
    let fs = lint_source("fixture.rs", src, &cfg);
    assert!(fs.is_empty(), "consistent order must not fire: {fs:?}");
}

#[test]
fn unused_pragmas_are_d0_and_never_suppressible() {
    let cfg = LintConfig::default();
    let fs = lint_source("fixture.rs", "// lint:allow(D4): suppresses nothing\nfn f() {}\n", &cfg);
    assert_eq!(fs.len(), 1, "{fs:?}");
    assert_eq!(fs[0].rule, Rule::Pragma);
    assert!(fs[0].suppressed.is_none());
}

/// Pinned token census of `tests/fixtures/lexer_torture.rs`: raw
/// identifiers, nested block comments, raw/byte strings,
/// lifetime-vs-char disambiguation, and float maximal munch. Any lexer
/// change that reclassifies one of these constructs moves a count here.
#[test]
fn lexer_survives_the_torture_fixture() {
    let path = Path::new(env!("CARGO_MANIFEST_DIR")).join("tests/fixtures/lexer_torture.rs");
    let text = std::fs::read_to_string(path).expect("torture fixture readable");
    let lexed = lex(&text);
    assert!(lexed.pragmas.is_empty() && lexed.malformed.is_empty());
    let mut idents = 0usize;
    let mut puncts = 0usize;
    let mut chars = 0usize;
    let mut lifetimes = 0usize;
    let mut nums: Vec<&str> = Vec::new();
    let mut strs: Vec<&str> = Vec::new();
    for t in &lexed.tokens {
        match &t.tok {
            Tok::Ident(_) => idents += 1,
            Tok::Punct(_) => puncts += 1,
            Tok::Char => chars += 1,
            Tok::Lifetime => lifetimes += 1,
            Tok::Num(n) => nums.push(n),
            Tok::Str(s) => strs.push(s),
        }
    }
    assert_eq!(lexed.tokens.len(), 63);
    assert_eq!((idents, puncts, chars, lifetimes), (25, 27, 2, 1));
    assert_eq!(nums, vec!["1.5e-3", "0.5f64", "0xEFu32", "0", "16"]);
    assert_eq!(nums.iter().filter(|n| is_float_literal(n)).count(), 2);
    assert_eq!(strs, vec!["raw \"quoted\" body", "byte raw ", "s"]);
    // raw idents resolve to the bare name, after the two-line nested
    // block comment kept the line counter honest
    let ty = lexed
        .tokens
        .iter()
        .find(|t| t.tok == Tok::Ident("type".to_string()))
        .expect("r#type lexes as `type`");
    assert_eq!(ty.line, 5);
}

/// L5 (drift) needs repo context — README text and the `configs/*.toml`
/// key inventory — so its one-finding fixture runs through
/// [`lint::analyze`] over a temp root rather than [`lint_source`].
#[test]
fn drift_rule_fires_once_on_an_undocumented_flag() {
    let dir = std::env::temp_dir().join(format!("smart_lint_l5_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(dir.join("src")).expect("temp root");
    std::fs::write(dir.join("src/main.rs"), "fn main() {\n    let _ = flag(\"ghost\");\n}\n")
        .expect("fixture main.rs");
    std::fs::write(dir.join("README.md"), "no flags documented here\n").expect("fixture README");
    let cfg = LintConfig { roots: vec!["src".to_string()], allows: Vec::new() };
    let analysis = lint::analyze(&dir, &[], &cfg).expect("analyze runs");
    let open: Vec<_> = analysis.report.unsuppressed().collect();
    assert_eq!(open.len(), 1, "{open:?}");
    assert_eq!(open[0].rule, Rule::Drift);
    assert_eq!(open[0].location(), "src/main.rs:2");
    let _ = std::fs::remove_dir_all(&dir);
}

/// Canonicalization regression: two back-to-back runs over the whole
/// repo serialize byte-identically, under the versioned report schema.
#[test]
fn lint_runs_are_byte_identical() {
    let root = Path::new(env!("CARGO_MANIFEST_DIR"));
    let cfg = LintConfig::load(&root.join("configs/lint.toml")).expect("lint.toml parses");
    let first = lint::run(root, &[], &cfg).expect("first run").to_json();
    let second = lint::run(root, &[], &cfg).expect("second run").to_json();
    assert_eq!(first, second, "report bytes must not depend on the run");
    assert!(first.contains("\"schema_version\": 2"), "{first}");
}

/// The acceptance criterion of DESIGN.md §12: the repository's own
/// sources produce zero unsuppressed findings under the checked-in
/// `configs/lint.toml`.
#[test]
fn repo_sources_are_lint_clean() {
    let root = Path::new(env!("CARGO_MANIFEST_DIR"));
    let cfg = LintConfig::load(&root.join("configs/lint.toml")).expect("lint.toml parses");
    let report = lint::run(root, &[], &cfg).expect("lint runs over rust/src");
    assert!(report.files >= 40, "scanned only {} files", report.files);
    let open: Vec<String> = report
        .unsuppressed()
        .map(|f| format!("{} {} — {}", f.rule, f.location(), f.note))
        .collect();
    assert!(open.is_empty(), "unsuppressed lint findings at HEAD:\n{}", open.join("\n"));
    // the canonical report parses and is stable under re-serialization
    let json = report.to_json();
    assert!(smart_insram::util::json::parse(&json).is_ok());
    assert_eq!(json, report.to_json());
}

/// CLI contract: nonzero exit on a seeded violation, rule id and
/// `file:line` in the panel, and `LINT_report.json` written via `--json`.
#[test]
fn cli_fails_with_rule_id_and_location_on_seeded_fixture() {
    let dir = std::env::temp_dir().join(format!("smart_lint_cli_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).expect("temp dir");
    let fixture = dir.join("seeded.rs");
    std::fs::write(&fixture, "fn f(o: Option<u8>) -> u8 { o.unwrap() }\n").expect("fixture");

    let out = std::process::Command::new(env!("CARGO_BIN_EXE_smart"))
        .current_dir(env!("CARGO_MANIFEST_DIR"))
        .args(["lint", "--json", "--out"])
        .arg(&dir)
        .arg(&fixture)
        .output()
        .expect("smart lint runs");
    assert!(!out.status.success(), "seeded violation must fail the lint");
    let stdout = String::from_utf8_lossy(&out.stdout);
    assert!(stdout.contains("D4"), "panel names the rule id:\n{stdout}");
    assert!(stdout.contains("seeded.rs:1"), "panel names file:line:\n{stdout}");
    let json = std::fs::read_to_string(dir.join("LINT_report.json")).expect("report written");
    assert!(json.contains("\"D4\""), "{json}");
    assert!(json.contains("\"unsuppressed\": 1"), "{json}");
    // the call graph ships alongside the report, failing lint or not
    let cg = std::fs::read_to_string(dir.join("CALLGRAPH.json")).expect("call graph written");
    assert!(cg.contains("\"schema_version\": 1"), "{cg}");
    let _ = std::fs::remove_dir_all(&dir);
}

/// CLI contract: the full repo run under the checked-in config exits 0.
#[test]
fn cli_is_clean_at_head() {
    let out = std::process::Command::new(env!("CARGO_BIN_EXE_smart"))
        .current_dir(env!("CARGO_MANIFEST_DIR"))
        .arg("lint")
        .output()
        .expect("smart lint runs");
    let stdout = String::from_utf8_lossy(&out.stdout);
    let stderr = String::from_utf8_lossy(&out.stderr);
    assert!(out.status.success(), "lint must be clean at HEAD\n{stdout}\n{stderr}");
}
