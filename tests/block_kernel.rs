//! Block/scalar equivalence — the acceptance contract of the block
//! execution engine (DESIGN.md §9): for ANY operands, deviates, block
//! size, shard count, or padding pattern, the lockstep `BlockKernel`
//! produces outputs bit-identical to the per-item `ScalarKernel` oracle,
//! and campaign aggregates are invariant under every performance knob.

use smart_insram::coordinator::{
    run_campaign, run_native_campaign_with, Backend, CampaignReport, CampaignSpec, Workload,
};
use smart_insram::mac::{BlockKernel, NativeMacEngine, ScalarKernel, SimKernel, TrialBlock, Variant};
use smart_insram::montecarlo::{Corner, MismatchSampler};
use smart_insram::params::Params;
use smart_insram::prop_assert;
use smart_insram::util::prop::check;

/// Bitwise comparison of every aggregate statistic in two reports.
fn assert_reports_bit_identical(a: &CampaignReport, b: &CampaignReport, label: &str) {
    assert_eq!(a.rows, b.rows, "{label}: rows");
    assert_eq!(a.raw_vmult.mean().to_bits(), b.raw_vmult.mean().to_bits(), "{label}: mean");
    assert_eq!(
        a.raw_vmult.std_dev().to_bits(),
        b.raw_vmult.std_dev().to_bits(),
        "{label}: sigma"
    );
    assert_eq!(
        a.accuracy.sigma_norm.to_bits(),
        b.accuracy.sigma_norm.to_bits(),
        "{label}: sigma_norm"
    );
    assert_eq!(a.accuracy.ber.to_bits(), b.accuracy.ber.to_bits(), "{label}: ber");
    assert_eq!(
        a.accuracy.fault_rate.to_bits(),
        b.accuracy.fault_rate.to_bits(),
        "{label}: fault_rate"
    );
    assert_eq!(a.hist.counts(), b.hist.counts(), "{label}: histogram");
    assert_eq!(a.energy.mean().to_bits(), b.energy.mean().to_bits(), "{label}: energy");
    assert_eq!(a.per_op.len(), b.per_op.len(), "{label}: per_op");
}

/// The block kernel's outputs equal the scalar oracle's, lane for lane and
/// bit for bit — random operands, deviates, block sizes, and pad patterns.
#[test]
fn block_kernel_is_bit_identical_to_scalar_oracle() {
    check(0xB10C, 40, |g| {
        let p = Params::default();
        let variant = *g.pick(&Variant::ALL);
        let engine = NativeMacEngine::new(p, variant.config(&p));
        let n = g.usize_in(1, 80);
        let seed = g.u64(1 << 40);
        let first_item = g.u64(1 << 20);

        let mut block = TrialBlock::with_capacity(n);
        block.reset(n);
        let sampler = MismatchSampler::new(seed, p.circuit.sigma_vth, p.circuit.sigma_beta)
            .with_corner(*g.pick(&[Corner::Tt, Corner::Ff, Corner::Ss]));
        {
            let (dvth, dbeta) = block.deviates_mut();
            sampler.fill_block(first_item, dvth, dbeta);
        }
        let mut n_live = 0usize;
        for i in 0..n {
            if g.usize_in(0, 9) == 0 {
                continue; // ~10% padding lanes, left unset
            }
            block.set_operands(i, g.u8_in(0, 15), g.u8_in(0, 15));
            n_live += 1;
        }
        let mut scalar = block.clone();

        BlockKernel.simulate(&engine, &mut block);
        ScalarKernel.simulate(&engine, &mut scalar);

        prop_assert!(block.out.v_mult.len() == n, "output shape");
        let mut live_seen = 0usize;
        for i in 0..n {
            prop_assert!(
                block.out.v_mult[i].to_bits() == scalar.out.v_mult[i].to_bits(),
                "lane {i}: v_mult {} != {}",
                block.out.v_mult[i],
                scalar.out.v_mult[i]
            );
            prop_assert!(
                block.out.energy[i].to_bits() == scalar.out.energy[i].to_bits(),
                "lane {i}: energy diverged"
            );
            prop_assert!(
                block.out.fault[i].to_bits() == scalar.out.fault[i].to_bits(),
                "lane {i}: fault flag diverged"
            );
            for k in 0..4 {
                prop_assert!(
                    block.out.v_blb[i * 4 + k].to_bits() == scalar.out.v_blb[i * 4 + k].to_bits(),
                    "lane {i} cell {k}: v_blb diverged"
                );
            }
            if block.is_pad(i) {
                prop_assert!(
                    block.out.v_mult[i] == 0.0
                        && block.out.energy[i] == 0.0
                        && block.out.fault[i] == 0.0,
                    "pad lane {i} simulated"
                );
            } else {
                live_seen += 1;
            }
        }
        prop_assert!(live_seen == n_live, "live-lane accounting");
        Ok(())
    });
}

/// Campaign aggregates are invariant bit for bit across kernel choice,
/// block size, and shard count — random workloads and specs.
#[test]
fn campaign_invariant_under_kernel_block_and_shards() {
    check(0xCA4470, 12, |g| {
        let p = Params::default();
        let spec = CampaignSpec {
            variant: *g.pick(&Variant::ALL),
            workload: match g.u64(3) {
                0 => Workload::Fixed { a: g.u8_in(0, 15), b: g.u8_in(0, 15) },
                1 => Workload::Random { n_ops: g.usize_in(1, 4) as u32 },
                _ => Workload::BitSweep { bits: g.u8_in(1, 2) as u32 },
            },
            n_mc: g.usize_in(1, 40) as u32,
            seed: g.u64(1 << 40),
            corner: *g.pick(&[Corner::Tt, Corner::Ff, Corner::Ss]),
            workers: 1,
            batch: 0,
            shards: 1,
            block: 0,
            kernel: smart_insram::mac::KernelKind::Block,
        };
        let base = run_native_campaign_with(&p, &spec, &ScalarKernel)
            .map_err(|e| format!("scalar: {e}"))?;
        let mut alt = spec.clone();
        alt.block = g.usize_in(1, 64);
        alt.shards = g.usize_in(1, 9);
        alt.workers = g.usize_in(1, 4);
        let block = run_native_campaign_with(&p, &alt, &BlockKernel)
            .map_err(|e| format!("block: {e}"))?;
        prop_assert!(base.rows == block.rows, "rows {} != {}", base.rows, block.rows);
        prop_assert!(
            base.raw_vmult.mean().to_bits() == block.raw_vmult.mean().to_bits(),
            "mean diverged"
        );
        prop_assert!(
            base.raw_vmult.std_dev().to_bits() == block.raw_vmult.std_dev().to_bits(),
            "sigma diverged"
        );
        prop_assert!(base.hist.counts() == block.hist.counts(), "histogram diverged");
        prop_assert!(
            base.accuracy.fault_rate.to_bits() == block.accuracy.fault_rate.to_bits(),
            "fault rate diverged"
        );
        prop_assert!(
            base.energy.mean().to_bits() == block.energy.mean().to_bits(),
            "energy diverged"
        );
        Ok(())
    });
}

/// The default native backend (block path) reproduces the scalar oracle on
/// the paper's fig8 campaign, and block size 1 equals block size 1000.
#[test]
fn acceptance_fig8_block_path_matches_oracle() {
    let p = Params::default();
    let mut spec = CampaignSpec::paper_fig8(Variant::Smart);
    spec.n_mc = 200;
    let native = run_campaign(&p, &spec, Backend::Native, None).unwrap();
    let oracle = run_native_campaign_with(&p, &spec, &ScalarKernel).unwrap();
    assert_reports_bit_identical(&native, &oracle, "fig8 block vs oracle");

    let mut tiny = spec.clone();
    tiny.block = 1;
    let one = run_campaign(&p, &tiny, Backend::Native, None).unwrap();
    let mut big = spec.clone();
    big.block = 1000;
    let thousand = run_campaign(&p, &big, Backend::Native, None).unwrap();
    assert_reports_bit_identical(&one, &thousand, "fig8 block=1 vs block=1000");
}

/// Weak-inversion and leakage lanes (low DAC codes, stored zeros) take the
/// scalar fallback inside the lockstep kernel; the full-sweep workload
/// exercises every such region and must still match the oracle exactly.
#[test]
fn full_sweep_mixed_regions_match_oracle() {
    let p = Params::default();
    let spec = CampaignSpec {
        variant: Variant::Imac, // linear DAC: smallest low-code overdrives
        workload: Workload::FullSweep,
        n_mc: 4,
        seed: 11,
        corner: Corner::Tt,
        workers: 2,
        batch: 0,
        shards: 3,
        block: 37,
        kernel: smart_insram::mac::KernelKind::Block,
    };
    let block = run_campaign(&p, &spec, Backend::Native, None).unwrap();
    let oracle = run_native_campaign_with(&p, &spec, &ScalarKernel).unwrap();
    assert_reports_bit_identical(&block, &oracle, "full sweep mixed regions");
    assert_eq!(block.rows, 256 * 4);
}
