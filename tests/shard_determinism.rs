//! Regression: a sharded MC campaign produces aggregates bit-identical to
//! a single-shard run — for any shard count, thread count, and trial-block
//! size. This is the contract that makes `--shards`/`--threads`/`--block`
//! pure performance knobs
//! (acceptance: `smart mc --variant smart --n-mc 256 --native --shards 8`
//! must match the single-shard aggregates bit for bit).

use smart_insram::coordinator::{run_campaign, Backend, CampaignSpec, Workload};
use smart_insram::mac::Variant;
use smart_insram::montecarlo::Corner;
use smart_insram::params::Params;

fn mc_spec(variant: Variant, workload: Workload, shards: usize, workers: usize) -> CampaignSpec {
    CampaignSpec {
        variant,
        workload,
        n_mc: 256,
        seed: 2022,
        corner: Corner::Tt,
        workers,
        batch: 0,
        shards,
        block: 0,
        kernel: smart_insram::mac::KernelKind::Block,
    }
}

/// Bitwise comparison of every aggregate statistic in two reports.
fn assert_bit_identical(
    a: &smart_insram::coordinator::CampaignReport,
    b: &smart_insram::coordinator::CampaignReport,
    label: &str,
) {
    assert_eq!(a.rows, b.rows, "{label}: rows");
    assert_eq!(
        a.raw_vmult.mean().to_bits(),
        b.raw_vmult.mean().to_bits(),
        "{label}: raw mean"
    );
    assert_eq!(
        a.raw_vmult.std_dev().to_bits(),
        b.raw_vmult.std_dev().to_bits(),
        "{label}: raw sigma"
    );
    assert_eq!(a.raw_vmult.min().to_bits(), b.raw_vmult.min().to_bits(), "{label}: min");
    assert_eq!(a.raw_vmult.max().to_bits(), b.raw_vmult.max().to_bits(), "{label}: max");
    assert_eq!(
        a.accuracy.sigma_norm.to_bits(),
        b.accuracy.sigma_norm.to_bits(),
        "{label}: sigma_norm"
    );
    assert_eq!(
        a.accuracy.rms_norm.to_bits(),
        b.accuracy.rms_norm.to_bits(),
        "{label}: rms_norm"
    );
    assert_eq!(a.accuracy.ber.to_bits(), b.accuracy.ber.to_bits(), "{label}: ber");
    assert_eq!(
        a.accuracy.fault_rate.to_bits(),
        b.accuracy.fault_rate.to_bits(),
        "{label}: fault_rate"
    );
    assert_eq!(a.hist.counts(), b.hist.counts(), "{label}: histogram");
    assert_eq!(a.energy.mean().to_bits(), b.energy.mean().to_bits(), "{label}: energy mean");
    assert_eq!(a.sigma_ci.is_some(), b.sigma_ci.is_some(), "{label}: CI presence");
    if let (Some((alo, ahi)), Some((blo, bhi))) = (a.sigma_ci, b.sigma_ci) {
        assert_eq!(alo.to_bits(), blo.to_bits(), "{label}: CI lo");
        assert_eq!(ahi.to_bits(), bhi.to_bits(), "{label}: CI hi");
    }
    assert_eq!(a.per_op.len(), b.per_op.len(), "{label}: per_op len");
    for ((ka, ra), (kb, rb)) in a.per_op.iter().zip(&b.per_op) {
        assert_eq!(ka, kb, "{label}: per_op key");
        assert_eq!(
            ra.sigma_norm.to_bits(),
            rb.sigma_norm.to_bits(),
            "{label}: per_op {ka:?} sigma"
        );
    }
}

#[test]
fn acceptance_shards8_matches_single_shard() {
    // the acceptance-criteria campaign: smart, n_mc 256, native, 8 shards
    let p = Params::default();
    let one = run_campaign(
        &p,
        &mc_spec(Variant::Smart, Workload::Fixed { a: 15, b: 15 }, 1, 1),
        Backend::Native,
        None,
    )
    .unwrap();
    let eight = run_campaign(
        &p,
        &mc_spec(Variant::Smart, Workload::Fixed { a: 15, b: 15 }, 8, 1),
        Backend::Native,
        None,
    )
    .unwrap();
    assert_bit_identical(&one, &eight, "shards 1 vs 8");
}

#[test]
fn thread_count_never_changes_aggregates() {
    let p = Params::default();
    let base = run_campaign(
        &p,
        &mc_spec(Variant::Aid, Workload::Fixed { a: 15, b: 15 }, 8, 1),
        Backend::Native,
        None,
    )
    .unwrap();
    for workers in [2usize, 4, 7] {
        let r = run_campaign(
            &p,
            &mc_spec(Variant::Aid, Workload::Fixed { a: 15, b: 15 }, 8, workers),
            Backend::Native,
            None,
        )
        .unwrap();
        assert_bit_identical(&base, &r, &format!("workers {workers}"));
    }
}

#[test]
fn full_sweep_shard_invariance() {
    // multi-operand workload: shard boundaries cut across operand groups
    let p = Params::default();
    let mut spec = mc_spec(Variant::Smart, Workload::FullSweep, 1, 1);
    spec.n_mc = 8; // 256 ops x 8 = 2048 items
    let one = run_campaign(&p, &spec, Backend::Native, None).unwrap();
    for shards in [5usize, 16] {
        spec.shards = shards;
        spec.workers = 4;
        let r = run_campaign(&p, &spec, Backend::Native, None).unwrap();
        assert_bit_identical(&one, &r, &format!("full sweep, {shards} shards"));
    }
}

#[test]
fn block_size_never_changes_aggregates() {
    // --block is the third pure performance knob: any trial-block size
    // folds identical rows in identical order (DESIGN.md §9)
    let p = Params::default();
    let base = run_campaign(
        &p,
        &mc_spec(Variant::Smart, Workload::Fixed { a: 15, b: 15 }, 2, 2),
        Backend::Native,
        None,
    )
    .unwrap();
    for block in [1usize, 7, 100, 4096] {
        let mut spec = mc_spec(Variant::Smart, Workload::Fixed { a: 15, b: 15 }, 2, 2);
        spec.block = block;
        let r = run_campaign(&p, &spec, Backend::Native, None).unwrap();
        assert_bit_identical(&base, &r, &format!("block {block}"));
    }
}

#[test]
fn auto_sharding_matches_explicit() {
    // shards = 0 (auto) must land on the same aggregates as any explicit
    // count — auto-sharding only picks scheduling granularity
    let p = Params::default();
    let auto = run_campaign(
        &p,
        &mc_spec(Variant::Smart, Workload::Fixed { a: 13, b: 7 }, 0, 0),
        Backend::Native,
        None,
    )
    .unwrap();
    let explicit = run_campaign(
        &p,
        &mc_spec(Variant::Smart, Workload::Fixed { a: 13, b: 7 }, 3, 2),
        Backend::Native,
        None,
    )
    .unwrap();
    assert_bit_identical(&auto, &explicit, "auto vs explicit shards");
}
