//! Bench: scalar oracle vs lockstep block kernel, items/sec (DESIGN.md §9).
//!
//! Measures the native campaign path both ways at several block sizes —
//! the number that gates the block-execution engine is the end-to-end
//! fig8-campaign speedup (target >= 2x). Pass `--smoke` for a single
//! low-cost sample (the CI configuration); `smart bench --json` records
//! the same measurement as `BENCH_native.json`.
//!
//! Run: `cargo bench --offline --bench mac_block`

use smart_insram::bench::Runner;
use smart_insram::coordinator::{run_native_campaign_with, CampaignSpec};
use smart_insram::mac::{BlockKernel, NativeMacEngine, ScalarKernel, SimKernel, TrialBlock, Variant};
use smart_insram::montecarlo::MismatchSampler;
use smart_insram::params::Params;

fn main() {
    let smoke = std::env::args().any(|a| a == "--smoke");
    let params = Params::default();
    let n_mc: u32 = if smoke { 64 } else { 1000 };
    let runner = if smoke { Runner { warmup: 0, samples: 1 } } else { Runner::default() };

    println!("=== kernel microbench — one reused 256-lane block ===");
    let engine = NativeMacEngine::new(params, Variant::Smart.config(&params));
    let sampler =
        MismatchSampler::new(7, params.circuit.sigma_vth, params.circuit.sigma_beta);
    let lanes = 256usize;
    let mut block = TrialBlock::with_capacity(lanes);
    let refill = |block: &mut TrialBlock| {
        block.reset(lanes);
        let (dvth, dbeta) = block.deviates_mut();
        sampler.fill_block(0, dvth, dbeta);
        for i in 0..lanes {
            block.set_operands(i, 15, 15);
        }
    };
    refill(&mut block);
    let s = runner.bench("mac_block/scalar kernel (256 lanes)", || {
        ScalarKernel.simulate(&engine, &mut block)
    });
    let scalar_lane_ips = s.per_second(lanes as u64);
    refill(&mut block);
    let s = runner.bench("mac_block/block kernel  (256 lanes)", || {
        BlockKernel.simulate(&engine, &mut block)
    });
    let block_lane_ips = s.per_second(lanes as u64);
    println!(
        "  scalar {scalar_lane_ips:.0} lanes/s, block {block_lane_ips:.0} lanes/s \
         ({:.2}x)\n",
        block_lane_ips / scalar_lane_ips
    );

    println!("=== end-to-end fig8 campaign (n_mc = {n_mc}) ===");
    let mut spec = CampaignSpec::paper_fig8(Variant::Smart);
    spec.n_mc = n_mc;
    spec.workers = 1; // single thread: isolate the kernel, not the pool
    let campaign = |label: &str, kernel: &dyn SimKernel, block: usize| {
        let mut spec = spec.clone();
        spec.block = block;
        let s = runner.bench(label, || {
            run_native_campaign_with(&params, &spec, kernel).expect("campaign")
        });
        s.per_second(u64::from(n_mc))
    };
    let scalar_ips = campaign("mac_block/campaign scalar oracle", &ScalarKernel, 0);
    let block_ips = campaign("mac_block/campaign block kernel", &BlockKernel, 0);
    for b in [64usize, 1024] {
        campaign(&format!("mac_block/campaign block kernel (block = {b})"), &BlockKernel, b);
    }
    let speedup = block_ips / scalar_ips;
    println!(
        "  campaign: scalar {scalar_ips:.0} items/s -> block {block_ips:.0} items/s \
         ({speedup:.2}x)"
    );
    if !smoke {
        assert!(
            speedup > 1.0,
            "block kernel slower than the scalar oracle ({speedup:.2}x)"
        );
    }
}
