//! Bench + regeneration of **Table 1**: the head-to-head comparison of
//! SMART vs AID [10] vs IMAC [9] (+ quoted [14]/[21] rows) on MAC energy,
//! accuracy (normalized sigma over the full operand space), and frequency.
//!
//! Run: `cargo bench --offline --bench table1_comparison`

use smart_insram::bench::Runner;
use smart_insram::coordinator::{run_campaign, Backend, CampaignSpec, Workload};
use smart_insram::energy::{nominal_cost, EnergyModel};
use smart_insram::mac::Variant;
use smart_insram::params::Params;
use smart_insram::report;
use smart_insram::runtime::default_artifact_dir;

fn main() {
    let params = Params::default();
    let dir = default_artifact_dir();
    let backend = if dir.join("manifest.json").exists() {
        Backend::Xla
    } else {
        Backend::Native
    };
    let model = EnergyModel::default();
    let n_mc = 100; // per operand pair x 256 pairs = 25.6k MACs per variant

    let accuracy = |variant: Variant| {
        let spec = CampaignSpec {
            variant,
            workload: Workload::FullSweep,
            n_mc,
            seed: 2022,
            corner: smart_insram::montecarlo::Corner::Tt,
            workers: 0,
            batch: 0,
            shards: 0,
            block: 0,
            kernel: smart_insram::mac::KernelKind::Block,
        };
        run_campaign(&params, &spec, backend, Some(dir.clone())).expect("campaign")
    };

    println!("=== Table 1 — comprehensive comparison ===\n");
    let mut sigmas = Vec::new();
    for v in [Variant::Smart, Variant::Aid, Variant::Imac] {
        let r = accuracy(v);
        println!(
            "{:<14} accuracy sweep: rms/FS {:.4}, BER {:.4}, {} evals in {:.2?}",
            v.name(),
            r.accuracy.rms_norm,
            r.accuracy.ber,
            r.rows,
            r.wall
        );
        sigmas.push((v, r.accuracy.rms_norm));
    }
    println!();
    println!("{}", report::build_table1(&params, &sigmas, &model));

    // shape assertions against the paper's Table 1
    let sig = |v: Variant| sigmas.iter().find(|(x, _)| *x == v).unwrap().1;
    let cost = |v: Variant| nominal_cost(&params, v, &model);
    assert!(sig(Variant::Smart) < sig(Variant::Aid), "accuracy column shape");
    assert!(sig(Variant::Aid) < sig(Variant::Imac), "accuracy column shape");
    assert!(
        cost(Variant::Aid).energy < cost(Variant::Smart).energy
            && cost(Variant::Smart).energy < cost(Variant::Imac).energy,
        "energy column shape (paper: 0.523 < 0.783 < 0.9 pJ)"
    );
    assert!(
        cost(Variant::Smart).frequency > cost(Variant::Aid).frequency
            && cost(Variant::Aid).frequency > cost(Variant::Imac).frequency,
        "frequency column shape (paper: 250 > 200 > 100 MHz)"
    );
    println!("all Table 1 orderings hold (energy, accuracy, frequency)");

    println!("\n=== timing — full-sweep campaign per variant ===");
    let r = Runner::quick();
    for v in [Variant::Smart, Variant::Aid, Variant::Imac] {
        let s = r.bench(&format!("table1/{} (cold)", v.name()), || accuracy(v));
        println!("  {:.0} MAC evals/s", s.per_second(256 * u64::from(n_mc)));
    }
    if backend == Backend::Xla {
        // §Perf: persistent engine amortizes the PJRT compile
        use smart_insram::coordinator::CampaignEngine;
        let mut engine = CampaignEngine::new(dir.clone(), 256, 1).expect("engine");
        for v in [Variant::Smart, Variant::Aid, Variant::Imac] {
            let spec = CampaignSpec {
                variant: v,
                workload: Workload::FullSweep,
                n_mc,
                seed: 2022,
                corner: smart_insram::montecarlo::Corner::Tt,
                workers: 1,
                batch: 256,
                shards: 0,
                block: 0,
                kernel: smart_insram::mac::KernelKind::Block,
            };
            let s = r.bench(&format!("table1/{} (warm engine)", v.name()), || {
                engine.run(&params, &spec).unwrap()
            });
            println!("  {:.0} MAC evals/s", s.per_second(256 * u64::from(n_mc)));
        }
    }
}
