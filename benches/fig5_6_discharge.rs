//! Bench + regeneration of **Fig. 5 / Fig. 6**: V_BLB(t) discharge for
//! IMAC [9] (Fig. 5) and AID [10] (Fig. 6), V_bulk = 0 vs 0.6 V — body
//! bias accelerates the discharge in both architectures.
//!
//! Exercises BOTH transient paths: the native Rust integrator and the
//! AOT trace artifact through PJRT, and checks they agree.
//!
//! Run: `cargo bench --offline --bench fig5_6_discharge`

use smart_insram::bench::Runner;
use smart_insram::circuit::{discharge_trace, BitlineInputs};
use smart_insram::dac::WordlineDac;
use smart_insram::device::Mosfet;
use smart_insram::mac::Variant;
use smart_insram::params::Params;
use smart_insram::runtime::{default_artifact_dir, MacBatch, XlaRuntime};

fn main() {
    let params = Params::default();
    let card = params.device;
    let t_total = 1.0e-9;

    for (fig, variant) in [("Fig. 5", Variant::Imac), ("Fig. 6", Variant::Aid)] {
        let cfg = variant.config(&params);
        let dac = WordlineDac::new(cfg.dac_mode, &card, &params.circuit, 0.0);
        let v_wl = dac.v_wl(15);
        println!("=== {fig} — {} V_BLB(t), V_WL = {:.0} mV ===", variant.name(), v_wl * 1e3);
        println!("{:>10} {:>14} {:>14}", "t (ps)", "Vb=0 (V)", "Vb=0.6 (V)");
        let trace = |vb: f64| {
            let inp = BitlineInputs { v_wl, bit: true, v_bulk: vb };
            discharge_trace(&params, &Mosfet::nominal(card), &inp, t_total, 512, 32)
        };
        let (w0, w6) = (trace(0.0), trace(0.6));
        for ((t, v0), (_, v6)) in w0.iter().zip(w6.iter()) {
            println!("{:>10.0} {v0:>14.4} {v6:>14.4}", t * 1e12);
            assert!(v6 <= v0 + 1e-12, "{fig} shape violated (bias must discharge faster)");
        }
        let c0 = w0.crossing_time(0.75);
        let c6 = w6.crossing_time(0.75);
        if let (Some(c0), Some(c6)) = (c0, c6) {
            println!(
                "time to 0.25 V discharge: {:.0} ps -> {:.0} ps ({:.2}x faster)\n",
                c0 * 1e12,
                c6 * 1e12,
                c0 / c6
            );
        } else {
            println!();
        }
    }

    // cross-check the AOT trace artifact against the native integrator
    let dir = default_artifact_dir();
    if dir.join("manifest.json").exists() {
        let mut rt = XlaRuntime::open(&dir).expect("runtime");
        let cfg = Variant::Aid.config(&params);
        let mut batch = MacBatch::nominal(8, 0.0, cfg.dac_mode.flag(), cfg.t_sample as f32);
        for i in 0..8 {
            batch.set_row(i, 15, 15, [0.0; 4], [0.0; 4]);
        }
        let n_points = rt.manifest().trace_points;
        let trace = rt.run_trace(&batch, t_total as f32).expect("trace");
        // native twin of row 0 / cell 0 at the artifact's sample stride
        let dac = WordlineDac::new(cfg.dac_mode, &card, &params.circuit, 0.0);
        let inp = BitlineInputs { v_wl: dac.v_wl(15), bit: true, v_bulk: 0.0 };
        let stride = params.circuit.n_steps / n_points as u32;
        let steps = params.circuit.n_steps;
        let wf = discharge_trace(&params, &Mosfet::nominal(card), &inp, t_total, steps, stride);
        let mut worst = 0.0f64;
        for t in 0..n_points {
            let hlo = f64::from(trace[t * 32]); // (t, row 0, cell 0)
            let nat = wf.values()[t + 1]; // wf includes t=0
            worst = worst.max((hlo - nat).abs());
        }
        println!("HLO trace vs native integrator, worst |delta| = {worst:.2e} V");
        assert!(worst < 1e-3, "trace paths disagree");

        println!("\n=== timing ===");
        let r = Runner::default();
        r.bench("fig5_6/native trace 512 steps", || {
            discharge_trace(&params, &Mosfet::nominal(card), &inp, t_total, 512, 32)
        });
        r.bench("fig5_6/hlo trace artifact (8 rows)", || {
            rt.run_trace(&batch, t_total as f32).unwrap()
        });
    } else {
        println!("artifacts not built; skipping HLO trace cross-check");
    }
}
