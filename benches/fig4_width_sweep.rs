//! Bench + regeneration of **Fig. 4**: drain current vs transistor width,
//! V_bulk = 0 (solid) against V_bulk = 0.6 V (dashed) — the biased curve
//! wins at every width.
//!
//! Run: `cargo bench --offline --bench fig4_width_sweep`

use smart_insram::bench::{eng, Runner};
use smart_insram::device::width_sweep;
use smart_insram::params::Params;

fn main() {
    let params = Params::default();
    let card = params.device;
    let ws: Vec<f64> = (1..=20).map(|k| k as f64 * 0.25).collect();
    let v_wl = 0.55;

    println!("=== Fig. 4 — I_D vs width scale (V_WL = {v_wl} V) ===");
    let pts = width_sweep(card, v_wl, &[0.0, 0.6], &ws);
    let (solid, dashed) = pts.split_at(ws.len());
    println!("{:>8} {:>14} {:>14} {:>8}", "W-scale", "Vb=0 (solid)", "Vb=0.6 (dash)", "gain");
    for (s, d) in solid.iter().zip(dashed) {
        println!(
            "{:>8.2} {:>14} {:>14} {:>7.2}x",
            s.w_scale,
            eng(s.i_d),
            eng(d.i_d),
            d.i_d / s.i_d
        );
        assert!(d.i_d > s.i_d, "Fig. 4 shape violated at W = {}", s.w_scale);
    }
    let gain = dashed[0].i_d / solid[0].i_d;
    println!("\nbody-bias gain is width-independent: {gain:.2}x (square-law overdrive ratio)");

    println!("\n=== timing ===");
    let r = Runner::default();
    let s = r.bench("fig4/width_sweep 2x20 widths", || {
        width_sweep(card, v_wl, &[0.0, 0.6], &ws)
    });
    println!("  {:.1} Mpoints/s", s.per_second(2 * ws.len() as u64) / 1e6);
}
