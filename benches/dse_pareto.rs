//! Bench + demo of the design-space exploration subsystem: expand the
//! threshold-suppression grid, run every point through the sharded MC
//! runner, extract the energy-vs-sigma Pareto front.
//!
//! Run: `cargo bench --offline --bench dse_pareto`

use smart_insram::bench::Runner;
use smart_insram::dse::{run_sweep, SweepOptions, SweepSpec};
use smart_insram::report;

const SPEC: &str = r#"
name = "dse-bench"
seed = 2022
n_mc = 16
[grid]
variant = ["smart"]
vdd = [0.9, 1.0]
v_bulk = [0.0, 0.3, 0.6]
bits = [2, 4]
corner = ["tt"]
"#;

fn main() {
    let spec = SweepSpec::parse(SPEC).expect("spec");
    let out_dir = std::env::temp_dir().join("smart_dse_bench");
    println!("=== DSE sweep — {} grid points, n_mc = {} ===", spec.grid.len(), spec.n_mc);

    let r = Runner::quick();
    for (shards, threads) in [(1usize, 1usize), (0, 0)] {
        let opts = SweepOptions {
            shards,
            threads,
            block: 0,
            resume: false,
            out_dir: out_dir.clone(),
        };
        let label = if threads == 0 {
            "dse/sweep (auto shards/threads)".to_string()
        } else {
            format!("dse/sweep ({shards} shard, {threads} thread)")
        };
        let s = r.bench(&label, || run_sweep(&spec, &opts).expect("sweep"));
        let total: u64 = spec.grid.len() as u64; // campaigns per iteration
        println!("  {:.1} grid points/s", s.per_second(total));
    }

    // resumed re-run: every row comes from the checkpoint (no simulation)
    let opts =
        SweepOptions { shards: 0, threads: 0, block: 0, resume: true, out_dir: out_dir.clone() };
    r.bench("dse/sweep (fully resumed)", || run_sweep(&spec, &opts).expect("resume"));

    let result = run_sweep(&spec, &opts).expect("sweep");
    print!("{}", report::sweep_panel(&result));
    assert_eq!(result.resumed, result.points.len(), "checkpoint must cover the grid");
    let n_front = result.pareto.iter().filter(|&&f| f).count();
    assert!(n_front >= 1, "empty Pareto front");
    assert!(n_front <= result.points.len());
}
