//! Bench + regeneration of **Fig. 3**: access-transistor I-V transfer for
//! V_bulk in {0, 0.2, 0.4, 0.6} V — body biasing shifts turn-on left by
//! ~125 mV at 0.6 V.
//!
//! Run: `cargo bench --offline --bench fig3_body_bias`

use smart_insram::bench::{eng, Runner};
use smart_insram::device::{iv_sweep, turn_on_v_wl, Mosfet};
use smart_insram::params::Params;

fn main() {
    let params = Params::default();
    let card = params.device;
    let bulks = [0.0, 0.2, 0.4, 0.6];

    println!("=== Fig. 3 — I_D(V_WL) per body bias ===");
    let dev = Mosfet::nominal(card);
    println!("{:>8} {:>12} {:>12} {:>12} {:>12}", "V_WL", "Vb=0.0", "Vb=0.2", "Vb=0.4", "Vb=0.6");
    for k in (0..=20).map(|k| k as f64 * 0.05) {
        let row: Vec<String> = bulks
            .iter()
            .map(|&vb| format!("{:>12}", eng(dev.drain_current(k, card.vdd, vb))))
            .collect();
        println!("{k:>8.2} {}", row.join(" "));
    }

    println!("\nturn-on voltage (I_D > 10 uA) per body bias:");
    let turn_on = |vb: f64| {
        turn_on_v_wl(&iv_sweep(card, &[vb], 4001), 10e-6).expect("sweep must cross 10 uA")
    };
    for &vb in &bulks {
        println!(
            "  V_bulk = {vb:.1} V: turn-on {:.0} mV  (Eq. 6 dVTH = {:+.1} mV)",
            turn_on(vb) * 1e3,
            card.delta_vth_body(vb) * 1e3
        );
    }
    let delta = turn_on(0.0) - turn_on(0.6);
    println!("shift at 0.6 V = {:.1} mV (paper: ~125 mV)", delta * 1e3);
    assert!((0.110..0.140).contains(&delta), "Fig. 3 shape violated");

    println!("\n=== timing ===");
    let r = Runner::default();
    let s = r.bench("fig3/iv_sweep 4x2001 points", || iv_sweep(card, &bulks, 2001));
    println!("  {:.1} Mpoints/s", s.per_second(4 * 2001) / 1e6);
}
