//! Bench + regeneration of **Fig. 8 / Fig. 9**: 1000-point Monte-Carlo
//! (process + mismatch) of the 1111 x 1111 MAC.
//!
//! * Fig. 8: SMART applied to AID [10] — sigma shrinks, histogram tightens.
//! * Fig. 9: SMART applied to IMAC [9] — same effect on the linear-DAC design.
//!
//! Benchmarks the end-to-end campaign on both backends (XLA worker pool
//! vs native) and at several worker counts.
//!
//! Run: `cargo bench --offline --bench fig8_9_montecarlo`

use smart_insram::bench::Runner;
use smart_insram::coordinator::{run_campaign, Backend, CampaignSpec};
use smart_insram::mac::Variant;
use smart_insram::params::Params;
use smart_insram::report;
use smart_insram::runtime::default_artifact_dir;

fn main() {
    let params = Params::default();
    let dir = default_artifact_dir();
    let have_artifacts = dir.join("manifest.json").exists();
    let backend = if have_artifacts { Backend::Xla } else { Backend::Native };
    if !have_artifacts {
        println!("artifacts not built; falling back to the native backend\n");
    }

    let run = |variant: Variant, n_mc: u32| {
        let mut spec = CampaignSpec::paper_fig8(variant);
        spec.n_mc = n_mc;
        run_campaign(&params, &spec, backend, Some(dir.clone())).expect("campaign")
    };

    println!("=== Fig. 8 — AID [10] vs SMART-on-[10], 1000-pt MC ===");
    let aid = run(Variant::Aid, 1000);
    let smart = run(Variant::Smart, 1000);
    print!("{}", report::mc_panel("AID [10]", &aid));
    print!("{}", report::mc_panel("SMART", &smart));
    let s_aid = aid.raw_vmult.std_dev() / aid.full_scale;
    let s_smart = smart.raw_vmult.std_dev() / smart.full_scale;
    println!(
        "normalized sigma: AID {s_aid:.4} -> SMART {s_smart:.4} \
         ({:.2}x better; paper: 0.086 -> 0.009)\n",
        s_aid / s_smart
    );
    assert!(s_smart < s_aid, "Fig. 8 shape violated");

    println!("=== Fig. 9 — IMAC [9] vs SMART-on-[9], 1000-pt MC ===");
    let imac = run(Variant::Imac, 1000);
    let soi = run(Variant::SmartOnImac, 1000);
    print!("{}", report::mc_panel("IMAC [9]", &imac));
    print!("{}", report::mc_panel("SMART-on-IMAC", &soi));
    let s_imac = imac.raw_vmult.std_dev() / imac.full_scale;
    let s_soi = soi.raw_vmult.std_dev() / soi.full_scale;
    println!(
        "normalized sigma: IMAC {s_imac:.4} -> SMART-on-IMAC {s_soi:.4} ({:.2}x better)\n",
        s_imac / s_soi
    );
    assert!(s_soi < s_imac, "Fig. 9 shape violated");

    println!("=== timing — end-to-end 1000-pt campaign ===");
    let r = Runner::quick();
    let s = r.bench("fig8_9/xla cold (compile + run)", || run(Variant::Smart, 1000));
    println!("  {:.0} MAC evals/s", s.per_second(1000));
    {
        // native kernels head to head (§9): the default campaign path is
        // the lockstep block kernel; the scalar oracle is the baseline
        use smart_insram::coordinator::run_native_campaign_with;
        use smart_insram::mac::{BlockKernel, ScalarKernel};
        let mut spec = CampaignSpec::paper_fig8(Variant::Smart);
        spec.n_mc = 1000;
        let s = r.bench("fig8_9/native scalar oracle", || {
            run_native_campaign_with(&params, &spec, &ScalarKernel).unwrap()
        });
        let scalar_ips = s.per_second(1000);
        let s = r.bench("fig8_9/native block kernel", || {
            run_native_campaign_with(&params, &spec, &BlockKernel).unwrap()
        });
        let block_ips = s.per_second(1000);
        println!(
            "  scalar {scalar_ips:.0} -> block {block_ips:.0} MAC evals/s ({:.2}x)",
            block_ips / scalar_ips
        );
    }
    if have_artifacts {
        // §Perf: persistent CampaignEngine amortizes the PJRT compile —
        // the dominant per-campaign cost on this host.
        use smart_insram::coordinator::CampaignEngine;
        let mut engine = CampaignEngine::new(dir.clone(), 256, 1).expect("engine");
        let mut spec = CampaignSpec::paper_fig8(Variant::Smart);
        spec.n_mc = 1000;
        let s = r.bench("fig8_9/xla warm (persistent engine)", || {
            engine.run(&params, &spec).unwrap()
        });
        println!("  {:.0} MAC evals/s", s.per_second(1000));
        for workers in [2usize, 4] {
            let mut spec = CampaignSpec::paper_fig8(Variant::Smart);
            spec.n_mc = 1000;
            spec.workers = workers;
            let s = r.bench(&format!("fig8_9/xla cold ({workers} workers)"), || {
                run_campaign(&params, &spec, Backend::Xla, Some(dir.clone())).unwrap()
            });
            println!("  {:.0} MAC evals/s", s.per_second(1000));
        }
        let mut spec = CampaignSpec::paper_fig8(Variant::Smart);
        spec.n_mc = 1000;
        let s = r.bench("fig8_9/native backend", || {
            run_campaign(&params, &spec, Backend::Native, None).unwrap()
        });
        println!("  {:.0} MAC evals/s", s.per_second(1000));
    }
}
