//! Dual-mode SRAM array: digital memory + analog MAC columns (Fig. 7).

use super::word::MacWord;
use crate::params::DeviceCard;

/// Operating mode (paper §III: "memory mode" vs "mathematical mode").
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ArrayMode {
    /// Plain SRAM read/write access.
    Memory,
    /// Analog in-memory MAC compute.
    Mathematical,
}

/// An array of MAC words. Each row holds one 4-bit stored operand; in
/// mathematical mode the row's word-lines carry the DAC-coded second
/// operand and the BLB charge-share produces the analog product.
#[derive(Debug, Clone)]
pub struct SramArray {
    rows: Vec<MacWord>,
    mode: ArrayMode,
    card: DeviceCard,
}

impl SramArray {
    /// `n_rows` nominal words, starting in memory mode.
    pub fn new(card: DeviceCard, n_rows: usize) -> Self {
        Self { rows: vec![MacWord::new(card); n_rows], mode: ArrayMode::Memory, card }
    }

    /// Number of word rows.
    pub fn n_rows(&self) -> usize {
        self.rows.len()
    }

    /// Current operating mode.
    pub fn mode(&self) -> ArrayMode {
        self.mode
    }

    /// Switch mode. Entering mathematical mode requires the operands to be
    /// written first (memory-mode writes), exactly like the paper's flow.
    pub fn set_mode(&mut self, mode: ArrayMode) {
        self.mode = mode;
    }

    /// Digital write of a 4-bit word (memory mode only).
    pub fn write(&mut self, row: usize, value: u8) -> Result<(), ModeError> {
        if self.mode != ArrayMode::Memory {
            return Err(ModeError::WriteInMathMode);
        }
        self.rows[row].store(value);
        Ok(())
    }

    /// Digital read of a 4-bit word (memory mode only).
    pub fn read(&self, row: usize) -> Result<u8, ModeError> {
        if self.mode != ArrayMode::Memory {
            return Err(ModeError::ReadInMathMode);
        }
        Ok(self.rows[row].load())
    }

    /// Access a row's word for the compute path (mathematical mode only).
    pub fn word(&self, row: usize) -> Result<&MacWord, ModeError> {
        if self.mode != ArrayMode::Mathematical {
            return Err(ModeError::ComputeInMemoryMode);
        }
        Ok(&self.rows[row])
    }

    /// Replace a row with a mismatch-bearing word (MC instantiation).
    pub fn instantiate_mismatch(&mut self, row: usize, dvth: [f64; 4], dbeta: [f64; 4]) {
        let stored = self.rows[row].load();
        let mut w = MacWord::with_mismatch(self.card, dvth, dbeta);
        w.store(stored);
        self.rows[row] = w;
    }
}

/// Mode-discipline violations — the paper's architecture forbids mixing
/// memory and mathematical operations in the same phase.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ModeError {
    /// Digital write attempted in mathematical mode.
    WriteInMathMode,
    /// Digital read attempted in mathematical mode.
    ReadInMathMode,
    /// Compute access attempted in memory mode.
    ComputeInMemoryMode,
}

impl std::fmt::Display for ModeError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let s = match self {
            Self::WriteInMathMode => "digital write while in mathematical mode",
            Self::ReadInMathMode => "digital read while in mathematical mode",
            Self::ComputeInMemoryMode => "compute access while in memory mode",
        };
        f.write_str(s)
    }
}

impl std::error::Error for ModeError {}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::params::DeviceCard;

    #[test]
    fn memory_mode_read_write() {
        let mut a = SramArray::new(DeviceCard::default(), 8);
        a.write(3, 0b1010).unwrap();
        assert_eq!(a.read(3).unwrap(), 0b1010);
    }

    #[test]
    fn mode_discipline_enforced() {
        let mut a = SramArray::new(DeviceCard::default(), 2);
        a.write(0, 7).unwrap();
        a.set_mode(ArrayMode::Mathematical);
        assert_eq!(a.write(0, 1), Err(ModeError::WriteInMathMode));
        assert_eq!(a.read(0), Err(ModeError::ReadInMathMode));
        assert_eq!(a.word(0).unwrap().load(), 7);
        a.set_mode(ArrayMode::Memory);
        assert_eq!(a.word(0).unwrap_err(), ModeError::ComputeInMemoryMode);
    }

    #[test]
    fn mismatch_instantiation_preserves_stored_value() {
        let mut a = SramArray::new(DeviceCard::default(), 1);
        a.write(0, 0b1101).unwrap();
        a.instantiate_mismatch(0, [1e-3; 4], [0.01; 4]);
        assert_eq!(a.read(0).unwrap(), 0b1101);
    }
}
