//! Precharge circuit model (paper §III: "[10]'s circuitry; no static
//! current, so no additional power overhead").

use crate::params::CircuitCard;

/// PMOS precharge network: restores BL/BLB to VDD between operations.
#[derive(Debug, Clone, Copy)]
pub struct Precharge {
    /// Effective pull-up current of the precharge PMOS pair (A).
    pub i_pullup: f64,
}

impl Default for Precharge {
    fn default() -> Self {
        // ~60 uA pull-up: restores a 30 fF bitline through ~0.5 V in <0.5 ns.
        Self { i_pullup: 60e-6 }
    }
}

impl Precharge {
    /// Time to restore the bitline from `v_from` to within `margin` of
    /// `vdd` (s) — a CV/I estimate with a settling guard band.
    pub fn restore_time(&self, c: &CircuitCard, vdd: f64, v_from: f64, margin: f64) -> f64 {
        let dv = (vdd - margin - v_from).max(0.0);
        // CV/I charge phase + 3 RC-equivalent settling constants.
        let t_slew = c.c_blb * dv / self.i_pullup;
        let r_eq = vdd / self.i_pullup;
        t_slew + 3.0 * r_eq * c.c_blb
    }

    /// Dynamic energy to restore the discharged charge (J): the charge
    /// C*dV is replaced from the supply at VDD.
    pub fn restore_energy(&self, c: &CircuitCard, vdd: f64, v_from: f64) -> f64 {
        c.c_blb * vdd * (vdd - v_from).max(0.0)
    }

    /// Static power is zero by construction (clocked PMOS, paper §III).
    pub fn static_power(&self) -> f64 {
        0.0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::params::CircuitCard;

    #[test]
    fn restore_time_scales_with_depth() {
        let p = Precharge::default();
        let c = CircuitCard::default();
        let shallow = p.restore_time(&c, 1.0, 0.9, 0.01);
        let deep = p.restore_time(&c, 1.0, 0.4, 0.01);
        assert!(deep > shallow);
        assert!(deep < 5e-9, "precharge should finish in a few ns: {deep}");
    }

    #[test]
    fn restore_energy_is_c_vdd_dv() {
        let p = Precharge::default();
        let c = CircuitCard::default();
        let e = p.restore_energy(&c, 1.0, 0.6);
        assert!((e - c.c_blb * 0.4).abs() < 1e-20);
        assert_eq!(p.restore_energy(&c, 1.0, 1.2), 0.0);
    }

    #[test]
    fn no_static_power() {
        assert_eq!(Precharge::default().static_power(), 0.0);
    }
}
