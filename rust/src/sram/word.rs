//! The 4-cell MAC word: one stored operand, MSB leftmost (paper Fig. 7).

use super::cell::SramCell;
use crate::params::DeviceCard;

/// Binary weights of the MSB-first cells, normalized to sum to 1
/// (8/15, 4/15, 2/15, 1/15) — the charge-share combine ratio.
pub const WEIGHTS: [f64; 4] = [8.0 / 15.0, 4.0 / 15.0, 2.0 / 15.0, 1.0 / 15.0];

/// A word of `N_BITS` cells storing one MAC operand.
#[derive(Debug, Clone)]
pub struct MacWord {
    cells: [SramCell; 4],
}

impl MacWord {
    /// Nominal word (no mismatch).
    pub fn new(card: DeviceCard) -> Self {
        Self { cells: [SramCell::new(card); 4] }
    }

    /// Word whose four access transistors carry per-cell mismatch.
    pub fn with_mismatch(card: DeviceCard, dvth: [f64; 4], dbeta: [f64; 4]) -> Self {
        let mk = |i: usize| SramCell::with_mismatch(card, dvth[i], dbeta[i]);
        Self { cells: [mk(0), mk(1), mk(2), mk(3)] }
    }

    /// Store a 4-bit operand, MSB into cell 0 (the leftmost cell).
    pub fn store(&mut self, value: u8) {
        assert!(value < 16, "operand must be 4-bit, got {value}");
        for (i, cell) in self.cells.iter_mut().enumerate() {
            cell.write(value >> (3 - i) & 1 == 1);
        }
    }

    /// Read the stored operand back digitally.
    pub fn load(&self) -> u8 {
        self.cells
            .iter()
            .enumerate()
            .fold(0u8, |acc, (i, c)| acc | (u8::from(c.read()) << (3 - i)))
    }

    /// MSB-first bit view, as the compute path sees it.
    pub fn bits(&self) -> [bool; 4] {
        [
            self.cells[0].conducts_blb(),
            self.cells[1].conducts_blb(),
            self.cells[2].conducts_blb(),
            self.cells[3].conducts_blb(),
        ]
    }

    /// The four cells, MSB first.
    pub fn cells(&self) -> &[SramCell; 4] {
        &self.cells
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::params::DeviceCard;

    #[test]
    fn store_load_roundtrip_all_codes() {
        let mut w = MacWord::new(DeviceCard::default());
        for v in 0..16u8 {
            w.store(v);
            assert_eq!(w.load(), v);
        }
    }

    #[test]
    fn msb_is_leftmost() {
        let mut w = MacWord::new(DeviceCard::default());
        w.store(0b1000);
        assert_eq!(w.bits(), [true, false, false, false]);
        w.store(0b0001);
        assert_eq!(w.bits(), [false, false, false, true]);
    }

    #[test]
    #[should_panic(expected = "4-bit")]
    fn store_rejects_wide_operands() {
        MacWord::new(DeviceCard::default()).store(16);
    }

    #[test]
    fn weights_sum_to_one() {
        assert!((WEIGHTS.iter().sum::<f64>() - 1.0).abs() < 1e-12);
    }
}
