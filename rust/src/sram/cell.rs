//! Single 6T-SRAM cell (paper Fig. 2).

use crate::device::Mosfet;
use crate::params::DeviceCard;

/// A 6T cell: two cross-coupled inverters plus two access transistors.
/// We track the stored state digitally and model the two access devices
/// (M1acc on BL, M2acc on BLB) as [`Mosfet`] instances whose mismatch
/// deviates come from the Monte-Carlo sampler.
#[derive(Debug, Clone, Copy)]
pub struct SramCell {
    /// Stored value at node Q (`true` = VDD). The paper's compute-mode
    /// initial condition is Q = VDD, Qbar = 0 (§II).
    q: bool,
    /// BLB-side access transistor M2acc — the compute-path device.
    pub m2_acc: Mosfet,
}

impl SramCell {
    /// A cell holding 0 with a nominal access device.
    pub fn new(card: DeviceCard) -> Self {
        Self { q: false, m2_acc: Mosfet::nominal(card) }
    }

    /// A cell whose access transistor carries mismatch deviates.
    pub fn with_mismatch(card: DeviceCard, dvth: f64, dbeta: f64) -> Self {
        Self { q: false, m2_acc: Mosfet::with_mismatch(card, dvth, dbeta) }
    }

    /// Digital write: drive BL/BLB full-rail and pulse the WL (§II).
    pub fn write(&mut self, value: bool) {
        self.q = value;
    }

    /// Digital read: returns the stored value (BL side discharges when
    /// Q = 0, BLB side when Q = 1 — we return the decoded bit).
    pub fn read(&self) -> bool {
        self.q
    }

    /// Whether the BLB discharge path (M2acc -> M3) conducts in compute
    /// mode: requires Qbar = 0, i.e. a stored 1.
    pub fn conducts_blb(&self) -> bool {
        self.q
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::params::DeviceCard;

    #[test]
    fn write_then_read_roundtrip() {
        let mut c = SramCell::new(DeviceCard::default());
        assert!(!c.read());
        c.write(true);
        assert!(c.read());
        c.write(false);
        assert!(!c.read());
    }

    #[test]
    fn compute_path_follows_stored_bit() {
        let mut c = SramCell::new(DeviceCard::default());
        assert!(!c.conducts_blb());
        c.write(true);
        assert!(c.conducts_blb());
    }

    #[test]
    fn mismatch_is_carried_by_access_device() {
        let c = SramCell::with_mismatch(DeviceCard::default(), 5e-3, -0.01);
        assert_eq!(c.m2_acc.dvth, 5e-3);
        assert_eq!(c.m2_acc.dbeta, -0.01);
    }
}
