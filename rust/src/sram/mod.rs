//! 6T-SRAM substrate: cells, the 4-cell MAC word, the array, and the
//! precharge circuit (paper §II, Fig. 2 and Fig. 7).
//!
//! The array is a real dual-mode memory: in *memory mode* it performs
//! digital read/write; in *mathematical mode* a row stores one MAC operand
//! and the word-lines carry the DAC-coded second operand (paper §III).

mod array;
mod cell;
mod precharge;
mod word;

pub use array::{ArrayMode, SramArray};
pub use cell::SramCell;
pub use precharge::Precharge;
pub use word::{MacWord, WEIGHTS};
