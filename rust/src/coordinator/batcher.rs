//! Batcher: packs the (operand, MC-sample) work stream into the fixed
//! batch shapes the AOT artifacts were compiled for.
//!
//! Work items are indexed globally: item `k` is MC draw `k % n_mc` of
//! operand `k / n_mc`, and its mismatch deviates come from a per-item
//! counter-derived stream ([`MismatchSampler::sample_item`]). A batcher
//! covers a half-open item range, so a sharded campaign is just one
//! batcher per shard — and because deviates are a pure function of the
//! item index, any shard partition reproduces the exact same rows.
//!
//! Invariants (property-tested in `tests/proptest_coordinator.rs`):
//! * every work item appears in exactly one batch row (no drops, no dups);
//! * padding rows are tagged invalid and never reach the aggregator;
//! * packing is deterministic given (spec, seed) and shard-invariant.

use crate::mac::VariantConfig;
use crate::montecarlo::MismatchSampler;
use crate::runtime::MacBatch;

/// Identity of one batch row: which operand pair and which MC draw it
/// carries, or padding.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RowTag {
    /// A real work item: MC draw `mc_idx` of operand pair `(a, b)`.
    Item {
        /// Index of the operand pair in the workload's operand list.
        op_idx: u32,
        /// Monte-Carlo draw index within the operand pair.
        mc_idx: u32,
        /// Stored 4-bit operand.
        a: u8,
        /// DAC-coded 4-bit operand.
        b: u8,
    },
    /// Padding row filling the fixed batch shape; never aggregated.
    Pad,
}

/// A fixed-size batch plus per-row identity tags.
#[derive(Debug, Clone)]
pub struct PackedBatch {
    /// Submission sequence number (the canonical fold order).
    pub seq: u64,
    /// The packed model inputs (fixed batch shape).
    pub inputs: MacBatch,
    /// Per-row identity, parallel to the input rows.
    pub tags: Vec<RowTag>,
}

impl PackedBatch {
    /// Number of non-padding rows.
    pub fn n_valid(&self) -> usize {
        self.tags.iter().filter(|t| !matches!(t, RowTag::Pad)).count()
    }
}

/// Scalar inputs shared by every batch of a campaign.
#[derive(Debug, Clone, Copy)]
pub struct BatchCfg {
    /// Forward body bias (V).
    pub v_bulk: f32,
    /// DAC transfer flag (0 = linear, 1 = sqrt) — the L2 model's input.
    pub dac_mode: f32,
    /// WL pulse width at the sampling instant (s).
    pub t_sample: f32,
}

impl From<&VariantConfig> for BatchCfg {
    fn from(c: &VariantConfig) -> Self {
        Self {
            v_bulk: c.v_bulk as f32,
            dac_mode: c.dac_mode.flag(),
            t_sample: c.t_sample as f32,
        }
    }
}

/// Streaming packer over an item range, in global item order (all MC draws
/// of operand 0, then operand 1, ...), drawing per-item mismatch deviates
/// so the stream is reproducible and partition-invariant.
pub struct Batcher {
    operands: Vec<(u8, u8)>,
    n_mc: u32,
    batch_size: usize,
    cfg: BatchCfg,
    sampler: MismatchSampler,
    // half-open global item range [start, end); cursor advances start..end
    start: u64,
    cursor: u64,
    end: u64,
    seq: u64,
}

impl Batcher {
    /// Batcher over the whole campaign (items `0..operands.len() * n_mc`).
    pub fn new(
        operands: Vec<(u8, u8)>,
        n_mc: u32,
        batch_size: usize,
        cfg: BatchCfg,
        sampler: MismatchSampler,
    ) -> Self {
        let end = operands.len() as u64 * u64::from(n_mc);
        Self::for_range(operands, n_mc, batch_size, cfg, sampler, 0, end)
    }

    /// Batcher over the shard item range `[start, end)`.
    pub fn for_range(
        operands: Vec<(u8, u8)>,
        n_mc: u32,
        batch_size: usize,
        cfg: BatchCfg,
        sampler: MismatchSampler,
        start: u64,
        end: u64,
    ) -> Self {
        assert!(batch_size > 0, "batch_size must be positive");
        assert!(!operands.is_empty(), "need at least one operand pair");
        let total = operands.len() as u64 * u64::from(n_mc);
        assert!(start <= end && end <= total, "bad item range [{start}, {end}) of {total}");
        Self { operands, n_mc, batch_size, cfg, sampler, start, cursor: start, end, seq: 0 }
    }

    /// Total number of batches this stream will yield — constant over the
    /// batcher's lifetime, regardless of how far iteration has advanced.
    pub fn n_batches(&self) -> u64 {
        (self.end - self.start).div_ceil(self.batch_size as u64)
    }
}

impl Iterator for Batcher {
    type Item = PackedBatch;

    fn next(&mut self) -> Option<PackedBatch> {
        if self.cursor >= self.end {
            return None; // range exhausted on a batch boundary
        }
        let mut inputs = MacBatch::nominal(
            self.batch_size,
            self.cfg.v_bulk,
            self.cfg.dac_mode,
            self.cfg.t_sample,
        );
        let mut tags = Vec::with_capacity(self.batch_size);
        for row in 0..self.batch_size {
            if self.cursor < self.end {
                let k = self.cursor;
                self.cursor += 1;
                let op_idx = (k / u64::from(self.n_mc)) as u32;
                let mc_idx = (k % u64::from(self.n_mc)) as u32;
                let (a, b) = self.operands[op_idx as usize];
                let mc = self.sampler.sample_item(k);
                inputs.set_row(row, a, b, mc.dvth.map(|x| x as f32), mc.dbeta.map(|x| x as f32));
                tags.push(RowTag::Item { op_idx, mc_idx, a, b });
            } else {
                tags.push(RowTag::Pad); // row stays nominal (0,0)
            }
        }
        let seq = self.seq;
        self.seq += 1;
        Some(PackedBatch { seq, inputs, tags })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mac::Variant;
    use crate::montecarlo::MismatchSampler;
    use crate::params::Params;

    fn mk(operands: Vec<(u8, u8)>, n_mc: u32, batch: usize) -> Batcher {
        let p = Params::default();
        let cfg = Variant::Smart.config(&p);
        Batcher::new(
            operands,
            n_mc,
            batch,
            BatchCfg::from(&cfg),
            MismatchSampler::new(1, 8e-3, 0.02),
        )
    }

    #[test]
    fn covers_every_item_exactly_once() {
        let b = mk(vec![(15, 15), (3, 7)], 10, 8);
        let mut seen = std::collections::HashSet::new();
        let mut pads = 0;
        for pb in b {
            for t in &pb.tags {
                match *t {
                    RowTag::Item { op_idx, mc_idx, .. } => {
                        assert!(seen.insert((op_idx, mc_idx)), "dup {op_idx}/{mc_idx}");
                    }
                    RowTag::Pad => pads += 1,
                }
            }
        }
        assert_eq!(seen.len(), 20);
        assert_eq!(pads, 4); // 20 items in batches of 8 -> 24 rows
    }

    #[test]
    fn n_batches_matches_iteration() {
        let b = mk(vec![(1, 1)], 1000, 256);
        assert_eq!(b.n_batches(), 4);
        assert_eq!(mk(vec![(1, 1)], 1000, 256).count(), 4);
        assert_eq!(mk(vec![(1, 1)], 1024, 256).n_batches(), 4);
    }

    #[test]
    fn deterministic_given_seed() {
        let a: Vec<_> = mk(vec![(15, 15)], 30, 16).collect();
        let b: Vec<_> = mk(vec![(15, 15)], 30, 16).collect();
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.tags, y.tags);
            assert_eq!(x.inputs.dvth, y.inputs.dvth);
        }
    }

    #[test]
    fn batch_rows_carry_operands() {
        let pb = mk(vec![(0b1010, 5)], 4, 4).next().unwrap();
        assert_eq!(&pb.inputs.a_bits[0..4], &[1.0, 0.0, 1.0, 0.0]);
        assert!(pb.inputs.b_code.iter().all(|&c| c == 5.0));
        assert_eq!(pb.n_valid(), 4);
    }

    #[test]
    fn exhausts_cleanly_on_boundary() {
        let mut b = mk(vec![(1, 2)], 8, 8);
        assert!(b.next().is_some());
        assert!(b.next().is_none());
        assert!(b.next().is_none());
    }

    #[test]
    fn shard_ranges_reproduce_the_full_stream() {
        // rows from [0, 13) + [13, 20) == rows from [0, 20), bit for bit
        let p = Params::default();
        let cfg = Variant::Aid.config(&p);
        let mk_range = |start: u64, end: u64| {
            Batcher::for_range(
                vec![(15, 15), (3, 7)],
                10,
                4,
                BatchCfg::from(&cfg),
                MismatchSampler::new(7, 8e-3, 0.02),
                start,
                end,
            )
        };
        let collect_rows = |b: Batcher| {
            let mut rows = Vec::new();
            for pb in b {
                for (i, t) in pb.tags.iter().enumerate() {
                    if let RowTag::Item { op_idx, mc_idx, .. } = *t {
                        let dvth: Vec<f32> = pb.inputs.dvth[i * 4..i * 4 + 4].to_vec();
                        rows.push((op_idx, mc_idx, dvth));
                    }
                }
            }
            rows
        };
        let whole = collect_rows(mk_range(0, 20));
        let mut split = collect_rows(mk_range(0, 13));
        split.extend(collect_rows(mk_range(13, 20)));
        assert_eq!(whole, split);
    }

    #[test]
    fn empty_range_yields_nothing() {
        let p = Params::default();
        let cfg = Variant::Smart.config(&p);
        let mut b = Batcher::for_range(
            vec![(1, 1)],
            8,
            4,
            BatchCfg::from(&cfg),
            MismatchSampler::new(0, 0.0, 0.0),
            5,
            5,
        );
        assert_eq!(b.n_batches(), 0);
        assert!(b.next().is_none());
    }
}
