//! Batcher: packs the (operand, MC-sample) work stream into the fixed
//! batch shapes the AOT artifacts were compiled for.
//!
//! Invariants (property-tested in `tests/proptest_coordinator.rs`):
//! * every work item appears in exactly one batch row (no drops, no dups);
//! * padding rows are tagged invalid and never reach the aggregator;
//! * packing is deterministic given (spec, seed).

use crate::mac::VariantConfig;
use crate::montecarlo::MismatchSampler;
use crate::runtime::MacBatch;

/// Identity of one batch row: which operand pair and which MC draw it
/// carries, or padding.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RowTag {
    Item { op_idx: u32, mc_idx: u32, a: u8, b: u8 },
    Pad,
}

/// A fixed-size batch plus per-row identity tags.
#[derive(Debug, Clone)]
pub struct PackedBatch {
    pub seq: u64,
    pub inputs: MacBatch,
    pub tags: Vec<RowTag>,
}

impl PackedBatch {
    pub fn n_valid(&self) -> usize {
        self.tags.iter().filter(|t| !matches!(t, RowTag::Pad)).count()
    }
}

/// Streaming packer: iterates operands x MC samples in row-major order
/// (all MC draws of operand 0, then operand 1, ...) drawing mismatch
/// deviates from a seeded sampler so the stream is reproducible.
pub struct Batcher {
    operands: Vec<(u8, u8)>,
    n_mc: u32,
    batch_size: usize,
    cfg: BatchCfg,
    sampler: MismatchSampler,
    // cursor
    op_idx: u32,
    mc_idx: u32,
    seq: u64,
}

/// Scalar inputs shared by every batch of a campaign.
#[derive(Debug, Clone, Copy)]
pub struct BatchCfg {
    pub v_bulk: f32,
    pub dac_mode: f32,
    pub t_sample: f32,
}

impl From<&VariantConfig> for BatchCfg {
    fn from(c: &VariantConfig) -> Self {
        Self {
            v_bulk: c.v_bulk as f32,
            dac_mode: c.dac_mode.flag(),
            t_sample: c.t_sample as f32,
        }
    }
}

impl Batcher {
    pub fn new(
        operands: Vec<(u8, u8)>,
        n_mc: u32,
        batch_size: usize,
        cfg: BatchCfg,
        sampler: MismatchSampler,
    ) -> Self {
        assert!(batch_size > 0, "batch_size must be positive");
        assert!(!operands.is_empty(), "need at least one operand pair");
        Self { operands, n_mc, batch_size, cfg, sampler, op_idx: 0, mc_idx: 0, seq: 0 }
    }

    /// Total number of batches this stream will yield.
    pub fn n_batches(&self) -> u64 {
        let items = self.operands.len() as u64 * u64::from(self.n_mc);
        items.div_ceil(self.batch_size as u64)
    }

    fn next_item(&mut self) -> Option<(u32, u32, u8, u8)> {
        if self.op_idx as usize >= self.operands.len() {
            return None;
        }
        let (a, b) = self.operands[self.op_idx as usize];
        let item = (self.op_idx, self.mc_idx, a, b);
        self.mc_idx += 1;
        if self.mc_idx >= self.n_mc {
            self.mc_idx = 0;
            self.op_idx += 1;
        }
        Some(item)
    }
}

impl Iterator for Batcher {
    type Item = PackedBatch;

    fn next(&mut self) -> Option<PackedBatch> {
        let mut inputs = MacBatch::nominal(
            self.batch_size,
            self.cfg.v_bulk,
            self.cfg.dac_mode,
            self.cfg.t_sample,
        );
        let mut tags = Vec::with_capacity(self.batch_size);
        for row in 0..self.batch_size {
            match self.next_item() {
                Some((op_idx, mc_idx, a, b)) => {
                    let mc = self.sampler.sample();
                    let dvth = mc.dvth.map(|x| x as f32);
                    let dbeta = mc.dbeta.map(|x| x as f32);
                    inputs.set_row(row, a, b, dvth, dbeta);
                    tags.push(RowTag::Item { op_idx, mc_idx, a, b });
                }
                None => {
                    if row == 0 {
                        return None; // stream exhausted on a batch boundary
                    }
                    tags.push(RowTag::Pad); // row stays nominal (0,0)
                }
            }
        }
        let seq = self.seq;
        self.seq += 1;
        Some(PackedBatch { seq, inputs, tags })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mac::Variant;
    use crate::montecarlo::MismatchSampler;
    use crate::params::Params;

    fn mk(operands: Vec<(u8, u8)>, n_mc: u32, batch: usize) -> Batcher {
        let p = Params::default();
        let cfg = Variant::Smart.config(&p);
        Batcher::new(
            operands,
            n_mc,
            batch,
            BatchCfg::from(&cfg),
            MismatchSampler::new(1, 8e-3, 0.02),
        )
    }

    #[test]
    fn covers_every_item_exactly_once() {
        let b = mk(vec![(15, 15), (3, 7)], 10, 8);
        let mut seen = std::collections::HashSet::new();
        let mut pads = 0;
        for pb in b {
            for t in &pb.tags {
                match *t {
                    RowTag::Item { op_idx, mc_idx, .. } => {
                        assert!(seen.insert((op_idx, mc_idx)), "dup {op_idx}/{mc_idx}");
                    }
                    RowTag::Pad => pads += 1,
                }
            }
        }
        assert_eq!(seen.len(), 20);
        assert_eq!(pads, 4); // 20 items in batches of 8 -> 24 rows
    }

    #[test]
    fn n_batches_matches_iteration() {
        let b = mk(vec![(1, 1)], 1000, 256);
        assert_eq!(b.n_batches(), 4);
        assert_eq!(mk(vec![(1, 1)], 1000, 256).count(), 4);
        assert_eq!(mk(vec![(1, 1)], 1024, 256).n_batches(), 4);
    }

    #[test]
    fn deterministic_given_seed() {
        let a: Vec<_> = mk(vec![(15, 15)], 30, 16).collect();
        let b: Vec<_> = mk(vec![(15, 15)], 30, 16).collect();
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.tags, y.tags);
            assert_eq!(x.inputs.dvth, y.inputs.dvth);
        }
    }

    #[test]
    fn batch_rows_carry_operands() {
        let pb = mk(vec![(0b1010, 5)], 4, 4).next().unwrap();
        assert_eq!(&pb.inputs.a_bits[0..4], &[1.0, 0.0, 1.0, 0.0]);
        assert!(pb.inputs.b_code.iter().all(|&c| c == 5.0));
        assert_eq!(pb.n_valid(), 4);
    }

    #[test]
    fn exhausts_cleanly_on_boundary() {
        let mut b = mk(vec![(1, 2)], 8, 8);
        assert!(b.next().is_some());
        assert!(b.next().is_none());
        assert!(b.next().is_none());
    }
}
