//! Campaign specification: what to run, reproducibly.

use crate::mac::{KernelKind, Variant};
use crate::montecarlo::Corner;
use crate::util::json::Value;

/// Operand workload of a campaign.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum Workload {
    /// A single operand pair — e.g. the paper's 1111 x 1111 (Fig. 8/9).
    Fixed { a: u8, b: u8 },
    /// The full 16x16 operand space (the accuracy/RMS metric of Table 1).
    FullSweep,
    /// Random operand pairs (workload-shaped accuracy, NN-style traffic).
    Random { n_ops: u32 },
    /// The full operand space restricted to `bits`-wide operands
    /// (`(0..2^bits)^2`) — the reduced-precision workload the DSE sweeps
    /// use for the bit-width axis. `BitSweep { bits: 4 }` is `FullSweep`.
    BitSweep { bits: u32 },
}

impl Workload {
    /// Expand into the operand list the campaign iterates.
    pub fn operands(&self, seed: u64) -> Vec<(u8, u8)> {
        match self {
            Self::Fixed { a, b } => vec![(*a, *b)],
            Self::FullSweep => {
                let mut v = Vec::with_capacity(256);
                for a in 0..16u8 {
                    for b in 0..16u8 {
                        v.push((a, b));
                    }
                }
                v
            }
            Self::Random { n_ops } => {
                let mut rng = crate::montecarlo::SplitMix64::new(seed ^ 0xA5A5_5A5A);
                (0..*n_ops)
                    .map(|_| ((rng.next_u64() % 16) as u8, (rng.next_u64() % 16) as u8))
                    .collect()
            }
            Self::BitSweep { bits } => {
                let hi = 1u16 << bits.min(4);
                let mut v = Vec::with_capacity((hi * hi) as usize);
                for a in 0..hi {
                    for b in 0..hi {
                        v.push((a as u8, b as u8));
                    }
                }
                v
            }
        }
    }

    /// Number of operand pairs [`Self::operands`] expands to, **without
    /// materializing the list**. The `smart serve` work-ceiling check
    /// must reject oversized workloads before allocating them — e.g. a
    /// 60-byte `random` request with `n_ops = u32::MAX` would otherwise
    /// collect ~4.3e9 pairs just to be counted and rejected.
    pub fn n_operands(&self) -> u64 {
        match self {
            Self::Fixed { .. } => 1,
            Self::FullSweep => 256,
            Self::Random { n_ops } => u64::from(*n_ops),
            Self::BitSweep { bits } => {
                let hi = 1u64 << (*bits).min(4);
                hi * hi
            }
        }
    }

    /// Encode as a config value tree — exactly the shape
    /// [`Self::from_value`] parses, so workloads round-trip. Used by the
    /// canonical `mc.json` artifact encoder ([`crate::report::mc_json`])
    /// and the `smart serve` request canonicalization.
    pub fn to_value(&self) -> Value {
        let mut m = std::collections::BTreeMap::new();
        match self {
            Self::Fixed { a, b } => {
                m.insert("kind".to_string(), Value::Str("fixed".to_string()));
                m.insert("a".to_string(), Value::Num(f64::from(*a)));
                m.insert("b".to_string(), Value::Num(f64::from(*b)));
            }
            Self::FullSweep => {
                m.insert("kind".to_string(), Value::Str("full_sweep".to_string()));
            }
            Self::Random { n_ops } => {
                m.insert("kind".to_string(), Value::Str("random".to_string()));
                m.insert("n_ops".to_string(), Value::Num(f64::from(*n_ops)));
            }
            Self::BitSweep { bits } => {
                m.insert("kind".to_string(), Value::Str("bit_sweep".to_string()));
                m.insert("bits".to_string(), Value::Num(f64::from(*bits)));
            }
        }
        Value::Obj(m)
    }

    /// Parse from a config tree: `{kind = "fixed", a = 15, b = 15}` etc.
    pub fn from_value(v: &Value) -> anyhow::Result<Self> {
        let kind = v
            .get("kind")
            .and_then(Value::as_str)
            .ok_or_else(|| anyhow::anyhow!("workload.kind missing"))?;
        // Range-checked narrowing, not `as` casts: this parser also sits
        // behind `smart serve`'s untrusted POST bodies, where a silently
        // wrapped integer (a = 256 -> 0) would return a 200 computed for
        // a different campaign than the client asked for.
        match kind {
            "fixed" => {
                let g = |k: &str| {
                    v.get(k)
                        .and_then(Value::as_u64)
                        .ok_or_else(|| anyhow::anyhow!("workload.{k} missing"))
                };
                let (a, b) = (g("a")?, g("b")?);
                anyhow::ensure!(
                    a <= 15 && b <= 15,
                    "fixed workload operands must be 4-bit (got a = {a}, b = {b})"
                );
                Ok(Self::Fixed {
                    a: u8::try_from(a).map_err(|_| anyhow::anyhow!("workload.a = {a} exceeds u8"))?,
                    b: u8::try_from(b).map_err(|_| anyhow::anyhow!("workload.b = {b} exceeds u8"))?,
                })
            }
            "full_sweep" => Ok(Self::FullSweep),
            "random" => {
                let n = v
                    .get("n_ops")
                    .and_then(Value::as_u64)
                    .ok_or_else(|| anyhow::anyhow!("workload.n_ops missing"))?;
                Ok(Self::Random {
                    n_ops: u32::try_from(n)
                        .map_err(|_| anyhow::anyhow!("workload.n_ops = {n} exceeds u32"))?,
                })
            }
            "bit_sweep" => {
                let bits = v
                    .get("bits")
                    .and_then(Value::as_u64)
                    .ok_or_else(|| anyhow::anyhow!("workload.bits missing"))?;
                anyhow::ensure!(
                    (1..=4).contains(&bits),
                    "workload.bits must be 1..=4, got {bits}"
                );
                Ok(Self::BitSweep {
                    bits: u32::try_from(bits)
                        .map_err(|_| anyhow::anyhow!("workload.bits = {bits} exceeds u32"))?,
                })
            }
            other => anyhow::bail!("unknown workload kind '{other}'"),
        }
    }
}

/// Everything needed to reproduce a campaign bit-for-bit.
///
/// ```
/// use smart_insram::coordinator::CampaignSpec;
/// use smart_insram::mac::Variant;
///
/// let spec = CampaignSpec::paper_fig8(Variant::Smart);
/// assert!(spec.validate().is_ok());
/// // specs round-trip through the TOML-lite config format
/// assert!(spec.to_toml().contains("variant = \"smart\""));
/// ```
#[derive(Debug, Clone, PartialEq)]
pub struct CampaignSpec {
    /// Design variant under test.
    pub variant: Variant,
    /// Operand workload the campaign iterates.
    pub workload: Workload,
    /// Monte-Carlo samples per operand pair (paper: 1000).
    pub n_mc: u32,
    /// RNG seed — campaigns are bit-reproducible from (spec, seed).
    pub seed: u64,
    /// Process corner the mismatch sampler is biased to.
    pub corner: Corner,
    /// Worker threads (native: shard executors; XLA: PJRT clients). 0 = auto.
    pub workers: usize,
    /// Preferred batch size; 0 = pick the largest compiled batch that fits.
    pub batch: usize,
    /// Shards the item space splits into (native backend). 0 = auto. Any
    /// value produces bit-identical aggregates; this only tunes scheduling
    /// granularity.
    pub shards: usize,
    /// Trial-block size of the native block-execution path (lanes per
    /// [`crate::mac::TrialBlock`], DESIGN.md §9). 0 = auto (the `batch`
    /// knob if set, else 256). Any value produces bit-identical
    /// aggregates; this only tunes SIMD width vs memory footprint.
    pub block: usize,
    /// Simulation kernel tier (DESIGN.md §13). Unlike
    /// `workers`/`batch`/`shards`/`block` this is an **identity** field:
    /// the fast tier is tolerance-bounded rather than bit-identical, so
    /// the choice is recorded in artifacts and forks serve cache keys.
    pub kernel: KernelKind,
}

impl CampaignSpec {
    /// The paper's headline experiment: 1000-point MC on 1111 x 1111.
    pub fn paper_fig8(variant: Variant) -> Self {
        Self {
            variant,
            workload: Workload::Fixed { a: 15, b: 15 },
            n_mc: 1000,
            seed: 2022,
            corner: Corner::Tt,
            workers: 0,
            batch: 0,
            shards: 0,
            block: 0,
            kernel: KernelKind::Block,
        }
    }

    /// Parse one `[[campaigns]]` table from a config tree.
    pub fn from_value(v: &Value) -> anyhow::Result<Self> {
        let variant: Variant = v
            .get("variant")
            .and_then(Value::as_str)
            .ok_or_else(|| anyhow::anyhow!("campaign.variant missing"))?
            .parse()
            .map_err(|e: String| anyhow::anyhow!(e))?;
        let workload = Workload::from_value(
            v.get("workload")
                .ok_or_else(|| anyhow::anyhow!("campaign.workload missing"))?,
        )?;
        let u = |k: &str, default: u64| v.get(k).and_then(Value::as_u64).unwrap_or(default);
        let corner = match v.get("corner").and_then(Value::as_str) {
            None => Corner::Tt,
            Some(s) => s.parse().map_err(|e: String| anyhow::anyhow!(e))?,
        };
        let kernel = match v.get("kernel").and_then(Value::as_str) {
            None => KernelKind::Block,
            Some(s) => s.parse().map_err(|e: String| anyhow::anyhow!(e))?,
        };
        // every narrowing is range-checked (no silent wrap for untrusted
        // HTTP bodies) — lint rule D3 holds this parser to try_from
        let n_mc = u("n_mc", 1000);
        let n_mc = u32::try_from(n_mc)
            .map_err(|_| anyhow::anyhow!("campaign.n_mc = {n_mc} exceeds u32"))?;
        let uz = |k: &str, default: u64| {
            let n = u(k, default);
            usize::try_from(n).map_err(|_| anyhow::anyhow!("campaign.{k} = {n} exceeds usize"))
        };
        let spec = Self {
            variant,
            workload,
            n_mc,
            seed: u("seed", 2022),
            corner,
            workers: uz("workers", 0)?,
            batch: uz("batch", 0)?,
            shards: uz("shards", 0)?,
            block: uz("block", 0)?,
            kernel,
        };
        spec.validate().map_err(|e| anyhow::anyhow!(e))?;
        Ok(spec)
    }

    /// Serialize as a TOML-lite `[[campaigns]]` block (round-trips through
    /// [`Self::from_value`]).
    pub fn to_toml(&self) -> String {
        let mut s = String::new();
        s.push_str("[[campaigns]]\n");
        s.push_str(&format!("variant = \"{}\"\n", self.variant.token()));
        s.push_str(&format!("n_mc = {}\n", self.n_mc));
        s.push_str(&format!("seed = {}\n", self.seed));
        s.push_str(&format!("corner = \"{}\"\n", self.corner.name()));
        s.push_str(&format!("workers = {}\n", self.workers));
        s.push_str(&format!("batch = {}\n", self.batch));
        s.push_str(&format!("shards = {}\n", self.shards));
        s.push_str(&format!("block = {}\n", self.block));
        s.push_str(&format!("kernel = \"{}\"\n", self.kernel.token()));
        s.push_str("[campaigns.workload]\n");
        match &self.workload {
            Workload::Fixed { a, b } => {
                s.push_str("kind = \"fixed\"\n");
                s.push_str(&format!("a = {a}\nb = {b}\n"));
            }
            Workload::FullSweep => s.push_str("kind = \"full_sweep\"\n"),
            Workload::Random { n_ops } => {
                s.push_str("kind = \"random\"\n");
                s.push_str(&format!("n_ops = {n_ops}\n"));
            }
            Workload::BitSweep { bits } => {
                s.push_str("kind = \"bit_sweep\"\n");
                s.push_str(&format!("bits = {bits}\n"));
            }
        }
        s
    }

    /// Total work items = operands x MC samples.
    pub fn total_items(&self, n_operands: usize) -> u64 {
        n_operands as u64 * u64::from(self.n_mc)
    }

    /// Check the spec is runnable and exactly reproducible.
    pub fn validate(&self) -> Result<(), String> {
        if self.n_mc == 0 {
            return Err("n_mc must be >= 1".into());
        }
        // Config values travel through an f64 number tree; keep seeds
        // exactly representable so campaigns stay bit-reproducible.
        if self.seed >= (1u64 << 53) {
            return Err("seed must be < 2^53 (config numbers are f64)".into());
        }
        if let Workload::Fixed { a, b } = self.workload {
            if a > 15 || b > 15 {
                return Err(format!("operands must be 4-bit: ({a}, {b})"));
            }
        }
        if let Workload::Random { n_ops } = self.workload {
            if n_ops == 0 {
                return Err("random workload needs n_ops >= 1".into());
            }
        }
        if let Workload::BitSweep { bits } = self.workload {
            if !(1..=4).contains(&bits) {
                return Err(format!("bit_sweep bits must be 1..=4, got {bits}"));
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::toml_lite;

    #[test]
    fn fixed_workload_single_operand() {
        let ops = Workload::Fixed { a: 15, b: 15 }.operands(0);
        assert_eq!(ops, vec![(15, 15)]);
    }

    #[test]
    fn full_sweep_covers_space() {
        let ops = Workload::FullSweep.operands(0);
        assert_eq!(ops.len(), 256);
        let mut seen = [[false; 16]; 16];
        for (a, b) in ops {
            seen[a as usize][b as usize] = true;
        }
        assert!(seen.iter().flatten().all(|&s| s));
    }

    #[test]
    fn bit_sweep_covers_reduced_space() {
        let ops = Workload::BitSweep { bits: 2 }.operands(0);
        assert_eq!(ops.len(), 16);
        assert!(ops.iter().all(|&(a, b)| a < 4 && b < 4));
        // bits = 4 is exactly the full sweep
        assert_eq!(
            Workload::BitSweep { bits: 4 }.operands(0),
            Workload::FullSweep.operands(0)
        );
        // round-trips through the config format and validates its range
        let mut spec = CampaignSpec::paper_fig8(Variant::Smart);
        spec.workload = Workload::BitSweep { bits: 3 };
        let doc = toml_lite::parse(&spec.to_toml()).unwrap();
        let arr = doc.get("campaigns").unwrap().as_arr().unwrap();
        assert_eq!(CampaignSpec::from_value(&arr[0]).unwrap(), spec);
        spec.workload = Workload::BitSweep { bits: 5 };
        assert!(spec.validate().is_err());
        spec.workload = Workload::BitSweep { bits: 0 };
        assert!(spec.validate().is_err());
    }

    #[test]
    fn n_operands_matches_the_materialized_list() {
        for w in [
            Workload::Fixed { a: 3, b: 12 },
            Workload::FullSweep,
            Workload::Random { n_ops: 9 },
            Workload::BitSweep { bits: 2 },
            Workload::BitSweep { bits: 4 },
        ] {
            assert_eq!(w.n_operands(), w.operands(7).len() as u64, "{w:?}");
        }
        // the point of the method: huge counts are computed, not allocated
        assert_eq!(Workload::Random { n_ops: u32::MAX }.n_operands(), u64::from(u32::MAX));
    }

    #[test]
    fn workload_value_roundtrip() {
        for w in [
            Workload::Fixed { a: 3, b: 12 },
            Workload::FullSweep,
            Workload::Random { n_ops: 9 },
            Workload::BitSweep { bits: 2 },
        ] {
            let back = Workload::from_value(&w.to_value()).unwrap();
            assert_eq!(back, w);
        }
    }

    #[test]
    fn random_workload_is_seeded() {
        let a = Workload::Random { n_ops: 50 }.operands(7);
        let b = Workload::Random { n_ops: 50 }.operands(7);
        let c = Workload::Random { n_ops: 50 }.operands(8);
        assert_eq!(a, b);
        assert_ne!(a, c);
        assert!(a.iter().all(|&(x, y)| x < 16 && y < 16));
    }

    #[test]
    fn validation_catches_bad_specs() {
        let mut s = CampaignSpec::paper_fig8(Variant::Smart);
        assert!(s.validate().is_ok());
        s.n_mc = 0;
        assert!(s.validate().is_err());
        s.n_mc = 10;
        s.workload = Workload::Fixed { a: 16, b: 0 };
        assert!(s.validate().is_err());
    }

    #[test]
    fn toml_roundtrip() {
        for variant in Variant::ALL {
            let mut spec = CampaignSpec::paper_fig8(variant);
            spec.workers = 3;
            spec.shards = 8;
            spec.block = 192;
            spec.kernel = KernelKind::Fast;
            let doc = toml_lite::parse(&spec.to_toml()).unwrap();
            let arr = doc.get("campaigns").unwrap().as_arr().unwrap();
            let back = CampaignSpec::from_value(&arr[0]).unwrap();
            assert_eq!(spec, back);
        }
    }

    #[test]
    fn from_value_applies_defaults() {
        let doc = toml_lite::parse(
            "[[campaigns]]\nvariant = \"aid\"\n[campaigns.workload]\nkind = \"full_sweep\"\n",
        )
        .unwrap();
        let spec =
            CampaignSpec::from_value(&doc.get("campaigns").unwrap().as_arr().unwrap()[0]).unwrap();
        assert_eq!(spec.n_mc, 1000);
        assert_eq!(spec.seed, 2022);
        assert_eq!(spec.corner, Corner::Tt);
        assert_eq!(spec.workload, Workload::FullSweep);
        assert_eq!(spec.shards, 0);
        assert_eq!(spec.block, 0);
        assert_eq!(spec.kernel, KernelKind::Block);
    }

    #[test]
    fn from_value_rejects_out_of_range_integers() {
        // regression: `as u8`/`as u32` casts silently wrapped (a = 256 ->
        // 0, n_mc = 2^32 + 8 -> 8), so the serve surface could answer 200
        // with results for a different campaign than the client requested
        for toml in [
            "[[campaigns]]\nvariant = \"smart\"\n[campaigns.workload]\nkind = \"fixed\"\na = 256\nb = 15\n",
            "[[campaigns]]\nvariant = \"smart\"\nn_mc = 4294967304\n[campaigns.workload]\nkind = \"full_sweep\"\n",
            "[[campaigns]]\nvariant = \"smart\"\n[campaigns.workload]\nkind = \"random\"\nn_ops = 4294967296\n",
            "[[campaigns]]\nvariant = \"smart\"\n[campaigns.workload]\nkind = \"bit_sweep\"\nbits = 4294967298\n",
        ] {
            let doc = toml_lite::parse(toml).unwrap();
            let c = &doc.get("campaigns").unwrap().as_arr().unwrap()[0];
            assert!(CampaignSpec::from_value(c).is_err(), "accepted: {toml}");
        }
    }

    #[test]
    fn from_value_rejects_bad_variant() {
        let doc = toml_lite::parse(
            "[[campaigns]]\nvariant = \"bogus\"\n[campaigns.workload]\nkind = \"full_sweep\"\n",
        )
        .unwrap();
        let c = &doc.get("campaigns").unwrap().as_arr().unwrap()[0];
        assert!(CampaignSpec::from_value(c).is_err());
    }

    #[test]
    fn from_value_rejects_bad_kernel() {
        let doc = toml_lite::parse(
            "[[campaigns]]\nvariant = \"smart\"\nkernel = \"warp\"\n[campaigns.workload]\nkind = \"full_sweep\"\n",
        )
        .unwrap();
        let c = &doc.get("campaigns").unwrap().as_arr().unwrap()[0];
        let err = CampaignSpec::from_value(c).unwrap_err().to_string();
        assert!(err.contains("unknown kernel 'warp'"), "{err}");
    }
}
