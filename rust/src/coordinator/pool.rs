//! Worker pool: OS threads each owning a private PJRT runtime, fed from a
//! bounded job queue (backpressure), results funneled to the aggregator.
//!
//! PJRT handles are `!Send`, so the executable can never cross a thread
//! boundary — each worker compiles its own from the artifact text. The
//! job queue is a `sync_channel` whose bound keeps at most
//! `2 * workers` batches in flight: the batcher (producer) blocks when
//! the pool falls behind, bounding memory for arbitrarily long campaigns.

use std::path::PathBuf;
use std::sync::mpsc::{sync_channel, Receiver, SyncSender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;

use anyhow::Result;

use super::batcher::PackedBatch;
use crate::runtime::{MacBatchOut, XlaRuntime};

/// A pool of PJRT worker threads executing fixed-size MAC batches.
pub struct WorkerPool {
    job_tx: Option<SyncSender<PackedBatch>>,
    result_rx: Receiver<Result<(PackedBatch, MacBatchOut)>>,
    handles: Vec<JoinHandle<()>>,
}

impl WorkerPool {
    /// Spawn `workers` threads, each compiling the `mac_b{batch}`
    /// artifact from `artifact_dir`. Fails fast if a worker cannot
    /// initialize (bad artifact dir, missing batch size).
    pub fn spawn(artifact_dir: PathBuf, batch: usize, workers: usize) -> Result<Self> {
        assert!(workers > 0);
        let (job_tx, job_rx) = sync_channel::<PackedBatch>(workers * 2);
        let job_rx = Arc::new(Mutex::new(job_rx));
        let (result_tx, result_rx) = sync_channel::<Result<(PackedBatch, MacBatchOut)>>(workers * 2);
        let (ready_tx, ready_rx) = sync_channel::<Result<()>>(workers);

        let mut handles = Vec::with_capacity(workers);
        for wid in 0..workers {
            let dir = artifact_dir.clone();
            let job_rx = Arc::clone(&job_rx);
            let result_tx = result_tx.clone();
            let ready_tx = ready_tx.clone();
            handles.push(std::thread::Builder::new()
                .name(format!("smart-worker-{wid}"))
                .spawn(move || {
                    // Initialize a private runtime; report readiness.
                    let exe = (|| {
                        let mut rt = XlaRuntime::open(&dir)?;
                        rt.mac_executable(batch)
                    })();
                    match exe {
                        Ok(exe) => {
                            let _ = ready_tx.send(Ok(()));
                            loop {
                                // hold the lock only while dequeuing
                                let job = { job_rx.lock().unwrap().recv() };
                                let Ok(job) = job else { break };
                                let out = exe.run(&job.inputs).map(|o| (job, o));
                                if result_tx.send(out).is_err() {
                                    break;
                                }
                            }
                        }
                        Err(e) => {
                            let _ = ready_tx.send(Err(e));
                        }
                    }
                })
                .expect("spawn worker"));
        }
        drop(ready_tx);
        for _ in 0..workers {
            ready_rx.recv().expect("worker readiness")?;
        }
        Ok(Self { job_tx: Some(job_tx), result_rx, handles })
    }

    /// Submit a batch (blocks when the queue is full — backpressure).
    pub fn submit(&self, batch: PackedBatch) -> Result<()> {
        self.job_tx
            .as_ref()
            .expect("pool already closed")
            .send(batch)
            .map_err(|_| anyhow::anyhow!("all workers exited"))
    }

    /// Signal no more jobs; workers drain and exit.
    pub fn close(&mut self) {
        self.job_tx.take();
    }

    /// Receive the next completed batch; `None` after close + drain.
    pub fn recv(&self) -> Option<Result<(PackedBatch, MacBatchOut)>> {
        self.result_rx.recv().ok()
    }

    /// Non-blocking receive for interleaved submit/drain loops.
    pub fn try_recv(&self) -> Option<Result<(PackedBatch, MacBatchOut)>> {
        self.result_rx.try_recv().ok()
    }
}

impl Drop for WorkerPool {
    fn drop(&mut self) {
        self.close();
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}
