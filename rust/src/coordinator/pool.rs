//! Worker pools: the dynamic shard executor behind native campaigns
//! ([`execute_sharded`]) and the PJRT thread pool behind the AOT path
//! ([`WorkerPool`]).
//!
//! PJRT handles are `!Send`, so the executable can never cross a thread
//! boundary — each worker compiles its own from the artifact text. The
//! job queue is a `sync_channel` whose bound keeps at most
//! `2 * workers` batches in flight: the batcher (producer) blocks when
//! the pool falls behind, bounding memory for arbitrarily long campaigns.

use std::collections::BTreeMap;
use std::path::PathBuf;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc::{channel, sync_channel, Receiver, SyncSender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;

use anyhow::Result;

use super::batcher::PackedBatch;
use crate::obs::{SpanId, Tracer};
use crate::runtime::{MacBatchOut, XlaRuntime};

/// Dynamic (work-stealing style) shard executor: worker threads claim
/// shard indices from a shared counter, so fast threads absorb slow
/// shards; results are re-sequenced and handed to `sink` strictly in
/// shard order. With shard-invariant inputs (per-item RNG streams) this
/// makes the downstream fold bit-identical for ANY `threads` value — the
/// schedule affects wall-clock only, never the aggregate.
pub fn execute_sharded<R, F, S>(n_shards: usize, threads: usize, run_shard: F, sink: S)
where
    R: Send,
    F: Fn(usize) -> R + Sync,
    S: FnMut(usize, R),
{
    execute_sharded_traced(n_shards, threads, &Tracer::disabled(), None, run_shard, sink);
}

/// [`execute_sharded`] with per-worker tracing: each worker thread emits
/// one `worker` span under `parent` recording how many shards it claimed
/// (the steal-count view of load balance — a worker that claimed many
/// shards absorbed the slack of its siblings). Spans observe the
/// schedule; the ordered merge below ignores them entirely, so traced
/// and untraced runs hand `sink` byte-identical sequences
/// (pinned by `tests/obs.rs`).
pub fn execute_sharded_traced<R, F, S>(
    n_shards: usize,
    threads: usize,
    tracer: &Tracer,
    parent: Option<SpanId>,
    run_shard: F,
    mut sink: S,
) where
    R: Send,
    F: Fn(usize) -> R + Sync,
    S: FnMut(usize, R),
{
    assert!(threads >= 1, "need at least one worker thread");
    if n_shards == 0 {
        return;
    }
    let next_shard = AtomicUsize::new(0);
    let (tx, rx) = channel::<(usize, R)>();
    let mut next_emit = 0usize;
    std::thread::scope(|scope| {
        for worker in 0..threads.min(n_shards) {
            let tx = tx.clone();
            let next_shard = &next_shard;
            let run_shard = &run_shard;
            scope.spawn(move || {
                let mut span = tracer.span_started("worker", parent, crate::obs::Stopwatch::start());
                span.attr_u64("worker", worker as u64);
                let mut claimed = 0u64;
                loop {
                    // lint:allow(L2): ticket dispenser — the pre-increment value is the claimed shard index, bounded by n_shards
                    let shard = next_shard.fetch_add(1, Ordering::Relaxed);
                    if shard >= n_shards || tx.send((shard, run_shard(shard))).is_err() {
                        break;
                    }
                    claimed += 1;
                }
                span.attr_u64("shards_claimed", claimed);
                tracer.finish(span);
            });
        }
        drop(tx);
        // ordered merge: buffer out-of-order shards, emit contiguously
        let mut pending: BTreeMap<usize, R> = BTreeMap::new();
        for (shard, out) in rx {
            pending.insert(shard, out);
            while let Some(ready) = pending.remove(&next_emit) {
                sink(next_emit, ready);
                next_emit += 1;
            }
        }
        // no assert here: if a worker panicked, scope's join must
        // propagate the ORIGINAL panic, not a shadowing assertion
    });
    assert_eq!(next_emit, n_shards, "shard worker exited early");
}

/// Contiguous item range of shard `shard` when `total` items are split
/// across `n_shards` shards as evenly as possible (first `total % n_shards`
/// shards get one extra item).
pub fn shard_range(total: u64, n_shards: usize, shard: usize) -> (u64, u64) {
    assert!(n_shards > 0 && shard < n_shards);
    let n = n_shards as u64;
    let s = shard as u64;
    let base = total / n;
    let rem = total % n;
    let start = s * base + s.min(rem);
    let len = base + u64::from(s < rem);
    (start, start + len)
}

/// A pool of PJRT worker threads executing fixed-size MAC batches.
pub struct WorkerPool {
    job_tx: Option<SyncSender<PackedBatch>>,
    result_rx: Receiver<Result<(PackedBatch, MacBatchOut)>>,
    handles: Vec<JoinHandle<()>>,
}

impl WorkerPool {
    /// Spawn `workers` threads, each compiling the `mac_b{batch}`
    /// artifact from `artifact_dir`. Fails fast if a worker cannot
    /// initialize (bad artifact dir, missing batch size). `workers` and
    /// `batch` must be >= 1 — a zero-worker pool would accept jobs into a
    /// rendezvous channel nobody drains (a silent deadlock, not a crash),
    /// so this is a descriptive error rather than a deep `assert!`.
    pub fn spawn(artifact_dir: PathBuf, batch: usize, workers: usize) -> Result<Self> {
        anyhow::ensure!(
            workers > 0,
            "worker pool needs at least 1 thread (got workers = 0; \
             pass 0 at the spec/CLI level for auto-selection instead)"
        );
        anyhow::ensure!(batch > 0, "worker pool needs a batch size >= 1 (got 0)");
        let (job_tx, job_rx) = sync_channel::<PackedBatch>(workers * 2);
        let job_rx = Arc::new(Mutex::new(job_rx));
        let (result_tx, result_rx) =
            sync_channel::<Result<(PackedBatch, MacBatchOut)>>(workers * 2);
        let (ready_tx, ready_rx) = sync_channel::<Result<()>>(workers);

        let mut handles = Vec::with_capacity(workers);
        for wid in 0..workers {
            let dir = artifact_dir.clone();
            let job_rx = Arc::clone(&job_rx);
            let result_tx = result_tx.clone();
            let ready_tx = ready_tx.clone();
            let handle = std::thread::Builder::new()
                .name(format!("smart-worker-{wid}"))
                .spawn(move || {
                    // Initialize a private runtime; report readiness.
                    let exe = (|| {
                        let mut rt = XlaRuntime::open(&dir)?;
                        rt.mac_executable(batch)
                    })();
                    match exe {
                        Ok(exe) => {
                            let _ = ready_tx.send(Ok(()));
                            loop {
                                // hold the lock only while dequeuing; a
                                // poisoned lock means a sibling worker
                                // panicked mid-dequeue — exit gracefully
                                // (the pool reports "all workers exited")
                                // instead of cascading the panic
                                let job = match job_rx.lock() {
                                    Ok(rx) => rx.recv(),
                                    Err(_) => break,
                                };
                                let Ok(job) = job else { break };
                                let out = exe.run(&job.inputs).map(|o| (job, o));
                                if result_tx.send(out).is_err() {
                                    break;
                                }
                            }
                        }
                        Err(e) => {
                            let _ = ready_tx.send(Err(e));
                        }
                    }
                })
                .map_err(|e| anyhow::anyhow!("spawning worker thread {wid}: {e}"))?;
            handles.push(handle);
        }
        drop(ready_tx);
        for _ in 0..workers {
            match ready_rx.recv() {
                Ok(status) => status?,
                Err(_) => anyhow::bail!("a worker exited before reporting readiness"),
            }
        }
        Ok(Self { job_tx: Some(job_tx), result_rx, handles })
    }

    /// Submit a batch (blocks when the queue is full — backpressure).
    /// Errors when the pool is closed or every worker has exited.
    pub fn submit(&self, batch: PackedBatch) -> Result<()> {
        let tx = self
            .job_tx
            .as_ref()
            .ok_or_else(|| anyhow::anyhow!("pool already closed"))?;
        tx.send(batch).map_err(|_| anyhow::anyhow!("all workers exited"))
    }

    /// Signal no more jobs; workers drain and exit.
    pub fn close(&mut self) {
        self.job_tx.take();
    }

    /// Receive the next completed batch; `None` after close + drain.
    pub fn recv(&self) -> Option<Result<(PackedBatch, MacBatchOut)>> {
        self.result_rx.recv().ok()
    }

    /// Non-blocking receive for interleaved submit/drain loops.
    pub fn try_recv(&self) -> Option<Result<(PackedBatch, MacBatchOut)>> {
        self.result_rx.try_recv().ok()
    }
}

impl Drop for WorkerPool {
    fn drop(&mut self) {
        self.close();
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shard_ranges_partition_exactly() {
        for (total, shards) in [(0u64, 1usize), (1, 8), (20, 3), (1000, 7), (256, 256)] {
            let mut cursor = 0u64;
            for s in 0..shards {
                let (start, end) = shard_range(total, shards, s);
                assert_eq!(start, cursor, "total={total} shards={shards} s={s}");
                assert!(end >= start);
                cursor = end;
            }
            assert_eq!(cursor, total);
            // even split: sizes differ by at most one item
            let sizes: Vec<u64> = (0..shards)
                .map(|s| {
                    let (a, b) = shard_range(total, shards, s);
                    b - a
                })
                .collect();
            let (min, max) = (sizes.iter().min().unwrap(), sizes.iter().max().unwrap());
            assert!(max - min <= 1);
        }
    }

    #[test]
    fn execute_sharded_emits_in_order_any_thread_count() {
        for threads in [1usize, 2, 5, 16] {
            let mut seen = Vec::new();
            execute_sharded(11, threads, |s| s * s, |shard, out| seen.push((shard, out)));
            let want: Vec<(usize, usize)> = (0..11).map(|s| (s, s * s)).collect();
            assert_eq!(seen, want, "threads={threads}");
        }
    }

    #[test]
    fn execute_sharded_zero_shards_is_noop() {
        execute_sharded(0, 4, |s| s, |_, _| panic!("no shards to emit"));
    }

    #[test]
    fn traced_execution_emits_worker_spans_and_keeps_order() {
        let path = std::env::temp_dir()
            .join(format!("smart-pool-trace-{}.jsonl", std::process::id()));
        let tracer = Tracer::to_file(&path, "test").unwrap();
        let mut seen = Vec::new();
        execute_sharded_traced(9, 3, &tracer, None, |s| s + 1, |shard, out| {
            seen.push((shard, out));
        });
        drop(tracer);
        let want: Vec<(usize, usize)> = (0..9).map(|s| (s, s + 1)).collect();
        assert_eq!(seen, want);
        let text = std::fs::read_to_string(&path).unwrap();
        let workers: Vec<crate::util::json::Value> = text
            .lines()
            .map(|l| crate::util::json::parse(l).unwrap())
            .filter(|r| r.get("name").and_then(|n| n.as_str()) == Some("worker"))
            .collect();
        assert_eq!(workers.len(), 3, "one span per worker thread");
        let claimed: u64 = workers
            .iter()
            .map(|w| w.path(&["attrs", "shards_claimed"]).unwrap().as_u64().unwrap())
            .sum();
        assert_eq!(claimed, 9, "every shard claimed exactly once");
        let _ = std::fs::remove_file(&path);
    }
}
