//! Aggregator: folds batch outputs into the paper's metrics.

use std::collections::BTreeMap;

use crate::mac::IdealTransfer;
use crate::metrics::{AccuracyReport, ErrorAccumulator, Histogram, OnlineStats, SampleSet};

use super::batcher::{PackedBatch, RowTag};
use crate::runtime::MacBatchOut;

/// Operand-pair key for per-point statistics.
pub type OpKey = (u8, u8);

/// Streaming aggregator. Padding rows are skipped; valid rows update the
/// global accumulator, the per-operand accumulators, the V_multiplication
/// histogram (Fig. 8/9) and the raw-energy stats.
pub struct Aggregator {
    ideal: IdealTransfer,
    global: ErrorAccumulator,
    per_op: BTreeMap<OpKey, ErrorAccumulator>,
    vmult_hist: Histogram,
    vmult_samples: SampleSet,
    energy: OnlineStats,
    rows_seen: u64,
    batches_seen: u64,
}

impl Aggregator {
    /// `full_scale` calibrates the ideal transfer; the histogram spans
    /// [0, 1.25 * full_scale) so MC tails stay on-scale.
    pub fn new(full_scale: f64, hist_bins: usize) -> Self {
        Self {
            ideal: IdealTransfer { full_scale },
            global: ErrorAccumulator::new(),
            per_op: BTreeMap::new(),
            vmult_hist: Histogram::new(0.0, full_scale * 1.25, hist_bins),
            vmult_samples: SampleSet::new(),
            energy: OnlineStats::new(),
            rows_seen: 0,
            batches_seen: 0,
        }
    }

    /// Fold one executed batch.
    pub fn push_batch(&mut self, batch: &PackedBatch, out: &MacBatchOut) {
        self.push_rows(&batch.tags, out);
    }

    /// Fold executed rows by tag — the batch inputs themselves are never
    /// needed here, so sharded runners can drop them before buffering.
    pub fn push_rows(&mut self, tags: &[RowTag], out: &MacBatchOut) {
        self.fold(tags, &out.v_mult, &out.energy, &out.fault);
    }

    /// Fold one executed trial block (the native block path's output SoA,
    /// same `f32` precision as the batch outputs — either path folds
    /// identical numbers in identical order, DESIGN.md §9).
    pub fn push_block(&mut self, tags: &[RowTag], out: &crate::mac::MacResultBlock) {
        self.fold(tags, &out.v_mult, &out.energy, &out.fault);
    }

    /// The shared fold core behind [`Self::push_rows`] / [`Self::push_block`].
    fn fold(&mut self, tags: &[RowTag], vm: &[f32], energy: &[f32], fault: &[f32]) {
        assert_eq!(tags.len(), vm.len(), "batch/output shape mismatch");
        self.batches_seen += 1;
        for (row, tag) in tags.iter().enumerate() {
            let &RowTag::Item { a, b, .. } = tag else { continue };
            let v_mult = f64::from(vm[row]);
            let v_ideal = self.ideal.v_ideal(a, b);
            let is_fault = fault[row] > 0.5;
            // BER at the architecture's 4-bit output resolution (§III: the
            // widened margin buys BER reduction at this grid).
            let code_err = crate::mac::reconstruct4(&self.ideal, v_mult)
                != crate::mac::exact_code4(a, b);
            self.global.push(v_mult, v_ideal, self.ideal.full_scale, code_err, is_fault);
            self.per_op
                .entry((a, b))
                .or_insert_with(ErrorAccumulator::new)
                .push(v_mult, v_ideal, self.ideal.full_scale, code_err, is_fault);
            self.vmult_hist.push(v_mult);
            self.vmult_samples.push(v_mult);
            self.energy.push(f64::from(energy[row]));
            self.rows_seen += 1;
        }
    }

    /// Seal the aggregates into the final report. `wall` is the measured
    /// campaign wall-clock (throughput reporting only — it never affects
    /// the statistics).
    pub fn finish(self, wall: std::time::Duration) -> CampaignReport {
        let per_op = self
            .per_op
            .iter()
            .map(|(k, acc)| (*k, acc.report()))
            .collect();
        // 95% bootstrap CI on the raw output sigma (seeded, reproducible)
        let sigma_ci = if self.vmult_samples.len() >= 8 {
            Some(self.vmult_samples.bootstrap_std_ci(200, 0.95, 0xC1))
        } else {
            None
        };
        CampaignReport {
            accuracy: self.global.report(),
            raw_vmult: *self.global.raw_stats(),
            sigma_ci,
            per_op,
            hist: self.vmult_hist,
            energy: self.energy,
            full_scale: self.ideal.full_scale,
            rows: self.rows_seen,
            batches: self.batches_seen,
            wall,
        }
    }
}

/// Final campaign output.
pub struct CampaignReport {
    /// Global accuracy over all operands and MC samples.
    pub accuracy: AccuracyReport,
    /// Raw V_multiplication stats (mean/sigma in volts — Fig. 8/9 axes).
    pub raw_vmult: OnlineStats,
    /// 95% bootstrap CI on the raw sigma (None below 8 samples).
    pub sigma_ci: Option<(f64, f64)>,
    /// Per-operand-pair accuracy.
    pub per_op: Vec<(OpKey, AccuracyReport)>,
    /// V_multiplication histogram (Fig. 8/9).
    pub hist: Histogram,
    /// Raw bitline energy stats (J).
    pub energy: OnlineStats,
    /// Nominal full-scale output (V) the accuracy metrics normalize by.
    pub full_scale: f64,
    /// Valid (non-padding) rows folded.
    pub rows: u64,
    /// Batches folded (padding included in their shapes).
    pub batches: u64,
    /// Campaign wall-clock (reporting only; never affects statistics).
    pub wall: std::time::Duration,
}

impl CampaignReport {
    /// Throughput in MAC evaluations per second (wall-clock).
    pub fn throughput(&self) -> f64 {
        self.rows as f64 / self.wall.as_secs_f64().max(1e-12)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::batcher::{BatchCfg, Batcher};
    use crate::montecarlo::MismatchSampler;
    use crate::runtime::MacBatchOut;

    fn fake_out(batch: &PackedBatch, v: f32) -> MacBatchOut {
        let n = batch.tags.len();
        MacBatchOut {
            v_mult: vec![v; n],
            v_blb: vec![0.8; n * 4],
            energy: vec![1e-14; n],
            fault: vec![0.0; n],
        }
    }

    fn mk_batches(n_mc: u32, batch: usize) -> Vec<PackedBatch> {
        let cfg = BatchCfg { v_bulk: 0.0, dac_mode: 1.0, t_sample: 1.7e-10 };
        Batcher::new(vec![(15, 15)], n_mc, batch, cfg, MismatchSampler::new(0, 0.0, 0.0))
            .collect()
    }

    #[test]
    fn pads_excluded_from_stats() {
        let batches = mk_batches(10, 8); // 2 batches, 6 pads
        let mut agg = Aggregator::new(0.5, 32);
        for b in &batches {
            let out = fake_out(b, 0.5);
            agg.push_batch(b, &out);
        }
        let r = agg.finish(std::time::Duration::from_secs(1));
        assert_eq!(r.rows, 10);
        assert_eq!(r.batches, 2);
        assert_eq!(r.hist.total(), 10);
        assert_eq!(r.accuracy.n, 10);
    }

    #[test]
    fn exact_outputs_zero_error() {
        let batches = mk_batches(16, 16);
        let mut agg = Aggregator::new(0.5, 32);
        for b in &batches {
            let out = fake_out(b, 0.5); // exactly ideal for (15,15)
            agg.push_batch(b, &out);
        }
        let r = agg.finish(std::time::Duration::from_millis(10));
        assert!(r.accuracy.sigma_norm < 1e-9);
        assert_eq!(r.accuracy.ber, 0.0);
        assert_eq!(r.per_op.len(), 1);
        assert!(r.throughput() > 0.0);
    }

    #[test]
    fn reconstruction_error_counts_in_ber() {
        let batches = mk_batches(4, 4);
        let mut agg = Aggregator::new(0.5, 32);
        for b in &batches {
            let out = fake_out(b, 0.45); // 202.5/225 units -> wrong product
            agg.push_batch(b, &out);
        }
        let r = agg.finish(std::time::Duration::from_millis(1));
        assert_eq!(r.accuracy.ber, 1.0);
    }
}
