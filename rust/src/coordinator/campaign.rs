//! Campaign orchestration: spec -> shards -> blocks -> kernel -> report.
//!
//! The native backend runs as a sharded parallel campaign: the item space
//! is split into contiguous shards ([`super::pool::shard_range`]), worker
//! threads claim shards dynamically ([`super::pool::execute_sharded`]),
//! each shard streams its items through one reusable SoA
//! [`crate::mac::TrialBlock`] executed by a [`crate::mac::SimKernel`]
//! (the lockstep [`crate::mac::BlockKernel`] by default, DESIGN.md §9),
//! and results are folded strictly in global item order. Because mismatch
//! deviates are a pure function of the item index
//! ([`crate::montecarlo::MismatchSampler::sample_item`]) and padding
//! lanes never reach the aggregator, the aggregate statistics are
//! bit-identical for ANY shard count, thread count, or block size —
//! `--shards`/`--threads`/`--block` are pure performance knobs. The
//! kernel tier is the exception: `--kernel {scalar,block}` are
//! bit-identical to each other, while `--kernel fast` is
//! tolerance-bounded (DESIGN.md §13), so the kernel choice is an
//! identity field on [`CampaignSpec`]. The XLA path keeps the
//! fixed-shape [`Batcher`] stream the AOT artifacts were compiled for.

use std::path::PathBuf;

use anyhow::Result;

use super::aggregate::{Aggregator, CampaignReport};
use super::batcher::{BatchCfg, Batcher, RowTag};
use super::pool::{execute_sharded_traced, shard_range, WorkerPool};
use super::spec::CampaignSpec;
use crate::mac::{
    BlockKernel, FastKernel, KernelKind, MacResultBlock, NativeMacEngine, ScalarKernel, SimKernel,
    TrialBlock,
};
use crate::montecarlo::MismatchSampler;
use crate::obs::{Stopwatch, Tracer};
use crate::params::Params;
use crate::runtime::{MacBatchOut, XlaRuntime};

/// Default lanes per [`TrialBlock`] when neither the `--block` nor the
/// legacy `--batch` knob is set — enough for the lockstep loop to keep
/// SIMD lanes busy. The single statement of the auto chunk size, shared
/// by the campaign runner, `smart bench`'s provenance fields, and the
/// `nn` inference tiler.
pub const DEFAULT_BLOCK_LEN: usize = 256;

/// Resolve a worker-thread knob: 0 (auto) means all available
/// parallelism. Shared by every runner so CLI provenance fields record
/// exactly what executed.
pub fn resolve_threads(threads: usize) -> usize {
    if threads > 0 {
        threads
    } else {
        std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
    }
}

/// Execution backend for a campaign.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Backend {
    /// AOT artifacts via the PJRT worker pool (the production path).
    Xla,
    /// The native Rust simulator, sharded across OS threads.
    Native,
}

/// Run a campaign to completion and return its report.
///
/// The native path fans shards out over a dynamic thread pool; the XLA
/// path interleaves submission and draining so the bounded job queue
/// applies backpressure to the batcher. Either way the report is
/// bit-identical for any shard/thread choice (DESIGN.md §4).
///
/// ```
/// use smart_insram::coordinator::{run_campaign, Backend, CampaignSpec};
/// use smart_insram::mac::Variant;
/// use smart_insram::params::Params;
///
/// let params = Params::default();
/// let mut spec = CampaignSpec::paper_fig8(Variant::Smart);
/// spec.n_mc = 8; // keep the example fast (the paper runs 1000)
/// let report = run_campaign(&params, &spec, Backend::Native, None).unwrap();
/// assert_eq!(report.rows, 8);
/// assert!(report.accuracy.sigma_norm < 0.05);
/// ```
pub fn run_campaign(
    params: &Params,
    spec: &CampaignSpec,
    backend: Backend,
    artifact_dir: Option<PathBuf>,
) -> Result<CampaignReport> {
    run_campaign_traced(params, spec, backend, artifact_dir, &Tracer::disabled())
}

/// [`run_campaign`] with tracing (DESIGN.md §15): emits one `campaign`
/// root span (kernel, item count, shard/block/thread shape, and — on the
/// fast tier — lane/fallback/table-build counter deltas) plus per-shard
/// `shard` and per-thread `worker` child spans on the native path. The
/// report is byte-identical to the untraced call for every backend and
/// tracer state: spans observe the run, the run never reads them
/// (pinned by `tests/obs.rs`).
pub fn run_campaign_traced(
    params: &Params,
    spec: &CampaignSpec,
    backend: Backend,
    artifact_dir: Option<PathBuf>,
    tracer: &Tracer,
) -> Result<CampaignReport> {
    spec.validate().map_err(|e| anyhow::anyhow!(e))?;
    match backend {
        Backend::Native => run_native_campaign_traced(params, spec, tracer),
        Backend::Xla => {
            let dir = artifact_dir.unwrap_or_else(crate::runtime::default_artifact_dir);
            // Pick a compiled batch size: honour the spec, else the largest
            // artifact not exceeding the total work.
            let rt = XlaRuntime::open(&dir)?;
            let total = spec.total_items(spec.workload.operands(spec.seed).len());
            let batch = if spec.batch > 0 { spec.batch } else { rt.best_batch(total as usize) };
            drop(rt);
            let workers = if spec.workers > 0 {
                spec.workers
            } else {
                // PJRT's CPU client is internally threaded; extra clients on
                // this host only add compile + contention cost (DESIGN.md §7).
                1
            };
            let mut engine = CampaignEngine::new(dir, batch, workers)?;
            let mut span = tracer.span("campaign");
            span.attr_str("backend", "xla");
            span.attr_u64("items", total);
            let report = engine.run(params, spec);
            tracer.finish(span);
            report
        }
    }
}

/// Sharded native campaign on the kernel tier the spec selects
/// ([`CampaignSpec::kernel`], DESIGN.md §13).
fn run_native_campaign_traced(
    params: &Params,
    spec: &CampaignSpec,
    tracer: &Tracer,
) -> Result<CampaignReport> {
    match spec.kernel {
        KernelKind::Scalar => run_native_campaign_with_traced(params, spec, &ScalarKernel, tracer),
        KernelKind::Block => run_native_campaign_with_traced(params, spec, &BlockKernel, tracer),
        KernelKind::Fast => {
            run_native_campaign_with_traced(params, spec, FastKernel::shared(), tracer)
        }
    }
}

/// Sharded native campaign over an explicit simulation kernel: split the
/// item space into contiguous shards, stream each shard through ONE
/// reusable [`TrialBlock`] (refilled in place per chunk — zero per-item
/// allocation), execute blocks on the given [`SimKernel`], and fold the
/// outputs in canonical item order.
///
/// [`BlockKernel`] (the default behind [`Backend::Native`]) and the
/// [`crate::mac::ScalarKernel`] oracle produce bit-identical aggregates;
/// the [`crate::mac::FastKernel`] surrogate is bounded by
/// [`crate::mac::FAST_TOLERANCE`] instead (DESIGN.md §13). Within ANY
/// fixed kernel, all `--shards`/`--threads`/`--block` choices are
/// bit-identical (DESIGN.md §9; property-tested in
/// `tests/block_kernel.rs` and `tests/fast_kernel.rs`).
pub fn run_native_campaign_with(
    params: &Params,
    spec: &CampaignSpec,
    kernel: &dyn SimKernel,
) -> Result<CampaignReport> {
    run_native_campaign_with_traced(params, spec, kernel, &Tracer::disabled())
}

/// [`run_native_campaign_with`] with tracing: the `campaign` root span
/// carries the run shape (kernel, items, shards, block, threads) plus
/// the kernel's counter deltas, each shard emits a `shard` child span
/// with its item count, and each pool thread a `worker` span with its
/// claimed-shard tally. All of it is observation only — the fold below
/// never reads a span, so the report is byte-identical with tracing on
/// or off (pinned by `tests/obs.rs`).
pub fn run_native_campaign_with_traced(
    params: &Params,
    spec: &CampaignSpec,
    kernel: &dyn SimKernel,
    tracer: &Tracer,
) -> Result<CampaignReport> {
    spec.validate().map_err(|e| anyhow::anyhow!(e))?;
    let cfg = spec.variant.config(params);
    let engine = NativeMacEngine::new(*params, cfg);
    let full_scale = engine.full_scale();
    let operands = spec.workload.operands(spec.seed);
    let sampler =
        MismatchSampler::new(spec.seed, params.circuit.sigma_vth, params.circuit.sigma_beta)
            .with_corner(spec.corner);

    let total = spec.total_items(operands.len());
    // Chunk size: `--block`, else the legacy `--batch` knob, else the
    // shared auto default.
    let block_len = if spec.block > 0 {
        spec.block
    } else if spec.batch > 0 {
        spec.batch
    } else {
        DEFAULT_BLOCK_LEN
    };
    let threads = resolve_threads(spec.workers);
    // Auto-sharding: a few shards per thread for load balance, never more
    // than one shard per block of work. Any choice yields identical
    // aggregates; this only tunes scheduling granularity.
    let n_blocks = total.div_ceil(block_len as u64).max(1) as usize;
    let n_shards = if spec.shards > 0 { spec.shards } else { n_blocks.min(threads * 4) };

    let mut cspan = tracer.span("campaign");
    cspan.attr_str("kernel", kernel.name());
    cspan.attr_u64("items", total);
    cspan.attr_u64("shards", n_shards as u64);
    cspan.attr_u64("block", block_len as u64);
    cspan.attr_u64("threads", threads as u64);
    let parent = cspan.id();
    let counters_before = kernel.counters();

    let t0 = Stopwatch::start();
    let mut agg = Aggregator::new(full_scale, 64);
    let n_mc = u64::from(spec.n_mc);
    // Shards buffer results only (tags, output SoA) — block inputs live
    // in the shard's single reusable TrialBlock and are overwritten per
    // chunk. Worst-case memory is still one campaign's outputs if the
    // first shard is the last to finish; with auto-sharding (a few
    // shards per thread) the typical in-flight window is a handful.
    let run_shard = |shard: usize| {
        let mut sspan = tracer.span_started("shard", parent, Stopwatch::start());
        let (start, end) = shard_range(total, n_shards, shard);
        sspan.attr_u64("shard", shard as u64);
        sspan.attr_u64("items", end - start);
        // no point reserving a 256-lane block for a 32-item shard —
        // clamp to the shard's own length
        let shard_block = block_len.min((end - start).max(1) as usize);
        let mut block = TrialBlock::with_capacity(shard_block);
        let mut results: Vec<(Vec<RowTag>, MacResultBlock)> = Vec::new();
        let mut cursor = start;
        while cursor < end {
            let n = shard_block.min((end - cursor) as usize);
            block.reset(n);
            let (dvth, dbeta) = block.deviates_mut();
            sampler.fill_block(cursor, dvth, dbeta);
            let mut tags = Vec::with_capacity(n);
            for i in 0..n {
                let k = cursor + i as u64;
                let op_idx = (k / n_mc) as u32;
                let mc_idx = (k % n_mc) as u32;
                let (a, b) = operands[op_idx as usize];
                block.set_operands(i, a, b);
                tags.push(RowTag::Item { op_idx, mc_idx, a, b });
            }
            kernel.simulate(&engine, &mut block);
            results.push((tags, block.out.clone()));
            cursor += n as u64;
        }
        tracer.finish(sspan);
        results
    };
    execute_sharded_traced(n_shards, threads, tracer, parent, run_shard, |_, outs| {
        for (tags, out) in &outs {
            agg.push_block(tags, out);
        }
    });
    let delta = kernel.counters().since(&counters_before);
    if delta != crate::mac::KernelCounters::default() {
        cspan.attr_u64("lanes", delta.lanes);
        cspan.attr_u64("fallbacks", delta.fallbacks);
        cspan.attr_u64("table_builds", delta.table_builds);
    }
    tracer.finish(cspan);
    Ok(agg.finish(t0.elapsed()))
}

/// Run several campaigns that share one variant and kernel tier through
/// ONE engine, ONE kernel instance, and ONE reusable [`TrialBlock`],
/// returning one report per spec in input order.
///
/// This is the serving path's cross-request batching primitive
/// (DESIGN.md §14): when a group of small compatible requests arrives,
/// the engine construction and — on the fast tier — the shared
/// surrogate tables amortize across all of them instead of being paid
/// per request. Each spec still replicates the solo runner's shard and
/// chunk arithmetic exactly and folds blocks in canonical item order,
/// so every report is **bit-identical** to what
/// [`run_native_campaign_with`] would produce for that spec alone
/// ([`TrialBlock::reset`] fully resizes the SoA buffers, making block
/// reuse byte-safe; property-tested in `tests/serve.rs`).
///
/// Specs run sequentially on the caller's thread: merged groups are
/// small (the serve `--batch-max` bound), and keeping one thread per
/// group lets the service's worker pool parallelize across groups
/// instead of within them.
pub fn run_native_campaigns_merged(
    params: &Params,
    specs: &[CampaignSpec],
) -> Result<Vec<CampaignReport>> {
    let Some(first) = specs.first() else {
        return Ok(Vec::new());
    };
    for s in specs {
        s.validate().map_err(|e| anyhow::anyhow!(e))?;
        anyhow::ensure!(
            s.variant == first.variant && s.kernel == first.kernel,
            "merged campaigns must share one variant and kernel tier (got {}/{} vs {}/{})",
            s.variant.token(),
            s.kernel.token(),
            first.variant.token(),
            first.kernel.token()
        );
    }
    let kernel: &dyn SimKernel = match first.kernel {
        KernelKind::Scalar => &ScalarKernel,
        KernelKind::Block => &BlockKernel,
        KernelKind::Fast => FastKernel::shared(),
    };
    let cfg = first.variant.config(params);
    let engine = NativeMacEngine::new(*params, cfg);
    let full_scale = engine.full_scale();
    let mut block = TrialBlock::with_capacity(DEFAULT_BLOCK_LEN);
    let mut reports = Vec::with_capacity(specs.len());
    for spec in specs {
        let operands = spec.workload.operands(spec.seed);
        let sampler =
            MismatchSampler::new(spec.seed, params.circuit.sigma_vth, params.circuit.sigma_beta)
                .with_corner(spec.corner);
        let total = spec.total_items(operands.len());
        let block_len = if spec.block > 0 {
            spec.block
        } else if spec.batch > 0 {
            spec.batch
        } else {
            DEFAULT_BLOCK_LEN
        };
        let threads = resolve_threads(spec.workers);
        let n_blocks = total.div_ceil(block_len as u64).max(1) as usize;
        let n_shards = if spec.shards > 0 { spec.shards } else { n_blocks.min(threads * 4) };
        let t0 = Stopwatch::start();
        let mut agg = Aggregator::new(full_scale, 64);
        let n_mc = u64::from(spec.n_mc);
        // Identical shard/chunk arithmetic to the solo runner, executed
        // in shard order — the same canonical fold order the threaded
        // path reduces in.
        for shard in 0..n_shards {
            let (start, end) = shard_range(total, n_shards, shard);
            let shard_block = block_len.min((end - start).max(1) as usize);
            let mut cursor = start;
            while cursor < end {
                let n = shard_block.min((end - cursor) as usize);
                block.reset(n);
                let (dvth, dbeta) = block.deviates_mut();
                sampler.fill_block(cursor, dvth, dbeta);
                let mut tags = Vec::with_capacity(n);
                for i in 0..n {
                    let k = cursor + i as u64;
                    let op_idx = (k / n_mc) as u32;
                    let mc_idx = (k % n_mc) as u32;
                    let (a, b) = operands[op_idx as usize];
                    block.set_operands(i, a, b);
                    tags.push(RowTag::Item { op_idx, mc_idx, a, b });
                }
                kernel.simulate(&engine, &mut block);
                agg.push_block(&tags, &block.out);
                cursor += n as u64;
            }
        }
        reports.push(agg.finish(t0.elapsed()));
    }
    Ok(reports)
}

/// A reusable campaign executor: the worker pool (and its compiled PJRT
/// executables) persist across campaigns of the same batch size. For
/// drivers that run many campaigns (mc_sweep, the benches, services) this
/// removes the per-campaign compile cost — the dominant term on this host
/// (DESIGN.md §7: ~120 ms compile vs ~25 ms per 256-row execute).
pub struct CampaignEngine {
    pool: WorkerPool,
    batch: usize,
}

impl CampaignEngine {
    /// Spawn a persistent pool of `workers` PJRT threads, each compiling
    /// the `batch`-row MAC artifact from `artifact_dir`.
    pub fn new(artifact_dir: PathBuf, batch: usize, workers: usize) -> Result<Self> {
        let pool = WorkerPool::spawn(artifact_dir, batch, workers.max(1))?;
        Ok(Self { pool, batch })
    }

    /// The compiled batch size every campaign on this engine must use.
    pub fn batch(&self) -> usize {
        self.batch
    }

    /// Run one campaign on the persistent pool. `spec.batch` must be 0 or
    /// equal to the engine's compiled batch size. Completed batches are
    /// re-sequenced by their submission order before aggregation, so the
    /// report is deterministic for any worker count.
    pub fn run(&mut self, params: &Params, spec: &CampaignSpec) -> Result<CampaignReport> {
        spec.validate().map_err(|e| anyhow::anyhow!(e))?;
        anyhow::ensure!(
            spec.batch == 0 || spec.batch == self.batch,
            "spec batch {} != engine batch {}",
            spec.batch,
            self.batch
        );
        let cfg = spec.variant.config(params);
        let native = NativeMacEngine::new(*params, cfg);
        let full_scale = native.full_scale();
        let operands = spec.workload.operands(spec.seed);
        let sampler =
            MismatchSampler::new(spec.seed, params.circuit.sigma_vth, params.circuit.sigma_beta)
                .with_corner(spec.corner);

        let t0 = Stopwatch::start();
        let mut agg = Aggregator::new(full_scale, 64);
        let batcher = Batcher::new(operands, spec.n_mc, self.batch, BatchCfg::from(&cfg), sampler);
        let mut in_flight: u64 = 0;
        // re-order buffer: batches fold in `seq` order, not arrival order
        let mut pending = std::collections::BTreeMap::new();
        let mut next_seq = 0u64;
        for pb in batcher {
            self.pool.submit(pb)?;
            in_flight += 1;
            // opportunistic drain keeps memory flat under backpressure
            while let Some(done) = self.pool.try_recv() {
                let (b, out) = done?;
                pending.insert(b.seq, (b, out));
                in_flight -= 1;
            }
            while let Some((b, out)) = pending.remove(&next_seq) {
                agg.push_batch(&b, &out);
                next_seq += 1;
            }
        }
        while in_flight > 0 {
            let (b, out) = self
                .pool
                .recv()
                .ok_or_else(|| {
                    anyhow::anyhow!("worker pool exited with {in_flight} batch(es) in flight")
                })??;
            pending.insert(b.seq, (b, out));
            in_flight -= 1;
        }
        while let Some((b, out)) = pending.remove(&next_seq) {
            agg.push_batch(&b, &out);
            next_seq += 1;
        }
        Ok(agg.finish(t0.elapsed()))
    }
}

/// Thread facade for embedding in services: the blocking campaign runs on
/// a dedicated OS thread (PJRT handles must never cross thread boundaries,
/// so a thread-per-campaign handle is the natural async unit here).
pub fn spawn_campaign(
    params: Params,
    spec: CampaignSpec,
    backend: Backend,
    artifact_dir: Option<PathBuf>,
) -> std::thread::JoinHandle<Result<CampaignReport>> {
    std::thread::spawn(move || run_campaign(&params, &spec, backend, artifact_dir))
}

/// Execute one packed batch on the native engine (row-by-row oracle).
/// Padding rows are left at zero — the aggregator never reads them, and
/// simulating them would multiply work across pad-heavy shards (the AOT
/// path has no such freedom: its executables are fixed-shape).
pub fn run_native_batch(
    engine: &NativeMacEngine,
    pb: &super::batcher::PackedBatch,
) -> MacBatchOut {
    let n = pb.tags.len();
    let mut out = MacBatchOut {
        v_mult: vec![0.0; n],
        v_blb: vec![0.0; n * 4],
        energy: vec![0.0; n],
        fault: vec![0.0; n],
    };
    for row in 0..n {
        if matches!(pb.tags[row], RowTag::Pad) {
            continue;
        }
        let a = (0..4).fold(0u8, |acc, k| {
            acc | ((pb.inputs.a_bits[row * 4 + k] > 0.5) as u8) << (3 - k)
        });
        let b = pb.inputs.b_code[row] as u8;
        let mc = crate::montecarlo::McSample {
            dvth: std::array::from_fn(|k| f64::from(pb.inputs.dvth[row * 4 + k])),
            dbeta: std::array::from_fn(|k| f64::from(pb.inputs.dbeta[row * 4 + k])),
        };
        let r = engine.mac(a, b, &mc);
        out.v_mult[row] = r.v_mult as f32;
        for k in 0..4 {
            out.v_blb[row * 4 + k] = r.v_blb[k] as f32;
        }
        out.energy[row] = r.energy as f32;
        out.fault[row] = f32::from(u8::from(r.fault));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::spec::{CampaignSpec, Workload};
    use crate::mac::Variant;

    #[test]
    fn native_campaign_reproduces_paper_shape() {
        let p = Params::default();
        let mut spec = CampaignSpec::paper_fig8(Variant::Smart);
        spec.n_mc = 64; // keep unit test fast; full 1000-pt runs in benches
        let smart = run_campaign(&p, &spec, Backend::Native, None).unwrap();
        spec.variant = Variant::Aid;
        let aid = run_campaign(&p, &spec, Backend::Native, None).unwrap();
        assert_eq!(smart.rows, 64);
        assert!(smart.accuracy.sigma_norm < aid.accuracy.sigma_norm);
    }

    #[test]
    fn native_campaign_deterministic() {
        let p = Params::default();
        let mut spec = CampaignSpec::paper_fig8(Variant::Aid);
        spec.n_mc = 32;
        let a = run_campaign(&p, &spec, Backend::Native, None).unwrap();
        let b = run_campaign(&p, &spec, Backend::Native, None).unwrap();
        assert_eq!(a.accuracy.sigma_norm, b.accuracy.sigma_norm);
        assert_eq!(a.raw_vmult.mean(), b.raw_vmult.mean());
    }

    #[test]
    fn full_sweep_covers_all_ops() {
        let p = Params::default();
        let spec = CampaignSpec {
            variant: Variant::Smart,
            workload: Workload::FullSweep,
            n_mc: 2,
            seed: 1,
            corner: crate::montecarlo::Corner::Tt,
            workers: 0,
            batch: 64,
            shards: 0,
            block: 0,
            kernel: KernelKind::Block,
        };
        let r = run_campaign(&p, &spec, Backend::Native, None).unwrap();
        assert_eq!(r.rows, 512);
        assert_eq!(r.per_op.len(), 256);
    }

    #[test]
    fn shard_and_thread_counts_do_not_change_aggregates() {
        let p = Params::default();
        let mk = |shards: usize, workers: usize| {
            let mut spec = CampaignSpec::paper_fig8(Variant::Smart);
            spec.n_mc = 96;
            spec.shards = shards;
            spec.workers = workers;
            run_campaign(&p, &spec, Backend::Native, None).unwrap()
        };
        let base = mk(1, 1);
        for (shards, workers) in [(4, 1), (4, 4), (7, 3)] {
            let r = mk(shards, workers);
            assert_eq!(r.rows, base.rows);
            assert_eq!(r.raw_vmult.mean().to_bits(), base.raw_vmult.mean().to_bits());
            assert_eq!(
                r.raw_vmult.std_dev().to_bits(),
                base.raw_vmult.std_dev().to_bits()
            );
            assert_eq!(r.hist.counts(), base.hist.counts());
        }
    }

    #[test]
    fn scalar_oracle_matches_block_kernel() {
        // the default (block) campaign path against the per-item oracle
        let p = Params::default();
        let mut spec = CampaignSpec::paper_fig8(Variant::Smart);
        spec.n_mc = 48;
        spec.workers = 1;
        let block = run_campaign(&p, &spec, Backend::Native, None).unwrap();
        let scalar =
            run_native_campaign_with(&p, &spec, &crate::mac::ScalarKernel).unwrap();
        assert_eq!(block.rows, scalar.rows);
        assert_eq!(
            block.raw_vmult.mean().to_bits(),
            scalar.raw_vmult.mean().to_bits()
        );
        assert_eq!(
            block.accuracy.sigma_norm.to_bits(),
            scalar.accuracy.sigma_norm.to_bits()
        );
        assert_eq!(block.hist.counts(), scalar.hist.counts());
        assert_eq!(block.energy.mean().to_bits(), scalar.energy.mean().to_bits());
    }

    #[test]
    fn merged_campaigns_bit_match_their_solo_runs() {
        let p = Params::default();
        let mut a = CampaignSpec::paper_fig8(Variant::Smart);
        a.n_mc = 24;
        a.workers = 1;
        let mut b = a.clone();
        b.seed ^= 7; // same variant/kernel, different campaign
        let mut c = a.clone();
        c.workload = Workload::Random { n_ops: 3 };
        let specs = [a, b, c];
        let merged = run_native_campaigns_merged(&p, &specs).unwrap();
        assert_eq!(merged.len(), specs.len());
        for (spec, m) in specs.iter().zip(&merged) {
            let solo = run_campaign(&p, spec, Backend::Native, None).unwrap();
            assert_eq!(m.rows, solo.rows);
            assert_eq!(m.raw_vmult.mean().to_bits(), solo.raw_vmult.mean().to_bits());
            assert_eq!(
                m.accuracy.sigma_norm.to_bits(),
                solo.accuracy.sigma_norm.to_bits()
            );
            assert_eq!(m.hist.counts(), solo.hist.counts());
            assert_eq!(m.energy.mean().to_bits(), solo.energy.mean().to_bits());
        }
    }

    #[test]
    fn merged_campaigns_reject_mixed_variants_or_kernels() {
        let p = Params::default();
        let a = CampaignSpec::paper_fig8(Variant::Smart);
        let b = CampaignSpec::paper_fig8(Variant::Aid);
        let err = run_native_campaigns_merged(&p, &[a.clone(), b]).unwrap_err().to_string();
        assert!(err.contains("variant"), "{err}");
        let mut f = a.clone();
        f.kernel = KernelKind::Fast;
        let err = run_native_campaigns_merged(&p, &[a, f]).unwrap_err().to_string();
        assert!(err.contains("kernel"), "{err}");
        assert!(run_native_campaigns_merged(&p, &[]).unwrap().is_empty());
    }

    #[test]
    fn fast_kernel_campaign_dispatches_and_tracks_the_oracle() {
        let p = Params::default();
        let mut spec = CampaignSpec::paper_fig8(Variant::Smart);
        spec.n_mc = 48;
        spec.kernel = KernelKind::Fast;
        let fast = run_campaign(&p, &spec, Backend::Native, None).unwrap();
        spec.kernel = KernelKind::Scalar;
        let oracle = run_campaign(&p, &spec, Backend::Native, None).unwrap();
        assert_eq!(fast.rows, oracle.rows);
        // aggregate means move at most by the per-lane tolerance
        assert!(
            (fast.raw_vmult.mean() - oracle.raw_vmult.mean()).abs()
                < 4.0 * crate::mac::FAST_TOLERANCE,
            "{} vs {}",
            fast.raw_vmult.mean(),
            oracle.raw_vmult.mean()
        );
    }
}
