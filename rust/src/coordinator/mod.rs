//! L3 — the Monte-Carlo campaign coordinator (the paper's evaluation
//! harness as a production service).
//!
//! A campaign = (variant, operand workload, MC sample count). The
//! coordinator splits it into contiguous item shards with deterministic
//! per-shard RNG streams, packs each shard into the fixed batch shapes the
//! AOT artifacts were compiled for ([`Batcher`]), executes shards on a
//! dynamic (work-stealing) thread pool ([`execute_sharded`]) or a pool of
//! PJRT worker threads with bounded-queue backpressure ([`WorkerPool`]),
//! and folds the results into the paper's metrics ([`Aggregator`]) in
//! canonical item order. Every campaign is bit-reproducible from
//! (spec, seed) — for ANY `--shards`/`--threads` (DESIGN.md §4).
//!
//! PJRT handles are `!Send`, so XLA workers are OS threads each owning a
//! private [`crate::runtime::XlaRuntime`]; [`spawn_campaign`] wraps the
//! blocking run in a thread handle for embedding in services.

mod aggregate;
mod batcher;
mod campaign;
mod pool;
mod spec;

pub use aggregate::{Aggregator, CampaignReport, OpKey};
pub use batcher::{BatchCfg, Batcher, PackedBatch, RowTag};
pub use campaign::{run_campaign, run_native_batch, spawn_campaign, Backend, CampaignEngine};
pub use pool::{execute_sharded, shard_range, WorkerPool};
pub use spec::{CampaignSpec, Workload};
