//! L3 — the Monte-Carlo campaign coordinator (the paper's evaluation
//! harness as a production service).
//!
//! A campaign = (variant, operand workload, MC sample count). The
//! coordinator splits it into contiguous item shards with deterministic
//! per-item RNG streams. Native shards stream through reusable SoA trial
//! blocks executed by a [`crate::mac::SimKernel`]
//! ([`run_native_campaign_with`], DESIGN.md §9); the AOT path packs the
//! fixed batch shapes its artifacts were compiled for ([`Batcher`]) and
//! runs them on a pool of PJRT worker threads with bounded-queue
//! backpressure ([`WorkerPool`]). Either way shards execute on a dynamic
//! (work-stealing) thread pool ([`execute_sharded`]) and results fold
//! into the paper's metrics ([`Aggregator`]) in canonical item order.
//! Every campaign is bit-reproducible from (spec, seed) — for ANY
//! `--shards`/`--threads`/`--block` (DESIGN.md §4).
//!
//! PJRT handles are `!Send`, so XLA workers are OS threads each owning a
//! private [`crate::runtime::XlaRuntime`]; [`spawn_campaign`] wraps the
//! blocking run in a thread handle for embedding in services.

mod aggregate;
mod batcher;
mod campaign;
mod pool;
mod spec;

pub use aggregate::{Aggregator, CampaignReport, OpKey};
pub use batcher::{BatchCfg, Batcher, PackedBatch, RowTag};
pub use campaign::{
    resolve_threads, run_campaign, run_campaign_traced, run_native_batch,
    run_native_campaign_with, run_native_campaign_with_traced, run_native_campaigns_merged,
    spawn_campaign, Backend, CampaignEngine, DEFAULT_BLOCK_LEN,
};
pub use pool::{execute_sharded, execute_sharded_traced, shard_range, WorkerPool};
pub use spec::{CampaignSpec, Workload};
