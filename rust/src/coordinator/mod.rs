//! L3 — the Monte-Carlo campaign coordinator (the paper's evaluation
//! harness as a production service).
//!
//! A campaign = (variant, operand workload, MC sample count). The
//! coordinator expands it into (operand, sample) work items, packs them
//! into the fixed batch shapes the AOT artifacts were compiled for
//! ([`Batcher`]), fans the batches out over a pool of PJRT worker threads
//! with bounded-queue backpressure ([`WorkerPool`]), and folds the results
//! into the paper's metrics ([`Aggregator`]). Every campaign is
//! bit-reproducible from (spec, seed).
//!
//! PJRT handles are `!Send`, so workers are OS threads each owning a
//! private [`crate::runtime::XlaRuntime`]; [`spawn_campaign`] wraps the
//! blocking run in a thread handle for embedding in services.

mod aggregate;
mod batcher;
mod campaign;
mod pool;
mod spec;

pub use aggregate::{Aggregator, CampaignReport, OpKey};
pub use batcher::{Batcher, PackedBatch, RowTag};
pub use campaign::{run_campaign, run_native_batch, spawn_campaign, Backend, CampaignEngine};
pub use pool::WorkerPool;
pub use spec::{CampaignSpec, Workload};
