//! Global-free metrics: saturating counters, gauges, and
//! fixed-log2-bucket histograms, collected in a [`MetricsRegistry`]
//! (DESIGN.md §15).
//!
//! Nothing here is a process-wide static: a registry is owned by
//! whoever needs one (the serve [`Pipeline`], a bench run) and handed
//! around explicitly, so two servers in one test process never share
//! counters. All primitives are lock-free `AtomicU64`s with `Relaxed`
//! ordering — they are statistics, not synchronization — and additions
//! saturate instead of wrapping so a countered service can run forever
//! without a counter ever going backwards.
//!
//! [`Counter`] generalizes what used to be `serve::stats::Monotonic`
//! (which is now a re-export of this type). [`Histogram`] uses 64 fixed
//! log2 buckets — bucket `i` covers `[2^i, 2^(i+1))`, with 0 landing in
//! bucket 0 — so recording is one `leading_zeros` and one atomic add,
//! and the bucket layout never depends on the data.
//!
//! Snapshots serialize through `util::json` ([`MetricsRegistry::snapshot`])
//! and as Prometheus text exposition ([`MetricsRegistry::prometheus`],
//! the `/v1/metrics` endpoint body).
//!
//! [`Pipeline`]: crate::serve::Pipeline

use std::collections::BTreeMap;
use std::fmt::Write as _;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, PoisonError};

use crate::util::json::Value;

/// A saturating monotonic counter. Increments use `Relaxed` ordering
/// (statistics, not synchronization) and saturate at `u64::MAX` rather
/// than wrapping, so readers can rely on it never decreasing.
#[derive(Debug, Default)]
pub struct Counter(AtomicU64);

impl Counter {
    /// A zeroed counter (usable in statics and struct literals).
    pub const fn new() -> Self {
        Counter(AtomicU64::new(0))
    }

    /// Add `n`, saturating at `u64::MAX`.
    pub fn add(&self, n: u64) {
        let _ = self.0.fetch_update(Ordering::Relaxed, Ordering::Relaxed, |v| {
            Some(v.saturating_add(n))
        });
    }

    /// Add one.
    pub fn incr(&self) {
        self.add(1);
    }

    /// Current value.
    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// A last-write-wins gauge: a value that can move both ways (queue
/// depth, resident entries). Stored as `u64`; `Relaxed` like the rest.
#[derive(Debug, Default)]
pub struct Gauge(AtomicU64);

impl Gauge {
    /// A zeroed gauge.
    pub const fn new() -> Self {
        Gauge(AtomicU64::new(0))
    }

    /// Overwrite the value.
    pub fn set(&self, v: u64) {
        self.0.store(v, Ordering::Relaxed);
    }

    /// Current value.
    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// Number of log2 buckets in a [`Histogram`]: one per bit of `u64`, so
/// every value has exactly one bucket and the layout is data-independent.
pub const N_BUCKETS: usize = 64;

/// The bucket index a value lands in: `floor(log2(v))`, with 0 in
/// bucket 0. Bucket `i` therefore covers `[2^i, 2^(i+1))`.
pub fn bucket_index(v: u64) -> usize {
    if v == 0 {
        0
    } else {
        63 - v.leading_zeros() as usize
    }
}

/// The inclusive upper bound of bucket `i` (the Prometheus `le` label):
/// `2^(i+1) - 1`, saturating at `u64::MAX` for the last bucket.
pub fn bucket_bound(i: usize) -> u64 {
    if i >= 63 {
        u64::MAX
    } else {
        (1u64 << (i + 1)) - 1
    }
}

/// A fixed-log2-bucket latency histogram: 64 buckets, a saturating
/// count, and a saturating sum. Recording is lock-free; quantile reads
/// are bucket-resolution estimates (the upper bound of the bucket the
/// nearest-rank sample falls in), which is exactly the resolution the
/// log2 layout promises.
#[derive(Debug)]
pub struct Histogram {
    buckets: [Counter; N_BUCKETS],
    count: Counter,
    sum: Counter,
}

impl Histogram {
    /// An empty histogram.
    pub fn new() -> Self {
        Histogram {
            buckets: std::array::from_fn(|_| Counter::new()),
            count: Counter::new(),
            sum: Counter::new(),
        }
    }

    /// Record one observation.
    pub fn record(&self, v: u64) {
        self.buckets[bucket_index(v)].incr();
        self.count.incr();
        self.sum.add(v);
    }

    /// Observations recorded.
    pub fn count(&self) -> u64 {
        self.count.get()
    }

    /// Sum of all observations (saturating).
    pub fn sum(&self) -> u64 {
        self.sum.get()
    }

    /// Count in bucket `i` (values in `[2^i, 2^(i+1))`).
    pub fn bucket(&self, i: usize) -> u64 {
        self.buckets[i].get()
    }

    /// Nearest-rank quantile estimate for `p` in [0, 100]: the upper
    /// bound of the bucket holding the rank-`ceil(p/100 * count)`
    /// observation. Returns 0 for an empty histogram.
    pub fn quantile(&self, p: f64) -> u64 {
        let count = self.count.get();
        if count == 0 {
            return 0;
        }
        // p is clamped to [0, 100] and count <= 2^53 in any realistic
        // run, so the f64 rank round-trips exactly
        let rank = ((p.clamp(0.0, 100.0) / 100.0 * count as f64).ceil() as u64).max(1);
        let mut seen = 0u64;
        for i in 0..N_BUCKETS {
            seen = seen.saturating_add(self.buckets[i].get());
            if seen >= rank {
                return bucket_bound(i);
            }
        }
        bucket_bound(N_BUCKETS - 1)
    }

    /// JSON snapshot: count, sum, and the non-empty buckets keyed by
    /// their `le` upper bound (sorted numerically via zero-padding).
    pub fn snapshot(&self) -> Value {
        let mut m = BTreeMap::new();
        m.insert("count".to_string(), Value::Num(self.count.get() as f64));
        m.insert("sum".to_string(), Value::Num(self.sum.get() as f64));
        let mut buckets = BTreeMap::new();
        for i in 0..N_BUCKETS {
            let n = self.buckets[i].get();
            if n > 0 {
                buckets.insert(format!("{:020}", bucket_bound(i)), Value::Num(n as f64));
            }
        }
        m.insert("buckets".to_string(), Value::Obj(buckets));
        Value::Obj(m)
    }
}

impl Default for Histogram {
    fn default() -> Self {
        Histogram::new()
    }
}

/// A registry of named metrics. Get-or-create accessors return `Arc`
/// handles, so hot paths resolve a name once and increment lock-free
/// thereafter; the maps themselves are `BTreeMap`s so every export is
/// deterministically ordered.
///
/// Metric names should already be Prometheus-shaped
/// (`[a-zA-Z_][a-zA-Z0-9_]*`, e.g. `serve_requests_total`); the
/// exposition writer does not rewrite them.
#[derive(Debug, Default)]
pub struct MetricsRegistry {
    counters: Mutex<BTreeMap<String, Arc<Counter>>>,
    gauges: Mutex<BTreeMap<String, Arc<Gauge>>>,
    histograms: Mutex<BTreeMap<String, Arc<Histogram>>>,
}

impl MetricsRegistry {
    /// An empty registry.
    pub fn new() -> Self {
        MetricsRegistry::default()
    }

    /// The counter named `name`, created zeroed on first use.
    pub fn counter(&self, name: &str) -> Arc<Counter> {
        let mut m = self.counters.lock().unwrap_or_else(PoisonError::into_inner);
        Arc::clone(m.entry(name.to_string()).or_default())
    }

    /// The gauge named `name`, created zeroed on first use.
    pub fn gauge(&self, name: &str) -> Arc<Gauge> {
        let mut m = self.gauges.lock().unwrap_or_else(PoisonError::into_inner);
        Arc::clone(m.entry(name.to_string()).or_default())
    }

    /// The histogram named `name`, created empty on first use.
    pub fn histogram(&self, name: &str) -> Arc<Histogram> {
        let mut m = self.histograms.lock().unwrap_or_else(PoisonError::into_inner);
        Arc::clone(m.entry(name.to_string()).or_default())
    }

    /// JSON snapshot of every metric, deterministically ordered. The
    /// shape is `{counters: {...}, gauges: {...}, histograms: {...}}`
    /// with empty sections elided.
    pub fn snapshot(&self) -> Value {
        let mut out = BTreeMap::new();
        let counters = self.counters.lock().unwrap_or_else(PoisonError::into_inner);
        if !counters.is_empty() {
            let m: BTreeMap<String, Value> = counters
                .iter()
                .map(|(k, c)| (k.clone(), Value::Num(c.get() as f64)))
                .collect();
            out.insert("counters".to_string(), Value::Obj(m));
        }
        drop(counters);
        let gauges = self.gauges.lock().unwrap_or_else(PoisonError::into_inner);
        if !gauges.is_empty() {
            let m: BTreeMap<String, Value> =
                gauges.iter().map(|(k, g)| (k.clone(), Value::Num(g.get() as f64))).collect();
            out.insert("gauges".to_string(), Value::Obj(m));
        }
        drop(gauges);
        let histograms = self.histograms.lock().unwrap_or_else(PoisonError::into_inner);
        if !histograms.is_empty() {
            let m: BTreeMap<String, Value> =
                histograms.iter().map(|(k, h)| (k.clone(), h.snapshot())).collect();
            out.insert("histograms".to_string(), Value::Obj(m));
        }
        Value::Obj(out)
    }

    /// Prometheus text exposition (version 0.0.4) of every metric:
    /// `# TYPE` lines, cumulative `_bucket{le="..."}` series plus
    /// `_sum`/`_count` for histograms. Deterministically ordered.
    pub fn prometheus(&self) -> String {
        let mut out = String::new();
        let counters = self.counters.lock().unwrap_or_else(PoisonError::into_inner);
        for (name, c) in counters.iter() {
            let _ = writeln!(out, "# TYPE {name} counter");
            let _ = writeln!(out, "{name} {}", c.get());
        }
        drop(counters);
        let gauges = self.gauges.lock().unwrap_or_else(PoisonError::into_inner);
        for (name, g) in gauges.iter() {
            let _ = writeln!(out, "# TYPE {name} gauge");
            let _ = writeln!(out, "{name} {}", g.get());
        }
        drop(gauges);
        let histograms = self.histograms.lock().unwrap_or_else(PoisonError::into_inner);
        for (name, h) in histograms.iter() {
            let _ = writeln!(out, "# TYPE {name} histogram");
            let mut cumulative = 0u64;
            for i in 0..N_BUCKETS {
                let n = h.bucket(i);
                cumulative = cumulative.saturating_add(n);
                // only emit buckets up to (and including) the last
                // non-empty one, plus +Inf — 64 mostly-zero series per
                // histogram would drown the exposition
                if n > 0 {
                    let _ =
                        writeln!(out, "{name}_bucket{{le=\"{}\"}} {cumulative}", bucket_bound(i));
                }
            }
            let _ = writeln!(out, "{name}_bucket{{le=\"+Inf\"}} {}", h.count());
            let _ = writeln!(out, "{name}_sum {}", h.sum());
            let _ = writeln!(out, "{name}_count {}", h.count());
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counter_saturates_instead_of_wrapping() {
        let c = Counter::new();
        c.add(u64::MAX - 1);
        c.add(5);
        assert_eq!(c.get(), u64::MAX);
        c.incr();
        assert_eq!(c.get(), u64::MAX);
    }

    #[test]
    fn gauge_moves_both_ways() {
        let g = Gauge::new();
        g.set(9);
        assert_eq!(g.get(), 9);
        g.set(3);
        assert_eq!(g.get(), 3);
    }

    #[test]
    fn bucket_boundaries_are_exact_powers_of_two() {
        // bucket i covers [2^i, 2^(i+1)): both edges must land correctly
        assert_eq!(bucket_index(0), 0);
        assert_eq!(bucket_index(1), 0);
        assert_eq!(bucket_index(2), 1);
        assert_eq!(bucket_index(3), 1);
        assert_eq!(bucket_index(4), 2);
        for i in 0..63 {
            let lo = 1u64 << i;
            assert_eq!(bucket_index(lo), i, "lower edge of bucket {i}");
            assert_eq!(bucket_index(lo + (lo - 1)), i, "upper edge of bucket {i}");
            if i < 62 {
                assert_eq!(bucket_index(lo * 2), i + 1, "first value past bucket {i}");
            }
        }
        assert_eq!(bucket_index(u64::MAX), 63);
        assert_eq!(bucket_bound(0), 1);
        assert_eq!(bucket_bound(1), 3);
        assert_eq!(bucket_bound(62), (1u64 << 63) - 1);
        assert_eq!(bucket_bound(63), u64::MAX);
    }

    #[test]
    fn histogram_counts_sums_and_estimates_quantiles() {
        let h = Histogram::new();
        for v in [1u64, 2, 3, 100, 1000] {
            h.record(v);
        }
        assert_eq!(h.count(), 5);
        assert_eq!(h.sum(), 1106);
        assert_eq!(h.bucket(0), 1); // 1
        assert_eq!(h.bucket(1), 2); // 2, 3
        assert_eq!(h.bucket(6), 1); // 100 in [64, 128)
        assert_eq!(h.bucket(9), 1); // 1000 in [512, 1024)
        // rank 3 of 5 lands in bucket 1 -> le bound 3
        assert_eq!(h.quantile(50.0), 3);
        // the top sample lands in bucket 9 -> le bound 1023
        assert_eq!(h.quantile(99.0), 1023);
        assert_eq!(Histogram::new().quantile(50.0), 0);
    }

    #[test]
    fn registry_handles_are_shared_and_snapshots_are_sorted() {
        let reg = MetricsRegistry::new();
        let a = reg.counter("zz_total");
        let b = reg.counter("zz_total");
        assert!(Arc::ptr_eq(&a, &b));
        a.add(2);
        reg.gauge("aa_depth").set(7);
        reg.histogram("mm_us").record(5);
        let snap = reg.snapshot();
        assert_eq!(snap.path(&["counters", "zz_total"]).unwrap().as_u64(), Some(2));
        assert_eq!(snap.path(&["gauges", "aa_depth"]).unwrap().as_u64(), Some(7));
        assert_eq!(snap.path(&["histograms", "mm_us", "count"]).unwrap().as_u64(), Some(1));
    }

    #[test]
    fn prometheus_exposition_has_types_buckets_and_totals() {
        let reg = MetricsRegistry::new();
        reg.counter("smart_requests_total").add(3);
        let h = reg.histogram("smart_request_us");
        h.record(2);
        h.record(700);
        let text = reg.prometheus();
        assert!(text.contains("# TYPE smart_requests_total counter"));
        assert!(text.contains("smart_requests_total 3"));
        assert!(text.contains("# TYPE smart_request_us histogram"));
        assert!(text.contains("smart_request_us_bucket{le=\"3\"} 1"));
        assert!(text.contains("smart_request_us_bucket{le=\"1023\"} 2"));
        assert!(text.contains("smart_request_us_bucket{le=\"+Inf\"} 2"));
        assert!(text.contains("smart_request_us_sum 702"));
        assert!(text.contains("smart_request_us_count 2"));
    }
}
