//! Post-hoc trace profiling: fold a JSONL trace into the `PROFILE.json`
//! aggregate (DESIGN.md §15).
//!
//! The trace is the raw record of one run; [`profile_trace`] reduces it
//! to the questions the ROADMAP's planning items actually ask:
//!
//! - **phases** — wall time per root span (campaign, sweep, infer);
//! - **spans** — count, total, and p50/p95/p99 latency per span name
//!   (exact nearest-rank over the recorded durations, not a bucket
//!   estimate — the profiler holds the full sample);
//! - **shards** — work balance across `shard` spans: item counts,
//!   max/mean balance factor, aggregate items/sec;
//! - **kernels** — the kernel mix across `campaign` spans, including
//!   the fast tier's lane/fallback/table-build counters and its
//!   fallback rate;
//! - **serve** — the request mix across `request` spans by cache tier
//!   (hit/disk/dedup/miss) with request-latency percentiles;
//! - **metrics** — the last `counters` registry snapshot, verbatim.
//!
//! Sections with no supporting records are elided, so an `mc` profile
//! has no `serve` section and a serve profile no `shards` section.
//! Derived ratios render at the [`report::canon`] 6-significant-digit
//! precision like every other derived float in the repo.
//!
//! [`report::canon`]: crate::report::canon

use std::collections::BTreeMap;

use crate::report::canon;
use crate::util::json::{self, Value};

/// Durations of one span-name group, with the attr sums the sections
/// need.
#[derive(Debug, Default)]
struct Group {
    durs_us: Vec<u64>,
    total_us: u64,
}

impl Group {
    fn push(&mut self, dur: u64) {
        self.durs_us.push(dur);
        self.total_us = self.total_us.saturating_add(dur);
    }
}

/// Nearest-rank percentile of a sorted sample (`p` in [0, 100]).
fn percentile_us(sorted: &[u64], p: f64) -> u64 {
    if sorted.is_empty() {
        return 0;
    }
    // p is in [0, 100] and sample sizes are far below 2^53, so the
    // rank arithmetic is exact
    let rank = ((p / 100.0 * sorted.len() as f64).ceil() as usize).clamp(1, sorted.len());
    sorted[rank - 1]
}

fn num(n: u64) -> Value {
    Value::Num(n as f64)
}

/// Fold a JSONL trace (the text of a `--trace` file) into the
/// `PROFILE.json` aggregate. Fails with a line-numbered message on an
/// unparseable line or a record without a `type`; unknown record types
/// are skipped (forward compatibility).
pub fn profile_trace(text: &str) -> Result<Value, String> {
    let mut n_records = 0u64;
    let mut phases: BTreeMap<String, Group> = BTreeMap::new();
    let mut spans: BTreeMap<String, Group> = BTreeMap::new();
    // shard spans: (items, dur_us)
    let mut shards: Vec<(u64, u64)> = Vec::new();
    // campaign spans keyed by kernel attr: summed counter attrs
    let mut kernels: BTreeMap<String, BTreeMap<String, u64>> = BTreeMap::new();
    // request spans: durations + per-cache-tier counts
    let mut req_durs: Vec<u64> = Vec::new();
    let mut req_tiers: BTreeMap<String, u64> = BTreeMap::new();
    let mut last_metrics: Option<Value> = None;

    for (i, line) in text.lines().enumerate() {
        if line.trim().is_empty() {
            continue;
        }
        let rec = json::parse(line).map_err(|e| format!("trace line {}: {e}", i + 1))?;
        let ty = rec
            .get("type")
            .and_then(Value::as_str)
            .ok_or_else(|| format!("trace line {}: record without a \"type\"", i + 1))?;
        n_records += 1;
        match ty {
            "meta" => {}
            "counters" => {
                if let Some(m) = rec.get("metrics") {
                    last_metrics = Some(m.clone());
                }
            }
            "span" => {
                let name = rec
                    .get("name")
                    .and_then(Value::as_str)
                    .ok_or_else(|| format!("trace line {}: span without a \"name\"", i + 1))?
                    .to_string();
                let dur = rec.get("dur_us").and_then(Value::as_u64).unwrap_or(0);
                let attrs = rec.get("attrs");
                let attr = |k: &str| attrs.and_then(|a| a.get(k)).and_then(Value::as_u64);
                spans.entry(name.clone()).or_default().push(dur);
                if rec.get("parent") == Some(&Value::Null) {
                    phases.entry(name.clone()).or_default().push(dur);
                }
                match name.as_str() {
                    "shard" => shards.push((attr("items").unwrap_or(0), dur)),
                    "campaign" => {
                        let kernel = attrs
                            .and_then(|a| a.get("kernel"))
                            .and_then(Value::as_str)
                            .unwrap_or("unknown")
                            .to_string();
                        let k = kernels.entry(kernel).or_default();
                        *k.entry("campaigns".to_string()).or_default() += 1;
                        for key in ["items", "lanes", "fallbacks", "table_builds"] {
                            if let Some(v) = attr(key) {
                                let e = k.entry(key.to_string()).or_default();
                                *e = e.saturating_add(v);
                            }
                        }
                    }
                    "request" => {
                        req_durs.push(dur);
                        let tier = attrs
                            .and_then(|a| a.get("cache"))
                            .and_then(Value::as_str)
                            .unwrap_or("none")
                            .to_string();
                        *req_tiers.entry(tier).or_default() += 1;
                    }
                    _ => {}
                }
            }
            _ => {}
        }
    }

    let mut out = BTreeMap::new();
    out.insert("records".to_string(), num(n_records));

    if !phases.is_empty() {
        let m: BTreeMap<String, Value> = phases
            .into_iter()
            .map(|(name, g)| {
                let mut p = BTreeMap::new();
                p.insert("count".to_string(), num(g.durs_us.len() as u64));
                p.insert("total_us".to_string(), num(g.total_us));
                (name, Value::Obj(p))
            })
            .collect();
        out.insert("phases".to_string(), Value::Obj(m));
    }

    if !spans.is_empty() {
        let m: BTreeMap<String, Value> = spans
            .into_iter()
            .map(|(name, mut g)| {
                g.durs_us.sort_unstable();
                let mut p = BTreeMap::new();
                p.insert("count".to_string(), num(g.durs_us.len() as u64));
                p.insert("total_us".to_string(), num(g.total_us));
                p.insert("p50_us".to_string(), num(percentile_us(&g.durs_us, 50.0)));
                p.insert("p95_us".to_string(), num(percentile_us(&g.durs_us, 95.0)));
                p.insert("p99_us".to_string(), num(percentile_us(&g.durs_us, 99.0)));
                (name, Value::Obj(p))
            })
            .collect();
        out.insert("spans".to_string(), Value::Obj(m));
    }

    if !shards.is_empty() {
        let n = shards.len() as u64;
        let items: u64 = shards.iter().map(|(i, _)| i).sum();
        let dur: u64 = shards.iter().map(|(_, d)| d).sum();
        let min_items = shards.iter().map(|(i, _)| *i).min().unwrap_or(0);
        let max_items = shards.iter().map(|(i, _)| *i).max().unwrap_or(0);
        let mean_items = items as f64 / n as f64;
        let mut m = BTreeMap::new();
        m.insert("n".to_string(), num(n));
        m.insert("items".to_string(), num(items));
        m.insert("min_items".to_string(), num(min_items));
        m.insert("max_items".to_string(), num(max_items));
        m.insert("mean_items".to_string(), Value::Num(canon(mean_items)));
        // balance = heaviest shard / mean: 1.0 is a perfect split
        let balance = if mean_items > 0.0 { max_items as f64 / mean_items } else { 0.0 };
        m.insert("balance".to_string(), Value::Num(canon(balance)));
        let ips = if dur > 0 { items as f64 * 1e6 / dur as f64 } else { 0.0 };
        m.insert("items_per_sec".to_string(), Value::Num(canon(ips)));
        out.insert("shards".to_string(), Value::Obj(m));
    }

    if !kernels.is_empty() {
        let m: BTreeMap<String, Value> = kernels
            .into_iter()
            .map(|(kernel, counts)| {
                let lanes = counts.get("lanes").copied().unwrap_or(0);
                let fallbacks = counts.get("fallbacks").copied().unwrap_or(0);
                let mut k: BTreeMap<String, Value> =
                    counts.into_iter().map(|(key, v)| (key, num(v))).collect();
                if lanes > 0 {
                    let rate = fallbacks as f64 / lanes as f64;
                    k.insert("fallback_rate".to_string(), Value::Num(canon(rate)));
                }
                (kernel, Value::Obj(k))
            })
            .collect();
        out.insert("kernels".to_string(), Value::Obj(m));
    }

    if !req_durs.is_empty() {
        req_durs.sort_unstable();
        let mut m = BTreeMap::new();
        m.insert("requests".to_string(), num(req_durs.len() as u64));
        for (tier, n) in req_tiers {
            m.insert(tier, num(n));
        }
        m.insert("p50_us".to_string(), num(percentile_us(&req_durs, 50.0)));
        m.insert("p95_us".to_string(), num(percentile_us(&req_durs, 95.0)));
        m.insert("p99_us".to_string(), num(percentile_us(&req_durs, 99.0)));
        out.insert("serve".to_string(), Value::Obj(m));
    }

    if let Some(metrics) = last_metrics {
        out.insert("metrics".to_string(), metrics);
    }

    Ok(Value::Obj(out))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn percentiles_are_nearest_rank() {
        let s: Vec<u64> = (1..=100).collect();
        assert_eq!(percentile_us(&s, 50.0), 50);
        assert_eq!(percentile_us(&s, 95.0), 95);
        assert_eq!(percentile_us(&s, 99.0), 99);
        assert_eq!(percentile_us(&[7], 99.0), 7);
        assert_eq!(percentile_us(&[], 50.0), 0);
    }

    #[test]
    fn aggregates_shards_kernels_and_requests() {
        let trace = concat!(
            "{\"type\":\"meta\",\"version\":1,\"cmd\":\"mc\"}\n",
            "{\"type\":\"span\",\"id\":\"aa\",\"parent\":null,\"name\":\"campaign\",",
            "\"start_us\":0,\"dur_us\":1000,\"attrs\":{\"kernel\":\"fast\",\"items\":1000,",
            "\"lanes\":1000,\"fallbacks\":250,\"table_builds\":1}}\n",
            "{\"type\":\"span\",\"id\":\"bb\",\"parent\":\"aa\",\"name\":\"shard\",",
            "\"start_us\":0,\"dur_us\":300,\"attrs\":{\"shard\":0,\"items\":600}}\n",
            "{\"type\":\"span\",\"id\":\"cc\",\"parent\":\"aa\",\"name\":\"shard\",",
            "\"start_us\":0,\"dur_us\":200,\"attrs\":{\"shard\":1,\"items\":400}}\n",
            "{\"type\":\"span\",\"id\":\"dd\",\"parent\":null,\"name\":\"request\",",
            "\"start_us\":0,\"dur_us\":40,\"attrs\":{\"cache\":\"hit\"}}\n",
        );
        let p = profile_trace(trace).unwrap();
        assert_eq!(p.get("records").unwrap().as_u64(), Some(5));
        assert_eq!(p.path(&["phases", "campaign", "total_us"]).unwrap().as_u64(), Some(1000));
        assert_eq!(p.path(&["shards", "n"]).unwrap().as_u64(), Some(2));
        assert_eq!(p.path(&["shards", "items"]).unwrap().as_u64(), Some(1000));
        assert_eq!(p.path(&["shards", "balance"]).unwrap().as_f64(), Some(1.2));
        assert_eq!(p.path(&["shards", "items_per_sec"]).unwrap().as_f64(), Some(2.0e6));
        assert_eq!(p.path(&["kernels", "fast", "fallback_rate"]).unwrap().as_f64(), Some(0.25));
        assert_eq!(p.path(&["kernels", "fast", "campaigns"]).unwrap().as_u64(), Some(1));
        assert_eq!(p.path(&["serve", "hit"]).unwrap().as_u64(), Some(1));
        assert_eq!(p.path(&["serve", "p99_us"]).unwrap().as_u64(), Some(40));
        assert_eq!(p.path(&["spans", "shard", "p50_us"]).unwrap().as_u64(), Some(200));
        // no counters record -> no metrics section; no sweep spans either
        assert!(p.get("metrics").is_none());
    }

    #[test]
    fn rejects_garbage_and_skips_unknown_types() {
        assert!(profile_trace("not json\n").is_err());
        assert!(profile_trace("{\"no_type\":1}\n").is_err());
        let p = profile_trace("{\"type\":\"future_thing\",\"x\":1}\n").unwrap();
        assert_eq!(p.get("records").unwrap().as_u64(), Some(1));
        assert!(p.get("spans").is_none());
    }
}
