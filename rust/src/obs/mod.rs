//! Observability: metrics, tracing spans, and post-hoc profiling
//! (DESIGN.md §15).
//!
//! Every other layer of this crate is bound by the byte-determinism
//! contract (DESIGN.md §4): artifacts depend only on the spec, never on
//! wall-clock time or thread schedule. Observability is the one place
//! that *wants* the clock — so the whole clock lives here, quarantined,
//! and everything it produces flows into a side channel (the JSONL
//! trace, the metrics registry, the `/v1/metrics` exposition) that no
//! result path ever reads back. The quarantine is machine-enforced:
//! lint rule D7 bans `Instant`/`SystemTime` and the raw trace-sink APIs
//! outside `rust/src/obs/`, so callers time things with [`Stopwatch`]
//! and emit through [`Tracer`] spans, both of which are inert no-ops
//! when tracing is disabled.
//!
//! The four submodules:
//!
//! - [`registry`] — global-free [`MetricsRegistry`] of saturating
//!   [`Counter`]s, [`Gauge`]s, and fixed-log2-bucket [`Histogram`]s,
//!   with JSON snapshots and Prometheus text exposition;
//! - [`span`] — lightweight [`Span`]s with counter-RNG-derived IDs,
//!   parent links, and attributes;
//! - [`emit`] — the [`Tracer`]: a JSONL trace sink (`--trace FILE` /
//!   `SMART_TRACE=`) written through `util::json`;
//! - [`profile`] — folds an emitted trace into the `PROFILE.json`
//!   aggregate (per-phase wall time, shard balance, kernel mix,
//!   serve-layer breakdown, span latency percentiles).
//!
//! The load-bearing invariant — pinned by `tests/obs.rs` — is that
//! tracing is **provably inert**: `mc.json`, the sweep CSV/JSON,
//! `infer.json`, and served response bodies are byte-identical with
//! tracing on or off, for any `--shards/--threads/--block/--kernel`.
//! Spans observe results; they never feed them.

pub mod emit;
pub mod profile;
pub mod registry;
pub mod span;

pub use emit::Tracer;
pub use profile::profile_trace;
pub use registry::{Counter, Gauge, Histogram, MetricsRegistry};
pub use span::{Span, SpanId};

/// A started monotonic timer: the only sanctioned way to measure a
/// duration outside this module (D7). `Stopwatch` wraps the quarantined
/// `Instant` read; what it measures may feed operator-facing statistics
/// (the `X-Smart-Time-Us` header, `/v1/stats` uptime, trace spans) but
/// never a canonical artifact.
#[derive(Debug, Clone, Copy)]
pub struct Stopwatch {
    // the Stopwatch IS the quarantine — every timing read outside
    // obs:: goes through this type
    t0: std::time::Instant,
}

impl Stopwatch {
    /// Start timing now.
    pub fn start() -> Self {
        // lint:allow(D6): sole sanctioned clock read — consumers only see durations
        Stopwatch { t0: std::time::Instant::now() }
    }

    /// Elapsed time since [`Stopwatch::start`].
    pub fn elapsed(&self) -> std::time::Duration {
        self.t0.elapsed()
    }

    /// Elapsed whole microseconds (saturating at `u64::MAX`).
    pub fn elapsed_us(&self) -> u64 {
        u64::try_from(self.t0.elapsed().as_micros()).unwrap_or(u64::MAX)
    }

    /// Elapsed seconds as a float (operator display only).
    pub fn elapsed_s(&self) -> f64 {
        self.t0.elapsed().as_secs_f64()
    }
}

impl Default for Stopwatch {
    fn default() -> Self {
        Stopwatch::start()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stopwatch_is_monotone() {
        let w = Stopwatch::start();
        let a = w.elapsed_us();
        let b = w.elapsed_us();
        assert!(b >= a);
        assert!(w.elapsed_s() >= 0.0);
    }
}
