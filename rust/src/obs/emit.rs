//! The JSONL trace sink (DESIGN.md §15).
//!
//! A [`Tracer`] is the one handle instrumented code holds: clone-cheap
//! (an `Option<Arc>`), shareable across shard workers, and a complete
//! no-op when disabled — `Tracer::disabled()` reads no clock, takes no
//! lock, allocates nothing per span. Enabled tracers append one compact
//! JSON record per line to the file named by `--trace FILE` (or the
//! `SMART_TRACE` environment variable), written through `util::json` so
//! the trace is parseable by the same code that parses every other
//! artifact.
//!
//! ## Record schema (version 1)
//!
//! ```text
//! {"type":"meta","version":1,"cmd":"mc"}
//! {"type":"span","id":"<16 hex>","parent":"<16 hex>"|null,"name":"...",
//!  "start_us":N,"dur_us":N,"attrs":{...}}
//! {"type":"counters","at_us":N,"metrics":{...registry snapshot...}}
//! ```
//!
//! `start_us`/`at_us` are microseconds since the tracer was created
//! (its epoch), `dur_us` is the span's wall time. These are the ONLY
//! wall-clock values the system ever writes, and they live only here:
//! canonical artifacts never contain them, and nothing ever reads a
//! trace back into a result path. Emission is best-effort — an I/O
//! error drops the record rather than failing the traced computation.
//!
//! Concurrent spans (shard workers, serve workers) interleave in
//! arrival order under the sink mutex; consumers must not assume record
//! order beyond "meta first". Span *identity* is still deterministic
//! ([`SpanId::derive`]), only emission order races.

use std::fs::File;
use std::io::{self, BufWriter, Write as _};
use std::path::Path;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, PoisonError};

use crate::util::json::{self, Value};

use super::registry::MetricsRegistry;
use super::span::{LiveSpan, Span, SpanId};
use super::Stopwatch;

/// Trace schema version, carried in the `meta` record.
pub const TRACE_VERSION: u64 = 1;

/// The raw line-oriented writer behind a [`Tracer`]. Outside `obs::`
/// this type is off-limits (lint rule D7): instrumentation goes through
/// [`Tracer`] spans, which stay inert when tracing is off.
#[derive(Debug)]
pub struct TraceSink {
    w: BufWriter<File>,
}

impl TraceSink {
    /// Open (truncating) the trace file at `path`.
    pub fn open(path: &Path) -> io::Result<TraceSink> {
        if let Some(dir) = path.parent().filter(|d| !d.as_os_str().is_empty()) {
            std::fs::create_dir_all(dir)?;
        }
        Ok(TraceSink { w: BufWriter::new(File::create(path)?) })
    }

    /// Append one record as a single compact JSON line and flush, so a
    /// killed process leaves a readable prefix.
    pub fn emit_record(&mut self, v: &Value) -> io::Result<()> {
        let mut line = json::to_string_compact(v);
        line.push('\n');
        self.w.write_all(line.as_bytes())?;
        self.w.flush()
    }
}

#[derive(Debug)]
struct TracerInner {
    sink: Mutex<TraceSink>,
    /// The trace epoch: all `start_us`/`at_us` values are relative to it.
    epoch: Stopwatch,
    /// Per-trace span sequence; span IDs derive from it.
    seq: AtomicU64,
}

/// The tracing handle. `Clone` is an `Arc` bump; a disabled tracer is a
/// `None` and every operation on it is free.
#[derive(Debug, Clone, Default)]
pub struct Tracer {
    inner: Option<Arc<TracerInner>>,
}

impl Tracer {
    /// The inert tracer: hands out [`Span::noop`]s, emits nothing.
    pub fn disabled() -> Tracer {
        Tracer { inner: None }
    }

    /// An enabled tracer appending to `path`. Writes the `meta` record
    /// immediately; `cmd` names the producing subcommand.
    pub fn to_file(path: &Path, cmd: &str) -> io::Result<Tracer> {
        let mut sink = TraceSink::open(path)?;
        let mut m = std::collections::BTreeMap::new();
        m.insert("type".to_string(), Value::Str("meta".to_string()));
        m.insert("version".to_string(), Value::Num(TRACE_VERSION as f64));
        m.insert("cmd".to_string(), Value::Str(cmd.to_string()));
        sink.emit_record(&Value::Obj(m))?;
        Ok(Tracer {
            inner: Some(Arc::new(TracerInner {
                sink: Mutex::new(sink),
                epoch: Stopwatch::start(),
                seq: AtomicU64::new(0),
            })),
        })
    }

    /// Whether spans from this tracer will be emitted.
    pub fn enabled(&self) -> bool {
        self.inner.is_some()
    }

    /// Start a root span named `name`.
    pub fn span(&self, name: &str) -> Span {
        self.span_started(name, None, Stopwatch::start())
    }

    /// Start a span under `parent`.
    pub fn child(&self, name: &str, parent: SpanId) -> Span {
        self.span_started(name, Some(parent), Stopwatch::start())
    }

    /// Start a span whose clock began at `watch` (e.g. a request's
    /// arrival stopwatch): `start_us` back-dates to when the watch
    /// started, and the eventual `dur_us` is the watch's full reading.
    pub fn span_started(&self, name: &str, parent: Option<SpanId>, watch: Stopwatch) -> Span {
        let Some(inner) = &self.inner else {
            return Span::noop();
        };
        // lint:allow(L2): span-id ticket — the previous value seeds SpanId::derive, saturation would collapse span ids
        let seq = inner.seq.fetch_add(1, Ordering::Relaxed);
        let start_us = inner.epoch.elapsed_us().saturating_sub(watch.elapsed_us());
        Span {
            live: Some(LiveSpan {
                id: SpanId::derive(seq),
                parent,
                name: name.to_string(),
                start_us,
                watch,
                attrs: std::collections::BTreeMap::new(),
            }),
        }
    }

    /// Emit a finished span. A hollow span (disabled tracer) is dropped
    /// silently; so is an I/O error — tracing never fails the traced
    /// computation.
    pub fn finish(&self, span: Span) {
        let (Some(inner), Some(live)) = (&self.inner, span.live) else {
            return;
        };
        let mut m = std::collections::BTreeMap::new();
        m.insert("type".to_string(), Value::Str("span".to_string()));
        m.insert("id".to_string(), Value::Str(live.id.to_hex()));
        let parent = match live.parent {
            Some(p) => Value::Str(p.to_hex()),
            None => Value::Null,
        };
        m.insert("parent".to_string(), parent);
        m.insert("name".to_string(), Value::Str(live.name));
        m.insert("start_us".to_string(), Value::Num(live.start_us as f64));
        m.insert("dur_us".to_string(), Value::Num(live.watch.elapsed_us() as f64));
        m.insert("attrs".to_string(), Value::Obj(live.attrs));
        let mut sink = inner.sink.lock().unwrap_or_else(PoisonError::into_inner);
        let _ = sink.emit_record(&Value::Obj(m));
    }

    /// Emit a `counters` record: a full registry snapshot stamped with
    /// the trace-relative time.
    pub fn counters(&self, registry: &MetricsRegistry) {
        let Some(inner) = &self.inner else {
            return;
        };
        let mut m = std::collections::BTreeMap::new();
        m.insert("type".to_string(), Value::Str("counters".to_string()));
        m.insert("at_us".to_string(), Value::Num(inner.epoch.elapsed_us() as f64));
        m.insert("metrics".to_string(), registry.snapshot());
        let mut sink = inner.sink.lock().unwrap_or_else(PoisonError::into_inner);
        let _ = sink.emit_record(&Value::Obj(m));
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn scratch(tag: &str) -> std::path::PathBuf {
        std::env::temp_dir().join(format!("smart-obs-{tag}-{}.jsonl", std::process::id()))
    }

    #[test]
    fn disabled_tracer_is_fully_inert() {
        let t = Tracer::disabled();
        assert!(!t.enabled());
        let mut s = t.span("campaign");
        assert!(!s.is_live());
        s.attr_u64("items", 3);
        t.finish(s);
        t.counters(&MetricsRegistry::new());
    }

    #[test]
    fn trace_records_are_one_parseable_json_object_per_line() {
        let path = scratch("emit");
        let t = Tracer::to_file(&path, "mc").unwrap();
        let mut root = t.span("campaign");
        root.attr_str("kernel", "block");
        root.attr_u64("items", 256);
        let parent = root.id().unwrap();
        let mut shard = t.child("shard", parent);
        shard.attr_u64("shard", 0);
        t.finish(shard);
        t.finish(root);
        let reg = MetricsRegistry::new();
        reg.counter("kernel_fast_lanes_total").add(12);
        t.counters(&reg);
        drop(t);

        let text = std::fs::read_to_string(&path).unwrap();
        let lines: Vec<&str> = text.lines().collect();
        assert_eq!(lines.len(), 4);
        let records: Vec<Value> =
            lines.iter().map(|l| json::parse(l).expect("every line parses")).collect();
        assert_eq!(records[0].get("type").unwrap().as_str(), Some("meta"));
        assert_eq!(records[0].get("cmd").unwrap().as_str(), Some("mc"));
        assert_eq!(records[0].get("version").unwrap().as_u64(), Some(TRACE_VERSION));
        // child precedes root (finished first); parent links line up
        assert_eq!(records[1].get("name").unwrap().as_str(), Some("shard"));
        assert_eq!(
            records[1].get("parent").unwrap().as_str(),
            Some(parent.to_hex().as_str())
        );
        assert_eq!(records[2].get("name").unwrap().as_str(), Some("campaign"));
        assert_eq!(records[2].get("parent"), Some(&Value::Null));
        assert_eq!(
            records[2].path(&["attrs", "kernel"]).unwrap().as_str(),
            Some("block")
        );
        assert!(records[2].get("dur_us").unwrap().as_u64().is_some());
        assert_eq!(
            records[3].path(&["metrics", "counters", "kernel_fast_lanes_total"]).unwrap().as_u64(),
            Some(12)
        );
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn span_started_backdates_to_the_watch() {
        let path = scratch("backdate");
        let t = Tracer::to_file(&path, "serve").unwrap();
        let watch = Stopwatch::start();
        let s = t.span_started("request", None, watch);
        t.finish(s);
        let text = std::fs::read_to_string(&path).unwrap();
        let rec = json::parse(text.lines().nth(1).unwrap()).unwrap();
        // the span started at (or before) the time it was registered
        let start = rec.get("start_us").unwrap().as_u64().unwrap();
        assert!(start <= Stopwatch::start().elapsed_us().max(1_000_000));
        let _ = std::fs::remove_file(&path);
    }
}
