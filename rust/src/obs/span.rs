//! Tracing spans: named, timed regions with parent links and attributes
//! (DESIGN.md §15).
//!
//! A [`Span`] is created by [`Tracer::span`]/[`Tracer::child`] and
//! emitted by [`Tracer::finish`]. When the tracer is disabled the span
//! is a hollow no-op — no clock read, no allocation beyond the enum
//! tag — which is what lets instrumented code paths run unconditionally
//! without violating inertness (the span observes the computation; the
//! computation never observes the span).
//!
//! Span IDs reuse the counter-RNG discipline that makes campaigns
//! shard-invariant ([`SplitMix64::for_stream`]): the ID of the `n`-th
//! span in a trace is a pure function of `n`, so two traces of the same
//! run (or a re-read of the same trace) agree on identity without any
//! global registry, and IDs are avalanche-mixed rather than sequential
//! so grepping a trace for an ID never aliases a count.
//!
//! [`Tracer::span`]: crate::obs::Tracer::span
//! [`Tracer::child`]: crate::obs::Tracer::child
//! [`Tracer::finish`]: crate::obs::Tracer::finish

use std::collections::BTreeMap;

use crate::montecarlo::SplitMix64;
use crate::util::json::Value;

use super::Stopwatch;

/// Fixed seed of the span-ID stream: IDs depend only on the per-trace
/// sequence number, exactly like per-item RNG streams depend only on
/// `(seed, item)`.
const SPAN_ID_SEED: u64 = 0x534D_4152_545F_4F42; // "SMART_OB"

/// A span identity: 64 avalanche-mixed bits, rendered as 16 hex digits
/// in the trace.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct SpanId(u64);

impl SpanId {
    /// Derive the ID of the `seq`-th span of a trace. Pure in `seq`, so
    /// identity never depends on emission order races.
    pub fn derive(seq: u64) -> SpanId {
        SpanId(SplitMix64::for_stream(SPAN_ID_SEED, seq).next_u64())
    }

    /// The trace rendering: 16 lowercase hex digits.
    pub fn to_hex(self) -> String {
        format!("{:016x}", self.0)
    }

    /// The raw bits (tests and profile cross-linking).
    pub fn raw(self) -> u64 {
        self.0
    }
}

/// The live payload of an enabled span.
#[derive(Debug)]
pub(crate) struct LiveSpan {
    pub(crate) id: SpanId,
    pub(crate) parent: Option<SpanId>,
    pub(crate) name: String,
    /// Microseconds since the tracer's epoch at span start.
    pub(crate) start_us: u64,
    /// Timer the duration is read from at finish.
    pub(crate) watch: Stopwatch,
    pub(crate) attrs: BTreeMap<String, Value>,
}

/// One tracing span. Hollow (every method a no-op) when the creating
/// tracer was disabled, so instrumentation sites need no `if traced`
/// branches of their own.
#[derive(Debug)]
pub struct Span {
    pub(crate) live: Option<LiveSpan>,
}

impl Span {
    /// The hollow span a disabled tracer hands out.
    pub fn noop() -> Span {
        Span { live: None }
    }

    /// Whether this span will actually be emitted.
    pub fn is_live(&self) -> bool {
        self.live.is_some()
    }

    /// This span's ID, if live — the parent link for [`Tracer::child`].
    ///
    /// [`Tracer::child`]: crate::obs::Tracer::child
    pub fn id(&self) -> Option<SpanId> {
        self.live.as_ref().map(|l| l.id)
    }

    /// Attach an integer attribute (item counts, shard indices).
    pub fn attr_u64(&mut self, key: &str, v: u64) {
        if let Some(l) = &mut self.live {
            l.attrs.insert(key.to_string(), Value::Num(v as f64));
        }
    }

    /// Attach a string attribute (kernel names, cache tiers).
    pub fn attr_str(&mut self, key: &str, v: &str) {
        if let Some(l) = &mut self.live {
            l.attrs.insert(key.to_string(), Value::Str(v.to_string()));
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ids_are_pure_in_seq_and_distinct() {
        assert_eq!(SpanId::derive(7), SpanId::derive(7));
        assert_ne!(SpanId::derive(7), SpanId::derive(8));
        assert_eq!(SpanId::derive(3).to_hex().len(), 16);
        // avalanche: sequential seqs do not produce sequential ids
        let d = SpanId::derive(1).raw().wrapping_sub(SpanId::derive(0).raw());
        assert_ne!(d, 1);
    }

    #[test]
    fn noop_spans_swallow_everything() {
        let mut s = Span::noop();
        assert!(!s.is_live());
        assert!(s.id().is_none());
        s.attr_u64("items", 5);
        s.attr_str("kernel", "fast");
        assert!(s.live.is_none());
    }
}
