//! ODE integrators for the single-node discharge equation dV/dt = f(V).

/// Integration scheme.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Method {
    /// Forward Euler with state clamped at 0 V — EXACTLY the scheme the
    /// AOT-compiled Pallas kernel uses, so native and HLO paths agree to
    /// f32 rounding.
    Euler,
    /// Classic RK4 (used to bound the Euler discretization error).
    Rk4,
}

/// Integrate `dv/dt = f(v)` from `v0` over `n_steps` of `dt`, clamping the
/// state at 0 (the bitline cannot undershoot ground).
pub fn integrate_fixed(
    v0: f64,
    dt: f64,
    n_steps: u32,
    method: Method,
    f: impl Fn(f64) -> f64,
) -> f64 {
    let mut v = v0;
    for _ in 0..n_steps {
        v = step(v, dt, method, &f);
    }
    v
}

#[inline]
fn step(v: f64, dt: f64, method: Method, f: &impl Fn(f64) -> f64) -> f64 {
    let next = match method {
        Method::Euler => v + dt * f(v),
        Method::Rk4 => {
            let k1 = f(v);
            let k2 = f((v + 0.5 * dt * k1).max(0.0));
            let k3 = f((v + 0.5 * dt * k2).max(0.0));
            let k4 = f((v + dt * k3).max(0.0));
            v + dt / 6.0 * (k1 + 2.0 * k2 + 2.0 * k3 + k4)
        }
    };
    next.max(0.0)
}

/// Step-doubling adaptive RK4: integrate to `t_end` keeping the local
/// error per step under `tol` volts. Returns `(v_end, steps_taken)`.
pub fn integrate_adaptive(
    v0: f64,
    t_end: f64,
    tol: f64,
    f: impl Fn(f64) -> f64,
) -> (f64, u32) {
    let mut v = v0;
    let mut t = 0.0;
    let mut dt = t_end / 64.0;
    let mut steps = 0u32;
    while t < t_end {
        if t + dt > t_end {
            dt = t_end - t;
        }
        let full = step(v, dt, Method::Rk4, &f);
        let half = step(step(v, dt * 0.5, Method::Rk4, &f), dt * 0.5, Method::Rk4, &f);
        let err = (full - half).abs();
        if err <= tol || dt <= t_end * 1e-6 {
            v = half;
            t += dt; // lint:allow(D2): adaptive ODE time stepping is inherently sequential
            steps += 1;
            if err < tol * 0.25 {
                dt *= 1.5;
            }
        } else {
            dt *= 0.5;
        }
    }
    (v, steps)
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Linear RC discharge: dv/dt = -v/tau has the closed form v0*exp(-t/tau).
    fn rc(v: f64) -> f64 {
        -v / 1e-9
    }

    #[test]
    fn euler_converges_to_exponential() {
        let v = integrate_fixed(1.0, 1e-9 / 4096.0, 4096, Method::Euler, rc);
        assert!((v - (-1.0f64).exp()).abs() < 1e-3);
    }

    #[test]
    fn rk4_much_tighter_than_euler() {
        let exact = (-1.0f64).exp();
        let e = integrate_fixed(1.0, 1e-9 / 64.0, 64, Method::Euler, rc);
        let r = integrate_fixed(1.0, 1e-9 / 64.0, 64, Method::Rk4, rc);
        assert!((r - exact).abs() < (e - exact).abs() / 100.0);
    }

    #[test]
    fn state_clamps_at_zero() {
        let v = integrate_fixed(0.1, 1e-9, 100, Method::Euler, |_| -1e12);
        assert_eq!(v, 0.0);
    }

    #[test]
    fn adaptive_matches_fixed_rk4() {
        let (va, steps) = integrate_adaptive(1.0, 1e-9, 1e-9, rc);
        let vf = integrate_fixed(1.0, 1e-9 / 1024.0, 1024, Method::Rk4, rc);
        assert!((va - vf).abs() < 1e-6, "adaptive={va} fixed={vf}");
        assert!(steps < 1024, "adaptive should need far fewer steps");
    }

    #[test]
    fn zero_steps_is_identity() {
        assert_eq!(integrate_fixed(0.7, 1e-12, 0, Method::Euler, rc), 0.7);
    }
}
