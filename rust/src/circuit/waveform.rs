//! Time-series container for transient traces (Fig. 5/6).

/// A sampled waveform: strictly increasing time points with values.
#[derive(Debug, Clone, Default)]
pub struct Waveform {
    t: Vec<f64>,
    v: Vec<f64>,
}

impl Waveform {
    /// Empty waveform with room for `n` samples.
    pub fn with_capacity(n: usize) -> Self {
        Self { t: Vec::with_capacity(n), v: Vec::with_capacity(n) }
    }

    /// Append a sample; `t` must be strictly after the previous sample.
    pub fn push(&mut self, t: f64, v: f64) {
        debug_assert!(self.t.last().is_none_or(|&last| t > last));
        self.t.push(t);
        self.v.push(v);
    }

    /// Number of samples.
    pub fn len(&self) -> usize {
        self.t.len()
    }

    /// True when no samples have been appended.
    pub fn is_empty(&self) -> bool {
        self.t.is_empty()
    }

    /// The time points (strictly increasing).
    pub fn times(&self) -> &[f64] {
        &self.t
    }

    /// The sampled values, parallel to [`Self::times`].
    pub fn values(&self) -> &[f64] {
        &self.v
    }

    /// Iterate `(t, v)` pairs in time order.
    pub fn iter(&self) -> impl Iterator<Item = (f64, f64)> + '_ {
        self.t.iter().copied().zip(self.v.iter().copied())
    }

    /// Linear interpolation at time `t` (clamped to the waveform's span).
    pub fn sample(&self, t: f64) -> f64 {
        assert!(!self.is_empty());
        if t <= self.t[0] {
            return self.v[0];
        }
        // lint:allow(D4): non-emptiness is asserted at entry — last() is always Some
        if t >= *self.t.last().unwrap() {
            // lint:allow(D4): non-emptiness is asserted at entry — last() is always Some
            return *self.v.last().unwrap();
        }
        let idx = self.t.partition_point(|&x| x < t);
        let (t0, t1) = (self.t[idx - 1], self.t[idx]);
        let (v0, v1) = (self.v[idx - 1], self.v[idx]);
        v0 + (v1 - v0) * (t - t0) / (t1 - t0)
    }

    /// First time the waveform crosses below `level`, by linear
    /// interpolation; `None` if it never does.
    pub fn crossing_time(&self, level: f64) -> Option<f64> {
        for i in 1..self.len() {
            if self.v[i - 1] >= level && self.v[i] < level {
                let frac = (self.v[i - 1] - level) / (self.v[i - 1] - self.v[i]);
                return Some(self.t[i - 1] + frac * (self.t[i] - self.t[i - 1]));
            }
        }
        None
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ramp() -> Waveform {
        let mut w = Waveform::default();
        for k in 0..=10 {
            w.push(k as f64, 1.0 - 0.1 * k as f64);
        }
        w
    }

    #[test]
    fn sample_interpolates() {
        let w = ramp();
        assert!((w.sample(2.5) - 0.75).abs() < 1e-12);
        assert_eq!(w.sample(-1.0), 1.0);
        assert!((w.sample(99.0) - 0.0).abs() < 1e-12);
    }

    #[test]
    fn crossing_time_interpolates() {
        let w = ramp();
        let t = w.crossing_time(0.55).unwrap();
        assert!((t - 4.5).abs() < 1e-12);
        assert_eq!(w.crossing_time(-0.5), None);
    }

    #[test]
    fn iter_matches_push_order() {
        let w = ramp();
        assert_eq!(w.len(), 11);
        let first = w.iter().next().unwrap();
        assert_eq!(first, (0.0, 1.0));
    }
}
