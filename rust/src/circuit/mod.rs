//! Transient circuit simulation substrate.
//!
//! The paper's evaluation rides on one ODE — the bitline discharge of
//! Eq. 1/3: `C_blb * dV/dt = -I_D(V)`. This module provides the
//! integrators (forward Euler matching the AOT kernel step-for-step, plus
//! RK4 and an adaptive-step integrator for convergence checks), the
//! bitline discharge driver, and a waveform container for the Fig. 5/6
//! traces.

mod bitline;
mod integrator;
mod waveform;

pub use bitline::{
    discharge, discharge_block, discharge_lane, discharge_trace, discharge_word, BitlineInputs,
};
pub use integrator::{integrate_adaptive, integrate_fixed, Method};
pub use waveform::Waveform;
