//! BLB discharge driver: the native-Rust twin of the Pallas kernel.

use super::integrator::{integrate_fixed, Method};
use super::waveform::Waveform;
use crate::device::Mosfet;
use crate::params::{DeviceCard, Params};

/// Bias/state inputs for one cell's discharge transient.
#[derive(Debug, Clone, Copy)]
pub struct BitlineInputs {
    /// Word-line (gate) voltage from the DAC (V).
    pub v_wl: f64,
    /// Stored bit: `true` opens the M2acc->M3 path (Q = VDD, Qbar = 0).
    pub bit: bool,
    /// Forward body bias on the access transistor (V).
    pub v_bulk: f64,
}

/// Integrate one cell's BLB discharge for `t_total` seconds in `n_steps`
/// forward-Euler steps (the AOT kernel's scheme) and return V_BLB(t_total).
///
/// Hot path of the native oracle: all time-invariant device quantities
/// (overdrive, effective beta, leak gate) are hoisted out of the loop and
/// the strong-inversion branch is inlined — bit-identical to
/// [`Mosfet::drain_current_vov`], ~6x faster than the closure-per-step
/// form (§Perf).
pub fn discharge(p: &Params, dev: &Mosfet, inp: &BitlineInputs, t_total: f64, n_steps: u32) -> f64 {
    let dt = t_total / n_steps as f64;
    let vov = inp.v_wl - dev.vth(inp.v_bulk);
    let gate = if inp.bit { 1.0 } else { dev.card.k_leak };
    let c = p.circuit.c_blb;
    let card = &dev.card;
    let beta = dev.beta();
    let vt = card.vt_thermal;
    let lam = card.lam;
    let dt_c = dt / c;
    let mut v = card.vdd;
    if vov >= 3.0 * vt {
        // strong inversion: square law only (see drain_current_vov proof)
        let half_bv2 = 0.5 * beta * vov * vov;
        for _ in 0..n_steps {
            let clm = 1.0 + lam * v;
            let i = if v >= vov { half_bv2 * clm } else { beta * (vov - 0.5 * v) * v * clm };
            v = (v - i.max(0.0) * gate * dt_c).max(0.0);
        }
    } else {
        for _ in 0..n_steps {
            v = (v - dev.drain_current_vov(vov, v) * gate * dt_c).max(0.0);
        }
    }
    v
}

/// Integrate a whole 4-cell word in one interleaved loop.
///
/// The per-cell recurrences are independent, so stepping all four lanes
/// per iteration hides the serial FP latency chain that bounds
/// [`discharge`] (~2x on this host, §Perf). Falls back to the scalar path
/// unless every lane is in strong inversion (vov >= 3*vt), where the
/// square-law-only loop applies; per-lane arithmetic order matches
/// [`discharge`] exactly, so results are bit-identical.
pub fn discharge_word(
    p: &Params,
    devs: &[Mosfet; 4],
    inps: &[BitlineInputs; 4],
    t_total: f64,
    n_steps: u32,
) -> [f64; 4] {
    let vt = devs[0].card.vt_thermal;
    let mut vov = [0.0f64; 4];
    let mut beta = [0.0f64; 4];
    let mut gate = [0.0f64; 4];
    for k in 0..4 {
        vov[k] = inps[k].v_wl - devs[k].vth(inps[k].v_bulk);
        beta[k] = devs[k].beta();
        gate[k] = if inps[k].bit { 1.0 } else { devs[k].card.k_leak };
    }
    if vov.iter().any(|&x| x < 3.0 * vt) {
        // mixed-region word: scalar per-cell path (exp-bearing lanes)
        let mut out = [0.0f64; 4];
        for k in 0..4 {
            out[k] = discharge(p, &devs[k], &inps[k], t_total, n_steps);
        }
        return out;
    }
    let dt_c = (t_total / n_steps as f64) / p.circuit.c_blb;
    let lam = devs[0].card.lam;
    let mut half_bv2 = [0.0f64; 4];
    for k in 0..4 {
        half_bv2[k] = 0.5 * beta[k] * vov[k] * vov[k];
    }
    let mut v = [devs[0].card.vdd; 4];
    for _ in 0..n_steps {
        for k in 0..4 {
            let clm = 1.0 + lam * v[k];
            let i = if v[k] >= vov[k] {
                half_bv2[k] * clm
            } else {
                beta[k] * (vov[k] - 0.5 * v[k]) * v[k] * clm
            };
            v[k] = (v[k] - i.max(0.0) * gate[k] * dt_c).max(0.0);
        }
    }
    v
}

/// Integrate an arbitrary number of independent cell lanes in lockstep —
/// the block-execution hot path (DESIGN.md §9).
///
/// Inputs are per-lane time-invariant device quantities, hoisted once by
/// the caller: overdrive `vov[k]`, effective beta `beta[k]` (as
/// [`Mosfet::beta`] returns it) and the conduction gate `gate[k]` (1 for a
/// stored 1, `k_leak` for a stored 0). Lanes in strong inversion
/// (`vov >= 3*vt`) are stepped together — steps outer, lanes inner, no
/// branches in the inner loop beyond the saturation/triode select — so
/// the compiler can auto-vectorize across lanes; when every lane is
/// strong (the campaign-dominant case) integration happens in place on
/// the caller's buffers and allocates nothing, otherwise the strong
/// lanes are packed densely first. The exp-bearing weak/cutoff lanes
/// integrate one lane at a time through a verbatim replica of
/// [`Mosfet::drain_current_vov`] below the strong-inversion cut.
///
/// Determinism contract: every lane's recurrence reads only that lane's
/// state, and the per-step expression tree is grouped exactly as in
/// [`discharge`] / [`discharge_word`], so each lane's endpoint is
/// bit-identical to the scalar oracle no matter how lanes are packed or
/// how many share a block (property-tested in `tests/block_kernel.rs`).
pub fn discharge_block(
    p: &Params,
    vov: &[f64],
    beta: &[f64],
    gate: &[f64],
    t_total: f64,
    n_steps: u32,
    v_out: &mut [f64],
) {
    let n = vov.len();
    assert!(
        beta.len() == n && gate.len() == n && v_out.len() == n,
        "lane buffers must be the same length"
    );
    let card = &p.device;
    let vt = card.vt_thermal;
    let lam = card.lam;
    let dt_c = (t_total / n_steps as f64) / p.circuit.c_blb;

    // Fast path: every lane in strong inversion (the campaign-dominant
    // case — all DAC codes well above threshold). Integrates in place on
    // the caller's buffers, so the hot path allocates nothing. The inline
    // product chain groups exactly like `discharge`'s hoisted
    // `half_bv2 * clm`, so endpoints stay bit-identical.
    if vov.iter().all(|&x| x >= 3.0 * vt) {
        v_out.fill(card.vdd);
        for _ in 0..n_steps {
            for k in 0..n {
                let v = v_out[k];
                let clm = 1.0 + lam * v;
                let i = if v >= vov[k] {
                    0.5 * beta[k] * vov[k] * vov[k] * clm
                } else {
                    beta[k] * (vov[k] - 0.5 * v) * v * clm
                };
                v_out[k] = (v - i.max(0.0) * gate[k] * dt_c).max(0.0);
            }
        }
        return;
    }

    // Mixed block: weak/cutoff lanes integrate per lane with the exp
    // model; the remaining strong lanes are packed densely for the
    // lockstep loop (packing allocates, but only on mixed blocks —
    // low DAC codes — where the exp lanes dominate the cost anyway).
    let mut idx: Vec<usize> = Vec::with_capacity(n);
    for k in 0..n {
        if vov[k] >= 3.0 * vt {
            idx.push(k);
        } else {
            v_out[k] = discharge_lane_weak(card, vov[k], beta[k], gate[k], dt_c, n_steps);
        }
    }
    let m = idx.len();
    let mut pv = vec![card.vdd; m];
    let mut pvov = Vec::with_capacity(m);
    let mut pbeta = Vec::with_capacity(m);
    let mut pgate = Vec::with_capacity(m);
    let mut phalf = Vec::with_capacity(m);
    for &k in &idx {
        pvov.push(vov[k]);
        pbeta.push(beta[k]);
        pgate.push(gate[k]);
        // same grouping as `discharge`'s hoisted half_bv2
        phalf.push(0.5 * beta[k] * vov[k] * vov[k]);
    }
    for _ in 0..n_steps {
        for j in 0..m {
            let v = pv[j];
            let clm = 1.0 + lam * v;
            let i = if v >= pvov[j] {
                phalf[j] * clm
            } else {
                pbeta[j] * (pvov[j] - 0.5 * v) * v * clm
            };
            pv[j] = (v - i.max(0.0) * pgate[j] * dt_c).max(0.0);
        }
    }
    for (j, &k) in idx.iter().enumerate() {
        v_out[k] = pv[j];
    }
}

/// Integrate ONE hoisted cell lane — the entry point the fast surrogate
/// kernel uses for its exact fallback and for building its endpoint
/// tables (DESIGN.md §13).
///
/// Takes the same hoisted time-invariant quantities as one lane of
/// [`discharge_block`] (overdrive `vov`, effective beta as
/// [`Mosfet::beta`] returns it, conduction gate) and steps the identical
/// Euler recurrence with the identical expression grouping, so the
/// endpoint is bit-identical to that lane's treatment inside
/// [`discharge`] / [`discharge_block`].
pub fn discharge_lane(
    p: &Params,
    vov: f64,
    beta: f64,
    gate: f64,
    t_total: f64,
    n_steps: u32,
) -> f64 {
    let card = &p.device;
    let dt_c = (t_total / n_steps as f64) / p.circuit.c_blb;
    if vov >= 3.0 * card.vt_thermal {
        // strong inversion: square law only (see drain_current_vov proof)
        let lam = card.lam;
        let half_bv2 = 0.5 * beta * vov * vov;
        let mut v = card.vdd;
        for _ in 0..n_steps {
            let clm = 1.0 + lam * v;
            let i = if v >= vov { half_bv2 * clm } else { beta * (vov - 0.5 * v) * v * clm };
            v = (v - i.max(0.0) * gate * dt_c).max(0.0);
        }
        v
    } else {
        discharge_lane_weak(card, vov, beta, gate, dt_c, n_steps)
    }
}

/// One weak/cutoff lane: the Euler recurrence of [`discharge`]'s
/// non-hoisted branch, with the current expression replicated term for
/// term from [`Mosfet::drain_current_vov`] below the `3*vt` cut (the
/// hoisted `beta` equals `Mosfet::beta()` exactly, so the endpoints are
/// bit-identical).
#[inline]
fn discharge_lane_weak(
    card: &DeviceCard,
    vov: f64,
    beta: f64,
    gate: f64,
    dt_c: f64,
    n_steps: u32,
) -> f64 {
    let vt = card.vt_thermal;
    let lam = card.lam;
    let mut v = card.vdd;
    for _ in 0..n_steps {
        let i_sub = beta * vt * vt * (vov.min(0.0) / (card.n_sub * vt)).exp()
            * (1.0 - (-v.max(0.0) / vt).exp());
        let i = if vov > 0.0 {
            let clm = 1.0 + lam * v;
            let i_on = if v >= vov {
                0.5 * beta * vov * vov * clm
            } else {
                beta * (vov - 0.5 * v) * v * clm
            };
            i_on.max(0.0).max(i_sub)
        } else {
            i_sub
        };
        v = (v - i * gate * dt_c).max(0.0);
    }
    v
}

/// Same transient, but record the waveform at every `stride` steps
/// (Fig. 5/6). The final sample equals [`discharge`]'s return value.
pub fn discharge_trace(
    p: &Params,
    dev: &Mosfet,
    inp: &BitlineInputs,
    t_total: f64,
    n_steps: u32,
    stride: u32,
) -> Waveform {
    assert!(stride > 0 && n_steps % stride == 0, "stride must divide n_steps");
    let dt = t_total / n_steps as f64;
    let vov = inp.v_wl - dev.vth(inp.v_bulk);
    let gate = if inp.bit { 1.0 } else { dev.card.k_leak };
    // same term grouping as `discharge` so the endpoint is bit-identical
    let dt_c = dt / p.circuit.c_blb;

    let mut wf = Waveform::with_capacity((n_steps / stride) as usize + 1);
    let mut v = dev.card.vdd;
    wf.push(0.0, v);
    for k in 1..=n_steps {
        v = (v - dev.drain_current_vov(vov, v) * gate * dt_c).max(0.0);
        if k % stride == 0 {
            wf.push(k as f64 * dt, v);
        }
    }
    wf
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::params::Params;

    fn setup() -> (Params, Mosfet) {
        let p = Params::default();
        (p, Mosfet::nominal(p.device))
    }

    fn inputs(v_wl: f64, bit: bool, v_bulk: f64) -> BitlineInputs {
        BitlineInputs { v_wl, bit, v_bulk }
    }

    #[test]
    fn stored_zero_barely_discharges() {
        let (p, dev) = setup();
        let v = discharge(&p, &dev, &inputs(0.7, false, 0.0), p.circuit.t_sample, 256);
        assert!(v > p.device.vdd - 1e-3);
    }

    #[test]
    fn stored_one_discharges() {
        let (p, dev) = setup();
        let v = discharge(&p, &dev, &inputs(0.7, true, 0.0), p.circuit.t_sample, 256);
        assert!(v < p.device.vdd - 0.1);
    }

    #[test]
    fn body_bias_accelerates_discharge() {
        let (p, dev) = setup();
        let base = discharge(&p, &dev, &inputs(0.55, true, 0.0), p.circuit.t_sample, 256);
        let smart = discharge(&p, &dev, &inputs(0.55, true, 0.6), p.circuit.t_sample, 256);
        assert!(smart < base - 0.02, "base={base} smart={smart}");
    }

    #[test]
    fn trace_endpoint_matches_single_shot() {
        let (p, dev) = setup();
        let inp = inputs(0.6, true, 0.3);
        let wf = discharge_trace(&p, &dev, &inp, p.circuit.t_sample, 256, 8);
        let end = discharge(&p, &dev, &inp, p.circuit.t_sample, 256);
        assert!((wf.values().last().unwrap() - end).abs() < 1e-12);
        assert_eq!(wf.len(), 33); // t=0 plus 256/8 samples
    }

    #[test]
    fn trace_monotone_nonincreasing() {
        let (p, dev) = setup();
        let wf = discharge_trace(&p, &dev, &inputs(0.65, true, 0.0), 1e-9, 512, 4);
        for w in wf.values().windows(2) {
            assert!(w[1] <= w[0] + 1e-15);
        }
    }

    #[test]
    fn block_matches_scalar_lane_for_lane() {
        // Mixed strong/weak/cutoff/leakage lanes in one block: every lane's
        // endpoint must be bit-identical to the scalar `discharge` path.
        let p = Params::default();
        let card = p.device;
        let cases: [(f64, bool, f64, f64, f64); 6] = [
            // (v_wl, bit, v_bulk, dvth, dbeta)
            (0.70, true, 0.6, 0.0, 0.0),    // strong
            (0.70, true, 0.0, 2e-3, 0.01),  // strong, mismatched
            (0.33, true, 0.0, 0.0, 0.0),    // weak inversion
            (0.10, true, 0.0, -1e-3, 0.0),  // cutoff
            (0.70, false, 0.6, 0.0, -0.02), // leakage gate
            (0.00, true, 0.0, 0.0, 0.0),    // grounded WL
        ];
        let mut vov = Vec::new();
        let mut beta = Vec::new();
        let mut gate = Vec::new();
        let mut want = Vec::new();
        for &(v_wl, bit, v_bulk, dvth, dbeta) in &cases {
            let dev = Mosfet::with_mismatch(card, dvth, dbeta);
            vov.push(v_wl - dev.vth(v_bulk));
            beta.push(dev.beta());
            gate.push(if bit { 1.0 } else { dev.card.k_leak });
            want.push(discharge(
                &p,
                &dev,
                &inputs(v_wl, bit, v_bulk),
                p.circuit.t_sample,
                p.circuit.n_steps,
            ));
        }
        let mut got = vec![0.0; cases.len()];
        discharge_block(
            &p,
            &vov,
            &beta,
            &gate,
            p.circuit.t_sample,
            p.circuit.n_steps,
            &mut got,
        );
        for (k, (g, w)) in got.iter().zip(&want).enumerate() {
            assert_eq!(g.to_bits(), w.to_bits(), "lane {k}: {g} != {w}");
        }
    }

    #[test]
    fn lane_matches_scalar_discharge_bit_for_bit() {
        // `discharge_lane` is the fast kernel's exact fallback: for every
        // operating region it must reproduce the scalar `discharge` path
        // (and therefore the block path) bit for bit.
        let p = Params::default();
        let card = p.device;
        let cases: [(f64, bool, f64, f64, f64); 6] = [
            (0.70, true, 0.6, 0.0, 0.0),    // strong
            (0.70, true, 0.0, 2e-3, 0.01),  // strong, mismatched
            (0.33, true, 0.0, 0.0, 0.0),    // weak inversion
            (0.10, true, 0.0, -1e-3, 0.0),  // cutoff
            (0.70, false, 0.6, 0.0, -0.02), // leakage gate
            (0.00, true, 0.0, 0.0, 0.0),    // grounded WL
        ];
        for &(v_wl, bit, v_bulk, dvth, dbeta) in &cases {
            let dev = Mosfet::with_mismatch(card, dvth, dbeta);
            let vov = v_wl - dev.vth(v_bulk);
            let gate = if bit { 1.0 } else { dev.card.k_leak };
            let want = discharge(
                &p,
                &dev,
                &inputs(v_wl, bit, v_bulk),
                p.circuit.t_sample,
                p.circuit.n_steps,
            );
            let got = discharge_lane(
                &p,
                vov,
                dev.beta(),
                gate,
                p.circuit.t_sample,
                p.circuit.n_steps,
            );
            assert_eq!(got.to_bits(), want.to_bits(), "v_wl={v_wl}: {got} != {want}");
        }
    }

    #[test]
    fn block_is_lane_order_free() {
        // permuting lanes permutes outputs and nothing else
        let p = Params::default();
        let card = p.device;
        let dev = Mosfet::nominal(card);
        let v_wls = [0.7, 0.55, 0.33, 0.62];
        let mk = |order: &[usize]| {
            let vov: Vec<f64> = order.iter().map(|&i| v_wls[i] - dev.vth(0.0)).collect();
            let beta = vec![dev.beta(); 4];
            let gate = vec![1.0; 4];
            let mut out = vec![0.0; 4];
            discharge_block(&p, &vov, &beta, &gate, p.circuit.t_sample, 128, &mut out);
            out
        };
        let fwd = mk(&[0, 1, 2, 3]);
        let rev = mk(&[3, 2, 1, 0]);
        for k in 0..4 {
            assert_eq!(fwd[k].to_bits(), rev[3 - k].to_bits(), "lane {k}");
        }
    }

    #[test]
    fn block_handles_empty_lane_set() {
        let p = Params::default();
        discharge_block(&p, &[], &[], &[], p.circuit.t_sample, 16, &mut []);
    }

    #[test]
    fn euler_discretization_error_is_bounded() {
        // The fixed-step Euler at n_steps=256 must sit within 2 mV of a
        // tight adaptive-RK4 run — validates the AOT kernel's step count.
        use crate::circuit::integrator::integrate_adaptive;
        let (p, dev) = setup();
        let inp = inputs(0.7, true, 0.6); // fastest discharge = worst case
        let vov = inp.v_wl - dev.vth(inp.v_bulk);
        let c = p.circuit.c_blb;
        let f = |v: f64| -dev.drain_current_vov(vov, v) / c;
        let euler = discharge(&p, &dev, &inp, p.circuit.t_sample, p.circuit.n_steps);
        let (exact, _) = integrate_adaptive(p.device.vdd, p.circuit.t_sample, 1e-7, f);
        assert!(
            (euler - exact).abs() < 2e-3,
            "euler={euler} adaptive={exact}"
        );
    }
}
