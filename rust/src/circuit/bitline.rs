//! BLB discharge driver: the native-Rust twin of the Pallas kernel.

use super::integrator::{integrate_fixed, Method};
use super::waveform::Waveform;
use crate::device::Mosfet;
use crate::params::Params;

/// Bias/state inputs for one cell's discharge transient.
#[derive(Debug, Clone, Copy)]
pub struct BitlineInputs {
    /// Word-line (gate) voltage from the DAC (V).
    pub v_wl: f64,
    /// Stored bit: `true` opens the M2acc->M3 path (Q = VDD, Qbar = 0).
    pub bit: bool,
    /// Forward body bias on the access transistor (V).
    pub v_bulk: f64,
}

/// Integrate one cell's BLB discharge for `t_total` seconds in `n_steps`
/// forward-Euler steps (the AOT kernel's scheme) and return V_BLB(t_total).
///
/// Hot path of the native oracle: all time-invariant device quantities
/// (overdrive, effective beta, leak gate) are hoisted out of the loop and
/// the strong-inversion branch is inlined — bit-identical to
/// [`Mosfet::drain_current_vov`], ~6x faster than the closure-per-step
/// form (§Perf).
pub fn discharge(p: &Params, dev: &Mosfet, inp: &BitlineInputs, t_total: f64, n_steps: u32) -> f64 {
    let dt = t_total / n_steps as f64;
    let vov = inp.v_wl - dev.vth(inp.v_bulk);
    let gate = if inp.bit { 1.0 } else { dev.card.k_leak };
    let c = p.circuit.c_blb;
    let card = &dev.card;
    let beta = dev.beta();
    let vt = card.vt_thermal;
    let lam = card.lam;
    let dt_c = dt / c;
    let mut v = card.vdd;
    if vov >= 3.0 * vt {
        // strong inversion: square law only (see drain_current_vov proof)
        let half_bv2 = 0.5 * beta * vov * vov;
        for _ in 0..n_steps {
            let clm = 1.0 + lam * v;
            let i = if v >= vov { half_bv2 * clm } else { beta * (vov - 0.5 * v) * v * clm };
            v = (v - i.max(0.0) * gate * dt_c).max(0.0);
        }
    } else {
        for _ in 0..n_steps {
            v = (v - dev.drain_current_vov(vov, v) * gate * dt_c).max(0.0);
        }
    }
    v
}

/// Integrate a whole 4-cell word in one interleaved loop.
///
/// The per-cell recurrences are independent, so stepping all four lanes
/// per iteration hides the serial FP latency chain that bounds
/// [`discharge`] (~2x on this host, §Perf). Falls back to the scalar path
/// unless every lane is in strong inversion (vov >= 3*vt), where the
/// square-law-only loop applies; per-lane arithmetic order matches
/// [`discharge`] exactly, so results are bit-identical.
pub fn discharge_word(
    p: &Params,
    devs: &[Mosfet; 4],
    inps: &[BitlineInputs; 4],
    t_total: f64,
    n_steps: u32,
) -> [f64; 4] {
    let vt = devs[0].card.vt_thermal;
    let mut vov = [0.0f64; 4];
    let mut beta = [0.0f64; 4];
    let mut gate = [0.0f64; 4];
    for k in 0..4 {
        vov[k] = inps[k].v_wl - devs[k].vth(inps[k].v_bulk);
        beta[k] = devs[k].beta();
        gate[k] = if inps[k].bit { 1.0 } else { devs[k].card.k_leak };
    }
    if vov.iter().any(|&x| x < 3.0 * vt) {
        // mixed-region word: scalar per-cell path (exp-bearing lanes)
        let mut out = [0.0f64; 4];
        for k in 0..4 {
            out[k] = discharge(p, &devs[k], &inps[k], t_total, n_steps);
        }
        return out;
    }
    let dt_c = (t_total / n_steps as f64) / p.circuit.c_blb;
    let lam = devs[0].card.lam;
    let mut half_bv2 = [0.0f64; 4];
    for k in 0..4 {
        half_bv2[k] = 0.5 * beta[k] * vov[k] * vov[k];
    }
    let mut v = [devs[0].card.vdd; 4];
    for _ in 0..n_steps {
        for k in 0..4 {
            let clm = 1.0 + lam * v[k];
            let i = if v[k] >= vov[k] {
                half_bv2[k] * clm
            } else {
                beta[k] * (vov[k] - 0.5 * v[k]) * v[k] * clm
            };
            v[k] = (v[k] - i.max(0.0) * gate[k] * dt_c).max(0.0);
        }
    }
    v
}

/// Same transient, but record the waveform at every `stride` steps
/// (Fig. 5/6). The final sample equals [`discharge`]'s return value.
pub fn discharge_trace(
    p: &Params,
    dev: &Mosfet,
    inp: &BitlineInputs,
    t_total: f64,
    n_steps: u32,
    stride: u32,
) -> Waveform {
    assert!(stride > 0 && n_steps % stride == 0, "stride must divide n_steps");
    let dt = t_total / n_steps as f64;
    let vov = inp.v_wl - dev.vth(inp.v_bulk);
    let gate = if inp.bit { 1.0 } else { dev.card.k_leak };
    // same term grouping as `discharge` so the endpoint is bit-identical
    let dt_c = dt / p.circuit.c_blb;

    let mut wf = Waveform::with_capacity((n_steps / stride) as usize + 1);
    let mut v = dev.card.vdd;
    wf.push(0.0, v);
    for k in 1..=n_steps {
        v = (v - dev.drain_current_vov(vov, v) * gate * dt_c).max(0.0);
        if k % stride == 0 {
            wf.push(k as f64 * dt, v);
        }
    }
    wf
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::params::Params;

    fn setup() -> (Params, Mosfet) {
        let p = Params::default();
        (p, Mosfet::nominal(p.device))
    }

    fn inputs(v_wl: f64, bit: bool, v_bulk: f64) -> BitlineInputs {
        BitlineInputs { v_wl, bit, v_bulk }
    }

    #[test]
    fn stored_zero_barely_discharges() {
        let (p, dev) = setup();
        let v = discharge(&p, &dev, &inputs(0.7, false, 0.0), p.circuit.t_sample, 256);
        assert!(v > p.device.vdd - 1e-3);
    }

    #[test]
    fn stored_one_discharges() {
        let (p, dev) = setup();
        let v = discharge(&p, &dev, &inputs(0.7, true, 0.0), p.circuit.t_sample, 256);
        assert!(v < p.device.vdd - 0.1);
    }

    #[test]
    fn body_bias_accelerates_discharge() {
        let (p, dev) = setup();
        let base = discharge(&p, &dev, &inputs(0.55, true, 0.0), p.circuit.t_sample, 256);
        let smart = discharge(&p, &dev, &inputs(0.55, true, 0.6), p.circuit.t_sample, 256);
        assert!(smart < base - 0.02, "base={base} smart={smart}");
    }

    #[test]
    fn trace_endpoint_matches_single_shot() {
        let (p, dev) = setup();
        let inp = inputs(0.6, true, 0.3);
        let wf = discharge_trace(&p, &dev, &inp, p.circuit.t_sample, 256, 8);
        let end = discharge(&p, &dev, &inp, p.circuit.t_sample, 256);
        assert!((wf.values().last().unwrap() - end).abs() < 1e-12);
        assert_eq!(wf.len(), 33); // t=0 plus 256/8 samples
    }

    #[test]
    fn trace_monotone_nonincreasing() {
        let (p, dev) = setup();
        let wf = discharge_trace(&p, &dev, &inputs(0.65, true, 0.0), 1e-9, 512, 4);
        for w in wf.values().windows(2) {
            assert!(w[1] <= w[0] + 1e-15);
        }
    }

    #[test]
    fn euler_discretization_error_is_bounded() {
        // The fixed-step Euler at n_steps=256 must sit within 2 mV of a
        // tight adaptive-RK4 run — validates the AOT kernel's step count.
        use crate::circuit::integrator::integrate_adaptive;
        let (p, dev) = setup();
        let inp = inputs(0.7, true, 0.6); // fastest discharge = worst case
        let vov = inp.v_wl - dev.vth(inp.v_bulk);
        let c = p.circuit.c_blb;
        let f = |v: f64| -dev.drain_current_vov(vov, v) / c;
        let euler = discharge(&p, &dev, &inp, p.circuit.t_sample, p.circuit.n_steps);
        let (exact, _) = integrate_adaptive(p.device.vdd, p.circuit.t_sample, 1e-7, f);
        assert!(
            (euler - exact).abs() < 2e-3,
            "euler={euler} adaptive={exact}"
        );
    }
}
