//! Lint configuration: scan roots and the file-level allowlist
//! (`configs/lint.toml`).
//!
//! Inline pragmas suppress single findings; the allowlist suppresses a
//! whole `(rule, file)` pair — the right tool when a file's *job* makes
//! a rule inapplicable (e.g. `bench/` timing code and D6). Every entry
//! carries a mandatory written reason, and unknown rule ids are
//! config-load errors, so the allowlist stays as honest as the pragmas.

use std::path::Path;

use anyhow::{anyhow, ensure, Context as _, Result};

use super::Rule;
use crate::util::json::Value;
use crate::util::toml_lite;

/// One allowlist entry: suppress `rule` findings in the file whose
/// repo-relative path ends with `path`, for the stated `reason`.
#[derive(Debug, Clone, PartialEq)]
pub struct AllowEntry {
    /// The rule being suppressed.
    pub rule: Rule,
    /// Path suffix the entry applies to (`rust/src/bench/mod.rs`).
    pub path: String,
    /// Written justification — mandatory, like pragma reasons.
    pub reason: String,
    /// 1-based line of the entry's `[[allow]]` header in the config
    /// file — where an unused-waiver D0 finding points. `0` for entries
    /// built programmatically.
    pub line: u32,
}

/// Parsed lint configuration.
#[derive(Debug, Clone, PartialEq)]
pub struct LintConfig {
    /// Directories (repo-relative) scanned when no paths are given on
    /// the command line.
    pub roots: Vec<String>,
    /// File-level suppressions.
    pub allows: Vec<AllowEntry>,
}

impl Default for LintConfig {
    /// Built-in defaults when no config file exists: scan `rust/src`,
    /// allow nothing.
    fn default() -> Self {
        LintConfig { roots: vec!["rust/src".to_string()], allows: Vec::new() }
    }
}

impl LintConfig {
    /// Load from a TOML file; a missing file yields the defaults (the
    /// analyzer must run in a bare checkout), any other error is fatal.
    pub fn load(path: &Path) -> Result<Self> {
        if !path.exists() {
            return Ok(LintConfig::default());
        }
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading {}", path.display()))?;
        let doc = toml_lite::parse(&text)
            .map_err(|e| anyhow!("parsing {}: {e}", path.display()))?;
        let mut cfg =
            Self::from_value(&doc).with_context(|| format!("in {}", path.display()))?;
        // `toml_lite` values carry no source positions; recover each
        // entry's line from the raw text (headers appear in entry order).
        for (entry, line) in cfg.allows.iter_mut().zip(allow_header_lines(&text)) {
            entry.line = line;
        }
        Ok(cfg)
    }

    /// Build from a parsed TOML document:
    ///
    /// ```toml
    /// [lint]
    /// roots = ["rust/src"]
    ///
    /// [[allow]]
    /// rule = "D6"
    /// path = "rust/src/bench/mod.rs"
    /// reason = "benchmark timing is the product, never a result artifact"
    /// ```
    pub fn from_value(doc: &Value) -> Result<Self> {
        let mut cfg = LintConfig::default();
        if let Some(roots) = doc.get("lint").and_then(|l| l.get("roots")) {
            let arr = roots.as_arr().ok_or_else(|| anyhow!("lint.roots must be an array"))?;
            cfg.roots = arr
                .iter()
                .map(|r| {
                    r.as_str()
                        .map(str::to_string)
                        .ok_or_else(|| anyhow!("lint.roots entries must be strings"))
                })
                .collect::<Result<_>>()?;
            ensure!(!cfg.roots.is_empty(), "lint.roots must not be empty");
        }
        if let Some(allows) = doc.get("allow") {
            let arr = allows.as_arr().ok_or_else(|| anyhow!("[[allow]] must be a table array"))?;
            for (idx, entry) in arr.iter().enumerate() {
                let field = |k: &str| {
                    entry
                        .get(k)
                        .and_then(Value::as_str)
                        .ok_or_else(|| anyhow!("[[allow]] #{idx}: missing string `{k}`"))
                };
                let rule_id = field("rule")?;
                let rule = Rule::from_id(rule_id)
                    .ok_or_else(|| anyhow!("[[allow]] #{idx}: unknown rule id `{rule_id}`"))?;
                ensure!(
                    rule != Rule::Pragma,
                    "[[allow]] #{idx}: D0 (pragma hygiene) cannot be allowlisted"
                );
                let path = field("path")?.to_string();
                let reason = field("reason")?.to_string();
                ensure!(
                    !reason.trim().is_empty(),
                    "[[allow]] #{idx}: reason must not be empty"
                );
                cfg.allows.push(AllowEntry { rule, path, reason, line: 0 });
            }
        }
        Ok(cfg)
    }

    /// The first allowlist entry covering `(rule, path)`, if any. Path
    /// matching is exact or by `/`-separated suffix, so entries work
    /// regardless of the scan root.
    pub fn allow_for(&self, rule: Rule, path: &str) -> Option<&AllowEntry> {
        self.allow_index(rule, path).map(|(_, a)| a)
    }

    /// Like [`LintConfig::allow_for`], but also yields the entry's index
    /// in [`LintConfig::allows`] — the analyzer tracks which waivers
    /// actually suppressed something (D0 flags the rest as rotted).
    pub fn allow_index(&self, rule: Rule, path: &str) -> Option<(usize, &AllowEntry)> {
        self.allows.iter().enumerate().find(|(_, a)| {
            a.rule == rule && (path == a.path || path.ends_with(&format!("/{}", a.path)))
        })
    }
}

/// 1-based line numbers of `[[allow]]` headers in raw TOML text, in
/// file order — zipped against the parsed entries to give each waiver a
/// source position.
fn allow_header_lines(text: &str) -> Vec<u32> {
    text.lines()
        .enumerate()
        .filter(|(_, l)| l.trim() == "[[allow]]")
        .map(|(i, _)| u32::try_from(i + 1).unwrap_or(u32::MAX))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(toml: &str) -> Result<LintConfig> {
        LintConfig::from_value(&toml_lite::parse(toml).unwrap())
    }

    #[test]
    fn defaults_without_file() {
        let cfg = LintConfig::load(Path::new("/nonexistent/lint.toml")).unwrap();
        assert_eq!(cfg.roots, vec!["rust/src"]);
        assert!(cfg.allows.is_empty());
    }

    #[test]
    fn parses_roots_and_allows() {
        let cfg = parse(
            "[lint]\nroots = [\"rust/src\"]\n\n[[allow]]\nrule = \"D6\"\n\
             path = \"rust/src/bench/mod.rs\"\nreason = \"timing is the product\"\n",
        )
        .unwrap();
        assert_eq!(cfg.allows.len(), 1);
        assert_eq!(cfg.allows[0].rule, Rule::WallClock);
        assert!(cfg.allow_for(Rule::WallClock, "rust/src/bench/mod.rs").is_some());
        assert!(cfg.allow_for(Rule::WallClock, "repo/rust/src/bench/mod.rs").is_some());
        assert!(cfg.allow_for(Rule::WallClock, "rust/src/serve/mod.rs").is_none());
        assert!(cfg.allow_for(Rule::PanicPath, "rust/src/bench/mod.rs").is_none());
    }

    #[test]
    fn rejects_bad_entries() {
        assert!(parse("[[allow]]\nrule = \"D9\"\npath = \"x\"\nreason = \"r\"\n").is_err());
        assert!(parse("[[allow]]\nrule = \"D4\"\npath = \"x\"\n").is_err());
        assert!(parse("[[allow]]\nrule = \"D4\"\npath = \"x\"\nreason = \" \"\n").is_err());
        assert!(parse("[[allow]]\nrule = \"D0\"\npath = \"x\"\nreason = \"r\"\n").is_err());
    }

    #[test]
    fn accepts_l_family_rules() {
        let cfg =
            parse("[[allow]]\nrule = \"L3\"\npath = \"x.rs\"\nreason = \"bounded index\"\n")
                .unwrap();
        assert_eq!(cfg.allows[0].rule, Rule::TaintedArith);
    }

    #[test]
    fn allow_header_lines_locate_entries() {
        let text = "[lint]\nroots = [\"rust/src\"]\n\n[[allow]]\nrule = \"D6\"\n\
                    path = \"a.rs\"\nreason = \"r\"\n\n[[allow]]\nrule = \"D5\"\n\
                    path = \"b.rs\"\nreason = \"r\"\n";
        assert_eq!(allow_header_lines(text), vec![4, 9]);
        assert!(allow_header_lines("roots = []\n").is_empty());
    }
}
