//! `smart lint` — a determinism-and-robustness static analyzer for the
//! campaign stack (DESIGN.md §12).
//!
//! Every headline guarantee this repo makes — byte-identical artifacts
//! for any `--shards/--threads/--block`, `--resume` checkpoints,
//! `smart serve` cache identity, scalar/block kernel equivalence —
//! rests on source-level invariants: canonical fold order, canonical
//! float formatting, no truncating casts on untrusted input, no panics
//! in library code. Until this pass existed they were enforced only by
//! integration tests *after* a violation shipped. `smart lint` checks
//! them statically on every commit.
//!
//! The analyzer is dependency-free: a hand-rolled lexer ([`lexer`])
//! strips comments and strings so rules never fire on prose, and the
//! rule passes ([`rules`]) walk the token stream. Rules are keyed
//! (`D1`..`D7`; `D0` is the pragma meta-rule) and individually
//! suppressible, either inline —
//!
//! ```text
//! // lint:allow(D6): wall-clock goes only to the console, never artifacts
//! let t0 = Instant::now();
//! ```
//!
//! — or per file via `configs/lint.toml` ([`config`]). Every
//! suppression must carry a written reason; a reasonless or unused
//! pragma is itself a finding (`D0`), so the suppression inventory can
//! never rot silently.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{Context as _, Result};

use crate::util::json::{to_string_pretty, Value};

pub mod config;
pub mod lexer;
pub mod rules;

pub use config::{AllowEntry, LintConfig};

/// The rule catalogue. Each variant is one checkable determinism or
/// robustness invariant; `D0` polices the suppression mechanism itself.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum Rule {
    /// D0 — malformed, reasonless, or unused `lint:allow` pragma.
    Pragma,
    /// D1 — `HashMap`/`HashSet` iteration in result-producing code
    /// (order-nondeterminism; keyed lookup is fine).
    MapIteration,
    /// D2 — floating-point accumulation (`+=`, `sum()`, `fold`) outside
    /// the approved canonical-fold sites (`Aggregator`, `Welford`).
    FloatAccum,
    /// D3 — `as` narrowing casts on parser-reachable values
    /// (`toml_lite`, `from_value`, HTTP bodies) — checked conversions
    /// required.
    NarrowingCast,
    /// D4 — `.unwrap()`/`.expect()`/`panic!` in non-test library code.
    PanicPath,
    /// D5 — direct `f64`/`f32` format specs outside
    /// `report::canon`/`csv_cell` (the `-0.0` / precision divergence
    /// class).
    FloatFormat,
    /// D6 — `Instant::now`/`SystemTime` in result-affecting paths.
    WallClock,
    /// D7 — time/trace primitives (`Instant`, `SystemTime`,
    /// `TraceSink`, `emit_record`) referenced outside `rust/src/obs/` —
    /// the observability quarantine (DESIGN.md §15): all timing lives
    /// behind `obs::Stopwatch`/`obs::Tracer` so inertness is auditable
    /// in one directory.
    TimeQuarantine,
}

/// All rules, in id order.
pub const RULES: [Rule; 8] = [
    Rule::Pragma,
    Rule::MapIteration,
    Rule::FloatAccum,
    Rule::NarrowingCast,
    Rule::PanicPath,
    Rule::FloatFormat,
    Rule::WallClock,
    Rule::TimeQuarantine,
];

impl Rule {
    /// Stable rule id (`"D0"`..`"D6"`), used in pragmas, the allowlist,
    /// and `LINT_report.json`.
    pub fn id(self) -> &'static str {
        match self {
            Rule::Pragma => "D0",
            Rule::MapIteration => "D1",
            Rule::FloatAccum => "D2",
            Rule::NarrowingCast => "D3",
            Rule::PanicPath => "D4",
            Rule::FloatFormat => "D5",
            Rule::WallClock => "D6",
            Rule::TimeQuarantine => "D7",
        }
    }

    /// One-line description of the invariant the rule checks.
    pub fn summary(self) -> &'static str {
        match self {
            Rule::Pragma => "suppression pragmas must parse, carry a reason, and match a finding",
            Rule::MapIteration => "no HashMap/HashSet iteration in result-producing code",
            Rule::FloatAccum => "float accumulation only at canonical-fold sites",
            Rule::NarrowingCast => "no `as` narrowing casts on parser-reachable values",
            Rule::PanicPath => "no unwrap/expect/panic! in library code",
            Rule::FloatFormat => "float formatting only via report::canon/csv_cell",
            Rule::WallClock => "no wall-clock reads in result-affecting paths",
            Rule::TimeQuarantine => "time/trace primitives only under rust/src/obs/",
        }
    }

    /// Resolve a rule id (`"D4"`); `None` for unknown ids.
    pub fn from_id(id: &str) -> Option<Rule> {
        RULES.into_iter().find(|r| r.id() == id)
    }
}

impl std::fmt::Display for Rule {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.id())
    }
}

/// One analyzer finding, suppressed or not.
#[derive(Debug, Clone, PartialEq)]
pub struct Finding {
    /// The rule that fired.
    pub rule: Rule,
    /// Repo-relative path (`/`-separated) of the offending file.
    pub path: String,
    /// 1-based source line.
    pub line: u32,
    /// What fired, in one sentence.
    pub note: String,
    /// `Some(reason)` when a pragma or allowlist entry suppressed the
    /// finding; the reason is the suppression's written justification.
    pub suppressed: Option<String>,
}

impl Finding {
    /// `path:line` — the clickable location of the finding.
    pub fn location(&self) -> String {
        format!("{}:{}", self.path, self.line)
    }
}

/// A finished lint run over a set of files.
#[derive(Debug, Clone, Default)]
pub struct LintReport {
    /// Every finding (suppressed ones included), sorted by
    /// `(path, line, rule)`.
    pub findings: Vec<Finding>,
    /// Number of `.rs` files scanned.
    pub files: usize,
}

impl LintReport {
    /// Findings not covered by a pragma or allowlist entry — the ones
    /// that fail the build.
    pub fn unsuppressed(&self) -> impl Iterator<Item = &Finding> {
        self.findings.iter().filter(|f| f.suppressed.is_none())
    }

    /// Count of unsuppressed findings.
    pub fn unsuppressed_count(&self) -> usize {
        self.unsuppressed().count()
    }

    /// Canonical `LINT_report.json` bytes: sorted findings, per-rule
    /// summary, no timestamps or host data — the same report is
    /// byte-identical on every machine (the lint practices what it
    /// preaches).
    pub fn to_json(&self) -> String {
        let mut root = BTreeMap::new();
        root.insert("files".to_string(), Value::Num(self.files as f64));
        let findings: Vec<Value> = self
            .findings
            .iter()
            .map(|f| {
                let mut m = BTreeMap::new();
                m.insert("rule".to_string(), Value::Str(f.rule.id().to_string()));
                m.insert("path".to_string(), Value::Str(f.path.clone()));
                m.insert("line".to_string(), Value::Num(f64::from(f.line)));
                m.insert("note".to_string(), Value::Str(f.note.clone()));
                m.insert(
                    "suppressed".to_string(),
                    match &f.suppressed {
                        Some(reason) => Value::Str(reason.clone()),
                        None => Value::Null,
                    },
                );
                Value::Obj(m)
            })
            .collect();
        root.insert("findings".to_string(), Value::Arr(findings));
        let mut summary = BTreeMap::new();
        for rule in RULES {
            let total = self.findings.iter().filter(|f| f.rule == rule).count();
            if total == 0 {
                continue;
            }
            let open = self.unsuppressed().filter(|f| f.rule == rule).count();
            let mut m = BTreeMap::new();
            m.insert("total".to_string(), Value::Num(total as f64));
            m.insert("unsuppressed".to_string(), Value::Num(open as f64));
            summary.insert(rule.id().to_string(), Value::Obj(m));
        }
        root.insert("summary".to_string(), Value::Obj(summary));
        root.insert(
            "unsuppressed".to_string(),
            Value::Num(self.unsuppressed_count() as f64),
        );
        let mut text = to_string_pretty(&Value::Obj(root));
        text.push('\n');
        text
    }
}

/// Lint one source file (pure — no filesystem access). `path` is the
/// repo-relative display path; it also drives the per-file rule scoping
/// (approved canonical-fold/format sites) and allowlist matching.
///
/// ```
/// use smart_insram::lint::{lint_source, LintConfig, Rule};
///
/// let cfg = LintConfig::default();
/// let findings = lint_source("src/demo.rs", "fn f(o: Option<u8>) -> u8 { o.unwrap() }", &cfg);
/// assert_eq!(findings.len(), 1);
/// assert_eq!(findings[0].rule, Rule::PanicPath);
/// assert!(findings[0].suppressed.is_none());
/// ```
pub fn lint_source(path: &str, text: &str, cfg: &LintConfig) -> Vec<Finding> {
    let lexed = lexer::lex(text);
    let raw = rules::scan(path, &lexed);
    let mut findings: Vec<Finding> = raw
        .into_iter()
        .map(|r| Finding {
            rule: r.rule,
            path: path.to_string(),
            line: r.line,
            note: r.note,
            suppressed: None,
        })
        .collect();

    // Inline pragmas first (closest to the code), then the config
    // allowlist for whatever is still open.
    let mut used = vec![false; lexed.pragmas.len()];
    for f in &mut findings {
        for (pi, p) in lexed.pragmas.iter().enumerate() {
            let covers = p.line == f.line || p.line + 1 == f.line;
            if covers && p.rules.iter().any(|r| r == f.rule.id()) {
                f.suppressed = Some(p.reason.clone());
                used[pi] = true;
                break;
            }
        }
    }
    for f in &mut findings {
        if f.suppressed.is_none() {
            if let Some(a) = cfg.allow_for(f.rule, path) {
                f.suppressed = Some(a.reason.clone());
            }
        }
    }

    // D0: the pragma mechanism polices itself. Malformed pragmas,
    // unknown rule ids, and pragmas that suppressed nothing are all
    // findings — and are never themselves suppressible.
    for (line, msg) in &lexed.malformed {
        findings.push(Finding {
            rule: Rule::Pragma,
            path: path.to_string(),
            line: *line,
            note: msg.clone(),
            suppressed: None,
        });
    }
    for (pi, p) in lexed.pragmas.iter().enumerate() {
        let unknown: Vec<&String> =
            p.rules.iter().filter(|r| Rule::from_id(r).is_none()).collect();
        if let Some(bad) = unknown.first() {
            findings.push(Finding {
                rule: Rule::Pragma,
                path: path.to_string(),
                line: p.line,
                note: format!("pragma names unknown rule id `{bad}`"),
                suppressed: None,
            });
        } else if !used[pi] {
            findings.push(Finding {
                rule: Rule::Pragma,
                path: path.to_string(),
                line: p.line,
                note: format!(
                    "unused pragma: no {} finding on this or the next line",
                    p.rules.join("/")
                ),
                suppressed: None,
            });
        }
    }

    findings.sort_by(|a, b| (a.line, a.rule).cmp(&(b.line, b.rule)));
    findings
}

/// Run the analyzer over `paths` (files or directories, resolved
/// relative to `root`; directories are walked recursively for `.rs`
/// files in sorted order). Empty `paths` falls back to the config's
/// `roots`.
pub fn run(root: &Path, paths: &[PathBuf], cfg: &LintConfig) -> Result<LintReport> {
    let requested: Vec<PathBuf> = if paths.is_empty() {
        cfg.roots.iter().map(PathBuf::from).collect()
    } else {
        paths.to_vec()
    };
    let mut files: Vec<PathBuf> = Vec::new();
    for p in &requested {
        let full = root.join(p);
        if full.is_dir() {
            collect_rs_files(&full, &mut files)
                .with_context(|| format!("walking {}", full.display()))?;
        } else if full.is_file() {
            files.push(full);
        } else {
            anyhow::bail!("lint path not found: {}", full.display());
        }
    }
    files.sort();
    files.dedup();

    let mut report = LintReport { findings: Vec::new(), files: files.len() };
    for file in &files {
        let text = std::fs::read_to_string(file)
            .with_context(|| format!("reading {}", file.display()))?;
        let rel = display_path(root, file);
        report.findings.extend(lint_source(&rel, &text, cfg));
    }
    report
        .findings
        .sort_by(|a, b| (&a.path, a.line, a.rule).cmp(&(&b.path, b.line, b.rule)));
    Ok(report)
}

/// Repo-relative, `/`-separated display path for a scanned file, so
/// reports (and the allowlist they are matched against) are identical
/// across hosts and platforms.
fn display_path(root: &Path, file: &Path) -> String {
    let rel = file.strip_prefix(root).unwrap_or(file);
    let parts: Vec<String> =
        rel.components().map(|c| c.as_os_str().to_string_lossy().into_owned()).collect();
    parts.join("/")
}

/// Depth-first, name-sorted `.rs` collection — deterministic scan order
/// for deterministic reports.
fn collect_rs_files(dir: &Path, out: &mut Vec<PathBuf>) -> Result<()> {
    let mut entries: Vec<PathBuf> = std::fs::read_dir(dir)
        .with_context(|| format!("reading dir {}", dir.display()))?
        .map(|e| e.map(|e| e.path()))
        .collect::<std::io::Result<_>>()?;
    entries.sort();
    for path in entries {
        if path.is_dir() {
            collect_rs_files(&path, out)?;
        } else if path.extension().is_some_and(|x| x == "rs") {
            out.push(path);
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rule_ids_roundtrip() {
        for rule in RULES {
            assert_eq!(Rule::from_id(rule.id()), Some(rule));
            assert!(!rule.summary().is_empty());
        }
        assert_eq!(Rule::from_id("D9"), None);
        assert_eq!(Rule::WallClock.to_string(), "D6");
        assert_eq!(Rule::TimeQuarantine.to_string(), "D7");
    }

    #[test]
    fn pragma_suppresses_same_and_next_line() {
        let cfg = LintConfig::default();
        let same = "fn f(o: Option<u8>) -> u8 { o.unwrap() } // lint:allow(D4): fixture\n";
        let fs = lint_source("x.rs", same, &cfg);
        assert_eq!(fs.len(), 1);
        assert_eq!(fs[0].suppressed.as_deref(), Some("fixture"));
        let above = "// lint:allow(D4): fixture\nfn f(o: Option<u8>) -> u8 { o.unwrap() }\n";
        let fs = lint_source("x.rs", above, &cfg);
        assert_eq!(fs.len(), 1);
        assert!(fs[0].suppressed.is_some());
    }

    #[test]
    fn unused_and_malformed_pragmas_are_d0_findings() {
        let cfg = LintConfig::default();
        let fs = lint_source("x.rs", "// lint:allow(D4): nothing here fires\nlet a = 1;\n", &cfg);
        assert_eq!(fs.len(), 1);
        assert_eq!(fs[0].rule, Rule::Pragma);
        assert!(fs[0].note.contains("unused"), "{}", fs[0].note);
        let fs = lint_source("x.rs", "// lint:allow(D4):\nlet a = 1;\n", &cfg);
        assert_eq!(fs.len(), 1);
        assert!(fs[0].note.contains("reason"), "{}", fs[0].note);
        let fs = lint_source("x.rs", "// lint:allow(D99): made-up rule\nlet a = 1;\n", &cfg);
        assert_eq!(fs.len(), 1);
        assert!(fs[0].note.contains("unknown rule id"), "{}", fs[0].note);
    }

    #[test]
    fn report_json_is_canonical() {
        let cfg = LintConfig::default();
        let findings =
            lint_source("b.rs", "fn g(o: Option<u8>) -> u8 { o.expect(\"x\") }\n", &cfg);
        let report = LintReport { findings, files: 1 };
        let json = report.to_json();
        assert!(crate::util::json::parse(&json).is_ok());
        assert!(json.contains("\"D4\""));
        assert!(json.contains("\"unsuppressed\": 1"), "{json}");
        assert!(json.ends_with('\n'));
        // byte-identical on re-serialization
        assert_eq!(json, report.to_json());
    }
}
