//! `smart lint` — a determinism-and-robustness static analyzer for the
//! campaign stack (DESIGN.md §12).
//!
//! Every headline guarantee this repo makes — byte-identical artifacts
//! for any `--shards/--threads/--block`, `--resume` checkpoints,
//! `smart serve` cache identity, scalar/block kernel equivalence —
//! rests on source-level invariants: canonical fold order, canonical
//! float formatting, no truncating casts on untrusted input, no panics
//! in library code. Until this pass existed they were enforced only by
//! integration tests *after* a violation shipped. `smart lint` checks
//! them statically on every commit.
//!
//! The analyzer is dependency-free: a hand-rolled lexer ([`lexer`])
//! strips comments and strings so rules never fire on prose, the
//! token-level rule passes ([`rules`]) walk the token stream, and a
//! recursive-descent structure pass ([`ast`]) plus a crate-local symbol
//! index / call graph ([`graph`], emitted as canonical
//! `CALLGRAPH.json`) power the structural rule family (DESIGN.md §16):
//! `L1` lock-order cycles, `L2` atomic-counter hygiene, `L3`
//! parser-tainted arithmetic, `L4` wildcard arms on repo-owned enums,
//! `L5` code/docs/config drift. Rules are keyed (`D1`..`D7`,
//! `L1`..`L5`; `D0` is the pragma meta-rule) and individually
//! suppressible, either inline —
//!
//! ```text
//! // lint:allow(D6): wall-clock goes only to the console, never artifacts
//! let t0 = Instant::now();
//! ```
//!
//! — or per file via `configs/lint.toml` ([`config`]). Every
//! suppression must carry a written reason; a reasonless or unused
//! pragma is itself a finding (`D0`), as is a config waiver that no
//! longer matches any finding, so the suppression inventory can never
//! rot silently.

use std::collections::{BTreeMap, BTreeSet};
use std::path::{Path, PathBuf};

use anyhow::{Context as _, Result};

use crate::util::json::{to_string_pretty, Value};
use crate::util::toml_lite;

pub mod ast;
pub mod config;
pub mod graph;
pub mod lexer;
pub mod rules;

pub use config::{AllowEntry, LintConfig};

/// Schema version stamped into `LINT_report.json`. Bump whenever the
/// report's shape changes so downstream consumers can dispatch.
pub const REPORT_SCHEMA_VERSION: u32 = 2;

/// The rule catalogue. Each variant is one checkable determinism or
/// robustness invariant; `D0` polices the suppression mechanism itself.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum Rule {
    /// D0 — malformed, reasonless, or unused `lint:allow` pragma.
    Pragma,
    /// D1 — `HashMap`/`HashSet` iteration in result-producing code
    /// (order-nondeterminism; keyed lookup is fine).
    MapIteration,
    /// D2 — floating-point accumulation (`+=`, `sum()`, `fold`) outside
    /// the approved canonical-fold sites (`Aggregator`, `Welford`).
    FloatAccum,
    /// D3 — `as` narrowing casts on parser-reachable values
    /// (`toml_lite`, `from_value`, HTTP bodies) — checked conversions
    /// required.
    NarrowingCast,
    /// D4 — `.unwrap()`/`.expect()`/`panic!` in non-test library code.
    PanicPath,
    /// D5 — direct `f64`/`f32` format specs outside
    /// `report::canon`/`csv_cell` (the `-0.0` / precision divergence
    /// class).
    FloatFormat,
    /// D6 — `Instant::now`/`SystemTime` in result-affecting paths.
    WallClock,
    /// D7 — time/trace primitives (`Instant`, `SystemTime`,
    /// `TraceSink`, `emit_record`) referenced outside `rust/src/obs/` —
    /// the observability quarantine (DESIGN.md §15): all timing lives
    /// behind `obs::Stopwatch`/`obs::Tracer` so inertness is auditable
    /// in one directory.
    TimeQuarantine,
    /// L1 — inconsistent lock acquisition order: cycles in the
    /// lock-order relation, propagated inter-procedurally over the call
    /// graph, are potential deadlocks.
    LockOrder,
    /// L2 — atomic-counter hygiene: non-saturating `fetch_add`/
    /// `fetch_sub` (counters must saturate, like `obs::Counter`), and
    /// `SeqCst` mixed with weaker orderings on the same atomic field.
    AtomicHygiene,
    /// L3 — unchecked `+`/`*` on values flowing from parser-scope
    /// bindings (extends D3 from casts to arithmetic).
    TaintedArith,
    /// L4 — wildcard `_` match arms on repo-owned enums (`KernelKind`,
    /// `Variant`, `Workload`, `Backend`) that would silently mask a new
    /// variant.
    WildcardArm,
    /// L5 — drift: every `--flag` read in `main.rs` must be documented
    /// in README/USAGE, and every config key the TOML parsers read must
    /// appear in at least one `configs/*.toml`.
    Drift,
}

/// All rules, in id order.
pub const RULES: [Rule; 13] = [
    Rule::Pragma,
    Rule::MapIteration,
    Rule::FloatAccum,
    Rule::NarrowingCast,
    Rule::PanicPath,
    Rule::FloatFormat,
    Rule::WallClock,
    Rule::TimeQuarantine,
    Rule::LockOrder,
    Rule::AtomicHygiene,
    Rule::TaintedArith,
    Rule::WildcardArm,
    Rule::Drift,
];

impl Rule {
    /// Stable rule id (`"D0"`..`"D7"`, `"L1"`..`"L5"`), used in
    /// pragmas, the allowlist, and `LINT_report.json`.
    pub fn id(self) -> &'static str {
        match self {
            Rule::Pragma => "D0",
            Rule::MapIteration => "D1",
            Rule::FloatAccum => "D2",
            Rule::NarrowingCast => "D3",
            Rule::PanicPath => "D4",
            Rule::FloatFormat => "D5",
            Rule::WallClock => "D6",
            Rule::TimeQuarantine => "D7",
            Rule::LockOrder => "L1",
            Rule::AtomicHygiene => "L2",
            Rule::TaintedArith => "L3",
            Rule::WildcardArm => "L4",
            Rule::Drift => "L5",
        }
    }

    /// One-line description of the invariant the rule checks.
    pub fn summary(self) -> &'static str {
        match self {
            Rule::Pragma => "suppression pragmas must parse, carry a reason, and match a finding",
            Rule::MapIteration => "no HashMap/HashSet iteration in result-producing code",
            Rule::FloatAccum => "float accumulation only at canonical-fold sites",
            Rule::NarrowingCast => "no `as` narrowing casts on parser-reachable values",
            Rule::PanicPath => "no unwrap/expect/panic! in library code",
            Rule::FloatFormat => "float formatting only via report::canon/csv_cell",
            Rule::WallClock => "no wall-clock reads in result-affecting paths",
            Rule::TimeQuarantine => "time/trace primitives only under rust/src/obs/",
            Rule::LockOrder => "lock acquisition order must be consistent across all call paths",
            Rule::AtomicHygiene => "atomic counters saturate; one memory-ordering discipline per field",
            Rule::TaintedArith => "no unchecked +/* on parser-tainted values",
            Rule::WildcardArm => "no wildcard `_` arms over repo-owned enums",
            Rule::Drift => "flags match README/USAGE; config keys match configs/*.toml",
        }
    }

    /// Resolve a rule id (`"D4"`); `None` for unknown ids.
    pub fn from_id(id: &str) -> Option<Rule> {
        RULES.into_iter().find(|r| r.id() == id)
    }
}

impl std::fmt::Display for Rule {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.id())
    }
}

/// One analyzer finding, suppressed or not.
#[derive(Debug, Clone, PartialEq)]
pub struct Finding {
    /// The rule that fired.
    pub rule: Rule,
    /// Repo-relative path (`/`-separated) of the offending file.
    pub path: String,
    /// 1-based source line.
    pub line: u32,
    /// What fired, in one sentence.
    pub note: String,
    /// `Some(reason)` when a pragma or allowlist entry suppressed the
    /// finding; the reason is the suppression's written justification.
    pub suppressed: Option<String>,
}

impl Finding {
    /// `path:line` — the clickable location of the finding.
    pub fn location(&self) -> String {
        format!("{}:{}", self.path, self.line)
    }
}

/// A finished lint run over a set of files.
#[derive(Debug, Clone, Default)]
pub struct LintReport {
    /// Every finding (suppressed ones included), sorted by
    /// `(path, line, rule)`.
    pub findings: Vec<Finding>,
    /// Number of `.rs` files scanned.
    pub files: usize,
}

impl LintReport {
    /// Findings not covered by a pragma or allowlist entry — the ones
    /// that fail the build.
    pub fn unsuppressed(&self) -> impl Iterator<Item = &Finding> {
        self.findings.iter().filter(|f| f.suppressed.is_none())
    }

    /// Count of unsuppressed findings.
    pub fn unsuppressed_count(&self) -> usize {
        self.unsuppressed().count()
    }

    /// Canonical `LINT_report.json` bytes: sorted findings, per-rule
    /// summary, no timestamps or host data — the same report is
    /// byte-identical on every machine (the lint practices what it
    /// preaches).
    pub fn to_json(&self) -> String {
        let mut root = BTreeMap::new();
        root.insert(
            "schema_version".to_string(),
            Value::Num(f64::from(REPORT_SCHEMA_VERSION)),
        );
        root.insert("files".to_string(), Value::Num(self.files as f64));
        let findings: Vec<Value> = self
            .findings
            .iter()
            .map(|f| {
                let mut m = BTreeMap::new();
                m.insert("rule".to_string(), Value::Str(f.rule.id().to_string()));
                m.insert("path".to_string(), Value::Str(f.path.clone()));
                m.insert("line".to_string(), Value::Num(f64::from(f.line)));
                m.insert("note".to_string(), Value::Str(f.note.clone()));
                m.insert(
                    "suppressed".to_string(),
                    match &f.suppressed {
                        Some(reason) => Value::Str(reason.clone()),
                        None => Value::Null,
                    },
                );
                Value::Obj(m)
            })
            .collect();
        root.insert("findings".to_string(), Value::Arr(findings));
        let mut summary = BTreeMap::new();
        for rule in RULES {
            let total = self.findings.iter().filter(|f| f.rule == rule).count();
            if total == 0 {
                continue;
            }
            let open = self.unsuppressed().filter(|f| f.rule == rule).count();
            let mut m = BTreeMap::new();
            m.insert("total".to_string(), Value::Num(total as f64));
            m.insert("unsuppressed".to_string(), Value::Num(open as f64));
            summary.insert(rule.id().to_string(), Value::Obj(m));
        }
        root.insert("summary".to_string(), Value::Obj(summary));
        root.insert(
            "unsuppressed".to_string(),
            Value::Num(self.unsuppressed_count() as f64),
        );
        let mut text = to_string_pretty(&Value::Obj(root));
        text.push('\n');
        text
    }
}

/// Lint one source file (pure — no filesystem access). `path` is the
/// repo-relative display path; it also drives the per-file rule scoping
/// (approved canonical-fold/format sites) and allowlist matching.
///
/// ```
/// use smart_insram::lint::{lint_source, LintConfig, Rule};
///
/// let cfg = LintConfig::default();
/// let findings = lint_source("src/demo.rs", "fn f(o: Option<u8>) -> u8 { o.unwrap() }", &cfg);
/// assert_eq!(findings.len(), 1);
/// assert_eq!(findings[0].rule, Rule::PanicPath);
/// assert!(findings[0].suppressed.is_none());
/// ```
pub fn lint_source(path: &str, text: &str, cfg: &LintConfig) -> Vec<Finding> {
    let unit = graph::FileUnit::new(path, text);
    let mut findings = unit_findings(&unit);
    // L1 over the single-file call graph: intra-file cycles are still
    // detectable without the rest of the crate.
    let g = graph::build(std::slice::from_ref(&unit));
    for (p, r) in graph::lock_order(&g) {
        if p == path {
            findings.push(raw_to_finding(path, r));
        }
    }
    let mut waiver_used = vec![false; cfg.allows.len()];
    suppress_file(path, &unit.lexed, &mut findings, cfg, &mut waiver_used);
    findings
}

/// Token- and structure-level findings for one parsed file (rules that
/// need no cross-file context).
fn unit_findings(unit: &graph::FileUnit) -> Vec<Finding> {
    rules::scan(&unit.path, &unit.lexed)
        .into_iter()
        .chain(rules::scan_ast(&unit.lexed, &unit.ast))
        .map(|r| raw_to_finding(&unit.path, r))
        .collect()
}

fn raw_to_finding(path: &str, r: rules::RawFinding) -> Finding {
    Finding { rule: r.rule, path: path.to_string(), line: r.line, note: r.note, suppressed: None }
}

/// Apply both suppression tiers to one file's findings, then append the
/// D0 pragma-hygiene findings and sort by `(line, rule)`.
///
/// `waiver_used[i]` is set when config allowlist entry `i` suppressed at
/// least one finding — [`analyze`] turns still-unused waivers into D0
/// findings of their own.
fn suppress_file(
    path: &str,
    lexed: &lexer::Lexed,
    findings: &mut Vec<Finding>,
    cfg: &LintConfig,
    waiver_used: &mut [bool],
) {
    // Inline pragmas first (closest to the code), then the config
    // allowlist for whatever is still open.
    let mut used = vec![false; lexed.pragmas.len()];
    for f in findings.iter_mut() {
        for (pi, p) in lexed.pragmas.iter().enumerate() {
            let covers = p.line == f.line || p.line + 1 == f.line;
            if covers && p.rules.iter().any(|r| r == f.rule.id()) {
                f.suppressed = Some(p.reason.clone());
                used[pi] = true;
                break;
            }
        }
    }
    for f in findings.iter_mut() {
        if f.suppressed.is_none() {
            if let Some((idx, a)) = cfg.allow_index(f.rule, path) {
                f.suppressed = Some(a.reason.clone());
                if let Some(slot) = waiver_used.get_mut(idx) {
                    *slot = true;
                }
            }
        }
    }

    // D0: the pragma mechanism polices itself. Malformed pragmas,
    // unknown rule ids, and pragmas that suppressed nothing are all
    // findings — and are never themselves suppressible.
    for (line, msg) in &lexed.malformed {
        findings.push(Finding {
            rule: Rule::Pragma,
            path: path.to_string(),
            line: *line,
            note: msg.clone(),
            suppressed: None,
        });
    }
    for (pi, p) in lexed.pragmas.iter().enumerate() {
        let unknown: Vec<&String> =
            p.rules.iter().filter(|r| Rule::from_id(r).is_none()).collect();
        if let Some(bad) = unknown.first() {
            findings.push(Finding {
                rule: Rule::Pragma,
                path: path.to_string(),
                line: p.line,
                note: format!("pragma names unknown rule id `{bad}`"),
                suppressed: None,
            });
        } else if !used[pi] {
            findings.push(Finding {
                rule: Rule::Pragma,
                path: path.to_string(),
                line: p.line,
                note: format!(
                    "unused pragma: no {} finding on this or the next line",
                    p.rules.join("/")
                ),
                suppressed: None,
            });
        }
    }

    findings.sort_by(|a, b| (a.line, a.rule).cmp(&(b.line, b.rule)));
}

/// A complete analysis: the lint report plus the crate call graph it
/// was derived from (for `CALLGRAPH.json`).
#[derive(Debug, Clone, Default)]
pub struct Analysis {
    /// The finished lint report.
    pub report: LintReport,
    /// The crate-local call graph over every scanned file.
    pub graph: graph::Graph,
}

/// Run the analyzer over `paths` (files or directories, resolved
/// relative to `root`; directories are walked recursively for `.rs`
/// files in sorted order). Empty `paths` falls back to the config's
/// `roots`. This is [`analyze`] keeping only the report.
pub fn run(root: &Path, paths: &[PathBuf], cfg: &LintConfig) -> Result<LintReport> {
    analyze(root, paths, cfg).map(|a| a.report)
}

/// Full structure-aware run: every per-file pass, the whole-crate call
/// graph with the inter-procedural `L1` lock-order pass, the `L5` drift
/// checks against `root`'s README and `configs/*.toml`, both suppression
/// tiers, and the D0 unused-waiver audit.
pub fn analyze(root: &Path, paths: &[PathBuf], cfg: &LintConfig) -> Result<Analysis> {
    let requested: Vec<PathBuf> = if paths.is_empty() {
        cfg.roots.iter().map(PathBuf::from).collect()
    } else {
        paths.to_vec()
    };
    let mut files: Vec<PathBuf> = Vec::new();
    for p in &requested {
        let full = root.join(p);
        if full.is_dir() {
            collect_rs_files(&full, &mut files)
                .with_context(|| format!("walking {}", full.display()))?;
        } else if full.is_file() {
            files.push(full);
        } else {
            anyhow::bail!("lint path not found: {}", full.display());
        }
    }
    files.sort();
    files.dedup();

    let mut units: Vec<graph::FileUnit> = Vec::new();
    for file in &files {
        let text = std::fs::read_to_string(file)
            .with_context(|| format!("reading {}", file.display()))?;
        units.push(graph::FileUnit::new(&display_path(root, file), &text));
    }

    let mut per_file: BTreeMap<String, Vec<Finding>> =
        units.iter().map(|u| (u.path.clone(), Vec::new())).collect();
    for u in &units {
        if let Some(v) = per_file.get_mut(&u.path) {
            v.extend(unit_findings(u));
        }
    }

    // L1: the whole-crate call graph sees every inter-procedural path.
    let g = graph::build(&units);
    for (path, r) in graph::lock_order(&g) {
        if let Some(v) = per_file.get_mut(&path) {
            let f = raw_to_finding(&path, r);
            v.push(f);
        }
    }

    // L5 (flag drift): the CLI entry point's flags vs README + its own
    // usage text (which lives in the same file).
    for u in &units {
        if !(u.path == "main.rs" || u.path.ends_with("/main.rs")) {
            continue;
        }
        let mut docs = std::fs::read_to_string(root.join("README.md")).unwrap_or_default();
        if let Some(full) = files.iter().find(|f| display_path(root, f) == u.path) {
            docs.push_str(&std::fs::read_to_string(full).unwrap_or_default());
        }
        if let Some(v) = per_file.get_mut(&u.path) {
            v.extend(rules::drift_flags(&u.lexed, &docs).into_iter().map(|r| {
                raw_to_finding(&u.path, r)
            }));
        }
    }

    // L5 (config-key drift): keys the TOML-reading sites consume vs the
    // keys any shipped configs/*.toml actually carries.
    if units.iter().any(|u| rules::is_config_key_site(&u.path)) {
        let available = harvest_config_keys(root);
        for u in &units {
            if !rules::is_config_key_site(&u.path) {
                continue;
            }
            if let Some(v) = per_file.get_mut(&u.path) {
                v.extend(
                    rules::drift_config_keys(&u.lexed, &available)
                        .into_iter()
                        .map(|r| raw_to_finding(&u.path, r)),
                );
            }
        }
    }

    let mut waiver_used = vec![false; cfg.allows.len()];
    let mut findings: Vec<Finding> = Vec::new();
    for u in &units {
        let mut fs = per_file.remove(&u.path).unwrap_or_default();
        suppress_file(&u.path, &u.lexed, &mut fs, cfg, &mut waiver_used);
        findings.extend(fs);
    }

    // D0 extension: a waiver whose rule/path matched no finding has
    // rotted — but only when its path matched a scanned file at all
    // (partial-tree runs must not indict waivers for files they never
    // looked at). Never suppressible, like every D0.
    for (idx, a) in cfg.allows.iter().enumerate() {
        if waiver_used[idx] {
            continue;
        }
        let seen = units
            .iter()
            .any(|u| u.path == a.path || u.path.ends_with(&format!("/{}", a.path)));
        if !seen {
            continue;
        }
        findings.push(Finding {
            rule: Rule::Pragma,
            path: "configs/lint.toml".to_string(),
            line: a.line,
            note: format!("unused waiver: no {} finding in {}", a.rule.id(), a.path),
            suppressed: None,
        });
    }

    // The single canonicalization point: every consumer sees findings
    // sorted by (path, line, rule), so report bytes cannot depend on
    // directory-walk order.
    findings.sort_by(|a, b| (&a.path, a.line, a.rule).cmp(&(&b.path, b.line, b.rule)));
    Ok(Analysis { report: LintReport { findings, files: files.len() }, graph: g })
}

/// Every key (at any nesting depth) appearing in any `root/configs/*.toml`
/// that parses — the inventory the L5 config-key check trusts.
fn harvest_config_keys(root: &Path) -> BTreeSet<String> {
    let mut keys = BTreeSet::new();
    let dir = root.join("configs");
    let Ok(entries) = std::fs::read_dir(&dir) else { return keys };
    let mut paths: Vec<PathBuf> = entries.filter_map(|e| e.ok().map(|e| e.path())).collect();
    paths.sort();
    for p in paths {
        if p.extension().is_none_or(|x| x != "toml") {
            continue;
        }
        let Ok(text) = std::fs::read_to_string(&p) else { continue };
        if let Ok(v) = toml_lite::parse(&text) {
            collect_keys(&v, &mut keys);
        }
    }
    keys
}

fn collect_keys(v: &Value, keys: &mut BTreeSet<String>) {
    match v {
        Value::Obj(m) => {
            for (k, inner) in m {
                keys.insert(k.clone());
                collect_keys(inner, keys);
            }
        }
        Value::Arr(items) => {
            for inner in items {
                collect_keys(inner, keys);
            }
        }
        _ => {}
    }
}

/// Repo-relative, `/`-separated display path for a scanned file, so
/// reports (and the allowlist they are matched against) are identical
/// across hosts and platforms.
fn display_path(root: &Path, file: &Path) -> String {
    let rel = file.strip_prefix(root).unwrap_or(file);
    let parts: Vec<String> =
        rel.components().map(|c| c.as_os_str().to_string_lossy().into_owned()).collect();
    parts.join("/")
}

/// Depth-first, name-sorted `.rs` collection — deterministic scan order
/// for deterministic reports.
fn collect_rs_files(dir: &Path, out: &mut Vec<PathBuf>) -> Result<()> {
    let mut entries: Vec<PathBuf> = std::fs::read_dir(dir)
        .with_context(|| format!("reading dir {}", dir.display()))?
        .map(|e| e.map(|e| e.path()))
        .collect::<std::io::Result<_>>()?;
    entries.sort();
    for path in entries {
        if path.is_dir() {
            collect_rs_files(&path, out)?;
        } else if path.extension().is_some_and(|x| x == "rs") {
            out.push(path);
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rule_ids_roundtrip() {
        for rule in RULES {
            assert_eq!(Rule::from_id(rule.id()), Some(rule));
            assert!(!rule.summary().is_empty());
        }
        assert_eq!(Rule::from_id("D9"), None);
        assert_eq!(Rule::from_id("L6"), None);
        assert_eq!(Rule::WallClock.to_string(), "D6");
        assert_eq!(Rule::TimeQuarantine.to_string(), "D7");
        assert_eq!(Rule::LockOrder.to_string(), "L1");
        assert_eq!(Rule::Drift.to_string(), "L5");
        // D rules sort before L rules, so mixed findings group cleanly.
        assert!(Rule::TimeQuarantine < Rule::LockOrder);
    }

    #[test]
    fn pragma_suppresses_same_and_next_line() {
        let cfg = LintConfig::default();
        let same = "fn f(o: Option<u8>) -> u8 { o.unwrap() } // lint:allow(D4): fixture\n";
        let fs = lint_source("x.rs", same, &cfg);
        assert_eq!(fs.len(), 1);
        assert_eq!(fs[0].suppressed.as_deref(), Some("fixture"));
        let above = "// lint:allow(D4): fixture\nfn f(o: Option<u8>) -> u8 { o.unwrap() }\n";
        let fs = lint_source("x.rs", above, &cfg);
        assert_eq!(fs.len(), 1);
        assert!(fs[0].suppressed.is_some());
    }

    #[test]
    fn unused_and_malformed_pragmas_are_d0_findings() {
        let cfg = LintConfig::default();
        let fs = lint_source("x.rs", "// lint:allow(D4): nothing here fires\nlet a = 1;\n", &cfg);
        assert_eq!(fs.len(), 1);
        assert_eq!(fs[0].rule, Rule::Pragma);
        assert!(fs[0].note.contains("unused"), "{}", fs[0].note);
        let fs = lint_source("x.rs", "// lint:allow(D4):\nlet a = 1;\n", &cfg);
        assert_eq!(fs.len(), 1);
        assert!(fs[0].note.contains("reason"), "{}", fs[0].note);
        let fs = lint_source("x.rs", "// lint:allow(D99): made-up rule\nlet a = 1;\n", &cfg);
        assert_eq!(fs.len(), 1);
        assert!(fs[0].note.contains("unknown rule id"), "{}", fs[0].note);
    }

    #[test]
    fn report_json_is_canonical() {
        let cfg = LintConfig::default();
        let findings =
            lint_source("b.rs", "fn g(o: Option<u8>) -> u8 { o.expect(\"x\") }\n", &cfg);
        let report = LintReport { findings, files: 1 };
        let json = report.to_json();
        assert!(crate::util::json::parse(&json).is_ok());
        assert!(json.contains("\"schema_version\": 2"), "{json}");
        assert!(json.contains("\"D4\""));
        assert!(json.contains("\"unsuppressed\": 1"), "{json}");
        assert!(json.ends_with('\n'));
        // byte-identical on re-serialization
        assert_eq!(json, report.to_json());
    }

    #[test]
    fn analyze_flags_unused_waivers_for_scanned_files_only() {
        let dir = std::env::temp_dir().join("smart_lint_waiver_test");
        let src_dir = dir.join("src");
        std::fs::create_dir_all(&src_dir).unwrap();
        std::fs::write(src_dir.join("clean.rs"), "fn f(x: u32) -> u32 { x }\n").unwrap();
        let mut cfg = LintConfig { roots: vec!["src".to_string()], allows: Vec::new() };
        // One waiver pointing at the scanned (clean) file: unused → D0.
        cfg.allows.push(AllowEntry {
            rule: Rule::PanicPath,
            path: "clean.rs".to_string(),
            reason: "test waiver".to_string(),
            line: 7,
        });
        // One waiver pointing outside the scanned set: not our business.
        cfg.allows.push(AllowEntry {
            rule: Rule::PanicPath,
            path: "elsewhere.rs".to_string(),
            reason: "test waiver".to_string(),
            line: 11,
        });
        let analysis = analyze(&dir, &[], &cfg).unwrap();
        let d0: Vec<&Finding> = analysis
            .report
            .findings
            .iter()
            .filter(|f| f.rule == Rule::Pragma)
            .collect();
        assert_eq!(d0.len(), 1, "{:?}", analysis.report.findings);
        assert_eq!(d0[0].path, "configs/lint.toml");
        assert_eq!(d0[0].line, 7);
        assert!(d0[0].note.contains("unused waiver"), "{}", d0[0].note);
        assert!(d0[0].suppressed.is_none());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn analyze_detects_flag_and_config_key_drift() {
        let dir = std::env::temp_dir().join("smart_lint_drift_test");
        let src_dir = dir.join("src");
        std::fs::create_dir_all(&src_dir).unwrap();
        std::fs::create_dir_all(dir.join("configs")).unwrap();
        // A main.rs reading two flags; only one is documented.
        std::fs::write(
            src_dir.join("main.rs"),
            "fn main() {\n    let a = args.flag(\"known\");\n    let b = args.flag(\"ghost\");\n}\n",
        )
        .unwrap();
        std::fs::write(dir.join("README.md"), "run with --known\n").unwrap();
        // A config-reading site (matches the `config.rs` site suffix)
        // consuming a key no shipped toml carries.
        std::fs::write(
            src_dir.join("config.rs"),
            "fn from_value(v: &Value) {\n    let s = v.get(\"seed\");\n    \
             let m = v.get(\"phantom\");\n}\n",
        )
        .unwrap();
        std::fs::write(dir.join("configs").join("a.toml"), "seed = 1\n").unwrap();
        let cfg = LintConfig { roots: vec!["src".to_string()], allows: Vec::new() };
        let analysis = analyze(&dir, &[], &cfg).unwrap();
        let drift: Vec<(String, u32)> = analysis
            .report
            .findings
            .iter()
            .filter(|f| f.rule == Rule::Drift)
            .map(|f| (f.path.clone(), f.line))
            .collect();
        assert_eq!(
            drift,
            vec![("src/config.rs".to_string(), 3), ("src/main.rs".to_string(), 3)],
            "{:?}",
            analysis.report.findings
        );
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn analyze_emits_the_call_graph() {
        let dir = std::env::temp_dir().join("smart_lint_graph_test");
        let src_dir = dir.join("src");
        std::fs::create_dir_all(&src_dir).unwrap();
        std::fs::write(src_dir.join("a.rs"), "fn leaf() {}\nfn top() { leaf(); }\n").unwrap();
        let cfg = LintConfig { roots: vec!["src".to_string()], allows: Vec::new() };
        let analysis = analyze(&dir, &[], &cfg).unwrap();
        let json = analysis.graph.to_json();
        assert!(crate::util::json::parse(&json).is_ok());
        assert!(json.contains("\"schema_version\": 1"), "{json}");
        assert!(json.contains("a::top"), "{json}");
        std::fs::remove_dir_all(&dir).ok();
    }
}
