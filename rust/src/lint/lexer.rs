//! Hand-rolled Rust lexer for the lint passes (DESIGN.md §12).
//!
//! The analyzer must never fire on text inside comments or string
//! literals (`"HashMap"` in a doc comment is not a determinism hazard),
//! so the rule passes run over a token stream, not raw lines. The lexer
//! understands exactly as much Rust as that requires: line and nested
//! block comments, cooked/raw/byte strings, char literals vs lifetimes,
//! numeric literals (with float suffixes and exponents), identifiers,
//! and multi-character operators. It is intentionally lossy everywhere
//! else — it never needs to parse, only to tokenize faithfully.
//!
//! Suppression pragmas travel in line comments
//! (`// lint:allow(D4): reason`) and are collected here, alongside any
//! malformed ones, so the rule layer can match findings against them
//! and flag pragmas that are unused or missing a written reason.

/// Token payload kinds.
#[derive(Debug, Clone, PartialEq)]
pub enum Tok {
    /// Identifier or keyword (Rust keywords are not distinguished).
    Ident(String),
    /// Numeric literal, verbatim including any suffix (`0.5f64`).
    Num(String),
    /// String literal content (cooked, raw, or byte), escapes verbatim.
    /// Content is retained so the float-format rule (D5) can inspect
    /// format specs.
    Str(String),
    /// Char or byte literal (`'x'`, `b'\xFF'`); content dropped.
    Char,
    /// Lifetime (`'a`, `'static`); distinct from char literals.
    Lifetime,
    /// Punctuation / operator, single or multi character (`::`, `+=`).
    Punct(String),
}

/// One token with the 1-based source line its first character sits on.
#[derive(Debug, Clone, PartialEq)]
pub struct Token {
    /// Token payload.
    pub tok: Tok,
    /// 1-based line number.
    pub line: u32,
}

/// A parsed `// lint:allow(D4): reason` suppression pragma. It covers
/// matching findings on its own line (trailing comment) and on the line
/// immediately below (comment above the offending statement).
#[derive(Debug, Clone, PartialEq)]
pub struct Pragma {
    /// 1-based line the pragma comment sits on.
    pub line: u32,
    /// Rule ids listed in the parentheses (`["D4"]`, `["D1", "D6"]`).
    pub rules: Vec<String>,
    /// The written justification after the closing `):`.
    pub reason: String,
}

/// Lexer output: the token stream plus the pragma sidecar channels.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct Lexed {
    /// All tokens, in source order.
    pub tokens: Vec<Token>,
    /// Well-formed suppression pragmas.
    pub pragmas: Vec<Pragma>,
    /// Malformed pragmas as `(line, problem)` — anything starting with
    /// `lint:allow` that does not parse to rules + a non-empty reason.
    pub malformed: Vec<(u32, String)>,
}

/// Multi-character operators, longest first (maximal munch).
const MULTI_PUNCT: [&str; 24] = [
    "<<=", ">>=", "..=", "...", "::", "->", "=>", "..", "==", "!=", "<=", ">=", "&&", "||", "<<",
    ">>", "+=", "-=", "*=", "/=", "%=", "^=", "&=", "|=",
];

fn is_ident_start(c: char) -> bool {
    c.is_ascii_alphabetic() || c == '_'
}

fn is_ident_continue(c: char) -> bool {
    c.is_ascii_alphanumeric() || c == '_'
}

/// True when a [`Tok::Num`] literal denotes a float (`0.5`, `1e-3`,
/// `2f64`) rather than an integer. Hex literals never count — their
/// `e` digits are not exponents — and an exponent `e`/`E` only counts
/// when followed by a digit or sign, so the `e` in an integer suffix
/// (`0usize`) never reads as one.
pub fn is_float_literal(num: &str) -> bool {
    if num.starts_with("0x") || num.starts_with("0X") {
        return false;
    }
    if num.contains('.') || num.ends_with("f32") || num.ends_with("f64") {
        return true;
    }
    num.bytes().zip(num.bytes().skip(1)).any(|(c, d)| {
        (c == b'e' || c == b'E') && (d.is_ascii_digit() || d == b'+' || d == b'-')
    })
}

/// Tokenize Rust source. Never fails: unrecognized bytes become
/// single-character [`Tok::Punct`] tokens, which no rule matches.
pub fn lex(text: &str) -> Lexed {
    Lexer { chars: text.chars().collect(), i: 0, line: 1, out: Lexed::default() }.run()
}

struct Lexer {
    chars: Vec<char>,
    i: usize,
    line: u32,
    out: Lexed,
}

impl Lexer {
    fn peek(&self, ahead: usize) -> Option<char> {
        self.chars.get(self.i + ahead).copied()
    }

    fn push(&mut self, tok: Tok, line: u32) {
        self.out.tokens.push(Token { tok, line });
    }

    fn run(mut self) -> Lexed {
        while let Some(c) = self.peek(0) {
            if c == '\n' {
                self.line += 1;
                self.i += 1;
            } else if c.is_whitespace() {
                self.i += 1;
            } else if c == '/' && self.peek(1) == Some('/') {
                self.line_comment();
            } else if c == '/' && self.peek(1) == Some('*') {
                self.block_comment();
            } else if c == '"' {
                self.cooked_string();
            } else if c == '\'' {
                self.char_or_lifetime();
            } else if c.is_ascii_digit() {
                self.number();
            } else if is_ident_start(c) {
                self.ident_or_prefixed_literal();
            } else {
                self.punct();
            }
        }
        self.out
    }

    fn line_comment(&mut self) {
        let start = self.i + 2;
        let mut j = start;
        while j < self.chars.len() && self.chars[j] != '\n' {
            j += 1;
        }
        let body: String = self.chars[start..j].iter().collect();
        self.scan_pragma(&body);
        self.i = j;
    }

    fn scan_pragma(&mut self, comment: &str) {
        // Strip doc-comment markers (`///`, `//!`) then whitespace.
        let body = comment.trim_start_matches(['/', '!']).trim();
        let Some(rest) = body.strip_prefix("lint:allow") else {
            return;
        };
        let line = self.line;
        let bad = |msg: &str| (line, format!("malformed pragma `{}`: {msg}", body.trim()));
        let Some(rest) = rest.strip_prefix('(') else {
            self.out.malformed.push(bad("expected `(` after lint:allow"));
            return;
        };
        let Some((rules, reason)) = rest.split_once(')') else {
            self.out.malformed.push(bad("missing `)`"));
            return;
        };
        let rules: Vec<String> =
            rules.split(',').map(|r| r.trim().to_string()).filter(|r| !r.is_empty()).collect();
        if rules.is_empty() {
            self.out.malformed.push(bad("no rule ids listed"));
            return;
        }
        let Some(reason) = reason.trim_start().strip_prefix(':') else {
            self.out.malformed.push(bad("expected `: reason` after `)`"));
            return;
        };
        let reason = reason.trim();
        if reason.is_empty() {
            self.out.malformed.push(bad("suppression needs a written reason"));
            return;
        }
        self.out.pragmas.push(Pragma { line, rules, reason: reason.to_string() });
    }

    fn block_comment(&mut self) {
        let mut depth = 1usize;
        let mut j = self.i + 2;
        while j < self.chars.len() && depth > 0 {
            match self.chars[j] {
                '\n' => {
                    self.line += 1;
                    j += 1;
                }
                '/' if self.chars.get(j + 1) == Some(&'*') => {
                    depth += 1;
                    j += 2;
                }
                '*' if self.chars.get(j + 1) == Some(&'/') => {
                    depth -= 1;
                    j += 2;
                }
                _ => j += 1,
            }
        }
        self.i = j;
    }

    /// Cooked string body starting at the opening quote: escapes skip
    /// the next char, newlines (including escaped line continuations)
    /// keep the line counter honest.
    fn cooked_string(&mut self) {
        let line = self.line;
        let mut j = self.i + 1;
        let mut content = String::new();
        while j < self.chars.len() {
            let c = self.chars[j];
            if c == '"' {
                j += 1;
                break;
            }
            if c == '\n' {
                self.line += 1;
            }
            content.push(c);
            if c == '\\' {
                if let Some(&e) = self.chars.get(j + 1) {
                    if e == '\n' {
                        self.line += 1;
                    }
                    content.push(e);
                    j += 1;
                }
            }
            j += 1;
        }
        self.i = j;
        self.push(Tok::Str(content), line);
    }

    /// Raw string starting at `r`/`br` + hashes: no escapes; terminated
    /// by `"` followed by the same number of hashes.
    fn raw_string(&mut self, hash_start: usize) {
        let line = self.line;
        let mut hashes = 0usize;
        let mut j = hash_start;
        while self.chars.get(j) == Some(&'#') {
            hashes += 1;
            j += 1;
        }
        // caller guarantees chars[j] == '"'
        j += 1;
        let body_start = j;
        let mut end = self.chars.len();
        while j < self.chars.len() {
            if self.chars[j] == '\n' {
                self.line += 1;
                j += 1;
                continue;
            }
            if self.chars[j] == '"'
                && self.chars[j + 1..].iter().take(hashes).filter(|&&h| h == '#').count() == hashes
            {
                end = j;
                j += 1 + hashes;
                break;
            }
            j += 1;
        }
        let content: String = self.chars[body_start..end.min(self.chars.len())].iter().collect();
        self.i = j;
        self.push(Tok::Str(content), line);
    }

    fn char_or_lifetime(&mut self) {
        let line = self.line;
        match (self.peek(1), self.peek(2)) {
            // escaped char: '\n', '\u{1F600}' — scan to the closing quote
            (Some('\\'), _) => {
                let mut j = self.i + 3;
                while j < self.chars.len() && self.chars[j] != '\'' {
                    j += 1;
                }
                self.i = j + 1;
                self.push(Tok::Char, line);
            }
            // plain char: 'x' (x may itself be an ident char)
            (Some(c), Some('\'')) if c != '\'' => {
                self.i += 3;
                self.push(Tok::Char, line);
            }
            // lifetime: 'ident with no closing quote
            (Some(c), _) if is_ident_start(c) => {
                let mut j = self.i + 1;
                while j < self.chars.len() && is_ident_continue(self.chars[j]) {
                    j += 1;
                }
                self.i = j;
                self.push(Tok::Lifetime, line);
            }
            _ => {
                self.i += 1;
                self.push(Tok::Punct("'".to_string()), line);
            }
        }
    }

    fn number(&mut self) {
        let line = self.line;
        let start = self.i;
        let mut j = self.i;
        while j < self.chars.len() {
            let c = self.chars[j];
            if is_ident_continue(c) {
                j += 1;
            } else if c == '.' && self.chars.get(j + 1).is_some_and(|d| d.is_ascii_digit()) {
                // `0.5` continues the literal; `0..16` does not
                j += 1;
            } else if (c == '+' || c == '-')
                && j > start
                && matches!(self.chars[j - 1], 'e' | 'E')
                && !self.chars[start..].starts_with(&['0', 'x'])
            {
                // decimal exponent sign: `1e-3`
                j += 1;
            } else {
                break;
            }
        }
        let text: String = self.chars[start..j].iter().collect();
        self.i = j;
        self.push(Tok::Num(text), line);
    }

    fn ident_or_prefixed_literal(&mut self) {
        let line = self.line;
        let start = self.i;
        let mut j = self.i;
        while j < self.chars.len() && is_ident_continue(self.chars[j]) {
            j += 1;
        }
        let word: String = self.chars[start..j].iter().collect();
        let next = self.chars.get(j).copied();
        match (word.as_str(), next) {
            // raw string r"..." / r#"..."# (also br variants)
            ("r" | "br", Some('"' | '#')) if self.raw_string_follows(j) => {
                self.i = j;
                self.raw_string(j);
            }
            // byte string b"..."
            ("b", Some('"')) => {
                self.i = j;
                self.cooked_string();
            }
            // byte char b'x'
            ("b", Some('\'')) => {
                self.i = j;
                self.char_or_lifetime();
            }
            // raw identifier r#fn — consume as a plain identifier
            ("r", Some('#')) if self.chars.get(j + 1).copied().is_some_and(is_ident_start) => {
                let mut k = j + 1;
                while k < self.chars.len() && is_ident_continue(self.chars[k]) {
                    k += 1;
                }
                let raw: String = self.chars[j + 1..k].iter().collect();
                self.i = k;
                self.push(Tok::Ident(raw), line);
            }
            _ => {
                self.i = j;
                self.push(Tok::Ident(word), line);
            }
        }
    }

    /// After `r`/`br`, is this actually a raw string (hashes then a
    /// quote), not a raw identifier or a lone `r`?
    fn raw_string_follows(&self, mut j: usize) -> bool {
        while self.chars.get(j) == Some(&'#') {
            j += 1;
        }
        self.chars.get(j) == Some(&'"')
    }

    fn punct(&mut self) {
        let line = self.line;
        for op in MULTI_PUNCT {
            let glyphs: Vec<char> = op.chars().collect();
            if self.chars[self.i..].starts_with(&glyphs) {
                self.i += glyphs.len();
                self.push(Tok::Punct(op.to_string()), line);
                return;
            }
        }
        let c = self.chars[self.i];
        self.i += 1;
        self.push(Tok::Punct(c.to_string()), line);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn idents(text: &str) -> Vec<String> {
        lex(text)
            .tokens
            .into_iter()
            .filter_map(|t| match t.tok {
                Tok::Ident(s) => Some(s),
                _ => None,
            })
            .collect()
    }

    #[test]
    fn comments_and_strings_produce_no_idents() {
        let src = r##"
            // HashMap in a line comment
            /// "HashMap" in a doc comment
            /* block /* nested */ HashMap */
            let s = "HashMap::iter()";
            let r = r#"unwrap() in a raw string"#;
        "##;
        let ids = idents(src);
        assert!(!ids.contains(&"HashMap".to_string()), "{ids:?}");
        assert!(!ids.contains(&"unwrap".to_string()), "{ids:?}");
        assert!(ids.contains(&"let".to_string()));
    }

    #[test]
    fn lines_are_tracked_through_multiline_constructs() {
        let src = "let a = 1;\n/* two\nlines */\nlet b = \"x\ny\";\nlet c = 2;";
        let lexed = lex(src);
        let line_of = |name: &str| {
            lexed
                .tokens
                .iter()
                .find(|t| t.tok == Tok::Ident(name.to_string()))
                .map(|t| t.line)
        };
        assert_eq!(line_of("a"), Some(1));
        assert_eq!(line_of("b"), Some(4));
        assert_eq!(line_of("c"), Some(6));
    }

    #[test]
    fn char_literals_vs_lifetimes() {
        let lexed = lex("fn f<'a>(x: &'a str) { let c = 'x'; let e = '\\n'; }");
        let chars = lexed.tokens.iter().filter(|t| t.tok == Tok::Char).count();
        let lifetimes = lexed.tokens.iter().filter(|t| t.tok == Tok::Lifetime).count();
        assert_eq!(chars, 2);
        assert_eq!(lifetimes, 2);
    }

    #[test]
    fn numbers_and_ranges() {
        let lexed = lex("for i in 0..16 { let x = 1.5e-3; let y = 0.5f64; }");
        let nums: Vec<String> = lexed
            .tokens
            .into_iter()
            .filter_map(|t| match t.tok {
                Tok::Num(s) => Some(s),
                _ => None,
            })
            .collect();
        assert_eq!(nums, vec!["0", "16", "1.5e-3", "0.5f64"]);
        assert!(!is_float_literal("0"));
        assert!(!is_float_literal("0xEF"));
        // the `e` in an integer suffix is not an exponent
        assert!(!is_float_literal("0usize"));
        assert!(!is_float_literal("1e"));
        assert!(is_float_literal("1.5e-3"));
        assert!(is_float_literal("0.5f64"));
    }

    #[test]
    fn multi_char_operators_are_single_tokens() {
        let lexed = lex("a += b; c::<f64>(); d -> e");
        let puncts: Vec<String> = lexed
            .tokens
            .into_iter()
            .filter_map(|t| match t.tok {
                Tok::Punct(s) => Some(s),
                _ => None,
            })
            .collect();
        assert!(puncts.contains(&"+=".to_string()));
        assert!(puncts.contains(&"::".to_string()));
        assert!(puncts.contains(&"->".to_string()));
    }

    #[test]
    fn pragmas_parse_with_rules_and_reason() {
        let src = "let x = 1; // lint:allow(D4, D6): console-only path\n";
        let lexed = lex(src);
        assert_eq!(lexed.pragmas.len(), 1);
        let p = &lexed.pragmas[0];
        assert_eq!(p.line, 1);
        assert_eq!(p.rules, vec!["D4", "D6"]);
        assert_eq!(p.reason, "console-only path");
        assert!(lexed.malformed.is_empty());
    }

    #[test]
    fn reasonless_pragmas_are_malformed() {
        for bad in [
            "// lint:allow(D4):",
            "// lint:allow(D4)",
            "// lint:allow D4: reason",
            "// lint:allow(): reason",
        ] {
            let lexed = lex(bad);
            assert!(lexed.pragmas.is_empty(), "{bad}");
            assert_eq!(lexed.malformed.len(), 1, "{bad}");
        }
    }

    #[test]
    fn string_escapes_do_not_end_the_literal() {
        let lexed = lex(r#"let s = "a\"b"; let t = 2;"#);
        assert!(lexed.tokens.iter().any(|t| t.tok == Tok::Str("a\\\"b".to_string())));
        assert!(lexed.tokens.iter().any(|t| t.tok == Tok::Ident("t".to_string())));
    }
}
