//! Structure recovery over the lexed token stream (DESIGN.md §16).
//!
//! The token-level passes of [`super::rules`] deliberately know nothing
//! about nesting; the structural rule families (`L1`–`L5`) need more:
//! which function a lock is acquired in, which *block* a guard binding
//! lives in, which arms a `match` has, and what a call expression's
//! callee path is. This module recovers exactly that much shape — a
//! hand-rolled, dependency-free recursive-descent pass that turns the
//! [`Lexed`] stream into items (`fn`/`impl`/`enum`/`mod`/`use`),
//! function bodies as block trees, match expressions with their arms,
//! and per-statement token spans for the linear scans the rules still
//! do.
//!
//! The parser is an *approximation* of the Rust grammar, tuned the same
//! way as the lexer: it must never panic, never diverge, and never
//! misattribute scope in the patterns this repository actually uses
//! (guards bound in nested block expressions, `match` scrutinees that
//! acquire locks, struct patterns in arms). Constructs it does not
//! model — e.g. expressions in const generics — degrade to plain
//! statement tokens, which no structural rule matches.

use super::lexer::{Lexed, Tok, Token};

/// One parsed source file: every function (flattened, with its module
/// and `impl` context recorded on the declaration), every enum, and
/// every `use` leaf.
#[derive(Debug, Clone, Default)]
pub struct Ast {
    /// All function declarations, in source order. Functions nested in
    /// `impl`/`trait`/`mod` blocks carry that context in
    /// [`FnDecl::owner`] / [`FnDecl::mods`].
    pub fns: Vec<FnDecl>,
    /// All enum declarations, in source order.
    pub enums: Vec<EnumDecl>,
    /// All `use` declaration leaves (grouped trees are expanded).
    pub uses: Vec<UseDecl>,
}

/// A function declaration with its recovered body.
#[derive(Debug, Clone)]
pub struct FnDecl {
    /// The function's own name.
    pub name: String,
    /// Enclosing `impl`/`trait` type, when the function is associated.
    pub owner: Option<String>,
    /// Inline `mod` path within the file (outermost first).
    pub mods: Vec<String>,
    /// 1-based line of the `fn` keyword.
    pub line: u32,
    /// Parameter names recovered from the signature (`self` excluded;
    /// destructuring patterns yield nothing).
    pub params: Vec<String>,
    /// Token-index span `[start, end)` of the signature — from the `fn`
    /// keyword to the body's opening brace (or terminating `;`).
    pub sig: (usize, usize),
    /// The body block; empty for bodyless declarations (trait methods).
    pub body: Block,
    /// True when the function (or an enclosing item) is test-only
    /// (`#[test]` / `#[cfg(test)]`).
    pub test: bool,
}

/// A `{ ... }` block: an ordered list of statements.
#[derive(Debug, Clone, Default)]
pub struct Block {
    /// Statements in source order.
    pub stmts: Vec<Stmt>,
}

/// One statement (or block-tail expression): the tokens at its own
/// nesting level plus any nested blocks / match expressions, in source
/// order.
#[derive(Debug, Clone)]
pub struct Stmt {
    /// 1-based line the statement starts on.
    pub line: u32,
    /// `Some(name)` for `let name = ...` / `let mut name = ...`
    /// bindings; `None` for destructuring patterns and non-`let`
    /// statements.
    pub let_name: Option<String>,
    /// Indices (into the file's token stream) of the tokens that sit at
    /// this statement's own nesting level — nested brace contents are
    /// excluded and appear in [`Stmt::subs`] instead.
    pub head: Vec<usize>,
    /// Nested blocks and match expressions, in source order.
    pub subs: Vec<Sub>,
}

/// A nested unit inside a statement.
#[derive(Debug, Clone)]
pub enum Sub {
    /// A nested `{ ... }` block (if/else bodies, loop bodies, closures,
    /// block expressions; struct literals degrade to this harmlessly).
    Block(Block),
    /// A `match` expression with its arms.
    Match(MatchExpr),
}

/// A recovered `match` expression.
#[derive(Debug, Clone)]
pub struct MatchExpr {
    /// 1-based line of the `match` keyword.
    pub line: u32,
    /// Token indices of the scrutinee expression (between `match` and
    /// the opening brace), at the statement's nesting level.
    pub scrutinee: Vec<usize>,
    /// The arms, in source order.
    pub arms: Vec<Arm>,
}

/// One `pat (if guard)? => body` match arm.
#[derive(Debug, Clone)]
pub struct Arm {
    /// 1-based line the pattern starts on.
    pub line: u32,
    /// Token indices of the pattern (guard excluded).
    pub pat: Vec<usize>,
    /// True when the arm carries an `if` guard.
    pub guarded: bool,
    /// The arm body as a block (expression bodies become a one-statement
    /// block).
    pub body: Block,
}

/// An enum declaration.
#[derive(Debug, Clone)]
pub struct EnumDecl {
    /// The enum's name.
    pub name: String,
    /// Inline `mod` path within the file.
    pub mods: Vec<String>,
    /// 1-based line of the `enum` keyword.
    pub line: u32,
    /// Variant names, in declaration order.
    pub variants: Vec<String>,
    /// True when declared in test-only code.
    pub test: bool,
}

/// One `use` declaration leaf: `use a::b::{c as d}` yields
/// `segs = ["a", "b", "c"], alias = "d"`.
#[derive(Debug, Clone)]
pub struct UseDecl {
    /// Full path segments of the imported item.
    pub segs: Vec<String>,
    /// The name the item is visible under locally (the last segment, or
    /// the `as` rename).
    pub alias: String,
    /// 1-based line of the `use` keyword.
    pub line: u32,
}

/// Parse one lexed file. Never fails: unmodeled constructs degrade to
/// plain statement tokens.
pub fn parse(lexed: &Lexed) -> Ast {
    let mut p = Parser { t: &lexed.tokens, out: Ast::default() };
    let end = p.t.len();
    let ctx = Ctx { mods: Vec::new(), owner: None, test: false };
    p.items(0, end, &ctx);
    p.out
}

/// Item-walk context: where in the module/impl tree we are.
struct Ctx {
    mods: Vec<String>,
    owner: Option<String>,
    test: bool,
}

struct Parser<'a> {
    t: &'a [Token],
    out: Ast,
}

impl<'a> Parser<'a> {
    fn ident(&self, i: usize) -> Option<&'a str> {
        match self.t.get(i).map(|t| &t.tok) {
            Some(Tok::Ident(s)) => Some(s.as_str()),
            _ => None,
        }
    }

    fn punct(&self, i: usize, op: &str) -> bool {
        matches!(self.t.get(i).map(|t| &t.tok), Some(Tok::Punct(p)) if p == op)
    }

    fn line(&self, i: usize) -> u32 {
        self.t.get(i).map(|t| t.line).unwrap_or(0)
    }

    /// Index of the delimiter matching `t[open]`; `end` if unbalanced.
    fn close_of(&self, open: usize, end: usize, open_d: &str, close_d: &str) -> usize {
        let mut depth = 0usize;
        let mut i = open;
        while i < end {
            if self.punct(i, open_d) {
                depth += 1;
            } else if self.punct(i, close_d) {
                depth = depth.saturating_sub(1);
                if depth == 0 {
                    return i;
                }
            }
            i += 1;
        }
        end
    }

    /// Skip a generics list starting at `<`; returns the index just
    /// past the matching `>`. Understands the shifted `>>`/`<<` tokens.
    fn skip_generics(&self, mut i: usize, end: usize) -> usize {
        if !self.punct(i, "<") {
            return i;
        }
        let mut depth = 0i32;
        while i < end {
            match self.t.get(i).map(|t| &t.tok) {
                Some(Tok::Punct(p)) if p == "<" => depth += 1,
                Some(Tok::Punct(p)) if p == "<<" => depth += 2,
                Some(Tok::Punct(p)) if p == ">" => depth -= 1,
                Some(Tok::Punct(p)) if p == ">>" => depth -= 2,
                _ => {}
            }
            i += 1;
            if depth <= 0 {
                break;
            }
        }
        i
    }

    /// Walk items in `[i, end)`.
    fn items(&mut self, mut i: usize, end: usize, ctx: &Ctx) {
        while i < end {
            i = self.item(i, end, ctx);
        }
    }

    /// Parse one item (or skip one token) starting at `i`; returns the
    /// index to continue from.
    fn item(&mut self, mut i: usize, end: usize, ctx: &Ctx) -> usize {
        let mut test = ctx.test;
        // Attributes: `#[...]` may mark the next item test-only;
        // `#![...]` inner attributes are skipped outright.
        while self.punct(i, "#") {
            let open = if self.punct(i + 1, "!") { i + 2 } else { i + 1 };
            if !self.punct(open, "[") {
                return i + 1;
            }
            let close = self.close_of(open, end, "[", "]");
            if open == i + 1 {
                test = test || self.attr_is_test(open + 1, close);
            }
            i = close + 1;
        }
        // Visibility and qualifier keywords before the item keyword.
        loop {
            match self.ident(i) {
                Some("pub") => {
                    i += 1;
                    if self.punct(i, "(") {
                        i = self.close_of(i, end, "(", ")") + 1;
                    }
                }
                Some("unsafe" | "async" | "default") => i += 1,
                Some("extern") => {
                    i += 1;
                    if matches!(self.t.get(i).map(|t| &t.tok), Some(Tok::Str(_))) {
                        i += 1;
                    }
                }
                _ => break,
            }
        }
        match self.ident(i) {
            Some("mod") => self.item_mod(i, end, ctx, test),
            Some("fn") => self.item_fn(i, end, ctx, test),
            Some("enum") => self.item_enum(i, end, ctx, test),
            Some("use") => self.item_use(i, end),
            Some("impl") => self.item_impl(i, end, ctx, test),
            Some("trait") => self.item_trait(i, end, ctx, test),
            Some("struct" | "union") => self.skip_struct(i, end),
            Some("const" | "static" | "type") => self.skip_to_semi(i, end),
            Some("macro_rules") => self.skip_macro(i, end),
            _ => i + 1,
        }
    }

    /// Does the attribute body `[start, end)` spell `test` or a
    /// `cfg(...)` whose arguments mention `test` without leading `not`?
    fn attr_is_test(&self, start: usize, close: usize) -> bool {
        if close <= start {
            return false;
        }
        if close - start == 1 {
            return self.ident(start) == Some("test");
        }
        if self.ident(start) == Some("cfg") && self.punct(start + 1, "(") {
            let args: Vec<&str> = (start + 2..close).filter_map(|k| self.ident(k)).collect();
            return args.first() != Some(&"not") && args.contains(&"test");
        }
        false
    }

    fn item_mod(&mut self, i: usize, end: usize, ctx: &Ctx, test: bool) -> usize {
        let Some(name) = self.ident(i + 1) else { return i + 1 };
        if self.punct(i + 2, "{") {
            let close = self.close_of(i + 2, end, "{", "}");
            let mut mods = ctx.mods.clone();
            mods.push(name.to_string());
            let inner = Ctx { mods, owner: None, test };
            self.items(i + 3, close, &inner);
            close + 1
        } else {
            // `mod name;` — an out-of-line module, its file is scanned
            // separately.
            i + 2
        }
    }

    fn item_fn(&mut self, i: usize, end: usize, ctx: &Ctx, test: bool) -> usize {
        let Some(name) = self.ident(i + 1) else { return i + 1 };
        let mut j = self.skip_generics(i + 2, end);
        if !self.punct(j, "(") {
            return i + 2;
        }
        let params_close = self.close_of(j, end, "(", ")");
        let params = self.param_names(j + 1, params_close);
        // Scan the rest of the signature (return type, where clause) to
        // the body `{` or a terminating `;`.
        j = params_close + 1;
        let mut body_open = None;
        while j < end {
            if self.punct(j, "{") {
                body_open = Some(j);
                break;
            }
            if self.punct(j, ";") {
                break;
            }
            j += 1;
        }
        let (sig_end, body, next) = match body_open {
            Some(open) => {
                let close = self.close_of(open, end, "{", "}");
                (open, self.block(open, close), close + 1)
            }
            None => (j, Block::default(), j + 1),
        };
        self.out.fns.push(FnDecl {
            name: name.to_string(),
            owner: ctx.owner.clone(),
            mods: ctx.mods.clone(),
            line: self.line(i),
            params,
            sig: (i, sig_end),
            body,
            test,
        });
        next
    }

    /// Parameter names in `[lo, hi)` (inside the signature parens):
    /// idents at paren depth 0 directly followed by `:`, preceded by
    /// `(`-start, `,`, or `mut`.
    fn param_names(&self, lo: usize, hi: usize) -> Vec<String> {
        let mut out = Vec::new();
        let mut depth = 0i32;
        for k in lo..hi {
            match self.t.get(k).map(|t| &t.tok) {
                Some(Tok::Punct(p)) if p == "(" || p == "[" || p == "<" => depth += 1,
                Some(Tok::Punct(p)) if p == ")" || p == "]" || p == ">" => depth -= 1,
                Some(Tok::Ident(s)) if depth == 0 && s != "self" => {
                    let prev_ok = k == lo
                        || self.punct(k - 1, ",")
                        || self.ident(k - 1) == Some("mut");
                    if prev_ok && self.punct(k + 1, ":") {
                        out.push(s.clone());
                    }
                }
                _ => {}
            }
        }
        out
    }

    fn item_enum(&mut self, i: usize, end: usize, ctx: &Ctx, test: bool) -> usize {
        let Some(name) = self.ident(i + 1) else { return i + 1 };
        let mut j = self.skip_generics(i + 2, end);
        while j < end && !self.punct(j, "{") && !self.punct(j, ";") {
            j += 1;
        }
        if !self.punct(j, "{") {
            return j + 1;
        }
        let close = self.close_of(j, end, "{", "}");
        let mut variants = Vec::new();
        let mut k = j + 1;
        let mut entry_start = true;
        let mut depth = 0i32;
        while k < close {
            if depth == 0 {
                // skip variant attributes (`#[default]`)
                if entry_start && self.punct(k, "#") && self.punct(k + 1, "[") {
                    k = self.close_of(k + 1, close, "[", "]") + 1;
                    continue;
                }
                if entry_start {
                    if let Some(v) = self.ident(k) {
                        variants.push(v.to_string());
                        entry_start = false;
                    }
                }
                if self.punct(k, ",") {
                    entry_start = true;
                }
            }
            match self.t.get(k).map(|t| &t.tok) {
                Some(Tok::Punct(p)) if p == "(" || p == "[" || p == "{" => depth += 1,
                Some(Tok::Punct(p)) if p == ")" || p == "]" || p == "}" => depth -= 1,
                _ => {}
            }
            k += 1;
        }
        self.out.enums.push(EnumDecl {
            name: name.to_string(),
            mods: ctx.mods.clone(),
            line: self.line(i),
            variants,
            test,
        });
        close + 1
    }

    fn item_use(&mut self, i: usize, end: usize) -> usize {
        let line = self.line(i);
        let mut semi = i + 1;
        while semi < end && !self.punct(semi, ";") {
            semi += 1;
        }
        let mut leaves = Vec::new();
        self.use_tree(i + 1, semi, &mut Vec::new(), &mut leaves);
        for (segs, alias) in leaves {
            self.out.uses.push(UseDecl { segs, alias, line });
        }
        semi + 1
    }

    /// Expand a use tree in `[lo, hi)` under `prefix`, appending
    /// `(segments, alias)` leaves.
    fn use_tree(
        &self,
        lo: usize,
        hi: usize,
        prefix: &mut Vec<String>,
        out: &mut Vec<(Vec<String>, String)>,
    ) {
        let base = prefix.len();
        let mut i = lo;
        let mut flush = |prefix: &Vec<String>, alias: Option<String>| {
            if let Some(last) = prefix.last() {
                let alias = alias.unwrap_or_else(|| last.clone());
                if alias != "_" {
                    out.push((prefix.clone(), alias));
                }
            }
        };
        while i < hi {
            match self.t.get(i).map(|t| &t.tok) {
                Some(Tok::Ident(s)) if s == "as" => {
                    let alias = self.ident(i + 1).map(str::to_string);
                    flush(prefix, alias);
                    prefix.truncate(base);
                    i += 2;
                }
                Some(Tok::Ident(s)) => {
                    prefix.push(s.clone());
                    i += 1;
                }
                Some(Tok::Punct(p)) if p == "{" => {
                    let close = self.close_of(i, hi, "{", "}");
                    // each comma-separated subtree at depth 0
                    let mut part = i + 1;
                    let mut k = i + 1;
                    let mut depth = 0i32;
                    while k <= close {
                        let at_comma = depth == 0 && self.punct(k, ",");
                        if at_comma || k == close {
                            if part < k {
                                let mut sub = prefix.clone();
                                self.use_tree(part, k, &mut sub, out);
                            }
                            part = k + 1;
                        }
                        match self.t.get(k).map(|t| &t.tok) {
                            Some(Tok::Punct(p)) if p == "{" => depth += 1,
                            Some(Tok::Punct(p)) if p == "}" => depth -= 1,
                            _ => {}
                        }
                        k += 1;
                    }
                    prefix.truncate(base);
                    return;
                }
                Some(Tok::Punct(p)) if p == "*" => {
                    // glob import: not resolvable, drop
                    prefix.truncate(base);
                    return;
                }
                Some(Tok::Punct(p)) if p == "," => {
                    flush(prefix, None);
                    prefix.truncate(base);
                    i += 1;
                }
                _ => i += 1, // `::` and anything else
            }
        }
        if prefix.len() > base {
            flush(prefix, None);
        }
        prefix.truncate(base);
    }

    fn item_impl(&mut self, i: usize, end: usize, ctx: &Ctx, test: bool) -> usize {
        let mut j = self.skip_generics(i + 1, end);
        // head tokens up to the body `{`
        let mut head_start = j;
        while j < end && !self.punct(j, "{") && !self.punct(j, ";") {
            // skip generics attached to path segments (`Foo<T>`)
            if self.punct(j, "<") {
                j = self.skip_generics(j, end);
            } else {
                j += 1;
            }
        }
        if !self.punct(j, "{") {
            return j + 1;
        }
        // `impl Trait for Type` — the implementing type follows the
        // last `for`; otherwise the head is the type path itself.
        for k in head_start..j {
            if self.ident(k) == Some("for") {
                head_start = k + 1;
            }
        }
        let mut ty = None;
        for k in head_start..j {
            if let Some(s) = self.ident(k) {
                if s != "where" && s != "dyn" && s != "mut" {
                    ty = Some(s.to_string());
                    // the first path segment may be a module: prefer the
                    // last segment of a leading `a::b::C` path
                    let mut m = k;
                    while self.punct(m + 1, "::") && self.ident(m + 2).is_some() {
                        m += 2;
                    }
                    if let Some(last) = self.ident(m) {
                        ty = Some(last.to_string());
                    }
                    break;
                }
            }
        }
        let close = self.close_of(j, end, "{", "}");
        let inner = Ctx { mods: ctx.mods.clone(), owner: ty, test };
        self.items(j + 1, close, &inner);
        close + 1
    }

    fn item_trait(&mut self, i: usize, end: usize, ctx: &Ctx, test: bool) -> usize {
        let Some(name) = self.ident(i + 1) else { return i + 1 };
        let mut j = i + 2;
        while j < end && !self.punct(j, "{") && !self.punct(j, ";") {
            j += 1;
        }
        if !self.punct(j, "{") {
            return j + 1;
        }
        let close = self.close_of(j, end, "{", "}");
        let inner = Ctx { mods: ctx.mods.clone(), owner: Some(name.to_string()), test };
        self.items(j + 1, close, &inner);
        close + 1
    }

    fn skip_struct(&mut self, i: usize, end: usize) -> usize {
        let mut j = i + 1;
        while j < end {
            if self.punct(j, "{") {
                return self.close_of(j, end, "{", "}") + 1;
            }
            if self.punct(j, ";") {
                return j + 1;
            }
            j += 1;
        }
        end
    }

    fn skip_to_semi(&mut self, i: usize, end: usize) -> usize {
        let mut j = i + 1;
        let mut depth = 0i32;
        while j < end {
            match self.t.get(j).map(|t| &t.tok) {
                Some(Tok::Punct(p)) if p == "(" || p == "[" || p == "{" => depth += 1,
                Some(Tok::Punct(p)) if p == ")" || p == "]" || p == "}" => depth -= 1,
                Some(Tok::Punct(p)) if p == ";" && depth <= 0 => return j + 1,
                _ => {}
            }
            j += 1;
        }
        end
    }

    fn skip_macro(&mut self, i: usize, end: usize) -> usize {
        let mut j = i + 1;
        while j < end && !self.punct(j, "{") {
            j += 1;
        }
        if self.punct(j, "{") {
            self.close_of(j, end, "{", "}") + 1
        } else {
            j + 1
        }
    }

    /// Parse the block `t[open] == '{'` .. `t[close] == '}'`.
    fn block(&mut self, open: usize, close: usize) -> Block {
        Block { stmts: self.stmts(open + 1, close) }
    }

    /// Split `[lo, hi)` into statements, recursing into nested braces.
    fn stmts(&mut self, lo: usize, hi: usize) -> Vec<Stmt> {
        let mut out = Vec::new();
        let mut i = lo;
        while i < hi {
            let line = self.line(i);
            let let_name = self.let_binding(i);
            let mut head: Vec<usize> = Vec::new();
            let mut subs: Vec<Sub> = Vec::new();
            // position (in `head`) of the latest un-consumed `match`
            let mut match_kw: Option<usize> = None;
            let mut pdepth = 0i32;
            while i < hi {
                if self.punct(i, "{") {
                    let close = self.close_of(i, hi, "{", "}");
                    if let Some(kw) = match_kw.take() {
                        let scrutinee: Vec<usize> = head[kw + 1..].to_vec();
                        head.truncate(kw + 1);
                        let mline = self.line(head[kw]);
                        let arms = self.arms(i, close);
                        subs.push(Sub::Match(MatchExpr { line: mline, scrutinee, arms }));
                    } else {
                        let b = self.block(i, close);
                        subs.push(Sub::Block(b));
                    }
                    i = close + 1;
                    if pdepth > 0 {
                        continue; // closure/block inside parens: same stmt
                    }
                    // `} else {`, `}.method()`, `}?` continue the
                    // statement; anything else ends it.
                    let continues = self.ident(i) == Some("else")
                        || self.punct(i, ".")
                        || self.punct(i, "?");
                    if continues {
                        continue;
                    }
                    break;
                }
                if self.punct(i, ";") && pdepth <= 0 {
                    i += 1;
                    break;
                }
                if self.ident(i) == Some("match") {
                    match_kw = Some(head.len());
                }
                match self.t.get(i).map(|t| &t.tok) {
                    Some(Tok::Punct(p)) if p == "(" || p == "[" => pdepth += 1,
                    Some(Tok::Punct(p)) if p == ")" || p == "]" => pdepth -= 1,
                    _ => {}
                }
                head.push(i);
                i += 1;
            }
            if !head.is_empty() || !subs.is_empty() {
                out.push(Stmt { line, let_name, head, subs });
            }
        }
        out
    }

    /// `Some(name)` when the statement at `i` is `let [mut] name ...`
    /// with a plain identifier pattern.
    fn let_binding(&self, i: usize) -> Option<String> {
        if self.ident(i) != Some("let") {
            return None;
        }
        let mut j = i + 1;
        if self.ident(j) == Some("mut") {
            j += 1;
        }
        let name = self.ident(j)?;
        // a plain binding is followed by `:` or `=`; `Some(x)` / tuple
        // patterns are not bindings of `name`
        if self.punct(j + 1, ":") || self.punct(j + 1, "=") {
            Some(name.to_string())
        } else {
            None
        }
    }

    /// Parse the arms of a match whose braces are `t[open]`/`t[close]`.
    fn arms(&mut self, open: usize, close: usize) -> Vec<Arm> {
        let mut out = Vec::new();
        let mut i = open + 1;
        while i < close {
            let line = self.line(i);
            // pattern: tokens to `=>` at depth 0; an `if` at depth 0
            // starts a guard
            let mut pat: Vec<usize> = Vec::new();
            let mut guarded = false;
            let mut depth = 0i32;
            while i < close && !(depth <= 0 && self.punct(i, "=>")) {
                match self.t.get(i).map(|t| &t.tok) {
                    Some(Tok::Punct(p)) if p == "(" || p == "[" || p == "{" => depth += 1,
                    Some(Tok::Punct(p)) if p == ")" || p == "]" || p == "}" => depth -= 1,
                    _ => {}
                }
                if depth <= 0 && self.ident(i) == Some("if") {
                    guarded = true;
                }
                if !guarded {
                    pat.push(i);
                }
                i += 1;
            }
            if i >= close {
                break;
            }
            i += 1; // past `=>`
            let body = if self.punct(i, "{") {
                let bclose = self.close_of(i, close, "{", "}");
                let b = self.block(i, bclose);
                i = bclose + 1;
                if self.punct(i, ",") {
                    i += 1;
                }
                b
            } else {
                // expression body: to `,` at depth 0 or the match close
                let lo = i;
                let mut depth = 0i32;
                while i < close && !(depth <= 0 && self.punct(i, ",")) {
                    match self.t.get(i).map(|t| &t.tok) {
                        Some(Tok::Punct(p)) if p == "(" || p == "[" || p == "{" => depth += 1,
                        Some(Tok::Punct(p)) if p == ")" || p == "]" || p == "}" => depth -= 1,
                        _ => {}
                    }
                    i += 1;
                }
                let b = Block { stmts: self.stmts(lo, i) };
                if self.punct(i, ",") {
                    i += 1;
                }
                b
            };
            if pat.is_empty() && body.stmts.is_empty() {
                break;
            }
            out.push(Arm { line, pat, guarded, body });
        }
        out
    }
}

/// Is the arm pattern a bare wildcard (`_`, optionally guarded)?
pub fn arm_is_wildcard(toks: &[Token], arm: &Arm) -> bool {
    let idents: Vec<&str> = arm
        .pat
        .iter()
        .filter_map(|&k| match toks.get(k).map(|t| &t.tok) {
            Some(Tok::Ident(s)) => Some(s.as_str()),
            _ => None,
        })
        .collect();
    idents == ["_"]
}

#[cfg(test)]
mod tests {
    use super::super::lexer::lex;
    use super::*;

    fn parse_src(src: &str) -> Ast {
        parse(&lex(src))
    }

    #[test]
    fn recovers_fns_impls_and_mods() {
        let src = "mod outer {\n    impl Widget {\n        fn poke(&self, n: u32) -> u32 { n }\n    }\n    fn free() {}\n}\n";
        let ast = parse_src(src);
        assert_eq!(ast.fns.len(), 2);
        assert_eq!(ast.fns[0].name, "poke");
        assert_eq!(ast.fns[0].owner.as_deref(), Some("Widget"));
        assert_eq!(ast.fns[0].mods, vec!["outer"]);
        assert_eq!(ast.fns[0].params, vec!["n"]);
        assert_eq!(ast.fns[1].name, "free");
        assert_eq!(ast.fns[1].owner, None);
    }

    #[test]
    fn trait_impls_attribute_methods_to_the_type() {
        let src = "impl std::fmt::Display for Badge {\n    fn fmt(&self) {}\n}\n";
        let ast = parse_src(src);
        assert_eq!(ast.fns.len(), 1);
        assert_eq!(ast.fns[0].owner.as_deref(), Some("Badge"));
    }

    #[test]
    fn blocks_scope_statements() {
        let src = "fn f() {\n    let a = { let g = acquire(); use_it(g) };\n    later(a);\n}\n";
        let ast = parse_src(src);
        let body = &ast.fns[0].body;
        assert_eq!(body.stmts.len(), 2);
        assert_eq!(body.stmts[0].let_name.as_deref(), Some("a"));
        assert_eq!(body.stmts[0].subs.len(), 1);
        let Sub::Block(inner) = &body.stmts[0].subs[0] else {
            panic!("expected nested block");
        };
        assert_eq!(inner.stmts.len(), 2);
        assert_eq!(inner.stmts[0].let_name.as_deref(), Some("g"));
    }

    #[test]
    fn match_arms_are_recovered() {
        let src = "fn f(k: Kind) -> u32 {\n    match k {\n        Kind::A => 1,\n        Kind::B { x } => x,\n        _ => 0,\n    }\n}\n";
        let ast = parse_src(src);
        let body = &ast.fns[0].body;
        assert_eq!(body.stmts.len(), 1);
        let Sub::Match(m) = &body.stmts[0].subs[0] else {
            panic!("expected match");
        };
        assert_eq!(m.arms.len(), 3);
        let lexed = lex(src);
        assert!(!arm_is_wildcard(&lexed.tokens, &m.arms[0]));
        assert!(!arm_is_wildcard(&lexed.tokens, &m.arms[1]));
        assert!(arm_is_wildcard(&lexed.tokens, &m.arms[2]));
        assert_eq!(m.arms[2].line, 5);
    }

    #[test]
    fn use_trees_expand_with_aliases() {
        let src = "use crate::util::json::{self, Value as V, parse};\nuse super::lexer::lex;\n";
        let ast = parse_src(src);
        let mut pairs: Vec<(String, String)> =
            ast.uses.iter().map(|u| (u.alias.clone(), u.segs.join("::"))).collect();
        pairs.sort();
        assert!(pairs.contains(&("V".to_string(), "crate::util::json::Value".to_string())));
        assert!(pairs.contains(&("parse".to_string(), "crate::util::json::parse".to_string())));
        assert!(pairs.contains(&("lex".to_string(), "super::lexer::lex".to_string())));
    }

    #[test]
    fn enums_list_variants() {
        let src = "pub enum Kind {\n    #[default]\n    A,\n    B(u32),\n    C { x: u8 },\n}\n";
        let ast = parse_src(src);
        assert_eq!(ast.enums.len(), 1);
        assert_eq!(ast.enums[0].variants, vec!["A", "B", "C"]);
    }

    #[test]
    fn test_items_are_flagged() {
        let src = "#[cfg(test)]\nmod tests {\n    fn helper() {}\n}\n#[test]\nfn t() {}\nfn live() {}\n";
        let ast = parse_src(src);
        let by_name = |n: &str| ast.fns.iter().find(|f| f.name == n).map(|f| f.test);
        assert_eq!(by_name("helper"), Some(true));
        assert_eq!(by_name("t"), Some(true));
        assert_eq!(by_name("live"), Some(false));
    }

    #[test]
    fn match_scrutinee_stays_in_head() {
        let src = "fn f() {\n    let job = match q.lock() {\n        Ok(rx) => rx.recv(),\n        Err(_) => return,\n    };\n}\n";
        let ast = parse_src(src);
        let stmt = &ast.fns[0].body.stmts[0];
        let Sub::Match(m) = &stmt.subs[0] else { panic!("expected match") };
        assert_eq!(m.arms.len(), 2);
        assert!(!m.scrutinee.is_empty());
    }
}
