//! Rule passes over the lexed token stream (`D1`..`D7`) and the
//! recovered structure (`L2`..`L5`).
//!
//! The `D` passes are linear walks with small, bounded look-around — no
//! AST, no type information. That keeps the analyzer dependency-free and
//! fast, at the cost of approximation; the approximations are chosen so
//! false *negatives* are possible but false *positives* are rare, and
//! every remaining false positive can carry a reasoned pragma.
//!
//! The `L` passes ([`scan_ast`], plus the repo-level drift helpers
//! [`drift_flags`]/[`drift_config_keys`] and the call-graph `L1` pass in
//! [`super::graph`]) layer structure on top: function scope and taint
//! for `L3`, match arms for `L4`, and cross-artifact consistency for
//! `L5` (DESIGN.md §16).
//!
//! All passes skip `#[cfg(test)]` / `#[test]` item bodies: the
//! invariants protect shipped artifacts, and tests legitimately
//! `unwrap`, time things, and accumulate ad-hoc sums.

use std::collections::{BTreeMap, BTreeSet};

use super::ast::{arm_is_wildcard, Ast, Block, FnDecl, Sub};
use super::lexer::{is_float_literal, Lexed, Tok, Token};
use super::Rule;

/// A rule hit before suppression (pragma / allowlist) is applied.
#[derive(Debug, Clone)]
pub struct RawFinding {
    /// Which rule fired.
    pub rule: Rule,
    /// 1-based line of the offending token.
    pub line: u32,
    /// One-sentence description of what fired.
    pub note: String,
}

/// Files whose whole job is canonical float accumulation (D2 exempt).
const FOLD_SITES: [&str; 2] = ["coordinator/aggregate.rs", "metrics/welford.rs"];
/// Files whose whole job is canonical float formatting (D5 exempt).
const FORMAT_SITES: [&str; 2] = ["report/mod.rs", "util/json.rs"];

/// Run every rule pass over one lexed file. `path` selects the per-file
/// exemptions (the canonical fold/format sites check themselves against
/// every *other* rule, but are the one sanctioned home of their own).
pub fn scan(path: &str, lexed: &Lexed) -> Vec<RawFinding> {
    let toks = &lexed.tokens;
    let test = test_mask(toks);
    let mut out = Vec::new();
    d1_map_iteration(toks, &test, &mut out);
    if !path_matches(path, &FOLD_SITES) {
        d2_float_accum(toks, &test, &mut out);
    }
    d3_narrowing_cast(toks, &test, &mut out);
    d4_panic_path(toks, &test, &mut out);
    if !path_matches(path, &FORMAT_SITES) {
        d5_float_format(toks, &test, &mut out);
    }
    d6_wall_clock(toks, &test, &mut out);
    if !in_obs(path) {
        d7_time_quarantine(toks, &test, &mut out);
    }
    out
}

/// Is `path` inside the observability quarantine (`rust/src/obs/`)?
/// D7 exempts the quarantine itself — it is the one sanctioned home of
/// the time and trace primitives.
fn in_obs(path: &str) -> bool {
    path.contains("/obs/") || path.starts_with("obs/")
}

fn path_matches(path: &str, sites: &[&str]) -> bool {
    sites.iter().any(|s| path == *s || path.ends_with(&format!("/{s}")))
}

// ---------------------------------------------------------------------------
// token helpers

fn ident_at<'a>(toks: &'a [Token], i: usize) -> Option<&'a str> {
    match toks.get(i).map(|t| &t.tok) {
        Some(Tok::Ident(s)) => Some(s.as_str()),
        _ => None,
    }
}

fn punct_at(toks: &[Token], i: usize, op: &str) -> bool {
    matches!(toks.get(i).map(|t| &t.tok), Some(Tok::Punct(p)) if p == op)
}

fn any_punct_at<'a>(toks: &'a [Token], i: usize) -> Option<&'a str> {
    match toks.get(i).map(|t| &t.tok) {
        Some(Tok::Punct(p)) => Some(p.as_str()),
        _ => None,
    }
}

/// Track `(`/`[`/`{` nesting while scanning forward; returns the new depth.
fn bump_depth(depth: i32, tok: &Tok) -> i32 {
    match tok {
        Tok::Punct(p) if p == "(" || p == "[" || p == "{" => depth + 1,
        Tok::Punct(p) if p == ")" || p == "]" || p == "}" => depth - 1,
        _ => depth,
    }
}

// ---------------------------------------------------------------------------
// test-region detection

/// Per-token mask: `true` when the token sits inside the body of an
/// item annotated `#[test]` or `#[cfg(test)]` (or any `cfg(...)` whose
/// arguments mention `test` without a leading `not`). All rules skip
/// masked tokens.
fn test_mask(toks: &[Token]) -> Vec<bool> {
    let mut mask = vec![false; toks.len()];
    let mut i = 0;
    while i < toks.len() {
        if punct_at(toks, i, "#") && punct_at(toks, i + 1, "[") {
            let close = match_delim(toks, i + 1, "[", "]");
            if is_test_attr(toks, i + 2, close) {
                if let Some((open, end)) = item_body(toks, close + 1) {
                    for m in mask.iter_mut().take(end + 1).skip(open) {
                        *m = true;
                    }
                    i = end + 1;
                    continue;
                }
            }
            i = close + 1;
        } else {
            i += 1;
        }
    }
    mask
}

/// Index of the delimiter matching `toks[open]` (which must be `open_d`);
/// the token stream's end if unbalanced.
fn match_delim(toks: &[Token], open: usize, open_d: &str, close_d: &str) -> usize {
    let mut depth = 0usize;
    let mut i = open;
    while i < toks.len() {
        if punct_at(toks, i, open_d) {
            depth += 1;
        } else if punct_at(toks, i, close_d) {
            depth -= 1;
            if depth == 0 {
                return i;
            }
        }
        i += 1;
    }
    toks.len().saturating_sub(1)
}

/// Does `toks[start..end]` spell a test attribute? Exactly `test`, or
/// `cfg(...)` whose arguments mention `test` and do not start with `not`.
fn is_test_attr(toks: &[Token], start: usize, end: usize) -> bool {
    if end <= start {
        return false;
    }
    if end - start == 1 {
        return ident_at(toks, start) == Some("test");
    }
    if ident_at(toks, start) == Some("cfg") && punct_at(toks, start + 1, "(") {
        let args: Vec<&str> =
            (start + 2..end).filter_map(|k| ident_at(toks, k)).collect();
        return args.first() != Some(&"not") && args.contains(&"test");
    }
    false
}

/// Given the token index just past an attribute, skip any further
/// stacked attributes and return the `{`..`}` span of the annotated
/// item's body (`None` for bodyless items like `use ...;`).
fn item_body(toks: &[Token], mut i: usize) -> Option<(usize, usize)> {
    while punct_at(toks, i, "#") && punct_at(toks, i + 1, "[") {
        i = match_delim(toks, i + 1, "[", "]") + 1;
    }
    while i < toks.len() {
        if punct_at(toks, i, "{") {
            return Some((i, match_delim(toks, i, "{", "}")));
        }
        if punct_at(toks, i, ";") {
            return None;
        }
        i += 1;
    }
    None
}

// ---------------------------------------------------------------------------
// D1: HashMap/HashSet iteration

const MAP_TYPES: [&str; 2] = ["HashMap", "HashSet"];
const ITER_METHODS: [&str; 9] = [
    "iter", "iter_mut", "keys", "values", "values_mut", "into_iter", "drain", "retain",
    "extract_if",
];

fn d1_map_iteration(toks: &[Token], test: &[bool], out: &mut Vec<RawFinding>) {
    let names = hash_bound_names(toks);
    if names.is_empty() {
        return;
    }
    for i in 0..toks.len() {
        if test[i] {
            continue;
        }
        // name.iter() / name.drain() / ...
        if let Some(name) = ident_at(toks, i) {
            if names.contains(name)
                && punct_at(toks, i + 1, ".")
                && ident_at(toks, i + 2).is_some_and(|m| ITER_METHODS.contains(&m))
                && punct_at(toks, i + 3, "(")
            {
                let method = ident_at(toks, i + 2).unwrap_or("iter");
                out.push(RawFinding {
                    rule: Rule::MapIteration,
                    line: toks[i + 2].line,
                    note: format!(
                        "`{name}.{method}()` iterates a HashMap/HashSet — order is \
                         nondeterministic; sort the items or use a BTree collection"
                    ),
                });
            }
        }
        // for k in &map { ... } / for k in map { ... }
        if ident_at(toks, i) == Some("in") {
            let mut j = i + 1;
            while punct_at(toks, j, "&") || ident_at(toks, j) == Some("mut") {
                j += 1;
            }
            if let Some(name) = ident_at(toks, j) {
                if names.contains(name) && punct_at(toks, j + 1, "{") {
                    out.push(RawFinding {
                        rule: Rule::MapIteration,
                        line: toks[j].line,
                        note: format!(
                            "`for _ in {name}` iterates a HashMap/HashSet — order is \
                             nondeterministic; sort the items or use a BTree collection"
                        ),
                    });
                }
            }
        }
    }
}

/// Names plausibly bound to a `HashMap`/`HashSet`: `let` bindings whose
/// initializing statement mentions a hash type at bracket depth 0, and
/// `name: ...HashMap...` field/parameter declarations.
fn hash_bound_names(toks: &[Token]) -> BTreeSet<String> {
    let mut names = BTreeSet::new();
    for i in 0..toks.len() {
        if ident_at(toks, i) == Some("let") {
            let mut j = i + 1;
            if ident_at(toks, j) == Some("mut") {
                j += 1;
            }
            if let Some(name) = ident_at(toks, j) {
                if span_mentions_hash(toks, j + 1, &[";"]) {
                    names.insert(name.to_string());
                }
            }
        }
        if let Some(name) = ident_at(toks, i) {
            if punct_at(toks, i + 1, ":") && span_mentions_hash(toks, i + 2, &[",", ";", "="]) {
                names.insert(name.to_string());
            }
        }
    }
    names
}

/// Scan forward from `start` for a `HashMap`/`HashSet` ident at bracket
/// depth 0, stopping at any of `stops` (depth 0), a closing delimiter,
/// or a bounded horizon.
fn span_mentions_hash(toks: &[Token], start: usize, stops: &[&str]) -> bool {
    let mut depth = 0i32;
    for k in start..toks.len().min(start + 100) {
        if depth == 0 {
            if let Some(p) = any_punct_at(toks, k) {
                if stops.contains(&p) || p == ")" || p == "}" || p == "]" {
                    return false;
                }
            }
            if ident_at(toks, k).is_some_and(|s| MAP_TYPES.contains(&s)) {
                return true;
            }
        }
        depth = bump_depth(depth, &toks[k].tok);
        if depth < 0 {
            return false;
        }
    }
    false
}

// ---------------------------------------------------------------------------
// D2: float accumulation

const FLOAT_TYPES: [&str; 2] = ["f64", "f32"];

fn d2_float_accum(toks: &[Token], test: &[bool], out: &mut Vec<RawFinding>) {
    let floats = float_bound_names(toks);
    for i in 0..toks.len() {
        if test[i] {
            continue;
        }
        // accumulate in place: floatvar += ... / floatvar -= ...
        if let Some(name) = ident_at(toks, i) {
            if floats.contains(name)
                && any_punct_at(toks, i + 1).is_some_and(|p| p == "+=" || p == "-=")
            {
                out.push(RawFinding {
                    rule: Rule::FloatAccum,
                    line: toks[i + 1].line,
                    note: format!(
                        "in-place float accumulation on `{name}` — route through the \
                         canonical fold (Aggregator/Welford) to keep summation order fixed"
                    ),
                });
            }
        }
        // .sum::<f64>() / .product::<f32>() / .sum() with a float let nearby
        if punct_at(toks, i, ".") {
            if let Some(m) = ident_at(toks, i + 1) {
                if m == "sum" || m == "product" {
                    let turbofish = punct_at(toks, i + 2, "::")
                        && punct_at(toks, i + 3, "<")
                        && ident_at(toks, i + 4).is_some_and(|t| FLOAT_TYPES.contains(&t));
                    let inferred =
                        punct_at(toks, i + 2, "(") && stmt_has_float_let(toks, i);
                    if turbofish || inferred {
                        out.push(RawFinding {
                            rule: Rule::FloatAccum,
                            line: toks[i + 1].line,
                            note: format!(
                                "floating-point `.{m}()` outside the approved \
                                 canonical-fold sites — order of reduction must be pinned"
                            ),
                        });
                    }
                }
                // .fold(0.0, ...) — float initial accumulator
                if m == "fold" && punct_at(toks, i + 2, "(") && fold_init_is_float(toks, i + 3) {
                    out.push(RawFinding {
                        rule: Rule::FloatAccum,
                        line: toks[i + 1].line,
                        note: "float-seeded `.fold()` outside the approved canonical-fold \
                               sites — order of reduction must be pinned"
                            .to_string(),
                    });
                }
            }
        }
    }
}

/// Names bound by `let` to an explicit `f64`/`f32` annotation or a
/// float-literal initializer.
fn float_bound_names(toks: &[Token]) -> BTreeSet<String> {
    let mut names = BTreeSet::new();
    for i in 0..toks.len() {
        if ident_at(toks, i) != Some("let") {
            continue;
        }
        let mut j = i + 1;
        if ident_at(toks, j) == Some("mut") {
            j += 1;
        }
        let Some(name) = ident_at(toks, j) else { continue };
        let mut is_float = false;
        if punct_at(toks, j + 1, ":") {
            let mut depth = 0i32;
            for k in j + 2..toks.len().min(j + 40) {
                if depth == 0 && (punct_at(toks, k, "=") || punct_at(toks, k, ";")) {
                    break;
                }
                if depth == 0 && ident_at(toks, k).is_some_and(|t| FLOAT_TYPES.contains(&t)) {
                    is_float = true;
                    break;
                }
                depth = bump_depth(depth, &toks[k].tok);
            }
        } else if punct_at(toks, j + 1, "=") {
            if let Some(Tok::Num(n)) = toks.get(j + 2).map(|t| &t.tok) {
                is_float = is_float_literal(n);
            }
        }
        if is_float {
            names.insert(name.to_string());
        }
    }
    names
}

/// Walk back from a `.sum()`/`.product()` to the start of its statement
/// looking for `let ...: f64/f32` / a float literal — evidence that the
/// untyped reduction is floating-point.
fn stmt_has_float_let(toks: &[Token], i: usize) -> bool {
    let lo = i.saturating_sub(120);
    let mut saw_let = false;
    let mut saw_float = false;
    for k in (lo..i).rev() {
        match &toks[k].tok {
            Tok::Punct(p) if p == ";" || p == "{" => break,
            Tok::Ident(s) if s == "let" => saw_let = true,
            Tok::Ident(s) if FLOAT_TYPES.contains(&s.as_str()) => saw_float = true,
            Tok::Num(n) if is_float_literal(n) => saw_float = true,
            _ => {}
        }
    }
    saw_let && saw_float
}

/// Is the first argument of `.fold(` (starting at `start`, just past the
/// `(`) a float literal or float-typed expression?
fn fold_init_is_float(toks: &[Token], start: usize) -> bool {
    let mut depth = 0i32;
    for k in start..toks.len().min(start + 12) {
        if depth == 0 {
            if punct_at(toks, k, ",") || punct_at(toks, k, ")") {
                return false;
            }
            match &toks[k].tok {
                Tok::Num(n) if is_float_literal(n) => return true,
                Tok::Ident(s) if FLOAT_TYPES.contains(&s.as_str()) => return true,
                _ => {}
            }
        }
        depth = bump_depth(depth, &toks[k].tok);
        if depth < 0 {
            return false;
        }
    }
    false
}

// ---------------------------------------------------------------------------
// D3: `as` narrowing casts in parser scope

const NARROW_INTS: [&str; 12] = [
    "u8", "u16", "u32", "u64", "u128", "usize", "i8", "i16", "i32", "i64", "i128", "isize",
];

fn d3_narrowing_cast(toks: &[Token], test: &[bool], out: &mut Vec<RawFinding>) {
    for (lo, hi) in parser_fn_bodies(toks) {
        for i in lo..=hi.min(toks.len().saturating_sub(1)) {
            if test[i] {
                continue;
            }
            if ident_at(toks, i) == Some("as") {
                if let Some(ty) = ident_at(toks, i + 1) {
                    if NARROW_INTS.contains(&ty) {
                        out.push(RawFinding {
                            rule: Rule::NarrowingCast,
                            line: toks[i].line,
                            note: format!(
                                "`as {ty}` on parser-reachable data — use \
                                 try_from/try_into with a descriptive error"
                            ),
                        });
                    }
                }
            }
        }
    }
}

/// Body spans of functions that handle parser output: named
/// `from_value`/`from_*`/`parse*`, or whose signature mentions `Value`
/// or `toml_lite`.
fn parser_fn_bodies(toks: &[Token]) -> Vec<(usize, usize)> {
    let mut spans = Vec::new();
    let mut i = 0;
    while i < toks.len() {
        if ident_at(toks, i) == Some("fn") {
            if let Some(name) = ident_at(toks, i + 1) {
                let mut sig_hit = name == "from_value"
                    || name.starts_with("from_")
                    || name.starts_with("parse");
                let mut j = i + 2;
                let mut body = None;
                while j < toks.len().min(i + 220) {
                    if punct_at(toks, j, "{") {
                        body = Some((j, match_delim(toks, j, "{", "}")));
                        break;
                    }
                    if punct_at(toks, j, ";") {
                        break;
                    }
                    if ident_at(toks, j).is_some_and(|s| s == "Value" || s == "toml_lite") {
                        sig_hit = true;
                    }
                    j += 1;
                }
                if sig_hit {
                    if let Some((open, close)) = body {
                        spans.push((open, close));
                        i = close + 1;
                        continue;
                    }
                }
            }
        }
        i += 1;
    }
    spans
}

// ---------------------------------------------------------------------------
// D4: unwrap/expect/panic! in library code

const PANIC_MACROS: [&str; 4] = ["panic", "unreachable", "todo", "unimplemented"];

fn d4_panic_path(toks: &[Token], test: &[bool], out: &mut Vec<RawFinding>) {
    for i in 0..toks.len() {
        if test[i] {
            continue;
        }
        if punct_at(toks, i, ".")
            && ident_at(toks, i + 1).is_some_and(|m| m == "unwrap" || m == "expect")
            && punct_at(toks, i + 2, "(")
        {
            let method = ident_at(toks, i + 1).unwrap_or("unwrap");
            out.push(RawFinding {
                rule: Rule::PanicPath,
                line: toks[i + 1].line,
                note: format!(
                    "`.{method}()` in library code — propagate an anyhow error instead"
                ),
            });
        }
        if ident_at(toks, i).is_some_and(|m| PANIC_MACROS.contains(&m))
            && punct_at(toks, i + 1, "!")
        {
            let mac = ident_at(toks, i).unwrap_or("panic");
            out.push(RawFinding {
                rule: Rule::PanicPath,
                line: toks[i].line,
                note: format!("`{mac}!` in library code — return an anyhow error instead"),
            });
        }
    }
}

// ---------------------------------------------------------------------------
// D5: direct float formatting

const FORMAT_MACROS: [&str; 12] = [
    "format", "write", "writeln", "print", "println", "eprint", "eprintln", "format_args",
    "assert", "assert_eq", "assert_ne", "debug_assert",
];

fn d5_float_format(toks: &[Token], test: &[bool], out: &mut Vec<RawFinding>) {
    for i in 0..toks.len() {
        if test[i] {
            continue;
        }
        let Tok::Str(content) = &toks[i].tok else { continue };
        if !in_format_macro(toks, i) {
            continue;
        }
        if let Some(spec) = float_format_spec(content) {
            out.push(RawFinding {
                rule: Rule::FloatFormat,
                line: toks[i].line,
                note: format!(
                    "float format spec `{{:{spec}}}` outside report::canon/csv_cell — \
                     canonical formatting keeps artifacts byte-identical"
                ),
            });
        }
    }
}

/// Is the string at `i` an argument of a formatting macro call? (Looks
/// back a few tokens for `ident !` followed by an open delimiter.)
fn in_format_macro(toks: &[Token], i: usize) -> bool {
    let lo = i.saturating_sub(8);
    for k in (lo..i).rev() {
        if punct_at(toks, k, "!")
            && ident_at(toks, k.wrapping_sub(1)).is_some_and(|m| FORMAT_MACROS.contains(&m))
        {
            return true;
        }
        // a statement boundary between the macro and the string breaks the link
        if punct_at(toks, k, ";") {
            return false;
        }
    }
    false
}

/// The first float-smelling format spec in a format string: explicit
/// precision (`{:.3}`) or scientific (`{:e}`), excluding Debug (`?`) and
/// integer-radix (`x`/`X`/`b`/`o`) specs.
fn float_format_spec(s: &str) -> Option<String> {
    let bytes = s.as_bytes();
    let mut i = 0;
    while i < bytes.len() {
        if bytes[i] != b'{' {
            i += 1;
            continue;
        }
        if bytes.get(i + 1) == Some(&b'{') {
            i += 2; // escaped brace
            continue;
        }
        let Some(end) = s[i..].find('}').map(|off| i + off) else { return None };
        let seg = &s[i + 1..end];
        if let Some((_, spec)) = seg.split_once(':') {
            let benign =
                spec.contains('?') || spec.contains(['x', 'X', 'b', 'o']);
            let floaty = spec.contains('.') || spec.ends_with('e') || spec.ends_with('E');
            if floaty && !benign {
                return Some(spec.to_string());
            }
        }
        i = end + 1;
    }
    None
}

// ---------------------------------------------------------------------------
// D6: wall-clock reads

fn d6_wall_clock(toks: &[Token], test: &[bool], out: &mut Vec<RawFinding>) {
    for i in 0..toks.len() {
        if test[i] {
            continue;
        }
        if ident_at(toks, i) == Some("Instant")
            && punct_at(toks, i + 1, "::")
            && ident_at(toks, i + 2) == Some("now")
        {
            out.push(RawFinding {
                rule: Rule::WallClock,
                line: toks[i].line,
                note: "`Instant::now()` — wall-clock reads must not influence result \
                       artifacts"
                    .to_string(),
            });
        }
        if ident_at(toks, i) == Some("SystemTime") && punct_at(toks, i + 1, "::") {
            out.push(RawFinding {
                rule: Rule::WallClock,
                line: toks[i].line,
                note: "`SystemTime` — wall-clock reads must not influence result artifacts"
                    .to_string(),
            });
        }
    }
}

// ---------------------------------------------------------------------------
// D7: the observability quarantine

/// Idents that may appear only under `rust/src/obs/`: the raw clock
/// types and the trace-sink internals. `Duration` stays legal everywhere
/// (a span of time is data, not a clock read); the quarantined surface
/// is anything that can *read* a clock or write a trace without going
/// through `obs::Stopwatch` / `obs::Tracer`.
const QUARANTINED: [&str; 4] = ["Instant", "SystemTime", "TraceSink", "emit_record"];

fn d7_time_quarantine(toks: &[Token], test: &[bool], out: &mut Vec<RawFinding>) {
    for i in 0..toks.len() {
        if test[i] {
            continue;
        }
        if let Some(name) = ident_at(toks, i) {
            if QUARANTINED.contains(&name) {
                out.push(RawFinding {
                    rule: Rule::TimeQuarantine,
                    line: toks[i].line,
                    note: format!(
                        "`{name}` outside rust/src/obs/ — time and trace primitives are \
                         quarantined there; use obs::Stopwatch / obs::Tracer"
                    ),
                });
            }
        }
    }
}

// ---------------------------------------------------------------------------
// structural passes: L2 / L3 / L4

/// Run the structural rule passes (`L2` atomic hygiene, `L3` tainted
/// arithmetic, `L4` wildcard arms) over one parsed file. `L1` needs the
/// whole-crate call graph and lives in [`super::graph::lock_order`];
/// `L5` needs repo context and lives in [`drift_flags`] /
/// [`drift_config_keys`].
pub fn scan_ast(lexed: &Lexed, ast: &Ast) -> Vec<RawFinding> {
    let toks = &lexed.tokens;
    let test = test_mask(toks);
    let mut out = Vec::new();
    l2_atomic_hygiene(toks, &test, &mut out);
    l3_tainted_arith(toks, ast, &mut out);
    l4_wildcard_arm(toks, ast, &mut out);
    out
}

/// Atomic methods that take an `Ordering` argument — used to attribute
/// orderings to the receiving field for the mixing check.
const ATOMIC_METHODS: [&str; 11] = [
    "load", "store", "swap", "fetch_add", "fetch_sub", "fetch_and", "fetch_or", "fetch_xor",
    "fetch_update", "compare_exchange", "compare_exchange_weak",
];
/// The non-saturating read-modify-write methods L2 flags outright.
const ATOMIC_RMW: [&str; 2] = ["fetch_add", "fetch_sub"];
/// Memory-ordering variants, strongest first.
const ORDERINGS: [&str; 5] = ["SeqCst", "AcqRel", "Acquire", "Release", "Relaxed"];

fn l2_atomic_hygiene(toks: &[Token], test: &[bool], out: &mut Vec<RawFinding>) {
    // receiver ident -> set of (ordering, first line seen)
    let mut orderings: BTreeMap<String, BTreeSet<(String, u32)>> = BTreeMap::new();
    for i in 0..toks.len() {
        if test[i] || !punct_at(toks, i, ".") {
            continue;
        }
        let Some(method) = ident_at(toks, i + 1) else { continue };
        if !ATOMIC_METHODS.contains(&method) || !punct_at(toks, i + 2, "(") {
            continue;
        }
        let close = match_delim(toks, i + 2, "(", ")");
        let mut saw_ordering = false;
        for k in i + 3..close {
            if ident_at(toks, k) == Some("Ordering") && punct_at(toks, k + 1, "::") {
                if let Some(o) = ident_at(toks, k + 2) {
                    if ORDERINGS.contains(&o) {
                        saw_ordering = true;
                        let recv =
                            ident_at(toks, i.wrapping_sub(1)).unwrap_or("<expr>").to_string();
                        orderings
                            .entry(recv)
                            .or_default()
                            .insert((o.to_string(), toks[k + 2].line));
                    }
                }
            }
        }
        // Only an Ordering argument marks the receiver as an atomic —
        // `.load()` exists on plenty of non-atomic types.
        if saw_ordering && ATOMIC_RMW.contains(&method) {
            let recv = ident_at(toks, i.wrapping_sub(1)).unwrap_or("<expr>");
            out.push(RawFinding {
                rule: Rule::AtomicHygiene,
                line: toks[i + 1].line,
                note: format!(
                    "non-saturating `.{method}()` on atomic `{recv}` — counters must \
                     saturate (fetch_update + saturating_add, see obs::Counter); waive \
                     only where the previous value itself is the point"
                ),
            });
        }
    }
    for (recv, set) in orderings {
        let has_seqcst = set.iter().any(|(o, _)| o == "SeqCst");
        let weakest: Option<u32> =
            set.iter().filter(|(o, _)| o != "SeqCst").map(|(_, l)| *l).min();
        if let (true, Some(line)) = (has_seqcst, weakest) {
            out.push(RawFinding {
                rule: Rule::AtomicHygiene,
                line,
                note: format!(
                    "atomic `{recv}` mixes SeqCst with weaker orderings — pick one \
                     ordering discipline per field"
                ),
            });
        }
    }
}

fn l3_tainted_arith(toks: &[Token], ast: &Ast, out: &mut Vec<RawFinding>) {
    for f in &ast.fns {
        if f.test || !is_parser_decl(toks, f) {
            continue;
        }
        let mut taint: BTreeSet<String> = f.params.iter().cloned().collect();
        if taint.is_empty() {
            continue;
        }
        l3_block(toks, &f.body, &mut taint, out);
    }
}

/// The D3/L3 parser scope, decided on the recovered declaration: named
/// `from_value`/`from_*`/`parse*`, or a signature mentioning `Value` /
/// `toml_lite`.
fn is_parser_decl(toks: &[Token], f: &FnDecl) -> bool {
    f.name == "from_value"
        || f.name.starts_with("from_")
        || f.name.starts_with("parse")
        || (f.sig.0..f.sig.1.min(toks.len()))
            .any(|k| ident_at(toks, k).is_some_and(|s| s == "Value" || s == "toml_lite"))
}

fn l3_block(toks: &[Token], b: &Block, taint: &mut BTreeSet<String>, out: &mut Vec<RawFinding>) {
    for s in &b.stmts {
        let tainted_stmt = s
            .head
            .iter()
            .filter_map(|&k| ident_at(toks, k))
            .any(|id| taint.contains(id));
        if tainted_stmt {
            if let Some(name) = &s.let_name {
                taint.insert(name.clone());
            }
        }
        // Float arithmetic is D2's domain; L3 polices integer overflow.
        let floaty = s.head.iter().any(|&k| match &toks[k].tok {
            Tok::Num(n) => is_float_literal(n),
            Tok::Ident(id) => FLOAT_TYPES.contains(&id.as_str()),
            _ => false,
        });
        if !floaty {
            for &k in &s.head {
                let Some(op) = any_punct_at(toks, k) else { continue };
                if op != "+" && op != "*" {
                    continue;
                }
                // binary position: the previous token must end a value
                let binary = match toks.get(k.wrapping_sub(1)).map(|t| &t.tok) {
                    Some(Tok::Ident(_) | Tok::Num(_)) => true,
                    Some(Tok::Punct(p)) => p == ")" || p == "]",
                    _ => false,
                };
                if !binary {
                    continue;
                }
                let hot = [ident_at(toks, k.wrapping_sub(1)), ident_at(toks, k + 1)]
                    .into_iter()
                    .flatten()
                    .find(|id| taint.contains(*id));
                if let Some(id) = hot {
                    out.push(RawFinding {
                        rule: Rule::TaintedArith,
                        line: toks[k].line,
                        note: format!(
                            "unchecked `{op}` on parser-tainted `{id}` — use \
                             checked/saturating arithmetic before trusting parsed \
                             magnitudes"
                        ),
                    });
                }
            }
        }
        for sub in &s.subs {
            match sub {
                Sub::Block(inner) => l3_block(toks, inner, taint, out),
                Sub::Match(m) => {
                    for arm in &m.arms {
                        l3_block(toks, &arm.body, taint, out);
                    }
                }
            }
        }
    }
}

/// Enums this repository owns whose variant set is expected to grow;
/// a wildcard arm on one of these silently swallows the next variant.
const REPO_ENUMS: [&str; 4] = ["KernelKind", "Variant", "Workload", "Backend"];

fn l4_wildcard_arm(toks: &[Token], ast: &Ast, out: &mut Vec<RawFinding>) {
    for f in &ast.fns {
        if f.test {
            continue;
        }
        let owner_enum = f
            .owner
            .as_deref()
            .filter(|o| REPO_ENUMS.contains(o));
        l4_block(toks, &f.body, owner_enum, out);
    }
}

fn l4_block(toks: &[Token], b: &Block, owner_enum: Option<&str>, out: &mut Vec<RawFinding>) {
    for s in &b.stmts {
        for sub in &s.subs {
            match sub {
                Sub::Block(inner) => l4_block(toks, inner, owner_enum, out),
                Sub::Match(m) => {
                    let mut named: Option<&str> = None;
                    for arm in &m.arms {
                        for &k in &arm.pat {
                            let Some(id) = ident_at(toks, k) else { continue };
                            if !punct_at(toks, k + 1, "::") {
                                continue;
                            }
                            if REPO_ENUMS.contains(&id) {
                                named = Some(id);
                            } else if id == "Self" {
                                if let Some(owner) = owner_enum {
                                    named = Some(owner);
                                }
                            }
                        }
                    }
                    if let Some(enum_name) = named {
                        if let Some(w) =
                            m.arms.iter().find(|a| !a.guarded && arm_is_wildcard(toks, a))
                        {
                            out.push(RawFinding {
                                rule: Rule::WildcardArm,
                                line: w.line,
                                note: format!(
                                    "wildcard `_` arm on repo-owned enum `{enum_name}` — \
                                     a new variant would be silently accepted; list the \
                                     variants explicitly"
                                ),
                            });
                        }
                    }
                    for arm in &m.arms {
                        l4_block(toks, &arm.body, owner_enum, out);
                    }
                }
            }
        }
    }
}

// ---------------------------------------------------------------------------
// L5: drift between code and its artifacts (flags vs docs, config keys
// vs configs/*.toml)

/// CLI accessor functions whose first string literal names a `--flag`.
const FLAG_ACCESSORS: [&str; 4] = ["flag", "opt", "opt_parse", "knob"];

/// Files whose TOML-reading `.get("key")` calls L5 checks against the
/// shipped `configs/*.toml` key inventory.
const CONFIG_KEY_SITES: [&str; 6] = [
    "config.rs",
    "coordinator/spec.rs",
    "nn/model.rs",
    "nn/layer.rs",
    "dse/spec.rs",
    "lint/config.rs",
];

/// Is `path` one of the TOML-reading sites whose config keys L5 audits?
pub fn is_config_key_site(path: &str) -> bool {
    path_matches(path, &CONFIG_KEY_SITES)
}

/// L5 (flag drift): every `--flag` name read through the CLI accessors
/// (`args.flag("x")`, `args.opt("x")`, `args.opt_parse("x", ..)`,
/// `knob(&args, "x")`) must appear as `--x` somewhere in `docs` (the
/// README plus the file's own usage text).
pub fn drift_flags(lexed: &Lexed, docs: &str) -> Vec<RawFinding> {
    let toks = &lexed.tokens;
    let test = test_mask(toks);
    let mut seen: BTreeSet<String> = BTreeSet::new();
    let mut out = Vec::new();
    for i in 0..toks.len() {
        if test[i] {
            continue;
        }
        let Some(m) = ident_at(toks, i) else { continue };
        if !FLAG_ACCESSORS.contains(&m) || !punct_at(toks, i + 1, "(") {
            continue;
        }
        let close = match_delim(toks, i + 1, "(", ")");
        let lit = (i + 2..close).find_map(|k| match &toks[k].tok {
            Tok::Str(s) => Some((s.clone(), toks[k].line)),
            _ => None,
        });
        let Some((name, line)) = lit else { continue };
        let flaggy = !name.is_empty()
            && name.chars().all(|c| c.is_ascii_lowercase() || c == '-');
        if !flaggy || !seen.insert(name.clone()) {
            continue;
        }
        if !docs.contains(&format!("--{name}")) {
            out.push(RawFinding {
                rule: Rule::Drift,
                line,
                note: format!(
                    "flag `--{name}` is read here but documented nowhere \
                     (README/USAGE drift)"
                ),
            });
        }
    }
    out
}

/// L5 (config-key drift): every literal key read via `.get("key")` in a
/// TOML-reading site must appear in at least one shipped `configs/*.toml`
/// (`available` is the harvested key inventory).
pub fn drift_config_keys(lexed: &Lexed, available: &BTreeSet<String>) -> Vec<RawFinding> {
    let toks = &lexed.tokens;
    let test = test_mask(toks);
    let mut seen: BTreeSet<String> = BTreeSet::new();
    let mut out = Vec::new();
    for i in 0..toks.len() {
        if test[i] {
            continue;
        }
        if !(punct_at(toks, i, ".")
            && ident_at(toks, i + 1) == Some("get")
            && punct_at(toks, i + 2, "("))
        {
            continue;
        }
        let Some(Tok::Str(key)) = toks.get(i + 3).map(|t| &t.tok) else { continue };
        let keyish =
            !key.is_empty() && key.chars().all(|c| c.is_ascii_lowercase() || c == '_');
        if !keyish || !seen.insert(key.clone()) {
            continue;
        }
        if !available.contains(key.as_str()) {
            out.push(RawFinding {
                rule: Rule::Drift,
                line: toks[i + 3].line,
                note: format!(
                    "config key `{key}` is read here but appears in no configs/*.toml \
                     (spec/config drift)"
                ),
            });
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::super::lexer::lex;
    use super::*;

    fn hits(src: &str) -> Vec<(Rule, u32)> {
        scan("x.rs", &lex(src)).into_iter().map(|f| (f.rule, f.line)).collect()
    }

    fn ast_hits(src: &str) -> Vec<(Rule, u32)> {
        let lexed = lex(src);
        let ast = super::super::ast::parse(&lexed);
        scan_ast(&lexed, &ast).into_iter().map(|f| (f.rule, f.line)).collect()
    }

    #[test]
    fn d1_fires_on_map_iteration_not_lookup() {
        let src = "fn f() {\n    let mut m: HashMap<String, u32> = HashMap::new();\n    \
                   let v = m.get(\"k\");\n    for (k, _) in &m { drop(k); }\n    \
                   let n: Vec<u32> = m.values().cloned().collect();\n}\n";
        let got = hits(src);
        assert_eq!(got, vec![(Rule::MapIteration, 4), (Rule::MapIteration, 5)]);
    }

    #[test]
    fn d1_ignores_btree_and_comment_mentions() {
        let src = "// a HashMap would be wrong here\nfn f() {\n    \
                   let m: BTreeMap<String, u32> = BTreeMap::new();\n    \
                   for (k, _) in &m { drop(k); }\n}\n";
        assert!(hits(src).is_empty());
    }

    #[test]
    fn d2_fires_on_float_accumulation() {
        let src = "fn f(xs: &[f64]) -> f64 {\n    let mut acc = 0.0;\n    \
                   for x in xs { acc += x; }\n    acc\n}\n";
        assert_eq!(hits(src), vec![(Rule::FloatAccum, 3)]);
        let turbo = "fn g(xs: &[f64]) -> f64 { xs.iter().sum::<f64>() }\n";
        assert_eq!(hits(turbo), vec![(Rule::FloatAccum, 1)]);
        let fold = "fn h(xs: &[f64]) -> f64 { xs.iter().fold(0.0, |a, b| a + b) }\n";
        assert_eq!(hits(fold), vec![(Rule::FloatAccum, 1)]);
    }

    #[test]
    fn d2_ignores_integer_accumulation() {
        let src = "fn f(xs: &[u64]) -> u64 {\n    let mut acc = 0u64;\n    \
                   for x in xs { acc += x; }\n    let s: u64 = xs.iter().sum();\n    acc + s\n}\n";
        assert!(hits(src).is_empty());
    }

    #[test]
    fn d3_fires_only_in_parser_scope() {
        let parser = "fn from_value(v: &Value) -> Spec {\n    let n = v.num();\n    \
                      let k = n as u32;\n    Spec { k }\n}\n";
        assert_eq!(hits(parser), vec![(Rule::NarrowingCast, 3)]);
        let free = "fn shade(x: u64) -> u32 { x as u32 }\n";
        assert!(hits(free).is_empty());
    }

    #[test]
    fn d4_fires_outside_tests_only() {
        let src = "fn f(o: Option<u8>) -> u8 { o.unwrap() }\n\
                   #[cfg(test)]\nmod tests {\n    fn g(o: Option<u8>) -> u8 { o.unwrap() }\n}\n";
        assert_eq!(hits(src), vec![(Rule::PanicPath, 1)]);
        let not_test = "#[cfg(not(test))]\nfn f(o: Option<u8>) -> u8 { o.expect(\"x\") }\n";
        assert_eq!(hits(not_test), vec![(Rule::PanicPath, 2)]);
    }

    #[test]
    fn d4_fires_on_panic_macro_not_assert() {
        let src = "fn f(x: u8) {\n    assert!(x < 10);\n    \
                   if x == 9 { panic!(\"nope\"); }\n}\n";
        assert_eq!(hits(src), vec![(Rule::PanicPath, 3)]);
    }

    #[test]
    fn d5_fires_on_float_spec_not_debug_or_hex() {
        let src = "fn f(x: f64) -> String {\n    let a = format!(\"{x:.3}\");\n    \
                   let b = format!(\"{x:?}\");\n    let c = format!(\"{:04x}\", 7u32);\n    \
                   a + &b + &c\n}\n";
        assert_eq!(hits(src), vec![(Rule::FloatFormat, 2)]);
    }

    #[test]
    fn d5_ignores_specs_in_plain_strings() {
        let src = "fn f() -> &'static str { \"use {:.3} for floats\" }\n";
        assert!(hits(src).is_empty());
    }

    #[test]
    fn d6_fires_on_clock_reads() {
        // Outside obs/ the same read also breaches the D7 quarantine.
        let src = "fn f() {\n    let t0 = Instant::now();\n    drop(t0);\n}\n";
        assert_eq!(hits(src), vec![(Rule::WallClock, 2), (Rule::TimeQuarantine, 2)]);
        let import_only = "use std::time::SystemTime;\nfn f() {}\n";
        assert_eq!(hits(import_only), vec![(Rule::TimeQuarantine, 1)]);
    }

    #[test]
    fn d7_quarantines_time_and_trace_idents_to_obs() {
        let src = "use std::time::Instant;\nfn f() {}\n";
        assert_eq!(hits(src), vec![(Rule::TimeQuarantine, 1)]);
        // The quarantine itself is the sanctioned home (D6 still applies
        // there, via its own pragmas).
        assert!(scan("rust/src/obs/emit.rs", &lex(src)).is_empty());
        assert!(scan("obs/mod.rs", &lex("struct X { t: Instant }\n")).is_empty());
        // Duration is data, not a clock read: legal everywhere.
        assert!(hits("use std::time::Duration;\nfn f(d: Duration) { drop(d); }\n").is_empty());
        // Trace-sink internals are quarantined too.
        assert_eq!(
            hits("fn f(s: &mut TraceSink) { s.emit_record(); }\n"),
            vec![(Rule::TimeQuarantine, 1), (Rule::TimeQuarantine, 1)]
        );
        // Tests may time things ad hoc.
        assert!(hits("#[cfg(test)]\nmod t {\n    fn g() { let _ = Instant::now(); }\n}\n")
            .is_empty());
    }

    #[test]
    fn approved_sites_are_exempt_from_their_own_rule() {
        let src = "fn f(xs: &[f64]) -> f64 { xs.iter().sum::<f64>() }\n";
        assert!(scan("rust/src/metrics/welford.rs", &lex(src)).is_empty());
        assert_eq!(scan("rust/src/metrics/other.rs", &lex(src)).len(), 1);
        let fmtsrc = "fn c(x: f64) -> String { format!(\"{x:.17}\") }\n";
        assert!(scan("rust/src/report/mod.rs", &lex(fmtsrc)).is_empty());
    }

    #[test]
    fn l2_fires_on_fetch_add_not_fetch_update() {
        let src = "fn bump(c: &AtomicU64) {\n    \
                   c.fetch_add(1, Ordering::Relaxed);\n}\n";
        assert_eq!(ast_hits(src), vec![(Rule::AtomicHygiene, 2)]);
        let saturating = "fn bump(c: &AtomicU64) {\n    let _ = c.fetch_update(\
                          Ordering::Relaxed, Ordering::Relaxed, |v| Some(v.saturating_add(1)));\n}\n";
        assert!(ast_hits(saturating).is_empty());
        // `.load()` on a non-atomic (no Ordering argument) is not flagged.
        assert!(ast_hits("fn f(m: &Model) -> u32 { m.load(7) }\n").is_empty());
    }

    #[test]
    fn l2_fires_on_seqcst_mixed_with_weaker() {
        let src = "fn f(c: &AtomicU64) -> u64 {\n    \
                   c.store(1, Ordering::SeqCst);\n    \
                   c.load(Ordering::Relaxed)\n}\n";
        assert_eq!(ast_hits(src), vec![(Rule::AtomicHygiene, 3)]);
        let uniform = "fn f(c: &AtomicU64) -> u64 {\n    \
                       c.store(1, Ordering::Relaxed);\n    \
                       c.load(Ordering::Relaxed)\n}\n";
        assert!(ast_hits(uniform).is_empty());
    }

    #[test]
    fn l3_fires_on_tainted_arith_in_parser_scope_only() {
        let src = "fn parse_len(n: u32) -> u32 {\n    n + 1\n}\n";
        assert_eq!(ast_hits(src), vec![(Rule::TaintedArith, 2)]);
        // taint propagates through let bindings
        let chained = "fn from_value(v: u32) -> u32 {\n    let w = v;\n    w * 2\n}\n";
        assert_eq!(ast_hits(chained), vec![(Rule::TaintedArith, 3)]);
        // same arithmetic outside parser scope is not L3's business
        assert!(ast_hits("fn widen(n: u32) -> u32 { n + 1 }\n").is_empty());
        // float math is D2's domain, not L3's
        assert!(ast_hits("fn parse_gain(x: f64) -> f64 { x * 2.0 }\n").is_empty());
        // checked arithmetic is the fix, and is clean
        assert!(ast_hits(
            "fn parse_len(n: u32) -> Option<u32> { n.checked_add(1) }\n"
        )
        .is_empty());
    }

    #[test]
    fn l4_fires_on_wildcard_over_repo_enum_only() {
        let src = "fn f(v: Variant) -> u32 {\n    match v {\n        \
                   Variant::Smart => 1,\n        _ => 0,\n    }\n}\n";
        assert_eq!(ast_hits(src), vec![(Rule::WildcardArm, 4)]);
        // exhaustive matches are clean
        let full = "fn f(b: Backend) -> u32 {\n    match b {\n        \
                    Backend::Xla => 0,\n        Backend::Native => 1,\n    }\n}\n";
        assert!(ast_hits(full).is_empty());
        // foreign enums may use wildcards freely
        let foreign = "fn f(o: Ordering) -> u32 {\n    match o {\n        \
                       Ordering::Less => 0,\n        _ => 1,\n    }\n}\n";
        assert!(ast_hits(foreign).is_empty());
        // guarded arms are not wildcards
        let guarded = "fn f(v: Variant, n: u32) -> u32 {\n    match v {\n        \
                       Variant::Smart => 1,\n        _ if n > 0 => 2,\n        \
                       Variant::Imac => 3,\n        Variant::Aid => 4,\n        \
                       Variant::SmartOnImac => 5,\n    }\n}\n";
        assert!(ast_hits(guarded).is_empty());
    }

    #[test]
    fn l4_resolves_self_to_the_impl_enum() {
        let src = "impl Variant {\n    fn code(&self) -> u32 {\n        \
                   match self {\n            Self::Smart => 0,\n            _ => 1,\n        \
                   }\n    }\n}\n";
        assert_eq!(ast_hits(src), vec![(Rule::WildcardArm, 5)]);
        let foreign = "impl Widget {\n    fn code(&self) -> u32 {\n        \
                       match self {\n            Self::A => 0,\n            _ => 1,\n        \
                       }\n    }\n}\n";
        assert!(ast_hits(foreign).is_empty());
    }

    #[test]
    fn l5_flag_drift_checks_docs_for_each_accessor() {
        let src = "fn main() {\n    let v = args.flag(\"verbose\");\n    \
                   let o = args.opt(\"out\");\n    let n = knob(&args, \"n-mc\");\n}\n";
        let lexed = lex(src);
        let documented = "Usage: --verbose --out FILE --n-mc N";
        assert!(drift_flags(&lexed, documented).is_empty());
        let partial = "Usage: --verbose --out FILE";
        let got: Vec<(Rule, u32)> =
            drift_flags(&lexed, partial).into_iter().map(|f| (f.rule, f.line)).collect();
        assert_eq!(got, vec![(Rule::Drift, 4)]);
    }

    #[test]
    fn l5_config_key_drift_checks_the_harvested_inventory() {
        let src = "fn from_value(v: &Value) {\n    let a = v.get(\"seed\");\n    \
                   let b = v.get(\"missing_key\");\n}\n";
        let lexed = lex(src);
        let available: BTreeSet<String> = ["seed".to_string()].into_iter().collect();
        let got: Vec<(Rule, u32)> = drift_config_keys(&lexed, &available)
            .into_iter()
            .map(|f| (f.rule, f.line))
            .collect();
        assert_eq!(got, vec![(Rule::Drift, 3)]);
        assert!(is_config_key_site("rust/src/coordinator/spec.rs"));
        assert!(!is_config_key_site("rust/src/coordinator/pool.rs"));
    }
}
