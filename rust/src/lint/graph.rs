//! Crate-local symbol index, call graph, and lock-order analysis
//! (DESIGN.md §16).
//!
//! [`build`] walks every parsed file ([`FileUnit`]), derives each
//! function's canonical qualified name (`module::path::Type::method`,
//! module path from the file's location under `rust/src/`), resolves
//! call expressions against the crate's own declarations (same-module
//! first, then `use` aliases — renames included — then unique
//! method/free-fn names), and records per-function `Mutex`/`RwLock`
//! acquisition sequences with their guard scopes. The result serves two
//! consumers: the canonical `CALLGRAPH.json` artifact
//! ([`Graph::to_json`]) and the `L1` lock-order pass ([`lock_order`]),
//! which propagates lock sets inter-procedurally over the call graph and
//! reports every cycle in the acquired-while-holding relation as a
//! potential deadlock.
//!
//! Resolution is deliberately conservative: a call that cannot be
//! attributed to exactly one crate-local function is dropped rather than
//! guessed (common std method names are stop-listed), so false edges —
//! which could manufacture phantom deadlock cycles — are rare by
//! construction.

use std::collections::{BTreeMap, BTreeSet};

use super::ast::{Ast, Block, FnDecl, Stmt, Sub};
use super::lexer::{Lexed, Tok, Token};
use super::rules::RawFinding;
use super::Rule;
use crate::util::json::{to_string_pretty, Value};

/// Schema version of the `CALLGRAPH.json` artifact.
pub const CALLGRAPH_SCHEMA_VERSION: u32 = 1;

/// One parsed source file, ready for cross-file analysis.
#[derive(Debug, Clone)]
pub struct FileUnit {
    /// Repo-relative, `/`-separated display path.
    pub path: String,
    /// The lexed token stream (pragmas included).
    pub lexed: Lexed,
    /// The recovered structure.
    pub ast: Ast,
}

impl FileUnit {
    /// Lex and parse one source file.
    pub fn new(path: &str, text: &str) -> FileUnit {
        let lexed = super::lexer::lex(text);
        let ast = super::ast::parse(&lexed);
        FileUnit { path: path.to_string(), lexed, ast }
    }
}

/// One function in the call graph.
#[derive(Debug, Clone)]
pub struct FnNode {
    /// Canonical qualified name (`serve::batch::Coalescer::submit`).
    pub qual: String,
    /// File the function is declared in.
    pub file: String,
    /// 1-based line of the `fn` keyword.
    pub line: u32,
    /// True for test-only functions (excluded from lock analysis).
    pub test: bool,
    /// Resolved crate-local call sites, in source order.
    pub calls: Vec<CallSite>,
    /// Direct lock acquisitions, in source order.
    pub acquires: Vec<LockEvent>,
    /// Intra-function lock-order edges (`acquired` taken while `held`).
    pub edges: Vec<LockEdge>,
}

/// A resolved call expression.
#[derive(Debug, Clone)]
pub struct CallSite {
    /// Qualified name of the callee.
    pub callee: String,
    /// 1-based line of the call.
    pub line: u32,
    /// Lock identities held at the call site (sorted, deduped).
    pub held: Vec<String>,
}

/// A direct lock acquisition.
#[derive(Debug, Clone)]
pub struct LockEvent {
    /// Lock identity — `Type.field` for `self.field.lock()`, a
    /// function-scoped name otherwise.
    pub lock: String,
    /// 1-based line of the acquisition.
    pub line: u32,
}

/// An acquired-while-holding pair observed inside one function.
#[derive(Debug, Clone)]
pub struct LockEdge {
    /// The lock already held.
    pub held: String,
    /// The lock being acquired.
    pub acquired: String,
    /// 1-based line of the acquisition.
    pub line: u32,
}

/// The crate call graph: every function, with resolved calls and lock
/// events, sorted by qualified name.
#[derive(Debug, Clone, Default)]
pub struct Graph {
    /// Function nodes, sorted by [`FnNode::qual`] (duplicates dropped,
    /// first declaration wins).
    pub fns: Vec<FnNode>,
}

/// Module path of a file under the crate root: `rust/src/serve/batch.rs`
/// → `["serve", "batch"]`; `mod.rs`/`lib.rs` fold into the parent;
/// `main.rs` keeps `main` so binary-only symbols stay distinct.
pub fn module_path(path: &str) -> Vec<String> {
    let p = path.strip_prefix("rust/src/").unwrap_or(path);
    let p = p.strip_suffix(".rs").unwrap_or(p);
    let mut segs: Vec<String> =
        p.split('/').filter(|s| !s.is_empty()).map(str::to_string).collect();
    if matches!(segs.last().map(String::as_str), Some("mod") | Some("lib")) {
        segs.pop();
    }
    segs
}

/// Method names too generic to resolve by uniqueness: they collide with
/// std/core inherent methods, so a lone crate-local definition must not
/// capture every `.name()` call in the crate.
const METHOD_STOPLIST: [&str; 64] = [
    "abs", "all", "any", "as_bytes", "as_mut", "as_ref", "as_slice", "as_str", "borrow",
    "borrow_mut", "clear", "clone", "cmp", "collect", "contains", "count", "default", "drain",
    "entry", "eq", "extend", "filter", "find", "finish", "flush", "fmt", "fold", "get",
    "get_mut", "hash", "insert", "into_iter", "is_empty", "iter", "join", "keys", "len",
    "lines", "load", "map", "max", "min", "new", "next", "parse", "pop", "position", "push",
    "read", "recv", "remove", "retain", "send", "sort", "split", "store", "sum", "swap",
    "take", "to_owned", "to_string", "trim", "values", "wait", "write",
];

/// Control-flow keywords that can precede `(` without being calls.
const CALL_KEYWORDS: [&str; 10] =
    ["if", "else", "while", "for", "loop", "match", "return", "break", "continue", "in"];

// ---------------------------------------------------------------------------
// symbol index

struct Index {
    /// Every declared function's qualified name.
    quals: BTreeSet<String>,
    /// Free functions by bare name.
    free: BTreeMap<String, BTreeSet<String>>,
    /// Methods by `(bare type name, method name)`.
    methods: BTreeMap<(String, String), BTreeSet<String>>,
    /// Methods by bare name (for unique-method fallback).
    by_method: BTreeMap<String, BTreeSet<String>>,
}

/// The qualified name of a declared function.
fn fn_qual(mod_path: &[String], f: &FnDecl) -> String {
    let mut segs: Vec<&str> = mod_path.iter().map(String::as_str).collect();
    segs.extend(f.mods.iter().map(String::as_str));
    if let Some(owner) = &f.owner {
        segs.push(owner);
    }
    segs.push(&f.name);
    segs.join("::")
}

fn build_index(units: &[FileUnit]) -> Index {
    let mut idx = Index {
        quals: BTreeSet::new(),
        free: BTreeMap::new(),
        methods: BTreeMap::new(),
        by_method: BTreeMap::new(),
    };
    for u in units {
        let mod_path = module_path(&u.path);
        for f in &u.ast.fns {
            let qual = fn_qual(&mod_path, f);
            idx.quals.insert(qual.clone());
            match &f.owner {
                None => {
                    idx.free.entry(f.name.clone()).or_default().insert(qual);
                }
                Some(ty) => {
                    idx.methods
                        .entry((ty.clone(), f.name.clone()))
                        .or_default()
                        .insert(qual.clone());
                    idx.by_method.entry(f.name.clone()).or_default().insert(qual);
                }
            }
        }
    }
    idx
}

// ---------------------------------------------------------------------------
// per-file resolution environment

struct FileEnv<'a> {
    mod_path: Vec<String>,
    /// `use` alias → crate-normalized full path segments.
    uses: BTreeMap<String, Vec<String>>,
    idx: &'a Index,
}

impl<'a> FileEnv<'a> {
    fn new(u: &FileUnit, idx: &'a Index) -> FileEnv<'a> {
        let mod_path = module_path(&u.path);
        let mut uses = BTreeMap::new();
        for decl in &u.ast.uses {
            let segs = normalize(&decl.segs, &mod_path);
            if !segs.is_empty() {
                uses.insert(decl.alias.clone(), segs);
            }
        }
        FileEnv { mod_path, uses, idx }
    }

    /// Resolve a call path (`["helper"]`, `["spec", "from_value"]`,
    /// `["Self", "finish"]`) to a declared function's qualified name.
    fn resolve_path(&self, segs: &[String], owner_prefix: Option<&str>) -> Option<String> {
        if segs.is_empty() {
            return None;
        }
        if segs[0] == "Self" {
            let prefix = owner_prefix?;
            if segs.len() == 2 {
                let cand = format!("{prefix}::{}", segs[1]);
                if self.idx.quals.contains(&cand) {
                    return Some(cand);
                }
            }
            return None;
        }
        // expand a leading `use` alias, then crate-normalize
        let mut full: Vec<String> = match self.uses.get(&segs[0]) {
            Some(exp) => exp.iter().chain(segs.iter().skip(1)).cloned().collect(),
            None => segs.to_vec(),
        };
        full = normalize(&full, &self.mod_path);
        if full.is_empty() {
            return None;
        }
        if full.len() == 1 {
            let name = &full[0];
            // same module first
            let mut cand: Vec<String> = self.mod_path.clone();
            cand.push(name.clone());
            let cand = cand.join("::");
            if self.idx.quals.contains(&cand) {
                return Some(cand);
            }
            // unique free fn anywhere in the crate
            return unique(self.idx.free.get(name));
        }
        let cand = full.join("::");
        if self.idx.quals.contains(&cand) {
            return Some(cand);
        }
        // `Type::method(...)` — resolve by the (type, method) pair
        let ty = &full[full.len() - 2];
        let name = &full[full.len() - 1];
        unique(self.idx.methods.get(&(ty.clone(), name.clone())))
    }

    /// Resolve a method call `recv.name(...)`: via the impl owner for
    /// `self.name()`, else by crate-wide uniqueness (stop-listed names
    /// excluded).
    fn resolve_method(
        &self,
        name: &str,
        recv_is_self: bool,
        owner_prefix: Option<&str>,
    ) -> Option<String> {
        if recv_is_self {
            let prefix = owner_prefix?;
            let cand = format!("{prefix}::{name}");
            if self.idx.quals.contains(&cand) {
                return Some(cand);
            }
            return None;
        }
        if METHOD_STOPLIST.contains(&name) {
            return None;
        }
        unique(self.idx.by_method.get(name))
    }
}

fn unique(set: Option<&BTreeSet<String>>) -> Option<String> {
    match set {
        Some(s) if s.len() == 1 => s.iter().next().cloned(),
        _ => None,
    }
}

/// Crate-normalize a path: strip `crate`/the crate name, expand
/// `self`/`super` against the file's module path. External paths are
/// returned as-is (they simply never match the index).
fn normalize(segs: &[String], mod_path: &[String]) -> Vec<String> {
    let mut out: Vec<String> = Vec::new();
    let mut i = 0;
    match segs.first().map(String::as_str) {
        Some("crate") | Some("smart_insram") => i = 1,
        Some("self") => {
            out.extend(mod_path.iter().cloned());
            i = 1;
        }
        Some("super") => {
            let mut parent = mod_path.to_vec();
            while i < segs.len() && segs[i] == "super" {
                parent.pop();
                i += 1;
            }
            out.extend(parent);
        }
        _ => {}
    }
    out.extend(segs.iter().skip(i).cloned());
    out
}

// ---------------------------------------------------------------------------
// function-body walk: lock events + call sites

struct HeldLock {
    id: String,
    binding: Option<String>,
}

struct Walker<'a> {
    toks: &'a [Token],
    env: &'a FileEnv<'a>,
    /// Qualified prefix of the enclosing impl (`serve::cache::Lru`).
    owner_prefix: Option<String>,
    fn_qual: String,
    held: Vec<HeldLock>,
    calls: Vec<CallSite>,
    acquires: Vec<LockEvent>,
    edges: Vec<LockEdge>,
}

impl<'a> Walker<'a> {
    fn ident(&self, i: usize) -> Option<&'a str> {
        match self.toks.get(i).map(|t| &t.tok) {
            Some(Tok::Ident(s)) => Some(s.as_str()),
            _ => None,
        }
    }

    fn punct(&self, i: usize, op: &str) -> bool {
        matches!(self.toks.get(i).map(|t| &t.tok), Some(Tok::Punct(p)) if p == op)
    }

    fn line(&self, i: usize) -> u32 {
        self.toks.get(i).map(|t| t.line).unwrap_or(0)
    }

    fn walk_block(&mut self, b: &Block) {
        let base = self.held.len();
        for stmt in &b.stmts {
            self.walk_stmt(stmt);
        }
        self.held.truncate(base);
    }

    fn walk_stmt(&mut self, s: &Stmt) {
        let stmt_base = self.held.len();
        self.scan_span(&s.head, s.let_name.as_deref());
        for sub in &s.subs {
            match sub {
                Sub::Block(b) => self.walk_block(b),
                Sub::Match(m) => {
                    // a scrutinee temporary guard lives for the whole match
                    let mbase = self.held.len();
                    self.scan_span(&m.scrutinee, None);
                    for arm in &m.arms {
                        self.walk_block(&arm.body);
                    }
                    self.held.truncate(mbase);
                }
            }
        }
        // statement end: unbound guard temporaries die; `let`-bound
        // guards live to the end of the enclosing block
        let kept: Vec<HeldLock> =
            self.held.drain(stmt_base..).filter(|h| h.binding.is_some()).collect();
        self.held.extend(kept);
    }

    /// Scan one span of statement-level tokens for lock acquisitions,
    /// releases, and call expressions.
    fn scan_span(&mut self, idx: &[usize], let_name: Option<&str>) {
        for &k in idx {
            // `drop(guard)` releases a bound guard
            if self.ident(k) == Some("drop") && self.punct(k + 1, "(") && self.punct(k + 3, ")")
            {
                if let Some(name) = self.ident(k + 2) {
                    self.held.retain(|h| h.binding.as_deref() != Some(name));
                }
                continue;
            }
            // `recv.lock()` / zero-arg `recv.read()` / `recv.write()`
            if self.punct(k, ".")
                && self
                    .ident(k + 1)
                    .is_some_and(|m| m == "lock" || m == "read" || m == "write")
                && self.punct(k + 2, "(")
                && self.punct(k + 3, ")")
            {
                let id = self.receiver_id(k);
                let line = self.line(k + 1);
                for h in &self.held {
                    self.edges.push(LockEdge {
                        held: h.id.clone(),
                        acquired: id.clone(),
                        line,
                    });
                }
                self.acquires.push(LockEvent { lock: id.clone(), line });
                self.held.push(HeldLock { id, binding: let_name.map(str::to_string) });
                continue;
            }
            // method call `recv.name(...)`
            if self.punct(k, ".") && self.punct(k + 2, "(") {
                if let Some(m) = self.ident(k + 1) {
                    let is_lock_shape = (m == "lock" || m == "read" || m == "write")
                        && self.punct(k + 3, ")");
                    if !is_lock_shape {
                        let recv_is_self =
                            self.ident(k.wrapping_sub(1)) == Some("self")
                                && !self.punct(k.wrapping_sub(2), ".");
                        let owner = self.owner_prefix.as_deref();
                        if let Some(callee) = self.env.resolve_method(m, recv_is_self, owner) {
                            self.record_call(callee, self.line(k + 1));
                        }
                    }
                }
                continue;
            }
            // free-fn / path call `name(...)` / `a::b::name(...)`
            if let Some(first) = self.ident(k) {
                let prev_blocks = self.punct(k.wrapping_sub(1), ".")
                    || self.punct(k.wrapping_sub(1), "::")
                    || self.ident(k.wrapping_sub(1)) == Some("fn");
                if k > 0 && prev_blocks {
                    continue;
                }
                if CALL_KEYWORDS.contains(&first) {
                    continue;
                }
                let mut segs = vec![first.to_string()];
                let mut j = k + 1;
                while self.punct(j, "::") {
                    match self.ident(j + 1) {
                        Some(next) => {
                            segs.push(next.to_string());
                            j += 2;
                        }
                        None => break,
                    }
                }
                if self.punct(j, "(") && j > k {
                    let owner = self.owner_prefix.as_deref();
                    if let Some(callee) = self.env.resolve_path(&segs, owner) {
                        self.record_call(callee, self.line(k));
                    }
                }
            }
        }
    }

    fn record_call(&mut self, callee: String, line: u32) {
        let mut held: Vec<String> = self.held.iter().map(|h| h.id.clone()).collect();
        held.sort();
        held.dedup();
        self.calls.push(CallSite { callee, line, held });
    }

    /// Lock identity of the receiver chain ending at the `.` before the
    /// lock method: `self.field` chains key on the impl type
    /// (`Type.field` — stable across functions), anything else keys on
    /// the enclosing function (guards passed by reference cannot be
    /// identified across functions without type information).
    fn receiver_id(&self, dot: usize) -> String {
        let mut chain: Vec<&str> = Vec::new();
        let mut j = dot;
        loop {
            let Some(id) = self.ident(j.wrapping_sub(1)) else { break };
            if j == 0 {
                break;
            }
            chain.insert(0, id);
            j -= 1;
            if j > 0 && self.punct(j - 1, ".") {
                j -= 1;
            } else {
                break;
            }
        }
        match chain.split_first() {
            Some((&"self", rest)) if !rest.is_empty() => match &self.owner_prefix {
                Some(prefix) => format!("{prefix}.{}", rest.join(".")),
                None => format!("{}#self.{}", self.fn_qual, rest.join(".")),
            },
            Some((first, rest)) if rest.is_empty() && *first != "self" => {
                format!("{}#{first}", self.fn_qual)
            }
            Some((first, rest)) => format!("{}#{first}.{}", self.fn_qual, rest.join(".")),
            None => format!("{}#expr@{}", self.fn_qual, self.line(dot)),
        }
    }
}

// ---------------------------------------------------------------------------
// graph construction

/// Build the call graph (with lock events) over a set of parsed files.
pub fn build(units: &[FileUnit]) -> Graph {
    let idx = build_index(units);
    let mut by_qual: BTreeMap<String, FnNode> = BTreeMap::new();
    for u in units {
        let env = FileEnv::new(u, &idx);
        for f in &u.ast.fns {
            let qual = fn_qual(&env.mod_path, f);
            let owner_prefix = f.owner.as_ref().map(|_| {
                qual.rsplit_once("::").map(|(p, _)| p.to_string()).unwrap_or_default()
            });
            let mut w = Walker {
                toks: &u.lexed.tokens,
                env: &env,
                owner_prefix,
                fn_qual: qual.clone(),
                held: Vec::new(),
                calls: Vec::new(),
                acquires: Vec::new(),
                edges: Vec::new(),
            };
            w.walk_block(&f.body);
            let node = FnNode {
                qual: qual.clone(),
                file: u.path.clone(),
                line: f.line,
                test: f.test,
                calls: w.calls,
                acquires: w.acquires,
                edges: w.edges,
            };
            by_qual.entry(qual).or_insert(node);
        }
    }
    Graph { fns: by_qual.into_values().collect() }
}

impl Graph {
    /// Canonical `CALLGRAPH.json` bytes: schema version plus every
    /// function with its resolved calls and direct lock acquisitions,
    /// sorted by qualified name — byte-identical across machines.
    pub fn to_json(&self) -> String {
        let mut root = BTreeMap::new();
        root.insert(
            "schema_version".to_string(),
            Value::Num(f64::from(CALLGRAPH_SCHEMA_VERSION)),
        );
        let fns: Vec<Value> = self
            .fns
            .iter()
            .map(|f| {
                let mut m = BTreeMap::new();
                m.insert("qual".to_string(), Value::Str(f.qual.clone()));
                m.insert("file".to_string(), Value::Str(f.file.clone()));
                m.insert("line".to_string(), Value::Num(f64::from(f.line)));
                m.insert("test".to_string(), Value::Bool(f.test));
                let mut calls: Vec<String> =
                    f.calls.iter().map(|c| c.callee.clone()).collect();
                calls.sort();
                calls.dedup();
                m.insert(
                    "calls".to_string(),
                    Value::Arr(calls.into_iter().map(Value::Str).collect()),
                );
                let mut locks: Vec<String> =
                    f.acquires.iter().map(|a| a.lock.clone()).collect();
                locks.sort();
                locks.dedup();
                m.insert(
                    "locks".to_string(),
                    Value::Arr(locks.into_iter().map(Value::Str).collect()),
                );
                Value::Obj(m)
            })
            .collect();
        root.insert("functions".to_string(), Value::Arr(fns));
        let mut text = to_string_pretty(&Value::Obj(root));
        text.push('\n');
        text
    }

    /// Look up a node by qualified name.
    pub fn get(&self, qual: &str) -> Option<&FnNode> {
        self.fns.iter().find(|f| f.qual == qual)
    }
}

// ---------------------------------------------------------------------------
// L1: lock-order cycles

/// Run the inter-procedural lock-order pass: transitive lock sets are
/// propagated over the call graph, every acquired-while-holding pair
/// becomes an edge in the lock-order relation, and each cycle (a
/// strongly-connected component, self-loops included) yields one `L1`
/// finding at its lexicographically smallest edge site. Test-only
/// functions are excluded.
pub fn lock_order(g: &Graph) -> Vec<(String, RawFinding)> {
    // transitive lock set per function (fixpoint over the call graph)
    let mut lockset: BTreeMap<&str, BTreeSet<String>> = BTreeMap::new();
    for f in g.fns.iter().filter(|f| !f.test) {
        lockset
            .insert(&f.qual, f.acquires.iter().map(|a| a.lock.clone()).collect());
    }
    let mut changed = true;
    let mut rounds = 0usize;
    while changed && rounds <= g.fns.len() {
        changed = false;
        rounds += 1;
        for f in g.fns.iter().filter(|f| !f.test) {
            let mut add: BTreeSet<String> = BTreeSet::new();
            for c in &f.calls {
                if let Some(callee_locks) = lockset.get(c.callee.as_str()) {
                    add.extend(callee_locks.iter().cloned());
                }
            }
            if let Some(own) = lockset.get_mut(f.qual.as_str()) {
                let before = own.len();
                own.extend(add);
                changed = changed || own.len() != before;
            }
        }
    }

    // lock-order edges: intra-function pairs plus held-at-call × callee
    // transitive lock set
    let mut adj: BTreeMap<String, BTreeSet<String>> = BTreeMap::new();
    let mut site: BTreeMap<(String, String), (String, u32)> = BTreeMap::new();
    let mut add_edge = |a: &str, b: &str, file: &str, line: u32| {
        adj.entry(a.to_string()).or_default().insert(b.to_string());
        let key = (a.to_string(), b.to_string());
        let loc = (file.to_string(), line);
        match site.get(&key) {
            Some(prev) if *prev <= loc => {}
            _ => {
                site.insert(key, loc);
            }
        }
    };
    for f in g.fns.iter().filter(|f| !f.test) {
        for e in &f.edges {
            add_edge(&e.held, &e.acquired, &f.file, e.line);
        }
        for c in &f.calls {
            let Some(callee_locks) = lockset.get(c.callee.as_str()) else { continue };
            for h in &c.held {
                for l in callee_locks {
                    // a self-pair through a call is a genuine double-lock
                    add_edge(h, l, &f.file, c.line);
                }
            }
        }
    }

    // cycles: a node on any cycle reaches itself; nodes that reach each
    // other share a component
    let reach = |from: &str| -> BTreeSet<String> {
        let mut seen: BTreeSet<String> = BTreeSet::new();
        let mut stack: Vec<String> =
            adj.get(from).map(|s| s.iter().cloned().collect()).unwrap_or_default();
        while let Some(n) = stack.pop() {
            if seen.insert(n.clone()) {
                if let Some(next) = adj.get(&n) {
                    stack.extend(next.iter().cloned());
                }
            }
        }
        seen
    };
    let cyclic: Vec<String> =
        adj.keys().filter(|n| reach(n).contains(*n)).cloned().collect();
    let mut groups: Vec<Vec<String>> = Vec::new();
    let mut grouped: BTreeSet<String> = BTreeSet::new();
    for n in &cyclic {
        if grouped.contains(n) {
            continue;
        }
        let rn = reach(n);
        let mut comp: Vec<String> = vec![n.clone()];
        for m in &cyclic {
            if m != n && rn.contains(m) && reach(m).contains(n) {
                comp.push(m.clone());
            }
        }
        comp.sort();
        for m in &comp {
            grouped.insert(m.clone());
        }
        groups.push(comp);
    }

    let mut out = Vec::new();
    for comp in groups {
        // the reporting site: smallest (file, line) over the component's
        // internal edges
        let mut best: Option<(&String, &u32, String)> = None;
        for a in &comp {
            for b in &comp {
                if let Some((file, line)) = site.get(&(a.clone(), b.clone())) {
                    let desc = format!("`{a}` then `{b}`");
                    match &best {
                        Some((bf, bl, _)) if (*bf, *bl) <= (file, line) => {}
                        _ => best = Some((file, line, desc)),
                    }
                }
            }
        }
        let Some((file, line, desc)) = best else { continue };
        let note = if comp.len() == 1 {
            format!(
                "lock `{}` can be acquired while already held ({desc}) — a \
                 non-reentrant Mutex self-deadlocks here",
                comp[0]
            )
        } else {
            format!(
                "lock-order cycle among {{{}}} — acquisition order is inconsistent \
                 across call paths (first inverted site: {desc}); pick one order \
                 and hold to it",
                comp.join(", ")
            )
        };
        out.push((
            file.clone(),
            RawFinding { rule: Rule::LockOrder, line: *line, note },
        ));
    }
    out.sort_by(|a, b| (&a.0, a.1.line).cmp(&(&b.0, b.1.line)));
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn unit(path: &str, src: &str) -> FileUnit {
        FileUnit::new(path, src)
    }

    #[test]
    fn module_paths_fold_mod_and_lib() {
        assert_eq!(module_path("rust/src/serve/batch.rs"), vec!["serve", "batch"]);
        assert_eq!(module_path("rust/src/serve/mod.rs"), vec!["serve"]);
        assert!(module_path("rust/src/lib.rs").is_empty());
        assert_eq!(module_path("rust/src/main.rs"), vec!["main"]);
    }

    #[test]
    fn resolves_same_module_and_use_renamed_calls() {
        let a = unit(
            "rust/src/alpha.rs",
            "pub fn tick() {}\npub fn run() {\n    tick();\n}\n",
        );
        let b = unit(
            "rust/src/beta.rs",
            "use crate::alpha::tick as pulse;\npub fn go() {\n    pulse();\n}\n",
        );
        let g = build(&[a, b]);
        let run = g.get("alpha::run").expect("alpha::run indexed");
        assert_eq!(run.calls.len(), 1);
        assert_eq!(run.calls[0].callee, "alpha::tick");
        let go = g.get("beta::go").expect("beta::go indexed");
        assert_eq!(go.calls.len(), 1, "use-renamed call resolves: {:?}", go.calls);
        assert_eq!(go.calls[0].callee, "alpha::tick");
    }

    #[test]
    fn distinguishes_methods_from_free_fns() {
        let src = "pub struct W;\nimpl W {\n    pub fn poke(&self) {}\n    \
                   pub fn both(&self) {\n        self.poke();\n        poke();\n    }\n}\n\
                   pub fn poke() {}\n";
        let g = build(&[unit("rust/src/w.rs", src)]);
        let both = g.get("w::W::both").expect("method indexed");
        let callees: Vec<&str> = both.calls.iter().map(|c| c.callee.as_str()).collect();
        assert_eq!(callees, vec!["w::W::poke", "w::poke"]);
    }

    #[test]
    fn type_method_paths_resolve() {
        let a = unit(
            "rust/src/alpha.rs",
            "pub struct Spec;\nimpl Spec {\n    pub fn build_it() -> Spec { Spec }\n}\n",
        );
        let b = unit(
            "rust/src/beta.rs",
            "use crate::alpha::Spec;\npub fn go() -> Spec {\n    Spec::build_it()\n}\n",
        );
        let g = build(&[a, b]);
        let go = g.get("beta::go").expect("beta::go indexed");
        assert_eq!(go.calls.len(), 1);
        assert_eq!(go.calls[0].callee, "alpha::Spec::build_it");
    }

    #[test]
    fn self_field_locks_key_on_the_type() {
        let src = "pub struct S { a: Mutex<u32>, b: Mutex<u32> }\nimpl S {\n    \
                   pub fn swap(&self) {\n        let g = self.a.lock();\n        \
                   let h = self.b.lock();\n        drop(h);\n        drop(g);\n    }\n}\n";
        let g = build(&[unit("rust/src/s.rs", src)]);
        let f = g.get("s::S::swap").expect("indexed");
        let locks: Vec<&str> = f.acquires.iter().map(|a| a.lock.as_str()).collect();
        assert_eq!(locks, vec!["s::S.a", "s::S.b"]);
        assert_eq!(f.edges.len(), 1);
        assert_eq!(f.edges[0].held, "s::S.a");
        assert_eq!(f.edges[0].acquired, "s::S.b");
    }

    #[test]
    fn consistent_order_is_cycle_free_and_inversion_is_detected() {
        let ok = "pub struct S { a: Mutex<u32>, b: Mutex<u32> }\nimpl S {\n    \
                  pub fn one(&self) {\n        let g = self.a.lock();\n        \
                  let h = self.b.lock();\n        drop(h);\n        drop(g);\n    }\n    \
                  pub fn two(&self) {\n        let g = self.a.lock();\n        \
                  let h = self.b.lock();\n        drop(h);\n        drop(g);\n    }\n}\n";
        let g = build(&[unit("rust/src/s.rs", ok)]);
        assert!(lock_order(&g).is_empty(), "consistent order must stay clean");

        let bad = "pub struct S { a: Mutex<u32>, b: Mutex<u32> }\nimpl S {\n    \
                   pub fn one(&self) {\n        let g = self.a.lock();\n        \
                   let h = self.b.lock();\n        drop(h);\n        drop(g);\n    }\n    \
                   pub fn two(&self) {\n        let h = self.b.lock();\n        \
                   let g = self.a.lock();\n        drop(g);\n        drop(h);\n    }\n}\n";
        let g = build(&[unit("rust/src/s.rs", bad)]);
        let findings = lock_order(&g);
        assert_eq!(findings.len(), 1, "{findings:?}");
        assert!(findings[0].1.note.contains("lock-order cycle"), "{}", findings[0].1.note);
    }

    #[test]
    fn interprocedural_cycle_through_a_call_is_detected() {
        let src = "pub struct S { a: Mutex<u32>, b: Mutex<u32> }\nimpl S {\n    \
                   pub fn outer(&self) {\n        let g = self.a.lock();\n        \
                   self.inner();\n        drop(g);\n    }\n    \
                   pub fn inner(&self) {\n        let h = self.b.lock();\n        \
                   let g = self.a.lock();\n        drop(g);\n        drop(h);\n    }\n}\n";
        let g = build(&[unit("rust/src/s.rs", src)]);
        let findings = lock_order(&g);
        assert!(!findings.is_empty(), "a->call->b->a inversion must be found");
    }

    #[test]
    fn dropped_guards_do_not_create_edges() {
        let src = "pub struct S { a: Mutex<u32>, b: Mutex<u32> }\nimpl S {\n    \
                   pub fn seq(&self) {\n        let g = self.a.lock();\n        \
                   drop(g);\n        let h = self.b.lock();\n        drop(h);\n    }\n}\n";
        let g = build(&[unit("rust/src/s.rs", src)]);
        let f = g.get("s::S::seq").expect("indexed");
        assert!(f.edges.is_empty(), "{:?}", f.edges);
    }

    #[test]
    fn block_scoped_guards_release_at_block_end() {
        let src = "pub struct S { a: Mutex<u32>, b: Mutex<u32> }\nimpl S {\n    \
                   pub fn scoped(&self) {\n        let x = {\n            \
                   let g = self.a.lock();\n            1\n        };\n        \
                   let h = self.b.lock();\n        drop(h);\n        drop(x);\n    }\n}\n";
        let g = build(&[unit("rust/src/s.rs", src)]);
        let f = g.get("s::S::scoped").expect("indexed");
        assert!(f.edges.is_empty(), "block guard must not outlive its block: {:?}", f.edges);
    }

    #[test]
    fn callgraph_json_is_canonical() {
        let g = build(&[unit("rust/src/alpha.rs", "pub fn tick() {}\n")]);
        let json = g.to_json();
        assert!(crate::util::json::parse(&json).is_ok());
        assert!(json.contains("\"schema_version\": 1"));
        assert!(json.contains("\"alpha::tick\""));
        assert_eq!(json, g.to_json());
    }
}
