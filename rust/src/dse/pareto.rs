//! Pareto-front extraction over the (energy, sigma) objective plane.
//!
//! Both objectives are minimized: a grid point is on the front iff no
//! other point is at least as good on both axes and strictly better on
//! one (weak domination — DESIGN.md §8). Ties survive: two points with
//! identical objectives are both on the front, so inert-axis duplicates
//! (e.g. a `v_bulk` sweep over an unbiased baseline) never knock each
//! other out. Points with non-finite objectives are never on the front.

/// Flag the Pareto-optimal points of a set of `(energy, sigma)` pairs,
/// minimizing both coordinates. Returns one flag per input, in order.
///
/// ```
/// use smart_insram::dse::pareto_flags;
/// // (energy, sigma): the third point is dominated by the second.
/// let flags = pareto_flags(&[(1.0, 3.0), (2.0, 1.0), (3.0, 2.0)]);
/// assert_eq!(flags, vec![true, true, false]);
/// ```
pub fn pareto_flags(objectives: &[(f64, f64)]) -> Vec<bool> {
    let finite = |p: (f64, f64)| p.0.is_finite() && p.1.is_finite();
    let dominates = |a: (f64, f64), b: (f64, f64)| {
        a.0 <= b.0 && a.1 <= b.1 && (a.0 < b.0 || a.1 < b.1)
    };
    objectives
        .iter()
        .map(|&p| finite(p) && !objectives.iter().any(|&q| finite(q) && dominates(q, p)))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn hand_computed_fixture() {
        // The fixture the acceptance criteria reference: six operating
        // points on the (energy pJ, sigma/FS) plane, front worked out by
        // hand. A: cheapest, D: most accurate, B: the knee — C, E, F are
        // each dominated (C by B, E by B, F by D).
        let pts = [
            (0.50, 0.090), // A: on the front (nothing is cheaper)
            (0.70, 0.020), // B: on the front (knee)
            (0.75, 0.030), // C: dominated by B (0.70 <= 0.75, 0.020 < 0.030)
            (0.95, 0.008), // D: on the front (nothing is more accurate)
            (0.90, 0.025), // E: dominated by B
            (1.10, 0.009), // F: dominated by D
        ];
        assert_eq!(pareto_flags(&pts), vec![true, true, false, true, false, false]);
    }

    #[test]
    fn single_point_is_always_optimal() {
        assert_eq!(pareto_flags(&[(5.0, 5.0)]), vec![true]);
        assert_eq!(pareto_flags(&[]), Vec::<bool>::new());
    }

    #[test]
    fn duplicates_survive_together() {
        let flags = pareto_flags(&[(1.0, 1.0), (1.0, 1.0), (2.0, 2.0)]);
        assert_eq!(flags, vec![true, true, false]);
    }

    #[test]
    fn equal_on_one_axis_still_dominates() {
        // same energy, strictly better sigma -> the first point falls
        let flags = pareto_flags(&[(1.0, 2.0), (1.0, 1.0)]);
        assert_eq!(flags, vec![false, true]);
    }

    #[test]
    fn non_finite_points_never_make_the_front() {
        let flags = pareto_flags(&[(f64::NAN, 0.1), (1.0, f64::INFINITY), (1.0, 0.1)]);
        assert_eq!(flags, vec![false, false, true]);
    }

    #[test]
    fn front_of_a_monotone_chain_is_everything() {
        // strictly trading energy for accuracy: the whole chain is optimal
        let pts: Vec<(f64, f64)> = (0..5).map(|i| (i as f64, 4.0 - i as f64)).collect();
        assert!(pareto_flags(&pts).iter().all(|&f| f));
    }
}
