//! Sweep execution: grid -> campaigns -> per-point stats -> artifacts.
//!
//! Every grid point runs as one sharded native campaign
//! ([`crate::coordinator::run_campaign`]); only the point's aggregate
//! statistics are retained, so sweep memory is O(grid points) no matter
//! how many Monte-Carlo samples each point draws. The CSV artifact doubles
//! as the resume checkpoint: it is rewritten after every computed point,
//! and with [`SweepOptions::resume`] set, rows whose (variant, vdd,
//! v_bulk, bits, corner, kernel, n_mc, seed, card-fingerprint) key
//! already exists in `sweep.csv` are reused instead of recomputed — so an
//! interrupted sweep resumes from its last completed point, and a
//! checkpoint from an edited spec (different seed, n_mc, or `[params.*]`
//! overrides) is never reused. Because every stored number is
//! canonicalized to the CSV cell precision first (6 significant digits),
//! a resumed sweep re-emits byte-identical artifacts (DESIGN.md §8).

use std::collections::BTreeMap;
use std::fmt::Write as _;
use std::path::PathBuf;

use anyhow::{Context, Result};

use crate::coordinator::{run_campaign_traced, Backend};
use crate::dac::WordlineDac;
use crate::mac::KernelKind;
use crate::energy::EnergyModel;
use crate::obs::{SpanId, Stopwatch, Tracer};
use crate::report::{canon, csv_cell};
use crate::util::json::{self, Value};

use super::pareto::pareto_flags;
use super::spec::{GridPoint, SweepSpec};

/// Execution knobs of one sweep run. `shards`/`threads`/`block` are pure
/// performance knobs and `resume` only skips work; `kernel` is an
/// **identity** field — the fast tier is tolerance-bounded rather than
/// bit-identical (DESIGN.md §13), so it enters every resume key and
/// artifact row.
#[derive(Debug, Clone)]
pub struct SweepOptions {
    /// Shards per campaign (0 = auto) — forwarded to the campaign runner.
    pub shards: usize,
    /// Worker threads per campaign (0 = auto).
    pub threads: usize,
    /// Trial-block size per campaign (0 = auto) — lanes per SoA block of
    /// the block-execution path (DESIGN.md §9).
    pub block: usize,
    /// Simulation kernel tier every grid point runs on (DESIGN.md §13).
    pub kernel: KernelKind,
    /// Reuse rows already present in the output CSV (cheap checkpointing
    /// for long sweeps).
    pub resume: bool,
    /// Directory receiving `sweep.csv` and `sweep.json`.
    pub out_dir: PathBuf,
    /// Trace sink (DESIGN.md §15): emits a `sweep` root span plus
    /// `grid_point` children (each wrapping its campaign) when enabled.
    /// Purely observational — artifacts are byte-identical whether
    /// tracing is on or off (`tests/obs.rs`).
    pub tracer: Tracer,
}

impl Default for SweepOptions {
    fn default() -> Self {
        Self {
            shards: 0,
            threads: 0,
            block: 0,
            kernel: KernelKind::Block,
            resume: false,
            out_dir: PathBuf::from("target/dse"),
            tracer: Tracer::disabled(),
        }
    }
}

/// Aggregate statistics of one grid point (one row of the artifacts).
#[derive(Debug, Clone, Copy)]
pub struct PointResult {
    /// The operating point these statistics belong to.
    pub point: GridPoint,
    /// Valid Monte-Carlo rows folded (operands x n_mc).
    pub rows: u64,
    /// Std-dev of the normalized error — Table 1's "Accuracy (STD.V)".
    pub sigma_norm: f64,
    /// RMS of the normalized error (includes systematic offset).
    pub rms_norm: f64,
    /// Bit-error rate at the 4-bit output grid.
    pub ber: f64,
    /// Saturation-exit (systematic) fault rate.
    pub fault_rate: f64,
    /// Full per-MAC energy (pJ): workload-mean bitline energy through the
    /// peripheral model, supply tracking the swept VDD.
    pub energy_pj: f64,
    /// Operating frequency (MHz) from the cycle-time model.
    pub freq_mhz: f64,
}

/// A finished sweep: per-point stats, the Pareto front, artifact paths.
#[derive(Debug, Clone)]
pub struct SweepResult {
    /// Sweep label (from the spec).
    pub name: String,
    /// Per-point statistics in canonical grid order.
    pub points: Vec<PointResult>,
    /// One flag per point: true iff on the energy-vs-sigma Pareto front.
    pub pareto: Vec<bool>,
    /// Grid points actually simulated this run.
    pub computed: usize,
    /// Grid points reused from the resume checkpoint.
    pub resumed: usize,
    /// Path of the CSV artifact (also the resume checkpoint).
    pub csv_path: PathBuf,
    /// Path of the JSON artifact.
    pub json_path: PathBuf,
}

impl SweepResult {
    /// The Pareto-optimal points, in canonical grid order.
    pub fn front(&self) -> Vec<&PointResult> {
        self.points
            .iter()
            .zip(&self.pareto)
            .filter_map(|(p, &on)| on.then_some(p))
            .collect()
    }
}

/// Column order of the CSV artifact; the first nine columns form the
/// resume key (`card` fingerprints the base model card so edited
/// `[params.*]` overrides invalidate old checkpoint rows; `kernel` makes
/// rows computed on a different tier non-reusable). Checkpoints from the
/// pre-kernel 16-column format fail the width check and recompute.
const CSV_HEADER: &str = "variant,vdd,v_bulk,bits,corner,kernel,n_mc,seed,card,rows,\
sigma_norm,rms_norm,ber,fault_rate,energy_pj,freq_mhz,pareto";

/// Run every grid point of `spec` and write the CSV/JSON artifacts.
///
/// Deterministic: the artifacts are byte-identical for any
/// [`SweepOptions::shards`]/[`SweepOptions::threads`] choice, and a
/// resumed run re-emits exactly the bytes a scratch run would produce.
pub fn run_sweep(spec: &SweepSpec, opts: &SweepOptions) -> Result<SweepResult> {
    spec.validate().map_err(|e| anyhow::anyhow!(e))?;
    let points = spec.grid.expand();
    let csv_path = opts.out_dir.join("sweep.csv");
    let json_path = opts.out_dir.join("sweep.json");

    let mut prior: BTreeMap<String, ResumeRow> = BTreeMap::new();
    if opts.resume {
        if let Ok(text) = std::fs::read_to_string(&csv_path) {
            prior = parse_resume_rows(&text);
        }
    }
    // fail on an unwritable --out before simulating anything
    std::fs::create_dir_all(&opts.out_dir)
        .with_context(|| format!("creating {}", opts.out_dir.display()))?;

    let flags_of = |results: &[PointResult]| {
        let objectives: Vec<(f64, f64)> =
            results.iter().map(|r| (r.energy_pj, r.sigma_norm)).collect();
        pareto_flags(&objectives)
    };

    let mut sspan = opts.tracer.span("sweep");
    sspan.attr_str("name", &spec.name);
    sspan.attr_str("kernel", opts.kernel.token());
    sspan.attr_u64("points", points.len() as u64);
    let parent = sspan.id();

    let mut results: Vec<PointResult> = Vec::with_capacity(points.len());
    let (mut computed, mut resumed) = (0usize, 0usize);
    for point in &points {
        let key = point_key(point, spec, opts.kernel);
        if let Some(row) = prior.get(&key) {
            results.push(row.to_result(*point));
            resumed += 1;
        } else {
            results.push(grid_point_traced(spec, point, opts, parent)?);
            computed += 1;
            // Checkpoint after every computed point, so an interrupted
            // sweep resumes from the last completed point rather than
            // from scratch. Pareto flags are provisional here (computed
            // over the rows so far); the final write below recomputes
            // them over the full grid — and resume ignores the flag
            // column anyway.
            let partial = flags_of(&results);
            std::fs::write(&csv_path, render_csv(spec, &results, &partial, opts.kernel))
                .with_context(|| format!("checkpointing {}", csv_path.display()))?;
        }
    }

    let pareto = flags_of(&results);
    std::fs::write(&csv_path, render_csv(spec, &results, &pareto, opts.kernel))
        .with_context(|| format!("writing {}", csv_path.display()))?;
    std::fs::write(&json_path, sweep_json(spec, &results, &pareto, opts.kernel))
        .with_context(|| format!("writing {}", json_path.display()))?;

    sspan.attr_u64("computed", computed as u64);
    sspan.attr_u64("resumed", resumed as u64);
    opts.tracer.finish(sspan);

    Ok(SweepResult {
        name: spec.name.clone(),
        points: results,
        pareto,
        computed,
        resumed,
        csv_path,
        json_path,
    })
}

/// Simulate one grid point: a full sharded campaign plus the energy model
/// evaluated at the point's operating conditions. Public so embedders
/// (`smart serve`'s `POST /v1/sweep/point`) can run a single point
/// through exactly the sweep pipeline — statistics are canonicalized
/// here, so a point's numbers are byte-identical however it is reached.
pub fn run_grid_point(
    spec: &SweepSpec,
    point: &GridPoint,
    opts: &SweepOptions,
) -> Result<PointResult> {
    grid_point_traced(spec, point, opts, None)
}

/// [`run_grid_point`] with an explicit trace parent, so sweep-driven
/// points hang under the `sweep` root span while solo embedders (the
/// serve layer) emit parentless `grid_point` phases.
fn grid_point_traced(
    spec: &SweepSpec,
    point: &GridPoint,
    opts: &SweepOptions,
    parent: Option<SpanId>,
) -> Result<PointResult> {
    let mut span = opts.tracer.span_started("grid_point", parent, Stopwatch::start());
    span.attr_u64("point", point.index as u64);
    span.attr_str("variant", point.variant.token());
    let params = point.apply(&spec.params);
    let cspec = point.campaign_spec(
        spec.seed,
        spec.n_mc,
        opts.shards,
        opts.threads,
        opts.block,
        opts.kernel,
    );
    let rep = run_campaign_traced(&params, &cspec, Backend::Native, None, &opts.tracer)
        .with_context(|| format!("grid point {} ({})", point.index, point.label()))?;
    opts.tracer.finish(span);
    Ok(point_result(spec, point, &rep))
}

/// Fold a finished campaign report into one grid point's canonical
/// statistics: the energy model evaluated at the point's operating
/// conditions, every float canonicalized to artifact precision. Public
/// so embedders that run the campaign themselves (the `smart serve`
/// batching layer merges compatible points through one engine) reach
/// byte-identical numbers to [`run_grid_point`].
pub fn point_result(
    spec: &SweepSpec,
    point: &GridPoint,
    rep: &crate::coordinator::CampaignReport,
) -> PointResult {
    let params = point.apply(&spec.params);
    // Per-MAC cost at this operating point: the campaign's workload-mean
    // raw bitline energy through the peripheral model. op_energy's
    // contract is raw energy from the 1 V card rescaled by supply^2
    // (see nominal_cost / Table 1); the campaign already simulated at
    // the swept VDD, so normalize its raw energy back to the 1 V card
    // before letting the supply (which tracks the swept VDD) rescale it
    // — otherwise the bitline term would count vdd^2 twice.
    let mut cfg = point.variant.config(&params);
    cfg.supply *= point.vdd;
    let raw_1v = rep.energy.mean() / (point.vdd * point.vdd);
    let dac = WordlineDac::new(cfg.dac_mode, &params.device, &params.circuit, cfg.v_bulk);
    let v_wl_max = dac.v_wl(((1u16 << point.bits) - 1) as u8);
    let cost = EnergyModel::default().cost(&cfg, raw_1v, rep.full_scale, v_wl_max);

    PointResult {
        point: *point,
        rows: rep.rows,
        sigma_norm: canon(rep.accuracy.sigma_norm),
        rms_norm: canon(rep.accuracy.rms_norm),
        ber: canon(rep.accuracy.ber),
        fault_rate: canon(rep.accuracy.fault_rate),
        energy_pj: canon(cost.energy * 1e12),
        freq_mhz: canon(cost.frequency / 1e6),
    }
}

/// The canonical identity key of one grid point under one sweep spec and
/// kernel tier: the first nine CSV columns, rendered exactly as the
/// writer renders them (floats through [`csv_cell`]'s
/// 6-significant-digit precision). Doubles as the `sweep.csv` resume key
/// and the `smart serve` cache key for `POST /v1/sweep/point`.
pub fn point_key(p: &GridPoint, spec: &SweepSpec, kernel: KernelKind) -> String {
    format!(
        "{},{},{},{},{},{},{},{},{}",
        p.variant.token(),
        csv_cell(p.vdd),
        csv_cell(p.v_bulk),
        p.bits,
        p.corner.name(),
        kernel.token(),
        spec.n_mc,
        spec.seed,
        card_fingerprint(&spec.params)
    )
}

/// FNV-1a fingerprint of the base model card, EXCLUDING `device.vdd` and
/// `circuit.v_bulk_smart` (those are per-point key columns already).
/// Any other `[params.*]` override changes the fingerprint, so `--resume`
/// never reuses rows computed under a different card. Crate-visible so
/// the `smart serve` batching layer can use it as a compatibility-group
/// field for `/v1/sweep/point` coalescing.
pub(crate) fn card_fingerprint(p: &crate::params::Params) -> String {
    let d = &p.device;
    let c = &p.circuit;
    let canon = format!(
        // lint:allow(D5): fingerprint needs exact roundtrip floats, not canon rounding
        "{:e},{:e},{:e},{:e},{:e},{:e},{:e},{:e},{:e},{:e},{:e},{:e},{},{},{:e},{:e}",
        d.vth0,
        d.gamma,
        d.phi2f,
        d.mu_cox,
        d.w_over_l,
        d.lam,
        d.n_sub,
        d.vt_thermal,
        d.k_leak,
        c.c_blb,
        c.wl_max,
        c.t_sample,
        c.n_steps,
        c.n_bits,
        c.sigma_vth,
        c.sigma_beta
    );
    format!("{:016x}", crate::util::fnv1a(&canon))
}

fn render_csv(
    spec: &SweepSpec,
    results: &[PointResult],
    pareto: &[bool],
    kernel: KernelKind,
) -> String {
    let mut s = String::with_capacity(results.len() * 128 + CSV_HEADER.len() + 1);
    s.push_str(CSV_HEADER);
    s.push('\n');
    for (r, &front) in results.iter().zip(pareto) {
        let _ = writeln!(
            s,
            "{},{},{},{},{},{},{},{},{}",
            point_key(&r.point, spec, kernel),
            r.rows,
            csv_cell(r.sigma_norm),
            csv_cell(r.rms_norm),
            csv_cell(r.ber),
            csv_cell(r.fault_rate),
            csv_cell(r.energy_pj),
            csv_cell(r.freq_mhz),
            u8::from(front)
        );
    }
    s
}

/// Render the canonical `sweep.json` artifact for `results` (one entry
/// per grid point, every float already canonicalized by
/// [`run_grid_point`]). The single JSON encoder for sweep results: the
/// CLI artifact writer and `smart serve`'s `POST /v1/sweep/point`
/// responses both call it, so a served single-point sweep is
/// byte-identical to the `smart sweep` artifact of the same spec and
/// kernel tier.
pub fn sweep_json(
    spec: &SweepSpec,
    results: &[PointResult],
    pareto: &[bool],
    kernel: KernelKind,
) -> String {
    let mut root = BTreeMap::new();
    root.insert("name".to_string(), Value::Str(spec.name.clone()));
    root.insert("seed".to_string(), Value::Num(spec.seed as f64));
    root.insert("n_mc".to_string(), Value::Num(f64::from(spec.n_mc)));
    root.insert("kernel".to_string(), Value::Str(kernel.token().to_string()));
    root.insert("card".to_string(), Value::Str(card_fingerprint(&spec.params)));
    let pts: Vec<Value> = results
        .iter()
        .zip(pareto)
        .map(|(r, &front)| {
            let mut m = BTreeMap::new();
            m.insert("variant".to_string(), Value::Str(r.point.variant.token().to_string()));
            m.insert("vdd".to_string(), Value::Num(r.point.vdd));
            m.insert("v_bulk".to_string(), Value::Num(r.point.v_bulk));
            m.insert("bits".to_string(), Value::Num(f64::from(r.point.bits)));
            m.insert("corner".to_string(), Value::Str(r.point.corner.name().to_string()));
            m.insert("rows".to_string(), Value::Num(r.rows as f64));
            m.insert("sigma_norm".to_string(), Value::Num(r.sigma_norm));
            m.insert("rms_norm".to_string(), Value::Num(r.rms_norm));
            m.insert("ber".to_string(), Value::Num(r.ber));
            m.insert("fault_rate".to_string(), Value::Num(r.fault_rate));
            m.insert("energy_pj".to_string(), Value::Num(r.energy_pj));
            m.insert("freq_mhz".to_string(), Value::Num(r.freq_mhz));
            m.insert("pareto".to_string(), Value::Bool(front));
            Value::Obj(m)
        })
        .collect();
    root.insert("points".to_string(), Value::Arr(pts));
    let mut text = json::to_string_pretty(&Value::Obj(root));
    text.push('\n');
    text
}

/// Stats columns of one checkpoint row (the key is the map key).
struct ResumeRow {
    rows: u64,
    sigma_norm: f64,
    rms_norm: f64,
    ber: f64,
    fault_rate: f64,
    energy_pj: f64,
    freq_mhz: f64,
}

impl ResumeRow {
    fn to_result(&self, point: GridPoint) -> PointResult {
        PointResult {
            point,
            rows: self.rows,
            sigma_norm: self.sigma_norm,
            rms_norm: self.rms_norm,
            ber: self.ber,
            fault_rate: self.fault_rate,
            energy_pj: self.energy_pj,
            freq_mhz: self.freq_mhz,
        }
    }
}

/// Parse checkpoint rows from a previous `sweep.csv`. Rows that fail to
/// parse (e.g. a file truncated mid-write) are silently skipped — they
/// are simply recomputed.
fn parse_resume_rows(text: &str) -> BTreeMap<String, ResumeRow> {
    let mut out = BTreeMap::new();
    for line in text.lines().skip(1) {
        let f: Vec<&str> = line.split(',').collect();
        if f.len() != 17 {
            continue;
        }
        let cell = |s: &str| -> Option<f64> {
            // empty cell = the CSV writer's non-finite sentinel
            if s.is_empty() {
                Some(f64::NAN)
            } else {
                s.parse().ok()
            }
        };
        let Ok(rows) = f[9].parse::<u64>() else { continue };
        let (Some(sigma_norm), Some(rms_norm), Some(ber), Some(fault_rate)) =
            (cell(f[10]), cell(f[11]), cell(f[12]), cell(f[13]))
        else {
            continue;
        };
        let (Some(energy_pj), Some(freq_mhz)) = (cell(f[14]), cell(f[15])) else { continue };
        out.insert(
            f[..9].join(","),
            ResumeRow { rows, sigma_norm, rms_norm, ber, fault_rate, energy_pj, freq_mhz },
        );
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn canon_is_idempotent_and_preserves_non_finite() {
        let x = canon(0.012_345_678_9);
        assert_eq!(canon(x), x);
        assert_eq!(format!("{x:.6e}"), "1.234568e-2");
        assert!(canon(f64::NAN).is_nan());
        assert_eq!(canon(f64::INFINITY), f64::INFINITY);
        assert_eq!(canon(0.0), 0.0);
    }

    #[test]
    fn resume_rows_roundtrip_through_the_writer() {
        let spec = SweepSpec::parse("name = \"rt\"\nn_mc = 8\nseed = 3\n").unwrap();
        let point = spec.grid.expand()[0];
        let r = PointResult {
            point,
            rows: 128,
            sigma_norm: canon(0.0123456789),
            rms_norm: canon(0.02),
            ber: canon(0.5),
            fault_rate: f64::NAN,
            energy_pj: canon(0.783),
            freq_mhz: canon(250.0),
        };
        let text = render_csv(&spec, &[r], &[true], KernelKind::Fast);
        let rows = parse_resume_rows(&text);
        assert_eq!(rows.len(), 1);
        let key = point_key(&point, &spec, KernelKind::Fast);
        let back = rows.get(&key).expect("key matches");
        assert_eq!(back.rows, 128);
        assert_eq!(back.sigma_norm.to_bits(), r.sigma_norm.to_bits());
        assert!(back.fault_rate.is_nan());
        // re-render from the parsed row: byte-identical
        let again = render_csv(&spec, &[back.to_result(point)], &[true], KernelKind::Fast);
        assert_eq!(text, again);
        // a row computed on one kernel tier never resumes another
        assert!(rows.get(&point_key(&point, &spec, KernelKind::Block)).is_none());
    }

    #[test]
    fn corrupt_resume_rows_are_skipped() {
        let text = "header\nnot,enough,cols\n\
                    smart,1.000000e0,0.000000e0,4,tt,block,8,3,cafe,oops,1e-2,1e-2,0,0,1,250,0\n";
        assert!(parse_resume_rows(text).is_empty());
        // pre-kernel 16-column checkpoints fail the width check (recomputed)
        let old = "header\nsmart,1.000000e0,0.000000e0,4,tt,8,3,cafe,128,1e-2,1e-2,0,0,1,250,0\n";
        assert!(parse_resume_rows(old).is_empty());
    }

    #[test]
    fn card_fingerprint_tracks_overrides_but_not_swept_fields() {
        let base = SweepSpec::parse("name = \"fp\"\n").unwrap();
        let overridden =
            SweepSpec::parse("name = \"fp\"\n[params.circuit]\nsigma_vth = 0.05\n").unwrap();
        assert_ne!(card_fingerprint(&base.params), card_fingerprint(&overridden.params));
        // the swept fields are per-point key columns, not card identity
        let mut swept = base.params;
        swept.device.vdd = 0.9;
        swept.circuit.v_bulk_smart = 0.3;
        assert_eq!(card_fingerprint(&base.params), card_fingerprint(&swept));
    }
}
