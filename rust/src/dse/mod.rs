//! Design-space exploration (DSE): sweep the SMART design knobs across a
//! multi-dimensional grid and map the energy–accuracy trade-off.
//!
//! The paper evaluates one operating point (1 V supply, 0.6 V body bias,
//! 4×4-bit MAC — Table 1); this subsystem turns the repo into an
//! exploration engine over the whole neighbourhood of that point
//! (DESIGN.md §8). A sweep is specified in a `configs/dse.toml`-style
//! file as one axis list per design knob:
//!
//! * `variant` — the designs of Table 1 (`smart`, `aid`, `imac`,
//!   `smart-on-imac`);
//! * `vdd` — cell supply voltage (V), the precharge level the transient
//!   integrates from;
//! * `v_bulk` — threshold-suppression level: the forward body bias (V)
//!   on the dual-VDD rail. It drives the biased variants (`smart`,
//!   `smart-on-imac`); the unbiased baselines ignore it, so
//!   `smart` at `v_bulk = 0` *is* the AID baseline;
//! * `bits` — operand bit-width (1..=4): the workload sweeps the full
//!   `bits`-wide operand space, the IMAC-style reduced-precision study;
//! * `corner` — process corner (`tt`/`ff`/`ss`).
//!
//! [`SweepSpec::parse`] expands the axes into a cartesian grid
//! ([`GridAxes::expand`]), [`run_sweep`] executes every point through the
//! sharded Monte-Carlo campaign runner
//! ([`crate::coordinator::run_campaign`], native backend) with streaming
//! per-point aggregation (memory stays O(grid), never O(samples)), and
//! the post-pass extracts the energy-vs-sigma Pareto front
//! ([`pareto_flags`]) and writes CSV/JSON artifacts.
//!
//! Determinism: a sweep's artifacts are **byte-identical** for any
//! `--shards`/`--threads` choice — the campaign layer's bit-reproducibility
//! contract (DESIGN.md §4) carries through the per-point statistics, and
//! every artifact number is canonicalized to the CSV cell precision so
//! `--resume` (which re-reads rows from a previous `sweep.csv`) re-emits
//! the same bytes it read.

mod pareto;
mod runner;
mod spec;

pub use pareto::pareto_flags;
pub use runner::{
    point_key, point_result, run_grid_point, run_sweep, sweep_json, PointResult, SweepOptions,
    SweepResult,
};
pub(crate) use runner::card_fingerprint;
pub use spec::{GridAxes, GridPoint, SweepSpec};
