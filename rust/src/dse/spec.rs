//! Sweep specification: the DSE grid a user can check in.
//!
//! A sweep file is TOML-lite (`crate::util::toml_lite`): top-level
//! `name`/`seed`/`n_mc`, optional `[params.*]` model-card overrides
//! (shared by every grid point), and a `[grid]` table with one axis list
//! per design knob. Missing axes collapse to the card's single default
//! value, so the degenerate sweep (no `[grid]`) is exactly one campaign.

use std::path::Path;

use anyhow::{Context, Result};

use crate::coordinator::{CampaignSpec, Workload};
use crate::mac::{KernelKind, Variant};
use crate::montecarlo::Corner;
use crate::params::Params;
use crate::util::{json::Value, toml_lite};

/// Axis lists of the design-space grid. Grid points are the cartesian
/// product, expanded in canonical nested order (variant, vdd, v_bulk,
/// bits, corner) — the order the artifacts list rows in.
#[derive(Debug, Clone, PartialEq)]
pub struct GridAxes {
    /// Design variants to sweep (Table 1 rows).
    pub variants: Vec<Variant>,
    /// Cell supply voltages (V).
    pub vdd: Vec<f64>,
    /// Threshold-suppression levels: forward body bias (V). Inert for the
    /// unbiased baselines (`aid`, `imac`).
    pub v_bulk: Vec<f64>,
    /// Operand bit-widths (1..=4): each point runs the full `bits`-wide
    /// operand space.
    pub bits: Vec<u32>,
    /// Process corners.
    pub corners: Vec<Corner>,
}

impl GridAxes {
    /// Number of grid points (product of the axis lengths).
    pub fn len(&self) -> usize {
        self.variants.len()
            * self.vdd.len()
            * self.v_bulk.len()
            * self.bits.len()
            * self.corners.len()
    }

    /// True when any axis is empty (the grid has no points).
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Expand into the full cartesian product, in canonical order.
    pub fn expand(&self) -> Vec<GridPoint> {
        let mut out = Vec::with_capacity(self.len());
        let mut index = 0usize;
        for &variant in &self.variants {
            for &vdd in &self.vdd {
                for &v_bulk in &self.v_bulk {
                    for &bits in &self.bits {
                        for &corner in &self.corners {
                            out.push(GridPoint { index, variant, vdd, v_bulk, bits, corner });
                            index += 1;
                        }
                    }
                }
            }
        }
        out
    }
}

/// One operating point of the design-space grid.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct GridPoint {
    /// Position in the canonical grid order (row index in the artifacts).
    pub index: usize,
    /// Design variant.
    pub variant: Variant,
    /// Cell supply voltage (V).
    pub vdd: f64,
    /// Forward body bias (V) — the threshold-suppression knob.
    pub v_bulk: f64,
    /// Operand bit-width (1..=4).
    pub bits: u32,
    /// Process corner.
    pub corner: Corner,
}

impl GridPoint {
    /// Model card for this point: the base card with the swept supply and
    /// body-bias rail applied.
    pub fn apply(&self, base: &Params) -> Params {
        let mut p = *base;
        p.device.vdd = self.vdd;
        p.circuit.v_bulk_smart = self.v_bulk;
        p
    }

    /// Campaign spec running this point's workload through the sharded
    /// block-execution Monte-Carlo runner. `shards`/`threads`/`block` are
    /// pure performance knobs — the artifacts never move; `kernel` is an
    /// identity field (the fast tier is tolerance-bounded, DESIGN.md §13)
    /// and is recorded in every sweep row.
    pub fn campaign_spec(
        &self,
        seed: u64,
        n_mc: u32,
        shards: usize,
        threads: usize,
        block: usize,
        kernel: KernelKind,
    ) -> CampaignSpec {
        CampaignSpec {
            variant: self.variant,
            workload: Workload::BitSweep { bits: self.bits },
            n_mc,
            seed,
            corner: self.corner,
            workers: threads,
            batch: 0,
            shards,
            block,
            kernel,
        }
    }

    /// Short human label for progress lines and panels.
    pub fn label(&self) -> String {
        format!(
            // lint:allow(D5): human progress label only — never artifact bytes
            "{} vdd={:.2} v_bulk={:.2} bits={} {}",
            self.variant.token(),
            self.vdd,
            self.v_bulk,
            self.bits,
            self.corner.name()
        )
    }
}

/// Everything needed to reproduce a design-space sweep bit-for-bit.
///
/// ```
/// let toml = r#"
/// name = "demo"
/// n_mc = 4
/// [grid]
/// variant = ["smart", "aid"]
/// v_bulk = [0.0, 0.6]
/// "#;
/// let spec = smart_insram::dse::SweepSpec::parse(toml).unwrap();
/// assert_eq!(spec.grid.expand().len(), 4);
/// assert_eq!(spec.n_mc, 4);
/// ```
#[derive(Debug, Clone, PartialEq)]
pub struct SweepSpec {
    /// Human label for reports and the JSON artifact.
    pub name: String,
    /// Base RNG seed shared by every grid point (campaign determinism).
    pub seed: u64,
    /// Monte-Carlo samples per operand pair at every point.
    pub n_mc: u32,
    /// Base model card (defaults + any `[params.*]` overrides).
    pub params: Params,
    /// The design-space grid.
    pub grid: GridAxes,
}

impl SweepSpec {
    /// Load and parse a sweep file from disk.
    pub fn load(path: impl AsRef<Path>) -> Result<Self> {
        let text = std::fs::read_to_string(path.as_ref())
            .with_context(|| format!("reading {}", path.as_ref().display()))?;
        Self::parse(&text)
    }

    /// Parse a sweep document (see the module docs for the format).
    pub fn parse(text: &str) -> Result<Self> {
        let doc = toml_lite::parse(text).map_err(|e| anyhow::anyhow!("sweep TOML: {e}"))?;
        let name = doc
            .get("name")
            .and_then(Value::as_str)
            .unwrap_or("dse")
            .to_string();
        let mut params = Params::default();
        if let Some(p) = doc.get("params") {
            params.apply_overrides(p).context("[params] overrides")?;
        }
        let u = |k: &str, default: u64| doc.get(k).and_then(Value::as_u64).unwrap_or(default);
        let empty = Value::Obj(Default::default());
        let grid_v = doc.get("grid").unwrap_or(&empty);
        let grid = GridAxes {
            variants: str_axis(grid_v, "variant", vec![Variant::Smart])?,
            vdd: num_axis(grid_v, "vdd", vec![params.device.vdd])?,
            v_bulk: num_axis(grid_v, "v_bulk", vec![params.circuit.v_bulk_smart])?,
            bits: bit_axis(grid_v, "bits", vec![params.circuit.n_bits])?,
            corners: str_axis(grid_v, "corner", vec![Corner::Tt])?,
        };
        let n_mc = u("n_mc", 1000);
        let n_mc =
            u32::try_from(n_mc).map_err(|_| anyhow::anyhow!("dse.n_mc = {n_mc} exceeds u32"))?;
        let spec = Self { name, seed: u("seed", 2022), n_mc, params, grid };
        spec.validate().map_err(|e| anyhow::anyhow!(e))?;
        Ok(spec)
    }

    /// Check the spec is runnable and reproducible.
    pub fn validate(&self) -> Result<(), String> {
        if self.n_mc == 0 {
            return Err("n_mc must be >= 1".into());
        }
        // Same f64-representability bound as CampaignSpec::validate.
        if self.seed >= (1u64 << 53) {
            return Err("seed must be < 2^53 (config numbers are f64)".into());
        }
        if self.grid.is_empty() {
            return Err("grid has an empty axis".into());
        }
        for &b in &self.grid.bits {
            if !(1..=4).contains(&b) {
                return Err(format!("grid.bits value {b} outside 1..=4"));
            }
        }
        for &v in &self.grid.vdd {
            if !v.is_finite() || v <= 0.0 {
                return Err(format!("grid.vdd value {v} must be a positive voltage"));
            }
        }
        for &v in &self.grid.v_bulk {
            if !v.is_finite() || v < 0.0 {
                return Err(format!("grid.v_bulk value {v} must be >= 0"));
            }
        }
        Ok(())
    }
}

/// A single value or a list — both are accepted for every axis.
fn list_of(v: &Value) -> &[Value] {
    match v {
        Value::Arr(a) => a,
        other => std::slice::from_ref(other),
    }
}

fn str_axis<T>(grid: &Value, key: &str, default: Vec<T>) -> Result<Vec<T>>
where
    T: std::str::FromStr<Err = String>,
{
    let Some(v) = grid.get(key) else { return Ok(default) };
    let mut out = Vec::new();
    for item in list_of(v) {
        let s = item
            .as_str()
            .ok_or_else(|| anyhow::anyhow!("grid.{key}: expected a string list"))?;
        out.push(s.parse().map_err(|e: String| anyhow::anyhow!("grid.{key}: {e}"))?);
    }
    Ok(out)
}

fn num_axis(grid: &Value, key: &str, default: Vec<f64>) -> Result<Vec<f64>> {
    let Some(v) = grid.get(key) else { return Ok(default) };
    let mut out = Vec::new();
    for item in list_of(v) {
        out.push(
            item.as_f64()
                .ok_or_else(|| anyhow::anyhow!("grid.{key}: expected a number list"))?,
        );
    }
    Ok(out)
}

fn bit_axis(grid: &Value, key: &str, default: Vec<u32>) -> Result<Vec<u32>> {
    let Some(v) = grid.get(key) else { return Ok(default) };
    let mut out = Vec::new();
    for item in list_of(v) {
        let n = item
            .as_u64()
            .ok_or_else(|| anyhow::anyhow!("grid.{key}: expected an integer list"))?;
        out.push(
            u32::try_from(n).map_err(|_| anyhow::anyhow!("grid.{key}: {n} exceeds u32"))?,
        );
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    const EXAMPLE: &str = r#"
        name = "dse-test"
        seed = 7
        n_mc = 16
        [grid]
        variant = ["smart", "aid"]
        vdd = [0.9, 1.0]
        v_bulk = [0.0, 0.3, 0.6]
        bits = [2, 4]
        corner = ["tt"]
    "#;

    #[test]
    fn parses_and_expands_cartesian_product() {
        let spec = SweepSpec::parse(EXAMPLE).unwrap();
        assert_eq!(spec.name, "dse-test");
        assert_eq!(spec.seed, 7);
        assert_eq!(spec.n_mc, 16);
        let points = spec.grid.expand();
        assert_eq!(points.len(), 2 * 2 * 3 * 2);
        assert_eq!(spec.grid.len(), points.len());
        // canonical order: corner fastest, variant slowest
        assert_eq!(points[0].variant, Variant::Smart);
        assert_eq!(points[0].vdd, 0.9);
        assert_eq!(points[0].v_bulk, 0.0);
        assert_eq!(points[0].bits, 2);
        assert_eq!(points[1].bits, 4);
        assert_eq!(points[2].v_bulk, 0.3);
        assert_eq!(points.last().unwrap().variant, Variant::Aid);
        // indices are the row order
        for (i, p) in points.iter().enumerate() {
            assert_eq!(p.index, i);
        }
    }

    #[test]
    fn missing_axes_default_to_single_card_values() {
        let spec = SweepSpec::parse("name = \"min\"\n[grid]\nvdd = [1.0]\n").unwrap();
        assert_eq!(spec.grid.variants, vec![Variant::Smart]);
        assert_eq!(spec.grid.v_bulk, vec![0.6]);
        assert_eq!(spec.grid.bits, vec![4]);
        assert_eq!(spec.grid.corners, vec![Corner::Tt]);
        assert_eq!(spec.grid.expand().len(), 1);
        // no [grid] at all: the degenerate one-point sweep
        let spec = SweepSpec::parse("name = \"none\"\n").unwrap();
        assert_eq!(spec.grid.expand().len(), 1);
        assert_eq!(spec.n_mc, 1000);
    }

    #[test]
    fn scalar_axis_values_accepted() {
        let spec = SweepSpec::parse("[grid]\nvdd = 0.95\nvariant = \"aid\"\n").unwrap();
        assert_eq!(spec.grid.vdd, vec![0.95]);
        assert_eq!(spec.grid.variants, vec![Variant::Aid]);
    }

    #[test]
    fn params_overrides_feed_axis_defaults() {
        let spec = SweepSpec::parse("[params.circuit]\nv_bulk_smart = 0.4\n").unwrap();
        assert_eq!(spec.grid.v_bulk, vec![0.4]);
    }

    #[test]
    fn validation_rejects_bad_specs() {
        assert!(SweepSpec::parse("[grid]\nbits = [5]\n").is_err());
        assert!(SweepSpec::parse("[grid]\nbits = [0]\n").is_err());
        assert!(SweepSpec::parse("[grid]\nvdd = [-1.0]\n").is_err());
        assert!(SweepSpec::parse("[grid]\nvdd = []\n").is_err());
        assert!(SweepSpec::parse("n_mc = 0\n").is_err());
        assert!(SweepSpec::parse("[grid]\nvariant = [\"bogus\"]\n").is_err());
        assert!(SweepSpec::parse("[grid]\ncorner = [\"xx\"]\n").is_err());
    }

    #[test]
    fn point_applies_card_overrides() {
        let spec = SweepSpec::parse(EXAMPLE).unwrap();
        let p = GridPoint {
            index: 0,
            variant: Variant::Smart,
            vdd: 0.9,
            v_bulk: 0.3,
            bits: 4,
            corner: Corner::Tt,
        };
        let card = p.apply(&spec.params);
        assert_eq!(card.device.vdd, 0.9);
        assert_eq!(card.circuit.v_bulk_smart, 0.3);
        let cspec = p.campaign_spec(spec.seed, spec.n_mc, 4, 2, 128, KernelKind::Fast);
        assert_eq!(cspec.n_mc, 16);
        assert_eq!(cspec.shards, 4);
        assert_eq!(cspec.workers, 2);
        assert_eq!(cspec.block, 128);
        assert_eq!(cspec.kernel, KernelKind::Fast);
        assert!(cspec.validate().is_ok());
        assert!(p.label().contains("smart"));
    }
}
