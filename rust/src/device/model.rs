//! Region-aware square-law NMOS model (paper Eq. 2 + Eq. 6).

use crate::params::DeviceCard;

/// Operating region of the access transistor at a given bias point.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Region {
    /// V_GS <= V_TH: only subthreshold conduction.
    Cutoff,
    /// V_DS >= V_OV: the analog-MAC operating region (Eq. 2 valid).
    Saturation,
    /// V_DS < V_OV: the paper's "systematic fault" region (§II-A).
    Triode,
}

/// An NMOS instance: a model card plus per-device mismatch offsets.
///
/// `dvth` and `dbeta` are the Pelgrom mismatch deviates drawn by
/// [`crate::montecarlo::MismatchSampler`]; nominal devices use 0.
#[derive(Debug, Clone, Copy)]
pub struct Mosfet {
    /// The shared model card this instance is built on.
    pub card: DeviceCard,
    /// Threshold mismatch offset (V).
    pub dvth: f64,
    /// Relative transconductance mismatch.
    pub dbeta: f64,
    /// Width scale relative to the card's W/L (Fig. 4 sweeps this).
    pub w_scale: f64,
}

impl Mosfet {
    /// Nominal (mismatch-free) device from a card.
    pub fn nominal(card: DeviceCard) -> Self {
        Self { card, dvth: 0.0, dbeta: 0.0, w_scale: 1.0 }
    }

    /// Device with mismatch deviates applied.
    pub fn with_mismatch(card: DeviceCard, dvth: f64, dbeta: f64) -> Self {
        Self { card, dvth, dbeta, w_scale: 1.0 }
    }

    /// Effective beta = mu*Cox*(W/L) including mismatch and width scaling (A/V^2).
    pub fn beta(&self) -> f64 {
        self.card.beta() * self.w_scale * (1.0 + self.dbeta)
    }

    /// Effective threshold under `v_bulk` forward body bias (Eq. 6).
    pub fn vth(&self, v_bulk: f64) -> f64 {
        self.card.vth_effective(v_bulk, self.dvth)
    }

    /// Operating region for gate overdrive `vov` and drain voltage `v_ds`.
    pub fn region(&self, vov: f64, v_ds: f64) -> Region {
        if vov <= 0.0 {
            Region::Cutoff
        } else if v_ds >= vov {
            Region::Saturation
        } else {
            Region::Triode
        }
    }

    /// Drain current (A) at gate voltage `v_gs`, drain voltage `v_ds`,
    /// bulk voltage `v_bulk` (source grounded — the M2acc/M3 stack of
    /// Fig. 1-b with M3 in deep triode).
    ///
    /// Matches `python/compile/kernels/ref.py::device_current` bit-for-bit
    /// in structure:
    ///   saturation: 1/2 * beta * Vov^2 * (1 + lam*Vds)
    ///   triode:     beta * (Vov - Vds/2) * Vds * (1 + lam*Vds)
    ///   cutoff:     beta * Vt^2 * exp(Vov/(n*Vt)) * (1 - exp(-Vds/Vt))
    /// Above threshold the square-law is floored at the Vov = 0
    /// subthreshold current so the weak->strong inversion handoff is
    /// continuous and monotone in V_GS (EKV-style moderate inversion).
    pub fn drain_current(&self, v_gs: f64, v_ds: f64, v_bulk: f64) -> f64 {
        let vov = v_gs - self.vth(v_bulk);
        self.drain_current_vov(vov, v_ds)
    }

    /// Drain current with a precomputed overdrive (hot-path form: the
    /// overdrive is time-invariant during a discharge transient).
    #[inline]
    pub fn drain_current_vov(&self, vov: f64, v_ds: f64) -> f64 {
        let c = &self.card;
        let beta = self.beta();
        let vt = c.vt_thermal;
        // Strong-inversion fast path (hot loop: two exp() calls saved).
        // For vov >= 3*vt the square-law branch provably dominates the
        // subthreshold floor at every v_ds >= 0:
        //   saturation: 1/2*vov^2 >= 4.5*vt^2 > vt^2 >= floor
        //   triode:     (vov - v/2)*v >= vov*v/2 > vt*v >= vt^2*(1-e^{-v/vt})
        // so max(i_on, i_sub) == i_on exactly and i_sub need not be built.
        if vov >= 3.0 * vt {
            let clm = 1.0 + c.lam * v_ds;
            let i = if v_ds >= vov {
                0.5 * beta * vov * vov * clm
            } else {
                beta * (vov - 0.5 * v_ds) * v_ds * clm
            };
            return i.max(0.0);
        }
        let i_sub = beta * vt * vt * (vov.min(0.0) / (c.n_sub * vt)).exp()
            * (1.0 - (-v_ds.max(0.0) / vt).exp());
        if vov > 0.0 {
            let clm = 1.0 + c.lam * v_ds;
            let i = if v_ds >= vov {
                0.5 * beta * vov * vov * clm
            } else {
                beta * (vov - 0.5 * v_ds) * v_ds * clm
            };
            i.max(0.0).max(i_sub)
        } else {
            i_sub
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::params::DeviceCard;

    fn dev() -> Mosfet {
        Mosfet::nominal(DeviceCard::default())
    }

    #[test]
    fn saturation_current_matches_eq2() {
        let d = dev();
        let vov: f64 = 0.4;
        let vds = 1.0;
        let want = 0.5 * d.beta() * vov * vov * (1.0 + d.card.lam * vds);
        assert!((d.drain_current_vov(vov, vds) - want).abs() < 1e-12);
    }

    #[test]
    fn regions_partition_bias_space() {
        let d = dev();
        assert_eq!(d.region(-0.1, 0.5), Region::Cutoff);
        assert_eq!(d.region(0.3, 0.5), Region::Saturation);
        assert_eq!(d.region(0.3, 0.2), Region::Triode);
    }

    #[test]
    fn current_continuous_at_sat_triode_boundary() {
        let d = dev();
        let vov = 0.35;
        let below = d.drain_current_vov(vov, vov - 1e-9);
        let above = d.drain_current_vov(vov, vov + 1e-9);
        assert!((below - above).abs() < 1e-9 * d.beta());
    }

    #[test]
    fn subthreshold_continuous_at_vov_zero() {
        // the moderate-inversion floor makes the branches meet at Vov = 0
        let d = dev();
        let on = d.drain_current_vov(1e-9, 0.8);
        let off = d.drain_current_vov(-1e-9, 0.8);
        assert!((on - off).abs() / off < 1e-6, "on={on} off={off}");
    }

    #[test]
    fn body_bias_increases_current() {
        let d = dev();
        let base = d.drain_current(0.55, 0.9, 0.0);
        let smart = d.drain_current(0.55, 0.9, 0.6);
        assert!(smart > base * 1.5, "base={base}, smart={smart}");
    }

    #[test]
    fn current_monotone_in_vgs() {
        let d = dev();
        let mut last = -1.0;
        for i in 0..50 {
            let vgs = i as f64 * 0.02;
            let i_d = d.drain_current(vgs, 0.9, 0.0);
            assert!(i_d >= last);
            last = i_d;
        }
    }

    #[test]
    fn zero_vds_zero_current() {
        let d = dev();
        assert_eq!(d.drain_current(0.7, 0.0, 0.0), 0.0);
        assert!(d.drain_current(0.1, 0.0, 0.0).abs() < 1e-18);
    }

    #[test]
    fn mismatch_shifts_current() {
        let card = DeviceCard::default();
        let slow = Mosfet::with_mismatch(card, 0.02, -0.05);
        let fast = Mosfet::with_mismatch(card, -0.02, 0.05);
        let nom = Mosfet::nominal(card);
        let (vgs, vds) = (0.6, 0.9);
        assert!(slow.drain_current(vgs, vds, 0.0) < nom.drain_current(vgs, vds, 0.0));
        assert!(fast.drain_current(vgs, vds, 0.0) > nom.drain_current(vgs, vds, 0.0));
    }

    #[test]
    fn width_scaling_is_linear_in_current() {
        let mut d = dev();
        let i1 = d.drain_current(0.6, 0.9, 0.0);
        d.w_scale = 2.0;
        let i2 = d.drain_current(0.6, 0.9, 0.0);
        assert!((i2 / i1 - 2.0).abs() < 1e-12);
    }
}
