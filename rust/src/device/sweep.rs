//! Device-level characterization sweeps: the data behind Fig. 3 and Fig. 4.

use super::Mosfet;
use crate::params::DeviceCard;

/// One point of an I_D(V_WL) transfer sweep (Fig. 3).
#[derive(Debug, Clone, Copy)]
pub struct IvPoint {
    /// Word-line (gate) voltage (V).
    pub v_wl: f64,
    /// Forward body bias (V).
    pub v_bulk: f64,
    /// Drain current (A).
    pub i_d: f64,
}

/// Fig. 3: access-transistor transfer characteristic for several bulk
/// voltages. Drain held at the precharged bitline (VDD), source grounded.
pub fn iv_sweep(card: DeviceCard, v_bulks: &[f64], n_points: usize) -> Vec<IvPoint> {
    let dev = Mosfet::nominal(card);
    let mut out = Vec::with_capacity(v_bulks.len() * n_points);
    for &vb in v_bulks {
        for k in 0..n_points {
            let v_wl = card.vdd * k as f64 / (n_points - 1) as f64;
            out.push(IvPoint { v_wl, v_bulk: vb, i_d: dev.drain_current(v_wl, card.vdd, vb) });
        }
    }
    out
}

/// Turn-on voltage extracted from an I-V sweep: the word-line voltage of
/// the first point whose drain current crosses `i_ref` (Fig. 3's
/// observable — the body-biased curve crosses ~125 mV earlier).
///
/// Errors instead of panicking when the sweep never reaches `i_ref`
/// (wrong bias range, too small a reference, or an empty sweep), naming
/// the ceiling actually reached so the caller can fix the sweep.
pub fn turn_on_v_wl(points: &[IvPoint], i_ref: f64) -> anyhow::Result<f64> {
    points.iter().find(|p| p.i_d > i_ref).map(|p| p.v_wl).ok_or_else(|| {
        // lint:allow(D2): max() fold is order-insensitive — no rounding accumulation
        let i_max = points.iter().fold(f64::NEG_INFINITY, |m, p| m.max(p.i_d));
        anyhow::anyhow!(
            "I-V sweep never crosses i_ref = {i_ref:.3e} A \
             (max current {i_max:.3e} A over {} points)",
            points.len()
        )
    })
}

/// One point of the width sweep (Fig. 4).
#[derive(Debug, Clone, Copy)]
pub struct WidthPoint {
    /// Width scale relative to the card's W/L.
    pub w_scale: f64,
    /// Forward body bias (V).
    pub v_bulk: f64,
    /// Drain current (A).
    pub i_d: f64,
}

/// Fig. 4: drain current vs transistor width, solid (V_bulk = 0) against
/// dashed (V_bulk = 0.6 V) — body bias wins at every width.
pub fn width_sweep(
    card: DeviceCard,
    v_wl: f64,
    v_bulks: &[f64],
    w_scales: &[f64],
) -> Vec<WidthPoint> {
    let mut out = Vec::with_capacity(v_bulks.len() * w_scales.len());
    for &vb in v_bulks {
        for &w in w_scales {
            let mut dev = Mosfet::nominal(card);
            dev.w_scale = w;
            let i_d = dev.drain_current(v_wl, card.vdd, vb);
            out.push(WidthPoint { w_scale: w, v_bulk: vb, i_d });
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn iv_sweep_shapes_and_monotonicity() {
        let pts = iv_sweep(DeviceCard::default(), &[0.0, 0.6], 21);
        assert_eq!(pts.len(), 42);
        for w in pts[..21].windows(2) {
            assert!(w[1].i_d >= w[0].i_d);
        }
    }

    #[test]
    fn body_bias_shifts_turn_on_left_by_125mv() {
        // Fig. 3's observable: the biased curve reaches a reference current
        // at a WL voltage ~125 mV lower than the unbiased one.
        let card = DeviceCard::default();
        let n = 2001;
        let pts = iv_sweep(card, &[0.0, 0.6], n);
        let (base, smart) = pts.split_at(n);
        let i_ref = 10e-6;
        let base_on = turn_on_v_wl(base, i_ref)
            .expect("unbiased sweep must cross the 10 uA reference on the default card");
        let smart_on = turn_on_v_wl(smart, i_ref)
            .expect("body-biased sweep must cross the 10 uA reference on the default card");
        let shift = base_on - smart_on;
        assert!(
            (0.110..0.140).contains(&shift),
            "turn-on shift {shift} V, expected ~125 mV"
        );
    }

    #[test]
    fn turn_on_errors_when_sweep_never_crosses() {
        let card = DeviceCard::default();
        let pts = iv_sweep(card, &[0.0], 51);
        // an absurd reference current is above every sweep point
        let err = turn_on_v_wl(&pts, 1.0).unwrap_err();
        let msg = format!("{err}");
        assert!(msg.contains("never crosses"), "{msg}");
        assert!(msg.contains("51 points"), "{msg}");
        // the empty sweep errors too, rather than panicking
        assert!(turn_on_v_wl(&[], 1e-6).is_err());
    }

    #[test]
    fn width_sweep_biased_wins_at_every_width() {
        let card = DeviceCard::default();
        let ws: Vec<f64> = (1..=10).map(|k| k as f64 * 0.5).collect();
        let pts = width_sweep(card, 0.55, &[0.0, 0.6], &ws);
        let (base, smart) = pts.split_at(ws.len());
        for (b, s) in base.iter().zip(smart) {
            assert!(s.i_d > b.i_d, "w={}: {} !> {}", b.w_scale, s.i_d, b.i_d);
        }
    }

    #[test]
    fn width_sweep_linear_in_width() {
        let card = DeviceCard::default();
        let pts = width_sweep(card, 0.6, &[0.0], &[1.0, 2.0, 4.0]);
        assert!((pts[1].i_d / pts[0].i_d - 2.0).abs() < 1e-9);
        assert!((pts[2].i_d / pts[0].i_d - 4.0).abs() < 1e-9);
    }
}
