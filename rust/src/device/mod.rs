//! MOSFET device models — the lowest substrate layer.
//!
//! Implements the paper's Eq. 2/6 physics: square-law NMOS with channel
//! length modulation, region-aware triode/saturation/subthreshold current,
//! and the body effect used by SMART to suppress V_TH. This is the model
//! the native simulator integrates and the oracle the HLO path is checked
//! against (both sides share `params.json`).

mod model;
mod sweep;

pub use model::{Mosfet, Region};
pub use sweep::{iv_sweep, turn_on_v_wl, width_sweep, IvPoint, WidthPoint};
