//! # smart-insram
//!
//! Full-system reproduction of **SMART: Investigating the Impact of
//! Threshold Voltage Suppression in an In-SRAM Multiplication/Accumulation
//! Accelerator for Accuracy Improvement in 65 nm CMOS Technology**
//! (Seyedfaraji, Mesgari, Rehman — DSD 2022,
//! DOI 10.1109/DSD57027.2022.00115).
//!
//! The paper's Cadence/Spectre testbed is replaced by a from-scratch
//! analog transient simulator (see DESIGN.md §2 for the substitution
//! table). The stack has three layers:
//!
//! * **L1** — a Pallas kernel integrating the bitline discharge ODE
//!   (`python/compile/kernels/discharge.py`), AOT-lowered to HLO text;
//! * **L2** — the JAX MAC-array model around it
//!   (`python/compile/model.py`);
//! * **L3** — this crate: the Monte-Carlo campaign coordinator that loads
//!   the artifacts via PJRT ([`runtime`]), generates mismatch batches
//!   ([`montecarlo`]), schedules them across workers ([`coordinator`]),
//!   and aggregates the paper's metrics ([`metrics`], [`energy`],
//!   [`report`]). Python never runs at campaign time.
//!
//! The native simulator ([`device`], [`circuit`], [`sram`], [`dac`],
//! [`mac`]) is a complete Rust twin of the AOT path, used as its
//! cross-check oracle and for shapes the fixed-batch artifacts don't
//! cover. On top of the campaign layer, [`dse`] sweeps the design knobs
//! (supply, body bias, bit-width, corner, variant) across a grid and
//! extracts the energy-vs-accuracy Pareto front (DESIGN.md §8), and
//! [`nn`] runs quantized neural-network inference with every
//! multiply-accumulate executed by the simulated noisy MAC — the
//! application-level accuracy story behind the paper's pitch
//! (DESIGN.md §10). [`serve`] fronts all three workloads (`mc`, sweep
//! points, inference) with a long-lived HTTP service whose spec-keyed
//! result cache exploits the byte-identity contract for O(1) repeat
//! lookups (DESIGN.md §11).

#![warn(missing_docs)]

/// Micro-benchmark harness for the `harness = false` benches.
pub mod bench;
/// Bitline discharge transients: the ODE integration behind every MAC.
pub mod circuit;
/// TOML-lite experiment configuration (`smart run`).
pub mod config;
/// L3 Monte-Carlo campaign coordinator (sharded, bit-reproducible).
pub mod coordinator;
/// Word-line DACs (Eq. 7 linear / Eq. 8 sqrt).
pub mod dac;
/// 65 nm device model + characterization sweeps (Fig. 3/4).
pub mod device;
/// Design-space exploration: grid sweeps + Pareto fronts (`smart sweep`).
pub mod dse;
/// Energy-per-MAC and cycle-time models behind Table 1.
pub mod energy;
/// `smart lint`: determinism/robustness static analysis (DESIGN.md §12).
pub mod lint;
/// The analog in-SRAM MAC engine and the design-variant table.
pub mod mac;
/// Statistics + accuracy metrics (Welford, histograms, BER, SNR).
pub mod metrics;
/// Seeded mismatch/corner sampling behind the 1000-point MC (§IV).
pub mod montecarlo;
/// Noisy NN inference on the simulated MAC (`smart infer`).
pub mod nn;
/// Tracing, metrics, and profiling — the wall-clock quarantine (§15).
pub mod obs;
/// The 65 nm model card (device + circuit constants).
pub mod params;
/// Report emission: the paper's tables/figures as markdown and CSV.
pub mod report;
/// PJRT/XLA artifact loading and execution (stubbed offline).
pub mod runtime;
/// `smart serve`: the concurrent, cache-fronted campaign-result service.
pub mod serve;
/// 6T cells, 4-cell MAC words, and the precharge model.
pub mod sram;
/// Self-contained utilities: CLI args, JSON, TOML-lite, property RNG.
pub mod util;

pub use mac::{MacResult, NativeMacEngine, Variant};
pub use params::Params;
