//! # smart-insram
//!
//! Full-system reproduction of **SMART: Investigating the Impact of
//! Threshold Voltage Suppression in an In-SRAM Multiplication/Accumulation
//! Accelerator for Accuracy Improvement in 65 nm CMOS Technology**
//! (Seyedfaraji, Mesgari, Rehman — DSD 2022,
//! DOI 10.1109/DSD57027.2022.00115).
//!
//! The paper's Cadence/Spectre testbed is replaced by a from-scratch
//! analog transient simulator (see DESIGN.md §2 for the substitution
//! table). The stack has three layers:
//!
//! * **L1** — a Pallas kernel integrating the bitline discharge ODE
//!   (`python/compile/kernels/discharge.py`), AOT-lowered to HLO text;
//! * **L2** — the JAX MAC-array model around it
//!   (`python/compile/model.py`);
//! * **L3** — this crate: the Monte-Carlo campaign coordinator that loads
//!   the artifacts via PJRT ([`runtime`]), generates mismatch batches
//!   ([`montecarlo`]), schedules them across workers ([`coordinator`]),
//!   and aggregates the paper's metrics ([`metrics`], [`energy`],
//!   [`report`]). Python never runs at campaign time.
//!
//! The native simulator ([`device`], [`circuit`], [`sram`], [`dac`],
//! [`mac`]) is a complete Rust twin of the AOT path, used as its
//! cross-check oracle and for shapes the fixed-batch artifacts don't
//! cover.

pub mod bench;
pub mod circuit;
pub mod config;
pub mod coordinator;
pub mod dac;
pub mod device;
pub mod energy;
pub mod mac;
pub mod metrics;
pub mod montecarlo;
pub mod params;
pub mod report;
pub mod runtime;
pub mod sram;
pub mod util;

pub use mac::{MacResult, NativeMacEngine, Variant};
pub use params::Params;
