//! `smart serve` — a long-lived campaign-result service (DESIGN.md §11).
//!
//! The first subsystem on the ROADMAP's "serve heavy traffic" axis:
//! instead of re-running a full Monte-Carlo campaign per CLI invocation,
//! a dependency-free (`std::net`) multi-threaded HTTP/1.1 JSON service
//! keeps a **spec-keyed result cache** in front of the existing
//! block-execution campaign stack. Because campaigns are deterministic
//! and their artifacts byte-identical (DESIGN.md §4/§9/§10), a cache hit
//! returns exactly the bytes a fresh run would produce — repeat requests
//! are O(1) lookups.
//!
//! Endpoints:
//!
//! | method/path          | body                                | response |
//! |----------------------|-------------------------------------|----------|
//! | `POST /v1/mc`        | a `[[campaigns]]` table as JSON     | canonical `mc.json` bytes |
//! | `POST /v1/sweep/point` | one DSE grid point (`dse.toml` terms) | canonical single-point `sweep.json` bytes |
//! | `POST /v1/infer`     | an `nn.toml` model document as JSON | canonical `infer.json` bytes |
//! | `GET /v1/health`     | —                                   | liveness probe |
//! | `GET /v1/stats`      | —                                   | request/cache/timing counters |
//!
//! Architecture: an acceptor thread feeds accepted connections into a
//! bounded channel drained by a fixed pool of request workers (one
//! campaign runs per worker thread — request-level parallelism comes
//! from the pool, not from nested campaign fan-out). Shutdown is
//! graceful: [`Server::stop`] stops accepting, drains the queue, and
//! joins every thread. Responses carry `X-Smart-Cache` (hit/miss) and
//! `X-Smart-Time-Us` provenance headers; the body bytes themselves never
//! depend on cache state or timing.

mod cache;
mod http;
mod router;

pub use cache::ResultCache;
pub use http::{http_request, read_request, write_response, Request, Response, MAX_BODY};
pub use router::{handle, Routed, MAX_REQUEST_ITEMS};

use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::{sync_channel, Receiver};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{Context, Result};

use crate::mac::KernelKind;
use crate::params::Params;
use crate::util::json::{to_string_pretty, Value};

/// Service configuration (the `smart serve` flags).
#[derive(Debug, Clone)]
pub struct ServeOptions {
    /// Bind address; port 0 binds an ephemeral port (tests, self-test).
    pub addr: String,
    /// Request worker threads (each runs at most one campaign at a time).
    pub workers: usize,
    /// Result-cache capacity in entries.
    pub cache_cap: usize,
}

impl Default for ServeOptions {
    fn default() -> Self {
        Self { addr: "127.0.0.1:7878".to_string(), workers: 4, cache_cap: 256 }
    }
}

/// Service-lifetime counters behind `GET /v1/stats`.
struct Counters {
    started: Instant,
    requests: AtomicU64,
    errors: AtomicU64,
    busy_us: AtomicU64,
}

impl Counters {
    fn new() -> Self {
        Self {
            started: Instant::now(),
            requests: AtomicU64::new(0),
            errors: AtomicU64::new(0),
            busy_us: AtomicU64::new(0),
        }
    }
}

/// A running `smart serve` instance: acceptor thread + bounded worker
/// pool + sharded result cache. Stop it with [`Self::stop`] (also runs
/// on drop), or block on [`Self::join`] to serve until killed.
pub struct Server {
    addr: SocketAddr,
    cache: Arc<ResultCache>,
    counters: Arc<Counters>,
    stopping: Arc<AtomicBool>,
    acceptor: Option<JoinHandle<()>>,
    workers: Vec<JoinHandle<()>>,
    n_workers: usize,
}

impl Server {
    /// Bind `opts.addr` and spawn the acceptor + `opts.workers` request
    /// workers. Returns once the socket is live — [`Self::addr`] carries
    /// the resolved address (useful with port 0).
    pub fn start(params: Params, opts: &ServeOptions) -> Result<Self> {
        anyhow::ensure!(
            opts.workers > 0,
            "smart serve needs at least 1 worker thread (got --workers 0)"
        );
        anyhow::ensure!(
            opts.cache_cap > 0,
            "smart serve needs a result-cache capacity >= 1 (got --cache-cap 0)"
        );
        let listener = TcpListener::bind(&opts.addr)
            .with_context(|| format!("binding {}", opts.addr))?;
        let addr = listener.local_addr().context("resolving bound address")?;
        let cache = Arc::new(ResultCache::new(opts.cache_cap, opts.workers.min(8)));
        let counters = Arc::new(Counters::new());
        let stopping = Arc::new(AtomicBool::new(false));

        // Bounded hand-off: when every worker is busy and the queue is
        // full, the acceptor blocks — the OS listen backlog, not this
        // process, absorbs the burst (backpressure, bounded memory).
        let (conn_tx, conn_rx) = sync_channel::<TcpStream>(opts.workers * 4);
        let conn_rx = Arc::new(Mutex::new(conn_rx));

        let mut workers = Vec::with_capacity(opts.workers);
        for wid in 0..opts.workers {
            let conn_rx = Arc::clone(&conn_rx);
            let cache = Arc::clone(&cache);
            let counters = Arc::clone(&counters);
            let n_workers = opts.workers;
            workers.push(
                std::thread::Builder::new()
                    .name(format!("smart-serve-{wid}"))
                    .spawn(move || worker_loop(&params, &cache, &counters, &conn_rx, n_workers))
                    .context("spawning serve worker")?,
            );
        }

        let acceptor = {
            let stopping = Arc::clone(&stopping);
            std::thread::Builder::new()
                .name("smart-serve-accept".to_string())
                .spawn(move || {
                    // conn_tx lives (only) here: when this loop exits, the
                    // channel closes and the workers drain + exit.
                    for conn in listener.incoming() {
                        if stopping.load(Ordering::SeqCst) {
                            break;
                        }
                        let Ok(stream) = conn else { continue };
                        if conn_tx.send(stream).is_err() {
                            break;
                        }
                    }
                })
                .context("spawning serve acceptor")?
        };

        Ok(Self {
            addr,
            cache,
            counters,
            stopping,
            acceptor: Some(acceptor),
            workers,
            n_workers: opts.workers,
        })
    }

    /// The resolved bind address (the ephemeral port when bound to `:0`).
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// The current `GET /v1/stats` body (also reachable over HTTP).
    pub fn stats_json(&self) -> String {
        stats_body(&self.cache, &self.counters, self.n_workers)
    }

    /// Block until the acceptor exits (i.e. serve until the process is
    /// killed or another thread calls [`Self::stop`]).
    pub fn join(&mut self) {
        if let Some(h) = self.acceptor.take() {
            let _ = h.join();
        }
        for h in self.workers.drain(..) {
            let _ = h.join();
        }
    }

    /// Graceful shutdown: stop accepting, let in-flight requests finish,
    /// join every thread. Idempotent; also runs on drop.
    pub fn stop(&mut self) {
        if self.acceptor.is_none() {
            return;
        }
        self.stopping.store(true, Ordering::SeqCst);
        // Unblock the acceptor's blocking accept with a loopback touch;
        // it observes `stopping` and exits, closing the connection queue.
        let _ = TcpStream::connect(self.addr);
        self.join();
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        self.stop();
    }
}

/// Per-connection socket timeout: a client that stalls mid-request (or
/// never reads its response) costs a worker at most this long, so a
/// handful of slow-loris connections cannot wedge the bounded pool.
const IO_TIMEOUT: Duration = Duration::from_secs(10);

/// One request worker: dequeue connections until the channel closes.
fn worker_loop(
    params: &Params,
    cache: &ResultCache,
    counters: &Counters,
    conn_rx: &Mutex<Receiver<TcpStream>>,
    n_workers: usize,
) {
    loop {
        // hold the lock only while dequeuing (same pattern as the PJRT
        // WorkerPool): handling runs fully in parallel
        // catch_unwind below means handlers cannot poison this lock, but
        // recover anyway rather than wedge the accept loop
        let conn =
            { conn_rx.lock().unwrap_or_else(std::sync::PoisonError::into_inner).recv() };
        let Ok(mut stream) = conn else { break };
        // A panic anywhere in request handling must cost one request,
        // not one worker: without this, `--workers` poisoned requests
        // would silently wedge the whole pool.
        let handled = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            serve_connection(params, cache, counters, &mut stream, n_workers)
        }));
        if handled.is_err() {
            counters.errors.fetch_add(1, Ordering::Relaxed);
            let _ = write_response(
                &mut stream,
                &Response::error(500, "internal error: request handler panicked"),
            );
        }
    }
}

/// Serve one connection: read a request, route it, frame the response
/// with cache/timing provenance headers, close.
fn serve_connection(
    params: &Params,
    cache: &ResultCache,
    counters: &Counters,
    stream: &mut TcpStream,
    n_workers: usize,
) {
    let t0 = Instant::now();
    counters.requests.fetch_add(1, Ordering::Relaxed);
    let _ = stream.set_read_timeout(Some(IO_TIMEOUT));
    let _ = stream.set_write_timeout(Some(IO_TIMEOUT));
    let mut routed = match read_request(stream) {
        // stats needs server-level state, so it is answered here rather
        // than in the (stateless) router
        Ok(req) if req.method == "GET" && req.path == "/v1/stats" => Routed {
            response: Response::ok(stats_body(cache, counters, n_workers)),
            cache: None,
        },
        Ok(req) => handle(params, cache, &req),
        Err(e) => Routed {
            response: Response::error(400, &format!("{e:#}")),
            cache: None,
        },
    };
    if routed.response.status >= 400 {
        counters.errors.fetch_add(1, Ordering::Relaxed);
    }
    let elapsed_us = t0.elapsed().as_micros() as u64;
    counters.busy_us.fetch_add(elapsed_us, Ordering::Relaxed);
    if let Some(hit) = routed.cache {
        routed
            .response
            .headers
            .push(("X-Smart-Cache".to_string(), if hit { "hit" } else { "miss" }.to_string()));
    }
    routed
        .response
        .headers
        .push(("X-Smart-Time-Us".to_string(), elapsed_us.to_string()));
    let _ = write_response(stream, &routed.response);
}

/// Render the `GET /v1/stats` body: request/error/busy counters plus the
/// cache's hit/miss/eviction/occupancy numbers. Diagnostic only — unlike
/// the compute endpoints, these bytes are not canonical artifacts.
fn stats_body(cache: &ResultCache, c: &Counters, workers: usize) -> String {
    let mut root = std::collections::BTreeMap::new();
    let mut put = |k: &str, v: Value| {
        root.insert(k.to_string(), v);
    };
    put("service", Value::Str("smart-serve".to_string()));
    put("workers", Value::Num(workers as f64));
    put("uptime_us", Value::Num(c.started.elapsed().as_micros() as f64));
    put("requests", Value::Num(c.requests.load(Ordering::Relaxed) as f64));
    put("errors", Value::Num(c.errors.load(Ordering::Relaxed) as f64));
    put("busy_us", Value::Num(c.busy_us.load(Ordering::Relaxed) as f64));
    let mut cm = std::collections::BTreeMap::new();
    cm.insert("entries".to_string(), Value::Num(cache.len() as f64));
    cm.insert("hits".to_string(), Value::Num(cache.hits() as f64));
    cm.insert("misses".to_string(), Value::Num(cache.misses() as f64));
    cm.insert("evictions".to_string(), Value::Num(cache.evictions() as f64));
    put("cache", Value::Obj(cm));
    let mut text = to_string_pretty(&Value::Obj(root));
    text.push('\n');
    text
}

/// Outcome of the `smart serve --self-test` loopback load generation.
#[derive(Debug, Clone)]
pub struct SelfTestReport {
    /// Compute requests issued (priming + concurrent phases).
    pub requests: u64,
    /// Requests answered from the cache.
    pub hits: u64,
    /// Requests that ran a campaign.
    pub misses: u64,
    /// Concurrent client threads of the load phase.
    pub clients: usize,
    /// Requests per endpoint per client in the load phase.
    pub repeats: usize,
    /// The server's `GET /v1/stats` body at the end of the run.
    pub stats_json: String,
}

/// Loopback self-test: start a server on an ephemeral port, hammer it
/// with concurrent clients, and assert the service contract —
///
/// 1. every compute response is **byte-identical** to the corresponding
///    CLI `--json` artifact encoder output ([`crate::report::mc_json`],
///    [`crate::dse::sweep_json`], [`crate::nn::infer_json`]);
/// 2. after one priming request per endpoint, every repeat (from any
///    client, concurrently) is served from the cache;
/// 3. a NaN-bearing sample stream no longer perturbs histogram bin 0
///    (the PR-5 `metrics::Histogram` regression).
///
/// `smoke` shrinks the campaign sizes and client counts for CI.
/// `kernel` selects the simulation tier every request (and every
/// expected artifact) is pinned to — `--kernel fast` exercises the
/// surrogate tier end to end, including its cache-key fork (DESIGN.md
/// §13). Returns the counters; any contract violation is an error.
pub fn self_test(
    params: &Params,
    workers: usize,
    smoke: bool,
    kernel: KernelKind,
) -> Result<SelfTestReport> {
    use crate::coordinator::{run_campaign, Backend, CampaignSpec};
    use crate::dse::{run_grid_point, sweep_json, GridAxes, SweepOptions, SweepSpec};
    use crate::mac::Variant;
    use crate::montecarlo::Corner;
    use crate::nn::{infer_json, run_infer, InferOptions, ModelSpec};

    // (3) the histogram-integrity fix backing the acceptance criterion:
    // non-finite samples must never reach bin 0.
    let mut h = crate::metrics::Histogram::new(0.0, 1.0, 8);
    h.push(f64::NAN);
    h.push(f64::INFINITY);
    h.push(0.4);
    anyhow::ensure!(
        h.counts()[0] == 0 && h.non_finite() == 2 && h.total() == 1,
        "NaN-bearing stream perturbed histogram bin 0"
    );

    let opts = ServeOptions {
        addr: "127.0.0.1:0".to_string(),
        workers,
        cache_cap: 64,
    };
    let mut server = Server::start(*params, &opts)?;
    let addr = server.addr().to_string();

    let (status, _, body) = http_request(&addr, "GET", "/v1/health", "")?;
    anyhow::ensure!(status == 200 && body.contains("smart-serve"), "health probe failed");

    // (1) expected bytes straight through the CLI artifact encoders.
    let n_mc: u32 = if smoke { 8 } else { 64 };
    let tok = kernel.token();
    let mc_body = format!(
        "{{\"variant\": \"smart\", \"n_mc\": {n_mc}, \"kernel\": \"{tok}\", \
         \"workload\": {{\"kind\": \"fixed\", \"a\": 15, \"b\": 15}}}}"
    );
    let mut mc_spec = CampaignSpec::paper_fig8(Variant::Smart);
    mc_spec.n_mc = n_mc;
    mc_spec.kernel = kernel;
    let mc_expect = crate::report::mc_json(
        &mc_spec,
        &run_campaign(params, &mc_spec, Backend::Native, None)?,
    );
    anyhow::ensure!(
        mc_expect.contains("\"non_finite\": 0"),
        "mc.json must expose the histogram's non-finite counter"
    );

    let sweep_n_mc: u32 = if smoke { 8 } else { 32 };
    let sweep_body = format!(
        "{{\"variant\": \"aid\", \"n_mc\": {sweep_n_mc}, \"bits\": 2, \"seed\": 5, \
         \"kernel\": \"{tok}\"}}"
    );
    let sweep_spec = SweepSpec {
        name: "serve".to_string(),
        seed: 5,
        n_mc: sweep_n_mc,
        grid: GridAxes {
            variants: vec![Variant::Aid],
            vdd: vec![params.device.vdd],
            v_bulk: vec![params.circuit.v_bulk_smart],
            bits: vec![2],
            corners: vec![Corner::Tt],
        },
        params: *params,
    };
    let sweep_point = sweep_spec.grid.expand().remove(0);
    let sweep_expect = {
        let opts = SweepOptions { kernel, ..SweepOptions::default() };
        let r = run_grid_point(&sweep_spec, &sweep_point, &opts)?;
        sweep_json(&sweep_spec, &[r], &[true], kernel)
    };

    let trials = if smoke { 3 } else { 8 };
    let infer_body = format!(
        "{{\"name\": \"serve-selftest\", \"seed\": 11, \"trials\": {trials}, \"bits\": 4, \
         \"kernel\": \"{tok}\", \
         \"dataset\": {{\"classes\": 3, \"features\": 6, \"jitter\": 0.1}}, \
         \"layers\": [{{\"inputs\": 6, \"outputs\": 4, \"relu\": true}}, \
                      {{\"inputs\": 4, \"outputs\": 3}}]}}"
    );
    let infer_spec = ModelSpec::from_value(
        &crate::util::json::parse(&infer_body).map_err(|e| anyhow::anyhow!(e))?,
    )?;
    let infer_expect = {
        let opts = InferOptions { kernel, ..InferOptions::default() };
        let r = run_infer(params, &infer_spec, &opts)?;
        infer_json(&infer_spec, &r)
    };

    let endpoints: Vec<(&str, String, String)> = vec![
        ("/v1/mc", mc_body, mc_expect),
        ("/v1/sweep/point", sweep_body, sweep_expect),
        ("/v1/infer", infer_body, infer_expect),
    ];

    // Prime each endpoint once: a miss that computes and caches.
    for (path, body, expect) in &endpoints {
        let (status, headers, got) = http_request(&addr, "POST", path, body)?;
        anyhow::ensure!(status == 200, "{path}: priming request failed ({status}): {got}");
        anyhow::ensure!(
            got == *expect,
            "{path}: response diverged from the CLI --json artifact bytes"
        );
        anyhow::ensure!(
            headers.iter().any(|(k, v)| k == "X-Smart-Cache" && v == "miss"),
            "{path}: priming request should be a cache miss"
        );
    }

    // (2) concurrent load: every repeat must be a byte-identical hit.
    let clients = if smoke { 3 } else { 8 };
    let repeats = if smoke { 3 } else { 8 };
    let failures: Vec<String> = std::thread::scope(|scope| {
        let handles: Vec<_> = (0..clients)
            .map(|_| {
                let addr = addr.clone();
                let endpoints = &endpoints;
                scope.spawn(move || -> Result<(), String> {
                    for _ in 0..repeats {
                        for (path, body, expect) in endpoints {
                            let (status, headers, got) =
                                http_request(&addr, "POST", path, body)
                                    .map_err(|e| format!("{path}: {e:#}"))?;
                            if status != 200 {
                                return Err(format!("{path}: status {status}: {got}"));
                            }
                            if got != *expect {
                                return Err(format!("{path}: cached bytes diverged"));
                            }
                            if !headers
                                .iter()
                                .any(|(k, v)| k == "X-Smart-Cache" && v == "hit")
                            {
                                return Err(format!("{path}: repeat was not a cache hit"));
                            }
                        }
                    }
                    Ok(())
                })
            })
            .collect();
        handles
            .into_iter()
            .filter_map(|h| match h.join() {
                Ok(outcome) => outcome.err(),
                Err(_) => Some("self-test client panicked".to_string()),
            })
            .collect()
    });
    anyhow::ensure!(failures.is_empty(), "self-test clients failed: {}", failures.join("; "));

    let (status, _, stats_json) = http_request(&addr, "GET", "/v1/stats", "")?;
    anyhow::ensure!(status == 200, "stats probe failed");
    crate::util::json::parse(&stats_json)
        .map_err(|e| anyhow::anyhow!("stats body is not valid JSON: {e}"))?;

    let want_hits = (clients * repeats * endpoints.len()) as u64;
    let (hits, misses) = (server.cache_hits(), server.cache_misses());
    anyhow::ensure!(
        hits == want_hits && misses == endpoints.len() as u64,
        "cache hit-rate off: {hits} hits / {misses} misses, expected {want_hits} / {}",
        endpoints.len()
    );
    server.stop();
    Ok(SelfTestReport {
        requests: want_hits + endpoints.len() as u64,
        hits,
        misses,
        clients,
        repeats,
        stats_json,
    })
}

impl Server {
    /// Cache lookups answered without running a campaign.
    pub fn cache_hits(&self) -> u64 {
        self.cache.hits()
    }

    /// Cache lookups that dispatched to the campaign stack.
    pub fn cache_misses(&self) -> u64 {
        self.cache.misses()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn start_stop_is_clean_and_idempotent() {
        let mut s = Server::start(
            Params::default(),
            &ServeOptions { addr: "127.0.0.1:0".to_string(), workers: 2, cache_cap: 8 },
        )
        .unwrap();
        assert_ne!(s.addr().port(), 0);
        let (status, _, body) =
            http_request(&s.addr().to_string(), "GET", "/v1/health", "").unwrap();
        assert_eq!(status, 200);
        assert!(body.contains("\"ok\""));
        s.stop();
        s.stop(); // idempotent
    }

    #[test]
    fn zero_workers_or_cache_cap_is_a_descriptive_error() {
        let err_of = |workers: usize, cache_cap: usize| match Server::start(
            Params::default(),
            &ServeOptions { addr: "127.0.0.1:0".to_string(), workers, cache_cap },
        ) {
            Err(e) => e.to_string(),
            Ok(_) => panic!("zero-knob server must not start"),
        };
        assert!(err_of(0, 8).contains("--workers 0"));
        assert!(err_of(1, 0).contains("--cache-cap 0"));
    }

    #[test]
    fn stats_endpoint_counts_requests() {
        let mut s = Server::start(
            Params::default(),
            &ServeOptions { addr: "127.0.0.1:0".to_string(), workers: 2, cache_cap: 8 },
        )
        .unwrap();
        let addr = s.addr().to_string();
        let _ = http_request(&addr, "GET", "/v1/health", "").unwrap();
        let (status, _, body) = http_request(&addr, "GET", "/v1/stats", "").unwrap();
        assert_eq!(status, 200);
        let v = crate::util::json::parse(&body).unwrap();
        assert!(v.get("requests").unwrap().as_u64().unwrap() >= 1);
        assert_eq!(v.get("workers").unwrap().as_u64().unwrap(), 2);
        assert!(v.get("cache").unwrap().get("entries").is_some());
        s.stop();
    }

    #[test]
    fn self_test_smoke_passes() {
        let r = self_test(&Params::default(), 2, true, KernelKind::Block).unwrap();
        assert_eq!(r.misses, 3);
        assert_eq!(r.hits, (r.clients * r.repeats * 3) as u64);
        assert!(r.stats_json.contains("smart-serve"));
    }

    #[test]
    fn self_test_smoke_passes_on_the_fast_tier() {
        let r = self_test(&Params::default(), 2, true, KernelKind::Fast).unwrap();
        assert_eq!(r.misses, 3);
        assert_eq!(r.hits, (r.clients * r.repeats * 3) as u64);
    }
}
