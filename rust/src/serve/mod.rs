//! `smart serve` — a long-lived campaign-result service (DESIGN.md
//! §11/§14).
//!
//! The ROADMAP's "serve heavy traffic" axis: instead of re-running a
//! full Monte-Carlo campaign per CLI invocation, a dependency-free
//! (`std::net`) multi-threaded HTTP/1.1 JSON service fronts the
//! block-execution campaign stack with a three-layer serving pipeline:
//!
//! 1. a **byte-budgeted sharded LRU** ([`ResultCache`], `--cache-cap`
//!    bytes) of canonical response bodies;
//! 2. a **disk tier** ([`DiskTier`], `--cache-dir`) that persists
//!    bodies keyed by the spec-identity hash, survives restarts, and is
//!    trivially validatable because served bytes are byte-reproducible;
//! 3. a **single-flight dedup map** ([`SingleFlight`]): concurrent
//!    misses on one canonical key cost one campaign — followers park
//!    their connection and the leader's `Arc<body>` fans out to all of
//!    them — plus a **cross-request coalescer** ([`Coalescer`]) that
//!    merges small compatible `/v1/infer` and `/v1/sweep/point`
//!    computations into shared engine executions.
//!
//! Because campaigns are deterministic and their artifacts
//! byte-identical (DESIGN.md §4/§9/§10), every layer returns exactly
//! the bytes a fresh solo run would produce.
//!
//! Endpoints:
//!
//! | method/path          | body                                | response |
//! |----------------------|-------------------------------------|----------|
//! | `POST /v1/mc`        | a `[[campaigns]]` table as JSON     | canonical `mc.json` bytes |
//! | `POST /v1/sweep/point` | one DSE grid point (`dse.toml` terms) | canonical single-point `sweep.json` bytes |
//! | `POST /v1/infer`     | an `nn.toml` model document as JSON | canonical `infer.json` bytes |
//! | `GET /v1/health`     | —                                   | liveness probe |
//! | `GET /v1/stats`      | —                                   | request/cache/flight/disk/batch counters |
//! | `GET /v1/metrics`    | —                                   | Prometheus text exposition of the same (DESIGN.md §15) |
//!
//! Architecture: an acceptor thread feeds accepted connections into a
//! bounded channel drained by a fixed pool of request workers (one
//! campaign runs per worker thread — request-level parallelism comes
//! from the pool, not from nested campaign fan-out). A worker whose
//! request joins an in-flight computation parks the connection and
//! returns to the pool immediately, so a thundering herd occupies one
//! worker. Shutdown is graceful: [`Server::stop`] stops accepting,
//! drains the queue, and joins every thread. Responses carry
//! `X-Smart-Cache` (`hit`/`disk`/`dedup`/`miss`) and `X-Smart-Time-Us`
//! provenance headers; the body bytes themselves never depend on cache
//! state or timing.

mod batch;
mod cache;
mod disk;
mod flight;
mod http;
mod router;
mod stats;

pub use batch::{infer_compat, sweep_compat, Coalescer, Job};
pub use cache::ResultCache;
pub use disk::DiskTier;
pub use flight::{Gate, Join, Lease, SingleFlight};
pub use http::{
    http_request, read_request, write_response, ParkedConn, Request, Response, MAX_BODY,
};
pub use router::{
    handle, handle_conn, mc_cache_key, CacheTier, Fetched, Pipeline, Routed, MAX_REQUEST_ITEMS,
};
pub use stats::{Monotonic, ServeStats};

use std::net::{SocketAddr, TcpListener, TcpStream};
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::{sync_channel, Receiver};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::Duration;

use anyhow::{Context, Result};

use crate::mac::KernelKind;
use crate::obs::{Stopwatch, Tracer};
use crate::params::Params;
use crate::util::json::{to_string_pretty, Value};

/// Service configuration (the `smart serve` flags).
#[derive(Debug, Clone)]
pub struct ServeOptions {
    /// Bind address; port 0 binds an ephemeral port (tests, self-test).
    pub addr: String,
    /// Request worker threads (each runs at most one campaign at a time).
    pub workers: usize,
    /// Result-cache budget in **bytes** (entries are charged their body
    /// length; eviction is by bytes, LRU order).
    pub cache_cap: usize,
    /// Disk cache directory (`--cache-dir`); `None` = memory-only.
    pub cache_dir: Option<PathBuf>,
    /// Maximum compatible jobs per merged batch execution
    /// (`--batch-max`).
    pub batch_max: usize,
    /// Request tracer (`--trace FILE` / `SMART_TRACE=`): one `request`
    /// span per connection. Inert by default; served bodies are
    /// byte-identical either way (tracing never feeds a response).
    pub tracer: Tracer,
}

impl Default for ServeOptions {
    fn default() -> Self {
        Self {
            addr: "127.0.0.1:7878".to_string(),
            workers: 4,
            cache_cap: 64 << 20,
            cache_dir: None,
            batch_max: 16,
            tracer: Tracer::disabled(),
        }
    }
}

/// A running `smart serve` instance: acceptor thread + bounded worker
/// pool over the serving [`Pipeline`]. Stop it with [`Self::stop`]
/// (also runs on drop), or block on [`Self::join`] to serve until
/// killed.
pub struct Server {
    addr: SocketAddr,
    pipe: Arc<Pipeline>,
    stopping: Arc<AtomicBool>,
    acceptor: Option<JoinHandle<()>>,
    workers: Vec<JoinHandle<()>>,
    n_workers: usize,
}

impl Server {
    /// Bind `opts.addr` and spawn the acceptor + `opts.workers` request
    /// workers. Returns once the socket is live — [`Self::addr`] carries
    /// the resolved address (useful with port 0).
    pub fn start(params: Params, opts: &ServeOptions) -> Result<Self> {
        anyhow::ensure!(
            opts.workers > 0,
            "smart serve needs at least 1 worker thread (got --workers 0)"
        );
        anyhow::ensure!(
            opts.cache_cap > 0,
            "smart serve needs a result-cache budget >= 1 byte (got --cache-cap 0)"
        );
        anyhow::ensure!(
            opts.batch_max > 0,
            "smart serve needs a batch window >= 1 (got --batch-max 0)"
        );
        let listener = TcpListener::bind(&opts.addr)
            .with_context(|| format!("binding {}", opts.addr))?;
        let addr = listener.local_addr().context("resolving bound address")?;
        let mut pipe = Pipeline::new(
            params,
            opts.cache_cap,
            opts.workers.min(8),
            opts.cache_dir.as_deref(),
            opts.batch_max,
        )
        .with_context(|| match &opts.cache_dir {
            Some(d) => format!("opening --cache-dir {}", d.display()),
            None => "building the serving pipeline".to_string(),
        })?;
        pipe.set_tracer(opts.tracer.clone());
        let pipe = Arc::new(pipe);
        let stopping = Arc::new(AtomicBool::new(false));

        // Bounded hand-off: when every worker is busy and the queue is
        // full, the acceptor blocks — the OS listen backlog, not this
        // process, absorbs the burst (backpressure, bounded memory).
        let (conn_tx, conn_rx) = sync_channel::<TcpStream>(opts.workers * 4);
        let conn_rx = Arc::new(Mutex::new(conn_rx));

        let mut workers = Vec::with_capacity(opts.workers);
        for wid in 0..opts.workers {
            let conn_rx = Arc::clone(&conn_rx);
            let pipe = Arc::clone(&pipe);
            let n_workers = opts.workers;
            workers.push(
                std::thread::Builder::new()
                    .name(format!("smart-serve-{wid}"))
                    .spawn(move || worker_loop(&pipe, &conn_rx, n_workers))
                    .context("spawning serve worker")?,
            );
        }

        let acceptor = {
            let stopping = Arc::clone(&stopping);
            std::thread::Builder::new()
                .name("smart-serve-accept".to_string())
                .spawn(move || {
                    // conn_tx lives (only) here: when this loop exits, the
                    // channel closes and the workers drain + exit.
                    for conn in listener.incoming() {
                        if stopping.load(Ordering::SeqCst) {
                            break;
                        }
                        let Ok(stream) = conn else { continue };
                        if conn_tx.send(stream).is_err() {
                            break;
                        }
                    }
                })
                .context("spawning serve acceptor")?
        };

        Ok(Self {
            addr,
            pipe,
            stopping,
            acceptor: Some(acceptor),
            workers,
            n_workers: opts.workers,
        })
    }

    /// The resolved bind address (the ephemeral port when bound to `:0`).
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// The serving pipeline (caches, flight map, coalescer, gate,
    /// counters). Shared — cheap to clone out of the server.
    pub fn pipeline(&self) -> Arc<Pipeline> {
        Arc::clone(&self.pipe)
    }

    /// The current `GET /v1/stats` body (also reachable over HTTP).
    pub fn stats_json(&self) -> String {
        stats_body(&self.pipe, self.n_workers)
    }

    /// Cache lookups answered without leaving the in-memory tier.
    pub fn cache_hits(&self) -> u64 {
        self.pipe.cache().hits()
    }

    /// Cache lookups that fell through the in-memory tier.
    pub fn cache_misses(&self) -> u64 {
        self.pipe.cache().misses()
    }

    /// Block until the acceptor exits (i.e. serve until the process is
    /// killed or another thread calls [`Self::stop`]).
    pub fn join(&mut self) {
        if let Some(h) = self.acceptor.take() {
            let _ = h.join();
        }
        for h in self.workers.drain(..) {
            let _ = h.join();
        }
    }

    /// Graceful shutdown: stop accepting, let in-flight requests finish,
    /// join every thread. Idempotent; also runs on drop.
    pub fn stop(&mut self) {
        if self.acceptor.is_none() {
            return;
        }
        self.stopping.store(true, Ordering::SeqCst);
        // Unblock the acceptor's blocking accept with a loopback touch;
        // it observes `stopping` and exits, closing the connection queue.
        let _ = TcpStream::connect(self.addr);
        self.join();
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        self.stop();
    }
}

/// Per-connection socket timeout: a client that stalls mid-request (or
/// never reads its response) costs a worker at most this long, so a
/// handful of slow-loris connections cannot wedge the bounded pool.
const IO_TIMEOUT: Duration = Duration::from_secs(10);

/// One request worker: dequeue connections until the channel closes.
fn worker_loop(pipe: &Pipeline, conn_rx: &Mutex<Receiver<TcpStream>>, n_workers: usize) {
    loop {
        // hold the lock only while dequeuing (same pattern as the PJRT
        // WorkerPool): handling runs fully in parallel
        // catch_unwind below means handlers cannot poison this lock, but
        // recover anyway rather than wedge the accept loop
        let conn =
            { conn_rx.lock().unwrap_or_else(std::sync::PoisonError::into_inner).recv() };
        let Ok(mut stream) = conn else { break };
        // A panic anywhere in request handling must cost one request,
        // not one worker: without this, `--workers` poisoned requests
        // would silently wedge the whole pool. (A panicking flight
        // leader additionally publishes a 500 to its parked followers
        // via the Lease drop guard.)
        let handled = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            serve_connection(pipe, &mut stream, n_workers)
        }));
        if handled.is_err() {
            pipe.stats().errors.incr();
            let _ = write_response(
                &mut stream,
                &Response::error(500, "internal error: request handler panicked"),
            );
        }
    }
}

/// Serve one connection: read a request, walk the pipeline, frame the
/// response with cache/timing provenance headers, close. If the request
/// joins an in-flight computation its connection is parked — the flight
/// leader's fan-out answers it and this worker returns immediately.
fn serve_connection(pipe: &Pipeline, stream: &mut TcpStream, n_workers: usize) {
    let t0 = Stopwatch::start();
    pipe.stats().requests.incr();
    let _ = stream.set_read_timeout(Some(IO_TIMEOUT));
    let _ = stream.set_write_timeout(Some(IO_TIMEOUT));
    let req = match read_request(stream) {
        Ok(req) => req,
        Err(e) => {
            pipe.stats().errors.incr();
            let mut resp = Response::error(400, &format!("{e:#}"));
            let mut span = pipe.tracer().span_started("request", None, t0);
            span.attr_u64("status", 400);
            respond(pipe, stream, &mut resp, t0);
            pipe.tracer().finish(span);
            return;
        }
    };
    // One span per connection, back-dated to arrival. The span observes
    // the request; nothing in the response path reads it back.
    let mut span = pipe.tracer().span_started("request", None, t0);
    span.attr_str("method", &req.method);
    span.attr_str("path", &req.path);
    // stats needs server-level state, so it is answered here rather
    // than in the router
    if req.method == "GET" && req.path == "/v1/stats" {
        let mut resp = Response::ok(stats_body(pipe, n_workers));
        span.attr_u64("status", 200);
        respond(pipe, stream, &mut resp, t0);
        pipe.tracer().finish(span);
        return;
    }
    // Duplicate the socket handle so the pipeline can park it on an
    // in-flight slot while this handle stays with the worker (dropping
    // one keeps the connection open for the other).
    let fetched = match stream.try_clone() {
        Ok(dup) => handle_conn(pipe, &req, ParkedConn { stream: dup, t0 }),
        // fd duplication failed: degrade to the blocking in-process path
        Err(_) => Fetched::Done(handle(pipe, &req), None),
    };
    match fetched {
        Fetched::Parked => {
            // The connection now belongs to the flight leader's fan-out;
            // only the routing time was spent on this worker.
            pipe.stats().busy_us.add(t0.elapsed_us());
            span.attr_str("cache", "parked");
            pipe.tracer().finish(span);
        }
        Fetched::Done(mut routed, _conn) => {
            if routed.response.status >= 400 {
                // a failed leader also answered its parked followers
                pipe.stats().errors.add(1 + routed.fanout as u64);
            }
            if let Some(tier) = routed.cache {
                routed
                    .response
                    .headers
                    .push(("X-Smart-Cache".to_string(), tier.token().to_string()));
                span.attr_str("cache", tier.token());
            }
            span.attr_u64("status", u64::from(routed.response.status));
            respond(pipe, stream, &mut routed.response, t0);
            pipe.tracer().finish(span);
        }
    }
}

/// Frame and write one response: account busy time, record the request
/// latency in the registry, stamp the timing header. (Parked followers
/// are answered by the flight fan-out instead and do not pass through
/// here — their latency is visible on the `X-Smart-Time-Us` header but
/// not in the server-side histogram.)
fn respond(pipe: &Pipeline, stream: &mut TcpStream, resp: &mut Response, t0: Stopwatch) {
    let elapsed_us = t0.elapsed_us();
    pipe.stats().busy_us.add(elapsed_us);
    pipe.registry().histogram("serve_request_us").record(elapsed_us);
    pipe.registry().counter("serve_responses_total").incr();
    resp.headers.push(("X-Smart-Time-Us".to_string(), elapsed_us.to_string()));
    let _ = write_response(stream, resp);
}

/// Render the `GET /v1/stats` body: request/error/busy/campaign
/// counters plus per-layer cache, disk, flight, and batch numbers.
/// Diagnostic only — unlike the compute endpoints, these bytes are not
/// canonical artifacts.
fn stats_body(pipe: &Pipeline, workers: usize) -> String {
    let s = pipe.stats();
    let num = |n: u64| Value::Num(n as f64);
    let mut root = std::collections::BTreeMap::new();
    let mut put = |k: &str, v: Value| {
        root.insert(k.to_string(), v);
    };
    put("service", Value::Str("smart-serve".to_string()));
    put("workers", num(workers as u64));
    put("uptime_us", num(s.uptime_us()));
    put("uptime_s", num(s.uptime_s()));
    put("requests", num(s.requests.get()));
    put("errors", num(s.errors.get()));
    put("busy_us", num(s.busy_us.get()));
    put("campaigns", num(s.campaigns.get()));
    let cache = pipe.cache();
    let mut cm = std::collections::BTreeMap::new();
    cm.insert("entries".to_string(), num(cache.len() as u64));
    cm.insert("bytes".to_string(), num(cache.bytes() as u64));
    cm.insert("hits".to_string(), num(cache.hits()));
    cm.insert("misses".to_string(), num(cache.misses()));
    cm.insert("evictions".to_string(), num(cache.evictions()));
    put("cache", Value::Obj(cm));
    let mut dm = std::collections::BTreeMap::new();
    let (enabled, h, m, w, bw, r, warm) = match pipe.disk() {
        Some(d) => (
            true,
            d.hits(),
            d.misses(),
            d.writes(),
            d.bytes_written(),
            d.rejects(),
            d.warm_entries(),
        ),
        None => (false, 0, 0, 0, 0, 0, 0),
    };
    dm.insert("enabled".to_string(), Value::Bool(enabled));
    dm.insert("hits".to_string(), num(h));
    dm.insert("misses".to_string(), num(m));
    dm.insert("writes".to_string(), num(w));
    dm.insert("bytes_written".to_string(), num(bw));
    dm.insert("rejects".to_string(), num(r));
    dm.insert("warm_entries".to_string(), num(warm));
    put("disk", Value::Obj(dm));
    let flight = pipe.flight();
    let mut fm = std::collections::BTreeMap::new();
    fm.insert("leads".to_string(), num(flight.leads()));
    fm.insert("deduped".to_string(), num(flight.deduped()));
    fm.insert("waiting".to_string(), num(flight.waiting()));
    put("flight", Value::Obj(fm));
    let batch = pipe.batch();
    let mut bm = std::collections::BTreeMap::new();
    bm.insert("batched".to_string(), num(batch.batched()));
    bm.insert("groups".to_string(), num(batch.groups()));
    bm.insert("queued".to_string(), num(batch.queued()));
    put("batch", Value::Obj(bm));
    let mut text = to_string_pretty(&Value::Obj(root));
    text.push('\n');
    text
}

/// Nearest-rank percentile over a sorted latency sample
/// (integer microseconds — no float accumulation anywhere).
fn percentile(sorted_us: &[u64], p: u64) -> u64 {
    if sorted_us.is_empty() {
        return 0;
    }
    let last = sorted_us.len() as u64 - 1;
    let idx = (last * p + 50) / 100;
    sorted_us[idx.min(last) as usize]
}

/// Outcome of the `smart serve --self-test` loopback load generation.
#[derive(Debug, Clone)]
pub struct SelfTestReport {
    /// Compute requests issued across all phases.
    pub requests: u64,
    /// Requests answered from the in-memory cache (hit phase).
    pub hits: u64,
    /// Priming requests that ran a campaign.
    pub misses: u64,
    /// Concurrent client threads of the hit phase.
    pub clients: usize,
    /// Requests per endpoint per client in the hit phase.
    pub repeats: usize,
    /// Concurrent clients of the thundering-herd phase.
    pub herd_clients: usize,
    /// Compatible concurrent inferences of the batching phase.
    pub batch_jobs: usize,
    /// Followers that shared an in-flight computation (must be
    /// `herd_clients - 1` for the herd).
    pub deduped: u64,
    /// Spec computations actually executed across all phases.
    pub campaigns: u64,
    /// Jobs that rode in merged batch groups.
    pub batched: u64,
    /// Merged batch executions covering two or more jobs.
    pub batch_groups: u64,
    /// Disk-tier entries found by the warm-start server.
    pub warm_entries: u64,
    /// Hit-phase throughput (requests per second, client-side wall
    /// clock).
    pub throughput_rps: f64,
    /// Hit-phase p50 latency (client-side, microseconds).
    pub p50_us: u64,
    /// Hit-phase p95 latency (client-side, microseconds).
    pub p95_us: u64,
    /// Hit-phase p99 latency (client-side, microseconds).
    pub p99_us: u64,
    /// The first server's `GET /v1/stats` body at the end of its run.
    pub stats_json: String,
    /// The `BENCH_serve.json` document (throughput, latency
    /// percentiles, hit/dedup/batch counters).
    pub bench_json: String,
}

/// Loopback self-test: start a server on an ephemeral port, hammer it
/// with concurrent clients, and assert the full serving contract —
///
/// 1. every compute response is **byte-identical** to the corresponding
///    CLI `--json` artifact encoder output ([`crate::report::mc_json`],
///    [`crate::dse::sweep_json`], [`crate::nn::infer_json`]);
/// 2. after one priming request per endpoint, every repeat (from any
///    client, concurrently) is served from the in-memory cache;
/// 3. **thundering herd**: with the compute gate paused, a herd of
///    clients requesting one uncached spec converges onto one flight
///    slot — exactly one campaign executes, every other client shares
///    its bytes (`X-Smart-Cache: dedup`);
/// 4. **cross-request batching**: compatible concurrent `/v1/infer`
///    requests coalesce into one merged engine execution, each body
///    byte-identical to its solo run;
/// 5. **kill/restart warm start**: a second server over the same
///    `--cache-dir` serves every prior body byte-identically from the
///    disk tier with zero recomputed campaigns;
/// 6. a NaN-bearing sample stream no longer perturbs histogram bin 0
///    (the PR-5 `metrics::Histogram` regression).
///
/// `smoke` shrinks campaign sizes, client counts, and the herd for CI.
/// `kernel` selects the simulation tier every request (and every
/// expected artifact) is pinned to — `--kernel fast` exercises the
/// surrogate tier end to end, including its cache-key fork (DESIGN.md
/// §13). The worker pool is widened to the batch-phase group size if
/// needed (batch followers block a worker each while they wait).
/// `tracer` instruments the first server's requests (`--trace`); the
/// asserted bodies are byte-identical with tracing on or off.
/// Returns the counters plus the `BENCH_serve.json` document; any
/// contract violation is an error.
pub fn self_test(
    params: &Params,
    workers: usize,
    smoke: bool,
    kernel: KernelKind,
    tracer: &Tracer,
) -> Result<SelfTestReport> {
    use crate::coordinator::{run_campaign, Backend, CampaignSpec, Workload};
    use crate::dse::{run_grid_point, sweep_json, GridAxes, SweepOptions, SweepSpec};
    use crate::mac::Variant;
    use crate::montecarlo::Corner;
    use crate::nn::{infer_json, run_infer, InferOptions, ModelSpec};

    // (6) the histogram-integrity fix backing the acceptance criterion:
    // non-finite samples must never reach bin 0.
    let mut h = crate::metrics::Histogram::new(0.0, 1.0, 8);
    h.push(f64::NAN);
    h.push(f64::INFINITY);
    h.push(0.4);
    anyhow::ensure!(
        h.counts()[0] == 0 && h.non_finite() == 2 && h.total() == 1,
        "NaN-bearing stream perturbed histogram bin 0"
    );

    let herd_clients: usize = if smoke { 64 } else { 1000 };
    let batch_jobs: usize = if smoke { 4 } else { 8 };
    // batch followers hold a worker each while they wait on the merged
    // execution, so the pool must fit the whole group
    let workers = workers.max(batch_jobs);

    // Self-cleaning disk tier for the warm-start phase.
    struct Cleanup(PathBuf);
    impl Drop for Cleanup {
        fn drop(&mut self) {
            let _ = std::fs::remove_dir_all(&self.0);
        }
    }
    let cache_dir =
        std::env::temp_dir().join(format!("smart-serve-selftest-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&cache_dir);
    let _cleanup = Cleanup(cache_dir.clone());

    let opts = ServeOptions {
        addr: "127.0.0.1:0".to_string(),
        workers,
        cache_cap: 16 << 20,
        cache_dir: Some(cache_dir.clone()),
        batch_max: batch_jobs.max(16),
        tracer: tracer.clone(),
    };
    let mut server = Server::start(*params, &opts)?;
    let addr = server.addr().to_string();
    let pipe = server.pipeline();

    let (status, _, body) = http_request(&addr, "GET", "/v1/health", "")?;
    anyhow::ensure!(status == 200 && body.contains("smart-serve"), "health probe failed");
    let (status, headers, text) = http_request(&addr, "GET", "/v1/metrics", "")?;
    anyhow::ensure!(
        status == 200
            && text.contains("serve_batch_group_size")
            && headers
                .iter()
                .any(|(k, v)| k == "Content-Type" && v.starts_with("text/plain")),
        "metrics probe failed"
    );

    // (1) expected bytes straight through the CLI artifact encoders.
    let n_mc: u32 = if smoke { 8 } else { 64 };
    let tok = kernel.token();
    let mc_body = format!(
        "{{\"variant\": \"smart\", \"n_mc\": {n_mc}, \"kernel\": \"{tok}\", \
         \"workload\": {{\"kind\": \"fixed\", \"a\": 15, \"b\": 15}}}}"
    );
    let mut mc_spec = CampaignSpec::paper_fig8(Variant::Smart);
    mc_spec.n_mc = n_mc;
    mc_spec.kernel = kernel;
    let mc_expect = crate::report::mc_json(
        &mc_spec,
        &run_campaign(params, &mc_spec, Backend::Native, None)?,
    );
    anyhow::ensure!(
        mc_expect.contains("\"non_finite\": 0"),
        "mc.json must expose the histogram's non-finite counter"
    );

    let sweep_n_mc: u32 = if smoke { 8 } else { 32 };
    let sweep_body = format!(
        "{{\"variant\": \"aid\", \"n_mc\": {sweep_n_mc}, \"bits\": 2, \"seed\": 5, \
         \"kernel\": \"{tok}\"}}"
    );
    let sweep_spec = SweepSpec {
        name: "serve".to_string(),
        seed: 5,
        n_mc: sweep_n_mc,
        grid: GridAxes {
            variants: vec![Variant::Aid],
            vdd: vec![params.device.vdd],
            v_bulk: vec![params.circuit.v_bulk_smart],
            bits: vec![2],
            corners: vec![Corner::Tt],
        },
        params: *params,
    };
    let sweep_point = sweep_spec.grid.expand().remove(0);
    let sweep_expect = {
        let opts = SweepOptions { kernel, ..SweepOptions::default() };
        let r = run_grid_point(&sweep_spec, &sweep_point, &opts)?;
        sweep_json(&sweep_spec, &[r], &[true], kernel)
    };

    let trials = if smoke { 3 } else { 8 };
    let infer_body = format!(
        "{{\"name\": \"serve-selftest\", \"seed\": 11, \"trials\": {trials}, \"bits\": 4, \
         \"kernel\": \"{tok}\", \
         \"dataset\": {{\"classes\": 3, \"features\": 6, \"jitter\": 0.1}}, \
         \"layers\": [{{\"inputs\": 6, \"outputs\": 4, \"relu\": true}}, \
                      {{\"inputs\": 4, \"outputs\": 3}}]}}"
    );
    let infer_spec = ModelSpec::from_value(
        &crate::util::json::parse(&infer_body).map_err(|e| anyhow::anyhow!(e))?,
    )?;
    let infer_expect = {
        let opts = InferOptions { kernel, ..InferOptions::default() };
        let r = run_infer(params, &infer_spec, &opts)?;
        infer_json(&infer_spec, &r)
    };

    let endpoints: Vec<(&str, String, String)> = vec![
        ("/v1/mc", mc_body, mc_expect),
        ("/v1/sweep/point", sweep_body, sweep_expect),
        ("/v1/infer", infer_body, infer_expect),
    ];

    // Prime each endpoint once: a miss that computes and caches.
    for (path, body, expect) in &endpoints {
        let (status, headers, got) = http_request(&addr, "POST", path, body)?;
        anyhow::ensure!(status == 200, "{path}: priming request failed ({status}): {got}");
        anyhow::ensure!(
            got == *expect,
            "{path}: response diverged from the CLI --json artifact bytes"
        );
        anyhow::ensure!(
            headers.iter().any(|(k, v)| k == "X-Smart-Cache" && v == "miss"),
            "{path}: priming request should be a cache miss"
        );
    }

    // (2) concurrent load: every repeat must be a byte-identical hit.
    // Client-side latency is the serving benchmark (recorded per
    // request, integer microseconds).
    let clients = if smoke { 3 } else { 8 };
    let repeats = if smoke { 3 } else { 8 };
    let t_load = Stopwatch::start();
    let outcomes: Vec<Result<Vec<u64>, String>> = std::thread::scope(|scope| {
        let handles: Vec<_> = (0..clients)
            .map(|_| {
                let addr = addr.clone();
                let endpoints = &endpoints;
                scope.spawn(move || -> Result<Vec<u64>, String> {
                    let mut lat = Vec::with_capacity(repeats * endpoints.len());
                    for _ in 0..repeats {
                        for (path, body, expect) in endpoints {
                            let t = Stopwatch::start();
                            let (status, headers, got) =
                                http_request(&addr, "POST", path, body)
                                    .map_err(|e| format!("{path}: {e:#}"))?;
                            lat.push(t.elapsed_us());
                            if status != 200 {
                                return Err(format!("{path}: status {status}: {got}"));
                            }
                            if got != *expect {
                                return Err(format!("{path}: cached bytes diverged"));
                            }
                            if !headers
                                .iter()
                                .any(|(k, v)| k == "X-Smart-Cache" && v == "hit")
                            {
                                return Err(format!("{path}: repeat was not a cache hit"));
                            }
                        }
                    }
                    Ok(lat)
                })
            })
            .collect();
        handles
            .into_iter()
            .map(|h| match h.join() {
                Ok(outcome) => outcome,
                Err(_) => Err("self-test client panicked".to_string()),
            })
            .collect()
    });
    let load_us = t_load.elapsed_us();
    let mut latencies: Vec<u64> = Vec::new();
    let mut failures: Vec<String> = Vec::new();
    for o in outcomes {
        match o {
            Ok(lat) => latencies.extend(lat),
            Err(e) => failures.push(e),
        }
    }
    anyhow::ensure!(failures.is_empty(), "self-test clients failed: {}", failures.join("; "));

    let want_hits = (clients * repeats * endpoints.len()) as u64;
    let (hits, misses) = (server.cache_hits(), server.cache_misses());
    anyhow::ensure!(
        hits == want_hits && misses == endpoints.len() as u64,
        "cache hit-rate off: {hits} hits / {misses} misses, expected {want_hits} / {}",
        endpoints.len()
    );
    latencies.sort_unstable();
    let (p50, p95, p99) = (
        percentile(&latencies, 50),
        percentile(&latencies, 95),
        percentile(&latencies, 99),
    );
    let throughput_rps = if load_us == 0 {
        0.0
    } else {
        want_hits as f64 * 1.0e6 / load_us as f64
    };

    // (3) thundering herd: N clients, one uncached spec, exactly one
    // campaign. The paused gate holds the flight leader mid-compute
    // until every follower has parked on its slot.
    let herd_body = format!(
        "{{\"variant\": \"smart\", \"n_mc\": {n_mc}, \"kernel\": \"{tok}\", \
         \"workload\": {{\"kind\": \"fixed\", \"a\": 3, \"b\": 13}}}}"
    );
    let herd_expect = {
        let mut spec = CampaignSpec::paper_fig8(Variant::Smart);
        spec.n_mc = n_mc;
        spec.kernel = kernel;
        spec.workload = Workload::Fixed { a: 3, b: 13 };
        crate::report::mc_json(&spec, &run_campaign(params, &spec, Backend::Native, None)?)
    };
    let campaigns_before = pipe.stats().campaigns.get();
    let deduped_before = pipe.flight().deduped();
    pipe.gate().pause();
    let (herded, herd_results) = std::thread::scope(|scope| {
        let handles: Vec<_> = (0..herd_clients)
            .map(|_| {
                let addr = addr.clone();
                let body = &herd_body;
                scope.spawn(move || {
                    http_request(&addr, "POST", "/v1/mc", body).map_err(|e| format!("{e:#}"))
                })
            })
            .collect();
        let herd_watch = Stopwatch::start();
        let mut herded = false;
        while herd_watch.elapsed() < Duration::from_secs(120) {
            if pipe.flight().waiting() >= herd_clients as u64 - 1 {
                herded = true;
                break;
            }
            std::thread::sleep(Duration::from_millis(1));
        }
        // resume unconditionally so stalled clients can finish either way
        pipe.gate().resume();
        let results: Vec<Result<(u16, Vec<(String, String)>, String), String>> = handles
            .into_iter()
            .map(|h| match h.join() {
                Ok(r) => r,
                Err(_) => Err("herd client panicked".to_string()),
            })
            .collect();
        (herded, results)
    });
    anyhow::ensure!(herded, "thundering herd never fully converged onto one flight slot");
    let (mut lead_n, mut dedup_n) = (0u64, 0u64);
    for r in &herd_results {
        let (status, headers, got) = match r {
            Ok(t) => t,
            Err(e) => anyhow::bail!("herd client failed: {e}"),
        };
        anyhow::ensure!(*status == 200, "herd request failed ({status}): {got}");
        anyhow::ensure!(
            *got == herd_expect,
            "herd response diverged from the CLI --json artifact bytes"
        );
        for (k, v) in headers {
            if k == "X-Smart-Cache" {
                match v.as_str() {
                    "miss" => lead_n += 1,
                    "dedup" => dedup_n += 1,
                    other => anyhow::bail!("unexpected herd cache tier: {other}"),
                }
            }
        }
    }
    let herd_campaigns = pipe.stats().campaigns.get() - campaigns_before;
    let herd_deduped = pipe.flight().deduped() - deduped_before;
    anyhow::ensure!(
        herd_campaigns == 1 && lead_n == 1,
        "thundering herd must cost exactly one campaign (ran {herd_campaigns}, {lead_n} leaders)"
    );
    anyhow::ensure!(
        herd_deduped == herd_clients as u64 - 1 && dedup_n == herd_clients as u64 - 1,
        "herd dedup off: {herd_deduped} deduped / {dedup_n} dedup responses, expected {}",
        herd_clients - 1
    );

    // (4) cross-request batching: M compatible inferences (same variant
    // + kernel tier, distinct seeds) coalesce into one merged engine
    // execution, each body byte-identical to its solo run.
    let batch_bodies: Vec<String> = (0..batch_jobs)
        .map(|i| {
            format!(
                "{{\"name\": \"serve-batch\", \"seed\": {}, \"trials\": {trials}, \
                 \"bits\": 4, \"kernel\": \"{tok}\", \
                 \"dataset\": {{\"classes\": 3, \"features\": 6, \"jitter\": 0.1}}, \
                 \"layers\": [{{\"inputs\": 6, \"outputs\": 4, \"relu\": true}}, \
                              {{\"inputs\": 4, \"outputs\": 3}}]}}",
                101 + i
            )
        })
        .collect();
    let mut batch_expects = Vec::with_capacity(batch_jobs);
    for body in &batch_bodies {
        let spec = ModelSpec::from_value(
            &crate::util::json::parse(body).map_err(|e| anyhow::anyhow!(e))?,
        )?;
        let opts = InferOptions { threads: 1, kernel, ..InferOptions::default() };
        let r = run_infer(params, &spec, &opts)?;
        batch_expects.push(infer_json(&spec, &r));
    }
    let campaigns_before = pipe.stats().campaigns.get();
    let (batched_before, groups_before) = (pipe.batch().batched(), pipe.batch().groups());
    pipe.gate().pause();
    let (queued_up, batch_results) = std::thread::scope(|scope| {
        let handles: Vec<_> = batch_bodies
            .iter()
            .map(|body| {
                let addr = addr.clone();
                scope.spawn(move || {
                    http_request(&addr, "POST", "/v1/infer", body)
                        .map_err(|e| format!("{e:#}"))
                })
            })
            .collect();
        let batch_watch = Stopwatch::start();
        let mut queued_up = false;
        while batch_watch.elapsed() < Duration::from_secs(120) {
            if pipe.batch().queued() >= batch_jobs as u64 - 1 {
                queued_up = true;
                break;
            }
            std::thread::sleep(Duration::from_millis(1));
        }
        pipe.gate().resume();
        let results: Vec<Result<(u16, Vec<(String, String)>, String), String>> = handles
            .into_iter()
            .map(|h| match h.join() {
                Ok(r) => r,
                Err(_) => Err("batch client panicked".to_string()),
            })
            .collect();
        (queued_up, results)
    });
    anyhow::ensure!(queued_up, "batch followers never queued behind the group leader");
    for (i, r) in batch_results.iter().enumerate() {
        let (status, _, got) = match r {
            Ok(t) => t,
            Err(e) => anyhow::bail!("batch client {i} failed: {e}"),
        };
        anyhow::ensure!(*status == 200, "batch request {i} failed ({status}): {got}");
        anyhow::ensure!(
            *got == batch_expects[i],
            "batched inference {i} diverged from its solo artifact bytes"
        );
    }
    let batch_campaigns = pipe.stats().campaigns.get() - campaigns_before;
    let batch_batched = pipe.batch().batched() - batched_before;
    let batch_groups = pipe.batch().groups() - groups_before;
    anyhow::ensure!(
        batch_campaigns == batch_jobs as u64 && batch_batched == batch_jobs as u64
            && batch_groups == 1,
        "batch phase off: {batch_campaigns} campaigns / {batch_batched} batched / \
         {batch_groups} groups, expected {batch_jobs} / {batch_jobs} / 1"
    );

    // Final first-server counters (the bench record), then kill it.
    let stats_json = server.stats_json();
    let metrics_snapshot = {
        pipe.sync_metrics();
        pipe.registry().snapshot()
    };
    let total_deduped = pipe.flight().deduped();
    let total_leads = pipe.flight().leads();
    let total_campaigns = pipe.stats().campaigns.get();
    let total_batched = pipe.batch().batched();
    let total_groups = pipe.batch().groups();
    let (hits_total, misses_total) = (server.cache_hits(), server.cache_misses());
    let disk_writes = match pipe.disk() {
        Some(d) => d.writes(),
        None => 0,
    };
    server.stop();
    drop(server);

    // (5) kill/restart warm start: a fresh server over the same
    // --cache-dir serves every prior body byte-identically from the
    // disk tier, recomputing nothing.
    let opts2 = ServeOptions {
        addr: "127.0.0.1:0".to_string(),
        workers: 2,
        cache_dir: Some(cache_dir.clone()),
        ..ServeOptions::default()
    };
    let mut server2 = Server::start(*params, &opts2)?;
    let addr2 = server2.addr().to_string();
    let pipe2 = server2.pipeline();
    let warm_entries = match pipe2.disk() {
        Some(d) => d.warm_entries(),
        None => 0,
    };
    let want_warm = (endpoints.len() + 1 + batch_jobs) as u64;
    anyhow::ensure!(
        warm_entries >= want_warm,
        "warm start found {warm_entries} disk entries, expected at least {want_warm}"
    );
    let mut warm_checks: Vec<(&str, &String, &String)> =
        endpoints.iter().map(|(p, b, e)| (*p, b, e)).collect();
    warm_checks.push(("/v1/mc", &herd_body, &herd_expect));
    for (path, body, expect) in warm_checks {
        let (status, headers, got) = http_request(&addr2, "POST", path, body)?;
        anyhow::ensure!(status == 200, "{path}: warm-start request failed ({status}): {got}");
        anyhow::ensure!(
            got == *expect,
            "{path}: warm-start bytes diverged from the CLI --json artifact"
        );
        anyhow::ensure!(
            headers.iter().any(|(k, v)| k == "X-Smart-Cache" && v == "disk"),
            "{path}: warm-start request must be served from the disk tier"
        );
    }
    let recomputed = pipe2.stats().campaigns.get();
    anyhow::ensure!(
        recomputed == 0,
        "warm start recomputed {recomputed} campaigns; the disk tier must serve all of them"
    );
    server2.stop();

    let requests_total = (endpoints.len()            // priming
        + clients * repeats * endpoints.len()        // hit phase
        + herd_clients                               // thundering herd
        + batch_jobs                                 // batching
        + endpoints.len() + 1) as u64; // warm start
    let bench_json = {
        let num = |n: u64| Value::Num(n as f64);
        let mut lat = std::collections::BTreeMap::new();
        lat.insert("p50".to_string(), num(p50));
        lat.insert("p95".to_string(), num(p95));
        lat.insert("p99".to_string(), num(p99));
        let mut cm = std::collections::BTreeMap::new();
        cm.insert("hits".to_string(), num(hits_total));
        cm.insert("misses".to_string(), num(misses_total));
        let mut fm = std::collections::BTreeMap::new();
        fm.insert("deduped".to_string(), num(total_deduped));
        fm.insert("leads".to_string(), num(total_leads));
        let mut bm = std::collections::BTreeMap::new();
        bm.insert("batched".to_string(), num(total_batched));
        bm.insert("groups".to_string(), num(total_groups));
        let mut dm = std::collections::BTreeMap::new();
        dm.insert("writes".to_string(), num(disk_writes));
        dm.insert("warm_entries".to_string(), num(warm_entries));
        let mut root = std::collections::BTreeMap::new();
        root.insert("service".to_string(), Value::Str("smart-serve".to_string()));
        root.insert("kernel".to_string(), Value::Str(tok.to_string()));
        root.insert("smoke".to_string(), Value::Bool(smoke));
        root.insert("clients".to_string(), num(clients as u64));
        root.insert("repeats".to_string(), num(repeats as u64));
        root.insert("herd_clients".to_string(), num(herd_clients as u64));
        root.insert("batch_jobs".to_string(), num(batch_jobs as u64));
        root.insert("requests".to_string(), num(requests_total));
        root.insert("campaigns".to_string(), num(total_campaigns));
        root.insert("throughput_rps".to_string(), Value::Num(throughput_rps));
        root.insert("latency_us".to_string(), Value::Obj(lat));
        root.insert("cache".to_string(), Value::Obj(cm));
        root.insert("flight".to_string(), Value::Obj(fm));
        root.insert("batch".to_string(), Value::Obj(bm));
        root.insert("disk".to_string(), Value::Obj(dm));
        // full registry snapshot: the server-side latency histogram and
        // the mirrored structural gauges (additive to the fields above)
        root.insert("metrics".to_string(), metrics_snapshot);
        let mut text = to_string_pretty(&Value::Obj(root));
        text.push('\n');
        text
    };

    Ok(SelfTestReport {
        requests: requests_total,
        hits,
        misses,
        clients,
        repeats,
        herd_clients,
        batch_jobs,
        deduped: total_deduped,
        campaigns: total_campaigns,
        batched: total_batched,
        batch_groups: total_groups,
        warm_entries,
        throughput_rps,
        p50_us: p50,
        p95_us: p95,
        p99_us: p99,
        stats_json,
        bench_json,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn opts(workers: usize) -> ServeOptions {
        ServeOptions {
            addr: "127.0.0.1:0".to_string(),
            workers,
            cache_cap: 1 << 20,
            ..ServeOptions::default()
        }
    }

    #[test]
    fn start_stop_is_clean_and_idempotent() {
        let mut s = Server::start(Params::default(), &opts(2)).unwrap();
        assert_ne!(s.addr().port(), 0);
        let (status, _, body) =
            http_request(&s.addr().to_string(), "GET", "/v1/health", "").unwrap();
        assert_eq!(status, 200);
        assert!(body.contains("\"ok\""));
        s.stop();
        s.stop(); // idempotent
    }

    #[test]
    fn zero_knobs_are_descriptive_errors() {
        let err_of = |o: ServeOptions| match Server::start(Params::default(), &o) {
            Err(e) => e.to_string(),
            Ok(_) => panic!("zero-knob server must not start"),
        };
        assert!(err_of(ServeOptions { workers: 0, ..opts(1) }).contains("--workers 0"));
        assert!(err_of(ServeOptions { cache_cap: 0, ..opts(1) }).contains("--cache-cap 0"));
        assert!(err_of(ServeOptions { batch_max: 0, ..opts(1) }).contains("--batch-max 0"));
    }

    #[test]
    fn stats_endpoint_reports_every_pipeline_layer() {
        let mut s = Server::start(Params::default(), &opts(2)).unwrap();
        let addr = s.addr().to_string();
        let _ = http_request(&addr, "GET", "/v1/health", "").unwrap();
        let (status, _, body) = http_request(&addr, "GET", "/v1/stats", "").unwrap();
        assert_eq!(status, 200);
        let v = crate::util::json::parse(&body).unwrap();
        assert!(v.get("requests").unwrap().as_u64().unwrap() >= 1);
        assert_eq!(v.get("workers").unwrap().as_u64().unwrap(), 2);
        assert_eq!(v.get("campaigns").unwrap().as_u64().unwrap(), 0);
        assert!(v.get("uptime_s").is_some());
        assert!(v.get("cache").unwrap().get("bytes").is_some());
        let disk = v.get("disk").unwrap();
        assert!(!disk.get("enabled").unwrap().as_bool().unwrap());
        assert!(disk.get("bytes_written").is_some());
        assert!(v.get("flight").unwrap().get("deduped").is_some());
        assert!(v.get("batch").unwrap().get("queued").is_some());
        s.stop();
    }

    #[test]
    fn self_test_smoke_passes() {
        let r =
            self_test(&Params::default(), 2, true, KernelKind::Block, &Tracer::disabled())
                .unwrap();
        assert_eq!(r.misses, 3);
        assert_eq!(r.hits, (r.clients * r.repeats * 3) as u64);
        assert_eq!(r.deduped, r.herd_clients as u64 - 1, "herd must dedup all followers");
        // priming (3) + herd leader (1) + one batch group of batch_jobs
        assert_eq!(r.campaigns, 4 + r.batch_jobs as u64);
        assert_eq!(r.batched, r.batch_jobs as u64);
        assert_eq!(r.batch_groups, 1);
        assert!(r.warm_entries >= 4 + r.batch_jobs as u64);
        assert!(r.stats_json.contains("smart-serve"));
        assert!(r.bench_json.contains("throughput_rps"));
        let bench = crate::util::json::parse(&r.bench_json).unwrap();
        // the registry snapshot rides along: server-side latency
        // histogram plus mirrored structural gauges
        assert!(bench
            .path(&["metrics", "histograms", "serve_request_us", "count"])
            .and_then(|v| v.as_u64())
            .is_some_and(|n| n > 0));
        assert!(bench.path(&["metrics", "gauges", "serve_flight_deduped"]).is_some());
    }

    #[test]
    fn self_test_smoke_passes_on_the_fast_tier() {
        let r =
            self_test(&Params::default(), 2, true, KernelKind::Fast, &Tracer::disabled())
                .unwrap();
        assert_eq!(r.misses, 3);
        assert_eq!(r.hits, (r.clients * r.repeats * 3) as u64);
        assert_eq!(r.deduped, r.herd_clients as u64 - 1);
    }
}
