//! Minimal HTTP/1.1 framing over `std::net` (no external dependencies).
//!
//! One request per connection (`Connection: close` on every response):
//! the service's workloads are campaign-sized, so connection reuse would
//! buy nothing while keep-alive bookkeeping would complicate the bounded
//! worker pool. The server side parses just what the JSON API needs —
//! request line, `Content-Length`, body; the client side
//! ([`http_request`]) is the loopback counterpart used by
//! `smart serve --self-test` and the integration tests.

use std::io::{BufRead, BufReader, Read, Write};
use std::net::TcpStream;
use std::sync::Arc;

use anyhow::{Context, Result};

/// Largest request body accepted (bytes) — guards the service against
/// unbounded allocations from a misbehaving client.
pub const MAX_BODY: usize = 1 << 20;

/// Largest request head (request line + headers) accepted (bytes). The
/// whole connection read is capped at `MAX_HEAD + MAX_BODY` via
/// [`Read::take`], so even a client streaming newline-free garbage can
/// never grow server memory past the cap.
pub const MAX_HEAD: usize = 16 << 10;

/// One parsed HTTP request.
#[derive(Debug, Clone)]
pub struct Request {
    /// Request method (`GET`, `POST`, ...), as sent.
    pub method: String,
    /// Request path (`/v1/mc`, ...), query strings included verbatim.
    pub path: String,
    /// Decoded request body (empty when no `Content-Length`).
    pub body: String,
}

/// One response about to be framed. `headers` rows are emitted verbatim
/// as extra response headers (cache/timing provenance); the body is
/// always served as `application/json`.
#[derive(Debug, Clone)]
pub struct Response {
    /// HTTP status code.
    pub status: u16,
    /// Extra response headers (name, value).
    pub headers: Vec<(String, String)>,
    /// Response body (canonical JSON). Shared so a cache hit serves the
    /// stored bytes without copying them.
    pub body: Arc<String>,
}

/// A connection handed off to an in-flight computation (single-flight
/// dedup, DESIGN.md §14): the follower's worker returns to the pool and
/// the leader's completion fan-out writes the response. Carries the
/// request's arrival stopwatch so the fan-out can stamp an honest
/// `X-Smart-Time-Us` per connection (the watch is started by the
/// caller; this module never reads the clock itself).
#[derive(Debug)]
pub struct ParkedConn {
    /// The follower's socket, still awaiting its response.
    pub stream: TcpStream,
    /// Started at request arrival (drives the per-connection latency
    /// header).
    pub t0: crate::obs::Stopwatch,
}

impl Response {
    /// A 200 response around a canonical JSON body.
    pub fn ok(body: String) -> Self {
        Self::ok_shared(Arc::new(body))
    }

    /// A 200 response around an already-shared body (a cache hit): the
    /// Arc is cloned, the bytes are not.
    pub fn ok_shared(body: Arc<String>) -> Self {
        Self { status: 200, headers: Vec::new(), body }
    }

    /// An error response with a JSON `{"error": ...}` body (the message
    /// travels through the JSON string escaper, so arbitrary error text
    /// is safe).
    pub fn error(status: u16, msg: &str) -> Self {
        let mut m = std::collections::BTreeMap::new();
        m.insert("error".to_string(), crate::util::json::Value::Str(msg.to_string()));
        let mut body = crate::util::json::to_string_pretty(&crate::util::json::Value::Obj(m));
        body.push('\n');
        Self { status, headers: Vec::new(), body: Arc::new(body) }
    }
}

/// Reason phrase for the status codes the router emits.
fn status_text(code: u16) -> &'static str {
    match code {
        200 => "OK",
        400 => "Bad Request",
        404 => "Not Found",
        405 => "Method Not Allowed",
        500 => "Internal Server Error",
        _ => "Unknown",
    }
}

/// Read one HTTP/1.1 request from the stream (request line, headers,
/// `Content-Length` body). The whole read is capped at
/// [`MAX_HEAD`] + [`MAX_BODY`] bytes ([`Read::take`]): a client that
/// never sends a newline exhausts its budget, not the server's memory.
pub fn read_request(stream: &mut TcpStream) -> Result<Request> {
    let mut r = BufReader::new(Read::take(&mut *stream, (MAX_HEAD + MAX_BODY) as u64));
    let mut line = String::new();
    r.read_line(&mut line).context("reading request line")?;
    anyhow::ensure!(line.len() <= MAX_HEAD, "request line over {MAX_HEAD} bytes");
    let mut parts = line.split_whitespace();
    let method = parts.next().unwrap_or_default().to_string();
    let path = parts.next().unwrap_or_default().to_string();
    anyhow::ensure!(
        !method.is_empty() && path.starts_with('/'),
        "malformed request line {line:?}"
    );
    let mut content_len = 0usize;
    loop {
        let mut h = String::new();
        let n = r.read_line(&mut h).context("reading header")?;
        let h = h.trim_end();
        if n == 0 || h.is_empty() {
            break;
        }
        if let Some((k, v)) = h.split_once(':') {
            if k.eq_ignore_ascii_case("content-length") {
                content_len = v.trim().parse().context("bad Content-Length")?;
            }
        }
    }
    anyhow::ensure!(content_len <= MAX_BODY, "request body over {MAX_BODY} bytes");
    let mut body = vec![0u8; content_len];
    r.read_exact(&mut body).context("reading request body")?;
    Ok(Request {
        method,
        path,
        body: String::from_utf8(body).context("request body is not UTF-8")?,
    })
}

/// Frame and send one response; always closes the connection afterwards
/// (`Connection: close`). The default `application/json` content type
/// yields to an explicit `Content-Type` row in `resp.headers` (the
/// Prometheus exposition is `text/plain`).
pub fn write_response(stream: &mut TcpStream, resp: &Response) -> std::io::Result<()> {
    let custom_type = resp
        .headers
        .iter()
        .any(|(k, _)| k.eq_ignore_ascii_case("content-type"));
    let mut head = format!(
        "HTTP/1.1 {} {}\r\nContent-Length: {}\r\nConnection: close\r\n",
        resp.status,
        status_text(resp.status),
        resp.body.len()
    );
    if !custom_type {
        head.push_str("Content-Type: application/json\r\n");
    }
    for (k, v) in &resp.headers {
        head.push_str(k);
        head.push_str(": ");
        head.push_str(v);
        head.push_str("\r\n");
    }
    head.push_str("\r\n");
    stream.write_all(head.as_bytes())?;
    stream.write_all(resp.body.as_bytes())?;
    stream.flush()
}

/// Blocking one-shot HTTP client: connect to `addr`, issue
/// `method path` with `body`, and return `(status, headers, body)`.
/// The loopback counterpart of the server framing, used by
/// `smart serve --self-test` and `tests/serve.rs`.
pub fn http_request(
    addr: &str,
    method: &str,
    path: &str,
    body: &str,
) -> Result<(u16, Vec<(String, String)>, String)> {
    let mut stream =
        TcpStream::connect(addr).with_context(|| format!("connecting to {addr}"))?;
    write!(
        stream,
        "{method} {path} HTTP/1.1\r\nHost: {addr}\r\nContent-Type: application/json\r\n\
         Content-Length: {}\r\nConnection: close\r\n\r\n{body}",
        body.len()
    )
    .context("sending request")?;
    stream.flush().context("flushing request")?;
    let mut text = String::new();
    stream.read_to_string(&mut text).context("reading response")?;
    let (head, payload) = text
        .split_once("\r\n\r\n")
        .ok_or_else(|| anyhow::anyhow!("response without header terminator"))?;
    let mut lines = head.lines();
    let status_line = lines.next().unwrap_or_default();
    let status: u16 = status_line
        .split_whitespace()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .ok_or_else(|| anyhow::anyhow!("malformed status line {status_line:?}"))?;
    let headers = lines
        .filter_map(|l| l.split_once(':'))
        .map(|(k, v)| (k.trim().to_string(), v.trim().to_string()))
        .collect();
    Ok((status, headers, payload.to_string()))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn error_bodies_are_valid_json() {
        let r = Response::error(400, "broken \"spec\"\nline two");
        assert_eq!(r.status, 400);
        let v = crate::util::json::parse(&r.body).unwrap();
        assert_eq!(
            v.get("error").unwrap().as_str().unwrap(),
            "broken \"spec\"\nline two"
        );
    }

    #[test]
    fn status_phrases_cover_the_router_codes() {
        for code in [200, 400, 404, 405, 500] {
            assert_ne!(status_text(code), "Unknown");
        }
        assert_eq!(status_text(418), "Unknown");
    }

    #[test]
    fn request_response_roundtrip_over_loopback() {
        // one real socket round-trip: client framing -> server parse ->
        // server framing -> client parse
        let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        let server = std::thread::spawn(move || {
            let (mut s, _) = listener.accept().unwrap();
            let req = read_request(&mut s).unwrap();
            assert_eq!(req.method, "POST");
            assert_eq!(req.path, "/v1/echo");
            assert_eq!(req.body, "{\"a\": 1}");
            let mut resp = Response::ok("{\"pong\": true}".to_string());
            resp.headers.push(("X-Smart-Cache".to_string(), "miss".to_string()));
            write_response(&mut s, &resp).unwrap();
        });
        let (status, headers, body) =
            http_request(&addr, "POST", "/v1/echo", "{\"a\": 1}").unwrap();
        server.join().unwrap();
        assert_eq!(status, 200);
        assert_eq!(body, "{\"pong\": true}");
        assert!(headers
            .iter()
            .any(|(k, v)| k == "X-Smart-Cache" && v == "miss"));
    }
}
