//! Saturating service counters and uptime for `/v1/stats`.
//!
//! Every monotonic counter the service exposes goes through [`Monotonic`],
//! which saturates at `u64::MAX` instead of wrapping.  A fleet-scale
//! deployment can legitimately run for months; a wrapped counter would
//! read as a *reset* to a dashboard and trip rate alarms, while a
//! saturated one merely stops moving — the safer failure.  None of these
//! values ever enter result bytes (DESIGN.md §9): they are observability
//! only, which is also why the wall-clock reads below carry reasoned
//! `lint:allow(D6)` pragmas instead of being banned outright.

use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Instant;

/// A monotonic, saturating `u64` counter safe for concurrent use.
///
/// `add`/`incr` never wrap: once the counter reaches `u64::MAX` it stays
/// there.  Loads are `Relaxed` — stats are a snapshot, not a fence.
#[derive(Debug)]
pub struct Monotonic(AtomicU64);

impl Monotonic {
    /// A fresh counter at zero.
    pub const fn new() -> Self {
        Monotonic(AtomicU64::new(0))
    }

    /// Add `n`, saturating at `u64::MAX` instead of wrapping.
    pub fn add(&self, n: u64) {
        // fetch_update with a total function never fails, but the trait
        // signature still returns Result; ignore the witness value.
        let _ = self
            .0
            .fetch_update(Ordering::Relaxed, Ordering::Relaxed, |v| {
                Some(v.saturating_add(n))
            });
    }

    /// Add one, saturating.
    pub fn incr(&self) {
        self.add(1);
    }

    /// Current value (relaxed snapshot).
    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }
}

impl Default for Monotonic {
    fn default() -> Self {
        Monotonic::new()
    }
}

/// Request-level counters plus the service start instant.
///
/// Owned by the `Server` and shared with every worker; all fields are
/// interior-mutable so the struct itself can live behind a plain `Arc`.
#[derive(Debug)]
pub struct ServeStats {
    started: Instant,
    /// Total connections answered (any status).
    pub requests: Monotonic,
    /// Responses with status >= 400, plus handler panics.
    pub errors: Monotonic,
    /// Microseconds spent inside request handling (not idle accept time).
    pub busy_us: Monotonic,
    /// Spec computations actually executed (cache hits, disk hits and
    /// dedup followers do NOT count; a merged batch of M jobs counts M).
    pub campaigns: Monotonic,
}

impl ServeStats {
    /// Fresh counters anchored at the current instant.
    pub fn new() -> Self {
        ServeStats {
            // lint:allow(D6): start instant feeds /v1/stats uptime only, never artifact bytes
            started: Instant::now(),
            requests: Monotonic::new(),
            errors: Monotonic::new(),
            busy_us: Monotonic::new(),
            campaigns: Monotonic::new(),
        }
    }

    /// Whole seconds since the service started.
    pub fn uptime_s(&self) -> u64 {
        self.started.elapsed().as_secs()
    }

    /// Microseconds since the service started (feeds the legacy
    /// `uptime_us` stats field).
    pub fn uptime_us(&self) -> u64 {
        self.started.elapsed().as_micros() as u64
    }
}

impl Default for ServeStats {
    fn default() -> Self {
        ServeStats::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn monotonic_saturates_instead_of_wrapping() {
        let c = Monotonic::new();
        c.add(u64::MAX - 1);
        c.incr();
        assert_eq!(c.get(), u64::MAX);
        // One past the top must stick at the top, not wrap to zero.
        c.incr();
        assert_eq!(c.get(), u64::MAX);
        c.add(12345);
        assert_eq!(c.get(), u64::MAX);
    }

    #[test]
    fn monotonic_counts_from_zero() {
        let c = Monotonic::default();
        assert_eq!(c.get(), 0);
        c.incr();
        c.add(4);
        assert_eq!(c.get(), 5);
    }

    #[test]
    fn stats_uptime_is_monotone() {
        let s = ServeStats::new();
        let a = s.uptime_us();
        let b = s.uptime_us();
        assert!(b >= a);
        // uptime_s is derived from the same start instant.
        assert!(s.uptime_s() <= 1);
    }
}
