//! Saturating service counters and uptime for `/v1/stats`.
//!
//! The counter type itself now lives in [`crate::obs::registry`] — the
//! serve layer was its first customer and the obs layer generalized it
//! for the whole stack — so [`Monotonic`] is a re-export kept for the
//! existing call sites (`flight`, `disk`, `batch`, `router`).  It
//! saturates at `u64::MAX` instead of wrapping: a fleet-scale deployment
//! can legitimately run for months, and a wrapped counter would read as
//! a *reset* to a dashboard and trip rate alarms, while a saturated one
//! merely stops moving — the safer failure.  None of these values ever
//! enter result bytes (DESIGN.md §9): they are observability only, which
//! is also why the uptime clock below is an [`obs::Stopwatch`] rather
//! than a raw `Instant` (lint rule D7 quarantines `std::time` inside
//! `obs::`).
//!
//! [`obs::Stopwatch`]: crate::obs::Stopwatch

pub use crate::obs::Counter as Monotonic;

use crate::obs::Stopwatch;

/// Request-level counters plus the service start instant.
///
/// Owned by the `Server` and shared with every worker; all fields are
/// interior-mutable so the struct itself can live behind a plain `Arc`.
#[derive(Debug)]
pub struct ServeStats {
    started: Stopwatch,
    /// Total connections answered (any status).
    pub requests: Monotonic,
    /// Responses with status >= 400, plus handler panics.
    pub errors: Monotonic,
    /// Microseconds spent inside request handling (not idle accept time).
    pub busy_us: Monotonic,
    /// Spec computations actually executed (cache hits, disk hits and
    /// dedup followers do NOT count; a merged batch of M jobs counts M).
    pub campaigns: Monotonic,
}

impl ServeStats {
    /// Fresh counters anchored at the current instant.
    pub fn new() -> Self {
        ServeStats {
            started: Stopwatch::start(),
            requests: Monotonic::new(),
            errors: Monotonic::new(),
            busy_us: Monotonic::new(),
            campaigns: Monotonic::new(),
        }
    }

    /// Whole seconds since the service started.
    pub fn uptime_s(&self) -> u64 {
        self.started.elapsed().as_secs()
    }

    /// Microseconds since the service started (feeds the legacy
    /// `uptime_us` stats field).
    pub fn uptime_us(&self) -> u64 {
        self.started.elapsed_us()
    }
}

impl Default for ServeStats {
    fn default() -> Self {
        ServeStats::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn monotonic_saturates_instead_of_wrapping() {
        let c = Monotonic::new();
        c.add(u64::MAX - 1);
        c.incr();
        assert_eq!(c.get(), u64::MAX);
        // One past the top must stick at the top, not wrap to zero.
        c.incr();
        assert_eq!(c.get(), u64::MAX);
        c.add(12345);
        assert_eq!(c.get(), u64::MAX);
    }

    #[test]
    fn monotonic_counts_from_zero() {
        let c = Monotonic::default();
        assert_eq!(c.get(), 0);
        c.incr();
        c.add(4);
        assert_eq!(c.get(), 5);
    }

    #[test]
    fn stats_uptime_is_monotone() {
        let s = ServeStats::new();
        let a = s.uptime_us();
        let b = s.uptime_us();
        assert!(b >= a);
        // uptime_s is derived from the same start instant.
        assert!(s.uptime_s() <= 1);
    }
}
