//! Request routing: JSON bodies -> canonical spec keys -> the serving
//! pipeline (memory cache -> disk tier -> single-flight -> batched
//! compute).
//!
//! Every compute endpoint follows the same shape (DESIGN.md §11/§14):
//!
//! 1. parse the JSON body into the same spec type the TOML configs parse
//!    into (`util::json` and `util::toml_lite` share one [`Value`] tree,
//!    so request bodies mirror the checked-in config files field for
//!    field);
//! 2. **canonicalize** the spec into a deterministic key — identity
//!    fields only, floats rendered at the `report::canon`/`csv_cell`
//!    precision, performance knobs (`shards`/`threads`/`block`/`workers`)
//!    excluded because the campaign layer guarantees they never move the
//!    artifacts (DESIGN.md §4). The `kernel` tier IS identity — the fast
//!    surrogate is tolerance-bounded, not bit-identical (DESIGN.md §13) —
//!    so it stays in the spec and forks the key;
//! 3. walk the [`Pipeline`]: answer from the sharded in-memory LRU on a
//!    hit; else from the [`DiskTier`] (promoting the body back into
//!    memory); else join the [`SingleFlight`] for the key — followers
//!    park their connection (or block, in-process) and share the
//!    leader's result; the leader computes through the [`Coalescer`]
//!    (for `/v1/infer` and `/v1/sweep/point`) or directly (for
//!    `/v1/mc`), then publishes the body to both cache tiers and every
//!    follower.
//!
//! Response bodies are produced by the *same* encoders the CLI artifact
//! writers use ([`crate::report::mc_json`], [`crate::dse::sweep_json`],
//! [`crate::nn::infer_json`]), so a served response is byte-identical to
//! the corresponding `--json` artifact — which is also what makes every
//! pipeline layer sound: a cached, disk-persisted, deduplicated, or
//! batch-computed body is the same bytes a solo computation would have
//! produced.

use std::fmt::Write as _;
use std::path::Path;
use std::sync::Arc;

use crate::coordinator::{run_campaign, Backend, CampaignSpec};
use crate::dse::{point_key, GridAxes, SweepSpec};
use crate::mac::{KernelKind, Variant};
use crate::montecarlo::Corner;
use crate::nn::{InferOptions, ModelSpec};
use crate::obs::{MetricsRegistry, Tracer};
use crate::params::Params;
use crate::report;
use crate::util::json::{self, Value};

use super::batch::{infer_compat, sweep_compat, Coalescer, Job};
use super::cache::ResultCache;
use super::disk::DiskTier;
use super::flight::{Gate, Join, SingleFlight};
use super::http::{ParkedConn, Request, Response};
use super::stats::ServeStats;

/// Work ceiling per request (MAC evaluations). A single request may not
/// monopolize a worker indefinitely: campaigns above this are rejected
/// with `400` instead of queued (batch-sized runs belong to the CLI).
pub const MAX_REQUEST_ITEMS: u64 = 1 << 22;

/// Which pipeline layer answered a compute request; the value of the
/// `X-Smart-Cache` provenance header.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CacheTier {
    /// Served from the in-memory LRU.
    Hit,
    /// Served from the disk tier (and promoted back into memory).
    Disk,
    /// Joined an in-flight computation and shared its result.
    Dedup,
    /// Computed by this request (the flight leader).
    Miss,
}

impl CacheTier {
    /// Header token for this tier.
    pub fn token(self) -> &'static str {
        match self {
            CacheTier::Hit => "hit",
            CacheTier::Disk => "disk",
            CacheTier::Dedup => "dedup",
            CacheTier::Miss => "miss",
        }
    }
}

/// One routed request: the response, which pipeline layer produced it
/// (`None` for non-compute endpoints), and how many parked follower
/// connections were answered by this request's fan-out.
pub struct Routed {
    /// The response to frame.
    pub response: Response,
    /// Pipeline provenance for the `X-Smart-Cache` header.
    pub cache: Option<CacheTier>,
    /// Parked connections answered alongside this response (leader
    /// fan-out); error statuses count once per answered connection.
    pub fanout: usize,
}

impl Routed {
    fn plain(response: Response) -> Self {
        Self { response, cache: None, fanout: 0 }
    }
}

/// Outcome of routing a request that carried a live connection.
pub enum Fetched {
    /// The response is ready; the connection (if one was passed in) is
    /// handed back for the caller to write to.
    Done(Routed, Option<ParkedConn>),
    /// The connection was parked on an in-flight computation; the
    /// flight leader's fan-out will answer it. Do not write anything.
    Parked,
}

/// The three-layer serving pipeline plus the compute stack it fronts.
pub struct Pipeline {
    params: Params,
    cache: ResultCache,
    disk: Option<DiskTier>,
    flight: SingleFlight,
    batch: Coalescer,
    gate: Arc<Gate>,
    stats: Arc<ServeStats>,
    registry: Arc<MetricsRegistry>,
    tracer: Tracer,
}

impl Pipeline {
    /// Build a pipeline: a byte-budgeted in-memory LRU (`cache_cap`
    /// bytes across `cache_shards` shards), an optional disk tier under
    /// `cache_dir` (created if missing; fails only on I/O errors), and
    /// a coalescer merging up to `batch_max` compatible jobs per
    /// execution.
    pub fn new(
        params: Params,
        cache_cap: usize,
        cache_shards: usize,
        cache_dir: Option<&Path>,
        batch_max: usize,
    ) -> std::io::Result<Self> {
        let gate = Arc::new(Gate::new());
        let stats = Arc::new(ServeStats::new());
        let registry = Arc::new(MetricsRegistry::new());
        let disk = match cache_dir {
            Some(dir) => Some(DiskTier::open(dir)?),
            None => None,
        };
        Ok(Pipeline {
            params,
            cache: ResultCache::new(cache_cap, cache_shards),
            disk,
            flight: SingleFlight::new(),
            batch: Coalescer::new(
                params,
                batch_max,
                Arc::clone(&gate),
                Arc::clone(&stats),
                registry.histogram("serve_batch_group_size"),
            ),
            gate,
            stats,
            registry,
            tracer: Tracer::disabled(),
        })
    }

    /// Install the request tracer (per-request spans). Called before the
    /// pipeline is shared; the default is the inert [`Tracer::disabled`].
    pub fn set_tracer(&mut self, tracer: Tracer) {
        self.tracer = tracer;
    }

    /// The request tracer (inert unless `--trace`/`SMART_TRACE` set one).
    pub fn tracer(&self) -> &Tracer {
        &self.tracer
    }

    /// The metrics registry behind `GET /v1/metrics` (request latency
    /// and batch group-size histograms natively; structural gauges
    /// mirrored at scrape time by [`Pipeline::sync_metrics`]).
    pub fn registry(&self) -> &Arc<MetricsRegistry> {
        &self.registry
    }

    /// Mirror the pipeline's structural counters (cache occupancy and
    /// traffic, disk tier, flight map, coalescer queue) into registry
    /// gauges so one registry export carries the whole serving picture.
    /// Values move monotonically or both ways depending on the source;
    /// they are exposed uniformly as gauges because they are *read* here,
    /// not owned here.
    pub fn sync_metrics(&self) {
        let g = |name: &str, v: u64| self.registry.gauge(name).set(v);
        g("serve_cache_entries", self.cache.len() as u64);
        g("serve_cache_bytes", self.cache.bytes() as u64);
        g("serve_cache_hits", self.cache.hits());
        g("serve_cache_misses", self.cache.misses());
        g("serve_cache_evictions", self.cache.evictions());
        g("serve_flight_leads", self.flight.leads());
        g("serve_flight_deduped", self.flight.deduped());
        g("serve_flight_waiting", self.flight.waiting());
        g("serve_batch_batched", self.batch.batched());
        g("serve_batch_groups", self.batch.groups());
        g("serve_batch_queued", self.batch.queued());
        g("serve_campaigns", self.stats.campaigns.get());
        g("serve_busy_us", self.stats.busy_us.get());
        if let Some(d) = &self.disk {
            g("serve_disk_hits", d.hits());
            g("serve_disk_misses", d.misses());
            g("serve_disk_writes", d.writes());
            g("serve_disk_bytes_written", d.bytes_written());
            g("serve_disk_rejects", d.rejects());
            g("serve_disk_warm_entries", d.warm_entries());
        }
    }

    /// The server's model card.
    pub fn params(&self) -> &Params {
        &self.params
    }

    /// The in-memory result cache.
    pub fn cache(&self) -> &ResultCache {
        &self.cache
    }

    /// The disk tier, if one is configured.
    pub fn disk(&self) -> Option<&DiskTier> {
        self.disk.as_ref()
    }

    /// The single-flight dedup map.
    pub fn flight(&self) -> &SingleFlight {
        &self.flight
    }

    /// The cross-request coalescer.
    pub fn batch(&self) -> &Coalescer {
        &self.batch
    }

    /// The compute gate (paused by the self-test to pile herds up).
    pub fn gate(&self) -> &Gate {
        &self.gate
    }

    /// The service counters.
    pub fn stats(&self) -> &ServeStats {
        &self.stats
    }
}

/// A rejected request: status + message, rendered as a JSON error body.
struct Reject {
    status: u16,
    msg: String,
}

/// Client-side problem (unparseable body, invalid spec, oversized work).
fn bad(msg: impl std::fmt::Display) -> Reject {
    Reject { status: 400, msg: msg.to_string() }
}

/// Server-side problem (the campaign stack failed).
fn fail(msg: impl std::fmt::Display) -> Reject {
    Reject { status: 500, msg: msg.to_string() }
}

/// A validated compute request: its canonical cache key plus the
/// computation that produces the canonical body on a full miss.
struct Prepared<'a> {
    key: String,
    compute: Box<dyn FnOnce() -> Result<String, Reject> + 'a>,
}

/// Route one parsed request synchronously (the in-process path: no
/// connection to park, so a follower blocks until the leader
/// publishes).
pub fn handle(pipe: &Pipeline, req: &Request) -> Routed {
    match route(pipe, req, None) {
        Fetched::Done(routed, _) => routed,
        // unreachable: join() only parks when a connection is supplied
        Fetched::Parked => Routed::plain(Response::error(
            500,
            "internal error: request parked without a connection",
        )),
    }
}

/// Route one parsed request that owns its connection. `Fetched::Parked`
/// means the connection now belongs to an in-flight leader's fan-out.
pub fn handle_conn(pipe: &Pipeline, req: &Request, conn: ParkedConn) -> Fetched {
    route(pipe, req, Some(conn))
}

fn route(pipe: &Pipeline, req: &Request, conn: Option<ParkedConn>) -> Fetched {
    let prepared = match (req.method.as_str(), req.path.as_str()) {
        ("GET", "/v1/health") => return Fetched::Done(Routed::plain(health()), conn),
        ("GET", "/v1/metrics") => return Fetched::Done(Routed::plain(metrics(pipe)), conn),
        ("POST", "/v1/mc") => mc(pipe, &req.body),
        ("POST", "/v1/sweep/point") => sweep_point(pipe, &req.body),
        ("POST", "/v1/infer") => infer(pipe, &req.body),
        (
            _,
            "/v1/health" | "/v1/metrics" | "/v1/mc" | "/v1/sweep/point" | "/v1/infer"
            | "/v1/stats",
        ) => {
            return Fetched::Done(
                Routed::plain(Response::error(405, "method not allowed")),
                conn,
            )
        }
        _ => {
            return Fetched::Done(Routed::plain(Response::error(404, "no such endpoint")), conn)
        }
    };
    match prepared {
        Ok(p) => fetch(pipe, p, conn),
        Err(e) => Fetched::Done(Routed::plain(Response::error(e.status, &e.msg)), conn),
    }
}

/// `GET /v1/metrics`: Prometheus text exposition of the pipeline's
/// registry (the machine-readable sibling of the JSON `GET /v1/stats`).
/// Structural gauges are refreshed at scrape time; the latency and
/// group-size histograms accumulate natively in the registry.
fn metrics(pipe: &Pipeline) -> Response {
    pipe.sync_metrics();
    let mut resp = Response::ok(pipe.registry().prometheus());
    resp.headers
        .push(("Content-Type".to_string(), "text/plain; version=0.0.4".to_string()));
    resp
}

/// `GET /v1/health`: liveness probe.
fn health() -> Response {
    let mut m = std::collections::BTreeMap::new();
    m.insert("service".to_string(), Value::Str("smart-serve".to_string()));
    m.insert("status".to_string(), Value::Str("ok".to_string()));
    let mut body = json::to_string_pretty(&Value::Obj(m));
    body.push('\n');
    Response::ok(body)
}

/// Walk the pipeline for one validated compute request: memory, disk
/// (with promotion), then the single-flight slot; the flight leader
/// computes and publishes to every layer and follower.
fn fetch(pipe: &Pipeline, p: Prepared<'_>, conn: Option<ParkedConn>) -> Fetched {
    if let Some(body) = pipe.cache.get(&p.key) {
        // a hit clones the Arc, never the bytes — the whole point of
        // caching Arc<String> bodies
        let routed =
            Routed { response: Response::ok_shared(body), cache: Some(CacheTier::Hit), fanout: 0 };
        return Fetched::Done(routed, conn);
    }
    if let Some(disk) = &pipe.disk {
        if let Some(body) = disk.get(&p.key) {
            // promote: the next request for this key is a memory hit
            pipe.cache.put(&p.key, Arc::clone(&body));
            let routed = Routed {
                response: Response::ok_shared(body),
                cache: Some(CacheTier::Disk),
                fanout: 0,
            };
            return Fetched::Done(routed, conn);
        }
    }
    match pipe.flight.join(&p.key, conn) {
        Join::Done { status, body, conn } => {
            let routed = Routed {
                response: Response { status, headers: Vec::new(), body },
                cache: Some(CacheTier::Dedup),
                fanout: 0,
            };
            Fetched::Done(routed, conn)
        }
        Join::Parked => Fetched::Parked,
        Join::Lead(lease, conn) => match (p.compute)() {
            Ok(body) => {
                let body = Arc::new(body);
                pipe.cache.put(&p.key, Arc::clone(&body));
                if let Some(disk) = &pipe.disk {
                    // persistence is best-effort: a full disk degrades
                    // the service to memory-only, never to failure
                    let _ = disk.put(&p.key, &body);
                }
                let fanout = lease.complete(200, &body);
                let routed = Routed {
                    response: Response::ok_shared(body),
                    cache: Some(CacheTier::Miss),
                    fanout,
                };
                Fetched::Done(routed, conn)
            }
            Err(e) => {
                let response = Response::error(e.status, &e.msg);
                let fanout = lease.complete(response.status, &response.body);
                Fetched::Done(Routed { response, cache: None, fanout }, conn)
            }
        },
    }
}

/// Canonical cache key of a `/v1/mc` campaign spec: the knob-zeroed
/// `to_toml` rendering. Public so warm-start tooling can seed the disk
/// tier from prior CLI artifacts — the key of an `mc.json` artifact is
/// `mc_cache_key` of the spec that produced it.
pub fn mc_cache_key(spec: &CampaignSpec) -> String {
    // Identity canonicalization: performance knobs never change the
    // artifact bytes (DESIGN.md §4), so they are stripped from the spec
    // before it becomes the cache key. The kernel field survives — a
    // fast-tier result is not byte-interchangeable with a block-tier one
    // (DESIGN.md §13).
    let mut c = spec.clone();
    c.workers = 0;
    c.batch = 0;
    c.shards = 0;
    c.block = 0;
    format!("mc\n{}", c.to_toml())
}

/// `POST /v1/mc`: body mirrors a `[[campaigns]]` table (JSON form);
/// response is the canonical `mc.json` bytes.
fn mc<'a>(pipe: &'a Pipeline, body: &str) -> Result<Prepared<'a>, Reject> {
    let v = json::parse(body).map_err(|e| bad(format!("mc request body: {e}")))?;
    let mut spec =
        CampaignSpec::from_value(&v).map_err(|e| bad(format!("mc spec: {e:#}")))?;
    spec.workers = 0;
    spec.batch = 0;
    spec.shards = 0;
    spec.block = 0;
    // n_operands never materializes the operand list: the ceiling must
    // reject a 4-billion-op request before allocating it
    let total = spec.workload.n_operands().saturating_mul(u64::from(spec.n_mc));
    if total > MAX_REQUEST_ITEMS {
        return Err(bad(format!(
            "campaign of {total} MAC evals exceeds the per-request ceiling of {MAX_REQUEST_ITEMS}"
        )));
    }
    let key = mc_cache_key(&spec);
    let compute = Box::new(move || {
        // campaigns are not batchable across requests (each spec is its
        // own engine configuration), so the gate sits directly here
        pipe.gate.wait();
        // One OS thread per request worker: request-level parallelism
        // comes from the serve pool, not from nested campaign fan-out.
        let mut exec = spec.clone();
        exec.workers = 1;
        let rep = run_campaign(&pipe.params, &exec, Backend::Native, None)
            .map_err(|e| fail(format!("mc campaign: {e:#}")))?;
        pipe.stats.campaigns.incr();
        Ok(report::mc_json(&spec, &rep))
    });
    Ok(Prepared { key, compute })
}

/// `POST /v1/sweep/point`: body is one grid point in `dse.toml` terms
/// (scalar `variant`/`vdd`/`v_bulk`/`bits`/`corner` plus `name`/`seed`/
/// `n_mc`, an optional `kernel` tier, and optional `params` overrides);
/// response is the canonical single-point `sweep.json` bytes. Computes
/// through the coalescer: compatible concurrent points share one merged
/// campaign engine.
fn sweep_point<'a>(pipe: &'a Pipeline, body: &str) -> Result<Prepared<'a>, Reject> {
    let v = json::parse(body).map_err(|e| bad(format!("sweep request body: {e}")))?;
    let kernel: KernelKind = match v.get("kernel").and_then(Value::as_str) {
        Some(s) => s.parse().map_err(bad)?,
        None => KernelKind::Block,
    };
    let mut card = Params::default();
    if let Some(p) = v.get("params") {
        card.apply_overrides(p).map_err(|e| bad(format!("sweep [params]: {e:#}")))?;
    }
    let variant: Variant = match v.get("variant").and_then(Value::as_str) {
        Some(s) => s.parse().map_err(bad)?,
        None => Variant::Smart,
    };
    let corner: Corner = match v.get("corner").and_then(Value::as_str) {
        Some(s) => s.parse().map_err(bad)?,
        None => Corner::Tt,
    };
    let num = |k: &str, default: f64| v.get(k).and_then(Value::as_f64).unwrap_or(default);
    let int = |k: &str, default: u64| v.get(k).and_then(Value::as_u64).unwrap_or(default);
    let spec = SweepSpec {
        name: v.get("name").and_then(Value::as_str).unwrap_or("serve").to_string(),
        seed: int("seed", 2022),
        n_mc: int("n_mc", 1000) as u32,
        grid: GridAxes {
            variants: vec![variant],
            vdd: vec![num("vdd", card.device.vdd)],
            v_bulk: vec![num("v_bulk", card.circuit.v_bulk_smart)],
            bits: vec![int("bits", u64::from(card.circuit.n_bits)) as u32],
            corners: vec![corner],
        },
        params: card,
    };
    spec.validate().map_err(bad)?;
    let point = spec.grid.expand().remove(0);
    let total = (1u64 << (2 * point.bits)) * u64::from(spec.n_mc);
    if total > MAX_REQUEST_ITEMS {
        return Err(bad(format!(
            "grid point of {total} MAC evals exceeds the per-request ceiling of {MAX_REQUEST_ITEMS}"
        )));
    }
    // The name is part of the response bytes but not of point_key, so it
    // joins the cache key explicitly. point_key carries the kernel tier.
    let key = format!("sweep\n{}\n{}", spec.name, point_key(&point, &spec, kernel));
    let compute = Box::new(move || {
        let compat = sweep_compat(&spec, &point, kernel);
        pipe.batch
            .submit(&compat, Job::SweepPoint { spec, point, kernel })
            .map_err(|e| fail(format!("sweep point: {e}")))
    });
    Ok(Prepared { key, compute })
}

/// `POST /v1/infer`: body mirrors an `nn.toml` model file plus optional
/// top-level `variant`, `kernel`, and `noise_off`; response is the
/// canonical `infer.json` bytes. Computes through the coalescer:
/// compatible concurrent inferences share one engine and tiler
/// calibration.
fn infer<'a>(pipe: &'a Pipeline, body: &str) -> Result<Prepared<'a>, Reject> {
    let v = json::parse(body).map_err(|e| bad(format!("infer request body: {e}")))?;
    let spec = ModelSpec::from_value(&v).map_err(|e| bad(format!("infer model: {e:#}")))?;
    let variant: Variant = match v.get("variant").and_then(Value::as_str) {
        Some(s) => s.parse().map_err(bad)?,
        None => Variant::Smart,
    };
    let kernel: KernelKind = match v.get("kernel").and_then(Value::as_str) {
        Some(s) => s.parse().map_err(bad)?,
        None => KernelKind::Block,
    };
    let noise_off = v.get("noise_off").and_then(Value::as_bool).unwrap_or(false);
    // saturating arithmetic: layer dims are client-controlled, and an
    // overflow that wrapped past the ceiling would admit a giant campaign
    let words = u64::from(spec.bits / 4);
    let ops: u64 = spec.layers.iter().fold(0u64, |acc, l| {
        acc.saturating_add(
            (l.inputs as u64)
                .saturating_mul(l.outputs as u64)
                .saturating_mul(words)
                .saturating_mul(words),
        )
    });
    let total = ops.saturating_mul(u64::from(spec.trials));
    if total > MAX_REQUEST_ITEMS {
        return Err(bad(format!(
            "inference of {total} MAC evals exceeds the per-request ceiling of {MAX_REQUEST_ITEMS}"
        )));
    }
    let key = infer_key(&spec, variant, noise_off, kernel);
    let compute = Box::new(move || {
        let opts = InferOptions {
            threads: 1,
            variant,
            kernel,
            noise_off,
            ..InferOptions::default()
        };
        let compat = infer_compat(variant, kernel);
        pipe.batch
            .submit(&compat, Job::Infer { spec, opts })
            .map_err(|e| fail(format!("infer campaign: {e}")))
    });
    Ok(Prepared { key, compute })
}

/// Canonical identity key of one inference request: every field that can
/// move the response bytes (model identity + variant + kernel tier +
/// noise switch), floats at the [`report::csv_cell`] precision;
/// `shards`/`threads`/`block` are bit-identical performance knobs and
/// never appear. The kernel is identity because `infer.json` records it
/// and the fast tier is tolerance-bounded (DESIGN.md §13).
fn infer_key(spec: &ModelSpec, variant: Variant, noise_off: bool, kernel: KernelKind) -> String {
    let mut k = String::from("infer\n");
    let _ = writeln!(
        k,
        "{}\n{}\n{}\n{}\n{}\n{}\n{}",
        spec.name,
        spec.seed,
        spec.trials,
        spec.bits,
        variant.token(),
        kernel.token(),
        u8::from(noise_off)
    );
    let d = &spec.dataset;
    let _ = writeln!(k, "dataset {} {} {}", d.classes, d.features, report::csv_cell(d.jitter));
    for l in &spec.layers {
        let _ = writeln!(k, "layer {} {} {}", l.inputs, l.outputs, u8::from(l.relu));
    }
    k
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req(method: &str, path: &str, body: &str) -> Request {
        Request { method: method.into(), path: path.into(), body: body.into() }
    }

    fn pipe() -> Pipeline {
        Pipeline::new(Params::default(), 1 << 20, 2, None, 8).unwrap()
    }

    /// Self-cleaning temp dir for disk-tier tests.
    struct Scratch(std::path::PathBuf);

    impl Scratch {
        fn new(tag: &str) -> Scratch {
            let dir = std::env::temp_dir()
                .join(format!("smart-router-{tag}-{}", std::process::id()));
            let _ = std::fs::remove_dir_all(&dir);
            Scratch(dir)
        }
    }

    impl Drop for Scratch {
        fn drop(&mut self) {
            let _ = std::fs::remove_dir_all(&self.0);
        }
    }

    #[test]
    fn health_is_a_plain_ok() {
        let p = pipe();
        let r = handle(&p, &req("GET", "/v1/health", ""));
        assert_eq!(r.response.status, 200);
        assert!(r.cache.is_none());
        assert!(r.response.body.contains("smart-serve"));
    }

    #[test]
    fn unknown_paths_and_methods_are_rejected() {
        let p = pipe();
        assert_eq!(handle(&p, &req("GET", "/nope", "")).response.status, 404);
        assert_eq!(handle(&p, &req("GET", "/v1/mc", "")).response.status, 405);
        assert_eq!(handle(&p, &req("POST", "/v1/health", "")).response.status, 405);
        assert_eq!(handle(&p, &req("POST", "/v1/metrics", "")).response.status, 405);
    }

    #[test]
    fn metrics_endpoint_exposes_the_registry_as_prometheus_text() {
        let p = pipe();
        let body = r#"{"variant": "smart", "n_mc": 8,
                       "workload": {"kind": "fixed", "a": 15, "b": 15}}"#;
        assert_eq!(handle(&p, &req("POST", "/v1/mc", body)).response.status, 200);
        let r = handle(&p, &req("GET", "/v1/metrics", ""));
        assert_eq!(r.response.status, 200);
        assert!(r.cache.is_none());
        assert!(r
            .response
            .headers
            .iter()
            .any(|(k, v)| k == "Content-Type" && v.starts_with("text/plain")));
        let text = &*r.response.body;
        assert!(text.contains("# TYPE serve_cache_misses gauge"));
        assert!(text.contains("serve_campaigns 1"));
        assert!(text.contains("# TYPE serve_batch_group_size histogram"));
        assert!(text.contains("serve_batch_group_size_count 0"));
    }

    #[test]
    fn bad_bodies_get_400_with_json_errors() {
        let p = pipe();
        for (path, body) in [
            ("/v1/mc", "not json"),
            ("/v1/mc", r#"{"variant": "bogus", "workload": {"kind": "full_sweep"}}"#),
            (
                "/v1/mc",
                r#"{"variant": "smart", "kernel": "warp", "workload": {"kind": "full_sweep"}}"#,
            ),
            ("/v1/sweep/point", r#"{"vdd": -1.0}"#),
            ("/v1/sweep/point", r#"{"kernel": "warp"}"#),
            ("/v1/infer", r#"{"name": "x"}"#),
        ] {
            let r = handle(&p, &req("POST", path, body));
            assert_eq!(r.response.status, 400, "{path} {body}");
            assert!(json::parse(&r.response.body).is_ok());
        }
        // work ceiling: a million-sample full sweep is CLI territory
        let r = handle(
            &p,
            &req(
                "POST",
                "/v1/mc",
                r#"{"variant": "smart", "n_mc": 1000000, "workload": {"kind": "full_sweep"}}"#,
            ),
        );
        assert_eq!(r.response.status, 400);
        assert!(r.response.body.contains("ceiling"));
    }

    #[test]
    fn mc_is_cached_and_byte_identical_to_the_artifact_encoder() {
        let p = pipe();
        let body = r#"{"variant": "smart", "n_mc": 8,
                       "workload": {"kind": "fixed", "a": 15, "b": 15}}"#;
        let first = handle(&p, &req("POST", "/v1/mc", body));
        assert_eq!(first.response.status, 200);
        assert_eq!(first.cache, Some(CacheTier::Miss));
        let again = handle(&p, &req("POST", "/v1/mc", body));
        assert_eq!(again.cache, Some(CacheTier::Hit));
        assert_eq!(first.response.body, again.response.body);
        assert_eq!(p.stats().campaigns.get(), 1, "the hit must not recompute");
        // the response is exactly the CLI artifact encoder's output
        let mut spec = crate::coordinator::CampaignSpec::paper_fig8(Variant::Smart);
        spec.n_mc = 8;
        let rep = run_campaign(&Params::default(), &spec, Backend::Native, None).unwrap();
        assert_eq!(*first.response.body, report::mc_json(&spec, &rep));
    }

    #[test]
    fn perf_knobs_share_one_cache_entry() {
        let p = pipe();
        let a = r#"{"variant": "aid", "n_mc": 8,
                    "workload": {"kind": "fixed", "a": 3, "b": 9}}"#;
        let b = r#"{"variant": "aid", "n_mc": 8, "shards": 4, "workers": 2, "block": 16,
                    "workload": {"kind": "fixed", "a": 3, "b": 9}}"#;
        let ra = handle(&p, &req("POST", "/v1/mc", a));
        let rb = handle(&p, &req("POST", "/v1/mc", b));
        assert_eq!(ra.cache, Some(CacheTier::Miss));
        assert_eq!(rb.cache, Some(CacheTier::Hit), "perf knobs must not fork the cache key");
        assert_eq!(ra.response.body, rb.response.body);
        // the kernel tier IS identity: an explicit fast-tier request
        // computes its own entry instead of reusing the block-tier bytes
        let c = r#"{"variant": "aid", "n_mc": 8, "kernel": "fast",
                    "workload": {"kind": "fixed", "a": 3, "b": 9}}"#;
        let rc = handle(&p, &req("POST", "/v1/mc", c));
        assert_eq!(rc.cache, Some(CacheTier::Miss), "kernel must fork the cache key");
        assert!(rc.response.body.contains("\"kernel\": \"fast\""));
    }

    #[test]
    fn concurrent_identical_requests_dedup_into_one_campaign() {
        let p = pipe();
        let body = r#"{"variant": "smart", "n_mc": 8,
                       "workload": {"kind": "fixed", "a": 5, "b": 7}}"#;
        // Pause the gate so the leader stalls mid-compute: the second
        // request then provably joins the in-flight slot rather than
        // hitting the cache.
        p.gate().pause();
        let (ra, rb) = std::thread::scope(|scope| {
            let a = {
                let p = &p;
                scope.spawn(move || handle(p, &req("POST", "/v1/mc", body)))
            };
            let b = {
                let p = &p;
                scope.spawn(move || handle(p, &req("POST", "/v1/mc", body)))
            };
            // one thread leads (stalled at the gate), the other waits on
            // the flight slot
            while p.flight().waiting() < 1 {
                std::thread::yield_now();
            }
            p.gate().resume();
            (a.join().unwrap(), b.join().unwrap())
        });
        assert_eq!(ra.response.status, 200);
        assert_eq!(rb.response.status, 200);
        assert_eq!(ra.response.body, rb.response.body);
        let tiers = [ra.cache, rb.cache];
        assert!(tiers.contains(&Some(CacheTier::Miss)), "{tiers:?}");
        assert!(tiers.contains(&Some(CacheTier::Dedup)), "{tiers:?}");
        assert_eq!(p.stats().campaigns.get(), 1, "the herd must cost one campaign");
        assert_eq!(p.flight().deduped(), 1);
        assert_eq!(p.flight().leads(), 1);
    }

    #[test]
    fn disk_tier_survives_a_restart_with_zero_recompute() {
        let scratch = Scratch::new("restart");
        let body = r#"{"variant": "smart", "n_mc": 8,
                       "workload": {"kind": "fixed", "a": 2, "b": 11}}"#;
        let first = {
            let p = Pipeline::new(Params::default(), 1 << 20, 2, Some(&scratch.0), 8).unwrap();
            let r = handle(&p, &req("POST", "/v1/mc", body));
            assert_eq!(r.cache, Some(CacheTier::Miss));
            assert_eq!(p.disk().unwrap().writes(), 1);
            r.response.body
        };
        // "restart": a fresh pipeline over the same directory
        let p = Pipeline::new(Params::default(), 1 << 20, 2, Some(&scratch.0), 8).unwrap();
        assert_eq!(p.disk().unwrap().warm_entries(), 1);
        let r = handle(&p, &req("POST", "/v1/mc", body));
        assert_eq!(r.cache, Some(CacheTier::Disk), "restart must serve from disk");
        assert_eq!(r.response.body, first, "disk bytes must be byte-identical");
        assert_eq!(p.stats().campaigns.get(), 0, "restart must not recompute");
        // the disk hit promoted the body into memory
        let again = handle(&p, &req("POST", "/v1/mc", body));
        assert_eq!(again.cache, Some(CacheTier::Hit));
    }

    #[test]
    fn infer_key_tracks_identity_fields_only() {
        let spec = ModelSpec::fixture();
        let base = infer_key(&spec, Variant::Smart, false, KernelKind::Block);
        assert_ne!(base, infer_key(&spec, Variant::Aid, false, KernelKind::Block));
        assert_ne!(base, infer_key(&spec, Variant::Smart, true, KernelKind::Block));
        assert_ne!(base, infer_key(&spec, Variant::Smart, false, KernelKind::Fast));
        let mut other = spec.clone();
        other.trials += 1;
        assert_ne!(base, infer_key(&other, Variant::Smart, false, KernelKind::Block));
        assert_eq!(base, infer_key(&spec, Variant::Smart, false, KernelKind::Block));
    }
}
