//! Request routing: JSON bodies -> canonical spec keys -> cache or the
//! campaign stack.
//!
//! Every compute endpoint follows the same shape (DESIGN.md §11):
//!
//! 1. parse the JSON body into the same spec type the TOML configs parse
//!    into (`util::json` and `util::toml_lite` share one [`Value`] tree,
//!    so request bodies mirror the checked-in config files field for
//!    field);
//! 2. **canonicalize** the spec into a deterministic key — identity
//!    fields only, floats rendered at the `report::canon`/`csv_cell`
//!    precision, performance knobs (`shards`/`threads`/`block`/`workers`)
//!    excluded because the campaign layer guarantees they never move the
//!    artifacts (DESIGN.md §4). The `kernel` tier IS identity — the fast
//!    surrogate is tolerance-bounded, not bit-identical (DESIGN.md §13) —
//!    so it stays in the spec and forks the key;
//! 3. answer from the sharded LRU on a hit, else run the existing
//!    block-execution campaign stack and cache the canonical JSON body.
//!
//! Response bodies are produced by the *same* encoders the CLI artifact
//! writers use ([`crate::report::mc_json`], [`crate::dse::sweep_json`],
//! [`crate::nn::infer_json`]), so a served response is byte-identical to
//! the corresponding `--json` artifact.

use std::fmt::Write as _;
use std::sync::Arc;

use crate::coordinator::{run_campaign, Backend, CampaignSpec};
use crate::dse::{point_key, run_grid_point, sweep_json, GridAxes, SweepOptions, SweepSpec};
use crate::mac::{KernelKind, Variant};
use crate::montecarlo::Corner;
use crate::nn::{infer_json, run_infer, InferOptions, ModelSpec};
use crate::params::Params;
use crate::report;
use crate::util::json::{self, Value};

use super::cache::ResultCache;
use super::http::{Request, Response};

/// Work ceiling per request (MAC evaluations). A single request may not
/// monopolize a worker indefinitely: campaigns above this are rejected
/// with `400` instead of queued (batch-sized runs belong to the CLI).
pub const MAX_REQUEST_ITEMS: u64 = 1 << 22;

/// One routed request: the response plus the cache outcome
/// (`Some(true)` = served from cache, `Some(false)` = computed,
/// `None` = not a compute endpoint).
pub struct Routed {
    /// The response to frame.
    pub response: Response,
    /// Cache outcome for the `X-Smart-Cache` provenance header.
    pub cache: Option<bool>,
}

impl Routed {
    fn plain(response: Response) -> Self {
        Self { response, cache: None }
    }
}

/// A rejected request: status + message, rendered as a JSON error body.
struct Reject {
    status: u16,
    msg: String,
}

/// Client-side problem (unparseable body, invalid spec, oversized work).
fn bad(msg: impl std::fmt::Display) -> Reject {
    Reject { status: 400, msg: msg.to_string() }
}

/// Server-side problem (the campaign stack failed).
fn fail(msg: impl std::fmt::Display) -> Reject {
    Reject { status: 500, msg: msg.to_string() }
}

/// Route one parsed request against the cache and the campaign stack.
pub fn handle(params: &Params, cache: &ResultCache, req: &Request) -> Routed {
    let outcome = match (req.method.as_str(), req.path.as_str()) {
        ("GET", "/v1/health") => return Routed::plain(health()),
        ("POST", "/v1/mc") => mc(params, cache, &req.body),
        ("POST", "/v1/sweep/point") => sweep_point(cache, &req.body),
        ("POST", "/v1/infer") => infer(params, cache, &req.body),
        (_, "/v1/health" | "/v1/mc" | "/v1/sweep/point" | "/v1/infer" | "/v1/stats") => {
            return Routed::plain(Response::error(405, "method not allowed"))
        }
        _ => return Routed::plain(Response::error(404, "no such endpoint")),
    };
    match outcome {
        Ok(routed) => routed,
        Err(e) => Routed::plain(Response::error(e.status, &e.msg)),
    }
}

/// `GET /v1/health`: liveness probe.
fn health() -> Response {
    let mut m = std::collections::BTreeMap::new();
    m.insert("service".to_string(), Value::Str("smart-serve".to_string()));
    m.insert("status".to_string(), Value::Str("ok".to_string()));
    let mut body = json::to_string_pretty(&Value::Obj(m));
    body.push('\n');
    Response::ok(body)
}

/// Answer from the cache, or compute + insert. `compute` only runs on a
/// miss; concurrent misses on one key may compute twice, which is safe
/// (and byte-identical) by the determinism contract.
fn cached(
    cache: &ResultCache,
    key: &str,
    compute: impl FnOnce() -> Result<String, Reject>,
) -> Result<Routed, Reject> {
    if let Some(body) = cache.get(key) {
        // a hit clones the Arc, never the bytes — the whole point of
        // caching Arc<String> bodies
        return Ok(Routed { response: Response::ok_shared(body), cache: Some(true) });
    }
    let body = Arc::new(compute()?);
    cache.put(key, Arc::clone(&body));
    Ok(Routed { response: Response::ok_shared(body), cache: Some(false) })
}

/// `POST /v1/mc`: body mirrors a `[[campaigns]]` table (JSON form);
/// response is the canonical `mc.json` bytes.
fn mc(params: &Params, cache: &ResultCache, body: &str) -> Result<Routed, Reject> {
    let v = json::parse(body).map_err(|e| bad(format!("mc request body: {e}")))?;
    let mut spec =
        CampaignSpec::from_value(&v).map_err(|e| bad(format!("mc spec: {e:#}")))?;
    // Identity canonicalization: performance knobs never change the
    // artifact bytes (DESIGN.md §4), so they are stripped from the spec
    // before it becomes the cache key. The kernel field survives — a
    // fast-tier result is not byte-interchangeable with a block-tier one
    // (DESIGN.md §13).
    spec.workers = 0;
    spec.batch = 0;
    spec.shards = 0;
    spec.block = 0;
    // n_operands never materializes the operand list: the ceiling must
    // reject a 4-billion-op request before allocating it
    let total = spec.workload.n_operands().saturating_mul(u64::from(spec.n_mc));
    if total > MAX_REQUEST_ITEMS {
        return Err(bad(format!(
            "campaign of {total} MAC evals exceeds the per-request ceiling of {MAX_REQUEST_ITEMS}"
        )));
    }
    let key = format!("mc\n{}", spec.to_toml());
    cached(cache, &key, || {
        // One OS thread per request worker: request-level parallelism
        // comes from the serve pool, not from nested campaign fan-out.
        let mut exec = spec.clone();
        exec.workers = 1;
        let rep = run_campaign(params, &exec, Backend::Native, None)
            .map_err(|e| fail(format!("mc campaign: {e:#}")))?;
        Ok(report::mc_json(&spec, &rep))
    })
}

/// `POST /v1/sweep/point`: body is one grid point in `dse.toml` terms
/// (scalar `variant`/`vdd`/`v_bulk`/`bits`/`corner` plus `name`/`seed`/
/// `n_mc`, an optional `kernel` tier, and optional `params` overrides);
/// response is the canonical single-point `sweep.json` bytes.
fn sweep_point(cache: &ResultCache, body: &str) -> Result<Routed, Reject> {
    let v = json::parse(body).map_err(|e| bad(format!("sweep request body: {e}")))?;
    let kernel: KernelKind = match v.get("kernel").and_then(Value::as_str) {
        Some(s) => s.parse().map_err(bad)?,
        None => KernelKind::Block,
    };
    let mut card = Params::default();
    if let Some(p) = v.get("params") {
        card.apply_overrides(p).map_err(|e| bad(format!("sweep [params]: {e:#}")))?;
    }
    let variant: Variant = match v.get("variant").and_then(Value::as_str) {
        Some(s) => s.parse().map_err(bad)?,
        None => Variant::Smart,
    };
    let corner: Corner = match v.get("corner").and_then(Value::as_str) {
        Some(s) => s.parse().map_err(bad)?,
        None => Corner::Tt,
    };
    let num = |k: &str, default: f64| v.get(k).and_then(Value::as_f64).unwrap_or(default);
    let int = |k: &str, default: u64| v.get(k).and_then(Value::as_u64).unwrap_or(default);
    let spec = SweepSpec {
        name: v.get("name").and_then(Value::as_str).unwrap_or("serve").to_string(),
        seed: int("seed", 2022),
        n_mc: int("n_mc", 1000) as u32,
        grid: GridAxes {
            variants: vec![variant],
            vdd: vec![num("vdd", card.device.vdd)],
            v_bulk: vec![num("v_bulk", card.circuit.v_bulk_smart)],
            bits: vec![int("bits", u64::from(card.circuit.n_bits)) as u32],
            corners: vec![corner],
        },
        params: card,
    };
    spec.validate().map_err(bad)?;
    let point = spec.grid.expand().remove(0);
    let total = (1u64 << (2 * point.bits)) * u64::from(spec.n_mc);
    if total > MAX_REQUEST_ITEMS {
        return Err(bad(format!(
            "grid point of {total} MAC evals exceeds the per-request ceiling of {MAX_REQUEST_ITEMS}"
        )));
    }
    // The name is part of the response bytes but not of point_key, so it
    // joins the cache key explicitly. point_key carries the kernel tier.
    let key = format!("sweep\n{}\n{}", spec.name, point_key(&point, &spec, kernel));
    cached(cache, &key, || {
        let opts = SweepOptions { threads: 1, kernel, ..SweepOptions::default() };
        let r = run_grid_point(&spec, &point, &opts)
            .map_err(|e| fail(format!("sweep point: {e:#}")))?;
        // a single point is trivially Pareto-optimal
        Ok(sweep_json(&spec, &[r], &[true], kernel))
    })
}

/// `POST /v1/infer`: body mirrors an `nn.toml` model file plus optional
/// top-level `variant`, `kernel`, and `noise_off`; response is the
/// canonical `infer.json` bytes.
fn infer(params: &Params, cache: &ResultCache, body: &str) -> Result<Routed, Reject> {
    let v = json::parse(body).map_err(|e| bad(format!("infer request body: {e}")))?;
    let spec = ModelSpec::from_value(&v).map_err(|e| bad(format!("infer model: {e:#}")))?;
    let variant: Variant = match v.get("variant").and_then(Value::as_str) {
        Some(s) => s.parse().map_err(bad)?,
        None => Variant::Smart,
    };
    let kernel: KernelKind = match v.get("kernel").and_then(Value::as_str) {
        Some(s) => s.parse().map_err(bad)?,
        None => KernelKind::Block,
    };
    let noise_off = v.get("noise_off").and_then(Value::as_bool).unwrap_or(false);
    // saturating arithmetic: layer dims are client-controlled, and an
    // overflow that wrapped past the ceiling would admit a giant campaign
    let words = u64::from(spec.bits / 4);
    let ops: u64 = spec.layers.iter().fold(0u64, |acc, l| {
        acc.saturating_add(
            (l.inputs as u64)
                .saturating_mul(l.outputs as u64)
                .saturating_mul(words)
                .saturating_mul(words),
        )
    });
    let total = ops.saturating_mul(u64::from(spec.trials));
    if total > MAX_REQUEST_ITEMS {
        return Err(bad(format!(
            "inference of {total} MAC evals exceeds the per-request ceiling of {MAX_REQUEST_ITEMS}"
        )));
    }
    let key = infer_key(&spec, variant, noise_off, kernel);
    cached(cache, &key, || {
        let opts = InferOptions {
            threads: 1,
            variant,
            kernel,
            noise_off,
            ..InferOptions::default()
        };
        let r = run_infer(params, &spec, &opts)
            .map_err(|e| fail(format!("infer campaign: {e:#}")))?;
        Ok(infer_json(&spec, &r))
    })
}

/// Canonical identity key of one inference request: every field that can
/// move the response bytes (model identity + variant + kernel tier +
/// noise switch), floats at the [`report::csv_cell`] precision;
/// `shards`/`threads`/`block` are bit-identical performance knobs and
/// never appear. The kernel is identity because `infer.json` records it
/// and the fast tier is tolerance-bounded (DESIGN.md §13).
fn infer_key(spec: &ModelSpec, variant: Variant, noise_off: bool, kernel: KernelKind) -> String {
    let mut k = String::from("infer\n");
    let _ = writeln!(
        k,
        "{}\n{}\n{}\n{}\n{}\n{}\n{}",
        spec.name,
        spec.seed,
        spec.trials,
        spec.bits,
        variant.token(),
        kernel.token(),
        u8::from(noise_off)
    );
    let d = &spec.dataset;
    let _ = writeln!(k, "dataset {} {} {}", d.classes, d.features, report::csv_cell(d.jitter));
    for l in &spec.layers {
        let _ = writeln!(k, "layer {} {} {}", l.inputs, l.outputs, u8::from(l.relu));
    }
    k
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req(method: &str, path: &str, body: &str) -> Request {
        Request { method: method.into(), path: path.into(), body: body.into() }
    }

    #[test]
    fn health_is_a_plain_ok() {
        let cache = ResultCache::new(4, 1);
        let r = handle(&Params::default(), &cache, &req("GET", "/v1/health", ""));
        assert_eq!(r.response.status, 200);
        assert!(r.cache.is_none());
        assert!(r.response.body.contains("smart-serve"));
    }

    #[test]
    fn unknown_paths_and_methods_are_rejected() {
        let cache = ResultCache::new(4, 1);
        let p = Params::default();
        assert_eq!(handle(&p, &cache, &req("GET", "/nope", "")).response.status, 404);
        assert_eq!(handle(&p, &cache, &req("GET", "/v1/mc", "")).response.status, 405);
        assert_eq!(handle(&p, &cache, &req("POST", "/v1/health", "")).response.status, 405);
    }

    #[test]
    fn bad_bodies_get_400_with_json_errors() {
        let cache = ResultCache::new(4, 1);
        let p = Params::default();
        for (path, body) in [
            ("/v1/mc", "not json"),
            ("/v1/mc", r#"{"variant": "bogus", "workload": {"kind": "full_sweep"}}"#),
            (
                "/v1/mc",
                r#"{"variant": "smart", "kernel": "warp", "workload": {"kind": "full_sweep"}}"#,
            ),
            ("/v1/sweep/point", r#"{"vdd": -1.0}"#),
            ("/v1/sweep/point", r#"{"kernel": "warp"}"#),
            ("/v1/infer", r#"{"name": "x"}"#),
        ] {
            let r = handle(&p, &cache, &req("POST", path, body));
            assert_eq!(r.response.status, 400, "{path} {body}");
            assert!(json::parse(&r.response.body).is_ok());
        }
        // work ceiling: a million-sample full sweep is CLI territory
        let r = handle(
            &p,
            &cache,
            &req(
                "POST",
                "/v1/mc",
                r#"{"variant": "smart", "n_mc": 1000000, "workload": {"kind": "full_sweep"}}"#,
            ),
        );
        assert_eq!(r.response.status, 400);
        assert!(r.response.body.contains("ceiling"));
    }

    #[test]
    fn mc_is_cached_and_byte_identical_to_the_artifact_encoder() {
        let cache = ResultCache::new(8, 2);
        let p = Params::default();
        let body = r#"{"variant": "smart", "n_mc": 8,
                       "workload": {"kind": "fixed", "a": 15, "b": 15}}"#;
        let first = handle(&p, &cache, &req("POST", "/v1/mc", body));
        assert_eq!(first.response.status, 200);
        assert_eq!(first.cache, Some(false));
        let again = handle(&p, &cache, &req("POST", "/v1/mc", body));
        assert_eq!(again.cache, Some(true));
        assert_eq!(first.response.body, again.response.body);
        // the response is exactly the CLI artifact encoder's output
        let mut spec = crate::coordinator::CampaignSpec::paper_fig8(Variant::Smart);
        spec.n_mc = 8;
        let rep = run_campaign(&p, &spec, Backend::Native, None).unwrap();
        assert_eq!(*first.response.body, report::mc_json(&spec, &rep));
    }

    #[test]
    fn perf_knobs_share_one_cache_entry() {
        let cache = ResultCache::new(8, 2);
        let p = Params::default();
        let a = r#"{"variant": "aid", "n_mc": 8,
                    "workload": {"kind": "fixed", "a": 3, "b": 9}}"#;
        let b = r#"{"variant": "aid", "n_mc": 8, "shards": 4, "workers": 2, "block": 16,
                    "workload": {"kind": "fixed", "a": 3, "b": 9}}"#;
        let ra = handle(&p, &cache, &req("POST", "/v1/mc", a));
        let rb = handle(&p, &cache, &req("POST", "/v1/mc", b));
        assert_eq!(ra.cache, Some(false));
        assert_eq!(rb.cache, Some(true), "perf knobs must not fork the cache key");
        assert_eq!(ra.response.body, rb.response.body);
        // the kernel tier IS identity: an explicit fast-tier request
        // computes its own entry instead of reusing the block-tier bytes
        let c = r#"{"variant": "aid", "n_mc": 8, "kernel": "fast",
                    "workload": {"kind": "fixed", "a": 3, "b": 9}}"#;
        let rc = handle(&p, &cache, &req("POST", "/v1/mc", c));
        assert_eq!(rc.cache, Some(false), "kernel must fork the cache key");
        assert!(rc.response.body.contains("\"kernel\": \"fast\""));
    }

    #[test]
    fn infer_key_tracks_identity_fields_only() {
        let spec = ModelSpec::fixture();
        let base = infer_key(&spec, Variant::Smart, false, KernelKind::Block);
        assert_ne!(base, infer_key(&spec, Variant::Aid, false, KernelKind::Block));
        assert_ne!(base, infer_key(&spec, Variant::Smart, true, KernelKind::Block));
        assert_ne!(base, infer_key(&spec, Variant::Smart, false, KernelKind::Fast));
        let mut other = spec.clone();
        other.trials += 1;
        assert_ne!(base, infer_key(&other, Variant::Smart, false, KernelKind::Block));
        assert_eq!(base, infer_key(&spec, Variant::Smart, false, KernelKind::Block));
    }
}
