//! Sharded LRU result cache keyed by canonical spec keys.
//!
//! Values are the finished canonical JSON response bodies (`Arc<String>`
//! — hits clone a pointer, never the bytes). Keys are the deterministic
//! spec keys the router builds (DESIGN.md §11): because every float in a
//! key passes through the `report::canon` precision rules, two requests
//! describing the same campaign always collide onto one entry, and a hit
//! returns bytes identical to what the campaign stack would recompute.
//!
//! Capacity is accounted in **bytes**, not entries: a `/v1/sweep/point`
//! body is orders of magnitude larger than a health probe, so an entry
//! count bounds nothing. Every entry is charged `body.len()`; eviction
//! removes least-recently-used entries until the newcomer fits, and a
//! body larger than a whole shard's budget is simply not cached (it
//! still gets served — the disk tier and single-flight layer above this
//! one keep recomputation bounded).
//!
//! Sharding bounds lock contention: a key hashes (FNV-1a) to one shard,
//! each shard is an independent `Mutex<BTreeMap>` with its own logical
//! clock, and eviction removes the shard's least-recently-used entry by
//! linear scan — shards hold service-sized entry counts, so O(entries)
//! eviction is cheaper than maintaining an intrusive list. The ordered
//! map keeps every walk (eviction scans, stats) deterministic by
//! construction.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use crate::util::fnv1a;

/// One cached response body plus its recency stamp.
struct Entry {
    body: Arc<String>,
    last_used: u64,
}

/// One independent LRU shard with its byte ledger.
struct Shard {
    map: BTreeMap<String, Entry>,
    clock: u64,
    /// Sum of `body.len()` over `map` — kept incrementally so stats and
    /// eviction never rescan.
    bytes: usize,
}

/// A sharded, byte-budgeted LRU cache of canonical response bodies.
pub struct ResultCache {
    shards: Vec<Mutex<Shard>>,
    cap_per_shard: usize,
    hits: AtomicU64,
    misses: AtomicU64,
    evictions: AtomicU64,
}

impl ResultCache {
    /// A cache holding at most `capacity` **bytes** of response bodies
    /// across `n_shards` shards (both clamped to >= 1; the byte budget
    /// rounds up to a multiple of the shard count).
    pub fn new(capacity: usize, n_shards: usize) -> Self {
        let n_shards = n_shards.max(1);
        let cap_per_shard = capacity.max(1).div_ceil(n_shards);
        let shards = (0..n_shards)
            .map(|_| Mutex::new(Shard { map: BTreeMap::new(), clock: 0, bytes: 0 }))
            .collect();
        Self {
            shards,
            cap_per_shard,
            hits: AtomicU64::new(0),
            misses: AtomicU64::new(0),
            evictions: AtomicU64::new(0),
        }
    }

    fn shard(&self, key: &str) -> &Mutex<Shard> {
        &self.shards[(fnv1a(key) % self.shards.len() as u64) as usize]
    }

    /// Look up a canonical key; a hit refreshes its recency.
    pub fn get(&self, key: &str) -> Option<Arc<String>> {
        // A poisoned shard only means a sibling panicked mid-update; the
        // map holds complete immutable bodies, so recover and keep serving.
        let mut s = self.shard(key).lock().unwrap_or_else(std::sync::PoisonError::into_inner);
        s.clock += 1;
        let clock = s.clock;
        match s.map.get_mut(key) {
            Some(e) => {
                e.last_used = clock;
                let _ = self
                    .hits
                    .fetch_update(Ordering::Relaxed, Ordering::Relaxed, |v| {
                        Some(v.saturating_add(1))
                    });
                Some(Arc::clone(&e.body))
            }
            None => {
                let _ = self
                    .misses
                    .fetch_update(Ordering::Relaxed, Ordering::Relaxed, |v| {
                        Some(v.saturating_add(1))
                    });
                None
            }
        }
    }

    /// Insert (or refresh) a canonical key, evicting least-recently-used
    /// entries until the shard's byte budget holds the newcomer. A body
    /// larger than the whole shard budget is not cached at all (the
    /// caller still serves it). Concurrent misses on the same key may
    /// both insert — the bodies are deterministic and byte-identical,
    /// so last-writer-wins is harmless.
    pub fn put(&self, key: &str, body: Arc<String>) {
        let cost = body.len();
        if cost > self.cap_per_shard {
            return;
        }
        let mut s = self.shard(key).lock().unwrap_or_else(std::sync::PoisonError::into_inner);
        s.clock += 1;
        let clock = s.clock;
        if let Some(old) = s.map.remove(key) {
            // Refresh: release the old charge, then re-admit as new.
            s.bytes -= old.body.len();
        }
        while s.bytes + cost > self.cap_per_shard {
            let Some(lru) = s
                .map
                .iter()
                .min_by_key(|(_, e)| e.last_used)
                .map(|(k, _)| k.clone())
            else {
                break;
            };
            if let Some(e) = s.map.remove(&lru) {
                s.bytes -= e.body.len();
            }
            let _ = self
                .evictions
                .fetch_update(Ordering::Relaxed, Ordering::Relaxed, |v| {
                    Some(v.saturating_add(1))
                });
        }
        s.bytes += cost;
        s.map.insert(key.to_string(), Entry { body, last_used: clock });
    }

    /// Entries currently cached (sum over shards).
    pub fn len(&self) -> usize {
        self.shards
            .iter()
            .map(|s| s.lock().unwrap_or_else(std::sync::PoisonError::into_inner).map.len())
            .sum()
    }

    /// True when no entry is cached.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Bytes of response bodies currently cached (sum over shards).
    pub fn bytes(&self) -> usize {
        self.shards
            .iter()
            .map(|s| s.lock().unwrap_or_else(std::sync::PoisonError::into_inner).bytes)
            .sum()
    }

    /// Lookups answered from the cache.
    pub fn hits(&self) -> u64 {
        self.hits.load(Ordering::Relaxed)
    }

    /// Lookups that missed (and went to the next tier down).
    pub fn misses(&self) -> u64 {
        self.misses.load(Ordering::Relaxed)
    }

    /// Entries evicted to make room.
    pub fn evictions(&self) -> u64 {
        self.evictions.load(Ordering::Relaxed)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn body(s: &str) -> Arc<String> {
        Arc::new(s.to_string())
    }

    #[test]
    fn get_put_hit_miss_counters() {
        let c = ResultCache::new(64, 2);
        assert!(c.get("a").is_none());
        c.put("a", body("A"));
        assert_eq!(c.get("a").unwrap().as_str(), "A");
        assert_eq!(c.hits(), 1);
        assert_eq!(c.misses(), 1);
        assert_eq!(c.len(), 1);
        assert!(!c.is_empty());
    }

    #[test]
    fn byte_accounting_tracks_inserts_and_replacements() {
        let c = ResultCache::new(100, 1);
        c.put("a", body("0123456789")); // 10 bytes
        assert_eq!(c.bytes(), 10);
        c.put("a", body("0123")); // refresh releases the old charge
        assert_eq!(c.bytes(), 4);
        c.put("b", body("012345"));
        assert_eq!(c.bytes(), 10);
        assert_eq!(c.len(), 2);
        assert_eq!(c.evictions(), 0);
    }

    #[test]
    fn lru_evicts_by_bytes_until_the_newcomer_fits() {
        // single shard so the LRU order is fully observable
        let c = ResultCache::new(10, 1);
        c.put("a", body("aaaa")); // 4 bytes
        c.put("b", body("bbbb")); // 4 bytes
        assert!(c.get("a").is_some()); // refresh a; b is now coldest
        c.put("c", body("cccccccc")); // 8 bytes: must displace b, then a
        assert_eq!(c.evictions(), 2);
        assert!(c.get("b").is_none());
        assert!(c.get("a").is_none());
        assert_eq!(c.get("c").unwrap().as_str(), "cccccccc");
        assert_eq!(c.bytes(), 8);
        assert_eq!(c.len(), 1);
    }

    #[test]
    fn same_size_refresh_never_evicts() {
        let c = ResultCache::new(8, 1);
        c.put("a", body("aaaa"));
        c.put("b", body("bbbb"));
        c.put("a", body("AAAA")); // deterministic bodies are same-sized
        assert_eq!(c.evictions(), 0);
        assert_eq!(c.get("a").unwrap().as_str(), "AAAA");
        assert!(c.get("b").is_some());
        assert_eq!(c.bytes(), 8);
    }

    #[test]
    fn oversize_bodies_are_skipped_not_cached() {
        let c = ResultCache::new(8, 1);
        c.put("small", body("ssss"));
        c.put("big", body("this body exceeds the shard budget"));
        assert_eq!(c.len(), 1, "oversize body must not be cached");
        assert_eq!(c.bytes(), 4);
        assert_eq!(c.evictions(), 0, "oversize insert must not displace residents");
        assert!(c.get("big").is_none());
        assert!(c.get("small").is_some());
    }

    #[test]
    fn sharding_is_deterministic_and_capacity_rounds_up() {
        let c = ResultCache::new(10, 4);
        assert_eq!(c.cap_per_shard, 3);
        for i in 0..40 {
            c.put(&format!("key-{i}"), body("x"));
        }
        // every shard respects its own byte budget
        assert!(c.bytes() <= 12, "bytes = {}", c.bytes());
        assert!(c.evictions() > 0);
        // same key always lands on the same shard: a put is always visible
        c.put("stable", body("S"));
        assert_eq!(c.get("stable").unwrap().as_str(), "S");
    }
}
