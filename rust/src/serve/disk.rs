//! Disk-backed cache tier: canonical response bodies that survive
//! restarts.
//!
//! Every body the service computes is byte-reproducible (DESIGN.md §9),
//! which makes a disk cache trivially validatable: a stored body is
//! either byte-identical to what a recompute would produce, or it is
//! corrupt and must be rejected. Entries live under `--cache-dir`, one
//! file per canonical spec key, named `{fnv1a(key):016x}.body`. The file
//! format is length-prefixed and fingerprinted:
//!
//! ```text
//! smart-serve-cache v1\n
//! key <len>\n
//! <key bytes>\n
//! body <len> <fnv1a(body):016x>\n
//! <body bytes>\n
//! ```
//!
//! Keys embed newlines (the `/v1/mc` key carries a whole canonical
//! TOML), so the format is length-prefixed rather than line-oriented.
//! A read validates magic, lengths, terminators, and the body
//! fingerprint; any mismatch rejects the entry — the file is deleted and
//! the request falls through to recompute, which rewrites it. The one
//! exception is a well-formed file whose *stored key* differs from the
//! requested key (an FNV filename collision): that is a plain miss and
//! the resident entry is kept.
//!
//! Writes go to a uniquely-suffixed temp file in the same directory and
//! are renamed into place, so a concurrent reader (or a crash) sees
//! either the old complete entry or the new complete entry, never a
//! torn one.
//!
//! Because bodies are the same bytes the CLI `--json` artifacts carry,
//! the tier also warm-starts from prior CLI runs: anything inserted via
//! [`DiskTier::put`] under the router's canonical key (see the
//! `*_cache_key` helpers) is served byte-identically with zero
//! recompute.

use std::fs;
use std::io;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

use crate::util::fnv1a;

use super::stats::Monotonic;

/// File magic; bump the version when the layout changes so stale tiers
/// reject cleanly instead of misparsing.
const MAGIC: &str = "smart-serve-cache v1\n";

/// The persistent cache tier under one directory.
pub struct DiskTier {
    dir: PathBuf,
    /// Monotonic temp-file suffix: concurrent writers in one process
    /// never collide on a temp name.
    tmp_seq: AtomicU64,
    hits: Monotonic,
    misses: Monotonic,
    writes: Monotonic,
    bytes_written: Monotonic,
    rejects: Monotonic,
    warm_entries: u64,
}

impl DiskTier {
    /// Open (creating if needed) the tier rooted at `dir` and count the
    /// entries already present — the warm-start inventory.
    pub fn open(dir: &Path) -> io::Result<DiskTier> {
        fs::create_dir_all(dir)?;
        let mut warm = 0u64;
        for entry in fs::read_dir(dir)? {
            let name = entry?.file_name();
            let Some(name) = name.to_str() else { continue };
            let Some(stem) = name.strip_suffix(".body") else { continue };
            if stem.len() == 16 && stem.bytes().all(|b| b.is_ascii_hexdigit()) {
                warm += 1;
            }
        }
        Ok(DiskTier {
            dir: dir.to_path_buf(),
            tmp_seq: AtomicU64::new(0),
            hits: Monotonic::new(),
            misses: Monotonic::new(),
            writes: Monotonic::new(),
            bytes_written: Monotonic::new(),
            rejects: Monotonic::new(),
            warm_entries: warm,
        })
    }

    /// The on-disk path an entry for `key` lives at.
    pub fn path_for(&self, key: &str) -> PathBuf {
        self.dir.join(format!("{:016x}.body", fnv1a(key)))
    }

    /// Look up `key`. A malformed, truncated, or fingerprint-mismatched
    /// file is rejected: deleted, counted, and reported as a miss so the
    /// caller recomputes (and rewrites) it.
    pub fn get(&self, key: &str) -> Option<Arc<String>> {
        let path = self.path_for(key);
        let text = match fs::read_to_string(&path) {
            Ok(text) => text,
            Err(e) if e.kind() == io::ErrorKind::NotFound => {
                self.misses.incr();
                return None;
            }
            Err(_) => {
                // Unreadable (non-UTF-8 garbage, permissions): reject.
                self.reject(&path);
                return None;
            }
        };
        match decode_entry(&text) {
            Ok((stored_key, body)) if stored_key == key => {
                self.hits.incr();
                Some(Arc::new(body.to_string()))
            }
            Ok(_) => {
                // FNV filename collision with a different spec: a plain
                // miss; the resident entry stays.
                self.misses.incr();
                None
            }
            Err(_) => self.reject(&path),
        }
    }

    fn reject(&self, path: &Path) -> Option<Arc<String>> {
        self.rejects.incr();
        self.misses.incr();
        let _ = fs::remove_file(path);
        None
    }

    /// Persist `body` under `key` (atomic temp-file + rename). Serving
    /// never depends on this succeeding; the caller may ignore the
    /// error after counting it.
    pub fn put(&self, key: &str, body: &str) -> io::Result<()> {
        let mut text = String::with_capacity(MAGIC.len() + key.len() + body.len() + 64);
        text.push_str(MAGIC);
        text.push_str(&format!("key {}\n", key.len()));
        text.push_str(key);
        text.push('\n');
        text.push_str(&format!("body {} {:016x}\n", body.len(), fnv1a(body)));
        text.push_str(body);
        text.push('\n');
        // lint:allow(L2): uniqueness ticket for temp-file names — the previous value is the name, wrap only reuses a suffix
        let seq = self.tmp_seq.fetch_add(1, Ordering::Relaxed);
        let path = self.path_for(key);
        let mut tmp = path.clone();
        tmp.set_extension(format!("tmp{seq}"));
        fs::write(&tmp, &text)?;
        fs::rename(&tmp, &path)?;
        self.writes.incr();
        self.bytes_written.add(text.len() as u64);
        Ok(())
    }

    /// Lookups served from disk.
    pub fn hits(&self) -> u64 {
        self.hits.get()
    }

    /// Lookups not present on disk (includes rejected entries and
    /// filename collisions).
    pub fn misses(&self) -> u64 {
        self.misses.get()
    }

    /// Entries persisted.
    pub fn writes(&self) -> u64 {
        self.writes.get()
    }

    /// Total on-disk bytes persisted across all writes (entry framing
    /// included) — the tier's write-amplification view.
    pub fn bytes_written(&self) -> u64 {
        self.bytes_written.get()
    }

    /// Malformed/truncated/mismatched entries deleted on read.
    pub fn rejects(&self) -> u64 {
        self.rejects.get()
    }

    /// Entries already present when the tier was opened.
    pub fn warm_entries(&self) -> u64 {
        self.warm_entries
    }
}

/// Split one stored entry into `(key, body)`, validating structure and
/// the body fingerprint. Any violation is an error the caller treats as
/// a rejected entry. Uses checked slicing throughout: a corrupt length
/// that lands mid-UTF-8-sequence is an error, never a panic.
fn decode_entry(text: &str) -> Result<(&str, &str), &'static str> {
    let rest = text.strip_prefix(MAGIC).ok_or("bad magic")?;
    let (key_line, rest) = rest.split_once('\n').ok_or("missing key header")?;
    let key_len: usize = key_line
        .strip_prefix("key ")
        .ok_or("bad key header")?
        .parse()
        .map_err(|_| "bad key length")?;
    let key = rest.get(..key_len).ok_or("truncated key")?;
    let rest = rest.get(key_len..).ok_or("truncated key")?;
    let rest = rest.strip_prefix('\n').ok_or("unterminated key")?;
    let (body_line, rest) = rest.split_once('\n').ok_or("missing body header")?;
    let mut fields = body_line.strip_prefix("body ").ok_or("bad body header")?.split(' ');
    let body_len: usize = fields
        .next()
        .ok_or("missing body length")?
        .parse()
        .map_err(|_| "bad body length")?;
    let fingerprint = fields.next().ok_or("missing body fingerprint")?;
    if fields.next().is_some() {
        return Err("trailing body header fields");
    }
    let body = rest.get(..body_len).ok_or("truncated body")?;
    if rest.get(body_len..) != Some("\n") {
        return Err("unterminated body");
    }
    if format!("{:016x}", fnv1a(body)) != fingerprint {
        return Err("body fingerprint mismatch");
    }
    Ok((key, body))
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Unique scratch directory per test (removed on drop).
    struct Scratch(PathBuf);

    impl Scratch {
        fn new(tag: &str) -> Scratch {
            let dir = std::env::temp_dir()
                .join(format!("smart-disktier-{tag}-{}", std::process::id()));
            let _ = fs::remove_dir_all(&dir);
            Scratch(dir)
        }
    }

    impl Drop for Scratch {
        fn drop(&mut self) {
            let _ = fs::remove_dir_all(&self.0);
        }
    }

    #[test]
    fn put_get_roundtrips_bytes_and_counts() {
        let scratch = Scratch::new("roundtrip");
        let tier = DiskTier::open(&scratch.0).unwrap();
        assert_eq!(tier.warm_entries(), 0);
        let key = "mc\nvariant = \"smart\"\nn_mc = 8\n"; // keys embed newlines
        let body = "{\n  \"sigma\": 0.009\n}\n";
        assert!(tier.get(key).is_none());
        tier.put(key, body).unwrap();
        assert_eq!(tier.get(key).unwrap().as_str(), body);
        assert_eq!((tier.hits(), tier.misses(), tier.writes(), tier.rejects()), (1, 1, 1, 0));
        // framing adds magic + headers on top of key + body bytes
        assert!(tier.bytes_written() > (key.len() + body.len()) as u64);

        // A reopened tier (the "restart") serves the same bytes and
        // reports the warm inventory.
        let reopened = DiskTier::open(&scratch.0).unwrap();
        assert_eq!(reopened.warm_entries(), 1);
        assert_eq!(reopened.get(key).unwrap().as_str(), body);
    }

    #[test]
    fn corrupt_and_truncated_entries_are_rejected_and_deleted() {
        let scratch = Scratch::new("corrupt");
        let tier = DiskTier::open(&scratch.0).unwrap();
        let cases: [&dyn Fn(&str); 3] = [
            &|p: &str| fs::write(p, "not a cache entry").unwrap(),
            &|p: &str| {
                // truncate the stored body mid-way
                let text = fs::read_to_string(p).unwrap();
                fs::write(p, &text[..text.len() - 4]).unwrap();
            },
            &|p: &str| {
                // flip a body byte: structure intact, fingerprint not
                let text = fs::read_to_string(p).unwrap();
                fs::write(p, text.replace("42", "43")).unwrap();
            },
        ];
        for (i, corrupt) in cases.iter().enumerate() {
            let key = format!("spec-{i}");
            tier.put(&key, "{\"answer\": 42}\n").unwrap();
            let path = tier.path_for(&key);
            corrupt(path.to_str().unwrap());
            assert!(tier.get(&key).is_none(), "case {i} must reject");
            assert!(!path.exists(), "case {i} must delete the bad entry");
            // recompute path: a fresh put repairs the entry
            tier.put(&key, "{\"answer\": 42}\n").unwrap();
            assert_eq!(tier.get(&key).unwrap().as_str(), "{\"answer\": 42}\n");
        }
        assert_eq!(tier.rejects(), 3);
    }

    #[test]
    fn filename_collisions_miss_without_evicting_the_resident() {
        let scratch = Scratch::new("collision");
        let tier = DiskTier::open(&scratch.0).unwrap();
        tier.put("resident", "{\"r\": 1}\n").unwrap();
        // Simulate an FNV collision: a well-formed entry for a different
        // key sitting at the requested key's path.
        fs::rename(tier.path_for("resident"), tier.path_for("wanted")).unwrap();
        assert!(tier.get("wanted").is_none());
        assert_eq!(tier.rejects(), 0, "a collision is a miss, not corruption");
        assert!(tier.path_for("wanted").exists(), "the resident entry must survive");
    }
}
