//! Single-flight deduplication of concurrent cache misses.
//!
//! N concurrent requests for the same canonical spec key should cost one
//! campaign, not N (DESIGN.md §14).  The first miss *leads*: it gets a
//! [`Lease`] and runs the computation.  Every later miss on the same key
//! is a *follower* — in the serve path it parks its connection inside
//! the flight slot and its worker returns to the pool, so a thundering
//! herd of a thousand clients occupies one worker, not a thousand.  When
//! the leader completes, the shared `Arc<String>` body fans out to every
//! parked connection (cloning the Arc, never the bytes) tagged
//! `X-Smart-Cache: dedup`.
//!
//! The [`Lease`] is a drop guard: if the leader panics mid-computation,
//! the unwinding drop completes the flight with a 500 so followers get
//! an answer instead of hanging until their socket timeout.
//!
//! [`Gate`] is the self-test's determinism lever: pausing it stalls
//! compute sites (never cache reads), so a test can pile an entire herd
//! onto one in-flight slot before releasing a single execution.

use std::collections::BTreeMap;
use std::sync::{Arc, Condvar, Mutex, PoisonError};

use super::http::{write_response, ParkedConn, Response};
use super::stats::Monotonic;

/// One in-flight computation keyed by canonical spec key.
struct Slot {
    /// Set exactly once, by the leader's completion (or its drop guard).
    done: Option<(u16, Arc<String>)>,
    /// Follower connections awaiting the fan-out.
    parked: Vec<ParkedConn>,
    /// Followers blocked in [`SingleFlight::join`] without a connection
    /// (the in-process `handle` path); they drain the slot on wake.
    sync_waiters: usize,
}

/// Outcome of joining a flight.
pub enum Join<'a> {
    /// This caller leads: run the computation, then
    /// [`Lease::complete`]. The connection (if any) is handed back so
    /// the leader can answer it directly.
    Lead(Lease<'a>, Option<ParkedConn>),
    /// The flight already finished; serve the shared result. The
    /// connection (if any) is handed back untouched.
    Done {
        /// Status the leader completed with.
        status: u16,
        /// Shared canonical body.
        body: Arc<String>,
        /// The caller's connection, returned unconsumed.
        conn: Option<ParkedConn>,
    },
    /// The connection was parked in the slot; the leader's fan-out will
    /// answer it. The caller's worker is free.
    Parked,
}

/// Drop-guard lease held by a flight leader. Completing publishes the
/// result to every follower; dropping without completing publishes a
/// 500 so followers never hang on a panicked leader.
pub struct Lease<'a> {
    flight: &'a SingleFlight,
    key: String,
    done: bool,
}

impl Lease<'_> {
    /// The canonical key this lease leads.
    pub fn key(&self) -> &str {
        &self.key
    }

    /// Publish the result: wakes sync waiters and writes the shared
    /// body to every parked connection (`X-Smart-Cache: dedup`).
    /// Returns how many parked connections were answered, so the caller
    /// can fold fan-out errors into the service counters.
    pub fn complete(mut self, status: u16, body: &Arc<String>) -> usize {
        self.done = true;
        self.flight.finish(&self.key, status, body)
    }
}

impl Drop for Lease<'_> {
    fn drop(&mut self) {
        if !self.done {
            let r = Response::error(500, "internal error: in-flight computation failed");
            self.flight.finish(&self.key, r.status, &r.body);
        }
    }
}

/// The dedup map: canonical key -> in-flight slot.
pub struct SingleFlight {
    slots: Mutex<BTreeMap<String, Slot>>,
    cv: Condvar,
    deduped: Monotonic,
    leads: Monotonic,
}

impl SingleFlight {
    /// An empty dedup map.
    pub fn new() -> Self {
        SingleFlight {
            slots: Mutex::new(BTreeMap::new()),
            cv: Condvar::new(),
            deduped: Monotonic::new(),
            leads: Monotonic::new(),
        }
    }

    /// Join the flight for `key`. The first caller leads; later callers
    /// either park their connection (serve path) or block until the
    /// leader publishes (in-process path, `conn == None`).
    pub fn join(&self, key: &str, conn: Option<ParkedConn>) -> Join<'_> {
        let mut slots = self.slots.lock().unwrap_or_else(PoisonError::into_inner);
        if !slots.contains_key(key) {
            slots.insert(
                key.to_string(),
                Slot { done: None, parked: Vec::new(), sync_waiters: 0 },
            );
            self.leads.incr();
            return Join::Lead(
                Lease { flight: self, key: key.to_string(), done: false },
                conn,
            );
        }
        self.deduped.incr();
        if let Some((status, body)) = slots.get(key).and_then(|s| s.done.clone()) {
            // Completed but not yet reaped (sync waiters still draining):
            // serve the published result directly.
            return Join::Done { status, body, conn };
        }
        match conn {
            Some(c) => {
                if let Some(slot) = slots.get_mut(key) {
                    slot.parked.push(c);
                }
                Join::Parked
            }
            None => {
                if let Some(slot) = slots.get_mut(key) {
                    slot.sync_waiters += 1;
                }
                loop {
                    if let Some((status, body)) = slots.get(key).and_then(|s| s.done.clone()) {
                        let mut drained = false;
                        if let Some(slot) = slots.get_mut(key) {
                            slot.sync_waiters -= 1;
                            drained = slot.sync_waiters == 0;
                        }
                        if drained {
                            slots.remove(key);
                        }
                        return Join::Done { status, body, conn: None };
                    }
                    slots = self.cv.wait(slots).unwrap_or_else(PoisonError::into_inner);
                }
            }
        }
    }

    /// Publish `key`'s result and fan it out; returns the number of
    /// parked connections answered.
    fn finish(&self, key: &str, status: u16, body: &Arc<String>) -> usize {
        let parked = {
            let mut slots = self.slots.lock().unwrap_or_else(PoisonError::into_inner);
            let Some(slot) = slots.get_mut(key) else {
                return 0;
            };
            slot.done = Some((status, Arc::clone(body)));
            let parked = std::mem::take(&mut slot.parked);
            if slot.sync_waiters == 0 {
                slots.remove(key);
            }
            self.cv.notify_all();
            parked
        };
        let n = parked.len();
        for mut c in parked {
            let mut resp = Response { status, headers: Vec::new(), body: Arc::clone(body) };
            resp.headers.push(("X-Smart-Cache".to_string(), "dedup".to_string()));
            resp.headers.push((
                "X-Smart-Time-Us".to_string(),
                c.t0.elapsed_us().to_string(),
            ));
            // A follower that hung up early is its own problem; the
            // fan-out must keep serving the rest.
            let _ = write_response(&mut c.stream, &resp);
        }
        n
    }

    /// Followers currently waiting (parked connections + sync waiters)
    /// across all in-flight slots.
    pub fn waiting(&self) -> u64 {
        let slots = self.slots.lock().unwrap_or_else(PoisonError::into_inner);
        let mut n = 0u64;
        for s in slots.values() {
            n += (s.parked.len() + s.sync_waiters) as u64;
        }
        n
    }

    /// Total followers that joined an existing flight (the work they
    /// did NOT duplicate).
    pub fn deduped(&self) -> u64 {
        self.deduped.get()
    }

    /// Total flights led (computations that actually ran or will run).
    pub fn leads(&self) -> u64 {
        self.leads.get()
    }
}

impl Default for SingleFlight {
    fn default() -> Self {
        SingleFlight::new()
    }
}

/// A pausable gate in front of compute sites. `wait` returns
/// immediately unless paused; the self-test pauses it to pile
/// concurrent misses onto one flight slot deterministically, then
/// resumes to release a single execution.
pub struct Gate {
    paused: Mutex<bool>,
    cv: Condvar,
}

impl Gate {
    /// An open (un-paused) gate.
    pub fn new() -> Self {
        Gate { paused: Mutex::new(false), cv: Condvar::new() }
    }

    /// Stall every subsequent `wait` until `resume`.
    pub fn pause(&self) {
        *self.paused.lock().unwrap_or_else(PoisonError::into_inner) = true;
    }

    /// Release all waiters and stop stalling.
    pub fn resume(&self) {
        *self.paused.lock().unwrap_or_else(PoisonError::into_inner) = false;
        self.cv.notify_all();
    }

    /// Block while the gate is paused; a no-op otherwise.
    pub fn wait(&self) {
        let mut paused = self.paused.lock().unwrap_or_else(PoisonError::into_inner);
        while *paused {
            paused = self.cv.wait(paused).unwrap_or_else(PoisonError::into_inner);
        }
    }
}

impl Default for Gate {
    fn default() -> Self {
        Gate::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicU64, Ordering};

    #[test]
    fn first_join_leads_and_later_joins_dedup() {
        let flight = SingleFlight::new();
        let computed = AtomicU64::new(0);
        let body = std::thread::scope(|scope| {
            let mut handles = Vec::new();
            // Take the lease first so every spawned join is a follower.
            let lease = match flight.join("k", None) {
                Join::Lead(lease, None) => lease,
                _ => panic!("first join must lead"),
            };
            for _ in 0..4 {
                let (flight, computed) = (&flight, &computed);
                handles.push(scope.spawn(move || match flight.join("k", None) {
                    Join::Done { status, body, conn } => {
                        assert_eq!(status, 200);
                        assert!(conn.is_none());
                        body
                    }
                    Join::Lead(..) => {
                        computed.fetch_add(1, Ordering::Relaxed);
                        panic!("follower must not lead");
                    }
                    Join::Parked => panic!("no conn, so no parking"),
                }));
            }
            // Give followers time to block on the condvar.
            while flight.waiting() < 4 {
                std::thread::yield_now();
            }
            computed.fetch_add(1, Ordering::Relaxed);
            let shared = Arc::new("{\"x\": 1}\n".to_string());
            lease.complete(200, &shared);
            let bodies: Vec<Arc<String>> =
                handles.into_iter().map(|h| h.join().unwrap()).collect();
            for b in &bodies {
                // Same allocation, not just equal bytes.
                assert!(Arc::ptr_eq(b, &shared));
            }
            shared
        });
        assert_eq!(computed.load(Ordering::Relaxed), 1);
        assert_eq!(flight.leads(), 1);
        assert_eq!(flight.deduped(), 4);
        assert_eq!(flight.waiting(), 0);
        assert_eq!(*body, "{\"x\": 1}\n");
    }

    #[test]
    fn dropped_lease_publishes_a_500_to_waiters() {
        let flight = SingleFlight::new();
        std::thread::scope(|scope| {
            let lease = match flight.join("k", None) {
                Join::Lead(lease, _) => lease,
                _ => panic!("first join must lead"),
            };
            let waiter = scope.spawn(|| match flight.join("k", None) {
                Join::Done { status, body, .. } => (status, body),
                _ => panic!("follower must get the published result"),
            });
            while flight.waiting() < 1 {
                std::thread::yield_now();
            }
            drop(lease); // leader "panicked"
            let (status, body) = waiter.join().unwrap();
            assert_eq!(status, 500);
            let v = crate::util::json::parse(&body).unwrap();
            assert!(v.get("error").unwrap().as_str().unwrap().contains("in-flight"));
        });
        // Slot fully reaped; the key can lead again.
        assert!(matches!(flight.join("k", None), Join::Lead(..)));
    }

    #[test]
    fn distinct_keys_fly_independently() {
        let flight = SingleFlight::new();
        let a = flight.join("a", None);
        let b = flight.join("b", None);
        assert!(matches!(a, Join::Lead(..)));
        assert!(matches!(b, Join::Lead(..)));
        assert_eq!(flight.leads(), 2);
        assert_eq!(flight.deduped(), 0);
    }

    #[test]
    fn gate_stalls_and_releases_waiters() {
        let gate = Gate::new();
        gate.wait(); // un-paused gate is a no-op
        gate.pause();
        let released = AtomicU64::new(0);
        std::thread::scope(|scope| {
            for _ in 0..3 {
                let (gate, released) = (&gate, &released);
                scope.spawn(move || {
                    gate.wait();
                    released.fetch_add(1, Ordering::Relaxed);
                });
            }
            std::thread::sleep(std::time::Duration::from_millis(20));
            assert_eq!(released.load(Ordering::Relaxed), 0);
            gate.resume();
        });
        assert_eq!(released.load(Ordering::Relaxed), 3);
    }
}
