//! Cross-request trial batching: group-commit coalescing of small
//! compatible `/v1/infer` and `/v1/sweep/point` computations.
//!
//! DSE-style clients hammer the service with many *near*-identical
//! requests — same model card and grid axes, different seeds or trial
//! ranges — that the cache and single-flight layers cannot collapse
//! because their canonical keys differ. This layer coalesces them at the
//! engine level instead: requests whose **compatibility key** matches
//! (same variant + kernel tier, and for sweep points the same operating
//! conditions and card fingerprint) merge into one shared execution
//! where the SoA engine, the `FastKernel` tables, and the tiler
//! calibration amortize across users
//! ([`crate::coordinator::run_native_campaigns_merged`],
//! [`crate::nn::run_infer_batch`]).
//!
//! The protocol is group-commit: the first submitter of a compatibility
//! key becomes the *group leader*; while it stalls at the compute
//! [`Gate`] (and, in production, simply while its own computation is
//! pending), later compatible submitters enqueue. The leader drains up
//! to `batch_max` jobs per merged execution (its own job rides in the
//! first group), delivers each body to its submitter, and keeps
//! draining until the queue is empty before retiring. Every body is
//! **byte-identical** to the solo computation of the same request — the
//! merged runners replicate the solo loops exactly — so coalescing is a
//! pure performance layer that never forks cache keys.
//!
//! Compatibility keys are coarser than cache keys (they drop the
//! per-request identity fields that the merged runners handle per job),
//! but for sweep points they carry `csv_cell`-precision floats; two
//! requests can collide on the key with different exact cards, so the
//! sweep executor re-partitions each group by exact [`Params`] equality
//! before merging.

use std::collections::{BTreeMap, BTreeSet};
use std::sync::{Arc, Condvar, Mutex, PoisonError};

use crate::coordinator::{run_native_campaigns_merged, CampaignSpec};
use crate::dse::{card_fingerprint, point_result, sweep_json, GridPoint, SweepSpec};
use crate::mac::{KernelKind, Variant};
use crate::nn::{infer_json, run_infer_batch, InferOptions, ModelSpec};
use crate::obs::Histogram;
use crate::params::Params;
use crate::report::csv_cell;

use super::flight::Gate;
use super::stats::{Monotonic, ServeStats};

/// One batchable computation.
pub enum Job {
    /// A `/v1/infer` request (spec and serve-shaped options).
    Infer {
        /// The parsed model spec.
        spec: ModelSpec,
        /// Execution options (variant/kernel/noise_off as requested).
        opts: InferOptions,
    },
    /// A `/v1/sweep/point` request.
    SweepPoint {
        /// The single-point sweep spec (card included).
        spec: SweepSpec,
        /// The one expanded grid point.
        point: GridPoint,
        /// Kernel tier the point runs on.
        kernel: KernelKind,
    },
}

/// Compatibility key for `/v1/infer` jobs: the fields the merged infer
/// runner must hold fixed across a group (everything else — seed,
/// trials, layers, noise_off — is per-job).
pub fn infer_compat(variant: Variant, kernel: KernelKind) -> String {
    format!("infer\n{}\n{}", variant.token(), kernel.token())
}

/// Compatibility key for `/v1/sweep/point` jobs: variant + kernel tier
/// plus the operating conditions and card fingerprint that pin the
/// merged campaign engine. Floats render at `csv_cell` precision, so
/// the executor re-checks exact [`Params`] equality before merging.
pub fn sweep_compat(spec: &SweepSpec, point: &GridPoint, kernel: KernelKind) -> String {
    format!(
        "sweep\n{}\n{}\n{}\n{}\n{}",
        point.variant.token(),
        kernel.token(),
        csv_cell(point.vdd),
        csv_cell(point.v_bulk),
        card_fingerprint(&spec.params)
    )
}

/// A follower's delivery slot: the leader stores the job's outcome and
/// signals the condvar.
type SlotCell = Arc<(Mutex<Option<Result<String, String>>>, Condvar)>;

/// One queued follower.
struct Cell {
    job: Job,
    slot: SlotCell,
}

/// Queue state shared by all submitters.
struct State {
    /// Compatibility keys with an active group leader.
    leaders: BTreeSet<String>,
    /// Followers queued per compatibility key, in arrival order.
    pending: BTreeMap<String, Vec<Cell>>,
}

/// The group-commit coalescer.
pub struct Coalescer {
    params: Params,
    batch_max: usize,
    gate: Arc<Gate>,
    stats: Arc<ServeStats>,
    state: Mutex<State>,
    batched: Monotonic,
    groups: Monotonic,
    /// Jobs per executed group (solo rounds included) — usually a
    /// registry histogram (`serve_batch_group_size`) so `/v1/metrics`
    /// exposes the coalescing distribution.
    group_sizes: Arc<Histogram>,
}

impl Coalescer {
    /// A coalescer over the server's model card. `batch_max` bounds the
    /// jobs per merged execution (clamped to >= 1); the [`Gate`] is the
    /// shared compute gate the self-test pauses; `group_sizes` records
    /// the job count of every executed group.
    pub fn new(
        params: Params,
        batch_max: usize,
        gate: Arc<Gate>,
        stats: Arc<ServeStats>,
        group_sizes: Arc<Histogram>,
    ) -> Self {
        Coalescer {
            params,
            batch_max: batch_max.max(1),
            gate,
            stats,
            state: Mutex::new(State { leaders: BTreeSet::new(), pending: BTreeMap::new() }),
            batched: Monotonic::new(),
            groups: Monotonic::new(),
            group_sizes,
        }
    }

    /// Submit one job under its compatibility key and block until its
    /// body is ready. The body is byte-identical to the solo
    /// computation; `Err` carries a message for a 500 response.
    pub fn submit(&self, compat: &str, job: Job) -> Result<String, String> {
        let mut job = Some(job);
        let follower_slot = {
            let mut st = self.state.lock().unwrap_or_else(PoisonError::into_inner);
            if st.leaders.contains(compat) {
                let slot: SlotCell = Arc::new((Mutex::new(None), Condvar::new()));
                if let Some(job) = job.take() {
                    st.pending
                        .entry(compat.to_string())
                        .or_default()
                        .push(Cell { job, slot: Arc::clone(&slot) });
                }
                Some(slot)
            } else {
                st.leaders.insert(compat.to_string());
                None
            }
        };
        match follower_slot {
            Some(slot) => {
                let (result, cv) = &*slot;
                let mut r = result.lock().unwrap_or_else(PoisonError::into_inner);
                loop {
                    if let Some(outcome) = r.take() {
                        return outcome;
                    }
                    r = cv.wait(r).unwrap_or_else(PoisonError::into_inner);
                }
            }
            None => {
                let Some(job) = job.take() else {
                    return Err("coalescer lost the leader's job".to_string());
                };
                self.lead(compat, job)
            }
        }
    }

    /// Group-leader loop: drain and execute merged groups until the
    /// compatibility queue is empty, then retire leadership.
    fn lead(&self, compat: &str, job: Job) -> Result<String, String> {
        // Leadership is registered, so compatible followers can enqueue
        // while we stall here — this is what lets the self-test pile a
        // whole group up behind one paused gate.
        self.gate.wait();
        let mut own: Option<Result<String, String>> = None;
        let mut own_pending = Some(job);
        loop {
            let mut cells: Vec<Cell> = Vec::new();
            let finished = {
                let mut st = self.state.lock().unwrap_or_else(PoisonError::into_inner);
                let room = self.batch_max - usize::from(own_pending.is_some());
                if let Some(q) = st.pending.get_mut(compat) {
                    let take = q.len().min(room.max(usize::from(own_pending.is_none())));
                    cells.extend(q.drain(..take));
                }
                let finished = own_pending.is_none() && cells.is_empty();
                if finished {
                    // Deregister under the same lock that enqueues, so a
                    // late submitter either lands in a queue we will
                    // drain or becomes the next leader — never both.
                    st.leaders.remove(compat);
                    st.pending.remove(compat);
                }
                finished
            };
            if finished {
                break;
            }
            let own_this_round = own_pending.take();
            let n_jobs = cells.len() + usize::from(own_this_round.is_some());
            self.group_sizes.record(n_jobs as u64);
            if n_jobs >= 2 {
                self.groups.incr();
                self.batched.add(n_jobs as u64);
            }
            let mut jobs: Vec<&Job> = Vec::with_capacity(n_jobs);
            if let Some(j) = own_this_round.as_ref() {
                jobs.push(j);
            }
            jobs.extend(cells.iter().map(|c| &c.job));
            match exec_group(&self.params, &jobs) {
                Ok(bodies) => {
                    // One spec computation actually executed per job.
                    self.stats.campaigns.add(jobs.len() as u64);
                    let mut bodies = bodies.into_iter();
                    if own_this_round.is_some() {
                        own = bodies.next().map(Ok);
                    }
                    for (cell, body) in cells.iter().zip(bodies) {
                        deliver(&cell.slot, Ok(body));
                    }
                }
                Err(msg) => {
                    if own_this_round.is_some() {
                        own = Some(Err(msg.clone()));
                    }
                    for cell in &cells {
                        deliver(&cell.slot, Err(msg.clone()));
                    }
                }
            }
        }
        own.unwrap_or_else(|| Err("coalescer produced no result for the leader".to_string()))
    }

    /// Followers currently queued across all compatibility keys.
    pub fn queued(&self) -> u64 {
        let st = self.state.lock().unwrap_or_else(PoisonError::into_inner);
        let mut n = 0u64;
        for q in st.pending.values() {
            n += q.len() as u64;
        }
        n
    }

    /// Jobs that rode in a merged group of two or more (leader included).
    pub fn batched(&self) -> u64 {
        self.batched.get()
    }

    /// Merged executions covering two or more jobs.
    pub fn groups(&self) -> u64 {
        self.groups.get()
    }
}

/// Store a follower's outcome and wake it.
fn deliver(slot: &SlotCell, outcome: Result<String, String>) {
    let (result, cv) = &**slot;
    *result.lock().unwrap_or_else(PoisonError::into_inner) = Some(outcome);
    cv.notify_all();
}

/// Execute one homogeneous merged group and return one canonical body
/// per job, in job order.
fn exec_group(params: &Params, jobs: &[&Job]) -> Result<Vec<String>, String> {
    match jobs.first() {
        None => Ok(Vec::new()),
        Some(Job::Infer { .. }) => {
            let mut pairs: Vec<(ModelSpec, InferOptions)> = Vec::with_capacity(jobs.len());
            for j in jobs {
                let Job::Infer { spec, opts } = j else {
                    return Err("mixed job kinds in one compatibility group".to_string());
                };
                pairs.push((spec.clone(), opts.clone()));
            }
            let reports = run_infer_batch(params, &pairs).map_err(|e| format!("{e:#}"))?;
            Ok(pairs.iter().zip(&reports).map(|((spec, _), r)| infer_json(spec, r)).collect())
        }
        Some(Job::SweepPoint { .. }) => exec_sweep_group(jobs),
    }
}

/// Execute a group of sweep points, re-partitioned by exact [`Params`]
/// equality (the compatibility key's `csv_cell` floats can collide
/// across different exact cards; a collider runs in its own sub-group).
fn exec_sweep_group(jobs: &[&Job]) -> Result<Vec<String>, String> {
    let mixed = || "mixed job kinds in one compatibility group".to_string();
    let mut out: Vec<Option<String>> = Vec::new();
    out.resize_with(jobs.len(), || None);
    let mut remaining: Vec<usize> = (0..jobs.len()).collect();
    while let Some(&anchor) = remaining.first() {
        let Job::SweepPoint { spec: anchor_spec, point: anchor_point, .. } = jobs[anchor] else {
            return Err(mixed());
        };
        let anchor_params = anchor_point.apply(&anchor_spec.params);
        let mut group: Vec<usize> = Vec::new();
        let mut rest: Vec<usize> = Vec::new();
        for &i in &remaining {
            let Job::SweepPoint { spec, point, .. } = jobs[i] else {
                return Err(mixed());
            };
            if point.apply(&spec.params) == anchor_params {
                group.push(i);
            } else {
                rest.push(i);
            }
        }
        let mut cspecs: Vec<CampaignSpec> = Vec::with_capacity(group.len());
        for &i in &group {
            let Job::SweepPoint { spec, point, kernel } = jobs[i] else {
                return Err(mixed());
            };
            // Mirror the solo serve path exactly: shards/block auto,
            // one worker thread (the service parallelizes across
            // requests, not within them).
            cspecs.push(point.campaign_spec(spec.seed, spec.n_mc, 0, 1, 0, *kernel));
        }
        let reps =
            run_native_campaigns_merged(&anchor_params, &cspecs).map_err(|e| format!("{e:#}"))?;
        for (&i, rep) in group.iter().zip(&reps) {
            let Job::SweepPoint { spec, point, kernel } = jobs[i] else {
                return Err(mixed());
            };
            let r = point_result(spec, point, rep);
            out[i] = Some(sweep_json(spec, &[r], &[true], *kernel));
        }
        remaining = rest;
    }
    out.into_iter()
        .map(|o| o.ok_or_else(|| "sweep sub-group produced no body".to_string()))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dse::{run_grid_point, SweepOptions};
    use crate::nn::run_infer;

    fn infer_job(seed_xor: u64) -> Job {
        let mut spec = ModelSpec::fixture();
        spec.seed ^= seed_xor;
        let opts = InferOptions { trials: 2, threads: 1, ..InferOptions::default() };
        Job::Infer { spec, opts }
    }

    fn solo_infer_body(seed_xor: u64) -> String {
        let Job::Infer { spec, opts } = infer_job(seed_xor) else { unreachable!() };
        let r = run_infer(&Params::default(), &spec, &opts).unwrap();
        infer_json(&spec, &r)
    }

    #[test]
    fn a_lone_submit_computes_without_grouping_counters() {
        let stats = Arc::new(ServeStats::new());
        let sizes = Arc::new(Histogram::new());
        let co = Coalescer::new(
            Params::default(),
            8,
            Arc::new(Gate::new()),
            Arc::clone(&stats),
            Arc::clone(&sizes),
        );
        let compat = infer_compat(Variant::Smart, KernelKind::Block);
        let body = co.submit(&compat, infer_job(0)).unwrap();
        assert_eq!(body, solo_infer_body(0));
        assert_eq!(co.groups(), 0);
        assert_eq!(co.batched(), 0);
        assert_eq!(co.queued(), 0);
        assert_eq!(stats.campaigns.get(), 1);
        // the solo round still lands in the group-size histogram
        assert_eq!(sizes.count(), 1);
        assert_eq!(sizes.bucket(0), 1);
    }

    #[test]
    fn concurrent_compatible_infers_coalesce_and_byte_match_solo_runs() {
        let stats = Arc::new(ServeStats::new());
        let gate = Arc::new(Gate::new());
        let sizes = Arc::new(Histogram::new());
        let co = Coalescer::new(
            Params::default(),
            8,
            Arc::clone(&gate),
            Arc::clone(&stats),
            Arc::clone(&sizes),
        );
        let compat = infer_compat(Variant::Smart, KernelKind::Block);
        gate.pause();
        let bodies: Vec<(u64, String)> = std::thread::scope(|scope| {
            let handles: Vec<_> = (0u64..3)
                .map(|i| {
                    let (co, compat) = (&co, &compat);
                    scope.spawn(move || (i, co.submit(compat, infer_job(i)).unwrap()))
                })
                .collect();
            // one leader stalled at the gate, the other two enqueued
            while co.queued() < 2 {
                std::thread::yield_now();
            }
            gate.resume();
            handles.into_iter().map(|h| h.join().unwrap()).collect()
        });
        for (i, body) in &bodies {
            assert_eq!(*body, solo_infer_body(*i), "job {i} must byte-match its solo run");
        }
        assert_eq!(co.groups(), 1, "three compatible jobs must merge into one group");
        assert_eq!(co.batched(), 3);
        // one group of 3 jobs -> one observation in bucket [2, 4)
        assert_eq!(sizes.count(), 1);
        assert_eq!(sizes.bucket(1), 1);
        assert_eq!(stats.campaigns.get(), 3, "each job is one spec computation");
        assert_eq!(co.queued(), 0);
    }

    #[test]
    fn sweep_points_coalesce_and_byte_match_the_grid_runner() {
        let stats = Arc::new(ServeStats::new());
        let gate = Arc::new(Gate::new());
        let co = Coalescer::new(
            Params::default(),
            4,
            Arc::clone(&gate),
            Arc::clone(&stats),
            Arc::new(Histogram::new()),
        );
        let spec_a = SweepSpec::parse("name = \"co\"\nn_mc = 8\nseed = 3\n").unwrap();
        let spec_b = SweepSpec::parse("name = \"co\"\nn_mc = 8\nseed = 4\n").unwrap();
        let (pa, pb) = (spec_a.grid.expand()[0], spec_b.grid.expand()[0]);
        let compat = sweep_compat(&spec_a, &pa, KernelKind::Block);
        assert_eq!(compat, sweep_compat(&spec_b, &pb, KernelKind::Block));
        gate.pause();
        let (body_a, body_b) = std::thread::scope(|scope| {
            let a = {
                let (co, compat, spec, point) = (&co, &compat, &spec_a, pa);
                scope.spawn(move || {
                    co.submit(
                        compat,
                        Job::SweepPoint { spec: spec.clone(), point, kernel: KernelKind::Block },
                    )
                    .unwrap()
                })
            };
            let b = {
                let (co, compat, spec, point) = (&co, &compat, &spec_b, pb);
                scope.spawn(move || {
                    co.submit(
                        compat,
                        Job::SweepPoint { spec: spec.clone(), point, kernel: KernelKind::Block },
                    )
                    .unwrap()
                })
            };
            while co.queued() < 1 {
                std::thread::yield_now();
            }
            gate.resume();
            (a.join().unwrap(), b.join().unwrap())
        });
        let opts = SweepOptions { threads: 1, ..SweepOptions::default() };
        let ra = run_grid_point(&spec_a, &pa, &opts).unwrap();
        let rb = run_grid_point(&spec_b, &pb, &opts).unwrap();
        assert_eq!(body_a, sweep_json(&spec_a, &[ra], &[true], KernelKind::Block));
        assert_eq!(body_b, sweep_json(&spec_b, &[rb], &[true], KernelKind::Block));
        assert_eq!(co.groups(), 1);
        assert_eq!(co.batched(), 2);
        assert_eq!(stats.campaigns.get(), 2);
    }
}
