//! `smart` — CLI for the SMART in-SRAM MAC reproduction.
//!
//! Subcommands map 1:1 onto the paper's experiments; see DESIGN.md §5.
//!
//! ```text
//! smart info
//! smart mac 13 7 --variant smart [--native]
//! smart mc --variant aid --n-mc 1000 [--a 15 --b 15 | --full-sweep]
//! smart table1 [--n-mc 300]
//! smart run configs/fig8.toml
//! smart sweep configs/dse.toml --shards 4 --threads 2 [--resume]
//! smart infer configs/nn.toml --trials 64 --variant smart [--json]
//! smart serve --addr 127.0.0.1:7878 --workers 4 [--self-test]
//! smart lint [paths…] [--json --out DIR]
//! smart profile target/mc/trace.jsonl --out target/mc
//! ```
//!
//! Every campaign-running subcommand accepts `--trace FILE` (or the
//! `SMART_TRACE` env var) to append a JSONL span/counter trace; tracing
//! is observability-only and provably inert — canonical artifacts are
//! byte-identical with it on or off (DESIGN.md §15).

use std::path::PathBuf;
use std::process::ExitCode;

use anyhow::Result;

use smart_insram::coordinator::{run_campaign, run_campaign_traced, Backend, CampaignSpec, Workload};
use smart_insram::dse::{run_sweep, SweepOptions, SweepSpec};
use smart_insram::energy::{nominal_cost, EnergyModel};
use smart_insram::mac::{KernelKind, Variant};
use smart_insram::montecarlo::Corner;
use smart_insram::obs::Tracer;
use smart_insram::params::Params;
use smart_insram::report;
use smart_insram::runtime::default_artifact_dir;
use smart_insram::util::cli::Args;

const USAGE: &str = "\
smart — SMART in-SRAM MAC accelerator campaign coordinator

USAGE:
  smart [--artifacts DIR] [--native] <command> [args]

COMMANDS:
  info                         platform + artifact manifest + PJRT smoke test
  mac <a> <b> [--variant V]    one 4x4-bit MAC through the full stack
  mc [--variant V] [--n-mc N] [--a A --b B | --full-sweep]
     [--seed S] [--shards K] [--threads T] [--batch N] [--block N]
     [--corner tt|ff|ss] [--kernel scalar|block|fast] [--json] [--out DIR]
                               Monte-Carlo campaign (paper Fig. 8/9);
                               aggregates are bit-identical for any
                               --shards/--threads/--block choice within a
                               fixed --kernel (the fast surrogate tier is
                               tolerance-bounded, DESIGN.md §13); --json
                               writes the canonical mc.json artifact
                               (identity fields only — the same bytes
                               `smart serve` answers POST /v1/mc with)
  table1 [--n-mc N]            regenerate Table 1 (all variants + lit rows)
  run <config.toml>            run campaigns from an experiment file
  sweep <dse.toml> [--shards K] [--threads T] [--block N] [--resume]
        [--kernel scalar|block|fast] [--out DIR]
                               design-space exploration: run every grid
                               point (variant x vdd x v_bulk x bits x
                               corner) through the sharded MC runner and
                               emit CSV/JSON + the energy-vs-sigma Pareto
                               front; artifacts are byte-identical for any
                               --shards/--threads/--block within a fixed
                               --kernel, and --resume skips points already
                               present in the CSV (the kernel is part of
                               each point's resume key)
  bench [--n-mc N] [--threads T] [--block N] [--json] [--smoke]
        [--out DIR]            native kernel throughput: the scalar
                               oracle, the lockstep block kernel, and the
                               fast surrogate tier on the fig8 campaign;
                               --json writes BENCH_native.json (schema:
                               backend, items_per_sec, n_items,
                               fast_items_per_sec, fast_speedup, the
                               fast tier's lane/fallback/table counters
                               and derived fast_fallback_rate /
                               fast_lanes_per_sec, plus variant/block/
                               threads provenance),
                               --smoke runs one sample for CI
  infer <nn.toml> [--trials N] [--variant V] [--shards K] [--threads T]
        [--block B] [--kernel scalar|block|fast] [--noise-off] [--json]
        [--out DIR] [--smoke]  noisy NN inference: run the model file's
                               quantized layers with every MAC executed
                               by the simulated noisy accelerator; report
                               ideal-vs-noisy top-1 accuracy, output
                               error, and energy per inference; --json
                               writes infer.csv/infer.json (byte-identical
                               for any --shards/--threads/--block; scalar
                               and block tiers also match each other);
                               --noise-off zeroes the mismatch sigmas
                               (the noisy pass must then equal the exact
                               integer pipeline); --scalar is a deprecated
                               alias for --kernel scalar;
                               --smoke caps trials at 8 for CI
  serve [--addr A] [--workers N] [--cache-cap BYTES] [--cache-dir DIR]
        [--batch-max N] [--self-test] [--kernel scalar|block|fast]
        [--smoke] [--json] [--out DIR]
                               long-lived campaign-result service:
                               POST /v1/mc, /v1/sweep/point, /v1/infer
                               (JSON bodies mirroring the TOML specs),
                               GET /v1/health, /v1/stats; responses are
                               byte-identical to the CLI --json
                               artifacts, served through a spec-keyed
                               byte-budgeted LRU (--cache-cap bytes), an
                               optional disk tier (--cache-dir) that
                               survives restarts, a single-flight map
                               (concurrent identical misses cost one
                               campaign), and a coalescer that merges up
                               to --batch-max compatible infer/sweep
                               requests into one engine execution;
                               --self-test starts an ephemeral server,
                               hammers it with concurrent loopback
                               clients, and asserts byte-identity,
                               cache hit-rate, thundering-herd dedup,
                               batched-vs-solo byte-identity, and
                               kill/restart warm-start from disk
                               (--smoke shrinks it for CI, --json writes
                               SERVE_stats.json + BENCH_serve.json to
                               --out)
  lint [paths...] [--json] [--out DIR]
                               structure-aware determinism/robustness
                               static analysis (rules D1-D7 and L1-L5,
                               DESIGN.md §12, §16): lexes and parses the
                               Rust sources under rust/src (or the given
                               paths), builds the crate call graph, and
                               applies the token rules (D1-D7) plus the
                               structural rules — L1 lock-order cycles,
                               L2 atomic-counter hygiene, L3 parser-
                               tainted arithmetic, L4 wildcard arms on
                               repo-owned enums, L5 flag/config drift —
                               with inline `// lint:allow(Dn|Ln): reason`
                               pragmas and the configs/lint.toml
                               allowlist, prints the findings panel, and
                               exits nonzero on any unsuppressed
                               finding; --json writes the canonical
                               LINT_report.json and CALLGRAPH.json to
                               --out (the CI gate artifacts)
  profile <trace.jsonl> [--out DIR]
                               fold a JSONL trace (written via --trace
                               or SMART_TRACE) into PROFILE.json:
                               per-phase wall time, span stats, shard
                               balance, kernel lane/fallback mix, serve
                               cache-tier breakdown with p50/p95/p99
                               request latency, and the final metrics
                               snapshot (DESIGN.md §15)

OPTIONS:
  --help            print this usage text and exit
  --artifacts DIR   artifact directory (default: $SMART_ARTIFACTS or ./artifacts)
  --batch N         MAC evaluations per engine batch (mc; default: auto)
  --trace FILE      append a JSONL span/counter trace of the run (mc,
                    sweep, infer, bench, serve, run); the SMART_TRACE
                    env var names the same sink when the flag is absent.
                    Tracing is observability-only: canonical artifacts
                    are byte-identical with it on or off (DESIGN.md §15)
  --native          use the native Rust simulator instead of the AOT/PJRT path
  --variant V       smart | aid | imac | smart-on-imac (default: smart)
  --kernel K        scalar | block | fast (default: block) — simulation
                    tier; fast is the table/closed-form surrogate, bounded
                    by the DESIGN.md §13 tolerance contract
  --out DIR         artifact directory (sweep default: target/dse;
                    infer default: target/infer; mc default: target/mc;
                    bench and serve --self-test default: .)
";

/// Parse a positive tuning knob (`--shards`/`--threads`/`--block`/
/// `--workers`): absent means 0 = auto-select; an **explicit** 0 is
/// rejected here with a descriptive error. Before this boundary check,
/// `--workers 0` and friends sailed into the campaign stack and died on
/// an `assert!` deep in `coordinator::pool` (or deadlocked a pool with
/// nobody to drain it) instead of telling the user what to fix.
fn knob(args: &Args, name: &str) -> Result<usize> {
    let v: usize = args.opt_parse(name, 0usize).map_err(|e| anyhow::anyhow!(e))?;
    anyhow::ensure!(
        v > 0 || args.opt(name).is_none(),
        "--{name} must be >= 1 (omit the flag to auto-select)"
    );
    Ok(v)
}

/// Resolve the worker-thread knob: `--threads` is the documented flag,
/// `--workers` remains as an alias for existing scripts (shared by the
/// `mc`, `sweep`, and `infer` subcommands). Explicit zeros are rejected
/// by [`knob`].
fn threads_opt(args: &Args) -> Result<usize> {
    let w = knob(args, "workers")?;
    if args.opt("threads").is_none() {
        return Ok(w);
    }
    knob(args, "threads")
}

/// Resolve `--kernel {scalar|block|fast}` (shared by `mc`, `sweep`,
/// `infer`, and `serve --self-test`). Unknown tokens are rejected with
/// the kernel parser's descriptive error; absent means the block kernel.
fn kernel_opt(args: &Args) -> Result<KernelKind> {
    args.opt_parse("kernel", KernelKind::Block).map_err(|e| anyhow::anyhow!(e))
}

/// Resolve the trace sink shared by every campaign-running subcommand:
/// `--trace FILE` wins, else a non-empty `SMART_TRACE` env var names the
/// file, else the disabled tracer (every emission a no-op). The sink is
/// truncated and seeded with the schema `meta` record up front so a
/// failed run still leaves a parseable trace. Tracing is
/// observability-only — canonical artifacts are byte-identical with it
/// on or off (DESIGN.md §15).
fn tracer_for(args: &Args, cmd: &str) -> Result<Tracer> {
    let path = args
        .opt("trace")
        .map(str::to_string)
        .or_else(|| std::env::var("SMART_TRACE").ok().filter(|v| !v.is_empty()));
    match path {
        Some(p) => Tracer::to_file(std::path::Path::new(&p), cmd)
            .map_err(|e| anyhow::anyhow!("opening trace sink {p}: {e}")),
        None => Ok(Tracer::disabled()),
    }
}

fn main() -> ExitCode {
    match run() {
        Ok(()) => ExitCode::SUCCESS,
        Err(e) => {
            eprintln!("error: {e:#}");
            ExitCode::FAILURE
        }
    }
}

fn run() -> Result<()> {
    let args = Args::parse(
        std::env::args().skip(1),
        &[
            "native", "full-sweep", "help", "resume", "json", "smoke", "scalar", "noise-off",
            "self-test",
        ],
    )
    .map_err(|e| anyhow::anyhow!(e))?;
    let cmd = match args.positional(0) {
        Some(cmd) if !args.flag("help") => cmd,
        _ => {
            print!("{USAGE}");
            return Ok(());
        }
    };
    let params = Params::default();
    let backend = if args.flag("native") { Backend::Native } else { Backend::Xla };
    let art: PathBuf = args
        .opt("artifacts")
        .map(PathBuf::from)
        .unwrap_or_else(default_artifact_dir);
    let variant: Variant = args
        .opt_parse("variant", Variant::Smart)
        .map_err(|e| anyhow::anyhow!(e))?;

    match cmd {
        "info" => cmd_info(&params, &art),
        "mac" => {
            let a: u8 = args
                .positional(1)
                .ok_or_else(|| anyhow::anyhow!("usage: smart mac <a> <b>"))?
                .parse()?;
            let b: u8 = args
                .positional(2)
                .ok_or_else(|| anyhow::anyhow!("usage: smart mac <a> <b>"))?
                .parse()?;
            cmd_mac(&params, &art, backend, variant, a, b)
        }
        "mc" => {
            let spec = CampaignSpec {
                variant,
                workload: if args.flag("full-sweep") {
                    Workload::FullSweep
                } else {
                    Workload::Fixed {
                        a: args.opt_parse("a", 15u8).map_err(|e| anyhow::anyhow!(e))?,
                        b: args.opt_parse("b", 15u8).map_err(|e| anyhow::anyhow!(e))?,
                    }
                },
                n_mc: args.opt_parse("n-mc", 1000u32).map_err(|e| anyhow::anyhow!(e))?,
                seed: args.opt_parse("seed", 2022u64).map_err(|e| anyhow::anyhow!(e))?,
                corner: args
                    .opt_parse("corner", Corner::Tt)
                    .map_err(|e| anyhow::anyhow!(e))?,
                workers: threads_opt(&args)?,
                batch: knob(&args, "batch")?,
                shards: knob(&args, "shards")?,
                block: knob(&args, "block")?,
                kernel: kernel_opt(&args)?,
            };
            let tracer = tracer_for(&args, "mc")?;
            let r = run_campaign_traced(&params, &spec, backend, Some(art), &tracer)?;
            print!(
                "{}",
                report::mc_panel(&format!("{} MC n={}", spec.variant.name(), spec.n_mc), &r)
            );
            println!(
                "throughput: {:.0} MAC evals/s over {} batches ({:.2?})",
                r.throughput(),
                r.batches,
                r.wall
            );
            if args.flag("json") {
                let out: PathBuf =
                    args.opt("out").map(PathBuf::from).unwrap_or_else(|| "target/mc".into());
                std::fs::create_dir_all(&out)
                    .map_err(|e| anyhow::anyhow!("creating {}: {e}", out.display()))?;
                let path = out.join("mc.json");
                std::fs::write(&path, report::mc_json(&spec, &r))
                    .map_err(|e| anyhow::anyhow!("writing {}: {e}", path.display()))?;
                println!("wrote {}", path.display());
            }
            Ok(())
        }
        "table1" => {
            let n_mc: u32 = args.opt_parse("n-mc", 300u32).map_err(|e| anyhow::anyhow!(e))?;
            cmd_table1(&params, &art, backend, n_mc)
        }
        "bench" => {
            let n_mc: u32 = args.opt_parse("n-mc", 1000u32).map_err(|e| anyhow::anyhow!(e))?;
            let out: PathBuf = args.opt("out").map(PathBuf::from).unwrap_or_else(|| ".".into());
            let threads = threads_opt(&args)?;
            let block = knob(&args, "block")?;
            cmd_bench(
                &params,
                variant,
                n_mc,
                threads,
                block,
                args.flag("smoke"),
                args.flag("json"),
                &out,
                &tracer_for(&args, "bench")?,
            )
        }
        "infer" => {
            let path = args.positional(1).ok_or_else(|| {
                anyhow::anyhow!(
                    "usage: smart infer <nn.toml> [--trials N --variant V --shards K \
                     --threads T --block B --kernel scalar|block|fast --noise-off \
                     --json --out DIR --smoke]"
                )
            })?;
            let spec = smart_insram::nn::ModelSpec::load(path)?;
            let trials = {
                let t = args.opt_parse("trials", 0u32).map_err(|e| anyhow::anyhow!(e))?;
                let t = if t > 0 { t } else { spec.trials };
                if args.flag("smoke") {
                    t.min(8)
                } else {
                    t
                }
            };
            // `--kernel` is authoritative; `--scalar` stays honored as a
            // deprecated alias for `--kernel scalar` (warned on stderr).
            let kernel = if args.opt("kernel").is_some() {
                kernel_opt(&args)?
            } else if args.flag("scalar") {
                eprintln!("warning: --scalar is deprecated; use --kernel scalar");
                KernelKind::Scalar
            } else {
                KernelKind::Block
            };
            let opts = smart_insram::nn::InferOptions {
                trials,
                shards: knob(&args, "shards")?,
                threads: threads_opt(&args)?,
                block: knob(&args, "block")?,
                variant,
                kernel,
                noise_off: args.flag("noise-off"),
                write_artifacts: args.flag("json"),
                out_dir: args
                    .opt("out")
                    .map(PathBuf::from)
                    .unwrap_or_else(|| smart_insram::nn::InferOptions::default().out_dir),
                tracer: tracer_for(&args, "infer")?,
            };
            let r = smart_insram::nn::run_infer(&params, &spec, &opts)?;
            print!("{}", report::infer_panel(&r));
            println!(
                "throughput: {:.0} MAC evals/s over {} trials ({:.2?})",
                r.throughput(),
                r.trials,
                r.wall
            );
            Ok(())
        }
        "sweep" => {
            let path = args.positional(1).ok_or_else(|| {
                anyhow::anyhow!(
                    "usage: smart sweep <dse.toml> [--shards K --threads T --block N \
                     --kernel scalar|block|fast --resume --out DIR]"
                )
            })?;
            let sweep = SweepSpec::load(path)?;
            let opts = SweepOptions {
                shards: knob(&args, "shards")?,
                threads: threads_opt(&args)?,
                block: knob(&args, "block")?,
                kernel: kernel_opt(&args)?,
                resume: args.flag("resume"),
                out_dir: args
                    .opt("out")
                    .map(PathBuf::from)
                    .unwrap_or_else(|| SweepOptions::default().out_dir),
                tracer: tracer_for(&args, "sweep")?,
            };
            let n_points = sweep.grid.len();
            println!("sweep '{}': {} grid points, n_mc = {}", sweep.name, n_points, sweep.n_mc);
            let r = run_sweep(&sweep, &opts)?;
            print!("{}", report::sweep_panel(&r));
            Ok(())
        }
        "serve" => cmd_serve(&params, &args),
        "lint" => cmd_lint(&args),
        "profile" => {
            let path = args.positional(1).ok_or_else(|| {
                anyhow::anyhow!("usage: smart profile <trace.jsonl> [--out DIR]")
            })?;
            cmd_profile(path, &args)
        }
        "run" => {
            let path = args
                .positional(1)
                .ok_or_else(|| anyhow::anyhow!("usage: smart run <config.toml>"))?;
            let cfg = smart_insram::config::ExperimentConfig::load(path)?;
            println!("experiment: {}", cfg.name);
            let tracer = tracer_for(&args, "run")?;
            for (i, spec) in cfg.campaigns.iter().enumerate() {
                let r = run_campaign_traced(&cfg.params, spec, backend, Some(art.clone()), &tracer)?;
                print!(
                    "{}",
                    report::mc_panel(&format!("campaign #{i} — {}", spec.variant.name()), &r)
                );
            }
            Ok(())
        }
        other => anyhow::bail!("unknown command '{other}'\n{USAGE}"),
    }
}

fn cmd_info(params: &Params, art: &PathBuf) -> Result<()> {
    let mut rt = smart_insram::runtime::XlaRuntime::open(art)?;
    println!("platform: {}", rt.platform());
    println!("artifact dir: {}", art.display());
    let m = rt.manifest().clone();
    println!("mac batches: {:?}", m.mac_batches);
    println!("trace batches: {:?} ({} points)", m.trace_batches, m.trace_points);
    println!("n_steps: {}", m.n_steps);
    if let Some(p) = &m.params {
        println!(
            "card: VTH0={} V, gamma={} sqrt(V), C_BLB={:e} F",
            p.device.vth0, p.device.gamma, p.circuit.c_blb
        );
        anyhow::ensure!(
            *p == *params,
            "artifacts/params.json drifted from the built-in card — re-run `make artifacts`"
        );
    }
    let exe = rt.mac_executable(1)?;
    let mut b = smart_insram::runtime::MacBatch::nominal(
        1,
        params.circuit.v_bulk_smart as f32,
        1.0,
        params.circuit.t_sample as f32,
    );
    b.set_row(0, 15, 15, [0.0; 4], [0.0; 4]);
    let out = exe.run(&b)?;
    println!("PJRT smoke 15x15 (SMART): v_mult = {:.1} mV", out.v_mult[0] * 1e3);
    Ok(())
}

fn cmd_mac(
    params: &Params,
    art: &PathBuf,
    backend: Backend,
    variant: Variant,
    a: u8,
    b: u8,
) -> Result<()> {
    let spec = CampaignSpec {
        variant,
        workload: Workload::Fixed { a, b },
        n_mc: 1,
        seed: 0,
        corner: Corner::Tt,
        workers: 1,
        batch: 1,
        shards: 1,
        block: 0,
        kernel: KernelKind::Block,
    };
    let r = run_campaign(params, &spec, backend, Some(art.clone()))?;
    println!(
        "{a} x {b} on {}: v_mult = {:.2} mV (ideal {:.2} mV, full-scale {:.1} mV)",
        variant.name(),
        r.raw_vmult.mean() * 1e3,
        r.full_scale * (f64::from(a) / 15.0) * (f64::from(b) / 15.0) * 1e3,
        r.full_scale * 1e3,
    );
    Ok(())
}

/// `smart bench`: native kernel throughput on the paper's fig8 campaign —
/// the scalar per-item oracle, the lockstep block kernel, and the fast
/// surrogate tier. With `--json`, records the measurement as
/// `BENCH_native.json` (schema: `backend`, `items_per_sec`, `n_items`,
/// `fast_items_per_sec`, `fast_speedup`, plus `variant`/`block`/
/// `threads` provenance so the perf trajectory is comparable across
/// commits and hosts); `--smoke` runs a single sample for CI. The fast
/// tier gets one untimed pre-warm campaign so its one-time interpolation
/// table build (DESIGN.md §13) never pollutes the measurement.
///
/// With `--trace`, each kernel's measurement emits a `bench_kernel` span
/// under one `bench` root, and the JSON gains the fast tier's
/// [`smart_insram::mac::KernelCounters`] view: `fast_lanes`,
/// `fast_fallbacks`, `fast_table_builds`, the derived
/// `fast_fallback_rate`, and `fast_lanes_per_sec` (lane throughput at
/// the measured items/s). The counter keys are additive to the schema
/// and land in the JSON with or without tracing.
#[allow(clippy::too_many_arguments)]
fn cmd_bench(
    params: &Params,
    variant: Variant,
    n_mc: u32,
    threads: usize,
    block: usize,
    smoke: bool,
    json: bool,
    out: &std::path::Path,
    tracer: &Tracer,
) -> Result<()> {
    use smart_insram::bench::Runner;
    use smart_insram::coordinator::run_native_campaign_with;
    use smart_insram::mac::{BlockKernel, FastKernel, ScalarKernel, SimKernel};

    let mut spec = CampaignSpec::paper_fig8(variant);
    spec.n_mc = n_mc;
    spec.workers = threads;
    spec.block = block;
    // Provenance for the JSON: the resolved thread count and the lane
    // cap handed to the runner (its auto default; shards may still clamp
    // a block to the shard's own length) — enough to compare
    // measurements across runs and hosts.
    let threads_used = smart_insram::coordinator::resolve_threads(threads);
    let block_cap =
        if block > 0 { block } else { smart_insram::coordinator::DEFAULT_BLOCK_LEN };
    let n_items = u64::from(n_mc);
    let runner = if smoke { Runner { warmup: 0, samples: 1 } } else { Runner::default() };
    let mut root = tracer.span("bench");
    root.attr_u64("n_mc", u64::from(n_mc));
    root.attr_u64("samples", runner.samples as u64);
    let measure = |kernel: &dyn SimKernel| {
        let mut span = match root.id() {
            Some(id) => tracer.child("bench_kernel", id),
            None => tracer.span("bench_kernel"),
        };
        span.attr_str("kernel", kernel.name());
        let s = runner.bench(&format!("bench/native {} kernel (n_mc = {n_mc})", kernel.name()), || {
            // lint:allow(D4): timing closure cannot propagate errors; spec is pre-validated
            run_native_campaign_with(params, &spec, kernel).expect("campaign")
        });
        let ips = s.per_second(n_items);
        span.attr_u64("items_per_sec", ips as u64);
        tracer.finish(span);
        ips
    };
    let scalar_ips = measure(&ScalarKernel);
    let block_ips = measure(&BlockKernel);
    let speedup = block_ips / scalar_ips;
    // Pre-warm the fast tier outside the timer: `--smoke` runs zero
    // warmup samples, and the surrogate's one-time table build must not
    // be billed to its steady-state throughput. Counter deltas bracket
    // the pre-warm + measurement so the fallback rate reflects every
    // lane the tier actually ran here.
    let fast_before = SimKernel::counters(FastKernel::shared());
    // lint:allow(D4): pre-warm shares the timing closure's pre-validated spec
    run_native_campaign_with(params, &spec, FastKernel::shared()).expect("campaign");
    let fast_ips = measure(FastKernel::shared());
    let fast = SimKernel::counters(FastKernel::shared()).since(&fast_before);
    let fast_speedup = fast_ips / block_ips;
    // Items the fast tier executed under this bracket: the explicit
    // pre-warm plus every warmup/timed sample the runner took.
    let fast_items = n_items * (1 + runner.warmup as u64 + runner.samples as u64);
    let lanes_per_item =
        if fast_items > 0 { fast.lanes as f64 / fast_items as f64 } else { 0.0 };
    let fast_lanes_per_sec = fast_ips * lanes_per_item;
    let fast_fallback_rate =
        if fast.lanes > 0 { fast.fallbacks as f64 / fast.lanes as f64 } else { 0.0 };
    tracer.finish(root);
    println!("scalar oracle: {scalar_ips:>12.0} items/s");
    println!("block kernel:  {block_ips:>12.0} items/s  ({speedup:.2}x)");
    println!("fast kernel:   {fast_ips:>12.0} items/s  ({fast_speedup:.2}x vs block)");
    println!(
        "fast tier:     {fast_lanes_per_sec:>12.0} lanes/s, fallback rate {:.4} \
         ({} of {} lanes), {} table build(s)",
        fast_fallback_rate, fast.fallbacks, fast.lanes, fast.table_builds
    );

    if json {
        use smart_insram::util::json::{to_string_pretty, Value};
        use std::collections::BTreeMap;
        let mut m = BTreeMap::new();
        m.insert("backend".to_string(), Value::Str("native-block".to_string()));
        m.insert("items_per_sec".to_string(), Value::Num(block_ips));
        m.insert("n_items".to_string(), Value::Num(n_items as f64));
        m.insert("scalar_items_per_sec".to_string(), Value::Num(scalar_ips));
        m.insert("speedup".to_string(), Value::Num(speedup));
        m.insert("fast_items_per_sec".to_string(), Value::Num(fast_ips));
        m.insert("fast_speedup".to_string(), Value::Num(fast_speedup));
        m.insert("fast_lanes".to_string(), Value::Num(fast.lanes as f64));
        m.insert("fast_fallbacks".to_string(), Value::Num(fast.fallbacks as f64));
        m.insert("fast_table_builds".to_string(), Value::Num(fast.table_builds as f64));
        m.insert("fast_fallback_rate".to_string(), Value::Num(fast_fallback_rate));
        m.insert("fast_lanes_per_sec".to_string(), Value::Num(fast_lanes_per_sec));
        m.insert("variant".to_string(), Value::Str(variant.token().to_string()));
        m.insert("block".to_string(), Value::Num(block_cap as f64));
        m.insert("threads".to_string(), Value::Num(threads_used as f64));
        let mut text = to_string_pretty(&Value::Obj(m));
        text.push('\n');
        std::fs::create_dir_all(out)
            .map_err(|e| anyhow::anyhow!("creating {}: {e}", out.display()))?;
        let path = out.join("BENCH_native.json");
        std::fs::write(&path, text)
            .map_err(|e| anyhow::anyhow!("writing {}: {e}", path.display()))?;
        println!("wrote {}", path.display());
    }
    Ok(())
}

/// `smart serve`: start the campaign-result service, or (with
/// `--self-test`) run the loopback load generator against an ephemeral
/// instance and assert the full serving contract — byte-identity with
/// the CLI `--json` artifacts, cache hit-rate, thundering-herd dedup,
/// batched-vs-solo byte-identity, kill/restart warm-start from disk,
/// histogram NaN integrity. With `--json` the self-test writes the
/// server's final `/v1/stats` body to `--out`/SERVE_stats.json and the
/// benchmark record (throughput, p50/p95/p99 latency, hit/dedup/batch
/// counters) to `--out`/BENCH_serve.json (the CI smoke artifacts).
fn cmd_serve(params: &Params, args: &Args) -> Result<()> {
    use smart_insram::serve::{self_test, ServeOptions, Server};
    let workers = {
        let w = threads_opt(args)?;
        if w > 0 {
            w
        } else {
            ServeOptions::default().workers
        }
    };
    let cache_cap = {
        let c = knob(args, "cache-cap")?;
        if c > 0 {
            c
        } else {
            ServeOptions::default().cache_cap
        }
    };
    let batch_max = {
        let b = knob(args, "batch-max")?;
        if b > 0 {
            b
        } else {
            ServeOptions::default().batch_max
        }
    };
    let cache_dir = args.opt("cache-dir").map(PathBuf::from);
    let tracer = tracer_for(args, "serve")?;
    if args.flag("self-test") {
        let r = self_test(params, workers, args.flag("smoke"), kernel_opt(args)?, &tracer)?;
        println!(
            "serve self-test OK: {} requests, {} hits / {} misses \
             ({} clients x {} repeats x 3 endpoints, byte-identical to the CLI artifacts)",
            r.requests, r.hits, r.misses, r.clients, r.repeats
        );
        println!(
            "  herd: {} clients -> 1 campaign ({} deduped); batch: {} jobs -> {} group(s); \
             warm start: {} disk entries, 0 recomputed",
            r.herd_clients, r.deduped, r.batched, r.batch_groups, r.warm_entries
        );
        println!(
            "  hit-phase: {:.0} req/s, latency p50 {} us / p95 {} us / p99 {} us",
            r.throughput_rps, r.p50_us, r.p95_us, r.p99_us
        );
        if args.flag("json") {
            let out: PathBuf = args.opt("out").map(PathBuf::from).unwrap_or_else(|| ".".into());
            std::fs::create_dir_all(&out)
                .map_err(|e| anyhow::anyhow!("creating {}: {e}", out.display()))?;
            for (name, text) in
                [("SERVE_stats.json", &r.stats_json), ("BENCH_serve.json", &r.bench_json)]
            {
                let path = out.join(name);
                std::fs::write(&path, text)
                    .map_err(|e| anyhow::anyhow!("writing {}: {e}", path.display()))?;
                println!("wrote {}", path.display());
            }
        }
        return Ok(());
    }
    let opts = ServeOptions {
        addr: args.opt("addr").unwrap_or("127.0.0.1:7878").to_string(),
        workers,
        cache_cap,
        cache_dir,
        batch_max,
        tracer,
    };
    let mut server = Server::start(*params, &opts)?;
    println!(
        "smart serve listening on {} ({} workers, cache budget {} bytes, disk tier {}, \
         batch window {})",
        server.addr(),
        opts.workers,
        opts.cache_cap,
        match &opts.cache_dir {
            Some(d) => d.display().to_string(),
            None => "off".to_string(),
        },
        opts.batch_max
    );
    println!(
        "endpoints: POST /v1/mc /v1/sweep/point /v1/infer ; \
         GET /v1/health /v1/stats /v1/metrics"
    );
    server.join();
    Ok(())
}

/// `smart profile`: fold a JSONL trace (written by `--trace` /
/// `SMART_TRACE`) into the `PROFILE.json` artifact — per-phase wall
/// time, span stats, shard balance, kernel lane/fallback mix, the serve
/// cache-tier breakdown with request-latency percentiles, and the last
/// metrics snapshot (DESIGN.md §15). The profile is derived purely from
/// the trace text, so the same trace always folds to the same bytes.
fn cmd_profile(path: &str, args: &Args) -> Result<()> {
    let text = std::fs::read_to_string(path)
        .map_err(|e| anyhow::anyhow!("reading {path}: {e}"))?;
    let profile = smart_insram::obs::profile_trace(&text)
        .map_err(|e| anyhow::anyhow!("profiling {path}: {e}"))?;
    let mut body = smart_insram::util::json::to_string_pretty(&profile);
    body.push('\n');
    let out: PathBuf = args.opt("out").map(PathBuf::from).unwrap_or_else(|| ".".into());
    std::fs::create_dir_all(&out)
        .map_err(|e| anyhow::anyhow!("creating {}: {e}", out.display()))?;
    let dest = out.join("PROFILE.json");
    std::fs::write(&dest, &body)
        .map_err(|e| anyhow::anyhow!("writing {}: {e}", dest.display()))?;
    print!("{body}");
    println!("wrote {}", dest.display());
    Ok(())
}

/// `smart lint`: run the structure-aware determinism/robustness
/// analyzer (DESIGN.md §12, §16) over `rust/src` (or explicit paths),
/// print the findings panel, optionally write the canonical
/// `LINT_report.json` + `CALLGRAPH.json`, and exit nonzero on any
/// unsuppressed finding — the CI gate contract.
fn cmd_lint(args: &Args) -> Result<()> {
    use smart_insram::lint;
    let cfg = lint::LintConfig::load(std::path::Path::new("configs/lint.toml"))?;
    let paths: Vec<PathBuf> =
        args.positionals().iter().skip(1).map(PathBuf::from).collect();
    let analysis = lint::analyze(std::path::Path::new("."), &paths, &cfg)?;
    let r = &analysis.report;
    print!("{}", report::lint_panel(r));
    if args.flag("json") {
        let out: PathBuf = args.opt("out").map(PathBuf::from).unwrap_or_else(|| ".".into());
        std::fs::create_dir_all(&out)
            .map_err(|e| anyhow::anyhow!("creating {}: {e}", out.display()))?;
        let path = out.join("LINT_report.json");
        std::fs::write(&path, r.to_json())
            .map_err(|e| anyhow::anyhow!("writing {}: {e}", path.display()))?;
        println!("wrote {}", path.display());
        let cg = out.join("CALLGRAPH.json");
        std::fs::write(&cg, analysis.graph.to_json())
            .map_err(|e| anyhow::anyhow!("writing {}: {e}", cg.display()))?;
        println!("wrote {}", cg.display());
    }
    let open = r.unsuppressed_count();
    anyhow::ensure!(open == 0, "{open} unsuppressed lint finding(s)");
    Ok(())
}

fn cmd_table1(params: &Params, art: &PathBuf, backend: Backend, n_mc: u32) -> Result<()> {
    let model = EnergyModel::default();
    let mut sigmas = Vec::new();
    for v in [Variant::Smart, Variant::Aid, Variant::Imac] {
        let spec = CampaignSpec {
            variant: v,
            workload: Workload::FullSweep,
            n_mc,
            seed: 2022,
            corner: Corner::Tt,
            workers: 0,
            batch: 0,
            shards: 0,
            block: 0,
            kernel: KernelKind::Block,
        };
        let r = run_campaign(params, &spec, backend, Some(art.clone()))?;
        sigmas.push((v, r.accuracy.rms_norm));
    }
    println!("{}", report::build_table1(params, &sigmas, &model));
    for (v, _) in &sigmas {
        let c = nominal_cost(params, *v, &model);
        println!(
            "{}: {:.3} pJ, {:.0} MHz, cycle {:.2} ns",
            v.name(),
            c.energy * 1e12,
            c.frequency / 1e6,
            c.t_cycle * 1e9
        );
    }
    Ok(())
}
