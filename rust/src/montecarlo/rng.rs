//! SplitMix64 PRNG + Box-Muller normals. Self-contained and seeded so every
//! campaign is bit-reproducible from its config (no external RNG crate).

/// SplitMix64: tiny, fast, passes BigCrush when used as a stream.
#[derive(Debug, Clone)]
pub struct SplitMix64 {
    state: u64,
    /// Cached second Box-Muller deviate.
    spare: Option<f64>,
}

impl SplitMix64 {
    /// Stream seeded at `seed`.
    pub fn new(seed: u64) -> Self {
        Self { state: seed, spare: None }
    }

    /// Derive an independent stream (for per-worker RNGs).
    pub fn split(&mut self, stream: u64) -> Self {
        Self::new(self.next_u64() ^ stream.wrapping_mul(0x9E37_79B9_7F4A_7C15))
    }

    /// Counter-derived independent stream: the state depends only on
    /// `(seed, stream)`, never on draw order, so item-indexed streams are
    /// identical under any shard partition or thread schedule — the basis
    /// of the coordinator's shard-invariant campaigns.
    pub fn for_stream(seed: u64, stream: u64) -> Self {
        // One splitmix avalanche over the mixed pair decorrelates
        // low-entropy (seed, k) inputs (sequential k especially).
        let salted = seed ^ stream.wrapping_mul(0x9E37_79B9_7F4A_7C15).rotate_left(17);
        let state = Self::new(salted).next_u64();
        Self::new(state)
    }

    /// Next 64 uniform bits (the SplitMix64 avalanche).
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }

    /// Uniform in [0, 1).
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 / (1u64 << 53) as f64
    }

    /// Standard normal via Box-Muller (polar-free form; caches the pair).
    pub fn next_normal(&mut self) -> f64 {
        if let Some(z) = self.spare.take() {
            return z;
        }
        // Guard u1 > 0 so ln() is finite.
        let u1 = loop {
            let u = self.next_f64();
            if u > 1e-300 {
                break u;
            }
        };
        let u2 = self.next_f64();
        let r = (-2.0 * u1.ln()).sqrt();
        let (s, c) = (std::f64::consts::TAU * u2).sin_cos();
        self.spare = Some(r * s);
        r * c
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_from_seed() {
        let mut a = SplitMix64::new(42);
        let mut b = SplitMix64::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = SplitMix64::new(1);
        let mut b = SplitMix64::new(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn uniform_in_unit_interval() {
        let mut r = SplitMix64::new(7);
        let mut sum = 0.0;
        for _ in 0..10_000 {
            let x = r.next_f64();
            assert!((0.0..1.0).contains(&x));
            sum += x;
        }
        assert!((sum / 10_000.0 - 0.5).abs() < 0.02);
    }

    #[test]
    fn normal_moments() {
        let mut r = SplitMix64::new(123);
        let n = 100_000;
        let (mut m, mut m2) = (0.0, 0.0);
        for _ in 0..n {
            let z = r.next_normal();
            m += z;
            m2 += z * z;
        }
        let mean = m / n as f64;
        let var = m2 / n as f64 - mean * mean;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.03, "var {var}");
    }

    #[test]
    fn for_stream_is_order_free_and_decorrelated() {
        // identical (seed, stream) -> identical stream, however many other
        // streams were derived in between
        let mut a = SplitMix64::for_stream(2022, 5);
        let _ = SplitMix64::for_stream(2022, 0).next_u64();
        let mut b = SplitMix64::for_stream(2022, 5);
        for _ in 0..16 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
        // neighbouring streams differ immediately
        assert_ne!(
            SplitMix64::for_stream(2022, 6).next_u64(),
            SplitMix64::for_stream(2022, 5).next_u64()
        );
        assert_ne!(
            SplitMix64::for_stream(2023, 5).next_u64(),
            SplitMix64::for_stream(2022, 5).next_u64()
        );
        // sequential streams look uniform, not structured
        let mean = (0..4096)
            .map(|k| SplitMix64::for_stream(9, k).next_f64())
            .sum::<f64>()
            / 4096.0;
        assert!((mean - 0.5).abs() < 0.02, "stream-0th-draw mean {mean}");
    }

    #[test]
    fn split_streams_are_independent_of_parent_order() {
        let mut base = SplitMix64::new(9);
        let mut s1 = base.split(1);
        let x = s1.next_u64();
        let mut base2 = SplitMix64::new(9);
        let mut s1b = base2.split(1);
        assert_eq!(x, s1b.next_u64());
    }
}
