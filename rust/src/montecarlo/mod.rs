//! Monte-Carlo process/mismatch substrate: seeded RNG, Pelgrom-style
//! mismatch sampling, and process-corner generation — the stand-in for the
//! foundry statistical models behind the paper's 1000-point MC (§IV).

mod rng;
mod sampler;

pub use rng::SplitMix64;
pub use sampler::{Corner, McSample, MismatchSampler};
