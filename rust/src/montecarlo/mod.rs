//! Monte-Carlo process/mismatch substrate: seeded RNG, Pelgrom-style
//! mismatch sampling, and process-corner generation — the stand-in for the
//! foundry statistical models behind the paper's 1000-point MC (§IV).
//!
//! The reproducibility keystone is [`SplitMix64::for_stream`] /
//! [`MismatchSampler::sample_item`]: deviates for work item `k` are a
//! pure function of `(seed, corner, k)`, never of draw order, which is
//! what lets the coordinator re-shard campaigns freely without moving a
//! single bit of the aggregates (DESIGN.md §4). The block-execution path
//! consumes the same streams through
//! [`MismatchSampler::fill_block`], which fills lane-major SoA buffers
//! with the identical per-item deviates (DESIGN.md §9).

mod rng;
mod sampler;

pub use rng::SplitMix64;
pub use sampler::{Corner, McSample, MismatchSampler};
