//! Pelgrom mismatch + process-corner sampling for the MC campaigns.

use super::rng::SplitMix64;

/// Per-word mismatch deviates: one (dVTH, dbeta/beta) pair per cell.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct McSample {
    /// Per-cell threshold-voltage offsets (V), MSB first.
    pub dvth: [f64; 4],
    /// Per-cell relative transconductance offsets, MSB first.
    pub dbeta: [f64; 4],
}

impl McSample {
    /// The mismatch-free nominal device set.
    pub fn nominal() -> Self {
        Self { dvth: [0.0; 4], dbeta: [0.0; 4] }
    }
}

/// Global process corner: a correlated shift applied on top of the local
/// (Pelgrom) mismatch. TT is centered; FS/SF skew VTH one way and beta the
/// other, as slow/fast corners do.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Corner {
    /// Typical-typical (centered).
    Tt,
    /// Fast-fast (lower VTH, higher beta).
    Ff,
    /// Slow-slow (higher VTH, lower beta).
    Ss,
}

impl std::str::FromStr for Corner {
    type Err = String;

    fn from_str(s: &str) -> Result<Self, Self::Err> {
        match s.to_ascii_lowercase().as_str() {
            "tt" => Ok(Self::Tt),
            "ff" => Ok(Self::Ff),
            "ss" => Ok(Self::Ss),
            other => Err(format!("unknown corner '{other}' (tt|ff|ss)")),
        }
    }
}

impl Corner {
    /// Config-file token (`tt`/`ff`/`ss`) — round-trips through FromStr.
    pub fn name(self) -> &'static str {
        match self {
            Self::Tt => "tt",
            Self::Ff => "ff",
            Self::Ss => "ss",
        }
    }

    /// (dVTH, dbeta) global shifts for the corner.
    pub fn shifts(self) -> (f64, f64) {
        match self {
            Self::Tt => (0.0, 0.0),
            Self::Ff => (-15e-3, 0.05),
            Self::Ss => (15e-3, -0.05),
        }
    }
}

/// Draws per-cell mismatch deviates: local Pelgrom N(0, sigma) plus the
/// corner's correlated shift.
#[derive(Debug, Clone)]
pub struct MismatchSampler {
    rng: SplitMix64,
    seed: u64,
    /// Local sigma(VTH) in volts (Pelgrom).
    pub sigma_vth: f64,
    /// Local relative sigma(beta).
    pub sigma_beta: f64,
    /// Global corner shift applied on top of the local mismatch.
    pub corner: Corner,
}

impl MismatchSampler {
    /// Sampler at the TT corner with the given local sigmas.
    pub fn new(seed: u64, sigma_vth: f64, sigma_beta: f64) -> Self {
        Self { rng: SplitMix64::new(seed), seed, sigma_vth, sigma_beta, corner: Corner::Tt }
    }

    /// Rebias to a process corner (builder style).
    pub fn with_corner(mut self, corner: Corner) -> Self {
        self.corner = corner;
        self
    }

    /// Draw one word's deviates.
    pub fn sample(&mut self) -> McSample {
        let (cv, cb) = self.corner.shifts();
        let mut s = McSample::nominal();
        for i in 0..4 {
            s.dvth[i] = cv + self.sigma_vth * self.rng.next_normal();
            s.dbeta[i] = cb + self.sigma_beta * self.rng.next_normal();
        }
        s
    }

    /// Draw a batch of `n` words.
    pub fn sample_batch(&mut self, n: usize) -> Vec<McSample> {
        (0..n).map(|_| self.sample()).collect()
    }

    /// Deviates for global work item `item`, independent of draw order:
    /// each item gets its own counter-derived stream
    /// ([`SplitMix64::for_stream`]), so the deviates are a pure function
    /// of `(seed, corner, item)`. This is what makes sharded campaigns
    /// bit-identical under any shard count or thread schedule.
    pub fn sample_item(&self, item: u64) -> McSample {
        let mut rng = SplitMix64::for_stream(self.seed, item);
        let (cv, cb) = self.corner.shifts();
        let mut s = McSample::nominal();
        for i in 0..4 {
            s.dvth[i] = cv + self.sigma_vth * rng.next_normal();
            s.dbeta[i] = cb + self.sigma_beta * rng.next_normal();
        }
        s
    }

    /// Fill lane-major SoA deviate buffers for the contiguous items
    /// `first_item .. first_item + n` where `n = dvth.len() / 4` — the
    /// block path's sampler (DESIGN.md §9). Lane `i` receives exactly
    /// [`Self::sample_item`]`(first_item + i)` quantized to `f32`, the
    /// same rounding the batch packer applies, so the block and batch
    /// paths consume bit-identical deviates for every item no matter how
    /// the item space is cut into blocks or shards.
    pub fn fill_block(&self, first_item: u64, dvth: &mut [f32], dbeta: &mut [f32]) {
        assert_eq!(dvth.len(), dbeta.len(), "deviate buffers must agree");
        assert_eq!(dvth.len() % 4, 0, "deviate buffers are (lane, 4)");
        let n = dvth.len() / 4;
        for i in 0..n {
            let s = self.sample_item(first_item + i as u64);
            for k in 0..4 {
                dvth[i * 4 + k] = s.dvth[k] as f32;
                dbeta[i * 4 + k] = s.dbeta[k] as f32;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn reproducible_from_seed() {
        let a = MismatchSampler::new(5, 8e-3, 0.02).sample_batch(16);
        let b = MismatchSampler::new(5, 8e-3, 0.02).sample_batch(16);
        assert_eq!(a, b);
    }

    #[test]
    fn moments_match_sigmas() {
        let mut s = MismatchSampler::new(11, 8e-3, 0.02);
        let batch = s.sample_batch(20_000);
        let vals: Vec<f64> = batch.iter().flat_map(|m| m.dvth).collect();
        let n = vals.len() as f64;
        let mean = vals.iter().sum::<f64>() / n;
        let var = vals.iter().map(|v| (v - mean).powi(2)).sum::<f64>() / n;
        assert!(mean.abs() < 3e-4, "mean {mean}");
        assert!((var.sqrt() - 8e-3).abs() < 3e-4, "sigma {}", var.sqrt());
    }

    #[test]
    fn corners_shift_the_mean() {
        let ss = MismatchSampler::new(3, 1e-6, 1e-6).with_corner(Corner::Ss).sample();
        let ff = MismatchSampler::new(3, 1e-6, 1e-6).with_corner(Corner::Ff).sample();
        assert!(ss.dvth[0] > 10e-3);
        assert!(ff.dvth[0] < -10e-3);
        assert!(ss.dbeta[0] < 0.0 && ff.dbeta[0] > 0.0);
    }

    #[test]
    fn zero_sigma_collapses_to_corner() {
        let s = MismatchSampler::new(1, 0.0, 0.0).sample();
        assert_eq!(s, McSample::nominal());
    }

    #[test]
    fn item_draws_are_order_free() {
        let s = MismatchSampler::new(2022, 8e-3, 0.02);
        // any access order yields the same per-item deviates
        let forward: Vec<McSample> = (0..32).map(|k| s.sample_item(k)).collect();
        let backward: Vec<McSample> = (0..32).rev().map(|k| s.sample_item(k)).collect();
        for (k, m) in forward.iter().enumerate() {
            assert_eq!(*m, backward[31 - k], "item {k}");
        }
        assert_ne!(forward[0], forward[1]);
        // corner shift applies to item draws too
        let ss = MismatchSampler::new(1, 1e-9, 1e-9).with_corner(Corner::Ss);
        assert!(ss.sample_item(0).dvth[0] > 10e-3);
    }

    #[test]
    fn item_draw_moments_match_sigmas() {
        let s = MismatchSampler::new(11, 8e-3, 0.02);
        let vals: Vec<f64> = (0..20_000u64).flat_map(|k| s.sample_item(k).dvth).collect();
        let n = vals.len() as f64;
        let mean = vals.iter().sum::<f64>() / n;
        let var = vals.iter().map(|v| (v - mean).powi(2)).sum::<f64>() / n;
        assert!(mean.abs() < 3e-4, "mean {mean}");
        assert!((var.sqrt() - 8e-3).abs() < 3e-4, "sigma {}", var.sqrt());
    }

    #[test]
    fn fill_block_matches_item_draws() {
        let s = MismatchSampler::new(2022, 8e-3, 0.02).with_corner(Corner::Ff);
        let mut dvth = vec![0.0f32; 12 * 4];
        let mut dbeta = vec![0.0f32; 12 * 4];
        s.fill_block(40, &mut dvth, &mut dbeta);
        for i in 0..12 {
            let m = s.sample_item(40 + i as u64);
            for k in 0..4 {
                assert_eq!(dvth[i * 4 + k].to_bits(), (m.dvth[k] as f32).to_bits());
                assert_eq!(dbeta[i * 4 + k].to_bits(), (m.dbeta[k] as f32).to_bits());
            }
        }
        // block boundaries never change the per-item deviates
        let mut lo = vec![0.0f32; 5 * 4];
        let mut lo_b = vec![0.0f32; 5 * 4];
        s.fill_block(40, &mut lo, &mut lo_b);
        assert_eq!(&dvth[..20], &lo[..]);
    }

    #[test]
    fn cells_are_uncorrelated() {
        let mut s = MismatchSampler::new(77, 8e-3, 0.02);
        let batch = s.sample_batch(5_000);
        // covariance between cell 0 and cell 1 dvth should be ~0
        let n = batch.len() as f64;
        let m0 = batch.iter().map(|b| b.dvth[0]).sum::<f64>() / n;
        let m1 = batch.iter().map(|b| b.dvth[1]).sum::<f64>() / n;
        let cov = batch.iter().map(|b| (b.dvth[0] - m0) * (b.dvth[1] - m1)).sum::<f64>() / n;
        assert!(cov.abs() < 5e-6, "cov {cov}");
    }
}
