//! 65 nm stand-in model card + circuit constants.
//!
//! Single source of truth on the Rust side, kept in lock-step with
//! `python/compile/params.py`. `make artifacts` mirrors the Python values
//! into `artifacts/params.json`; [`Params::load_artifact_json`] plus the
//! `params_json_matches_builtin` integration test guarantee the two sides
//! never drift.

use crate::util::json::{self, Value};

/// 65 nm NMOS access-transistor card (`M2acc` in the paper's Fig. 1).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct DeviceCard {
    /// Cell supply voltage (V). Paper Table 1: 1.0 V for SMART/AID, 1.2 V for IMAC [9].
    pub vdd: f64,
    /// Zero-bias threshold voltage (V). Low-VT access device: the paper's WL
    /// margin starts at 300 mV unbiased, 175 mV under 0.6 V body bias.
    pub vth0: f64,
    /// Body-effect coefficient gamma (sqrt(V)) — Eq. 6. Calibrated so
    /// dVTH(V_bulk = 0.6 V) = -125 mV (paper Fig. 3).
    pub gamma: f64,
    /// 2*phi_F surface potential (V) — Eq. 6.
    pub phi2f: f64,
    /// Process transconductance mu_n * C_ox (A/V^2).
    pub mu_cox: f64,
    /// Gate aspect ratio W/L (195 nm / 65 nm).
    pub w_over_l: f64,
    /// Channel-length modulation lambda (1/V).
    pub lam: f64,
    /// Subthreshold slope factor n.
    pub n_sub: f64,
    /// Thermal voltage kT/q at 300 K (V).
    pub vt_thermal: f64,
    /// Relative conductance of the off (stored-0) leakage path.
    pub k_leak: f64,
}

impl Default for DeviceCard {
    fn default() -> Self {
        Self {
            vdd: 1.0,
            vth0: 0.30,
            gamma: 0.306,
            phi2f: 0.88,
            mu_cox: 180e-6,
            w_over_l: 3.0,
            lam: 0.08,
            n_sub: 1.5,
            vt_thermal: 0.026,
            k_leak: 1e-4,
        }
    }
}

impl DeviceCard {
    /// Transconductance factor beta = mu_n * C_ox * W/L (A/V^2).
    pub fn beta(&self) -> f64 {
        self.mu_cox * self.w_over_l
    }

    /// Eq. 6 threshold shift for a forward body bias of `v_bulk` volts
    /// (V_SB = -v_bulk; the sqrt argument is clamped at 0 — beyond that the
    /// bulk-source junction would forward-bias).
    pub fn delta_vth_body(&self, v_bulk: f64) -> f64 {
        let inner = (self.phi2f - v_bulk).max(0.0);
        self.gamma * (inner.sqrt() - self.phi2f.sqrt())
    }

    /// Effective threshold under body bias plus a mismatch offset.
    pub fn vth_effective(&self, v_bulk: f64, dvth: f64) -> f64 {
        self.vth0 + self.delta_vth_body(v_bulk) + dvth
    }

    fn from_value(v: &Value) -> anyhow::Result<Self> {
        let mut d = Self::default();
        let f = |key: &str, dst: &mut f64| -> anyhow::Result<()> {
            *dst = v
                .get(key)
                .and_then(Value::as_f64)
                .ok_or_else(|| anyhow::anyhow!("device.{key} missing"))?;
            Ok(())
        };
        f("vdd", &mut d.vdd)?;
        f("vth0", &mut d.vth0)?;
        f("gamma", &mut d.gamma)?;
        f("phi2f", &mut d.phi2f)?;
        f("mu_cox", &mut d.mu_cox)?;
        f("w_over_l", &mut d.w_over_l)?;
        f("lam", &mut d.lam)?;
        f("n_sub", &mut d.n_sub)?;
        f("vt_thermal", &mut d.vt_thermal)?;
        f("k_leak", &mut d.k_leak)?;
        Ok(d)
    }
}

/// Bitline / timing / DAC constants for the 4x4-bit MAC column.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct CircuitCard {
    /// BLB sampling capacitance (F).
    pub c_blb: f64,
    /// Top of the usable WL range (V) — paper §III: 700 mV.
    pub wl_max: f64,
    /// WL pulse width at the sampling instant (s); identical across variants
    /// per the paper's "same WL timing" comparison setup.
    pub t_sample: f64,
    /// Transient integration steps (must match the AOT-compiled kernel).
    pub n_steps: u32,
    /// Operand bit width N (4x4-bit MAC).
    pub n_bits: u32,
    /// SMART forward body bias (V) from the dual-VDD rail.
    pub v_bulk_smart: f64,
    /// Pelgrom-model sigma(VTH) for the MC stand-in (V).
    pub sigma_vth: f64,
    /// Relative sigma(beta).
    pub sigma_beta: f64,
}

impl Default for CircuitCard {
    fn default() -> Self {
        Self {
            c_blb: 30e-15,
            wl_max: 0.70,
            t_sample: 0.12e-9,
            n_steps: 256,
            n_bits: 4,
            v_bulk_smart: 0.6,
            sigma_vth: 8e-3,
            sigma_beta: 0.02,
        }
    }
}

impl CircuitCard {
    /// Number of DAC levels minus one: 2^N - 1 (15 for the 4-bit operand).
    pub fn full_code(&self) -> f64 {
        (1u32 << self.n_bits) as f64 - 1.0
    }

    fn from_value(v: &Value) -> anyhow::Result<Self> {
        let mut c = Self::default();
        let get = |key: &str| -> anyhow::Result<f64> {
            v.get(key)
                .and_then(Value::as_f64)
                .ok_or_else(|| anyhow::anyhow!("circuit.{key} missing"))
        };
        c.c_blb = get("c_blb")?;
        c.wl_max = get("wl_max")?;
        c.t_sample = get("t_sample")?;
        c.n_steps = count_u32("circuit.n_steps", get("n_steps")?)?;
        c.n_bits = count_u32("circuit.n_bits", get("n_bits")?)?;
        c.v_bulk_smart = get("v_bulk_smart")?;
        c.sigma_vth = get("sigma_vth")?;
        c.sigma_beta = get("sigma_beta")?;
        Ok(c)
    }
}

/// Checked conversion for spec-provided counts: rejects negatives,
/// fractions, and out-of-range values instead of silently truncating
/// through an `as` cast.
fn count_u32(key: &str, x: f64) -> anyhow::Result<u32> {
    anyhow::ensure!(
        x.is_finite() && x.fract() == 0.0 && x >= 0.0 && x <= f64::from(u32::MAX),
        "{key} = {x} is not a valid count (need an integer in 0..=u32::MAX)"
    );
    Ok(x as u32)
}

/// Complete model card (device + circuit).
#[derive(Debug, Clone, Copy, PartialEq, Default)]
pub struct Params {
    /// Access-transistor device card.
    pub device: DeviceCard,
    /// Bitline / timing / DAC circuit card.
    pub circuit: CircuitCard,
}

impl Params {
    /// Parse the card mirrored by `make artifacts` into `artifacts/params.json`.
    pub fn load_artifact_json(text: &str) -> anyhow::Result<Self> {
        let v = json::parse(text).map_err(|e| anyhow::anyhow!("{e}"))?;
        Ok(Self {
            device: DeviceCard::from_value(
                v.get("device").ok_or_else(|| anyhow::anyhow!("'device' missing"))?,
            )?,
            circuit: CircuitCard::from_value(
                v.get("circuit").ok_or_else(|| anyhow::anyhow!("'circuit' missing"))?,
            )?,
        })
    }

    /// Override card fields from a parsed config `Value` (TOML-lite tree);
    /// unknown keys error, missing keys keep their defaults.
    pub fn apply_overrides(&mut self, v: &Value) -> anyhow::Result<()> {
        let apply = |obj: &Value, setters: &mut [(&str, &mut f64)]| -> anyhow::Result<()> {
            if let Value::Obj(m) = obj {
                'keys: for (k, val) in m {
                    for (name, dst) in setters.iter_mut() {
                        if k == name {
                            **dst = val
                                .as_f64()
                                .ok_or_else(|| anyhow::anyhow!("{k} must be a number"))?;
                            continue 'keys;
                        }
                    }
                    anyhow::bail!("unknown param key '{k}'");
                }
            }
            Ok(())
        };
        if let Some(dev) = v.get("device") {
            let d = &mut self.device;
            apply(
                dev,
                &mut [
                    ("vdd", &mut d.vdd),
                    ("vth0", &mut d.vth0),
                    ("gamma", &mut d.gamma),
                    ("phi2f", &mut d.phi2f),
                    ("mu_cox", &mut d.mu_cox),
                    ("w_over_l", &mut d.w_over_l),
                    ("lam", &mut d.lam),
                    ("n_sub", &mut d.n_sub),
                    ("vt_thermal", &mut d.vt_thermal),
                    ("k_leak", &mut d.k_leak),
                ],
            )?;
        }
        if let Some(cir) = v.get("circuit") {
            let mut n_steps = self.circuit.n_steps as f64;
            let mut n_bits = self.circuit.n_bits as f64;
            let c = &mut self.circuit;
            apply(
                cir,
                &mut [
                    ("c_blb", &mut c.c_blb),
                    ("wl_max", &mut c.wl_max),
                    ("t_sample", &mut c.t_sample),
                    ("n_steps", &mut n_steps),
                    ("n_bits", &mut n_bits),
                    ("v_bulk_smart", &mut c.v_bulk_smart),
                    ("sigma_vth", &mut c.sigma_vth),
                    ("sigma_beta", &mut c.sigma_beta),
                ],
            )?;
            c.n_steps = count_u32("circuit.n_steps", n_steps)?;
            c.n_bits = count_u32("circuit.n_bits", n_bits)?;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn body_bias_shift_is_minus_125mv() {
        let d = DeviceCard::default();
        let shift = d.delta_vth_body(0.6);
        assert!(
            (-0.130..-0.120).contains(&shift),
            "dVTH(0.6 V) = {shift} V, expected ~-125 mV (Fig. 3)"
        );
    }

    #[test]
    fn body_bias_zero_is_noop() {
        let d = DeviceCard::default();
        assert_eq!(d.delta_vth_body(0.0), 0.0);
        assert_eq!(d.vth_effective(0.0, 0.0), d.vth0);
    }

    #[test]
    fn body_bias_monotone_decreasing() {
        let d = DeviceCard::default();
        let mut last = f64::INFINITY;
        for i in 0..=12 {
            let vth = d.vth_effective(i as f64 * 0.05, 0.0);
            assert!(vth < last, "VTH must decrease with forward body bias");
            last = vth;
        }
    }

    #[test]
    fn wl_margins_match_paper() {
        // [300, 700] mV unbiased -> [175, 700] mV at 0.6 V (paper §III).
        let d = DeviceCard::default();
        let c = CircuitCard::default();
        assert!((d.vth_effective(0.0, 0.0) - 0.300).abs() < 1e-3);
        assert!((d.vth_effective(c.v_bulk_smart, 0.0) - 0.175).abs() < 2e-3);
    }

    #[test]
    fn junction_clamp_beyond_phi2f() {
        let d = DeviceCard::default();
        let at_limit = d.delta_vth_body(d.phi2f);
        let beyond = d.delta_vth_body(d.phi2f + 0.3);
        assert_eq!(at_limit, beyond);
    }

    #[test]
    fn full_code_is_15() {
        assert_eq!(CircuitCard::default().full_code(), 15.0);
    }

    #[test]
    fn parses_python_style_json() {
        let text = r#"{
            "circuit": {"c_blb": 3e-14, "n_bits": 4, "n_steps": 256,
                        "sigma_beta": 0.02, "sigma_vth": 0.008,
                        "t_sample": 1.2e-10, "v_bulk_smart": 0.6, "wl_max": 0.7},
            "device": {"gamma": 0.306, "k_leak": 0.0001, "lam": 0.08,
                       "mu_cox": 0.00018, "n_sub": 1.5, "phi2f": 0.88,
                       "vdd": 1.0, "vt_thermal": 0.026, "vth0": 0.3,
                       "w_over_l": 3.0}
        }"#;
        let p = Params::load_artifact_json(text).unwrap();
        assert_eq!(p, Params::default());
    }

    #[test]
    fn load_rejects_missing_fields() {
        assert!(Params::load_artifact_json(r#"{"device": {}, "circuit": {}}"#).is_err());
        assert!(Params::load_artifact_json("{}").is_err());
    }

    #[test]
    fn overrides_apply_and_reject_unknown() {
        let mut p = Params::default();
        let v = crate::util::toml_lite::parse("[circuit]\nc_blb = 4.5e-14\n").unwrap();
        p.apply_overrides(&v).unwrap();
        assert_eq!(p.circuit.c_blb, 4.5e-14);
        let bad = crate::util::toml_lite::parse("[device]\nbogus = 1\n").unwrap();
        assert!(p.apply_overrides(&bad).is_err());
    }
}
