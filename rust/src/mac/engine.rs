//! Native analog MAC engine: the Rust twin of the AOT-compiled L2 model.
//!
//! Used as the cross-check oracle for the HLO path (integration tests
//! assert agreement), for single-shot/interactive runs, and for sweeps
//! whose shapes the fixed-batch artifacts do not cover.

use super::variant::VariantConfig;
use crate::circuit::BitlineInputs;
use crate::dac::WordlineDac;
use crate::montecarlo::McSample;
use crate::params::Params;
use crate::sram::{MacWord, WEIGHTS};

/// Outputs of one 4x4-bit analog MAC operation — mirrors the tuple the
/// AOT artifact returns: (v_mult, v_blb[4], energy, fault).
#[derive(Debug, Clone, Copy)]
pub struct MacResult {
    /// Binary-weighted discharge voltage — the paper's V_multiplication.
    pub v_mult: f64,
    /// Sampled per-cell BLB voltages, MSB first.
    pub v_blb: [f64; 4],
    /// Raw dynamic bitline energy sum(C * VDD * dV) in J (overheads are
    /// applied by [`crate::energy::EnergyModel`]).
    pub energy: f64,
    /// True when any conducting cell left saturation before sampling —
    /// the paper's "systematic fault" condition (§II-A).
    pub fault: bool,
}

/// The native engine: owns the model card and a variant configuration.
#[derive(Debug, Clone)]
pub struct NativeMacEngine {
    params: Params,
    cfg: VariantConfig,
    dac: WordlineDac,
}

impl NativeMacEngine {
    /// Engine for one variant configuration on one model card.
    pub fn new(params: Params, cfg: VariantConfig) -> Self {
        let dac = WordlineDac::new(cfg.dac_mode, &params.device, &params.circuit, cfg.v_bulk);
        Self { params, cfg, dac }
    }

    /// The model card the engine was built on.
    pub fn params(&self) -> &Params {
        &self.params
    }

    /// The resolved variant configuration.
    pub fn config(&self) -> &VariantConfig {
        &self.cfg
    }

    /// The calibrated word-line DAC.
    pub fn dac(&self) -> &WordlineDac {
        &self.dac
    }

    /// One MAC: `a` stored in the word, `b` DAC-coded on the WL, with the
    /// word's access transistors perturbed by `mc`.
    pub fn mac(&self, a: u8, b: u8, mc: &McSample) -> MacResult {
        let word = {
            let mut w = MacWord::with_mismatch(self.params.device, mc.dvth, mc.dbeta);
            w.store(a);
            w
        };
        self.mac_word(&word, b)
    }

    /// MAC against an already-instantiated word (array-resident operand).
    pub fn mac_word(&self, word: &MacWord, b: u8) -> MacResult {
        let p = &self.params;
        let v_wl = self.dac.v_wl(b);
        let bits = word.bits();
        let cells = word.cells();
        let devs = [cells[0].m2_acc, cells[1].m2_acc, cells[2].m2_acc, cells[3].m2_acc];
        let mk = |i: usize| BitlineInputs { v_wl, bit: bits[i], v_bulk: self.cfg.v_bulk };
        let inps = [mk(0), mk(1), mk(2), mk(3)];
        // 4-lane interleaved transient (hot path; bit-identical to the
        // per-cell scalar integration)
        let v_blb =
            crate::circuit::discharge_word(p, &devs, &inps, self.cfg.t_sample, p.circuit.n_steps);
        let mut fault = false;
        for i in 0..4 {
            // Saturation-exit check (Eq. 4 validity): conducting cell whose
            // BLB fell below its overdrive has entered triode.
            let vov = v_wl - devs[i].vth(self.cfg.v_bulk);
            if bits[i] && vov > 0.0 && v_blb[i] < vov {
                fault = true;
            }
        }

        let vdd = p.device.vdd;
        let v_mult: f64 = v_blb
            .iter()
            .zip(WEIGHTS)
            .map(|(&v, w)| (vdd - v) * w)
            // lint:allow(D2): fixed 4-lane weighted fold in array order — the modeled physics
            .sum();
        // lint:allow(D2): fixed 4-lane weighted fold in array order — the modeled physics
        let energy: f64 = v_blb.iter().map(|&v| p.circuit.c_blb * vdd * (vdd - v)).sum();
        MacResult { v_mult, v_blb, energy, fault }
    }

    /// Nominal full-scale output (a = b = 15, no mismatch) — the
    /// normalization for the accuracy metrics and Fig. 8/9 axes.
    pub fn full_scale(&self) -> f64 {
        self.mac(15, 15, &McSample::nominal()).v_mult
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mac::Variant;
    use crate::montecarlo::McSample;
    use crate::params::Params;

    fn engine(v: Variant) -> NativeMacEngine {
        let p = Params::default();
        NativeMacEngine::new(p, v.config(&p))
    }

    #[test]
    fn zero_operands_give_zero() {
        let e = engine(Variant::Smart);
        let nom = McSample::nominal();
        assert!(e.mac(0, 9, &nom).v_mult < 2e-3);
        assert!(e.mac(11, 0, &nom).v_mult < 2e-3);
        assert!(!e.mac(0, 0, &nom).fault);
    }

    #[test]
    fn output_monotone_in_operands() {
        let e = engine(Variant::Aid);
        let nom = McSample::nominal();
        let mut grid = [[0.0f64; 16]; 16];
        for a in 0..16u8 {
            for b in 0..16u8 {
                grid[a as usize][b as usize] = e.mac(a, b, &nom).v_mult;
            }
        }
        for a in 0..16 {
            for b in 1..16 {
                assert!(grid[a][b] >= grid[a][b - 1] - 1e-9);
            }
        }
        for b in 0..16 {
            for a in 1..16 {
                assert!(grid[a][b] >= grid[a - 1][b] - 1e-9);
            }
        }
    }

    #[test]
    fn stored_weighting_is_binary_under_sqrt_dac() {
        let e = engine(Variant::Aid);
        let nom = McSample::nominal();
        let fs = e.mac(15, 15, &nom).v_mult;
        for a in 1..16u8 {
            let v = e.mac(a, 15, &nom).v_mult;
            let want = fs * a as f64 / 15.0;
            assert!((v - want).abs() < 0.01 * fs, "a={a}: {v} vs {want}");
        }
    }

    #[test]
    fn smart_fullscale_exceeds_aid() {
        let fs_smart = engine(Variant::Smart).full_scale();
        let fs_aid = engine(Variant::Aid).full_scale();
        assert!(fs_smart > fs_aid * 1.3, "{fs_smart} vs {fs_aid}");
    }

    #[test]
    fn no_fault_at_design_timing() {
        for v in Variant::ALL {
            let e = engine(v);
            let nom = McSample::nominal();
            for b in 0..16u8 {
                assert!(!e.mac(15, b, &nom).fault, "{v:?} b={b}");
            }
        }
    }

    #[test]
    fn overlong_pulse_faults() {
        let p = Params::default();
        let mut cfg = Variant::Smart.config(&p);
        cfg.t_sample = 2e-9;
        let e = NativeMacEngine::new(p, cfg);
        assert!(e.mac(15, 15, &McSample::nominal()).fault);
    }

    #[test]
    fn energy_is_cv_dv_sum() {
        let e = engine(Variant::Smart);
        let r = e.mac(15, 15, &McSample::nominal());
        let p = e.params();
        let want: f64 = r
            .v_blb
            .iter()
            .map(|&v| p.circuit.c_blb * p.device.vdd * (p.device.vdd - v))
            .sum();
        assert!((r.energy - want).abs() < 1e-20);
    }

    #[test]
    fn mac_word_agrees_with_mac() {
        let e = engine(Variant::Smart);
        let mc = McSample { dvth: [2e-3, -1e-3, 0.5e-3, -3e-3], dbeta: [0.01, -0.02, 0.0, 0.005] };
        let direct = e.mac(0b1011, 7, &mc);
        let mut w = MacWord::with_mismatch(e.params().device, mc.dvth, mc.dbeta);
        w.store(0b1011);
        let via_word = e.mac_word(&w, 7);
        assert_eq!(direct.v_mult, via_word.v_mult);
    }
}
