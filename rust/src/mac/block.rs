//! Block execution: struct-of-arrays trial blocks and the [`SimKernel`]
//! trait the campaign layer drives them through (DESIGN.md §9).
//!
//! A [`TrialBlock`] packs many independent MAC trials (one lane per
//! Monte-Carlo item) into flat SoA buffers: per-lane operands and
//! deviates on the input side, per-lane `v_mult`/`v_blb`/`energy`/`fault`
//! on the output side. Blocks are allocated once per shard and refilled
//! in place, so the steady state of a campaign allocates nothing per
//! item. Three kernels execute a block:
//!
//! * [`ScalarKernel`] — the oracle: one [`NativeMacEngine::mac`] call per
//!   lane, numerically identical to the historical per-item path;
//! * [`BlockKernel`] — [`NativeMacEngine::mac_block`]: hoists the
//!   time-invariant device quantities once per lane and integrates every
//!   lane in lockstep through
//!   [`crate::circuit::discharge_block`];
//! * [`crate::mac::FastKernel`] — the surrogate tier (DESIGN.md §13):
//!   replaces the per-step Euler loop with a closed-form saturation
//!   endpoint plus per-configuration interpolation tables, accurate to a
//!   *documented* tolerance instead of bit-identity.
//!
//! The first two are bit-identical lane for lane (property-tested in
//! `tests/block_kernel.rs`): deviates enter both through the same `f32`
//! quantization the batch path uses, every per-lane recurrence is grouped
//! exactly as the scalar expression tree, and outputs round to `f32` at
//! the same point — so campaign aggregates and sweep artifacts do not
//! move by a bit when the block path takes over. The fast tier's
//! endpoint error against the oracle is bounded by
//! [`crate::mac::FAST_TOLERANCE`] (enforced in `tests/fast_kernel.rs`),
//! which is why the kernel choice is an *identity* field on campaign
//! specs rather than a performance knob.

use crate::device::Mosfet;
use crate::montecarlo::McSample;
use crate::sram::WEIGHTS;

use super::engine::NativeMacEngine;

/// Per-lane outputs of one executed block — the SoA twin of
/// [`crate::runtime::MacBatchOut`], in the same `f32` precision so the
/// aggregator sees identical numbers from either path.
#[derive(Debug, Clone, Default)]
pub struct MacResultBlock {
    /// Weighted discharge voltage per lane — the paper's V_multiplication.
    pub v_mult: Vec<f32>,
    /// Sampled BLB voltages, lane-major `(lane, 4)`, MSB first.
    pub v_blb: Vec<f32>,
    /// Raw dynamic bitline energy per lane (J).
    pub energy: Vec<f32>,
    /// Saturation-exit fault flags per lane (0/1).
    pub fault: Vec<f32>,
}

impl MacResultBlock {
    /// Number of lanes currently held.
    pub fn len(&self) -> usize {
        self.v_mult.len()
    }

    /// True when no lanes are held.
    pub fn is_empty(&self) -> bool {
        self.v_mult.is_empty()
    }

    /// Resize to `n` lanes with every output zeroed (capacity is kept, so
    /// repeated resets on a reused block allocate nothing).
    pub fn reset(&mut self, n: usize) {
        self.v_mult.clear();
        self.v_mult.resize(n, 0.0);
        self.v_blb.clear();
        self.v_blb.resize(n * 4, 0.0);
        self.energy.clear();
        self.energy.resize(n, 0.0);
        self.fault.clear();
        self.fault.resize(n, 0.0);
    }
}

/// A struct-of-arrays block of independent MAC trials.
///
/// Lanes are set with [`TrialBlock::set_operands`] after a
/// [`TrialBlock::reset`]; lanes left untouched stay padding (simulated by
/// neither kernel, outputs all zero — exactly how batch padding rows
/// behave). Deviates live in lane-major `f32` buffers filled by
/// [`crate::montecarlo::MismatchSampler::fill_block`], mirroring the
/// `f32` batch layout so both execution paths see the same quantized
/// values.
#[derive(Debug, Clone, Default)]
pub struct TrialBlock {
    n: usize,
    pub(super) a: Vec<u8>,
    pub(super) b: Vec<u8>,
    pub(super) pad: Vec<bool>,
    pub(super) dvth: Vec<f32>,
    pub(super) dbeta: Vec<f32>,
    /// DAC word-line voltage per lane, filled by the executing kernel
    /// (time-invariant during the transient).
    pub(super) v_wl: Vec<f64>,
    // hoisted per-cell-lane quantities + active-lane map: kernel scratch
    // shared with the sibling fast kernel, retained across refills so
    // reuse allocates nothing
    pub(super) active: Vec<usize>,
    pub(super) vov: Vec<f64>,
    pub(super) beta: Vec<f64>,
    pub(super) gate: Vec<f64>,
    pub(super) v_lane: Vec<f64>,
    /// Per-lane outputs of the last kernel run.
    pub out: MacResultBlock,
}

impl TrialBlock {
    /// Block with buffers preallocated for `cap` lanes.
    pub fn with_capacity(cap: usize) -> Self {
        let mut blk = Self::default();
        blk.reserve(cap);
        blk
    }

    fn reserve(&mut self, cap: usize) {
        self.a.reserve(cap);
        self.b.reserve(cap);
        self.pad.reserve(cap);
        self.dvth.reserve(cap * 4);
        self.dbeta.reserve(cap * 4);
        self.v_wl.reserve(cap);
        self.active.reserve(cap);
        self.vov.reserve(cap * 4);
        self.beta.reserve(cap * 4);
        self.gate.reserve(cap * 4);
        self.v_lane.reserve(cap * 4);
    }

    /// Number of lanes (padding included).
    pub fn len(&self) -> usize {
        self.n
    }

    /// True for a zero-lane block.
    pub fn is_empty(&self) -> bool {
        self.n == 0
    }

    /// Re-shape to `n` lanes, all padding, all buffers zeroed. Capacity is
    /// retained: refilling a reused block allocates nothing per item.
    pub fn reset(&mut self, n: usize) {
        self.n = n;
        self.a.clear();
        self.a.resize(n, 0);
        self.b.clear();
        self.b.resize(n, 0);
        self.pad.clear();
        self.pad.resize(n, true);
        self.dvth.clear();
        self.dvth.resize(n * 4, 0.0);
        self.dbeta.clear();
        self.dbeta.resize(n * 4, 0.0);
        self.v_wl.clear();
        self.v_wl.resize(n, 0.0);
        self.out.reset(n);
    }

    /// Mark lane `i` live with operands `(a, b)` (4-bit each). Deviates
    /// come from the lane-major buffers ([`Self::dvth_mut`] /
    /// [`Self::dbeta_mut`]).
    pub fn set_operands(&mut self, i: usize, a: u8, b: u8) {
        assert!(a < 16 && b < 16, "operands must be 4-bit: ({a}, {b})");
        assert!(i < self.n, "lane {i} out of range 0..{}", self.n);
        self.a[i] = a;
        self.b[i] = b;
        self.pad[i] = false;
    }

    /// True when lane `i` is padding (never simulated, outputs zero).
    pub fn is_pad(&self, i: usize) -> bool {
        self.pad[i]
    }

    /// Operands of lane `i`.
    pub fn operands(&self, i: usize) -> (u8, u8) {
        (self.a[i], self.b[i])
    }

    /// DAC word-line voltage of lane `i` (V) — a hoisted, time-invariant
    /// per-lane quantity, filled by the last kernel run (zero until then).
    pub fn v_wl(&self, i: usize) -> f64 {
        self.v_wl[i]
    }

    /// VTH deviate buffer, lane-major `(lane, 4)` (V).
    pub fn dvth_mut(&mut self) -> &mut [f32] {
        &mut self.dvth
    }

    /// Relative beta deviate buffer, lane-major `(lane, 4)`.
    pub fn dbeta_mut(&mut self) -> &mut [f32] {
        &mut self.dbeta
    }

    /// Both deviate buffers at once — the shape
    /// [`crate::montecarlo::MismatchSampler::fill_block`] fills.
    pub fn deviates_mut(&mut self) -> (&mut [f32], &mut [f32]) {
        (&mut self.dvth, &mut self.dbeta)
    }

    /// The deviates of lane `i` as the `f64` sample both kernels consume
    /// (the `f32` buffer widened, matching the batch path's round trip).
    pub fn mc_sample(&self, i: usize) -> McSample {
        McSample {
            dvth: std::array::from_fn(|k| f64::from(self.dvth[i * 4 + k])),
            dbeta: std::array::from_fn(|k| f64::from(self.dbeta[i * 4 + k])),
        }
    }
}

/// Cumulative work counters a kernel exposes for observability
/// (DESIGN.md §15). Counters are additive bookkeeping only: they are
/// read by the campaign layer for trace span attributes and bench
/// provenance, and never feed a result value — the inertness contract
/// in `tests/obs.rs` pins that they cannot move artifact bytes.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct KernelCounters {
    /// Cell-lane endpoints computed (4 per live trial lane).
    pub lanes: u64,
    /// Lanes whose shortcut failed a validity check and fell back to the
    /// exact integrator (fast tier only; zero on the exact kernels).
    pub fallbacks: u64,
    /// Interpolation tables built (fast tier only).
    pub table_builds: u64,
}

impl KernelCounters {
    /// The counter movement since an earlier snapshot (saturating, so a
    /// snapshot taken across kernel instances never underflows).
    pub fn since(&self, earlier: &KernelCounters) -> KernelCounters {
        KernelCounters {
            lanes: self.lanes.saturating_sub(earlier.lanes),
            fallbacks: self.fallbacks.saturating_sub(earlier.fallbacks),
            table_builds: self.table_builds.saturating_sub(earlier.table_builds),
        }
    }
}

/// A simulation kernel: executes every live lane of a [`TrialBlock`] on a
/// [`NativeMacEngine`], writing `block.out`. Implementations must be pure
/// per lane — the campaign layer relies on lane results being independent
/// of block shape and lane order (DESIGN.md §9).
pub trait SimKernel: Sync {
    /// Short identifier for reports and bench rows.
    fn name(&self) -> &'static str;

    /// Simulate all live lanes of `block`; padding lanes keep zero outputs.
    fn simulate(&self, engine: &NativeMacEngine, block: &mut TrialBlock);

    /// Cumulative work counters since this kernel was created. The
    /// stateless exact kernels report zeros; stateful kernels (the fast
    /// tier) override with real lane/fallback/table tallies.
    fn counters(&self) -> KernelCounters {
        KernelCounters::default()
    }
}

/// The scalar oracle: one full [`NativeMacEngine::mac`] evaluation per
/// lane, numerically identical to the historical per-item batch path.
#[derive(Debug, Clone, Copy, Default)]
pub struct ScalarKernel;

impl SimKernel for ScalarKernel {
    fn name(&self) -> &'static str {
        "scalar"
    }

    fn simulate(&self, engine: &NativeMacEngine, block: &mut TrialBlock) {
        let n = block.len();
        block.out.reset(n);
        for i in 0..n {
            if block.is_pad(i) {
                continue;
            }
            let (a, b) = block.operands(i);
            let mc = block.mc_sample(i);
            block.v_wl[i] = engine.dac().v_wl(b);
            let r = engine.mac(a, b, &mc);
            block.out.v_mult[i] = r.v_mult as f32;
            for k in 0..4 {
                block.out.v_blb[i * 4 + k] = r.v_blb[k] as f32;
            }
            block.out.energy[i] = r.energy as f32;
            block.out.fault[i] = f32::from(u8::from(r.fault));
        }
    }
}

/// The data-parallel kernel: [`NativeMacEngine::mac_block`] integrates
/// every live lane in lockstep.
#[derive(Debug, Clone, Copy, Default)]
pub struct BlockKernel;

impl SimKernel for BlockKernel {
    fn name(&self) -> &'static str {
        "block"
    }

    fn simulate(&self, engine: &NativeMacEngine, block: &mut TrialBlock) {
        engine.mac_block(block);
    }
}

impl NativeMacEngine {
    /// Execute every live lane of `block` in lockstep, filling
    /// `block.out`. Bit-identical to running [`NativeMacEngine::mac`] per
    /// lane: the per-lane hoists below reproduce
    /// [`NativeMacEngine::mac_word`]'s setup value for value, the
    /// integration is [`crate::circuit::discharge_block`] (grouped as the
    /// scalar loops), and the combine/fault tail mirrors `mac_word`
    /// expression for expression.
    pub fn mac_block(&self, block: &mut TrialBlock) {
        let p = self.params();
        let cfg = *self.config();
        let card = p.device;
        let n = block.len();
        block.out.reset(n);

        // Hoist the time-invariant device quantities of every live lane
        // (4 cell lanes per trial lane), packed densely so padding costs
        // nothing downstream.
        block.active.clear();
        block.vov.clear();
        block.beta.clear();
        block.gate.clear();
        for i in 0..n {
            if block.pad[i] {
                continue;
            }
            let v_wl = self.dac().v_wl(block.b[i]);
            block.v_wl[i] = v_wl;
            let a = block.a[i];
            block.active.push(i);
            for k in 0..4 {
                let dev = Mosfet::with_mismatch(
                    card,
                    f64::from(block.dvth[i * 4 + k]),
                    f64::from(block.dbeta[i * 4 + k]),
                );
                let bit = a >> (3 - k) & 1 == 1;
                block.vov.push(v_wl - dev.vth(cfg.v_bulk));
                block.beta.push(dev.beta());
                block.gate.push(if bit { 1.0 } else { dev.card.k_leak });
            }
        }

        let m = block.active.len() * 4;
        block.v_lane.clear();
        block.v_lane.resize(m, 0.0);
        crate::circuit::discharge_block(
            p,
            &block.vov,
            &block.beta,
            &block.gate,
            cfg.t_sample,
            p.circuit.n_steps,
            &mut block.v_lane,
        );

        // Combine + fault tail, mirroring `mac_word` exactly.
        let vdd = card.vdd;
        for (j, &i) in block.active.iter().enumerate() {
            let base = j * 4;
            let a = block.a[i];
            let mut fault = false;
            for k in 0..4 {
                let bit = a >> (3 - k) & 1 == 1;
                let vov = block.vov[base + k];
                let v = block.v_lane[base + k];
                if bit && vov > 0.0 && v < vov {
                    fault = true;
                }
                block.out.v_blb[i * 4 + k] = v as f32;
            }
            let lanes = &block.v_lane[base..base + 4];
            // lint:allow(D2): fixed 4-lane weighted fold in array order — the modeled physics
            let v_mult: f64 = lanes.iter().zip(WEIGHTS).map(|(&v, w)| (vdd - v) * w).sum();
            // lint:allow(D2): fixed 4-lane weighted fold in array order — the modeled physics
            let energy: f64 = lanes.iter().map(|&v| p.circuit.c_blb * vdd * (vdd - v)).sum();
            block.out.v_mult[i] = v_mult as f32;
            block.out.energy[i] = energy as f32;
            block.out.fault[i] = f32::from(u8::from(fault));
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mac::Variant;
    use crate::montecarlo::MismatchSampler;
    use crate::params::Params;

    fn engine(v: Variant) -> NativeMacEngine {
        let p = Params::default();
        NativeMacEngine::new(p, v.config(&p))
    }

    fn fill(blk: &mut TrialBlock, n: usize, seed: u64) {
        blk.reset(n);
        let sampler = MismatchSampler::new(seed, 8e-3, 0.02);
        let (dvth, dbeta) = blk.deviates_mut();
        sampler.fill_block(0, dvth, dbeta);
        for i in 0..n {
            let a = (i * 7 % 16) as u8;
            let b = (i * 3 % 16) as u8;
            blk.set_operands(i, a, b);
        }
    }

    fn filled_block(n: usize, seed: u64) -> TrialBlock {
        let mut blk = TrialBlock::with_capacity(n);
        fill(&mut blk, n, seed);
        blk
    }

    #[test]
    fn kernels_agree_bit_for_bit() {
        for variant in Variant::ALL {
            let e = engine(variant);
            let mut scalar = filled_block(33, 9);
            let mut block = scalar.clone();
            ScalarKernel.simulate(&e, &mut scalar);
            BlockKernel.simulate(&e, &mut block);
            assert_eq!(scalar.out.v_mult.len(), block.out.v_mult.len());
            for i in 0..scalar.out.v_mult.len() {
                assert_eq!(
                    scalar.out.v_mult[i].to_bits(),
                    block.out.v_mult[i].to_bits(),
                    "{variant:?} lane {i} v_mult"
                );
                assert_eq!(
                    scalar.out.energy[i].to_bits(),
                    block.out.energy[i].to_bits(),
                    "{variant:?} lane {i} energy"
                );
                assert_eq!(scalar.out.fault[i], block.out.fault[i], "{variant:?} lane {i} fault");
            }
            assert_eq!(scalar.out.v_blb.len(), block.out.v_blb.len());
            for k in 0..scalar.out.v_blb.len() {
                assert_eq!(
                    scalar.out.v_blb[k].to_bits(),
                    block.out.v_blb[k].to_bits(),
                    "{variant:?} cell lane {k}"
                );
            }
        }
    }

    #[test]
    fn block_matches_engine_mac() {
        let e = engine(Variant::Smart);
        let mut blk = filled_block(10, 4);
        e.mac_block(&mut blk);
        for i in 0..10 {
            let (a, b) = blk.operands(i);
            let r = e.mac(a, b, &blk.mc_sample(i));
            assert_eq!(blk.out.v_mult[i].to_bits(), (r.v_mult as f32).to_bits(), "lane {i}");
            assert_eq!(blk.out.fault[i] > 0.5, r.fault, "lane {i} fault");
            // the hoisted per-lane DAC voltage is recorded on the block
            assert_eq!(blk.v_wl(i).to_bits(), e.dac().v_wl(b).to_bits(), "lane {i} v_wl");
        }
    }

    #[test]
    fn padding_lanes_stay_zero() {
        let e = engine(Variant::Aid);
        let mut blk = filled_block(8, 1);
        // re-reset and only set half the lanes
        let dvth: Vec<f32> = blk.dvth_mut().to_vec();
        blk.reset(8);
        blk.dvth_mut().copy_from_slice(&dvth);
        for i in [0usize, 2, 5, 7] {
            blk.set_operands(i, 15, 15);
        }
        e.mac_block(&mut blk);
        for i in [1usize, 3, 4, 6] {
            assert!(blk.is_pad(i));
            assert_eq!(blk.out.v_mult[i], 0.0);
            assert_eq!(blk.out.energy[i], 0.0);
            assert_eq!(blk.out.fault[i], 0.0);
            for k in 0..4 {
                assert_eq!(blk.out.v_blb[i * 4 + k], 0.0);
            }
        }
        for i in [0usize, 2, 5, 7] {
            assert!(blk.out.v_mult[i] > 0.0, "live lane {i} must simulate");
        }
    }

    #[test]
    fn reuse_does_not_leak_state() {
        // a block refilled in place (smaller, then original shape again)
        // reproduces its first run bit for bit — the coordinator reuses
        // one block per shard on exactly this contract
        let e = engine(Variant::Smart);
        let mut blk = filled_block(16, 2);
        e.mac_block(&mut blk);
        let first: Vec<u32> = blk.out.v_mult.iter().map(|v| v.to_bits()).collect();
        fill(&mut blk, 5, 77);
        e.mac_block(&mut blk);
        assert_eq!(blk.out.v_mult.len(), 5);
        fill(&mut blk, 16, 2);
        e.mac_block(&mut blk);
        let second: Vec<u32> = blk.out.v_mult.iter().map(|v| v.to_bits()).collect();
        assert_eq!(first, second);
    }

    #[test]
    #[should_panic(expected = "4-bit")]
    fn set_operands_rejects_wide_values() {
        let mut blk = TrialBlock::with_capacity(1);
        blk.reset(1);
        blk.set_operands(0, 16, 0);
    }
}
