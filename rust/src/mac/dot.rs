//! Native twin of the multi-row dot-product (VMM) path: R rows of one
//! array column discharging the shared bitlines simultaneously
//! (Fig. 7 array used as IMAC-class accelerators intend — NN layers).

use super::variant::VariantConfig;
use crate::dac::WordlineDac;
use crate::device::Mosfet;
use crate::montecarlo::McSample;
use crate::params::Params;
use crate::sram::WEIGHTS;

/// Result of one analog dot product sum_r(a_r * b_r).
#[derive(Debug, Clone)]
pub struct DotResult {
    /// Binary-weighted shared-bitline discharge voltage.
    pub v_dot: f64,
    /// Sampled shared-bitline voltages, MSB first.
    pub v_bl: [f64; 4],
    /// Raw dynamic bitline energy (J), C_bl = C_BLB * R/4.
    pub energy: f64,
    /// True if any conducting row left saturation before sampling.
    pub fault: bool,
}

/// Native shared-bitline dot-product engine.
#[derive(Debug, Clone)]
pub struct NativeDotEngine {
    params: Params,
    cfg: VariantConfig,
    dac: WordlineDac,
    rows: usize,
}

impl NativeDotEngine {
    /// Engine for `rows` simultaneously-discharging array rows.
    pub fn new(params: Params, cfg: VariantConfig, rows: usize) -> Self {
        let dac = WordlineDac::new(cfg.dac_mode, &params.device, &params.circuit, cfg.v_bulk);
        Self { params, cfg, dac, rows }
    }

    /// Array rows per dot product.
    pub fn rows(&self) -> usize {
        self.rows
    }

    /// WL pulse width convention: `t_sample / 4` keeps the all-rows-max
    /// full scale equal to the single-row MAC's (C_bl grows with R).
    pub fn t_sample(&self) -> f64 {
        self.cfg.t_sample / 4.0
    }

    /// One dot product: `weights[r]` stored in row r, `codes[r]` on its WL,
    /// with per-row mismatch samples.
    pub fn dot(&self, weights: &[u8], codes: &[u8], mc: &[McSample]) -> DotResult {
        assert_eq!(weights.len(), self.rows);
        assert_eq!(codes.len(), self.rows);
        assert_eq!(mc.len(), self.rows);
        let p = &self.params;
        let c_bl = p.circuit.c_blb * self.rows as f64 / 4.0;
        let n_steps = p.circuit.n_steps;
        let dt = self.t_sample() / f64::from(n_steps);
        let vdd = p.device.vdd;

        // Pre-resolve per-(row, cell) overdrive, beta, gate.
        let mut vov = vec![[0.0f64; 4]; self.rows];
        let mut dev = vec![[Mosfet::nominal(p.device); 4]; self.rows];
        let mut gate = vec![[0.0f64; 4]; self.rows];
        for r in 0..self.rows {
            let v_wl = self.dac.v_wl(codes[r]);
            for c in 0..4 {
                let m = Mosfet::with_mismatch(p.device, mc[r].dvth[c], mc[r].dbeta[c]);
                vov[r][c] = v_wl - m.vth(self.cfg.v_bulk);
                gate[r][c] = if weights[r] >> (3 - c) & 1 == 1 { 1.0 } else { p.device.k_leak };
                dev[r][c] = m;
            }
        }

        // Shared-bitline forward-Euler transient, one state per cell column.
        let mut v = [vdd; 4];
        for _ in 0..n_steps {
            for (c, vc) in v.iter_mut().enumerate() {
                let mut i_total = 0.0;
                for r in 0..self.rows {
                    // lint:allow(D2): KCL row-current sum in fixed array order — the modeled physics
                    i_total += dev[r][c].drain_current_vov(vov[r][c], *vc) * gate[r][c];
                }
                *vc = (*vc - i_total * dt / c_bl).max(0.0);
            }
        }

        let mut fault = false;
        for r in 0..self.rows {
            for c in 0..4 {
                if weights[r] >> (3 - c) & 1 == 1 && vov[r][c] > 0.0 && v[c] < vov[r][c] {
                    fault = true;
                }
            }
        }
        // lint:allow(D2): fixed 4-column weighted fold in array order — the modeled physics
        let v_dot: f64 = v.iter().zip(WEIGHTS).map(|(&vc, w)| (vdd - vc) * w).sum();
        // lint:allow(D2): fixed 4-column weighted fold in array order — the modeled physics
        let energy: f64 = v.iter().map(|&vc| c_bl * vdd * (vdd - vc)).sum();
        DotResult { v_dot, v_bl: v, energy, fault }
    }

    /// Nominal full scale: all rows storing 15, all codes 15, no mismatch.
    pub fn full_scale(&self) -> f64 {
        let w = vec![15u8; self.rows];
        let c = vec![15u8; self.rows];
        let mc = vec![McSample::nominal(); self.rows];
        self.dot(&w, &c, &mc).v_dot
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mac::Variant;

    fn engine(rows: usize) -> NativeDotEngine {
        let p = Params::default();
        NativeDotEngine::new(p, Variant::Smart.config(&p), rows)
    }

    #[test]
    fn single_row_matches_mac_engine() {
        let p = Params::default();
        let cfg = Variant::Aid.config(&p);
        let dot = NativeDotEngine::new(p, cfg, 1);
        let mac = crate::mac::NativeMacEngine::new(p, cfg);
        let d = dot.dot(&[15], &[15], &[McSample::nominal()]);
        let m = mac.mac(15, 15, &McSample::nominal());
        // R=1: C/4 with t/4 -> identical dt/C
        assert!((d.v_dot - m.v_mult).abs() < 1e-9, "{} vs {}", d.v_dot, m.v_mult);
    }

    #[test]
    fn additive_in_saturation() {
        let e = engine(4);
        let nom = vec![McSample::nominal(); 4];
        let a = e.dot(&[9, 0, 0, 0], &[12, 0, 0, 0], &nom).v_dot;
        let b = e.dot(&[0, 0, 5, 0], &[0, 0, 7, 0], &nom).v_dot;
        let ab = e.dot(&[9, 0, 5, 0], &[12, 0, 7, 0], &nom).v_dot;
        assert!((ab - a - b).abs() < 3e-3, "{ab} vs {a}+{b}");
    }

    #[test]
    fn tracks_integer_dot_product() {
        let e = engine(8);
        let nom = vec![McSample::nominal(); 8];
        let fs = e.full_scale();
        let w = [3u8, 15, 7, 0, 9, 12, 1, 5];
        let c = [14u8, 2, 8, 15, 4, 11, 6, 0];
        let got = e.dot(&w, &c, &nom).v_dot;
        let exact: u32 = w.iter().zip(c).map(|(&a, b)| u32::from(a) * u32::from(b)).sum();
        let ideal = fs * f64::from(exact) / (8.0 * 225.0);
        assert!((got - ideal).abs() < 0.05 * fs, "{got} vs {ideal}");
    }

    #[test]
    fn no_fault_at_design_point_full_activation() {
        let e = engine(16);
        let nom = vec![McSample::nominal(); 16];
        let r = e.dot(&[15; 16], &[15; 16], &nom);
        assert!(!r.fault);
        assert!(r.v_dot > 0.1);
    }

    #[test]
    fn full_scale_invariant_in_rows() {
        // C_bl ∝ R with t = t0/4 keeps full scale constant
        let f4 = engine(4).full_scale();
        let f16 = engine(16).full_scale();
        assert!((f4 - f16).abs() < 2e-3, "{f4} vs {f16}");
    }
}
