//! Design variants compared in the paper (Table 1).

use crate::dac::DacMode;
use crate::params::Params;

/// The designs the paper evaluates head-to-head.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Variant {
    /// This paper: AID's sqrt DAC + 0.6 V forward body bias (dual-VDD).
    Smart,
    /// AID [10]: sqrt DAC, no body bias, 1.0 V supply.
    Aid,
    /// IMAC [9]: linear DAC, no body bias, 1.2 V supply.
    Imac,
    /// Ablation: SMART's body bias applied to IMAC's linear DAC (Fig. 9).
    SmartOnImac,
}

impl Variant {
    /// Every design variant, in Table 1 order.
    pub const ALL: [Variant; 4] =
        [Variant::Smart, Variant::Aid, Variant::Imac, Variant::SmartOnImac];

    /// Display name with the paper's citation tags (Table 1 row labels).
    pub fn name(self) -> &'static str {
        match self {
            Self::Smart => "SMART",
            Self::Aid => "AID [10]",
            Self::Imac => "IMAC [9]",
            Self::SmartOnImac => "SMART-on-IMAC",
        }
    }

    /// Config-file token — round-trips through [`std::str::FromStr`], and
    /// is what campaign/sweep artifacts store.
    pub fn token(self) -> &'static str {
        match self {
            Self::Smart => "smart",
            Self::Aid => "aid",
            Self::Imac => "imac",
            Self::SmartOnImac => "smart-on-imac",
        }
    }

    /// Circuit configuration for this variant.
    pub fn config(self, p: &Params) -> VariantConfig {
        let c = &p.circuit;
        match self {
            Self::Smart => VariantConfig {
                variant: self,
                dac_mode: DacMode::Sqrt,
                v_bulk: c.v_bulk_smart,
                supply: 1.0,
                t_sample: c.t_sample,
            },
            Self::Aid => VariantConfig {
                variant: self,
                dac_mode: DacMode::Sqrt,
                v_bulk: 0.0,
                supply: 1.0,
                t_sample: c.t_sample,
            },
            Self::Imac => VariantConfig {
                variant: self,
                dac_mode: DacMode::Linear,
                v_bulk: 0.0,
                supply: 1.2,
                t_sample: c.t_sample,
            },
            Self::SmartOnImac => VariantConfig {
                variant: self,
                dac_mode: DacMode::Linear,
                v_bulk: c.v_bulk_smart,
                supply: 1.0,
                t_sample: c.t_sample,
            },
        }
    }
}

impl std::str::FromStr for Variant {
    type Err = String;

    fn from_str(s: &str) -> Result<Self, Self::Err> {
        match s.to_ascii_lowercase().as_str() {
            "smart" => Ok(Self::Smart),
            "aid" => Ok(Self::Aid),
            "imac" => Ok(Self::Imac),
            "smart-on-imac" | "smartonimac" => Ok(Self::SmartOnImac),
            other => Err(format!("unknown variant '{other}' (smart|aid|imac|smart-on-imac)")),
        }
    }
}

/// Resolved per-variant circuit knobs.
#[derive(Debug, Clone, Copy)]
pub struct VariantConfig {
    /// The variant these knobs belong to.
    pub variant: Variant,
    /// WL DAC transfer curve (Eq. 7 linear / Eq. 8 sqrt).
    pub dac_mode: DacMode,
    /// Forward body bias on the access transistors (V).
    pub v_bulk: f64,
    /// Peripheral supply (V) — enters the energy model only; the cell
    /// array itself runs at the card's VDD in all variants.
    pub supply: f64,
    /// WL pulse width at the sampling instant (s).
    pub t_sample: f64,
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::params::Params;

    #[test]
    fn smart_is_aid_plus_body_bias() {
        let p = Params::default();
        let s = Variant::Smart.config(&p);
        let a = Variant::Aid.config(&p);
        assert_eq!(s.dac_mode, a.dac_mode);
        assert_eq!(s.v_bulk, 0.6);
        assert_eq!(a.v_bulk, 0.0);
    }

    #[test]
    fn imac_uses_linear_dac_at_1v2() {
        let p = Params::default();
        let i = Variant::Imac.config(&p);
        assert_eq!(i.dac_mode, DacMode::Linear);
        assert_eq!(i.supply, 1.2);
    }

    #[test]
    fn from_str_roundtrip() {
        for v in Variant::ALL {
            let s = v.name().split_whitespace().next().unwrap().to_lowercase();
            let parsed: Variant = s.parse().unwrap();
            assert_eq!(parsed, v);
        }
        assert!("bogus".parse::<Variant>().is_err());
    }

    #[test]
    fn token_roundtrip() {
        for v in Variant::ALL {
            assert_eq!(v.token().parse::<Variant>().unwrap(), v);
        }
    }
}
