//! The analog in-SRAM MAC engine built on the native simulator, plus the
//! design-variant table (SMART vs the state-of-the-art baselines) and the
//! sense/reconstruction model.
//!
//! One MAC stores operand `a` in a 4-cell word, DAC-codes operand `b`
//! onto the word line, integrates the four BLB discharges for
//! `t_sample`, and combines them with binary weights — paper Fig. 7 /
//! DESIGN.md §3. [`Variant`] captures the head-to-head designs of
//! Table 1; [`NativeMacEngine`] is the single-MAC oracle the campaign
//! layer cross-checks the AOT path against. Campaign-scale execution
//! goes through the block layer ([`TrialBlock`], [`SimKernel`],
//! DESIGN.md §9): many trials in one struct-of-arrays block, integrated
//! in lockstep by [`BlockKernel`], lane-by-lane by the [`ScalarKernel`]
//! oracle, or by the [`FastKernel`] surrogate tier — closed-form and
//! table endpoints within a documented tolerance of the oracle
//! (DESIGN.md §13), selected by [`KernelKind`].

mod block;
mod dot;
mod engine;
mod fast;
mod ideal;
mod variant;

pub use block::{BlockKernel, KernelCounters, MacResultBlock, ScalarKernel, SimKernel, TrialBlock};
pub use fast::{FastKernel, KernelKind, FAST_TOLERANCE};
pub use dot::{DotResult, NativeDotEngine};
pub use engine::{MacResult, NativeMacEngine};
pub use ideal::{exact_code4, reconstruct, reconstruct4, IdealTransfer, SenseAmp};
pub use variant::{Variant, VariantConfig};
