//! The analog in-SRAM MAC engine built on the native simulator, plus the
//! design-variant table (SMART vs the state-of-the-art baselines) and the
//! sense/reconstruction model.

mod dot;
mod engine;
mod ideal;
mod variant;

pub use dot::{DotResult, NativeDotEngine};
pub use engine::{MacResult, NativeMacEngine};
pub use ideal::{exact_code4, reconstruct, reconstruct4, IdealTransfer, SenseAmp};
pub use variant::{Variant, VariantConfig};
