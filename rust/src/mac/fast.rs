//! The fast surrogate kernel tier (DESIGN.md §13).
//!
//! [`FastKernel`] replaces the per-lane per-step Euler integration of the
//! bit-exact kernels with two error-bounded shortcuts:
//!
//! * **closed-form saturation endpoint** — inside saturation the channel
//!   current is *exactly* linear in the bitline voltage
//!   (`i = half_bv2 + half_bv2·λ·v`), so the forward-Euler recurrence is
//!   an affine map whose n-th iterate has a closed form. Where the lane
//!   provably never leaves saturation, the closed form reproduces the
//!   oracle trajectory to floating-point rounding (~1e-14 V at 256
//!   steps);
//! * **per-configuration interpolation tables** — lanes that do leave
//!   saturation (overlong pulses, low supplies) read their endpoint from
//!   a bilinear table over (V_ov, β), built once per device/timing
//!   configuration by the exact [`crate::circuit::discharge_lane`]
//!   integrator and cached process-wide.
//!
//! Weak/cutoff lanes freeze the subthreshold current over the pulse (one
//! or two current evaluations instead of 256), accepting the shortcut
//! only when a midpoint refinement confirms the current is constant to
//! well below the tolerance. Every lane that fails its validity check
//! falls back to the exact integrator, so the kernel is *always* within
//! the documented tolerance — speed degrades before accuracy does.
//!
//! The contract is a **stated tolerance**, not bit-identity: every lane
//! endpoint is within [`FAST_TOLERANCE`] volts of the [`ScalarKernel`]
//! oracle, and fault flags agree exactly (the crossing construction below
//! makes the saturation-exit decision provable, not approximate). Per-
//! configuration measured errors are pinned in `configs/fast_tol.toml`
//! and enforced by `tests/fast_kernel.rs`. Because results are not
//! bit-identical to the other kernels, the kernel choice is an *identity*
//! field ([`KernelKind`] on [`crate::coordinator::CampaignSpec`]) — it
//! appears in artifacts, serve cache keys, and sweep checkpoint rows.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, OnceLock, PoisonError};

use crate::device::Mosfet;
use crate::params::{DeviceCard, Params};
use crate::sram::WEIGHTS;

use super::block::{KernelCounters, SimKernel, TrialBlock};
use super::engine::NativeMacEngine;

/// Documented global endpoint tolerance of the fast tier: the maximum
/// |Δv| on any bitline endpoint versus the bit-exact [`ScalarKernel`]
/// oracle, in volts (DESIGN.md §13). Chosen at the same order as the
/// Euler-vs-RK4 discretization bound already accepted by the simulator
/// (2 mV, see `euler_discretization_error_is_bounded`); the measured
/// per-configuration errors in `configs/fast_tol.toml` sit orders of
/// magnitude below it.
///
/// [`ScalarKernel`]: super::ScalarKernel
pub const FAST_TOLERANCE: f64 = 2.5e-3;

/// Guard band around the saturation boundary (V): a closed-form endpoint
/// within this distance of `vov` cannot be classified reliably against
/// floating-point drift, so the lane falls back to the exact integrator.
const CROSS_GUARD: f64 = 1e-6;

/// Clamp margin keeping table endpoints strictly below `vov` (V), so the
/// fault flag of a lane that provably left saturation agrees with the
/// oracle by construction.
const FAULT_MARGIN: f64 = 1e-9;

/// Weak-lane frozen-current acceptance: a total discharge below this (V)
/// makes the current constant to ~1e-8 V of endpoint error.
const FREEZE_EPS: f64 = 1e-4;

/// Weak-lane midpoint acceptance: the frozen and midpoint-refined
/// discharges must agree within this (V) for the refinement to stand.
const MID_EPS: f64 = 1e-5;

/// Which [`SimKernel`] executes a campaign's trial blocks.
///
/// `Scalar` and `Block` are bit-identical to each other (DESIGN.md §9);
/// `Fast` is accurate to [`FAST_TOLERANCE`] instead (DESIGN.md §13).
/// Because the fast tier can move aggregate bytes, the kernel choice is
/// an **identity** field: it is recorded in `mc.json`/`sweep.csv`/
/// checkpoint rows and forks the `smart serve` cache keys, unlike the
/// `shards`/`threads`/`block` performance knobs.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum KernelKind {
    /// The per-item [`super::ScalarKernel`] oracle.
    Scalar,
    /// The lockstep [`super::BlockKernel`] (the default).
    #[default]
    Block,
    /// The [`FastKernel`] table/closed-form surrogate.
    Fast,
}

impl KernelKind {
    /// Every kernel tier, in `scalar|block|fast` order.
    pub const ALL: [KernelKind; 3] = [KernelKind::Scalar, KernelKind::Block, KernelKind::Fast];

    /// Canonical token used in artifacts, TOML specs, and `--kernel`.
    pub fn token(self) -> &'static str {
        match self {
            KernelKind::Scalar => "scalar",
            KernelKind::Block => "block",
            KernelKind::Fast => "fast",
        }
    }
}

impl std::str::FromStr for KernelKind {
    type Err = String;

    fn from_str(s: &str) -> Result<Self, Self::Err> {
        match s {
            "scalar" => Ok(KernelKind::Scalar),
            "block" => Ok(KernelKind::Block),
            "fast" => Ok(KernelKind::Fast),
            other => Err(format!("unknown kernel '{other}' (scalar|block|fast)")),
        }
    }
}

impl std::fmt::Display for KernelKind {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.token())
    }
}

/// Precomputed endpoint table for fully-conducting strong-inversion lanes
/// that leave saturation before sampling: bilinear interpolation over
/// (V_ov, β), node values integrated by the exact
/// [`crate::circuit::discharge_lane`] at `gate = 1`.
#[derive(Debug)]
struct FastTable {
    vov_lo: f64,
    vov_step: f64,
    n_vov: usize,
    beta_lo: f64,
    beta_step: f64,
    n_beta: usize,
    /// Endpoints, vov-major `(n_vov, n_beta)`.
    v: Vec<f64>,
}

/// V_ov axis resolution: ~2 mV spacing over the reachable overdrive range
/// keeps the bilinear error well under a tenth of [`FAST_TOLERANCE`].
const TABLE_N_VOV: usize = 257;

/// β axis resolution over ±30% of the nominal card value (>10 sigma of
/// the mismatch model, and wide enough for every process corner).
const TABLE_N_BETA: usize = 33;

impl FastTable {
    fn build(p: &Params, t_sample: f64, vov_hi: f64) -> Self {
        let card = &p.device;
        let vov_lo = 3.0 * card.vt_thermal;
        let vov_hi = vov_hi.max(vov_lo + 0.05);
        let beta_nom = card.beta();
        let beta_lo = 0.7 * beta_nom;
        let beta_hi = 1.3 * beta_nom;
        let vov_step = (vov_hi - vov_lo) / (TABLE_N_VOV - 1) as f64;
        let beta_step = (beta_hi - beta_lo) / (TABLE_N_BETA - 1) as f64;
        let mut v = Vec::with_capacity(TABLE_N_VOV * TABLE_N_BETA);
        for iv in 0..TABLE_N_VOV {
            let vov = vov_lo + iv as f64 * vov_step;
            for ib in 0..TABLE_N_BETA {
                let beta = beta_lo + ib as f64 * beta_step;
                v.push(crate::circuit::discharge_lane(
                    p,
                    vov,
                    beta,
                    1.0,
                    t_sample,
                    p.circuit.n_steps,
                ));
            }
        }
        Self { vov_lo, vov_step, n_vov: TABLE_N_VOV, beta_lo, beta_step, n_beta: TABLE_N_BETA, v }
    }

    /// Bilinear lookup; `None` when `(vov, beta)` falls outside the grid
    /// (the caller then takes the exact fallback).
    fn lookup(&self, vov: f64, beta: f64) -> Option<f64> {
        let x = (vov - self.vov_lo) / self.vov_step;
        let y = (beta - self.beta_lo) / self.beta_step;
        if !(x >= 0.0 && y >= 0.0) {
            return None;
        }
        let ix = x.floor() as usize;
        let iy = y.floor() as usize;
        if ix + 1 >= self.n_vov || iy + 1 >= self.n_beta {
            return None;
        }
        let fx = x - ix as f64;
        let fy = y - iy as f64;
        let at = |i: usize, j: usize| self.v[i * self.n_beta + j];
        let v0 = at(ix, iy) * (1.0 - fy) + at(ix, iy + 1) * fy;
        let v1 = at(ix + 1, iy) * (1.0 - fy) + at(ix + 1, iy + 1) * fy;
        Some(v0 * (1.0 - fx) + v1 * fx)
    }
}

/// Cache key of one table configuration: exact round-trip renderings of
/// every quantity the node values depend on. Two engines with the same
/// fingerprint would build byte-identical tables, so sharing is safe.
fn table_fingerprint(p: &Params, t_sample: f64, vov_hi: f64) -> u64 {
    let card = &p.device;
    let text = format!(
        // lint:allow(D5): fingerprint needs exact roundtrip floats, not canon rounding
        "{:e}|{:e}|{:e}|{:e}|{:e}|{:e}|{}|{:e}|{:e}",
        card.vdd,
        card.lam,
        card.vt_thermal,
        card.n_sub,
        card.beta(),
        t_sample,
        p.circuit.n_steps,
        p.circuit.c_blb,
        vov_hi,
    );
    crate::util::fnv1a(&text)
}

/// The weak/cutoff drain current of [`Mosfet::drain_current_vov`] below
/// the `3·vt` cut, replicated term for term at one bitline voltage `v`.
/// Returns the current and whether the square-law branch won the `max`
/// (the branch winner must be stable across the pulse for the frozen-
/// current shortcut to be valid).
fn weak_current(card: &DeviceCard, vov: f64, beta: f64, v: f64) -> (f64, bool) {
    let vt = card.vt_thermal;
    let i_sub = beta * vt * vt * (vov.min(0.0) / (card.n_sub * vt)).exp()
        * (1.0 - (-v.max(0.0) / vt).exp());
    if vov > 0.0 {
        let lam = card.lam;
        let clm = 1.0 + lam * v;
        let i_on = if v >= vov {
            0.5 * beta * vov * vov * clm
        } else {
            beta * (vov - 0.5 * v) * v * clm
        };
        let on = i_on.max(0.0);
        (on.max(i_sub), on >= i_sub)
    } else {
        (i_sub, false)
    }
}

/// The fast surrogate kernel (DESIGN.md §13): closed-form saturation
/// endpoints, per-configuration interpolation tables for saturation-exit
/// lanes, frozen-current weak lanes — every lane within
/// [`FAST_TOLERANCE`] of the [`super::ScalarKernel`] oracle, with exact
/// fault-flag agreement, falling back to the exact integrator whenever a
/// validity check fails.
///
/// Tables are built lazily on the first saturation-exit lane of a given
/// device/timing configuration and cached for the life of the kernel;
/// use [`FastKernel::shared`] so campaigns, shards, and serve workers
/// reuse one cache.
#[derive(Debug, Default)]
pub struct FastKernel {
    tables: Mutex<std::collections::BTreeMap<u64, Arc<FastTable>>>,
    // Work tallies for observability ([`SimKernel::counters`]): relaxed
    // atomics because they are read only as after-the-fact snapshots —
    // they never gate a lane's execution path (DESIGN.md §15).
    lanes: AtomicU64,
    fallbacks: AtomicU64,
    table_builds: AtomicU64,
}

impl FastKernel {
    /// A kernel with an empty table cache.
    pub fn new() -> Self {
        Self::default()
    }

    /// The process-wide shared instance: table construction costs a few
    /// milliseconds per configuration, so campaign dispatch shares one
    /// cache across every shard, thread, and campaign.
    pub fn shared() -> &'static FastKernel {
        static SHARED: OnceLock<FastKernel> = OnceLock::new();
        SHARED.get_or_init(FastKernel::new)
    }

    /// The endpoint table for `engine`'s configuration, built on first use.
    fn table(&self, engine: &NativeMacEngine) -> Arc<FastTable> {
        let p = engine.params();
        let cfg = engine.config();
        let card = &p.device;
        // Upper overdrive bound: the strongest DAC code minus the nominal
        // threshold, plus 0.10 V of headroom for mismatch/corner shifts
        // (>12 sigma of the vth model). Lanes beyond it fall back.
        let vov_hi = engine.dac().v_wl(15) - card.vth_effective(cfg.v_bulk, 0.0) + 0.10;
        let key = table_fingerprint(p, cfg.t_sample, vov_hi);
        let mut tables = self.tables.lock().unwrap_or_else(PoisonError::into_inner);
        if let Some(t) = tables.get(&key) {
            return Arc::clone(t);
        }
        let t = Arc::new(FastTable::build(p, cfg.t_sample, vov_hi));
        tables.insert(key, Arc::clone(&t));
        let _ = self
            .table_builds
            .fetch_update(Ordering::Relaxed, Ordering::Relaxed, |v| {
                Some(v.saturating_add(1))
            });
        t
    }

    /// One cell lane's endpoint. The decision tree (DESIGN.md §13):
    ///
    /// * strong inversion, closed form stays in saturation → closed form
    ///   (exact to fp rounding; no fault, provably);
    /// * strong inversion, closed form crosses below `vov` → the oracle
    ///   provably faults; fully-conducting lanes read the endpoint from
    ///   the table, clamped below `vov` so the flag agrees;
    /// * weak/cutoff → frozen or midpoint-refined subthreshold current;
    /// * anything unprovable → exact [`crate::circuit::discharge_lane`].
    fn endpoint(
        &self,
        engine: &NativeMacEngine,
        table: &mut Option<Arc<FastTable>>,
        vov: f64,
        beta: f64,
        gate: f64,
    ) -> f64 {
        let p = engine.params();
        let cfg = engine.config();
        let card = &p.device;
        let vt = card.vt_thermal;
        let n_steps = p.circuit.n_steps;
        let dt_c = (cfg.t_sample / f64::from(n_steps)) / p.circuit.c_blb;
        let exact = || {
            let _ = self
                .fallbacks
                .fetch_update(Ordering::Relaxed, Ordering::Relaxed, |v| {
                    Some(v.saturating_add(1))
                });
            crate::circuit::discharge_lane(p, vov, beta, gate, cfg.t_sample, n_steps)
        };

        if vov >= 3.0 * vt {
            // Saturation current is exactly linear in v:
            //   i = half_bv2·(1 + λ·v)  ⇒  v' = v·(1 − h·half_bv2·λ) − h·half_bv2
            // with h = gate·dt_c — an affine map with fixed point −1/λ,
            // so the n-th iterate is (v0 + 1/λ)·rⁿ − 1/λ. The trajectory
            // is strictly decreasing; it equals the oracle's until the
            // first step below vov, hence:
            //   v_cf ≥ vov  ⇔  the oracle never left saturation.
            let h = gate * dt_c;
            let a = 0.5 * beta * vov * vov;
            let lam = card.lam;
            let v_cf = if lam.abs() < 1e-12 {
                card.vdd - f64::from(n_steps) * h * a
            } else {
                let r = 1.0 - h * a * lam;
                if r <= 0.0 {
                    // step size too coarse for the closed form's stability
                    return exact();
                }
                let v_star = -1.0 / lam;
                (card.vdd - v_star) * r.powi(n_steps as i32) + v_star
            };
            if v_cf >= vov + CROSS_GUARD {
                return v_cf;
            }
            if v_cf <= vov - CROSS_GUARD && gate == 1.0 {
                // The oracle provably left saturation (fault = true): the
                // endpoint comes from the exact-integrator table, clamped
                // strictly below vov so the recomputed flag agrees.
                let t = table.get_or_insert_with(|| self.table(engine));
                if let Some(v_tab) = t.lookup(vov, beta) {
                    return v_tab.min(vov - FAULT_MARGIN).max(0.0);
                }
            }
            // within the guard band of the boundary, leaking gate, or
            // outside the table grid: integrate exactly
            exact()
        } else {
            // Weak/cutoff: the subthreshold current barely moves over a
            // design-timing pulse, so freeze it at v = vdd...
            let (i0, on0) = weak_current(card, vov, beta, card.vdd);
            let dv = f64::from(n_steps) * i0 * gate * dt_c;
            if dv <= FREEZE_EPS {
                return card.vdd - dv;
            }
            // ...or refine once at the midpoint of the predicted drop.
            // Valid only when the two estimates agree, the max-branch
            // winner is stable, and the endpoint stays far above both the
            // fault threshold and the exponential's sensitive region.
            let (i_m, on_m) = weak_current(card, vov, beta, card.vdd - 0.5 * dv);
            let dv2 = f64::from(n_steps) * i_m * gate * dt_c;
            let end = card.vdd - dv2;
            if (dv2 - dv).abs() <= MID_EPS && on0 == on_m && end >= vov.max(10.0 * vt) {
                return end;
            }
            exact()
        }
    }
}

impl SimKernel for FastKernel {
    fn name(&self) -> &'static str {
        "fast"
    }

    fn simulate(&self, engine: &NativeMacEngine, block: &mut TrialBlock) {
        let p = engine.params();
        let cfg = *engine.config();
        let card = p.device;
        let n = block.len();
        block.out.reset(n);

        // Hoist the time-invariant device quantities of every live lane —
        // value for value the same setup as `NativeMacEngine::mac_block`,
        // reusing the block's kernel scratch.
        block.active.clear();
        block.vov.clear();
        block.beta.clear();
        block.gate.clear();
        for i in 0..n {
            if block.pad[i] {
                continue;
            }
            let v_wl = engine.dac().v_wl(block.b[i]);
            block.v_wl[i] = v_wl;
            let a = block.a[i];
            block.active.push(i);
            for k in 0..4 {
                let dev = Mosfet::with_mismatch(
                    card,
                    f64::from(block.dvth[i * 4 + k]),
                    f64::from(block.dbeta[i * 4 + k]),
                );
                let bit = a >> (3 - k) & 1 == 1;
                block.vov.push(v_wl - dev.vth(cfg.v_bulk));
                block.beta.push(dev.beta());
                block.gate.push(if bit { 1.0 } else { dev.card.k_leak });
            }
        }

        // Per-lane surrogate endpoints (pure per lane: independent of
        // block shape and lane order, like the exact kernels). The table
        // handle is resolved lazily so configurations whose lanes never
        // exit saturation — the design point — build no table at all.
        let m = block.active.len() * 4;
        block.v_lane.clear();
        block.v_lane.resize(m, 0.0);
        let mut table: Option<Arc<FastTable>> = None;
        for j in 0..m {
            block.v_lane[j] = self.endpoint(
                engine,
                &mut table,
                block.vov[j],
                block.beta[j],
                block.gate[j],
            );
        }
        let m_lanes = m as u64;
        let _ = self
            .lanes
            .fetch_update(Ordering::Relaxed, Ordering::Relaxed, |v| {
                Some(v.saturating_add(m_lanes))
            });

        // Combine + fault tail, mirroring `mac_word` exactly.
        let vdd = card.vdd;
        for (j, &i) in block.active.iter().enumerate() {
            let base = j * 4;
            let a = block.a[i];
            let mut fault = false;
            for k in 0..4 {
                let bit = a >> (3 - k) & 1 == 1;
                let vov = block.vov[base + k];
                let v = block.v_lane[base + k];
                if bit && vov > 0.0 && v < vov {
                    fault = true;
                }
                block.out.v_blb[i * 4 + k] = v as f32;
            }
            let lanes = &block.v_lane[base..base + 4];
            // lint:allow(D2): fixed 4-lane weighted fold in array order — the modeled physics
            let v_mult: f64 = lanes.iter().zip(WEIGHTS).map(|(&v, w)| (vdd - v) * w).sum();
            // lint:allow(D2): fixed 4-lane weighted fold in array order — the modeled physics
            let energy: f64 = lanes.iter().map(|&v| p.circuit.c_blb * vdd * (vdd - v)).sum();
            block.out.v_mult[i] = v_mult as f32;
            block.out.energy[i] = energy as f32;
            block.out.fault[i] = f32::from(u8::from(fault));
        }
    }

    fn counters(&self) -> KernelCounters {
        KernelCounters {
            lanes: self.lanes.load(Ordering::Relaxed),
            fallbacks: self.fallbacks.load(Ordering::Relaxed),
            table_builds: self.table_builds.load(Ordering::Relaxed),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::super::{ScalarKernel, Variant};
    use super::*;
    use crate::montecarlo::MismatchSampler;

    #[test]
    fn kernel_kind_tokens_roundtrip() {
        for k in KernelKind::ALL {
            assert_eq!(k.token().parse::<KernelKind>(), Ok(k));
            assert_eq!(k.to_string(), k.token());
        }
        assert_eq!(KernelKind::default(), KernelKind::Block);
        let err = "bogus".parse::<KernelKind>().unwrap_err();
        assert!(err.contains("unknown kernel 'bogus'"), "{err}");
        assert!(err.contains("scalar|block|fast"), "{err}");
    }

    fn filled_block(n: usize, seed: u64) -> TrialBlock {
        let mut blk = TrialBlock::with_capacity(n);
        blk.reset(n);
        let sampler = MismatchSampler::new(seed, 8e-3, 0.02);
        let (dvth, dbeta) = blk.deviates_mut();
        sampler.fill_block(0, dvth, dbeta);
        for i in 0..n {
            blk.set_operands(i, (i * 7 % 16) as u8, (i * 3 % 16) as u8);
        }
        blk
    }

    fn assert_within_tolerance(engine: &NativeMacEngine, n: usize, seed: u64) {
        let mut fast = filled_block(n, seed);
        let mut oracle = fast.clone();
        FastKernel::new().simulate(engine, &mut fast);
        ScalarKernel.simulate(engine, &mut oracle);
        for i in 0..n {
            for k in 0..4 {
                let dv =
                    f64::from(fast.out.v_blb[i * 4 + k]) - f64::from(oracle.out.v_blb[i * 4 + k]);
                assert!(
                    dv.abs() <= FAST_TOLERANCE,
                    "lane {i} cell {k}: |dv| = {} > {FAST_TOLERANCE}",
                    dv.abs()
                );
            }
            assert_eq!(fast.out.fault[i], oracle.out.fault[i], "lane {i} fault flag");
        }
    }

    #[test]
    fn fast_matches_oracle_within_tolerance_all_variants() {
        for variant in Variant::ALL {
            let p = Params::default();
            let engine = NativeMacEngine::new(p, variant.config(&p));
            assert_within_tolerance(&engine, 33, 0xFA57);
        }
    }

    #[test]
    fn saturation_exit_lanes_use_the_table_and_agree_on_faults() {
        // An overlong pulse drives every conducting lane out of
        // saturation (the `overlong_pulse_faults` condition): the table
        // path must stay within tolerance and flag exactly the oracle's
        // faults.
        let p = Params::default();
        let mut cfg = Variant::Smart.config(&p);
        cfg.t_sample = 2e-9;
        let engine = NativeMacEngine::new(p, cfg);
        assert_within_tolerance(&engine, 24, 0xFA11);
    }

    #[test]
    fn padding_lanes_stay_zero() {
        let p = Params::default();
        let engine = NativeMacEngine::new(p, Variant::Smart.config(&p));
        let mut blk = TrialBlock::with_capacity(4);
        blk.reset(4);
        blk.set_operands(1, 15, 15);
        FastKernel::new().simulate(&engine, &mut blk);
        for i in [0usize, 2, 3] {
            assert_eq!(blk.out.v_mult[i], 0.0, "pad lane {i}");
            assert_eq!(blk.out.fault[i], 0.0, "pad lane {i}");
        }
        assert!(blk.out.v_mult[1] > 0.0, "live lane must simulate");
    }

    #[test]
    fn table_cache_is_shared_and_keyed_on_configuration() {
        let p = Params::default();
        let kernel = FastKernel::new();
        let mut cfg = Variant::Smart.config(&p);
        cfg.t_sample = 2e-9; // saturation-exit regime: forces a table
        let engine = NativeMacEngine::new(p, cfg);
        let a = kernel.table(&engine);
        let b = kernel.table(&engine);
        assert!(Arc::ptr_eq(&a, &b), "same configuration must share one table");
        let mut other = Variant::Smart.config(&p);
        other.t_sample = 1e-9;
        let c = kernel.table(&NativeMacEngine::new(p, other));
        assert!(!Arc::ptr_eq(&a, &c), "different timing must fork the table");
    }

    #[test]
    fn counters_tally_lanes_fallbacks_and_table_builds() {
        let p = Params::default();
        let kernel = FastKernel::new();
        assert_eq!(kernel.counters(), KernelCounters::default());
        // Exact kernels report zeros through the trait default.
        assert_eq!(SimKernel::counters(&ScalarKernel), KernelCounters::default());

        // Design-point regime: every lane takes a shortcut, no table.
        let engine = NativeMacEngine::new(p, Variant::Smart.config(&p));
        let mut blk = filled_block(8, 3);
        kernel.simulate(&engine, &mut blk);
        let after = kernel.counters();
        assert_eq!(after.lanes, 32, "4 cell lanes per trial lane");
        assert_eq!(after.table_builds, 0, "no saturation exit, no table");

        // Saturation-exit regime forces a table build; counters only grow.
        let mut cfg = Variant::Smart.config(&p);
        cfg.t_sample = 2e-9;
        let engine = NativeMacEngine::new(p, cfg);
        let mut blk = filled_block(8, 3);
        kernel.simulate(&engine, &mut blk);
        let end = kernel.counters();
        assert_eq!(end.lanes, 64);
        assert_eq!(end.table_builds, 1);
        let delta = end.since(&after);
        assert_eq!(delta.lanes, 32);
        assert_eq!(delta.table_builds, 1);
    }

    #[test]
    fn closed_form_equals_the_iterated_recurrence_in_saturation() {
        // A lane that never exits saturation: the closed form must agree
        // with the exact integrator to fp rounding, far below tolerance.
        let p = Params::default();
        let engine = NativeMacEngine::new(p, Variant::Smart.config(&p));
        let dev = Mosfet::nominal(p.device);
        let vov = engine.dac().v_wl(15) - dev.vth(0.6);
        let beta = dev.beta();
        let exact = crate::circuit::discharge_lane(
            &p,
            vov,
            beta,
            1.0,
            p.circuit.t_sample,
            p.circuit.n_steps,
        );
        let kernel = FastKernel::new();
        let mut table = None;
        let got = kernel.endpoint(&engine, &mut table, vov, beta, 1.0);
        assert!((got - exact).abs() < 1e-9, "closed form {got} vs exact {exact}");
        assert!(table.is_none(), "no saturation exit, no table");
    }
}
