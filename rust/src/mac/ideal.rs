//! Ideal transfer, sense amplifier, and digital reconstruction: how the
//! analog V_multiplication is interpreted back into a product code.

use super::engine::NativeMacEngine;

/// The ideal (mismatch-free) transfer the accuracy metrics compare against:
/// V_ideal(a, b) = (a/15) * (b/15) * full_scale.
#[derive(Debug, Clone, Copy)]
pub struct IdealTransfer {
    /// Nominal full-scale output V_ideal(15, 15) in volts.
    pub full_scale: f64,
}

impl IdealTransfer {
    /// Calibrate from a variant's nominal full-scale output.
    pub fn calibrate(engine: &NativeMacEngine) -> Self {
        Self { full_scale: engine.full_scale() }
    }

    /// Ideal analog output for operands `a`, `b`.
    pub fn v_ideal(&self, a: u8, b: u8) -> f64 {
        self.full_scale * (a as f64 / 15.0) * (b as f64 / 15.0)
    }

    /// Normalize a measured voltage into product units (0..=225).
    pub fn to_product_units(&self, v: f64) -> f64 {
        v / self.full_scale * 225.0
    }
}

/// Sense-amplifier model: input-referred offset + quantizing comparator.
#[derive(Debug, Clone, Copy)]
pub struct SenseAmp {
    /// Input-referred RMS offset (V). ~2 mV for a 65 nm StrongARM latch.
    pub sigma_offset: f64,
}

impl Default for SenseAmp {
    fn default() -> Self {
        Self { sigma_offset: 2e-3 }
    }
}

/// Reconstruct the digital product code from the analog output: quantize
/// V_mult against the ideal transfer's 8-bit (0..225) product grid.
/// Returns the nearest product value.
pub fn reconstruct(ideal: &IdealTransfer, v_mult: f64) -> u16 {
    let units = ideal.to_product_units(v_mult);
    units.round().clamp(0.0, 225.0) as u16
}

/// 4-bit readout: quantize to the 16-level output grid the architecture's
/// sense stage resolves (the paper's BER is about confusing *these*
/// levels; the full 8-bit product is below the analog noise floor).
pub fn reconstruct4(ideal: &IdealTransfer, v_mult: f64) -> u8 {
    let code = v_mult / ideal.full_scale * 15.0;
    code.round().clamp(0.0, 15.0) as u8
}

/// The 4-bit output code an exact multiplier would produce for (a, b).
pub fn exact_code4(a: u8, b: u8) -> u8 {
    ((u16::from(a) * u16::from(b)) as f64 / 225.0 * 15.0).round() as u8
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mac::{NativeMacEngine, Variant};
    use crate::montecarlo::McSample;
    use crate::params::Params;

    fn engine() -> NativeMacEngine {
        let p = Params::default();
        NativeMacEngine::new(p, Variant::Smart.config(&p))
    }

    #[test]
    fn ideal_corners() {
        let e = engine();
        let t = IdealTransfer::calibrate(&e);
        assert_eq!(t.v_ideal(0, 15), 0.0);
        assert!((t.v_ideal(15, 15) - t.full_scale).abs() < 1e-15);
        assert!((t.to_product_units(t.full_scale) - 225.0).abs() < 1e-9);
    }

    #[test]
    fn reconstruct_nominal_max_code_exact() {
        let e = engine();
        let t = IdealTransfer::calibrate(&e);
        let r = e.mac(15, 15, &McSample::nominal());
        assert_eq!(reconstruct(&t, r.v_mult), 225);
    }

    #[test]
    fn reconstruct_scales_with_stored_operand() {
        // sqrt DAC makes the B axis linear and the A axis is binary
        // weighting, so nominal a*15 reconstructs near a*15 exactly.
        let e = engine();
        let t = IdealTransfer::calibrate(&e);
        for a in 0..16u8 {
            let r = e.mac(a, 15, &McSample::nominal());
            let got = reconstruct(&t, r.v_mult);
            let want = a as u16 * 15;
            assert!(
                (got as i32 - want as i32).abs() <= 3,
                "a={a}: got {got}, want {want}"
            );
        }
    }

    #[test]
    fn reconstruct_clamps() {
        let t = IdealTransfer { full_scale: 0.4 };
        assert_eq!(reconstruct(&t, -0.1), 0);
        assert_eq!(reconstruct(&t, 0.9), 225);
    }
}
