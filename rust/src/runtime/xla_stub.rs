//! API-compatible stub of the `xla` crate surface [`super`] uses.
//!
//! This build environment carries no PJRT/XLA native library, so the FFI
//! bindings cannot link. The stub keeps the whole L3 runtime compiling and
//! behaviorally honest: opening a runtime and reading manifests works,
//! while anything that would need the real compiler/executor fails with a
//! clear error. Swapping `use xla_stub as xla;` in `runtime/mod.rs` for
//! the real crate re-enables the AOT path unchanged (DESIGN.md §2).

use std::path::Path;

use anyhow::{anyhow, Error, Result};

fn unavailable() -> Error {
    anyhow!("XLA/PJRT backend is not available in this offline build; use --native")
}

/// Stub PJRT client. Construction succeeds (so manifests can be inspected);
/// compilation fails.
pub struct PjRtClient;

impl PjRtClient {
    /// Always succeeds — manifests stay inspectable offline.
    pub fn cpu() -> Result<Self> {
        Ok(Self)
    }

    /// Stub platform tag, distinguishable from a real PJRT CPU client.
    pub fn platform_name(&self) -> String {
        "cpu-stub (xla unavailable)".to_string()
    }

    /// Always fails: there is no compiler behind the stub.
    pub fn compile(&self, _computation: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(unavailable())
    }
}

/// Stub loaded executable — never actually constructed (compile fails).
pub struct PjRtLoadedExecutable {
    _priv: (),
}

impl PjRtLoadedExecutable {
    /// Always fails (unreachable in practice — compile never succeeds).
    pub fn execute<T>(&self, _args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(unavailable())
    }
}

/// Stub device buffer.
pub struct PjRtBuffer;

impl PjRtBuffer {
    /// Always fails (unreachable in practice).
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(unavailable())
    }
}

/// Stub HLO module proto. Text loading always errors — there is no parser
/// behind it, and honest failure here is what the failure-injection tests
/// exercise.
pub struct HloModuleProto;

impl HloModuleProto {
    /// Always fails, naming the artifact that could not be loaded.
    pub fn from_text_file(path: &Path) -> Result<Self> {
        Err(anyhow!(
            "cannot load HLO text '{}': XLA/PJRT backend is not available in this offline build",
            path.display()
        ))
    }
}

/// Stub computation wrapper.
pub struct XlaComputation;

impl XlaComputation {
    /// Wraps a (stub) proto; trivially succeeds.
    pub fn from_proto(_proto: &HloModuleProto) -> Self {
        Self
    }
}

/// Stub host literal.
pub struct Literal;

impl Literal {
    /// Host-side literal construction trivially succeeds.
    pub fn vec1(_values: &[f32]) -> Literal {
        Literal
    }

    /// Host-side literal construction trivially succeeds.
    pub fn scalar(_value: f32) -> Literal {
        Literal
    }

    /// Host-side reshape trivially succeeds.
    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal> {
        Ok(Literal)
    }

    /// Always fails: no device data exists to read back.
    pub fn to_vec<T>(&self) -> Result<Vec<T>> {
        Err(unavailable())
    }

    /// Always fails: no device data exists to read back.
    pub fn to_tuple(self) -> Result<Vec<Literal>> {
        Err(unavailable())
    }

    /// Always fails: no device data exists to read back.
    pub fn to_tuple1(self) -> Result<Literal> {
        Err(unavailable())
    }
}
