//! Artifact manifest: the index `python/compile/aot.py` writes next to the
//! HLO text files, plus the mirrored model card.

use std::path::Path;

use anyhow::{Context, Result};

use crate::params::Params;
use crate::util::json::{self, Value};

/// One AOT artifact entry.
#[derive(Debug, Clone)]
pub struct Artifact {
    /// Lookup key (e.g. `mac_b256`).
    pub name: String,
    /// HLO text file path, relative to the artifact directory.
    pub path: String,
    /// Artifact family (`mac`, `trace`, `dot`).
    pub kind: String,
    /// Compiled batch size.
    pub batch: usize,
    /// Trace artifacts only: number of time points.
    pub n_points: Option<usize>,
}

/// `artifacts/manifest.json`.
#[derive(Debug, Clone)]
pub struct Manifest {
    /// Every artifact, in manifest order.
    pub artifacts: Vec<Artifact>,
    /// Batch sizes of the compiled MAC artifacts.
    pub mac_batches: Vec<usize>,
    /// Batch sizes of the waveform-trace artifacts.
    pub trace_batches: Vec<usize>,
    /// Time points per waveform trace.
    pub trace_points: usize,
    /// Batch sizes of the multi-row dot-product artifacts (may be empty
    /// for manifests generated before the VMM extension).
    pub dot_batches: Vec<usize>,
    /// Row count R of the dot artifacts.
    pub dot_rows: usize,
    /// Transient integration steps the kernels were compiled with.
    pub n_steps: u32,
    /// The mirrored model card (`params.json`), when present.
    pub params: Option<Params>,
}

impl Manifest {
    /// Load manifest + the mirrored params from an artifact directory.
    pub fn load(dir: &Path) -> Result<Self> {
        let text = std::fs::read_to_string(dir.join("manifest.json"))
            .context("manifest.json missing — run `make artifacts`")?;
        let mut m = Self::parse(&text)?;
        if let Ok(ptext) = std::fs::read_to_string(dir.join("params.json")) {
            m.params = Some(Params::load_artifact_json(&ptext)?);
        }
        Ok(m)
    }

    /// Parse the manifest JSON body.
    pub fn parse(text: &str) -> Result<Self> {
        let v = json::parse(text).map_err(|e| anyhow::anyhow!("{e}"))?;
        let usizes = |key: &str| -> Result<Vec<usize>> {
            v.get(key)
                .and_then(Value::as_arr)
                .ok_or_else(|| anyhow::anyhow!("manifest '{key}' missing"))?
                .iter()
                .map(|x| {
                    x.as_u64()
                        .and_then(|n| usize::try_from(n).ok())
                        .ok_or_else(|| anyhow::anyhow!("bad entry in '{key}'"))
                })
                .collect()
        };
        let to_usize = |key: &str, n: u64| -> Result<usize> {
            usize::try_from(n).map_err(|_| anyhow::anyhow!("manifest '{key}' = {n} exceeds usize"))
        };
        let mut artifacts = Vec::new();
        for a in v
            .get("artifacts")
            .and_then(Value::as_arr)
            .ok_or_else(|| anyhow::anyhow!("manifest 'artifacts' missing"))?
        {
            let s = |k: &str| -> Result<String> {
                Ok(a.get(k)
                    .and_then(Value::as_str)
                    .ok_or_else(|| anyhow::anyhow!("artifact '{k}' missing"))?
                    .to_string())
            };
            artifacts.push(Artifact {
                name: s("name")?,
                path: s("path")?,
                kind: s("kind")?,
                batch: to_usize(
                    "batch",
                    a.get("batch")
                        .and_then(Value::as_u64)
                        .ok_or_else(|| anyhow::anyhow!("artifact 'batch' missing"))?,
                )?,
                n_points: a
                    .get("n_points")
                    .and_then(Value::as_u64)
                    .map(|n| to_usize("n_points", n))
                    .transpose()?,
            });
        }
        let dot_batches = if v.get("dot_batches").is_some() {
            usizes("dot_batches")?
        } else {
            Vec::new()
        };
        let mac_batches = usizes("mac_batches")?;
        anyhow::ensure!(
            !mac_batches.is_empty(),
            "manifest 'mac_batches' is empty — the artifact bundle has no MAC kernel"
        );
        Ok(Self {
            artifacts,
            mac_batches,
            trace_batches: usizes("trace_batches")?,
            dot_batches,
            dot_rows: to_usize("dot_rows", v.get("dot_rows").and_then(Value::as_u64).unwrap_or(0))?,
            trace_points: to_usize(
                "trace_points",
                v.get("trace_points").and_then(Value::as_u64).unwrap_or(0),
            )?,
            n_steps: {
                let n = v.get("n_steps").and_then(Value::as_u64).unwrap_or(0);
                u32::try_from(n)
                    .map_err(|_| anyhow::anyhow!("manifest 'n_steps' = {n} exceeds u32"))?
            },
            params: None,
        })
    }

    /// Look up an artifact by its manifest name.
    pub fn find(&self, name: &str) -> Option<&Artifact> {
        self.artifacts.iter().find(|a| a.name == name)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_generated_manifest_shape() {
        let json = r#"{
            "artifacts": [
                {"name": "mac_b1", "path": "mac_b1.hlo.txt", "kind": "mac", "batch": 1},
                {"name": "trace_b8", "path": "trace_b8.hlo.txt", "kind": "trace", "batch": 8, "n_points": 64}
            ],
            "mac_batches": [1, 256, 1024],
            "trace_batches": [8],
            "trace_points": 64,
            "n_steps": 256
        }"#;
        let m = Manifest::parse(json).unwrap();
        assert_eq!(m.mac_batches, vec![1, 256, 1024]);
        assert_eq!(m.find("mac_b1").unwrap().batch, 1);
        assert_eq!(m.find("trace_b8").unwrap().n_points, Some(64));
        assert!(m.find("nope").is_none());
        assert_eq!(m.n_steps, 256);
    }

    #[test]
    fn rejects_malformed_manifest() {
        assert!(Manifest::parse("{}").is_err());
        let bad = r#"{"artifacts": [{"name": 3}], "mac_batches": [], "trace_batches": []}"#;
        assert!(Manifest::parse(bad).is_err());
    }
}
